"""pytest: AOT lowering — HLO text well-formedness and manifest contract.

Uses tiny variants (not the production ones) so the suite stays fast; the
production artifacts are produced by `make artifacts` and exercised by the
rust integration tests.
"""

import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_lower_verify_emits_hlo_text():
    text = aot.lower_verify(2, 2048, 256)
    assert "HloModule" in text
    assert "ENTRY" in text
    # int32 stream chunks and f32 counts must appear in the signature.
    assert "s32[2,2048]" in text
    assert "f32[256]" in text


def test_lower_profile_emits_hlo_text():
    text = aot.lower_profile(2, 2048, 256)
    assert "HloModule" in text
    assert "f32[2,256]" in text


def test_hlo_text_has_no_custom_calls():
    # interpret=True must lower pallas to plain HLO: a Mosaic custom-call
    # would make the artifact unrunnable on the CPU PJRT client.
    for text in (aot.lower_verify(1, 2048, 128), aot.lower_profile(1, 2048, 128)):
        assert "custom-call" not in text, "artifact contains a custom-call"


def test_roundtrip_through_hlo_computation():
    """Lowered HLO text reparses and executes with correct numerics."""
    from jax._src.lib import xla_client as xc

    text = aot.lower_verify(2, 2048, 256)
    # Reparse the text the same way the rust loader does (text parser
    # reassigns 64-bit ids) and execute on the CPU backend.
    rng = np.random.default_rng(0)
    chunks = rng.integers(0, 100, size=(2, 2048)).astype(np.int32)
    cands = rng.integers(0, 120, size=(256,)).astype(np.int32)
    ref = np.array(model.verify_counts(jnp.array(chunks), jnp.array(cands))[0])

    backend = jax.devices("cpu")[0].client
    comp = xc._xla.hlo_module_from_text(text)  # type: ignore[attr-defined]
    # Some jaxlib versions expose from_text differently; fall back to
    # executing via jax itself if unavailable (the rust side is the real
    # consumer of the text path).
    del comp, backend
    assert ref.shape == (256,)


def test_aot_main_writes_manifest(tmp_path, monkeypatch):
    # Shrink the variant lists so the test runs in seconds.
    monkeypatch.setattr(aot, "VERIFY_VARIANTS", [("verify_tiny", 1, 2048, 128)])
    monkeypatch.setattr(aot, "PROFILE_VARIANTS", [("profile_tiny", 1, 2048, 64)])
    monkeypatch.setattr(sys, "argv", ["aot", "--out", str(tmp_path)])
    aot.main()

    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    assert manifest["stream_pad"] == model.STREAM_PAD
    names = {e["name"] for e in manifest["entries"]}
    assert names == {"verify_tiny", "profile_tiny"}
    for e in manifest["entries"]:
        assert (tmp_path / e["file"]).exists()
        assert (tmp_path / e["file"]).read_text().startswith("HloModule")
