"""pytest: Pallas kernels vs pure-jnp oracle — the CORE L1 correctness signal.

hypothesis sweeps shapes/dtypes/value ranges; every property asserts
bit-exact agreement (counts are integers represented in f32, so allclose
with atol=0 is the right check).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import block_histogram, candidate_count, fib_hash32
from compile.kernels.ref import (
    block_histogram_ref,
    candidate_count_ref,
    fib_hash32_ref,
)
from compile import model


def _stream(rng, n, lo=0, hi=1000, dtype=np.int32):
    return rng.integers(lo, hi, size=n).astype(dtype)


# ---------------------------------------------------------------- candidate


class TestCandidateCount:
    def test_basic(self):
        rng = np.random.default_rng(1)
        s = _stream(rng, 8192)
        c = _stream(rng, 256)
        out = candidate_count(jnp.array(s), jnp.array(c))
        ref = candidate_count_ref(jnp.array(s), jnp.array(c))
        np.testing.assert_allclose(np.array(out), np.array(ref), atol=0)

    def test_multi_tile_grid(self):
        rng = np.random.default_rng(2)
        s = _stream(rng, 4 * 2048, hi=100)
        c = _stream(rng, 4 * 64, hi=120)
        out = candidate_count(jnp.array(s), jnp.array(c), block_b=2048, block_k=64)
        ref = candidate_count_ref(jnp.array(s), jnp.array(c))
        np.testing.assert_allclose(np.array(out), np.array(ref), atol=0)

    def test_absent_candidates_zero(self):
        s = jnp.zeros((2048,), jnp.int32)
        c = jnp.arange(1, 65, dtype=jnp.int32)
        out = candidate_count(s, c)
        assert np.array(out).sum() == 0

    def test_all_same_item(self):
        s = jnp.full((2048,), 7, jnp.int32)
        c = jnp.array([7] + [0] * 63, jnp.int32)
        out = np.array(candidate_count(s, c))
        assert out[0] == 2048
        assert out[1:].sum() == 0

    def test_sentinels_never_match(self):
        # stream pad (-2) and candidate pad (-1) must not collide.
        s = jnp.full((2048,), model.STREAM_PAD, jnp.int32)
        c = jnp.full((64,), model.CANDIDATE_PAD, jnp.int32)
        assert np.array(candidate_count(s, c)).sum() == 0

    def test_rejects_ragged(self):
        with pytest.raises(ValueError):
            candidate_count(
                jnp.zeros((3000,), jnp.int32),
                jnp.zeros((64,), jnp.int32),
                block_b=2048,
            )

    @settings(max_examples=25, deadline=None)
    @given(
        n_tiles=st.integers(1, 4),
        k_tiles=st.integers(1, 4),
        block_b=st.sampled_from([128, 512, 2048]),
        block_k=st.sampled_from([32, 128]),
        hi=st.integers(2, 5000),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, n_tiles, k_tiles, block_b, block_k, hi, seed):
        rng = np.random.default_rng(seed)
        s = _stream(rng, n_tiles * block_b, hi=hi)
        c = _stream(rng, k_tiles * block_k, hi=hi)
        out = candidate_count(jnp.array(s), jnp.array(c), block_b=block_b, block_k=block_k)
        ref = candidate_count_ref(jnp.array(s), jnp.array(c))
        np.testing.assert_allclose(np.array(out), np.array(ref), atol=0)

    @settings(max_examples=10, deadline=None)
    @given(
        dtype=st.sampled_from([np.int32, np.uint32, np.int64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_dtypes(self, dtype, seed):
        # ids are encoded into [0, 2^31) on the rust side; any int dtype
        # carrying such values must agree after the int32 cast.
        rng = np.random.default_rng(seed)
        s = _stream(rng, 1024, hi=2**31 - 1, dtype=dtype)
        c = _stream(rng, 128, hi=2**31 - 1, dtype=dtype)
        c[:16] = s[:16]  # force some hits
        out = candidate_count(jnp.array(s), jnp.array(c), block_b=512, block_k=64)
        ref = candidate_count_ref(jnp.array(s), jnp.array(c))
        np.testing.assert_allclose(np.array(out), np.array(ref), atol=0)

    def test_duplicate_candidates_counted_independently(self):
        s = jnp.array([5] * 100 + [9] * 28, jnp.int32)
        c = jnp.array([5, 5, 9, 0] * 16, jnp.int32)
        out = np.array(candidate_count(s, c, block_b=128, block_k=64))
        assert (out[c == 5] == 100).all() if hasattr(out, "all") else True
        np.testing.assert_array_equal(out[np.array(c) == 5], 100)
        np.testing.assert_array_equal(out[np.array(c) == 9], 28)


# ---------------------------------------------------------------- histogram


class TestBlockHistogram:
    def test_basic(self):
        rng = np.random.default_rng(3)
        s = _stream(rng, 8192, hi=10**6)
        out = block_histogram(jnp.array(s), num_buckets=1024)
        ref = block_histogram_ref(jnp.array(s), 1024)
        np.testing.assert_allclose(np.array(out), np.array(ref), atol=0)

    def test_total_mass_preserved(self):
        rng = np.random.default_rng(4)
        s = _stream(rng, 6 * 2048, hi=10**9)
        out = np.array(block_histogram(jnp.array(s), num_buckets=512))
        assert out.sum() == s.size

    def test_rejects_non_power_of_two(self):
        with pytest.raises(ValueError):
            block_histogram(jnp.zeros((2048,), jnp.int32), num_buckets=300)

    @settings(max_examples=20, deadline=None)
    @given(
        n_tiles=st.integers(1, 4),
        nb=st.sampled_from([64, 256, 1024]),
        hi=st.integers(2, 10**9),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis(self, n_tiles, nb, hi, seed):
        rng = np.random.default_rng(seed)
        s = _stream(rng, n_tiles * 2048, hi=hi)
        out = block_histogram(jnp.array(s), num_buckets=nb)
        ref = block_histogram_ref(jnp.array(s), nb)
        np.testing.assert_allclose(np.array(out), np.array(ref), atol=0)

    def test_hash_matches_ref(self):
        x = jnp.arange(0, 4096, dtype=jnp.int32)
        np.testing.assert_array_equal(
            np.array(fib_hash32(x, 1024)), np.array(fib_hash32_ref(x, 1024))
        )

    def test_hash_range(self):
        rng = np.random.default_rng(5)
        x = jnp.array(_stream(rng, 4096, hi=2**31 - 1))
        for nb in (64, 256, 4096):
            h = np.array(fib_hash32(x, nb))
            assert h.min() >= 0 and h.max() < nb


# ---------------------------------------------------------------- L2 model


class TestModel:
    def test_verify_counts_matches_flat_ref(self):
        rng = np.random.default_rng(6)
        s = _stream(rng, 8 * 2048, hi=300)
        c = _stream(rng, 2048, hi=300)
        out = model.verify_counts(jnp.array(s.reshape(8, 2048)), jnp.array(c))
        ref = candidate_count_ref(jnp.array(s), jnp.array(c))
        np.testing.assert_allclose(np.array(out[0]), np.array(ref), atol=0)

    def test_verify_counts_pad_chunks_ignored(self):
        rng = np.random.default_rng(7)
        s = _stream(rng, 2 * 2048, hi=300)
        pad = np.full((2, 2048), model.STREAM_PAD, np.int32)
        chunks = np.concatenate([s.reshape(2, 2048), pad])
        c = _stream(rng, 2048, hi=300)
        out = model.verify_counts(jnp.array(chunks), jnp.array(c))
        ref = candidate_count_ref(jnp.array(s), jnp.array(c))
        np.testing.assert_allclose(np.array(out[0]), np.array(ref), atol=0)

    def test_skew_profile_shape_and_mass(self):
        rng = np.random.default_rng(8)
        s = _stream(rng, 4 * 2048, hi=10**6)
        out = np.array(model.skew_profile(jnp.array(s.reshape(4, 2048)), num_buckets=256)[0])
        assert out.shape == (4, 256)
        np.testing.assert_array_equal(out.sum(axis=1), 2048)

    @settings(max_examples=10, deadline=None)
    @given(chunks=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
    def test_verify_counts_hypothesis(self, chunks, seed):
        rng = np.random.default_rng(seed)
        s = _stream(rng, chunks * 2048, hi=500)
        c = _stream(rng, 512, hi=500)
        out = model.verify_counts(jnp.array(s.reshape(chunks, 2048)), jnp.array(c))
        ref = candidate_count_ref(jnp.array(s), jnp.array(c))
        np.testing.assert_allclose(np.array(out[0]), np.array(ref), atol=0)
