"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest/hypothesis suite compares against;
they make no tiling or memory-hierarchy assumptions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import numpy as _np
_FIB_MULT = _np.uint32(2654435769)


def candidate_count_ref(stream: jax.Array, candidates: jax.Array) -> jax.Array:
    """(K,) float32 exact counts of each candidate in stream."""
    eq = stream.astype(jnp.int32)[:, None] == candidates.astype(jnp.int32)[None, :]
    return jnp.sum(eq.astype(jnp.float32), axis=0)


def fib_hash32_ref(x: jax.Array, num_buckets: int) -> jax.Array:
    shift = 32 - int(num_buckets).bit_length() + 1
    return ((x.astype(jnp.uint32) * _FIB_MULT) >> shift).astype(jnp.int32)


def block_histogram_ref(stream: jax.Array, num_buckets: int) -> jax.Array:
    """(num_buckets,) float32 totals via segment_sum."""
    b = fib_hash32_ref(stream, num_buckets)
    return jax.ops.segment_sum(
        jnp.ones_like(b, dtype=jnp.float32), b, num_segments=num_buckets
    )
