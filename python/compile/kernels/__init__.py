"""L1 Pallas kernels (build-time only; lowered into AOT artifacts)."""

from .candidate_count import candidate_count  # noqa: F401
from .histogram import block_histogram, fib_hash32  # noqa: F401
