"""L1 Pallas kernel: per-block bucketed histogram of hashed item ids.

Second kernel of the offline pipeline: a coarse *sketch pre-pass* that
histograms stream blocks into ``num_buckets`` hash buckets.  The rust
coordinator uses it to (a) estimate block skew for adaptive sharding and
(b) cheaply bound which blocks can contain heavy candidates (a bucket's
total is an upper bound on any item hashed into it, CountMin-style with
one row).

TPU formulation: bucketing is a one-hot scatter, expressed densely as
compare-against-iota + matmul-shaped reduce, so it lands on VPU+MXU just
like candidate_count.  Buckets accumulate in VMEM across the stream grid
axis.

The hash is a Fibonacci multiplicative hash (Knuth) on int32, kept
bit-exact with the rust side (`pss::gen::fib_hash32`) and with ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 2048

# Knuth's 32-bit Fibonacci multiplier (2**32 / phi, odd).  Kept as a plain
# python int: weak typing keeps the product uint32 and avoids capturing a
# traced constant inside the pallas kernel body.
import numpy as _np
_FIB_MULT = _np.uint32(2654435769)


def fib_hash32(x: jax.Array, num_buckets: int) -> jax.Array:
    """Fibonacci multiplicative hash into [0, num_buckets).

    num_buckets must be a power of two; the bucket index is taken from the
    *high* bits of the product, which is where this hash mixes well.
    """
    shift = 32 - int(num_buckets).bit_length() + 1
    h = (x.astype(jnp.uint32) * _FIB_MULT) >> shift
    return h.astype(jnp.int32)


def _hist_kernel(stream_ref, out_ref, *, num_buckets: int):
    sb = pl.program_id(0)

    items = stream_ref[...]
    buckets = fib_hash32(items, num_buckets)

    # Dense one-hot scatter: (B, num_buckets) match vs bucket iota, then
    # column-reduce (MXU-shaped, same trick as candidate_count).
    iota = jax.lax.iota(jnp.int32, num_buckets)
    onehot = (buckets[:, None] == iota[None, :]).astype(jnp.float32)
    partial = jnp.sum(onehot, axis=0)

    @pl.when(sb == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(sb != 0)
    def _acc():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("num_buckets", "block_b"))
def block_histogram(
    stream: jax.Array,
    *,
    num_buckets: int = 1024,
    block_b: int = DEFAULT_BLOCK_B,
) -> jax.Array:
    """Histogram ``stream`` into ``num_buckets`` hash buckets.

    Args:
      stream: (N,) int32/uint32 ids, N a multiple of block_b.
      num_buckets: power of two, <= 4096 to respect the VMEM budget.

    Returns:
      (num_buckets,) float32 bucket totals.
    """
    n = stream.shape[0]
    if n % block_b != 0:
        raise ValueError(f"stream length {n} not a multiple of {block_b}")
    if num_buckets & (num_buckets - 1) != 0:
        raise ValueError("num_buckets must be a power of two")

    kernel = functools.partial(_hist_kernel, num_buckets=num_buckets)
    return pl.pallas_call(
        kernel,
        grid=(n // block_b,),
        in_specs=[pl.BlockSpec((block_b,), lambda sb: (sb,))],
        out_specs=pl.BlockSpec((num_buckets,), lambda sb: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_buckets,), jnp.float32),
        interpret=True,
    )(stream.astype(jnp.int32))
