"""L1 Pallas kernel: batched candidate-frequency counting.

The paper's hot loop (hash-table counter updates) is pointer-chasing and,
as the paper's own Intel-Phi experiment shows, hostile to wide SIMD.  The
dense, data-parallel part of the pipeline is *candidate verification*:
given the <=K candidate items reported by (parallel) Space Saving and the
raw stream, compute every candidate's exact frequency.  That is what this
kernel does, reformulated for a TPU-like memory hierarchy:

  - the stream is processed in blocks of ``block_b`` items; each grid step
    stages one block from HBM into VMEM (BlockSpec),
  - the block is compared against the full candidate vector (broadcast
    compare -> (B, K) one-hot match matrix, formed only in registers/VMEM,
    never materialized in HBM),
  - the match matrix is column-reduced; on a real TPU the reduction
    ``ones(1,B) @ match(B,K)`` maps onto the MXU systolic array while the
    compare feeds the VPU,
  - partial counts accumulate into the output block, which Pallas keeps
    resident in VMEM across grid steps (same out index_map every step).

VMEM budget (see DESIGN.md SHardware-Adaptation): with B=2048, K<=8192,
the staged operands are B*4 + K*4 bytes and the transient match tile is
B*K*4 bytes float32 at worst; we sub-tile K with a second grid axis so the
live tile stays under ~8 MiB.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; the interpret path lowers to plain HLO so the same kernel
runs inside the AOT artifact consumed by the rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes, chosen for the VMEM budget documented above.
DEFAULT_BLOCK_B = 2048
DEFAULT_BLOCK_K = 1024


def _count_kernel(stream_ref, cand_ref, out_ref):
    """One grid step: count occurrences of cand block within stream block.

    Grid = (num_stream_blocks, num_cand_blocks).  The output block index
    depends only on the candidate-block axis, so Pallas accumulates the
    stream axis in VMEM without HBM round-trips.
    """
    sb = pl.program_id(0)

    # (B,) items and (Kb,) candidates staged in VMEM by BlockSpec.
    items = stream_ref[...]
    cands = cand_ref[...]

    # (B, Kb) one-hot match matrix; compare on the VPU.
    match = (items[:, None] == cands[None, :]).astype(jnp.float32)
    # Column sum == ones(1,B) @ match -> MXU-shaped reduction.
    partial = jnp.sum(match, axis=0)

    # First stream block initializes the accumulator, later blocks add.
    @pl.when(sb == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(sb != 0)
    def _acc():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("block_b", "block_k"))
def candidate_count(
    stream: jax.Array,
    candidates: jax.Array,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """Exact frequency of every candidate in ``stream``.

    Args:
      stream: (N,) int32/uint32 item ids; N must be a multiple of block_b
        (pad with a sentinel absent from candidates, e.g. 0xFFFFFFFF).
      candidates: (K,) item ids; K must be a multiple of block_k.
      block_b / block_k: VMEM tile sizes.

    Returns:
      (K,) float32 counts (float so the reduction is MXU-friendly; exact
      for counts < 2**24, far above any realistic block budget).
    """
    n = stream.shape[0]
    k = candidates.shape[0]
    # Clamp tiles to the operand shapes (small inputs use a single tile).
    block_b = min(block_b, n)
    block_k = min(block_k, k)
    if n % block_b != 0:
        raise ValueError(f"stream length {n} not a multiple of {block_b}")
    if k % block_k != 0:
        raise ValueError(f"candidate length {k} not a multiple of {block_k}")

    grid = (n // block_b, k // block_k)
    return pl.pallas_call(
        _count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda sb, kb: (sb,)),
            pl.BlockSpec((block_k,), lambda sb, kb: (kb,)),
        ],
        out_specs=pl.BlockSpec((block_k,), lambda sb, kb: (kb,)),
        out_shape=jax.ShapeDtypeStruct((k,), jnp.float32),
        interpret=True,
    )(stream.astype(jnp.int32), candidates.astype(jnp.int32))
