"""AOT: lower the L2 graphs to HLO *text* artifacts for the rust runtime.

HLO text — NOT ``lowered.compile()`` / ``.serialize()`` — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published
`xla` rust crate binds) rejects (`proto.id() <= INT_MAX`).  The text
parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --out ../artifacts

Emits one ``<name>.hlo.txt`` per variant plus ``manifest.json`` describing
shapes/dtypes so the rust loader can validate its inputs.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (name, C chunks per call, B chunk length, K candidate slots)
VERIFY_VARIANTS = [
    ("verify_16x65536x2048", 16, 65536, 2048),
    ("verify_16x65536x8192", 16, 65536, 8192),
    ("verify_1x65536x2048", 1, 65536, 2048),
]

# (name, C, B, num_buckets)
PROFILE_VARIANTS = [
    ("profile_16x65536x1024", 16, 65536, 1024),
]


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_verify(c: int, b: int, k: int) -> str:
    chunks = jax.ShapeDtypeStruct((c, b), jnp.int32)
    cands = jax.ShapeDtypeStruct((k,), jnp.int32)
    return to_hlo_text(jax.jit(model.verify_counts).lower(chunks, cands))


def lower_profile(c: int, b: int, nb: int) -> str:
    chunks = jax.ShapeDtypeStruct((c, b), jnp.int32)
    fn = lambda x: model.skew_profile(x, num_buckets=nb)  # noqa: E731
    return to_hlo_text(jax.jit(fn).lower(chunks))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"format": "hlo-text", "stream_pad": model.STREAM_PAD,
                "candidate_pad": model.CANDIDATE_PAD, "entries": []}

    for name, c, b, k in VERIFY_VARIANTS:
        text = lower_verify(c, b, k)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append({
            "name": name, "kind": "verify", "chunks": c, "chunk_len": b,
            "k": k, "file": f"{name}.hlo.txt",
            "inputs": [["i32", [c, b]], ["i32", [k]]],
            "outputs": [["f32", [k]]],
        })
        print(f"wrote {path} ({len(text)} chars)")

    for name, c, b, nb in PROFILE_VARIANTS:
        text = lower_profile(c, b, nb)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append({
            "name": name, "kind": "profile", "chunks": c, "chunk_len": b,
            "num_buckets": nb, "file": f"{name}.hlo.txt",
            "inputs": [["i32", [c, b]]],
            "outputs": [["f32", [c, nb]]],
        })
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
