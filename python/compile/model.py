"""L2: the offline verification compute graph, built on the L1 kernels.

The paper (S1) distinguishes the on-line setting from the off-line one, in
which "a parallel scan of the input can be used to determine the actual
frequent items" and discard false positives.  This module is that parallel
scan, as a single fused XLA program:

  verify_counts : (C, B) stream chunks x (K,) candidates -> (K,) exact counts
  skew_profile  : (C, B) stream chunks -> (C, NB) per-chunk hash histograms

Both are lowered once by aot.py to HLO text; the rust runtime
(`pss::runtime`) executes them from the coordinator.  Shapes are static
(one artifact per variant); the rust side pads the last chunk/candidate
slots with sentinels.

Sentinel conventions (shared with rust/src/runtime/verifier.rs):
  STREAM_PAD    = -2  (never a real item id; ids are encoded into [0, 2^31))
  CANDIDATE_PAD = -1
Pad slots can never match, so their counts are 0 and are dropped in rust.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import block_histogram, candidate_count

STREAM_PAD = -2
CANDIDATE_PAD = -1


@functools.partial(jax.jit, donate_argnums=(), static_argnames=())
def verify_counts(stream_chunks: jax.Array, candidates: jax.Array):
    """Exact counts of each candidate over all chunks.

    Args:
      stream_chunks: (C, B) int32, B a multiple of the kernel stream tile.
      candidates:    (K,) int32, K a multiple of the kernel candidate tile.

    Returns:
      1-tuple of (K,) float32 counts (tuple to match return_tuple=True AOT).
    """
    k = candidates.shape[0]

    def body(acc, chunk):
        return acc + candidate_count(chunk, candidates), None

    init = jnp.zeros((k,), jnp.float32)
    acc, _ = jax.lax.scan(body, init, stream_chunks)
    return (acc,)


@functools.partial(jax.jit, static_argnames=("num_buckets",))
def skew_profile(stream_chunks: jax.Array, *, num_buckets: int = 1024):
    """Per-chunk hash histograms, used by the coordinator's sharder.

    Args:
      stream_chunks: (C, B) int32.

    Returns:
      1-tuple of (C, num_buckets) float32 bucket totals.
    """
    hist = jax.vmap(lambda c: block_histogram(c, num_buckets=num_buckets))(
        stream_chunks
    )
    return (hist,)
