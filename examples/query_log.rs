//! Web query-log / computational-linguistics analysis — paper §1: "the
//! problem also arises in the context of the analysis of web query
//! logs" and "the estimation of the frequencies of specific words in a
//! given language ... where a verification of the Zipf–Mandelbrot law
//! is required".
//!
//! A zipf-Mandelbrot word stream (s=1.3, q=2.7 — typical corpus
//! parameters) is summarized by Space Saving and by the related-work
//! baselines (§2), and the reported head frequencies are fitted against
//! the Zipf–Mandelbrot law.
//!
//! ```text
//! cargo run --release --example query_log
//! ```

use pss::baselines::{CountMin, Exact, Frequent};
use pss::gen::{GeneratedSource, ItemSource};
use pss::metrics::AccuracyReport;
use pss::summary::{FrequencySummary, SpaceSaving};

fn main() {
    // "Vocabulary" of 1M distinct words; 4M queries.
    let n = 4_000_000u64;
    let (s, q) = (1.3f64, 2.7f64);
    let src = GeneratedSource::zipf_mandelbrot(n, 1 << 20, s, q, 7);
    let words = src.slice(0, n);

    let k = 500usize;
    let mut exact = Exact::new();
    exact.offer_all(&words);

    // --- Space Saving vs the related-work baselines (paper §2) --------
    let mut ss = SpaceSaving::new(k);
    ss.offer_all(&words);
    let ss_report = ss.freeze().prune(n, k as u64);

    let mut mg = Frequent::new(k);
    mg.offer_all(&words);
    let mg_report: Vec<_> = mg
        .counters()
        .into_iter()
        .filter(|c| c.count > n / k as u64)
        .collect();

    let mut cm = CountMin::new(4096, 4, k);
    cm.offer_all(&words);
    let cm_report: Vec<_> = cm
        .counters()
        .into_iter()
        .filter(|c| c.count > n / k as u64)
        .collect();

    println!("query log: n={n}, vocabulary=2^20, zipf-mandelbrot(s={s}, q={q})");
    println!("\nalgorithm        reported  ARE        precision  recall");
    for (name, rep) in [
        ("space_saving", &ss_report),
        ("misra_gries", &mg_report),
        ("count_min", &cm_report),
    ] {
        let acc = AccuracyReport::evaluate(rep, &exact, k as u64);
        println!(
            "{name:<16} {:>8}  {:<9.3e}  {:<9.3}  {:.3}",
            rep.len(),
            acc.are,
            acc.precision,
            acc.recall
        );
    }

    // --- Zipf–Mandelbrot law verification on the reported head --------
    // P(rank r) ∝ (r + q)^(-s)  ⇒  log f(r) ≈ C - s·log(r + q).
    // Fit s from the Space Saving head estimates by least squares.
    let head: Vec<(f64, f64)> = ss_report
        .iter()
        .take(50)
        .enumerate()
        .map(|(i, c)| (((i + 1) as f64 + q).ln(), (c.count as f64).ln()))
        .collect();
    let m = head.len() as f64;
    let (sx, sy): (f64, f64) = head.iter().fold((0.0, 0.0), |a, p| (a.0 + p.0, a.1 + p.1));
    let sxx: f64 = head.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = head.iter().map(|p| p.0 * p.1).sum();
    let slope = (m * sxy - sx * sy) / (m * sxx - sx * sx);
    println!(
        "\nZipf–Mandelbrot fit on the reported head: ŝ = {:.3} (generator s = {s})",
        -slope
    );
    assert!(
        (-slope - s).abs() < 0.15,
        "law verification failed: fitted {} vs {}",
        -slope,
        s
    );
    println!("law verified ✓ (|ŝ - s| < 0.15)");
}
