//! Quickstart: find the k-majority elements of a zipfian stream with
//! shared-memory Parallel Space Saving (paper Algorithm 1).
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use pss::baselines::Exact;
use pss::gen::{GeneratedSource, ItemSource};
use pss::metrics::AccuracyReport;
use pss::parallel::{run_shared, SummaryKind};
use pss::summary::FrequencySummary;

fn main() {
    // 2M items, zipf skew 1.1 over a 4M-id universe — a miniature of the
    // paper's workload.
    let n = 2_000_000u64;
    let src = GeneratedSource::zipf(n, 1 << 22, 1.1, 42);

    // k = 200 counters; report items with frequency > n/200. The
    // compact SoA core is the fastest per-worker structure; `heap` and
    // `bucket` give identical guarantees (see ARCHITECTURE.md).
    let k = 200usize;
    let threads = std::thread::available_parallelism().map_or(4, |p| p.get());
    let result = run_shared(&src, k, k as u64, threads, SummaryKind::Compact);

    println!("Parallel Space Saving: n={n}, k={k}, threads={threads}");
    println!(
        "phases: spawn {:.2}ms scan {:.2}ms reduce {:.2}ms prune {:.2}ms",
        result.times.spawn * 1e3,
        result.times.scan * 1e3,
        result.times.reduce * 1e3,
        result.times.prune * 1e3
    );
    println!("\ntop k-majority candidates (f̂ > n/{k}):");
    for c in result.frequent.iter().take(10) {
        println!(
            "  item {:>8}  f̂ = {:<8} guaranteed ≥ {}",
            c.item,
            c.count,
            c.guaranteed()
        );
    }

    // Ground truth (the off-line setting of paper §1).
    let mut exact = Exact::new();
    exact.offer_all(&src.slice(0, n));
    let acc = AccuracyReport::evaluate(&result.frequent, &exact, k as u64);
    println!(
        "\naccuracy vs exact oracle: ARE={:.3e} precision={:.2} recall={:.2}",
        acc.are, acc.precision, acc.recall
    );
    assert_eq!(acc.recall, 1.0, "Space Saving guarantees recall 1");
}
