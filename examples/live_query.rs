//! Live queries over a running ingest: writers stream a zipfian
//! workload through the sharded coordinator while readers concurrently
//! ask for top-k, point estimates and the k-majority split — all
//! answered from epoch snapshots, never blocking ingestion.
//!
//! ```text
//! cargo run --release --example live_query
//! ```

use std::time::{Duration, Instant};

use pss::coordinator::{Coordinator, CoordinatorConfig, Routing};
use pss::gen::{GeneratedSource, ItemSource};

fn main() {
    let n = 4_000_000u64;
    let src = GeneratedSource::zipf(n, 1 << 22, 1.1, 42);
    let shards = std::thread::available_parallelism().map_or(4, |p| p.get().min(8));
    let k = 500usize;

    let (mut coord, engine) = Coordinator::spawn(CoordinatorConfig {
        shards,
        k,
        k_majority: k as u64,
        queue_depth: 8,
        routing: Routing::RoundRobin,
        epoch_items: 100_000, // publish a snapshot every 100k items/shard
        batch_ingest: true,   // pre-aggregate chunks into weighted runs
        ..Default::default()
    });
    println!("live query demo: n={n}, {shards} shards, k={k}");

    let t0 = Instant::now();
    let result = std::thread::scope(|scope| {
        // Writer thread: the ingest path.
        let stream = &src;
        let writer = scope.spawn(move || {
            let mut pos = 0u64;
            while pos < n {
                let take = (n - pos).min(65_536);
                coord.push(stream.slice(pos, pos + take));
                pos += take;
            }
            coord.finish()
        });

        // Reader: this thread queries while the writer ingests.
        let mut polls = 0u32;
        while !writer.is_finished() {
            std::thread::sleep(Duration::from_millis(150));
            polls += 1;
            let snap = engine.snapshot();
            let stats = engine.stats();
            let top: Vec<String> = snap
                .top_k(3)
                .iter()
                .map(|c| format!("{}:{}", c.item, c.count))
                .collect();
            println!(
                "[{:5.2}s] epoch n={:>9} (lag {:>7} items)  ε={:>5}  top3=[{}]  p(item 1)={}",
                t0.elapsed().as_secs_f64(),
                snap.n(),
                stats.staleness_items,
                snap.epsilon(),
                top.join(" "),
                snap.point(1).estimate,
            );
            // Snapshot answers are internally consistent: coverage
            // always equals the sum of the per-shard epochs merged.
            let part_sum: u64 = snap.epochs().iter().map(|e| e.n).sum();
            assert_eq!(snap.n(), part_sum);
        }
        println!("({polls} live polls)");
        writer.join().expect("writer panicked")
    });

    println!(
        "\ndrained {} items in {:.2}s ({:.1} M items/s), {} epochs published",
        result.stats.items,
        t0.elapsed().as_secs_f64(),
        result.stats.items as f64 / t0.elapsed().as_secs_f64() / 1e6,
        result.stats.epochs_published,
    );

    // After finish() the engine serves the drain-time epochs: exact
    // coverage of the whole stream.
    let report = engine.frequent();
    println!(
        "final k-majority (f̂ > n/{k}): {} guaranteed + {} possible, ε = {}",
        report.guaranteed.len(),
        report.possible.len(),
        report.epsilon
    );
    for c in report.guaranteed.iter().take(8) {
        println!("  item {:>8}  f̂ = {:<9} guaranteed ≥ {}", c.item, c.count, c.guaranteed());
    }
    let s = engine.stats();
    println!("\nserved {} queries ({})", s.queries_served, s.query_latency);
    assert_eq!(engine.snapshot().n(), n, "drain epochs cover the full stream");
}
