//! End-to-end driver — proves all layers compose (DESIGN.md §3):
//!
//! 1. **generate**: write a real PSSD dataset file (zipf 1.1, 8M items);
//! 2. **ingest**: stream it through the L3 coordinator (sharded Space
//!    Saving, bounded queues, combine-tree merge);
//! 3. **verify (PJRT)**: replay the stream through the AOT-compiled
//!    jax/Pallas `verify_counts` artifact — python built it once at
//!    `make artifacts`, rust executes it here — to get exact candidate
//!    frequencies, prune false positives, and compute ARE;
//! 4. **cross-check**: the PJRT counts must equal the rust oracle;
//! 5. **paper-scale simulation**: one Table III/IV point on the
//!    calibrated cluster simulator for the headline metric.
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```text
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use std::time::Instant;

use pss::baselines::Exact;
use pss::coordinator::{run_source, CoordinatorConfig, Routing};
use pss::distsim::SimWorkload;
use pss::gen::{DatasetHeader, DatasetReader, DatasetWriter, GeneratedSource, ItemSource};
use pss::hybrid;
use pss::runtime::Verifier;
use pss::summary::FrequencySummary;
use pss::util::TempDir;

fn main() -> anyhow::Result<()> {
    let n = 8_000_000u64;
    let k = 2000usize;
    let dir = TempDir::new()?;
    let path = dir.path().join("stream.pssd");

    // ---- 1. generate ---------------------------------------------------
    let t0 = Instant::now();
    let header = DatasetHeader { n, universe: 1 << 22, skew: 1.1, shift: 0.0, seed: 99 };
    let gen = GeneratedSource::zipf(n, header.universe, header.skew, header.seed);
    let mut w = DatasetWriter::create(&path, &header)?;
    let mut buf = vec![0u64; 1 << 16];
    let mut pos = 0u64;
    while pos < n {
        let take = ((n - pos) as usize).min(buf.len());
        gen.fill(pos, &mut buf[..take]);
        w.write_items(&buf[..take])?;
        pos += take as u64;
    }
    w.finish()?;
    println!(
        "[1/5] generated {} items -> {} ({:.1} MB) in {:.2}s",
        n,
        path.display(),
        (n * 8) as f64 / 1e6,
        t0.elapsed().as_secs_f64()
    );

    // ---- 2. ingest through the coordinator -----------------------------
    let (hdr, file_src) = DatasetReader::open(&path)?;
    assert_eq!(hdr, header);
    let cfg = CoordinatorConfig {
        shards: 4,
        k,
        k_majority: k as u64,
        queue_depth: 8,
        routing: Routing::RoundRobin,
        // The cache-conscious SoA summary core (same guarantees as the
        // default bucket list; see bench_summary_core for the numbers).
        structure: pss::summary::SummaryKind::Compact,
        // Batch session (queried only at finish): no epoch publication.
        epoch_items: 0,
        batch_ingest: true,
        ..Default::default()
    };
    let (routing, transport) = (cfg.routing, cfg.transport);
    let t1 = Instant::now();
    let result = run_source(
        cfg,
        &file_src,
        // L2-resident chunks for the batched scratch map (16384 at the
        // default 1 MiB L2 assumption).
        pss::parallel::batch_chunk_len_default(),
    );
    let ingest_s = t1.elapsed().as_secs_f64();
    println!(
        "[2/5] coordinator: {} items in {:.2}s ({:.1} M items/s), {} candidates, {} stalls",
        result.stats.items,
        ingest_s,
        result.stats.items as f64 / ingest_s / 1e6,
        result.frequent.len(),
        result.stats.backpressure_events
    );
    // Effective transport/routing + counters: the example doubles as a
    // smoke test for the SPSC ring write path and its buffer recycling.
    println!(
        "      routing={routing} transport={transport}: {} transport retries, {} buffers recycled",
        result.stats.transport_retries, result.stats.buffers_recycled
    );
    assert!(
        result.stats.buffers_recycled > 0,
        "ring transport must recycle chunk buffers through run_source"
    );

    // ---- 3. PJRT offline verification ----------------------------------
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut verifier = Verifier::new(&artifacts)?;
    let items = file_src.slice(0, n);
    let t2 = Instant::now();
    let report = verifier.verify_report(&items, &result.frequent, k as u64)?;
    println!(
        "[3/5] PJRT verify ({} candidates x {} items) in {:.2}s: precision={:.4} ARE={:.3e} confirmed={}",
        result.frequent.len(),
        n,
        t2.elapsed().as_secs_f64(),
        report.precision,
        report.are,
        report.confirmed.len()
    );

    // ---- 4. cross-check against the rust oracle ------------------------
    let mut exact = Exact::new();
    exact.offer_all(&items);
    for (item, _est, f) in &report.rows {
        assert_eq!(*f, exact.count(*item), "PJRT vs oracle mismatch on {item}");
    }
    let truth: Vec<u64> = exact.k_majority(k as u64).iter().map(|c| c.item).collect();
    let confirmed: Vec<u64> = report.confirmed.iter().map(|c| c.item).collect();
    assert_eq!(confirmed, truth, "confirmed set != exact k-majority");
    println!("[4/5] PJRT counts == rust oracle for all {} candidates ✓", report.rows.len());

    // ---- 5. paper-scale headline ---------------------------------------
    let w29 = SimWorkload::paper(29_000_000_000, k, 1.1, 10_000_000, 1);
    let mpi512 = hybrid::run_mpi(&w29, 512)?;
    let hyb512 = hybrid::run_hybrid(&w29, 512)?;
    let mpi1 = hybrid::run_mpi(&w29, 1)?;
    println!(
        "[5/5] simulated 29B items, 512 cores: MPI {:.2}s (paper 3.35) vs hybrid {:.2}s (paper 2.40); 1-core {:.1}s (paper 874.88)",
        mpi512.total_seconds(),
        hyb512.total_seconds(),
        mpi1.total_seconds()
    );
    assert!(hyb512.total_seconds() < mpi512.total_seconds(), "headline: hybrid must win at 512");

    println!("\nE2E PIPELINE OK — all five stages verified");
    Ok(())
}
