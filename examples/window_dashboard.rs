//! Sliding-window dashboard: a *drifting* workload streams through the
//! coordinator while this thread prints the landmark top-k next to the
//! windowed top-k. The hot set changes every phase — the windowed view
//! tracks the drift within a few epochs, while the landmark view keeps
//! averaging over everything since startup.
//!
//! ```text
//! cargo run --release --example window_dashboard
//! ```

use std::time::{Duration, Instant};

use pss::coordinator::{Coordinator, CoordinatorConfig, Routing};
use pss::util::SplitMix64;

/// Phases of the drifting workload: each phase has its own hot items
/// (`phase * 1000 + rank`), drawn with 60% probability over a uniform
/// background.
const PHASES: u64 = 5;
const CHUNKS_PER_PHASE: u64 = 80;
const CHUNK: usize = 16_384;

fn main() {
    let shards = std::thread::available_parallelism().map_or(4, |p| p.get().min(8));
    let k = 256usize;
    let (mut coord, engine) = Coordinator::spawn(CoordinatorConfig {
        shards,
        k,
        k_majority: k as u64,
        queue_depth: 8,
        routing: Routing::RoundRobin,
        epoch_items: 50_000, // delta cadence == snapshot cadence
        batch_ingest: true,
        delta_ring: 16, // keep the last 16 epoch deltas per shard
        window_epochs: 4, // "recent" = the last 4 epochs per shard
        ..Default::default()
    });
    let windows = coord.windows().expect("delta ring on");
    let n = PHASES * CHUNKS_PER_PHASE * CHUNK as u64;
    println!(
        "window dashboard: {n} items over {PHASES} drift phases, {shards} shards, k={k}"
    );
    println!("hot set of phase p = items p*1000 .. p*1000+3\n");

    let t0 = Instant::now();
    let result = std::thread::scope(|scope| {
        // Writer: the drifting workload.
        let writer = scope.spawn(move || {
            let mut rng = SplitMix64::new(42);
            for phase in 0..PHASES {
                for _ in 0..CHUNKS_PER_PHASE {
                    let chunk: Vec<u64> = (0..CHUNK)
                        .map(|_| {
                            if rng.next_f64() < 0.6 {
                                phase * 1000 + rng.next_below(4)
                            } else {
                                10_000 + rng.next_below(1 << 20)
                            }
                        })
                        .collect();
                    coord.push(chunk);
                }
            }
            coord.finish()
        });

        // Reader: landmark vs windowed top-3, side by side.
        while !writer.is_finished() {
            std::thread::sleep(Duration::from_millis(150));
            let snap = engine.snapshot();
            let win = windows.latest();
            let fmt = |cs: &[pss::summary::Counter]| {
                cs.iter()
                    .map(|c| format!("{}:{}", c.item, c.count))
                    .collect::<Vec<_>>()
                    .join(" ")
            };
            println!(
                "[{:5.2}s] landmark n={:>9} top3=[{}]  |  window(4) W={:>8} top3=[{}]",
                t0.elapsed().as_secs_f64(),
                snap.n(),
                fmt(&snap.top_k(3)),
                win.n(),
                fmt(&win.top_k(3)),
            );
        }
        writer.join().expect("writer panicked")
    });

    println!(
        "\ndrained {} items in {:.2}s; {} epochs, {} deltas published",
        result.stats.items,
        t0.elapsed().as_secs_f64(),
        result.stats.epochs_published,
        result.stats.deltas_published,
    );

    // Post-drain: the landmark view still averages over all phases; the
    // window only remembers the last one.
    let final_win = windows.latest();
    let last_hot = (PHASES - 1) * 1000;
    println!(
        "final landmark top3: [{}]",
        engine
            .top_k(3)
            .iter()
            .map(|c| format!("{}:{}", c.item, c.count))
            .collect::<Vec<_>>()
            .join(" ")
    );
    println!(
        "final window(4) top3: [{}]  (expected hot set ≥ {last_hot})",
        final_win
            .top_k(3)
            .iter()
            .map(|c| format!("{}:{}", c.item, c.count))
            .collect::<Vec<_>>()
            .join(" ")
    );
    let rep = final_win.k_majority(k as u64);
    println!(
        "windowed k-majority over W={} items: {} guaranteed + {} possible, ε={}",
        rep.n,
        rep.guaranteed.len(),
        rep.possible.len(),
        rep.epsilon
    );
    assert!(
        final_win.top_k(3).iter().all(|c| c.item >= last_hot && c.item < last_hot + 4),
        "the windowed top must come from the final drift phase"
    );
    let ws = windows.window_stats();
    println!(
        "served {} windowed queries ({})",
        ws.queries_served, ws.query_latency
    );
}
