//! The serve layer in one process: bind a loopback server, drive it
//! with the multi-client load generator, query it over the wire while
//! ingest is still running, then shut it down through the protocol —
//! the same path `pss serve` / `pss loadgen` exercise across
//! processes.
//!
//! The point to notice: the answers come back as the library's own
//! types ([`pss::query::PointEstimate`], [`pss::summary::Counter`]),
//! and the server's final stats show `buffers_recycled > 0` — the
//! allocation-free ingest steady state survives the socket hop.
//!
//! ```text
//! cargo run --release --example serve_roundtrip
//! ```

use std::thread;

use pss::coordinator::CoordinatorConfig;
use pss::serve::{run_loadgen, LoadgenConfig, QueryClient, ServeConfig, Server};

fn main() -> anyhow::Result<()> {
    let k = 1024usize;
    let server = Server::bind(
        &"127.0.0.1:0".parse().map_err(anyhow::Error::msg)?,
        ServeConfig {
            coordinator: CoordinatorConfig {
                shards: 4,
                k,
                k_majority: 64,
                epoch_items: 25_000,
                ..Default::default()
            },
            query_threads: 2,
            ..Default::default()
        },
    )?;
    let endpoint = server.endpoint().clone();
    println!("serving on {endpoint}");

    // Writers: 4 loadgen clients, each its own socket = its own
    // producer, pipelined frames against recycled chunk buffers.
    let writer = thread::spawn(move || {
        run_loadgen(
            &endpoint,
            &LoadgenConfig {
                clients: 4,
                items_per_client: 500_000,
                universe: 1 << 20,
                skew: 1.1,
                seed: 7,
                ..Default::default()
            },
        )
    });

    // Reader: concurrent wire queries while the writers stream.
    let mut q = QueryClient::connect(server.endpoint())?;
    loop {
        let s = q.stats()?;
        if s.items == 0 {
            server.queries().refresh();
            thread::yield_now();
            continue;
        }
        let top = q.top_k(5, 0)?;
        println!("live: n={} ε={} (bound n/k={})", top.n, top.epsilon, top.n / k as u64);
        for c in &top.counters {
            println!("  item {:>8}  f̂={:<10} ε≤{}", c.item, c.count, c.err);
        }
        if s.items >= 2_000_000 {
            break;
        }
        thread::sleep(std::time::Duration::from_millis(50));
        server.queries().refresh();
    }

    let report = writer.join().expect("loadgen thread panicked")?;
    println!(
        "loadgen: acked {} items at {:.2} M items/s, per-frame {}",
        report.items_acked,
        report.items_per_sec() / 1e6,
        report.frame_latency,
    );

    q.shutdown_server()?;
    server.wait_shutdown(None);
    let (result, stats) = server.finish();
    println!(
        "drained: {} items, {} ingest conns, {} frames, {} buffers recycled",
        result.stats.items, stats.ingest_connections, stats.frames, result.stats.buffers_recycled,
    );
    assert!(result.stats.buffers_recycled > 0, "socket ingest must reuse chunk buffers");
    Ok(())
}
