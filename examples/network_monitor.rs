//! Network traffic monitoring — the paper §1 motivation: "extracting
//! essential characteristics of network traffic streams passing through
//! internet routers" and inferring congestion/heavy flows.
//!
//! A synthetic packet stream mixes a handful of elephant flows (a DDoS
//! victim, a backup transfer) into heavy-tailed background traffic. The
//! streaming coordinator ingests packets in batches with bounded queues
//! (backpressure), and the merged Space Saving summary exposes the
//! elephants in real time with guaranteed recall.
//!
//! ```text
//! cargo run --release --example network_monitor
//! ```

use pss::coordinator::{Coordinator, CoordinatorConfig, Routing};
use pss::util::SplitMix64;

/// Encode a (src /24, dst ip) flow into an item id below 2^31 so the
/// PJRT verification path could also process it.
fn flow_id(src24: u32, dst: u32) -> u64 {
    ((src24 as u64) << 16 ^ dst as u64) & 0x7FFF_FFFF
}

fn main() {
    let mut rng = SplitMix64::new(2024);

    // Elephant flows: ~8% of all packets each.
    let elephants = [
        flow_id(0x0A00_01, 80),   // web server under load
        flow_id(0xC0A8_00, 443),  // TLS backup transfer
        flow_id(0x0A02_03, 53),   // DNS amplification victim
    ];

    let cfg = CoordinatorConfig {
        shards: 4,
        k: 1024,
        k_majority: 50, // report flows with > 2% of packets
        queue_depth: 16,
        // Keyed routing: each flow id hashes to one home shard, so the
        // per-shard summaries are flow-disjoint and the merged error
        // bound is the max-per-shard one — per-flow counts come from
        // exactly one worker, as a per-flow NIC steering would do.
        routing: Routing::Keyed,
        // Batch session (queried only at finish): no epoch publication.
        epoch_items: 0,
        // NIC batches are heavily duplicated (elephant flows): the
        // batched path collapses each drain into per-flow runs.
        batch_ingest: true,
        ..Default::default()
    };
    let (routing, transport) = (cfg.routing, cfg.transport);
    let mut monitor = Coordinator::start(cfg);

    // 1.5M packets in 1500-packet batches (a NIC ring buffer drain),
    // the drain buffers recycled through the coordinator's free rings.
    let total = 1_500_000usize;
    let batch = 1_500usize;
    let mut truth = std::collections::HashMap::<u64, u64>::new();
    for _ in 0..total / batch {
        let mut pkts = monitor.take_buffer();
        pkts.reserve(batch);
        for _ in 0..batch {
            let flow = if rng.next_f64() < 0.24 {
                elephants[rng.next_below(3) as usize]
            } else {
                // Mice: heavy-tailed background scan traffic.
                flow_id(rng.next_below(1 << 24) as u32, rng.next_below(65_536) as u32)
            };
            *truth.entry(flow).or_default() += 1;
            pkts.push(flow);
        }
        monitor.push(pkts);
    }

    let report = monitor.finish();
    println!(
        "monitored {} packets over {} shards ({} backpressure stalls, per-shard {:?})",
        report.stats.items,
        report.stats.per_shard_items.len(),
        report.stats.backpressure_events,
        report.stats.per_shard_items
    );
    // Effective transport/routing + counters: the example doubles as a
    // smoke test for the keyed SPSC write path.
    println!(
        "routing={routing} transport={transport}: {} transport retries, {} buffers recycled",
        report.stats.transport_retries, report.stats.buffers_recycled
    );

    println!("\nheavy flows (>{} packets):", report.stats.items / 50);
    for c in &report.frequent {
        let share = c.count as f64 / report.stats.items as f64 * 100.0;
        println!(
            "  flow {:>10}  ~{:>6.2}% of traffic (f̂={}, true={})",
            c.item,
            share,
            c.count,
            truth.get(&c.item).copied().unwrap_or(0)
        );
    }

    // Every elephant must be caught — Space Saving's recall guarantee.
    for e in &elephants {
        assert!(
            report.frequent.iter().any(|c| c.item == *e),
            "elephant flow {e} missed!"
        );
    }
    println!("\nall {} elephant flows detected ✓", elephants.len());
}
