//! The `PSSD` binary dataset format.
//!
//! Layout (little-endian):
//!
//! ```text
//! offset  size  field
//! 0       8     magic  b"PSSD\x01\0\0\0"
//! 8       8     n           (u64 item count)
//! 16      8     universe    (u64)
//! 24      8     skew        (f64 bits; 0.0 for uniform)
//! 32      8     shift q     (f64 bits)
//! 40      8     seed        (u64)
//! 48      n*8   items       (u64 each)
//! ```
//!
//! Written by `pss generate`, consumed by [`FileSource`] for streaming
//! block reads from any worker.
//!
//! [`FileSource`]: super::source::FileSource

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::Path;

use crate::Result;

use super::source::FileSource;

const MAGIC: [u8; 8] = *b"PSSD\x01\0\0\0";
const HEADER_LEN: u64 = 48;

/// Parsed dataset header.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetHeader {
    /// Item count.
    pub n: u64,
    /// Rank universe size.
    pub universe: u64,
    /// Zipf skew (0.0 = uniform).
    pub skew: f64,
    /// Mandelbrot shift.
    pub shift: f64,
    /// Generation seed.
    pub seed: u64,
}

/// Streaming dataset writer.
pub struct DatasetWriter {
    out: BufWriter<File>,
    declared_n: u64,
    written: u64,
}

impl DatasetWriter {
    /// Create `path`, writing a header that declares `header.n` items.
    pub fn create(path: &Path, header: &DatasetHeader) -> Result<Self> {
        let f = File::create(path)?;
        let mut out = BufWriter::with_capacity(1 << 20, f);
        out.write_all(&MAGIC)?;
        out.write_all(&header.n.to_le_bytes())?;
        out.write_all(&header.universe.to_le_bytes())?;
        out.write_all(&header.skew.to_le_bytes())?;
        out.write_all(&header.shift.to_le_bytes())?;
        out.write_all(&header.seed.to_le_bytes())?;
        Ok(Self { out, declared_n: header.n, written: 0 })
    }

    /// Append a block of items.
    pub fn write_items(&mut self, items: &[u64]) -> Result<()> {
        for &it in items {
            self.out.write_all(&it.to_le_bytes())?;
        }
        self.written += items.len() as u64;
        Ok(())
    }

    /// Flush and validate the declared count.
    pub fn finish(mut self) -> Result<()> {
        self.out.flush()?;
        anyhow::ensure!(
            self.written == self.declared_n,
            "dataset declared {} items but wrote {}",
            self.declared_n,
            self.written
        );
        Ok(())
    }
}

/// Dataset opener: header parsing + [`FileSource`] construction.
pub struct DatasetReader;

impl DatasetReader {
    /// Read and validate the header of `path`.
    pub fn header(path: &Path) -> Result<DatasetHeader> {
        let mut f = File::open(path)?;
        let mut buf = [0u8; HEADER_LEN as usize];
        f.read_exact(&mut buf)?;
        anyhow::ensure!(buf[..8] == MAGIC, "not a PSSD dataset: bad magic");
        let u64_at = |o: usize| u64::from_le_bytes(buf[o..o + 8].try_into().unwrap());
        let f64_at = |o: usize| f64::from_le_bytes(buf[o..o + 8].try_into().unwrap());
        let header = DatasetHeader {
            n: u64_at(8),
            universe: u64_at(16),
            skew: f64_at(24),
            shift: f64_at(32),
            seed: u64_at(40),
        };
        let expect = HEADER_LEN + header.n * 8;
        let actual = f.metadata()?.len();
        anyhow::ensure!(
            actual == expect,
            "dataset truncated: expected {expect} bytes, found {actual}"
        );
        Ok(header)
    }

    /// Open `path` as an [`ItemSource`](super::source::ItemSource).
    pub fn open(path: &Path) -> Result<(DatasetHeader, FileSource)> {
        let header = Self::header(path)?;
        let f = File::open(path)?;
        Ok((header.clone(), FileSource::new(f, HEADER_LEN, header.n)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::source::{GeneratedSource, ItemSource};
    use crate::util::TempDir;

    #[test]
    fn roundtrip() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("t.pssd");
        let header = DatasetHeader { n: 5_000, universe: 100, skew: 1.1, shift: 0.0, seed: 3 };
        let src = GeneratedSource::zipf(5_000, 100, 1.1, 3);
        let mut w = DatasetWriter::create(&path, &header).unwrap();
        let items = src.slice(0, 5_000);
        w.write_items(&items[..2_500]).unwrap();
        w.write_items(&items[2_500..]).unwrap();
        w.finish().unwrap();

        let (h2, fs) = DatasetReader::open(&path).unwrap();
        assert_eq!(h2, header);
        assert_eq!(fs.len(), 5_000);
        assert_eq!(fs.slice(0, 5_000), items);
        assert_eq!(fs.slice(1_234, 1_240), items[1_234..1_240].to_vec());
    }

    #[test]
    fn wrong_count_rejected() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("bad.pssd");
        let header = DatasetHeader { n: 10, universe: 5, skew: 0.0, shift: 0.0, seed: 0 };
        let mut w = DatasetWriter::create(&path, &header).unwrap();
        w.write_items(&[1, 2, 3]).unwrap();
        assert!(w.finish().is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("junk.pssd");
        std::fs::write(&path, vec![0u8; 64]).unwrap();
        assert!(DatasetReader::header(&path).is_err());
    }

    #[test]
    fn truncated_rejected() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("trunc.pssd");
        let header = DatasetHeader { n: 100, universe: 5, skew: 0.0, shift: 0.0, seed: 0 };
        let mut w = DatasetWriter::create(&path, &header).unwrap();
        w.write_items(&vec![1; 100]).unwrap();
        w.finish().unwrap();
        let data = std::fs::read(&path).unwrap();
        std::fs::write(&path, &data[..data.len() - 8]).unwrap();
        assert!(DatasetReader::header(&path).is_err());
    }
}
