//! Zipf / Zipf-Mandelbrot sampling by rejection-inversion.
//!
//! Samples ranks `x ∈ {1..universe}` with `P(x) ∝ (x + q)^{-s}` — `q = 0`
//! is pure zipf (the paper's workloads, ρ = s), `q > 0` is
//! zipf-Mandelbrot (the linguistics workloads the paper's §1 motivates).
//!
//! Algorithm: Hörmann & Derflinger's rejection-inversion, the same scheme
//! as Apache Commons RNG's `RejectionInversionZipfSampler`, generalized
//! to the shifted hazard `h(x) = (x+q)^{-s}`: `O(1)` expected time per
//! sample, no tables, any universe size. The shift preserves the
//! decreasing-convexity `h` needs, so the envelope construction is
//! unchanged.

use crate::util::SplitMix64;

/// Rejection-inversion sampler for `P(x) ∝ (x+q)^{-s}`, `x ∈ [1, n]`.
#[derive(Debug, Clone)]
pub struct ZipfSampler {
    /// Universe size (number of distinct ranks).
    n: u64,
    /// Skew exponent `s > 0` (the paper's ρ).
    s: f64,
    /// Mandelbrot shift `q >= 0` (0 = pure zipf).
    q: f64,
    h_integral_x1: f64,
    h_integral_n: f64,
    threshold: f64,
}

impl ZipfSampler {
    /// Pure zipf with skew `s` over `universe` ranks.
    pub fn new(universe: u64, s: f64) -> Self {
        Self::with_shift(universe, s, 0.0)
    }

    /// Zipf-Mandelbrot with skew `s` and shift `q`.
    pub fn with_shift(universe: u64, s: f64, q: f64) -> Self {
        assert!(universe >= 1, "universe must be >= 1");
        assert!(s > 0.0, "skew must be positive");
        assert!(q >= 0.0, "shift must be non-negative");
        let mut z = Self {
            n: universe,
            s,
            q,
            h_integral_x1: 0.0,
            h_integral_n: 0.0,
            threshold: 0.0,
        };
        z.h_integral_x1 = z.h_integral(1.5) - z.h(1.0);
        z.h_integral_n = z.h_integral(universe as f64 + 0.5);
        z.threshold = 2.0 - z.h_integral_inverse(z.h_integral(2.5) - z.h(2.0));
        z
    }

    /// Universe size.
    pub fn universe(&self) -> u64 {
        self.n
    }

    /// Skew exponent.
    pub fn skew(&self) -> f64 {
        self.s
    }

    /// `h(x) = (x+q)^{-s}`.
    #[inline]
    fn h(&self, x: f64) -> f64 {
        (x + self.q).powf(-self.s)
    }

    /// Antiderivative of `h`: `(x+q)^{1-s}/(1-s)` (or `ln(x+q)` at s=1).
    #[inline]
    fn h_integral(&self, x: f64) -> f64 {
        if (self.s - 1.0).abs() < 1e-12 {
            (x + self.q).ln()
        } else {
            (x + self.q).powf(1.0 - self.s) / (1.0 - self.s)
        }
    }

    /// Inverse of [`Self::h_integral`].
    #[inline]
    fn h_integral_inverse(&self, y: f64) -> f64 {
        if (self.s - 1.0).abs() < 1e-12 {
            y.exp() - self.q
        } else {
            (y * (1.0 - self.s)).powf(1.0 / (1.0 - self.s)) - self.q
        }
    }

    /// Draw one rank in `[1, universe]`.
    #[inline]
    pub fn sample(&self, rng: &mut SplitMix64) -> u64 {
        loop {
            let u = self.h_integral_n
                + rng.next_f64() * (self.h_integral_x1 - self.h_integral_n);
            let x = self.h_integral_inverse(u);
            // Clamp to the valid rank range (floating error at the edges).
            let k = (x + 0.5).floor().clamp(1.0, self.n as f64);
            if k - x <= self.threshold || u >= self.h_integral(k + 0.5) - self.h(k) {
                return k as u64;
            }
        }
    }

    /// Exact probability of rank `x` (for tests/metrics; `O(universe)`
    /// on first call pattern — computes the normalizer by summation).
    pub fn exact_pmf(&self, x: u64) -> f64 {
        assert!(x >= 1 && x <= self.n);
        let z: f64 = (1..=self.n).map(|i| (i as f64 + self.q).powf(-self.s)).sum();
        (x as f64 + self.q).powf(-self.s) / z
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empirical(universe: u64, s: f64, q: f64, draws: usize, seed: u64) -> Vec<f64> {
        let z = ZipfSampler::with_shift(universe, s, q);
        let mut rng = SplitMix64::new(seed);
        let mut hist = vec![0u64; universe as usize + 1];
        for _ in 0..draws {
            hist[z.sample(&mut rng) as usize] += 1;
        }
        hist.iter().map(|&c| c as f64 / draws as f64).collect()
    }

    fn check_against_pmf(universe: u64, s: f64, q: f64, seed: u64) {
        let draws = 400_000;
        let emp = empirical(universe, s, q, draws, seed);
        let z = ZipfSampler::with_shift(universe, s, q);
        // Compare the head (top 20 ranks) within 5 sigma binomial noise.
        for x in 1..=20.min(universe) {
            let p = z.exact_pmf(x);
            let sigma = (p * (1.0 - p) / draws as f64).sqrt();
            let diff = (emp[x as usize] - p).abs();
            assert!(
                diff < 5.0 * sigma + 1e-4,
                "rank {x}: emp {} vs pmf {p} (s={s}, q={q})",
                emp[x as usize]
            );
        }
        // Total variation over the whole support stays small.
        let tv: f64 = (1..=universe)
            .map(|x| (emp[x as usize] - z.exact_pmf(x)).abs())
            .sum::<f64>()
            / 2.0;
        assert!(tv < 0.02, "TV distance {tv} too large (s={s}, q={q})");
    }

    #[test]
    fn matches_pmf_skew_1_1() {
        check_against_pmf(1_000, 1.1, 0.0, 71);
    }

    #[test]
    fn matches_pmf_skew_1_8() {
        check_against_pmf(1_000, 1.8, 0.0, 72);
    }

    #[test]
    fn matches_pmf_s_equal_1() {
        check_against_pmf(500, 1.0, 0.0, 73);
    }

    #[test]
    fn matches_pmf_mandelbrot() {
        check_against_pmf(1_000, 1.3, 2.7, 74);
    }

    #[test]
    fn samples_in_range() {
        for &(s, q) in &[(0.5, 0.0), (1.0, 0.0), (1.1, 0.0), (1.8, 3.0), (3.0, 0.5)] {
            let z = ZipfSampler::with_shift(100, s, q);
            let mut rng = SplitMix64::new(75);
            for _ in 0..50_000 {
                let x = z.sample(&mut rng);
                assert!((1..=100).contains(&x), "out of range: {x} (s={s}, q={q})");
            }
        }
    }

    #[test]
    fn universe_one() {
        let z = ZipfSampler::new(1, 1.1);
        let mut rng = SplitMix64::new(76);
        for _ in 0..100 {
            assert_eq!(z.sample(&mut rng), 1);
        }
    }

    #[test]
    fn rank_one_dominates_high_skew() {
        let emp = empirical(10_000, 1.8, 0.0, 100_000, 77);
        assert!(emp[1] > 0.5, "rank 1 should carry most mass at s=1.8");
    }

    #[test]
    fn deterministic_given_seed() {
        let z = ZipfSampler::new(1_000, 1.1);
        let mut a = SplitMix64::new(9);
        let mut b = SplitMix64::new(9);
        for _ in 0..1_000 {
            assert_eq!(z.sample(&mut a), z.sample(&mut b));
        }
    }
}
