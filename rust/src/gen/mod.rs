//! Workload synthesis and dataset I/O.
//!
//! The paper evaluates on zipfian streams (skew ρ ∈ {1.1, 1.8}) of 1–29
//! billion items. This module provides:
//!
//! * [`ZipfSampler`] — an `O(1)` rejection-inversion sampler for the
//!   zipf / zipf-Mandelbrot family (the Hurwitz-zeta distribution of the
//!   authors' Information Sciences paper is the same family),
//! * [`UniformSampler`] — the unskewed control,
//! * [`ItemSource`] — random-access, thread-safe stream sources whose
//!   content is independent of the parallel decomposition (chunk-seeded
//!   RNG), so `p` workers see the *same* stream for every `p`,
//! * [`dataset`] — the `PSSD` binary on-disk format + chunked readers.

pub mod dataset;
pub mod source;
pub mod uniform;
pub mod zipf;

pub use dataset::{DatasetHeader, DatasetReader, DatasetWriter};
pub use source::{FileSource, GeneratedSource, InMemorySource, ItemSource};
pub use uniform::UniformSampler;
pub use zipf::ZipfSampler;
