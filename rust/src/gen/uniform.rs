//! Uniform item sampling — the no-skew control distribution.

use crate::util::SplitMix64;

/// Uniform over `[1, universe]` (rank-compatible with [`ZipfSampler`]).
///
/// [`ZipfSampler`]: super::zipf::ZipfSampler
#[derive(Debug, Clone)]
pub struct UniformSampler {
    universe: u64,
}

impl UniformSampler {
    /// New sampler over `[1, universe]`.
    pub fn new(universe: u64) -> Self {
        assert!(universe >= 1);
        Self { universe }
    }

    /// Draw one item.
    #[inline]
    pub fn sample(&self, rng: &mut SplitMix64) -> u64 {
        1 + rng.next_below(self.universe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_range_and_roughly_flat() {
        let s = UniformSampler::new(100);
        let mut rng = SplitMix64::new(81);
        let mut hist = vec![0u64; 101];
        let draws = 200_000;
        for _ in 0..draws {
            hist[s.sample(&mut rng) as usize] += 1;
        }
        assert_eq!(hist[0], 0);
        let expect = draws as f64 / 100.0;
        for (i, &c) in hist.iter().enumerate().skip(1) {
            assert!(
                (c as f64 - expect).abs() < expect * 0.15,
                "bucket {i}: {c} vs {expect}"
            );
        }
    }
}
