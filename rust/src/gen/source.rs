//! `ItemSource` — random-access, thread-safe stream sources.
//!
//! The parallel layers (OpenMP threads, MPI ranks, the coordinator's
//! shard workers) all consume the stream through this trait, which makes
//! two guarantees the experiments rely on:
//!
//! 1. **Decomposition independence**: the item at position `i` does not
//!    depend on which worker reads it or on the block boundaries —
//!    [`GeneratedSource`] seeds its RNG *per fixed-size generation chunk*
//!    (`GEN_CHUNK` positions), so any `[left, right)` range re-generates
//!    identically for every `p`. Sequential and parallel runs therefore
//!    process bit-identical streams.
//! 2. **Zero shared mutable state**: `fill` takes `&self`; sources are
//!    `Sync` and can be read by any number of workers concurrently.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::sync::Mutex;

use crate::util::{hash::mix64, SplitMix64};

use super::zipf::ZipfSampler;

/// Positions per generation chunk (fixed so streams are decomposition-
/// independent; must divide typical block sizes cheaply).
pub const GEN_CHUNK: u64 = 4096;

/// A random-access stream of `u64` item ids.
pub trait ItemSource: Sync {
    /// Total number of items.
    fn len(&self) -> u64;

    /// True if the stream is empty.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fill `out` with the items at positions `[start, start + out.len())`.
    fn fill(&self, start: u64, out: &mut [u64]);

    /// Convenience: materialize `[start, end)` as a vector.
    fn slice(&self, start: u64, end: u64) -> Vec<u64> {
        let mut v = vec![0u64; (end - start) as usize];
        self.fill(start, &mut v);
        v
    }
}

// ---------------------------------------------------------------- memory

/// A fully materialized stream (tests, small workloads).
#[derive(Debug, Clone)]
pub struct InMemorySource {
    items: Vec<u64>,
}

impl InMemorySource {
    /// Wrap a vector of items.
    pub fn new(items: Vec<u64>) -> Self {
        Self { items }
    }

    /// Borrow the underlying items.
    pub fn items(&self) -> &[u64] {
        &self.items
    }
}

impl ItemSource for InMemorySource {
    fn len(&self) -> u64 {
        self.items.len() as u64
    }

    fn fill(&self, start: u64, out: &mut [u64]) {
        let s = start as usize;
        out.copy_from_slice(&self.items[s..s + out.len()]);
    }
}

// ------------------------------------------------------------- generated

/// Distribution drawn by a [`GeneratedSource`].
#[derive(Debug, Clone)]
pub enum Distribution {
    /// Zipf / zipf-Mandelbrot over a rank universe.
    Zipf(ZipfSampler),
    /// Uniform over `[1, universe]`.
    Uniform { universe: u64 },
}

/// A stream synthesized on the fly: nothing is stored; any range
/// regenerates deterministically from `(seed, chunk_index)`.
#[derive(Debug, Clone)]
pub struct GeneratedSource {
    dist: Distribution,
    seed: u64,
    n: u64,
}

impl GeneratedSource {
    /// Zipf stream of `n` items, skew `s`, over `universe` ranks.
    pub fn zipf(n: u64, universe: u64, s: f64, seed: u64) -> Self {
        Self { dist: Distribution::Zipf(ZipfSampler::new(universe, s)), seed, n }
    }

    /// Zipf-Mandelbrot stream with shift `q`.
    pub fn zipf_mandelbrot(n: u64, universe: u64, s: f64, q: f64, seed: u64) -> Self {
        Self {
            dist: Distribution::Zipf(ZipfSampler::with_shift(universe, s, q)),
            seed,
            n,
        }
    }

    /// Uniform stream.
    pub fn uniform(n: u64, universe: u64, seed: u64) -> Self {
        Self { dist: Distribution::Uniform { universe }, seed, n }
    }

    #[inline]
    fn draw(&self, rng: &mut SplitMix64) -> u64 {
        match &self.dist {
            Distribution::Zipf(z) => z.sample(rng),
            Distribution::Uniform { universe } => 1 + rng.next_below(*universe),
        }
    }
}

impl ItemSource for GeneratedSource {
    fn len(&self) -> u64 {
        self.n
    }

    fn fill(&self, start: u64, out: &mut [u64]) {
        debug_assert!(start + out.len() as u64 <= self.n);
        let mut pos = start;
        let end = start + out.len() as u64;
        let mut off = 0usize;
        while pos < end {
            let chunk = pos / GEN_CHUNK;
            let chunk_start = chunk * GEN_CHUNK;
            let chunk_end = (chunk_start + GEN_CHUNK).min(self.n);
            // Per-chunk RNG: decomposition-independent regeneration.
            let mut rng = SplitMix64::new(mix64(self.seed ^ mix64(chunk)));
            // Burn draws up to `pos` within the chunk.
            // (A draw consumes a variable number of RNG words under
            // rejection, so we re-draw items, not RNG words.)
            for _ in chunk_start..pos {
                self.draw(&mut rng);
            }
            let take = ((chunk_end.min(end)) - pos) as usize;
            for slot in &mut out[off..off + take] {
                *slot = self.draw(&mut rng);
            }
            off += take;
            pos += take as u64;
        }
    }
}

// ------------------------------------------------------------------ file

/// A stream backed by a `PSSD` dataset file (see [`super::dataset`]).
///
/// Reads are `pread`-style (seek + read on a per-call handle clone) so
/// concurrent workers don't serialize on one file offset.
pub struct FileSource {
    file: Mutex<File>,
    data_offset: u64,
    n: u64,
}

impl FileSource {
    /// Open from a file positioned at its data section.
    pub(crate) fn new(file: File, data_offset: u64, n: u64) -> Self {
        Self { file: Mutex::new(file), data_offset, n }
    }
}

impl ItemSource for FileSource {
    fn len(&self) -> u64 {
        self.n
    }

    fn fill(&self, start: u64, out: &mut [u64]) {
        debug_assert!(start + out.len() as u64 <= self.n);
        let mut buf = vec![0u8; out.len() * 8];
        {
            let mut f = self.file.lock().expect("file lock poisoned");
            f.seek(SeekFrom::Start(self.data_offset + start * 8))
                .expect("seek failed");
            f.read_exact(&mut buf).expect("dataset read failed");
        }
        for (i, chunk) in buf.chunks_exact(8).enumerate() {
            out[i] = u64::from_le_bytes(chunk.try_into().unwrap());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inmemory_roundtrip() {
        let s = InMemorySource::new(vec![10, 20, 30, 40]);
        assert_eq!(s.len(), 4);
        assert_eq!(s.slice(1, 3), vec![20, 30]);
    }

    #[test]
    fn generated_is_decomposition_independent() {
        let src = GeneratedSource::zipf(20_000, 1_000, 1.1, 42);
        let whole = src.slice(0, 20_000);
        // Any partition must reproduce the same items.
        for p in [2u64, 3, 7, 16] {
            let mut parts = Vec::new();
            for r in 0..p {
                let left = r * 20_000 / p;
                let right = (r + 1) * 20_000 / p;
                parts.extend(src.slice(left, right));
            }
            assert_eq!(parts, whole, "p={p} changed the stream");
        }
    }

    #[test]
    fn generated_unaligned_ranges() {
        let src = GeneratedSource::uniform(10_000, 500, 7);
        let whole = src.slice(0, 10_000);
        assert_eq!(src.slice(4095, 4097), whole[4095..4097].to_vec());
        assert_eq!(src.slice(1, 9999), whole[1..9999].to_vec());
    }

    #[test]
    fn generated_zipf_is_skewed() {
        let src = GeneratedSource::zipf(50_000, 10_000, 1.8, 1);
        let items = src.slice(0, 50_000);
        let ones = items.iter().filter(|&&x| x == 1).count();
        assert!(ones as f64 > 0.4 * 50_000.0, "rank 1 share {ones}");
    }

    #[test]
    fn different_seeds_differ() {
        let a = GeneratedSource::zipf(1_000, 100, 1.1, 1).slice(0, 1_000);
        let b = GeneratedSource::zipf(1_000, 100, 1.1, 2).slice(0, 1_000);
        assert_ne!(a, b);
    }
}
