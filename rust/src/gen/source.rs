//! `ItemSource` — random-access, thread-safe stream sources.
//!
//! The parallel layers (OpenMP threads, MPI ranks, the coordinator's
//! shard workers) all consume the stream through this trait, which makes
//! two guarantees the experiments rely on:
//!
//! 1. **Decomposition independence**: the item at position `i` does not
//!    depend on which worker reads it or on the block boundaries —
//!    [`GeneratedSource`] seeds its RNG *per fixed-size generation chunk*
//!    (`GEN_CHUNK` positions), so any `[left, right)` range re-generates
//!    identically for every `p`. Sequential and parallel runs therefore
//!    process bit-identical streams.
//! 2. **Zero shared mutable state**: `fill` takes `&self`; sources are
//!    `Sync` and can be read by any number of workers concurrently.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::sync::Mutex;

use crate::util::{hash::mix64, SplitMix64};

use super::zipf::ZipfSampler;

/// Positions per generation chunk (fixed so streams are decomposition-
/// independent; must divide typical block sizes cheaply).
pub const GEN_CHUNK: u64 = 4096;

/// A random-access stream of `u64` item ids.
pub trait ItemSource: Sync {
    /// Total number of items.
    fn len(&self) -> u64;

    /// True if the stream is empty.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fill `out` with the items at positions `[start, start + out.len())`.
    fn fill(&self, start: u64, out: &mut [u64]);

    /// Convenience: materialize `[start, end)` as a vector.
    fn slice(&self, start: u64, end: u64) -> Vec<u64> {
        let mut v = vec![0u64; (end - start) as usize];
        self.fill(start, &mut v);
        v
    }
}

// ---------------------------------------------------------------- memory

/// A fully materialized stream (tests, small workloads).
#[derive(Debug, Clone)]
pub struct InMemorySource {
    items: Vec<u64>,
}

impl InMemorySource {
    /// Wrap a vector of items.
    pub fn new(items: Vec<u64>) -> Self {
        Self { items }
    }

    /// Borrow the underlying items.
    pub fn items(&self) -> &[u64] {
        &self.items
    }
}

impl ItemSource for InMemorySource {
    fn len(&self) -> u64 {
        self.items.len() as u64
    }

    fn fill(&self, start: u64, out: &mut [u64]) {
        let s = start as usize;
        out.copy_from_slice(&self.items[s..s + out.len()]);
    }
}

// ------------------------------------------------------------- generated

/// Distribution drawn by a [`GeneratedSource`].
#[derive(Debug, Clone)]
pub enum Distribution {
    /// Zipf / zipf-Mandelbrot over a rank universe.
    Zipf(ZipfSampler),
    /// Uniform over `[1, universe]`.
    Uniform { universe: u64 },
    /// Adversarial single-hot-key workload: one item id drawn with
    /// probability `p`, a zipf tail over `[1, universe]` otherwise.
    /// The worst case for keyed routing — a `p` fraction of the stream
    /// hashes to one shard. `drift = Some((at, to))` switches the hot
    /// identity to `to` at absolute position `at` (mid-stream drift).
    HotKey {
        /// Tail sampler for the non-hot draws.
        tail: ZipfSampler,
        /// The hot item id (outside the tail universe).
        hot: u64,
        /// Optional `(position, new_id)` identity switch.
        drift: Option<(u64, u64)>,
        /// Probability of drawing the hot id.
        p: f64,
    },
}

/// A stream synthesized on the fly: nothing is stored; any range
/// regenerates deterministically from `(seed, chunk_index)`.
#[derive(Debug, Clone)]
pub struct GeneratedSource {
    dist: Distribution,
    seed: u64,
    n: u64,
}

impl GeneratedSource {
    /// Zipf stream of `n` items, skew `s`, over `universe` ranks.
    pub fn zipf(n: u64, universe: u64, s: f64, seed: u64) -> Self {
        Self { dist: Distribution::Zipf(ZipfSampler::new(universe, s)), seed, n }
    }

    /// Zipf-Mandelbrot stream with shift `q`.
    pub fn zipf_mandelbrot(n: u64, universe: u64, s: f64, q: f64, seed: u64) -> Self {
        Self {
            dist: Distribution::Zipf(ZipfSampler::with_shift(universe, s, q)),
            seed,
            n,
        }
    }

    /// Uniform stream.
    pub fn uniform(n: u64, universe: u64, seed: u64) -> Self {
        Self { dist: Distribution::Uniform { universe }, seed, n }
    }

    /// Single-hot-key stream: item `universe + 1` with probability `p`,
    /// a zipf tail of skew `s` over `universe` ranks otherwise.
    pub fn hot_key(n: u64, universe: u64, s: f64, p: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&p), "p must be in [0, 1]");
        Self {
            dist: Distribution::HotKey {
                tail: ZipfSampler::new(universe, s),
                hot: universe + 1,
                drift: None,
                p,
            },
            seed,
            n,
        }
    }

    /// [`GeneratedSource::hot_key`] with mid-stream drift: the hot
    /// identity switches from `universe + 1` to `universe + 2` at
    /// absolute position `drift_at`.
    pub fn hot_key_drift(
        n: u64,
        universe: u64,
        s: f64,
        p: f64,
        drift_at: u64,
        seed: u64,
    ) -> Self {
        assert!((0.0..=1.0).contains(&p), "p must be in [0, 1]");
        Self {
            dist: Distribution::HotKey {
                tail: ZipfSampler::new(universe, s),
                hot: universe + 1,
                drift: Some((drift_at, universe + 2)),
                p,
            },
            seed,
            n,
        }
    }

    /// Draw the item at absolute position `pos`. The RNG consumption
    /// pattern is position-independent (the position only selects the
    /// hot *identity* under drift), so chunk-seeded regeneration stays
    /// decomposition-independent.
    #[inline]
    fn draw_at(&self, pos: u64, rng: &mut SplitMix64) -> u64 {
        match &self.dist {
            Distribution::Zipf(z) => z.sample(rng),
            Distribution::Uniform { universe } => 1 + rng.next_below(*universe),
            Distribution::HotKey { tail, hot, drift, p } => {
                if rng.next_f64() < *p {
                    match drift {
                        Some((at, to)) if pos >= *at => *to,
                        _ => *hot,
                    }
                } else {
                    tail.sample(rng)
                }
            }
        }
    }
}

impl ItemSource for GeneratedSource {
    fn len(&self) -> u64 {
        self.n
    }

    fn fill(&self, start: u64, out: &mut [u64]) {
        debug_assert!(start + out.len() as u64 <= self.n);
        let mut pos = start;
        let end = start + out.len() as u64;
        let mut off = 0usize;
        while pos < end {
            let chunk = pos / GEN_CHUNK;
            let chunk_start = chunk * GEN_CHUNK;
            let chunk_end = (chunk_start + GEN_CHUNK).min(self.n);
            // Per-chunk RNG: decomposition-independent regeneration.
            let mut rng = SplitMix64::new(mix64(self.seed ^ mix64(chunk)));
            // Burn draws up to `pos` within the chunk.
            // (A draw consumes a variable number of RNG words under
            // rejection, so we re-draw items, not RNG words.)
            for i in chunk_start..pos {
                self.draw_at(i, &mut rng);
            }
            let take = ((chunk_end.min(end)) - pos) as usize;
            for (i, slot) in out[off..off + take].iter_mut().enumerate() {
                *slot = self.draw_at(pos + i as u64, &mut rng);
            }
            off += take;
            pos += take as u64;
        }
    }
}

// ------------------------------------------------------------------ file

/// A stream backed by a `PSSD` dataset file (see [`super::dataset`]).
///
/// Reads are `pread`-style (seek + read on a per-call handle clone) so
/// concurrent workers don't serialize on one file offset.
pub struct FileSource {
    file: Mutex<File>,
    data_offset: u64,
    n: u64,
}

impl FileSource {
    /// Open from a file positioned at its data section.
    pub(crate) fn new(file: File, data_offset: u64, n: u64) -> Self {
        Self { file: Mutex::new(file), data_offset, n }
    }
}

impl ItemSource for FileSource {
    fn len(&self) -> u64 {
        self.n
    }

    fn fill(&self, start: u64, out: &mut [u64]) {
        debug_assert!(start + out.len() as u64 <= self.n);
        let mut buf = vec![0u8; out.len() * 8];
        {
            let mut f = self.file.lock().expect("file lock poisoned");
            f.seek(SeekFrom::Start(self.data_offset + start * 8))
                .expect("seek failed");
            f.read_exact(&mut buf).expect("dataset read failed");
        }
        for (i, chunk) in buf.chunks_exact(8).enumerate() {
            out[i] = u64::from_le_bytes(chunk.try_into().unwrap());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inmemory_roundtrip() {
        let s = InMemorySource::new(vec![10, 20, 30, 40]);
        assert_eq!(s.len(), 4);
        assert_eq!(s.slice(1, 3), vec![20, 30]);
    }

    #[test]
    fn generated_is_decomposition_independent() {
        let src = GeneratedSource::zipf(20_000, 1_000, 1.1, 42);
        let whole = src.slice(0, 20_000);
        // Any partition must reproduce the same items.
        for p in [2u64, 3, 7, 16] {
            let mut parts = Vec::new();
            for r in 0..p {
                let left = r * 20_000 / p;
                let right = (r + 1) * 20_000 / p;
                parts.extend(src.slice(left, right));
            }
            assert_eq!(parts, whole, "p={p} changed the stream");
        }
    }

    #[test]
    fn generated_unaligned_ranges() {
        let src = GeneratedSource::uniform(10_000, 500, 7);
        let whole = src.slice(0, 10_000);
        assert_eq!(src.slice(4095, 4097), whole[4095..4097].to_vec());
        assert_eq!(src.slice(1, 9999), whole[1..9999].to_vec());
    }

    #[test]
    fn generated_zipf_is_skewed() {
        let src = GeneratedSource::zipf(50_000, 10_000, 1.8, 1);
        let items = src.slice(0, 50_000);
        let ones = items.iter().filter(|&&x| x == 1).count();
        assert!(ones as f64 > 0.4 * 50_000.0, "rank 1 share {ones}");
    }

    #[test]
    fn hot_key_share_tracks_p_and_drift_switches_identity() {
        let n = 50_000u64;
        let src = GeneratedSource::hot_key(n, 1_000, 1.1, 0.6, 11);
        let items = src.slice(0, n);
        let hot = 1_001u64;
        let share =
            items.iter().filter(|&&x| x == hot).count() as f64 / n as f64;
        assert!((share - 0.6).abs() < 0.02, "hot share {share}");
        assert!(items.iter().all(|&x| x <= hot), "ids beyond the universe");

        // Drift at the midpoint: the old id never appears after, the
        // new one never before.
        let drift = GeneratedSource::hot_key_drift(n, 1_000, 1.1, 0.6, n / 2, 11);
        let d = drift.slice(0, n);
        let (pre, post) = d.split_at((n / 2) as usize);
        assert!(pre.iter().any(|&x| x == hot));
        assert!(pre.iter().all(|&x| x != 1_002));
        assert!(post.iter().any(|&x| x == 1_002));
        assert!(post.iter().all(|&x| x != hot));
    }

    #[test]
    fn hot_key_is_decomposition_independent() {
        // Drift makes draws position-dependent — exactly the case the
        // position-threaded burn loop must keep bit-identical.
        let src = GeneratedSource::hot_key_drift(20_000, 500, 1.1, 0.3, 9_999, 5);
        let whole = src.slice(0, 20_000);
        for p in [2u64, 3, 7, 16] {
            let mut parts = Vec::new();
            for r in 0..p {
                let left = r * 20_000 / p;
                let right = (r + 1) * 20_000 / p;
                parts.extend(src.slice(left, right));
            }
            assert_eq!(parts, whole, "p={p} changed the stream");
        }
        assert_eq!(src.slice(4_095, 4_097), whole[4_095..4_097].to_vec());
    }

    #[test]
    fn different_seeds_differ() {
        let a = GeneratedSource::zipf(1_000, 100, 1.1, 1).slice(0, 1_000);
        let b = GeneratedSource::zipf(1_000, 100, 1.1, 2).slice(0, 1_000);
        assert_ne!(a, b);
    }
}
