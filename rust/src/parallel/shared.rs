//! The end-to-end shared-memory driver — paper Algorithm 1 realized with
//! scoped threads: block decomposition → per-thread sequential Space
//! Saving → frequency-sorted freeze → tree reduction → prune.
//!
//! Per-phase wallclock is recorded into [`PhaseTimes`] so the fractional
//! overhead of Figure 3 can be measured on real executions.

use std::time::Instant;

use crate::gen::ItemSource;
use crate::metrics::PhaseTimes;
use crate::summary::{Counter, FrequencySummary, Summary};

use super::partition::block_range;
use super::reduction::tree_reduce;
use super::thread_pool::fork_join;

// The structure selector lives with the structures it selects
// (`summary::kind`); re-exported here because the shared-memory driver
// is where it historically surfaced (`run_shared(..., SummaryKind)`).
pub use crate::summary::SummaryKind;

/// One worker's scan of `[left, right)` with the selected structure.
fn scan(kind: SummaryKind, src: &dyn ItemSource, left: u64, right: u64, k: usize) -> Summary {
    /// Read granularity: large enough to amortize `fill`, small
    /// enough to stay in L2.
    const BUF: usize = 1 << 16;
    let mut buf = vec![0u64; BUF];
    let mut s = kind.build(k);
    scan_into(&mut s, src, left, right, &mut buf);
    s.freeze()
}

fn scan_into<S: FrequencySummary>(
    s: &mut S,
    src: &dyn ItemSource,
    left: u64,
    right: u64,
    buf: &mut [u64],
) {
    let mut pos = left;
    while pos < right {
        let take = ((right - pos) as usize).min(buf.len());
        src.fill(pos, &mut buf[..take]);
        s.offer_all(&buf[..take]);
        pos += take as u64;
    }
}

/// Result of one shared-memory parallel run.
#[derive(Debug, Clone)]
pub struct SharedRunResult {
    /// The reduced global summary (before pruning).
    pub summary: Summary,
    /// Final k-majority candidates (`f̂ > n/k`), descending.
    pub frequent: Vec<Counter>,
    /// Wallclock phase breakdown (max over threads for the scan).
    pub times: PhaseTimes,
}

/// Run Parallel Space Saving over `source` with `threads` workers and
/// `k` counters each; report items with `f̂ > n / k_majority`.
pub fn run_shared(
    source: &dyn ItemSource,
    k: usize,
    k_majority: u64,
    threads: usize,
    kind: SummaryKind,
) -> SharedRunResult {
    assert!(threads >= 1);
    let n = source.len();

    let t0 = Instant::now();
    // Parallel region: local scans (scan time = per-thread max, the
    // barrier semantics of an OpenMP region).
    let scans: Vec<(Summary, f64)> = fork_join(threads, |r| {
        let (left, right) = block_range(n, threads as u64, r as u64);
        let t = Instant::now();
        let local = scan(kind, source, left, right, k);
        (local, t.elapsed().as_secs_f64())
    });
    let region = t0.elapsed().as_secs_f64();
    let scan = scans.iter().map(|(_, t)| *t).fold(0.0f64, f64::max);
    let spawn = (region - scan).max(0.0);

    let t1 = Instant::now();
    let summary = tree_reduce(scans.into_iter().map(|(s, _)| s).collect());
    let reduce = t1.elapsed().as_secs_f64();

    let t2 = Instant::now();
    let frequent = summary.prune(n, k_majority);
    let prune = t2.elapsed().as_secs_f64();

    SharedRunResult {
        summary,
        frequent,
        times: PhaseTimes { spawn, scan, reduce, prune },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::Exact;
    use crate::gen::{GeneratedSource, InMemorySource};
    use crate::metrics::AccuracyReport;

    #[test]
    fn parallel_equals_sequential_guarantees() {
        let src = GeneratedSource::zipf(100_000, 5_000, 1.1, 13);
        let seq = run_shared(&src, 200, 200, 1, SummaryKind::Heap);

        let mut exact = Exact::new();
        exact.offer_all(&src.slice(0, src.len()));

        for threads in [2usize, 3, 4, 8] {
            let par = run_shared(&src, 200, 200, threads, SummaryKind::Heap);
            assert_eq!(par.summary.n(), 100_000);
            let acc = AccuracyReport::evaluate(&par.frequent, &exact, 200);
            assert_eq!(acc.recall, 1.0, "threads={threads}");
            assert_eq!(acc.precision, 1.0, "threads={threads}");
            // ARE stays tiny (paper Figure 1: ~1e-8 at billions scale;
            // scaled down we still expect near-zero).
            assert!(acc.are < 0.01, "threads={threads}: ARE {}", acc.are);
            // Parallel must report the same frequent item set as seq
            // (order can differ: merged estimates differ slightly).
            let a: std::collections::HashSet<u64> =
                seq.frequent.iter().map(|c| c.item).collect();
            let b: std::collections::HashSet<u64> =
                par.frequent.iter().map(|c| c.item).collect();
            assert_eq!(a, b, "threads={threads}");
        }
    }

    #[test]
    fn all_summary_kinds_agree() {
        let src = GeneratedSource::zipf(50_000, 2_000, 1.8, 17);
        let h = run_shared(&src, 100, 100, 4, SummaryKind::Heap);
        let hi: std::collections::HashSet<u64> = h.frequent.iter().map(|c| c.item).collect();
        for kind in [SummaryKind::BucketList, SummaryKind::Compact] {
            let b = run_shared(&src, 100, 100, 4, kind);
            let bi: std::collections::HashSet<u64> =
                b.frequent.iter().map(|c| c.item).collect();
            assert_eq!(hi, bi, "{kind}");
        }
    }

    #[test]
    fn handles_tiny_inputs_and_more_threads_than_items() {
        let src = InMemorySource::new(vec![1, 1, 2]);
        let r = run_shared(&src, 4, 2, 8, SummaryKind::Heap);
        assert_eq!(r.summary.n(), 3);
        assert_eq!(r.frequent.len(), 1);
        assert_eq!(r.frequent[0].item, 1);
    }

    #[test]
    fn hot_key_workload_recall_and_bounds() {
        // The adversarial single-hot-key workload (and its mid-stream
        // drift variant) through the chunk-parallel driver: block
        // decomposition concentrates the hot key in every block, and
        // the reduced result must still report it first with the
        // bounds honored against exact truth.
        for src in [
            GeneratedSource::hot_key(120_000, 4_000, 1.1, 0.6, 23),
            GeneratedSource::hot_key_drift(120_000, 4_000, 1.1, 0.6, 60_000, 23),
        ] {
            let mut exact = Exact::new();
            exact.offer_all(&src.slice(0, src.len()));
            for threads in [1usize, 4] {
                let r = run_shared(&src, 256, 256, threads, SummaryKind::Heap);
                assert_eq!(r.summary.n(), 120_000);
                let acc = AccuracyReport::evaluate(&r.frequent, &exact, 256);
                assert_eq!(acc.recall, 1.0, "threads={threads}");
                // The top report is a hot identity: ≥ p·n before the
                // drift, ≥ p·n/2 for each identity after it.
                let top = &r.frequent[0];
                let f = exact.count(top.item);
                assert!(top.count >= f && top.count - top.err <= f);
                assert!(f >= 120_000 * 25 / 100, "top item is not the hot key");
            }
        }
    }

    #[test]
    fn times_are_populated() {
        let src = GeneratedSource::zipf(50_000, 1_000, 1.1, 5);
        let r = run_shared(&src, 64, 64, 2, SummaryKind::Heap);
        assert!(r.times.scan > 0.0);
        assert!(r.times.total() >= r.times.scan);
    }
}
