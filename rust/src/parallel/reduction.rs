//! Pairwise tree reduction with the `combine` operator — the
//! shared-memory stand-in for OpenMP v4's user-defined reduction (and,
//! structurally, for `MPI_Reduce` with a user-defined op: both execute a
//! ⌈log₂ p⌉-depth combine tree).

use crate::summary::Summary;

use super::thread_pool::fork_join;

/// Reduce `summaries` to one with a binary combine tree.
///
/// Each round pairs adjacent survivors — on the compacted vector this is
/// exactly the recursive-halving schedule (`i` with `i + 2^d` on original
/// indices) that MPI implementations use, so the simulated and real
/// versions agree on tree shape (which matters: combine is
/// order-sensitive in its exact `f̂` values, though not in its
/// guarantees). Each round's combines are independent and run fork/join,
/// mirroring what the OpenMP runtime does during a reduction.
pub fn tree_reduce(mut current: Vec<Summary>) -> Summary {
    assert!(!current.is_empty(), "nothing to reduce");
    while current.len() > 1 {
        let npairs = current.len() / 2;
        let refs = &current;
        let mut next: Vec<Summary> = if npairs > 1 {
            fork_join(npairs, |w| refs[2 * w].combine(&refs[2 * w + 1]))
        } else {
            vec![refs[0].combine(&refs[1])]
        };
        if current.len() % 2 == 1 {
            next.push(current.pop().expect("odd leftover"));
        }
        current = next;
    }
    current.pop().expect("non-empty")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::summary::{FrequencySummary, SpaceSaving};
    use crate::util::SplitMix64;

    fn summarize(items: &[u64], k: usize) -> Summary {
        let mut ss = SpaceSaving::new(k);
        ss.offer_all(items);
        ss.freeze()
    }

    #[test]
    fn reduce_single_is_identity() {
        let s = summarize(&[1, 1, 2], 4);
        assert_eq!(tree_reduce(vec![s.clone()]).counters(), s.counters());
    }

    #[test]
    fn reduce_matches_sequential_fold_for_two() {
        let a = summarize(&[1, 1, 2, 3], 4);
        let b = summarize(&[2, 2, 5], 4);
        let want = a.combine(&b);
        assert_eq!(tree_reduce(vec![a, b]).counters(), want.counters());
    }

    #[test]
    fn reduce_preserves_n_and_guarantees() {
        let mut rng = SplitMix64::new(91);
        for p in [2usize, 3, 4, 5, 8, 13, 16] {
            let k = 32;
            let blocks: Vec<Vec<u64>> = (0..p)
                .map(|_| (0..4_000).map(|_| rng.next_below(100)).collect())
                .collect();
            let total_n: u64 = blocks.iter().map(|b| b.len() as u64).sum();
            let reduced =
                tree_reduce(blocks.iter().map(|b| summarize(b, k)).collect());
            assert_eq!(reduced.n(), total_n, "p={p}");

            // Recall on the union: every global k-majority item survives.
            let mut exact = crate::baselines::Exact::new();
            for b in &blocks {
                exact.offer_all(b);
            }
            let monitored: std::collections::HashSet<u64> =
                reduced.counters().iter().map(|c| c.item).collect();
            let thresh = total_n / k as u64;
            for c in exact.k_majority(k as u64) {
                assert!(
                    monitored.contains(&c.item),
                    "p={p}: lost frequent item {} (f={} > {thresh})",
                    c.item,
                    c.count
                );
            }
            // Over-approximation: every reported count upper-bounds truth.
            for c in reduced.counters() {
                assert!(c.count >= exact.count(c.item), "p={p}: under-estimate");
                assert!(c.count - c.err <= exact.count(c.item), "p={p}: bad err");
            }
        }
    }

    #[test]
    fn reduce_handles_non_power_of_two() {
        let blocks: Vec<Summary> =
            (0..7).map(|i| summarize(&vec![i as u64; 10], 4)).collect();
        let r = tree_reduce(blocks);
        assert_eq!(r.n(), 70);
    }
}
