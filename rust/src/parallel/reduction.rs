//! Pairwise tree reduction with the `combine` operator — the
//! shared-memory stand-in for OpenMP v4's user-defined reduction (and,
//! structurally, for `MPI_Reduce` with a user-defined op: both execute a
//! ⌈log₂ p⌉-depth combine tree).

use crate::summary::Summary;

use super::thread_pool::fork_join;

/// Below this leaf count the whole reduction runs inline: spawning a
/// worker costs more than combining a handful of `k`-counter summaries,
/// and the query read path ([`crate::query`]) calls this per query.
const INLINE_LEAVES: usize = 32;

/// [`tree_reduce`] over *borrowed* leaves — the first combine round
/// reads straight from the borrows (no upfront clone of every input),
/// so read paths that hold `Arc`-shared epoch snapshots (see
/// [`crate::query`]) can run the same merge tree without copying the
/// per-shard summaries they do not own. Only an odd leftover leaf is
/// cloned. The pairing schedule is identical to [`tree_reduce`]'s (the
/// exact `f̂` values are tree-shape-sensitive), but small reductions run
/// entirely inline so a latency-critical query never pays thread-spawn
/// overhead.
pub fn tree_reduce_refs(leaves: &[&Summary]) -> Summary {
    assert!(!leaves.is_empty(), "nothing to reduce");
    if leaves.len() == 1 {
        return leaves[0].clone();
    }
    let npairs = leaves.len() / 2;
    let mut first: Vec<Summary> = if npairs > 1 && leaves.len() > INLINE_LEAVES {
        fork_join(npairs, |w| leaves[2 * w].combine(leaves[2 * w + 1]))
    } else {
        (0..npairs).map(|w| leaves[2 * w].combine(leaves[2 * w + 1])).collect()
    };
    if leaves.len() % 2 == 1 {
        first.push((*leaves.last().expect("non-empty")).clone());
    }
    if first.len() <= 1 {
        return first.pop().expect("non-empty");
    }
    if first.len() <= INLINE_LEAVES {
        // Finish inline with the same adjacent-pair schedule.
        let mut current = first;
        while current.len() > 1 {
            let npairs = current.len() / 2;
            let mut next: Vec<Summary> =
                (0..npairs).map(|w| current[2 * w].combine(&current[2 * w + 1])).collect();
            if current.len() % 2 == 1 {
                next.push(current.pop().expect("odd leftover"));
            }
            current = next;
        }
        current.pop().expect("non-empty")
    } else {
        tree_reduce(first)
    }
}

/// Reduce `summaries` to one with a binary combine tree.
///
/// Each round pairs adjacent survivors — on the compacted vector this is
/// exactly the recursive-halving schedule (`i` with `i + 2^d` on original
/// indices) that MPI implementations use, so the simulated and real
/// versions agree on tree shape (which matters: combine is
/// order-sensitive in its exact `f̂` values, though not in its
/// guarantees). Each round's combines are independent and run fork/join,
/// mirroring what the OpenMP runtime does during a reduction.
pub fn tree_reduce(mut current: Vec<Summary>) -> Summary {
    assert!(!current.is_empty(), "nothing to reduce");
    while current.len() > 1 {
        let npairs = current.len() / 2;
        let refs = &current;
        let mut next: Vec<Summary> = if npairs > 1 {
            fork_join(npairs, |w| refs[2 * w].combine(&refs[2 * w + 1]))
        } else {
            vec![refs[0].combine(&refs[1])]
        };
        if current.len() % 2 == 1 {
            next.push(current.pop().expect("odd leftover"));
        }
        current = next;
    }
    current.pop().expect("non-empty")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::summary::{FrequencySummary, SpaceSaving};
    use crate::util::SplitMix64;

    fn summarize(items: &[u64], k: usize) -> Summary {
        let mut ss = SpaceSaving::new(k);
        ss.offer_all(items);
        ss.freeze()
    }

    #[test]
    fn reduce_single_is_identity() {
        let s = summarize(&[1, 1, 2], 4);
        assert_eq!(tree_reduce(vec![s.clone()]).counters(), s.counters());
    }

    #[test]
    fn reduce_matches_sequential_fold_for_two() {
        let a = summarize(&[1, 1, 2, 3], 4);
        let b = summarize(&[2, 2, 5], 4);
        let want = a.combine(&b);
        assert_eq!(tree_reduce(vec![a, b]).counters(), want.counters());
    }

    #[test]
    fn reduce_preserves_n_and_guarantees() {
        let mut rng = SplitMix64::new(91);
        for p in [2usize, 3, 4, 5, 8, 13, 16] {
            let k = 32;
            let blocks: Vec<Vec<u64>> = (0..p)
                .map(|_| (0..4_000).map(|_| rng.next_below(100)).collect())
                .collect();
            let total_n: u64 = blocks.iter().map(|b| b.len() as u64).sum();
            let reduced =
                tree_reduce(blocks.iter().map(|b| summarize(b, k)).collect());
            assert_eq!(reduced.n(), total_n, "p={p}");

            // Recall on the union: every global k-majority item survives.
            let mut exact = crate::baselines::Exact::new();
            for b in &blocks {
                exact.offer_all(b);
            }
            let monitored: std::collections::HashSet<u64> =
                reduced.counters().iter().map(|c| c.item).collect();
            let thresh = total_n / k as u64;
            for c in exact.k_majority(k as u64) {
                assert!(
                    monitored.contains(&c.item),
                    "p={p}: lost frequent item {} (f={} > {thresh})",
                    c.item,
                    c.count
                );
            }
            // Over-approximation: every reported count upper-bounds truth.
            for c in reduced.counters() {
                assert!(c.count >= exact.count(c.item), "p={p}: under-estimate");
                assert!(c.count - c.err <= exact.count(c.item), "p={p}: bad err");
            }
        }
    }

    #[test]
    fn reduce_handles_non_power_of_two() {
        let blocks: Vec<Summary> =
            (0..7).map(|i| summarize(&vec![i as u64; 10], 4)).collect();
        let r = tree_reduce(blocks);
        assert_eq!(r.n(), 70);
    }

    #[test]
    fn refs_variant_matches_owned_tree() {
        let mut rng = SplitMix64::new(17);
        for p in [1usize, 2, 3, 5, 8, 9] {
            let blocks: Vec<Summary> = (0..p)
                .map(|_| {
                    let items: Vec<u64> =
                        (0..2_000).map(|_| rng.next_below(150)).collect();
                    summarize(&items, 24)
                })
                .collect();
            let want = tree_reduce(blocks.clone());
            let refs: Vec<&Summary> = blocks.iter().collect();
            let got = tree_reduce_refs(&refs);
            assert_eq!(got.counters(), want.counters(), "p={p}");
            assert_eq!(got.n(), want.n(), "p={p}");
        }
    }
}
