//! Bounded lock-free single-producer / single-consumer ring — the
//! coordinator's chunk-handoff transport (and, reversed, its chunk
//! free-list).
//!
//! `std::sync::mpsc::sync_channel` pays a mutex + condvar handshake per
//! message; at the coordinator's chunk rate that handshake *is* the
//! transport cost. QPOPSS (Jarlow et al., 2024) makes the same point
//! for parallelism-optimized Space Saving: the producer→worker handoff
//! must be a couple of plain stores, not a lock. This ring is the
//! std-only (vendored-crates rule: no `crossbeam`) classic Lamport
//! queue with the two standard refinements — cache-line-padded indices
//! and producer/consumer-local index caches — plus an explicit close
//! protocol so drain ordering stays deterministic.
//!
//! # Memory-ordering argument
//!
//! The ring is correct with exactly four ordered atomic operations per
//! transfer; everything else is `Relaxed` or plain memory:
//!
//! * **`tail`** is written only by the producer and read by the
//!   consumer. The producer writes the slot *then* stores `tail + 1`
//!   with `Release`; the consumer loads `tail` with `Acquire` before
//!   reading the slot. The Release/Acquire pair makes the slot write
//!   *happen-before* any consumer read that observed the new `tail`,
//!   so the consumer never reads a half-written message.
//! * **`head`** is the mirror image: the consumer moves the value out
//!   of the slot *then* stores `head + 1` with `Release`; the producer
//!   loads `head` with `Acquire` before reusing a slot. A slot is
//!   therefore provably vacated before the producer overwrites it.
//! * Each side may read **its own** index with `Relaxed` (a thread
//!   always observes its own stores), and caches the *other* side's
//!   index locally, refreshing it only when the cached value implies
//!   full/empty. In steady state a push or pop touches one shared
//!   cache line, not two.
//! * **`closed`** is a `Release`-stored flag checked with `Acquire`.
//!   The close race (producer pushes, then closes, while the consumer
//!   sees "empty") is handled by re-loading `tail` *after* observing
//!   `closed`: the producer's final `tail` store happens-before its
//!   `closed` store, so a consumer that sees `closed` and then still
//!   sees an empty ring is guaranteed no message is in flight.
//! * **`consumer_parked`** implements the idle-consumer wakeup as a
//!   Dekker-style store/fence/load pair: the consumer stores the flag,
//!   fences `SeqCst`, then re-checks `tail`/`closed` before parking;
//!   a publisher stores `tail` (or `closed`), fences `SeqCst`, then
//!   checks the flag. The fences totally order the two sequences, so
//!   either the consumer sees the publication and skips the park, or
//!   the publisher sees the flag and unparks — never a lost wakeup
//!   (and `unpark`'s token makes an early wake merely a fast retry).
//!   The thread handle lives behind a `Mutex` touched only on this
//!   cold path — the message fast path takes no lock.
//!
//! Indices are monotonically increasing `u64` sequence numbers
//! (`slot = seq & mask`, capacity a power of two), so full/empty are
//! `tail - head == capacity` / `tail == head` with no wraparound
//! ambiguity and no reserved empty slot.
//!
//! # Close protocol
//!
//! Either side closes the ring by dropping its handle (or the producer
//! explicitly via [`Producer::close`]). Closing never discards
//! in-flight messages: the consumer keeps draining a closed ring until
//! it is empty and only then observes [`TryPopError::Closed`] — so
//! "close while full" delivers every message, and "close while empty"
//! terminates the consumer immediately. A producer pushing into a ring
//! whose consumer is gone gets its value back
//! ([`TryPushError::Closed`]). Messages still buffered when *both*
//! handles are gone are dropped with the ring itself.
//!
//! # Waiting
//!
//! [`Backoff`] implements the spin-then-park escalation: a few
//! exponentially-growing `spin_loop` bursts (cheap, keeps the line in
//! cache while the peer is mid-operation), then `yield_now`, then
//! bounded `park_timeout` sleeps. The producer-side blocking
//! [`Producer::push`] uses it as-is — a full ring means the consumer
//! is actively draining, so those waits are short-lived and need no
//! handshake. The consumer-side [`Consumer::pop_timeout`] spins/yields
//! briefly and then parks *for the remaining deadline* under the
//! `consumer_parked` handshake above: an idle shard worker costs zero
//! periodic wakeups, yet the first push after an idle spell delivers
//! immediately. Callers that need retry accounting (the coordinator's
//! `transport_retries`) drive `try_push` + [`Backoff::snooze`]
//! themselves.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{fence, AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::Thread;
use std::time::{Duration, Instant};

/// Pad-and-align wrapper keeping each index on its own cache line —
/// 128 bytes to also defeat adjacent-line prefetching on common x86
/// parts (the same constant crossbeam uses).
#[repr(align(128))]
struct CachePadded<T>(T);

/// The shared ring state. Use [`ring`] to create a connected
/// [`Producer`]/[`Consumer`] pair; the ring itself is never handled
/// directly.
struct Ring<T> {
    /// Message slots; slot `seq & mask` holds message `seq`.
    slots: Box<[UnsafeCell<MaybeUninit<T>>]>,
    /// `slots.len() - 1` (capacity is a power of two).
    mask: u64,
    /// Next sequence number the producer will write (producer-owned).
    tail: CachePadded<AtomicU64>,
    /// Next sequence number the consumer will read (consumer-owned).
    head: CachePadded<AtomicU64>,
    /// Set once by whichever side closes/drops first.
    closed: AtomicBool,
    /// True while the consumer is (about to be) parked waiting for a
    /// message — the producer's cue to unpark it after publishing.
    /// See [`Consumer::pop_timeout`] for the Dekker-style protocol.
    consumer_parked: AtomicBool,
    /// The parked consumer's thread handle. Cold path only: locked by
    /// the consumer around parking and by a publisher only when
    /// `consumer_parked` reads true — never on the message hot path,
    /// so the transfer fast path stays lock-free.
    sleeper: Mutex<Option<Thread>>,
}

// SAFETY: the slot array is a SPSC mailbox. A slot is written only by
// the single producer before it publishes `tail` (Release) and read
// only by the single consumer after it observes that `tail` (Acquire),
// so no slot is ever accessed from two threads without an intervening
// happens-before edge. `T: Send` is required because values cross the
// thread boundary.
unsafe impl<T: Send> Sync for Ring<T> {}
unsafe impl<T: Send> Send for Ring<T> {}

impl<T> Ring<T> {
    /// Publisher side of the park handshake: after making progress
    /// visible (a tail store, or setting `closed`), wake the consumer
    /// if it is parked. The caller must issue a `SeqCst` fence between
    /// its store and this check — see [`Consumer::pop_timeout`].
    fn wake_consumer(&self) {
        if self.consumer_parked.load(Ordering::Relaxed) {
            if let Some(t) = self.sleeper.lock().expect("sleeper poisoned").take() {
                t.unpark();
            }
        }
    }
}

impl<T> Drop for Ring<T> {
    fn drop(&mut self) {
        // Both handles are gone (`Arc` refcount hit zero), so this
        // thread has exclusive access: drop whatever was never popped.
        let head = self.head.0.load(Ordering::Relaxed);
        let tail = self.tail.0.load(Ordering::Relaxed);
        for seq in head..tail {
            let slot = &self.slots[(seq & self.mask) as usize];
            unsafe { (*slot.get()).assume_init_drop() };
        }
    }
}

/// Rejected push: the message always comes back to the caller.
#[derive(Debug, PartialEq, Eq)]
pub enum TryPushError<T> {
    /// The ring is full; retry after the consumer drains.
    Full(T),
    /// The consumer is gone; the message can never be delivered.
    Closed(T),
}

impl<T> TryPushError<T> {
    /// Recover the rejected message.
    pub fn into_inner(self) -> T {
        match self {
            TryPushError::Full(v) | TryPushError::Closed(v) => v,
        }
    }
}

/// Failed non-blocking pop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TryPopError {
    /// Nothing buffered right now (producer still live).
    Empty,
    /// Ring closed *and* fully drained — no message will ever arrive.
    Closed,
}

/// Failed bounded-wait pop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PopTimeoutError {
    /// Nothing arrived within the timeout (producer still live).
    Timeout,
    /// Ring closed and fully drained.
    Closed,
}

/// The producing half: `Send`, not `Clone` (single producer).
pub struct Producer<T> {
    ring: Arc<Ring<T>>,
    /// Last observed consumer index; refreshed only on apparent full.
    head_cache: u64,
}

/// The consuming half: `Send`, not `Clone` (single consumer).
pub struct Consumer<T> {
    ring: Arc<Ring<T>>,
    /// Last observed producer index; refreshed only on apparent empty.
    tail_cache: u64,
}

/// Create a connected producer/consumer pair over a ring holding at
/// least `capacity` messages (rounded up to the next power of two so
/// slot indexing is a mask).
pub fn ring<T: Send>(capacity: usize) -> (Producer<T>, Consumer<T>) {
    assert!(capacity >= 1, "ring capacity must be positive");
    let slots = capacity.next_power_of_two();
    let ring = Arc::new(Ring {
        slots: (0..slots)
            .map(|_| UnsafeCell::new(MaybeUninit::uninit()))
            .collect(),
        mask: slots as u64 - 1,
        tail: CachePadded(AtomicU64::new(0)),
        head: CachePadded(AtomicU64::new(0)),
        closed: AtomicBool::new(false),
        consumer_parked: AtomicBool::new(false),
        sleeper: Mutex::new(None),
    });
    (
        Producer { ring: ring.clone(), head_cache: 0 },
        Consumer { ring, tail_cache: 0 },
    )
}

impl<T> Producer<T> {
    /// Usable capacity (the requested size rounded up to a power of
    /// two).
    pub fn capacity(&self) -> usize {
        self.ring.slots.len()
    }

    /// Whether the peer (or this side, explicitly) closed the ring.
    pub fn is_closed(&self) -> bool {
        self.ring.closed.load(Ordering::Acquire)
    }

    /// Non-blocking push. On [`TryPushError::Full`] the consumer is
    /// alive but behind; on [`TryPushError::Closed`] it is gone.
    pub fn try_push(&mut self, value: T) -> Result<(), TryPushError<T>> {
        if self.is_closed() {
            return Err(TryPushError::Closed(value));
        }
        let tail = self.ring.tail.0.load(Ordering::Relaxed);
        if tail - self.head_cache == self.ring.slots.len() as u64 {
            self.head_cache = self.ring.head.0.load(Ordering::Acquire);
            if tail - self.head_cache == self.ring.slots.len() as u64 {
                return Err(TryPushError::Full(value));
            }
        }
        let slot = &self.ring.slots[(tail & self.ring.mask) as usize];
        unsafe { (*slot.get()).write(value) };
        self.ring.tail.0.store(tail + 1, Ordering::Release);
        // Park handshake (Dekker): tail store, fence, parked load on
        // this side; parked store, fence, tail load on the consumer's.
        // The fences totally order the two sequences, so either we see
        // `consumer_parked` here, or the consumer's re-check sees our
        // tail store and never sleeps — a wakeup cannot be lost.
        fence(Ordering::SeqCst);
        self.ring.wake_consumer();
        Ok(())
    }

    /// Blocking push with [`Backoff`]; returns the message if the
    /// consumer is gone.
    pub fn push(&mut self, value: T) -> Result<(), T> {
        let mut value = value;
        let mut backoff = Backoff::new();
        loop {
            match self.try_push(value) {
                Ok(()) => return Ok(()),
                Err(TryPushError::Closed(v)) => return Err(v),
                Err(TryPushError::Full(v)) => {
                    value = v;
                    backoff.snooze();
                }
            }
        }
    }

    /// Explicitly close the ring: buffered messages stay deliverable,
    /// but the consumer will observe [`TryPopError::Closed`] once it
    /// drains them. Dropping the producer does the same. A consumer
    /// parked in [`Consumer::pop_timeout`] is woken immediately.
    pub fn close(&mut self) {
        self.ring.closed.store(true, Ordering::Release);
        // Same handshake as try_push: closed store, fence, parked load.
        fence(Ordering::SeqCst);
        self.ring.wake_consumer();
    }
}

impl<T> Drop for Producer<T> {
    fn drop(&mut self) {
        self.close();
    }
}

impl<T> Consumer<T> {
    /// Usable capacity (the requested size rounded up to a power of
    /// two).
    pub fn capacity(&self) -> usize {
        self.ring.slots.len()
    }

    /// Whether the peer (or this side, by dropping) closed the ring.
    /// A closed ring may still hold undelivered messages.
    pub fn is_closed(&self) -> bool {
        self.ring.closed.load(Ordering::Acquire)
    }

    /// Messages currently buffered (racy snapshot; exact only when the
    /// producer is quiescent).
    pub fn len(&self) -> usize {
        let tail = self.ring.tail.0.load(Ordering::Acquire);
        let head = self.ring.head.0.load(Ordering::Relaxed);
        (tail - head) as usize
    }

    /// Whether the buffer is empty right now (same caveat as
    /// [`Consumer::len`]).
    pub fn is_empty(&self) -> bool {
        let tail = self.ring.tail.0.load(Ordering::Acquire);
        let head = self.ring.head.0.load(Ordering::Relaxed);
        tail == head
    }

    /// Non-blocking pop. [`TryPopError::Closed`] is only reported once
    /// every in-flight message has been delivered.
    pub fn try_pop(&mut self) -> Result<T, TryPopError> {
        let head = self.ring.head.0.load(Ordering::Relaxed);
        if head == self.tail_cache {
            self.tail_cache = self.ring.tail.0.load(Ordering::Acquire);
            if head == self.tail_cache {
                if !self.ring.closed.load(Ordering::Acquire) {
                    return Err(TryPopError::Empty);
                }
                // Closed — but the final push may have landed between
                // the tail load and the closed load. The producer's
                // tail store happens-before its closed store, so one
                // re-load after observing `closed` is decisive.
                self.tail_cache = self.ring.tail.0.load(Ordering::Acquire);
                if head == self.tail_cache {
                    return Err(TryPopError::Closed);
                }
            }
        }
        let slot = &self.ring.slots[(head & self.ring.mask) as usize];
        let value = unsafe { (*slot.get()).assume_init_read() };
        self.ring.head.0.store(head + 1, Ordering::Release);
        Ok(value)
    }

    /// Pop, waiting up to `timeout` for a message to arrive: a brief
    /// [`Backoff`] spin/yield phase for the contended case, then a real
    /// park for the remaining deadline. A parked consumer is woken by
    /// the producer's next push (or close) via the `consumer_parked`
    /// handshake, so an *idle* ring costs no periodic wakeups while a
    /// *resuming* producer still gets immediate service.
    pub fn pop_timeout(&mut self, timeout: Duration) -> Result<T, PopTimeoutError> {
        let deadline = Instant::now() + timeout;
        let mut backoff = Backoff::new();
        loop {
            match self.try_pop() {
                Ok(v) => return Ok(v),
                Err(TryPopError::Closed) => return Err(PopTimeoutError::Closed),
                Err(TryPopError::Empty) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return Err(PopTimeoutError::Timeout);
                    }
                    if !backoff.is_parking() {
                        backoff.snooze();
                        continue;
                    }
                    // Contention outlasted the spin/yield phases: park
                    // until the producer wakes us or the deadline hits.
                    // Dekker protocol against a concurrent push (see
                    // `Producer::try_push`): register + set the parked
                    // flag, fence, then re-check — either we observe
                    // the push/close and skip the park, or the
                    // publisher observes the flag and unparks us (an
                    // early unpark just sets the park token).
                    *self.ring.sleeper.lock().expect("sleeper poisoned") =
                        Some(std::thread::current());
                    self.ring.consumer_parked.store(true, Ordering::Relaxed);
                    fence(Ordering::SeqCst);
                    let head = self.ring.head.0.load(Ordering::Relaxed);
                    let quiet = self.ring.tail.0.load(Ordering::Acquire) == head
                        && !self.ring.closed.load(Ordering::Acquire);
                    if quiet {
                        std::thread::park_timeout(deadline.saturating_duration_since(now));
                    }
                    self.ring.consumer_parked.store(false, Ordering::Relaxed);
                    self.ring.sleeper.lock().expect("sleeper poisoned").take();
                }
            }
        }
    }
}

impl<T> Drop for Consumer<T> {
    fn drop(&mut self) {
        // Signal the producer; leftover messages are freed by
        // `Ring::drop` once the producer handle is gone too.
        self.ring.closed.store(true, Ordering::Release);
    }
}

/// How many exponential spin rounds before yielding (2^6 = 64 spins at
/// the crossover).
const SPIN_ROUNDS: u32 = 6;
/// How many yield rounds before parking.
const YIELD_ROUNDS: u32 = 4;
/// First bounded park once spinning and yielding failed; the park
/// doubles per round up to [`PARK_MAX`]. [`Backoff`] itself has no
/// unpark handshake (its users re-check ring state every wake), so
/// `PARK_MAX` is also its worst-case extra wake-up latency — the
/// handshake-based long wait lives in [`Consumer::pop_timeout`].
const PARK_BASE: Duration = Duration::from_micros(50);
/// Ceiling on the escalating park (keeps waiters cheap without making
/// wake-up latency unbounded).
const PARK_MAX: Duration = Duration::from_millis(1);

/// Spin-then-park waiter: exponential `spin_loop` bursts, then
/// `yield_now`, then exponentially-growing bounded `park_timeout`
/// sleeps. Reset it after a successful operation; snooze it after a
/// failed one.
#[derive(Debug, Default)]
pub struct Backoff {
    step: u32,
}

impl Backoff {
    /// A fresh (fully spinning) backoff.
    pub fn new() -> Self {
        Self { step: 0 }
    }

    /// Back to the spinning phase (call after progress is made).
    pub fn reset(&mut self) {
        self.step = 0;
    }

    /// Whether the next [`Backoff::snooze`] will park (true once the
    /// contention outlasted the spin/yield phases).
    pub fn is_parking(&self) -> bool {
        self.step >= SPIN_ROUNDS + YIELD_ROUNDS
    }

    /// Wait a little, escalating spin → yield → park across calls.
    pub fn snooze(&mut self) {
        if self.step < SPIN_ROUNDS {
            for _ in 0..(1u32 << self.step) {
                std::hint::spin_loop();
            }
        } else if self.step < SPIN_ROUNDS + YIELD_ROUNDS {
            std::thread::yield_now();
        } else {
            let doublings = (self.step - SPIN_ROUNDS - YIELD_ROUNDS).min(8);
            let park = PARK_BASE.saturating_mul(1u32 << doublings).min(PARK_MAX);
            std::thread::park_timeout(park);
        }
        self.step = self.step.saturating_add(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_capacity_rounding() {
        let (mut tx, mut rx) = ring::<u64>(3); // rounds up to 4
        assert_eq!(tx.capacity(), 4);
        assert_eq!(rx.capacity(), 4);
        for v in 0..4u64 {
            tx.try_push(v).unwrap();
        }
        assert!(matches!(tx.try_push(99), Err(TryPushError::Full(99))));
        for want in 0..4u64 {
            assert_eq!(rx.try_pop().unwrap(), want);
        }
        assert_eq!(rx.try_pop(), Err(TryPopError::Empty));
    }

    #[test]
    fn close_while_full_delivers_everything() {
        let (mut tx, mut rx) = ring::<u64>(4);
        for v in 0..4u64 {
            tx.try_push(v).unwrap();
        }
        tx.close();
        assert!(matches!(tx.try_push(5), Err(TryPushError::Closed(5))));
        // The consumer drains all buffered messages before Closed.
        for want in 0..4u64 {
            assert_eq!(rx.try_pop().unwrap(), want);
        }
        assert_eq!(rx.try_pop(), Err(TryPopError::Closed));
        assert_eq!(
            rx.pop_timeout(Duration::from_millis(1)),
            Err(PopTimeoutError::Closed)
        );
    }

    #[test]
    fn close_while_empty_terminates_immediately() {
        let (tx, mut rx) = ring::<u64>(4);
        assert_eq!(rx.try_pop(), Err(TryPopError::Empty));
        drop(tx); // producer drop == close
        assert_eq!(rx.try_pop(), Err(TryPopError::Closed));
    }

    #[test]
    fn consumer_drop_rejects_pushes_and_frees_buffered() {
        let (mut tx, rx) = ring::<Vec<u64>>(4);
        tx.try_push(vec![1, 2, 3]).unwrap();
        drop(rx);
        match tx.try_push(vec![4]) {
            Err(TryPushError::Closed(v)) => assert_eq!(v, vec![4]),
            other => panic!("expected Closed, got {other:?}"),
        }
        // The buffered vec is freed by Ring::drop (checked by miri /
        // leak sanitizers; here we just exercise the path).
        drop(tx);
    }

    #[test]
    fn pop_timeout_times_out_then_delivers() {
        let (mut tx, mut rx) = ring::<u64>(2);
        assert_eq!(
            rx.pop_timeout(Duration::from_millis(5)),
            Err(PopTimeoutError::Timeout)
        );
        tx.try_push(7).unwrap();
        assert_eq!(rx.pop_timeout(Duration::from_millis(5)).unwrap(), 7);
    }

    #[test]
    fn parked_consumer_wakes_promptly_on_push() {
        let (mut tx, mut rx) = ring::<u64>(4);
        std::thread::scope(|s| {
            s.spawn(move || {
                // Let the consumer reach the parked phase first.
                std::thread::sleep(Duration::from_millis(50));
                tx.try_push(7).unwrap();
            });
            let t0 = Instant::now();
            let v = rx.pop_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(v, 7);
            // Woken by the handshake, not the deadline.
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "parked consumer missed the push wakeup"
            );
        });
    }

    #[test]
    fn blocking_push_completes_across_threads() {
        let (mut tx, mut rx) = ring::<u64>(1);
        std::thread::scope(|s| {
            s.spawn(move || {
                for v in 0..10_000u64 {
                    tx.push(v).unwrap();
                }
            });
            s.spawn(move || {
                let mut backoff = Backoff::new();
                for want in 0..10_000u64 {
                    loop {
                        match rx.try_pop() {
                            Ok(v) => {
                                assert_eq!(v, want);
                                backoff.reset();
                                break;
                            }
                            Err(TryPopError::Empty) => backoff.snooze(),
                            Err(TryPopError::Closed) => panic!("closed early"),
                        }
                    }
                }
            });
        });
    }

    #[test]
    fn cross_thread_transfer_preserves_payloads() {
        // Heap payloads across the boundary: ordering bugs would show
        // up as torn/duplicated boxes under this churn.
        let (mut tx, mut rx) = ring::<Box<u64>>(8);
        std::thread::scope(|s| {
            s.spawn(move || {
                for v in 0..100_000u64 {
                    tx.push(Box::new(v)).unwrap();
                }
            });
            s.spawn(move || {
                let mut expected = 0u64;
                loop {
                    match rx.try_pop() {
                        Ok(b) => {
                            assert_eq!(*b, expected);
                            expected += 1;
                        }
                        Err(TryPopError::Empty) => std::thread::yield_now(),
                        Err(TryPopError::Closed) => break,
                    }
                }
                assert_eq!(expected, 100_000);
            });
        });
    }

    #[test]
    fn backoff_escalates_to_parking() {
        let mut b = Backoff::new();
        assert!(!b.is_parking());
        for _ in 0..(SPIN_ROUNDS + YIELD_ROUNDS) {
            b.snooze();
        }
        assert!(b.is_parking());
        b.reset();
        assert!(!b.is_parking());
    }
}
