//! Fork/join over worker ranks — the stand-in for an OpenMP parallel
//! region. Built on `std::thread::scope` so workers may borrow the
//! shared, immutable [`ItemSource`](crate::gen::ItemSource).

/// Run `f(rank)` on `workers` scoped threads and collect results in rank
/// order. Panics in workers propagate.
pub fn fork_join<T, F>(workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(workers >= 1);
    if workers == 1 {
        // Avoid spawn overhead for the sequential baseline.
        return vec![f(0)];
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|r| scope.spawn({ let f = &f; move || f(r) }))
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_in_rank_order() {
        let out = fork_join(8, |r| r * 10);
        assert_eq!(out, vec![0, 10, 20, 30, 40, 50, 60, 70]);
    }

    #[test]
    fn all_workers_run() {
        let counter = AtomicUsize::new(0);
        fork_join(16, |_| counter.fetch_add(1, Ordering::SeqCst));
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn single_worker_runs_inline() {
        let id = std::thread::current().id();
        let out = fork_join(1, move |_| std::thread::current().id() == id);
        assert!(out[0], "workers=1 must not spawn");
    }

    #[test]
    #[should_panic(expected = "worker panicked")]
    fn worker_panic_propagates() {
        fork_join(2, |r| {
            if r == 1 {
                panic!("boom");
            }
            r
        });
    }
}
