//! Block domain decomposition — paper Algorithm 1 lines 3–4:
//! `left = ⌊r·n/p⌋`, `right = ⌊(r+1)·n/p⌋ − 1`, so every worker holds
//! either `⌊n/p⌋` or `⌈n/p⌉` elements — plus the chunk-size heuristic
//! for the batched ingest path ([`batch_chunk_len`]).

/// Bytes per scratch-map entry: `FastMap` stores a `u64` key plus a
/// `u32` value per slot.
const SCRATCH_ENTRY_BYTES: usize = 12;

/// L2 size assumed when the caller has no better number (1 MiB — the
/// low end of current server cores; Skylake-SP onward ship 1–2 MiB).
const DEFAULT_L2_BYTES: usize = 1 << 20;

/// Chunk length tuned for the batched-ingest scratch map
/// ([`ChunkAggregator`]): the largest power-of-two chunk whose
/// worst-case (all-distinct) scratch footprint stays within *half* an
/// L2 of `l2_bytes` — the other half is left for the summary's own
/// counters and the streamed chunk itself.
///
/// The scratch map keeps a ≤50% load factor, so a chunk of `c` items
/// allocates `2·c` slots of 12 bytes; solving `24·c ≤ l2/2` and
/// rounding down to a power of two gives 16384 for the 1 MiB default.
/// Larger chunks would still be *correct* (the scratch grows on
/// demand) but start missing L2 on high-entropy streams, which is
/// exactly where the pre-aggregation pass must stay cheap.
///
/// [`ChunkAggregator`]: crate::summary::ChunkAggregator
pub fn batch_chunk_len(l2_bytes: usize) -> usize {
    let budget = (l2_bytes / 2).max(128 * SCRATCH_ENTRY_BYTES);
    // Largest len with 2·len slots fitting the budget.
    let max_len = budget / (2 * SCRATCH_ENTRY_BYTES);
    let floor_pow2 = (max_len + 1).next_power_of_two() / 2;
    floor_pow2.max(64)
}

/// [`batch_chunk_len`] at the default L2 assumption: the chunk length
/// `CoordinatorConfig`/`RunConfig` default to when batched ingest is on.
pub fn batch_chunk_len_default() -> usize {
    batch_chunk_len(DEFAULT_L2_BYTES)
}

/// Half-open range `[left, right)` of worker `r` among `p` over `n` items.
///
/// (The paper states the inclusive `right − 1`; half-open is the rust
/// idiom and covers the same elements.)
#[inline]
pub fn block_range(n: u64, p: u64, r: u64) -> (u64, u64) {
    debug_assert!(p >= 1 && r < p);
    // u128 so r*n cannot overflow for paper-scale n on many workers.
    let left = ((r as u128 * n as u128) / p as u128) as u64;
    let right = (((r + 1) as u128 * n as u128) / p as u128) as u64;
    (left, right)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_exactly_without_overlap() {
        for &(n, p) in &[(10u64, 3u64), (29, 16), (1_000_000, 7), (5, 8), (0, 4)] {
            let mut next = 0u64;
            for r in 0..p {
                let (l, rgt) = block_range(n, p, r);
                assert_eq!(l, next, "gap/overlap at rank {r} (n={n}, p={p})");
                assert!(rgt >= l);
                next = rgt;
            }
            assert_eq!(next, n);
        }
    }

    #[test]
    fn sizes_differ_by_at_most_one() {
        for &(n, p) in &[(29u64, 16u64), (1_000, 7), (12345, 13)] {
            let sizes: Vec<u64> = (0..p)
                .map(|r| {
                    let (l, rt) = block_range(n, p, r);
                    rt - l
                })
                .collect();
            let min = *sizes.iter().min().unwrap();
            let max = *sizes.iter().max().unwrap();
            assert!(max - min <= 1);
            assert_eq!(min, n / p);
        }
    }

    #[test]
    fn batch_chunk_len_fits_budget_and_is_pow2() {
        for &l2 in &[1usize << 18, 1 << 19, 1 << 20, 1 << 21, 2_500_000] {
            let len = batch_chunk_len(l2);
            assert!(len.is_power_of_two(), "l2={l2}: len {len} not a power of two");
            // Worst-case scratch footprint (2·len slots, 12 B each) fits
            // the half-L2 budget.
            assert!(
                len * 2 * SCRATCH_ENTRY_BYTES <= (l2 / 2).max(128 * SCRATCH_ENTRY_BYTES),
                "l2={l2}: len {len} blows the scratch budget"
            );
            // Doubling would not fit (the heuristic is maximal).
            assert!(
                len * 4 * SCRATCH_ENTRY_BYTES > l2 / 2 || len == 64,
                "l2={l2}: len {len} is not maximal"
            );
        }
        // Degenerate tiny "L2" still yields a usable floor.
        assert!(batch_chunk_len(0) >= 64);
        assert_eq!(batch_chunk_len_default(), batch_chunk_len(1 << 20));
    }

    #[test]
    fn no_overflow_at_paper_scale() {
        // 29 billion items on 512 ranks.
        let n = 29_000_000_000u64;
        let (l, r) = block_range(n, 512, 511);
        assert_eq!(r, n);
        assert!(r - l <= n / 512 + 1);
    }
}
