//! Block domain decomposition — paper Algorithm 1 lines 3–4:
//! `left = ⌊r·n/p⌋`, `right = ⌊(r+1)·n/p⌋ − 1`, so every worker holds
//! either `⌊n/p⌋` or `⌈n/p⌉` elements.

/// Half-open range `[left, right)` of worker `r` among `p` over `n` items.
///
/// (The paper states the inclusive `right − 1`; half-open is the rust
/// idiom and covers the same elements.)
#[inline]
pub fn block_range(n: u64, p: u64, r: u64) -> (u64, u64) {
    debug_assert!(p >= 1 && r < p);
    // u128 so r*n cannot overflow for paper-scale n on many workers.
    let left = ((r as u128 * n as u128) / p as u128) as u64;
    let right = (((r + 1) as u128 * n as u128) / p as u128) as u64;
    (left, right)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_exactly_without_overlap() {
        for &(n, p) in &[(10u64, 3u64), (29, 16), (1_000_000, 7), (5, 8), (0, 4)] {
            let mut next = 0u64;
            for r in 0..p {
                let (l, rgt) = block_range(n, p, r);
                assert_eq!(l, next, "gap/overlap at rank {r} (n={n}, p={p})");
                assert!(rgt >= l);
                next = rgt;
            }
            assert_eq!(next, n);
        }
    }

    #[test]
    fn sizes_differ_by_at_most_one() {
        for &(n, p) in &[(29u64, 16u64), (1_000, 7), (12345, 13)] {
            let sizes: Vec<u64> = (0..p)
                .map(|r| {
                    let (l, rt) = block_range(n, p, r);
                    rt - l
                })
                .collect();
            let min = *sizes.iter().min().unwrap();
            let max = *sizes.iter().max().unwrap();
            assert!(max - min <= 1);
            assert_eq!(min, n / p);
        }
    }

    #[test]
    fn no_overflow_at_paper_scale() {
        // 29 billion items on 512 ranks.
        let n = 29_000_000_000u64;
        let (l, r) = block_range(n, 512, 511);
        assert_eq!(r, n);
        assert!(r - l <= n / 512 + 1);
    }
}
