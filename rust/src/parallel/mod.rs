//! The shared-memory ("OpenMP") Parallel Space Saving algorithm —
//! paper **Algorithm 1** with the user-defined reduction of §3.
//!
//! * [`partition`] — the block domain decomposition (lines 3–4) and the
//!   batched-ingest chunk-size heuristic.
//! * [`thread_pool`] — scoped-thread fork/join, the stand-in for an
//!   OpenMP parallel region.
//! * [`reduction`] — pairwise tree reduction with the `combine` operator,
//!   the stand-in for OpenMP v4's user-defined reduction.
//! * [`shared`] — the end-to-end driver: decompose → local Space Saving
//!   scans → tree reduce → prune, with per-phase timing.
//! * [`spsc`] — the bounded lock-free SPSC ring the streaming
//!   coordinator uses for producer→shard chunk handoff and the
//!   reverse chunk-buffer free list.

pub mod partition;
pub mod reduction;
pub mod shared;
pub mod spsc;
pub mod thread_pool;

pub use partition::{batch_chunk_len, batch_chunk_len_default, block_range};
pub use reduction::{tree_reduce, tree_reduce_refs};
pub use shared::{run_shared, SharedRunResult, SummaryKind};
