//! The reproduction harness: one driver per paper table/figure.
//!
//! `pss repro --exp <id>` renders the same rows/series the paper reports
//! (runtime+speedup grids, ARE curves, fractional-overhead curves, the
//! Phi comparisons) from the calibrated simulator; `--out <dir>` also
//! writes CSVs for replotting. The experiment registry lives in
//! [`crate::config::EXPERIMENTS`]; the index mapping each id to paper
//! artifact and modules is DESIGN.md §5.

pub mod experiments;

pub use experiments::{run_experiment, ExperimentOutput};
