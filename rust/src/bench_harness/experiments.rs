//! One driver per paper table/figure (DESIGN.md §5).
//!
//! Each driver re-runs the paper's parameter sweep on the calibrated
//! cluster simulator (accuracy real, time virtual — see
//! [`crate::distsim`]) and renders the same rows/series the paper
//! reports. `scale` divides the stream actually processed
//! (`n_real = n_paper / scale`); the virtual clock always charges paper
//! scale.

use crate::baselines::Exact;
use crate::distsim::{simulate, ClusterSpec, MachineModel, NetworkModel, SimOutcome, SimWorkload};
use crate::gen::ItemSource;
use crate::hybrid;
use crate::metrics::{AccuracyReport, Series, Table};
use crate::mic;
use crate::Result;

/// Output of one experiment driver.
#[derive(Debug, Clone)]
pub struct ExperimentOutput {
    /// Artifact id (e.g. `tab3`, `fig1a`).
    pub name: String,
    /// Human-readable rendering (paper-style table / series block).
    pub rendered: String,
    /// CSV export for replotting.
    pub csv: String,
}

/// Billions, in items.
const B: u64 = 1_000_000_000;

/// Paper parameter grids (Table I).
const OMP_CORES: &[u32] = &[1, 2, 4, 8, 16];
const MPI_CORES: &[u32] = &[1, 32, 64, 128, 256, 512];
const K_SWEEP: &[usize] = &[500, 1000, 2000, 4000, 8000];
const N_SWEEP_B: &[u64] = &[4, 8, 16, 29];
const RHO_SWEEP: &[f64] = &[1.1, 1.8];
const PHI_THREADS: &[u32] = &[15, 30, 60, 120, 240];
const SOCKETS: &[u32] = &[1, 4, 8, 16, 32, 64];

fn xeon() -> MachineModel {
    MachineModel::xeon_e5_2630_v3()
}

fn qdr() -> NetworkModel {
    NetworkModel::qdr_infiniband()
}

fn openmp_run(w: &SimWorkload, threads: u32) -> Result<SimOutcome> {
    simulate(w, &ClusterSpec::openmp(xeon(), threads), &qdr())
}

/// ARE of a simulated outcome against the exact oracle of its (scaled)
/// stream, over the reported frequent items — the paper's Figure 1
/// metric — expressed in 1e-8 units like the paper's axes.
fn are_1e8(w: &SimWorkload, out: &SimOutcome) -> f64 {
    let src = w.source();
    let mut exact = Exact::new();
    let mut buf = vec![0u64; 1 << 16];
    let mut pos = 0u64;
    while pos < w.n_real {
        let take = ((w.n_real - pos) as usize).min(buf.len());
        src.fill(pos, &mut buf[..take]);
        for &it in &buf[..take] {
            use crate::summary::FrequencySummary;
            exact.offer(it);
        }
        pos += take as u64;
    }
    let acc = AccuracyReport::evaluate(&out.frequent, &exact, w.k_majority);
    acc.are * 1e8
}

/// Run one experiment id. `scale` is the stream-size divisor for the
/// real computation, `seed` fixes the synthetic streams.
pub fn run_experiment(id: &str, scale: u64, seed: u64) -> Result<Vec<ExperimentOutput>> {
    match id {
        "fig1a" => fig1(scale, seed, Vary::K).map(|o| vec![o]),
        "fig1b" => fig1(scale, seed, Vary::N).map(|o| vec![o]),
        "fig1c" => fig1(scale, seed, Vary::Rho).map(|o| vec![o]),
        "fig2a" => fig2(scale, seed, Vary::K).map(|o| vec![o]),
        "fig2b" => fig2(scale, seed, Vary::N).map(|o| vec![o]),
        "fig2c" => fig2(scale, seed, Vary::Rho).map(|o| vec![o]),
        "tab2" => tab2(scale, seed).map(|o| vec![o]),
        "fig3a" => fig3(scale, seed, Vary::K).map(|o| vec![o]),
        "fig3b" => fig3(scale, seed, Vary::N).map(|o| vec![o]),
        "tab3" => tab34(scale, seed, Mode::Mpi).map(|o| vec![o]),
        "tab4" => tab34(scale, seed, Mode::Hybrid).map(|o| vec![o]),
        "fig4" => fig4(scale, seed),
        "fig5" => fig5(scale, seed).map(|o| vec![o]),
        "fig6" => fig6(scale, seed),
        "all" => {
            let mut out = Vec::new();
            for e in crate::config::EXPERIMENTS {
                if e.id != "all" {
                    out.extend(run_experiment(e.id, scale, seed)?);
                }
            }
            Ok(out)
        }
        other => anyhow::bail!(
            "unknown experiment '{other}' (see `pss repro --list`)"
        ),
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Vary {
    K,
    N,
    Rho,
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Mpi,
    Hybrid,
}

/// The sweep points of one panel: (label, workload).
fn panel_workloads(vary: Vary, scale: u64, seed: u64) -> Vec<(String, SimWorkload)> {
    match vary {
        Vary::K => K_SWEEP
            .iter()
            .map(|&k| (format!("k={k}"), SimWorkload::paper(8 * B, k, 1.1, scale, seed)))
            .collect(),
        Vary::N => N_SWEEP_B
            .iter()
            .map(|&nb| {
                (format!("n={nb}B"), SimWorkload::paper(nb * B, 2000, 1.1, scale, seed))
            })
            .collect(),
        Vary::Rho => RHO_SWEEP
            .iter()
            .map(|&r| {
                (format!("rho={r}"), SimWorkload::paper(8 * B, 2000, r, scale, seed))
            })
            .collect(),
    }
}

// ----------------------------------------------------------------- Figure 1

fn fig1(scale: u64, seed: u64, vary: Vary) -> Result<ExperimentOutput> {
    let (suffix, title) = match vary {
        Vary::K => ("a", "Figure 1a: ARE (1e-8) vs cores, varying k [OpenMP]"),
        Vary::N => ("b", "Figure 1b: ARE (1e-8) vs cores, varying n [OpenMP]"),
        Vary::Rho => ("c", "Figure 1c: ARE (1e-8) vs cores, varying rho [OpenMP]"),
    };
    let panels = panel_workloads(vary, scale, seed);
    let names: Vec<&str> = panels.iter().map(|(l, _)| l.as_str()).collect();
    let mut s = Series::new(title, "cores", &names);
    for &cores in OMP_CORES {
        let mut row = Vec::new();
        for (_, w) in &panels {
            let out = openmp_run(w, cores)?;
            row.push(Some(are_1e8(w, &out)));
        }
        s.point(cores as f64, row);
    }
    Ok(ExperimentOutput {
        name: format!("fig1{suffix}"),
        rendered: s.render(),
        csv: s.to_csv(),
    })
}

// ----------------------------------------------------------------- Figure 2

fn fig2(scale: u64, seed: u64, vary: Vary) -> Result<ExperimentOutput> {
    let (suffix, title) = match vary {
        Vary::K => ("a", "Figure 2a: runtime (s) vs cores, varying k [OpenMP]"),
        Vary::N => ("b", "Figure 2b: runtime (s) vs cores, varying n [OpenMP]"),
        Vary::Rho => ("c", "Figure 2c: runtime (s) vs cores, varying rho [OpenMP]"),
    };
    let panels = panel_workloads(vary, scale, seed);
    let names: Vec<&str> = panels.iter().map(|(l, _)| l.as_str()).collect();
    let mut s = Series::new(title, "cores", &names);
    for &cores in OMP_CORES {
        let mut row = Vec::new();
        for (_, w) in &panels {
            row.push(Some(openmp_run(w, cores)?.total_seconds()));
        }
        s.point(cores as f64, row);
    }
    Ok(ExperimentOutput {
        name: format!("fig2{suffix}"),
        rendered: s.render(),
        csv: s.to_csv(),
    })
}

// ------------------------------------------------------------------ Table II

/// The paper's grid tables (II/III/IV) share one layout: rows = cores,
/// columns = varying-n, varying-k, varying-rho; each cell is
/// runtime (s) over speedup.
fn grid_table(
    title: &str,
    cores_list: &[u32],
    run: impl Fn(&SimWorkload, u32) -> Result<SimOutcome>,
    n_for_k_rho: u64,
    scale: u64,
    seed: u64,
) -> Result<(Table, String)> {
    let mut cols: Vec<(String, SimWorkload)> = Vec::new();
    for &nb in N_SWEEP_B {
        cols.push((format!("n={nb}B"), SimWorkload::paper(nb * B, 2000, 1.1, scale, seed)));
    }
    for &k in K_SWEEP {
        cols.push((format!("k={k}"), SimWorkload::paper(n_for_k_rho, k, 1.1, scale, seed)));
    }
    for &r in RHO_SWEEP {
        cols.push((format!("rho={r}"), SimWorkload::paper(n_for_k_rho, 2000, r, scale, seed)));
    }

    let headers: Vec<&str> = std::iter::once("cores")
        .chain(cols.iter().map(|(l, _)| l.as_str()))
        .collect();
    let mut table = Table::new(title, &headers);
    let mut csv = format!("{}\n", headers.join(","));
    let mut base: Vec<f64> = Vec::new();
    for &cores in cores_list {
        let mut cells = vec![cores.to_string()];
        let mut csv_row = vec![cores.to_string()];
        for (ci, (_, w)) in cols.iter().enumerate() {
            let t = run(w, cores)?.total_seconds();
            if base.len() <= ci {
                base.push(t);
            }
            let speedup = base[ci] / t;
            cells.push(format!("{t:.2} ({speedup:.2}x)"));
            csv_row.push(format!("{t:.4}/{speedup:.3}"));
        }
        table.row(cells);
        csv.push_str(&csv_row.join(","));
        csv.push('\n');
    }
    Ok((table, csv))
}

fn tab2(scale: u64, seed: u64) -> Result<ExperimentOutput> {
    let (table, csv) = grid_table(
        "Table II: OpenMP — runtime (speedup)",
        OMP_CORES,
        |w, cores| openmp_run(w, cores),
        8 * B, // Table II's k/rho sweeps were measured at n=8B
        scale,
        seed,
    )?;
    Ok(ExperimentOutput { name: "tab2".into(), rendered: table.render(), csv })
}

// ----------------------------------------------------------------- Figure 3

fn fig3(scale: u64, seed: u64, vary: Vary) -> Result<ExperimentOutput> {
    let (suffix, title) = match vary {
        Vary::K => ("a", "Figure 3a: fractional overhead vs threads, varying k [OpenMP]"),
        _ => ("b", "Figure 3b: fractional overhead vs threads, varying n [OpenMP]"),
    };
    let panels = panel_workloads(if vary == Vary::K { Vary::K } else { Vary::N }, scale, seed);
    let names: Vec<&str> = panels.iter().map(|(l, _)| l.as_str()).collect();
    let mut s = Series::new(title, "threads", &names);
    for &cores in OMP_CORES {
        let mut row = Vec::new();
        for (_, w) in &panels {
            let out = openmp_run(w, cores)?;
            // Overhead relative to the ideal per-thread compute: spawn +
            // reduce + the contention-inflation of the scan.
            let ideal_scan = openmp_run(w, 1)?.times.scan / cores as f64;
            let t = out.times;
            let overhead = t.spawn + t.reduce + t.prune + (t.scan - ideal_scan).max(0.0);
            row.push(Some(overhead / ideal_scan));
        }
        s.point(cores as f64, row);
    }
    Ok(ExperimentOutput {
        name: format!("fig3{suffix}"),
        rendered: s.render(),
        csv: s.to_csv(),
    })
}

// ------------------------------------------------------------ Tables III/IV

fn tab34(scale: u64, seed: u64, mode: Mode) -> Result<ExperimentOutput> {
    let (name, title): (&str, &str) = match mode {
        Mode::Mpi => ("tab3", "Table III: pure MPI — runtime (speedup)"),
        Mode::Hybrid => ("tab4", "Table IV: hybrid MPI/OpenMP — runtime (speedup)"),
    };
    let (table, csv) = grid_table(
        title,
        MPI_CORES,
        |w, cores| match mode {
            Mode::Mpi => hybrid::run_mpi(w, cores),
            Mode::Hybrid => hybrid::run_hybrid(w, cores),
        },
        29 * B, // Tables III/IV swept k and rho at n=29B
        scale,
        seed,
    )?;
    Ok(ExperimentOutput { name: name.into(), rendered: table.render(), csv })
}

// ----------------------------------------------------------------- Figure 4

fn fig4(scale: u64, seed: u64) -> Result<Vec<ExperimentOutput>> {
    let mut outs = Vec::new();
    for &nb in &[8u64, 29] {
        let w = SimWorkload::paper(nb * B, 2000, 1.1, scale, seed);
        let pts = hybrid::compare(&w, MPI_CORES)?;
        let t1_mpi = pts[0].mpi.total_seconds();
        let t1_hyb = pts[0].hybrid.as_ref().map_or(t1_mpi, |h| h.total_seconds());

        let mut sp = Series::new(
            format!("Figure 4 (n={nb}B): speedup — MPI vs MPI/OpenMP"),
            "cores",
            &["mpi", "hybrid", "ideal"],
        );
        let mut ov = Series::new(
            format!("Figure 4 (n={nb}B): fractional overhead"),
            "cores",
            &["mpi", "hybrid"],
        );
        for p in &pts {
            let (s_mpi, s_hyb) = p.speedups(t1_mpi, t1_hyb);
            sp.point(p.cores as f64, vec![Some(s_mpi), s_hyb, Some(p.cores as f64)]);
            let (o_mpi, o_hyb) = p.overheads();
            ov.point(p.cores as f64, vec![Some(o_mpi), o_hyb]);
        }
        outs.push(ExperimentOutput {
            name: format!("fig4_speedup_{nb}B"),
            rendered: sp.render(),
            csv: sp.to_csv(),
        });
        outs.push(ExperimentOutput {
            name: format!("fig4_overhead_{nb}B"),
            rendered: ov.render(),
            csv: ov.to_csv(),
        });
    }
    Ok(outs)
}

// ----------------------------------------------------------------- Figure 5

fn fig5(scale: u64, seed: u64) -> Result<ExperimentOutput> {
    let w = SimWorkload::paper(3 * B, 2000, 1.1, scale, seed);
    let sweep = mic::phi_thread_sweep(&w, PHI_THREADS)?;
    let mut s = Series::new(
        "Figure 5: one Intel Phi — runtime (s) vs OpenMP threads",
        "threads",
        &["runtime_s", "speedup_vs_15"],
    );
    let t15 = sweep[0].1.total_seconds();
    for (t, out) in &sweep {
        s.point(*t as f64, vec![Some(out.total_seconds()), Some(t15 / out.total_seconds())]);
    }
    Ok(ExperimentOutput { name: "fig5".into(), rendered: s.render(), csv: s.to_csv() })
}

// ----------------------------------------------------------------- Figure 6

fn fig6(scale: u64, seed: u64) -> Result<Vec<ExperimentOutput>> {
    let mut outs = Vec::new();
    let panel = |label: String, w: SimWorkload| -> Result<ExperimentOutput> {
        let pts = mic::xeon_vs_mic(&w, SOCKETS)?;
        let mut s = Series::new(
            format!("Figure 6 ({label}): Xeon vs Phi — runtime (s) vs sockets"),
            "sockets",
            &["xeon", "phi", "phi/xeon"],
        );
        for p in &pts {
            let (tx, tm) = (p.xeon.total_seconds(), p.mic.total_seconds());
            s.point(p.sockets as f64, vec![Some(tx), Some(tm), Some(tm / tx)]);
        }
        Ok(ExperimentOutput {
            name: format!("fig6_{}", label.replace('=', "").replace('.', "_")),
            rendered: s.render(),
            csv: s.to_csv(),
        })
    };
    for &k in K_SWEEP {
        outs.push(panel(format!("k={k}"), SimWorkload::paper(3 * B, k, 1.1, scale, seed))?);
    }
    for &r in RHO_SWEEP {
        outs.push(panel(format!("rho={r}"), SimWorkload::paper(3 * B, 2000, r, scale, seed))?);
    }
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Small scales keep these fast; shape assertions live in the
    // integration suite (rust/tests/integration_repro.rs).

    #[test]
    fn unknown_experiment_errors() {
        assert!(run_experiment("fig99", 1_000_000, 1).is_err());
    }

    #[test]
    fn tab2_grid_has_all_rows() {
        let out = run_experiment("tab2", 100_000_000, 1).unwrap();
        assert_eq!(out[0].name, "tab2");
        // 5 core counts + header rows in the CSV.
        assert_eq!(out[0].csv.lines().count(), 1 + OMP_CORES.len());
        // 11 data columns: 4 n + 5 k + 2 rho.
        assert_eq!(out[0].csv.lines().next().unwrap().split(',').count(), 12);
    }

    #[test]
    fn fig5_identifies_120_threads() {
        let out = run_experiment("fig5", 100_000_000, 1).unwrap();
        let csv = &out[0].csv;
        let mut best = (0u32, f64::MAX);
        for line in csv.lines().skip(1) {
            let mut parts = line.split(',');
            let threads: u32 = parts.next().unwrap().parse().unwrap();
            let t: f64 = parts.next().unwrap().parse().unwrap();
            if t < best.1 {
                best = (threads, t);
            }
        }
        assert_eq!(best.0, 120, "csv: {csv}");
    }
}
