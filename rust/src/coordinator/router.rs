//! Chunk routing policies for the streaming coordinator.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// How incoming chunks are assigned to shard workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Routing {
    /// Cycle through shards — the block decomposition of Algorithm 1 in
    /// streaming form (every shard sees an interleaved 1/s of the
    /// stream, which is still a valid partition for the combine merge).
    RoundRobin,
    /// Send each chunk to the shard with the least queued items —
    /// adaptive balancing for heterogeneous shards (the coordinator
    /// analogue of the paper's ⌊n/p⌋/⌈n/p⌉ balance guarantee).
    LeastLoaded,
}

/// Shared routing state (load counters are updated by both the router
/// and the shard workers as they drain).
#[derive(Debug)]
pub struct Router {
    routing: Routing,
    next: u64,
    /// Queued items per shard (enqueued − drained).
    pub loads: Arc<Vec<AtomicU64>>,
}

impl Router {
    /// New router over `shards` workers.
    pub fn new(routing: Routing, shards: usize) -> Self {
        assert!(shards >= 1);
        Self {
            routing,
            next: 0,
            loads: Arc::new((0..shards).map(|_| AtomicU64::new(0)).collect()),
        }
    }

    /// Choose the shard for a chunk of `len` items and account its load.
    pub fn route(&mut self, len: usize) -> usize {
        let shard = match self.routing {
            Routing::RoundRobin => {
                let s = (self.next % self.loads.len() as u64) as usize;
                self.next += 1;
                s
            }
            Routing::LeastLoaded => self
                .loads
                .iter()
                .enumerate()
                .min_by_key(|(_, l)| l.load(Ordering::Relaxed))
                .map(|(i, _)| i)
                .expect("at least one shard"),
        };
        self.loads[shard].fetch_add(len as u64, Ordering::Relaxed);
        shard
    }

    /// Worker-side: mark `len` items drained from `shard`.
    pub fn drained(loads: &[AtomicU64], shard: usize, len: usize) {
        loads[shard].fetch_sub(len as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(Routing::RoundRobin, 3);
        let picks: Vec<usize> = (0..6).map(|_| r.route(10)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_drained_shard() {
        let mut r = Router::new(Routing::LeastLoaded, 3);
        let a = r.route(100); // 0
        let b = r.route(50); // 1 (0 has load)
        let c = r.route(10); // 2
        assert_eq!((a, b, c), (0, 1, 2));
        // Shard 2 has least load (10) -> next pick is 2 again.
        assert_eq!(r.route(5), 2);
        // Drain shard 0 fully; it becomes the least loaded.
        Router::drained(&r.loads, 0, 100);
        assert_eq!(r.route(1), 0);
    }
}
