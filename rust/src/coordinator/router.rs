//! Chunk routing policies for the streaming coordinator.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

pub use crate::util::shard_of;

/// How incoming items are assigned to shard workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Routing {
    /// Cycle whole chunks through shards — the block decomposition of
    /// Algorithm 1 in streaming form (every shard sees an interleaved
    /// 1/s of the stream, which is still a valid partition for the
    /// combine merge). The default.
    RoundRobin,
    /// Send each chunk to the shard with the least queued items —
    /// adaptive balancing for heterogeneous shards (the coordinator
    /// analogue of the paper's ⌊n/p⌋/⌈n/p⌉ balance guarantee).
    LeastLoaded,
    /// Hash-partition *items* to shards with [`shard_of`] (the same
    /// mix64 family as `FastMap`), the streaming analogue of the pure
    /// MPI formulation's hash decomposition (arXiv 1401.0702): every
    /// occurrence of an item lands on one home shard, so per-shard
    /// summaries are **key-disjoint** and merge by concatenation
    /// (`summary::merge_disjoint`) under the tighter max-per-shard
    /// error bound `maxᵢ ⌊nᵢ/k⌋` instead of the additive `⌊n/k⌋`.
    Keyed,
    /// [`Routing::Keyed`] plus a skew-adaptive hot-key tier: the
    /// producer detects heavy keys online (a small Space Saving sketch
    /// over a sampled substream, seeded with the top counters of the
    /// shards' own published snapshots) and splits detected hot keys
    /// round-robin across *all* shards. Split-key occurrences are
    /// counted **exactly** in per-shard side tables (never entering
    /// the shards' Space Saving structures), so per-shard summaries
    /// stay key-disjoint and the read side recombines a split key as
    /// `home-shard estimate + Σ exact partials` — the max-per-shard
    /// bound `maxᵢ ⌊nᵢ/k⌋` survives with at most one shard's ε of
    /// over-estimation per key. The tier removes keyed routing's
    /// hot-key cliff: one viral key no longer saturates a single
    /// shard's ring.
    KeyedAdaptive,
}

impl Routing {
    /// Whether this policy yields key-disjoint per-shard summaries
    /// (and therefore the disjoint merge + max-per-shard bound).
    /// Keyed-adaptive qualifies: split keys bypass the Space Saving
    /// structures entirely (exact side tables), so the *summaries*
    /// remain disjoint.
    pub fn is_disjoint(&self) -> bool {
        matches!(self, Routing::Keyed | Routing::KeyedAdaptive)
    }

    /// Whether items are hash-partitioned to home shards (either keyed
    /// flavor) — i.e. the coordinator scatters per item instead of
    /// routing whole chunks.
    pub fn is_keyed(&self) -> bool {
        matches!(self, Routing::Keyed | Routing::KeyedAdaptive)
    }

    /// Whether the hot-key detection/split tier is active.
    pub fn is_adaptive(&self) -> bool {
        matches!(self, Routing::KeyedAdaptive)
    }
}

impl std::fmt::Display for Routing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Routing::RoundRobin => "rr",
            Routing::LeastLoaded => "ll",
            Routing::Keyed => "keyed",
            Routing::KeyedAdaptive => "keyed-adaptive",
        })
    }
}

impl std::str::FromStr for Routing {
    type Err = String;

    /// `rr`/`chunks` (round-robin), `ll`/`least-loaded`, `keyed`,
    /// `keyed-adaptive`/`adaptive`.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "rr" | "chunks" | "round-robin" => Ok(Routing::RoundRobin),
            "ll" | "least-loaded" => Ok(Routing::LeastLoaded),
            "keyed" | "hash" => Ok(Routing::Keyed),
            "keyed-adaptive" | "adaptive" => Ok(Routing::KeyedAdaptive),
            other => Err(format!(
                "unknown routing '{other}' (rr|ll|keyed|keyed-adaptive)"
            )),
        }
    }
}

/// Shared routing state (load counters are updated by both the router
/// and the shard workers as they drain).
#[derive(Debug)]
pub struct Router {
    routing: Routing,
    next: u64,
    /// Queued items per shard (enqueued − drained).
    pub loads: Arc<Vec<AtomicU64>>,
}

impl Router {
    /// New router over `shards` workers.
    pub fn new(routing: Routing, shards: usize) -> Self {
        assert!(shards >= 1);
        Self {
            routing,
            next: 0,
            loads: Arc::new((0..shards).map(|_| AtomicU64::new(0)).collect()),
        }
    }

    /// The policy in use.
    pub fn routing(&self) -> Routing {
        self.routing
    }

    /// Choose the shard for a whole chunk of `len` items and account
    /// its load. Chunk-granular policies only — in [`Routing::Keyed`]
    /// mode the coordinator scatters per item with [`shard_of`] and
    /// accounts loads via [`Router::enqueued`].
    pub fn route(&mut self, len: usize) -> usize {
        let shard = match self.routing {
            Routing::RoundRobin => {
                let s = (self.next % self.loads.len() as u64) as usize;
                self.next += 1;
                s
            }
            Routing::LeastLoaded => self
                .loads
                .iter()
                .enumerate()
                .min_by_key(|(_, l)| l.load(Ordering::Relaxed))
                .map(|(i, _)| i)
                .expect("at least one shard"),
            Routing::Keyed | Routing::KeyedAdaptive => {
                unreachable!("keyed routing scatters per item in the coordinator")
            }
        };
        self.loads[shard].fetch_add(len as u64, Ordering::Relaxed);
        shard
    }

    /// Producer-side: account `len` items enqueued to `shard` (the
    /// keyed scatter path, where [`Router::route`] is not used).
    pub fn enqueued(&self, shard: usize, len: usize) {
        self.loads[shard].fetch_add(len as u64, Ordering::Relaxed);
    }

    /// Worker-side: mark `len` items drained from `shard`.
    pub fn drained(loads: &[AtomicU64], shard: usize, len: usize) {
        loads[shard].fetch_sub(len as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(Routing::RoundRobin, 3);
        let picks: Vec<usize> = (0..6).map(|_| r.route(10)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_drained_shard() {
        let mut r = Router::new(Routing::LeastLoaded, 3);
        let a = r.route(100); // 0
        let b = r.route(50); // 1 (0 has load)
        let c = r.route(10); // 2
        assert_eq!((a, b, c), (0, 1, 2));
        // Shard 2 has least load (10) -> next pick is 2 again.
        assert_eq!(r.route(5), 2);
        // Drain shard 0 fully; it becomes the least loaded.
        Router::drained(&r.loads, 0, 100);
        assert_eq!(r.route(1), 0);
    }

    #[test]
    fn routing_parses_and_roundtrips() {
        for (s, want) in [
            ("rr", Routing::RoundRobin),
            ("chunks", Routing::RoundRobin),
            ("ll", Routing::LeastLoaded),
            ("keyed", Routing::Keyed),
            ("keyed-adaptive", Routing::KeyedAdaptive),
            ("adaptive", Routing::KeyedAdaptive),
        ] {
            assert_eq!(s.parse::<Routing>().unwrap(), want, "{s}");
        }
        assert!("bogus".parse::<Routing>().is_err());
        for r in [
            Routing::RoundRobin,
            Routing::LeastLoaded,
            Routing::Keyed,
            Routing::KeyedAdaptive,
        ] {
            assert_eq!(r.to_string().parse::<Routing>().unwrap(), r);
        }
        assert!(Routing::Keyed.is_disjoint());
        assert!(Routing::KeyedAdaptive.is_disjoint());
        assert!(Routing::KeyedAdaptive.is_adaptive());
        assert!(Routing::KeyedAdaptive.is_keyed());
        assert!(Routing::Keyed.is_keyed());
        assert!(!Routing::Keyed.is_adaptive());
        assert!(!Routing::RoundRobin.is_disjoint());
        assert!(!Routing::RoundRobin.is_keyed());
    }

    #[test]
    fn keyed_scatter_accounting_via_enqueued() {
        let r = Router::new(Routing::Keyed, 4);
        assert!(r.routing().is_disjoint());
        r.enqueued(2, 30);
        r.enqueued(2, 10);
        Router::drained(&r.loads, 2, 25);
        assert_eq!(r.loads[2].load(Ordering::Relaxed), 15);
    }
}
