//! The streaming coordinator: sharded ingestion with bounded queues
//! (backpressure), per-shard Space Saving, epoch snapshot publication
//! for the live read path, and a final combine-tree merge — Parallel
//! Space Saving as a long-running service rather than a one-shot batch
//! job.
//!
//! Topology:
//!
//! ```text
//!  push(chunk) ─▶ router ─▶ [bounded queue]─▶ shard 0: SpaceSaving ──▶ epoch Arc ─┐
//!                        ─▶ [bounded queue]─▶ shard 1: SpaceSaving ──▶ epoch Arc ─┼▶ QueryEngine
//!                        ─▶      ...      ─▶ shard s: SpaceSaving ──▶ epoch Arc ─┘  (live reads)
//!  finish() ──────────────── join ─▶ tree_reduce(combine) ─▶ prune
//! ```
//!
//! With [`CoordinatorConfig::batch_ingest`] on (the default) each shard
//! first collapses an incoming chunk into `(item, weight)` runs with a
//! reusable scratch map and applies weighted Space Saving updates — one
//! summary touch per distinct item instead of per occurrence (see
//! [`crate::summary::batch`]).
//!
//! Queues are `std::sync::mpsc::sync_channel`s of `queue_depth` chunks;
//! a full queue blocks the producer (backpressure), and every such stall
//! is counted in [`IngestStats::backpressure_events`]. The non-blocking
//! [`Coordinator::try_push`] instead returns the chunk in a typed
//! [`PushError`] and counts the rejection.
//!
//! Every `epoch_items` items (and at drain), each shard freezes its
//! summary and swaps it into the shared [`EpochRegistry`], so
//! [`QueryEngine`] handles returned by [`Coordinator::spawn`] serve
//! `top_k` / `point` / `threshold` queries concurrently with ingestion.
//!
//! With [`CoordinatorConfig::delta_ring`] > 0 each publication also
//! cuts a per-epoch *delta summary* (the Space Saving state of just
//! that epoch's items, accumulated by a [`DeltaBuilder`] from the same
//! runs the batched path already aggregates) into a bounded
//! [`WindowStore`] ring, enabling sliding-window queries
//! (`top_k_window`, `k_majority_window`, …) through the
//! [`WindowedQueryEngine`] handle from [`Coordinator::windows`] — see
//! [`crate::window`].

use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, RecvTimeoutError, SyncSender, TrySendError};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::gen::ItemSource;
use crate::parallel::reduction::tree_reduce;
use crate::query::{EpochRegistry, QueryEngine};
use crate::summary::batch::{offer_runs, ChunkAggregator};
use crate::summary::{Counter, FrequencySummary, StreamSummary, Summary};
use crate::window::{DeltaBuilder, WindowStore, WindowedQueryEngine};

use super::router::{Router, Routing};

/// How long an idle shard sleeps between checks for refresh requests.
const IDLE_POLL: Duration = Duration::from_millis(20);

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Shard workers (each owns one Space Saving instance).
    pub shards: usize,
    /// Counters per shard summary.
    pub k: usize,
    /// k-majority parameter for the final prune.
    pub k_majority: u64,
    /// Bounded queue depth, in chunks, per shard.
    pub queue_depth: usize,
    /// Chunk routing policy.
    pub routing: Routing,
    /// Per-shard epoch snapshot cadence, in items: a shard republishes
    /// its summary after processing this many items since its last
    /// publication. 0 disables count-triggered publication (snapshots
    /// then only happen on [`QueryEngine::refresh`] and at drain).
    pub epoch_items: u64,
    /// Route chunks through the batched ingest fast path (default on):
    /// each shard pre-aggregates a chunk into `(item, weight)` runs
    /// with a reusable [`ChunkAggregator`] and applies one weighted
    /// Space Saving update per *distinct* item instead of one per
    /// occurrence. Identical error guarantees (`f ≤ f̂ ≤ f + n/k`,
    /// full recall above `n/k`) — individual estimates may differ
    /// within those bounds from per-item ingestion. Turn off to
    /// reproduce exact per-item update sequences.
    pub batch_ingest: bool,
    /// Sliding-window read path: ring capacity, in epoch *deltas*
    /// retained per shard. When > 0 every epoch publication also cuts a
    /// delta summary — the Space Saving state of just that epoch's
    /// items — into the shard's bounded [`WindowStore`] ring, and
    /// [`Coordinator::windows`] hands out a [`WindowedQueryEngine`]
    /// serving `top_k_window` / `point_in_window` / `k_majority_window`
    /// under the windowed bound `f ≤ f̂ ≤ f + W/k` (`W` = window mass).
    /// 0 (the default) disables delta publication entirely: zero
    /// write-path overhead, windowed queries unavailable.
    pub delta_ring: usize,
    /// Default windowed-query width, in epochs, for the engine handed
    /// back by [`Coordinator::spawn`] (only meaningful with
    /// `delta_ring > 0`; explicit widths can always be passed per
    /// query).
    pub window_epochs: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            shards: 4,
            k: 2000,
            k_majority: 2000,
            queue_depth: 8,
            routing: Routing::RoundRobin,
            epoch_items: 65_536,
            batch_ingest: true,
            delta_ring: 0,
            window_epochs: 8,
        }
    }
}

/// Ingestion statistics.
#[derive(Debug, Clone, Default)]
pub struct IngestStats {
    /// Chunks accepted.
    pub chunks: u64,
    /// Items accepted.
    pub items: u64,
    /// Producer stalls on a full shard queue (blocking `push`).
    pub backpressure_events: u64,
    /// Chunks rejected by the non-blocking `try_push`.
    pub rejected_chunks: u64,
    /// Epoch snapshots published by the shards (filled at `finish`).
    pub epochs_published: u64,
    /// Epoch deltas published into the window rings (filled at
    /// `finish`; 0 when [`CoordinatorConfig::delta_ring`] is 0). Their
    /// masses partition the accepted items exactly: every ingested item
    /// lands in exactly one delta.
    pub deltas_published: u64,
    /// Items processed per shard.
    pub per_shard_items: Vec<u64>,
}

/// Typed rejection from [`Coordinator::try_push`]: the chunk comes back
/// so the caller can retry, reroute or drop it deliberately.
#[derive(Debug)]
pub enum PushError {
    /// The routed shard's queue was full.
    Full {
        /// Shard whose queue rejected the chunk.
        shard: usize,
        /// The rejected chunk, returned to the caller.
        chunk: Vec<u64>,
    },
    /// The routed shard's worker has terminated.
    Disconnected {
        /// Shard whose worker is gone.
        shard: usize,
        /// The rejected chunk, returned to the caller.
        chunk: Vec<u64>,
    },
}

impl PushError {
    /// Recover the rejected chunk.
    pub fn into_chunk(self) -> Vec<u64> {
        match self {
            PushError::Full { chunk, .. } | PushError::Disconnected { chunk, .. } => chunk,
        }
    }
}

impl std::fmt::Display for PushError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PushError::Full { shard, chunk } => {
                write!(f, "shard {shard} queue full ({} items returned)", chunk.len())
            }
            PushError::Disconnected { shard, chunk } => {
                write!(f, "shard {shard} worker gone ({} items returned)", chunk.len())
            }
        }
    }
}

impl std::error::Error for PushError {}

/// Final result of a coordinator session.
#[derive(Debug, Clone)]
pub struct QueryResult {
    /// Merged global summary.
    pub summary: Summary,
    /// k-majority candidates (`f̂ > n/k_majority`), descending.
    pub frequent: Vec<Counter>,
    /// Ingestion statistics.
    pub stats: IngestStats,
}

enum Msg {
    Chunk(Vec<u64>),
    Finish,
}

/// What one shard worker hands back at drain.
struct ShardOutcome {
    /// The shard's final cumulative summary.
    summary: Summary,
    /// Items the shard processed.
    items: u64,
    /// Total mass of the deltas the shard published (must equal
    /// `items` when the delta ring is on — every item lands in exactly
    /// one delta).
    delta_mass: u64,
}

/// A running coordinator session.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    senders: Vec<SyncSender<Msg>>,
    handles: Vec<JoinHandle<ShardOutcome>>,
    router: Router,
    stats: IngestStats,
    engine: QueryEngine,
    /// Sliding-window query handle; `Some` iff `delta_ring > 0`.
    windows: Option<WindowedQueryEngine>,
}

impl Coordinator {
    /// Spawn the shard workers and return the session plus a live
    /// [`QueryEngine`] handle attached to its epoch registry. The
    /// engine (and any clone of it) keeps answering queries during
    /// ingestion and remains valid after [`Coordinator::finish`] —
    /// final drain snapshots stay published.
    pub fn spawn(cfg: CoordinatorConfig) -> (Self, QueryEngine) {
        assert!(cfg.shards >= 1 && cfg.queue_depth >= 1);
        let router = Router::new(cfg.routing, cfg.shards);
        let registry = EpochRegistry::new(cfg.shards, cfg.k);
        // Windowed read path: a bounded delta ring per shard, served by
        // a WindowedQueryEngine the coordinator hands out (the landmark
        // QueryEngine stays independent of the window layer).
        let store = (cfg.delta_ring > 0)
            .then(|| WindowStore::new(cfg.shards, cfg.delta_ring, cfg.k));
        let windows = store
            .as_ref()
            .map(|s| WindowedQueryEngine::new(s.clone(), cfg.window_epochs, cfg.k_majority));
        let engine = QueryEngine::new(registry.clone(), cfg.k_majority);
        let mut senders = Vec::with_capacity(cfg.shards);
        let mut handles = Vec::with_capacity(cfg.shards);
        for shard in 0..cfg.shards {
            let (tx, rx) = sync_channel::<Msg>(cfg.queue_depth);
            let k = cfg.k;
            let epoch_items = cfg.epoch_items;
            let batch_ingest = cfg.batch_ingest;
            let loads = router.loads.clone();
            let registry = registry.clone();
            let window = store.clone();
            handles.push(std::thread::spawn(move || {
                // Bucket-list Space Saving: O(1) amortized and ~30% faster
                // on the eviction-heavy paths (see EXPERIMENTS.md §Perf).
                let mut ss = StreamSummary::new(k);
                // Scratch for the batched fast path, reused across chunks
                // so the steady state allocates nothing.
                let mut scratch = batch_ingest.then(ChunkAggregator::new);
                // Window side: accumulate this epoch's exact (item,
                // weight) runs; cut into a delta at each publication.
                let mut delta = window.as_ref().map(|_| DeltaBuilder::new());
                let mut delta_mass = 0u64;
                let mut items = 0u64;
                let mut since_publish = 0u64;
                let mut refresh_seen = 0u64;
                loop {
                    match rx.recv_timeout(IDLE_POLL) {
                        Ok(Msg::Chunk(chunk)) => {
                            match scratch.as_mut() {
                                Some(agg) => {
                                    // Aggregate once, apply twice: the
                                    // runs feed the cumulative summary
                                    // and (one map probe per distinct
                                    // item) the pending delta.
                                    let runs = agg.aggregate(&chunk);
                                    offer_runs(&mut ss, runs);
                                    if let Some(db) = delta.as_mut() {
                                        db.absorb_runs(runs);
                                    }
                                }
                                None => {
                                    ss.offer_all(&chunk);
                                    if let Some(db) = delta.as_mut() {
                                        db.absorb_items(&chunk);
                                    }
                                }
                            }
                            items += chunk.len() as u64;
                            since_publish += chunk.len() as u64;
                            Router::drained(&loads, shard, chunk.len());
                            let watermark = registry.refresh_watermark();
                            let due = epoch_items > 0 && since_publish >= epoch_items;
                            if due || watermark > refresh_seen {
                                // Delta first, cumulative snapshot second:
                                // a reader that observes the new landmark
                                // epoch (e.g. staleness reaching 0) is then
                                // guaranteed the matching window delta is
                                // already in the ring.
                                if let (Some(db), Some(ws)) = (delta.as_mut(), window.as_ref()) {
                                    if !db.is_empty() {
                                        delta_mass += db.mass();
                                        ws.publish(shard, db.cut(k), false);
                                    }
                                }
                                registry.publish(shard, ss.freeze(), false);
                                since_publish = 0;
                                refresh_seen = watermark;
                            }
                        }
                        Ok(Msg::Finish) => break,
                        Err(RecvTimeoutError::Timeout) => {
                            // Idle: honor on-demand refresh requests so
                            // readers are not stuck behind a quiet shard.
                            let watermark = registry.refresh_watermark();
                            if watermark > refresh_seen {
                                if let (Some(db), Some(ws)) = (delta.as_mut(), window.as_ref()) {
                                    if !db.is_empty() {
                                        delta_mass += db.mass();
                                        ws.publish(shard, db.cut(k), false);
                                    }
                                }
                                registry.publish(shard, ss.freeze(), false);
                                since_publish = 0;
                                refresh_seen = watermark;
                            }
                        }
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                // Drain: the final epoch covers everything this shard saw.
                // The last partial epoch must reach the window ring too —
                // before the final landmark snapshot, as above — or items
                // since the final cadence cut would be visible to landmark
                // queries but silently missing from windowed ones.
                let summary = ss.freeze();
                if let (Some(db), Some(ws)) = (delta.as_mut(), window.as_ref()) {
                    if db.is_empty() {
                        ws.finish_shard(shard);
                    } else {
                        delta_mass += db.mass();
                        ws.publish(shard, db.cut(k), true);
                    }
                }
                registry.publish(shard, summary.clone(), true);
                ShardOutcome { summary, items, delta_mass }
            }));
            senders.push(tx);
        }
        let coordinator = Self {
            stats: IngestStats { per_shard_items: vec![0; cfg.shards], ..Default::default() },
            cfg,
            senders,
            handles,
            router,
            engine: engine.clone(),
            windows,
        };
        (coordinator, engine)
    }

    /// Spawn without keeping the query handle (batch-style sessions).
    pub fn start(cfg: CoordinatorConfig) -> Self {
        Self::spawn(cfg).0
    }

    /// Configuration in use.
    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    /// A live query handle over this session's epoch snapshots (same
    /// registry as the handle returned by [`Coordinator::spawn`]).
    pub fn queries(&self) -> QueryEngine {
        self.engine.clone()
    }

    /// The sliding-window query handle, when this session publishes
    /// epoch deltas ([`CoordinatorConfig::delta_ring`] > 0). Cheap to
    /// clone; stays valid (serving the final drain-time deltas) after
    /// [`Coordinator::finish`].
    pub fn windows(&self) -> Option<WindowedQueryEngine> {
        self.windows.clone()
    }

    /// Ingestion statistics so far (`epochs_published` is finalized by
    /// [`Coordinator::finish`]).
    pub fn stats(&self) -> &IngestStats {
        &self.stats
    }

    fn account(&mut self, shard: usize, len: usize) {
        self.stats.chunks += 1;
        self.stats.items += len as u64;
        self.stats.per_shard_items[shard] += len as u64;
        self.engine.registry().add_items_routed(len as u64);
    }

    /// Ingest one chunk. Blocks when the target shard's queue is full
    /// (counted as a backpressure event).
    pub fn push(&mut self, chunk: Vec<u64>) {
        if chunk.is_empty() {
            return;
        }
        let len = chunk.len();
        let shard = self.router.route(len);
        match self.senders[shard].try_send(Msg::Chunk(chunk)) {
            Ok(()) => {}
            Err(TrySendError::Full(msg)) => {
                self.stats.backpressure_events += 1;
                // Block until the shard drains — backpressure, not drop.
                self.senders[shard].send(msg).expect("shard died");
            }
            Err(TrySendError::Disconnected(_)) => panic!("shard died"),
        }
        self.account(shard, len);
    }

    /// Non-blocking ingest: route the chunk and enqueue it if the shard
    /// has room, otherwise hand it straight back as a typed
    /// [`PushError`] (counted in [`IngestStats::rejected_chunks`]).
    /// Load-shedding callers can drop the chunk; latency-tolerant ones
    /// retry or fall back to the blocking [`Coordinator::push`].
    pub fn try_push(&mut self, chunk: Vec<u64>) -> Result<(), PushError> {
        if chunk.is_empty() {
            return Ok(());
        }
        let len = chunk.len();
        let shard = self.router.route(len);
        match self.senders[shard].try_send(Msg::Chunk(chunk)) {
            Ok(()) => {
                self.account(shard, len);
                Ok(())
            }
            Err(err) => {
                // Undo the router's load accounting for the queued-items
                // gauge; the chunk never reached the shard.
                Router::drained(&self.router.loads, shard, len);
                self.stats.rejected_chunks += 1;
                Err(match err {
                    TrySendError::Full(Msg::Chunk(chunk)) => PushError::Full { shard, chunk },
                    TrySendError::Disconnected(Msg::Chunk(chunk)) => {
                        PushError::Disconnected { shard, chunk }
                    }
                    _ => unreachable!("only chunks are try-sent"),
                })
            }
        }
    }

    /// Current queued load per shard (items), for monitoring.
    pub fn queued(&self) -> Vec<u64> {
        self.router
            .loads
            .iter()
            .map(|l| l.load(Ordering::Relaxed))
            .collect()
    }

    /// Drain, merge and prune. The epoch registry (and every
    /// [`QueryEngine`] handle) survives with each shard's final
    /// snapshot published.
    pub fn finish(self) -> QueryResult {
        for tx in &self.senders {
            let _ = tx.send(Msg::Finish);
        }
        drop(self.senders);
        let mut summaries = Vec::with_capacity(self.handles.len());
        let mut stats = self.stats;
        for (shard, h) in self.handles.into_iter().enumerate() {
            let out = h.join().expect("shard panicked");
            debug_assert_eq!(out.items, stats.per_shard_items[shard]);
            if self.windows.is_some() {
                // Delta accounting balance: the published deltas of a
                // shard partition exactly the items it ingested (the
                // drain path publishes the last partial epoch).
                debug_assert_eq!(
                    out.delta_mass, out.items,
                    "shard {shard}: delta mass must cover every ingested item"
                );
            }
            summaries.push(out.summary);
        }
        let summary = tree_reduce(summaries);
        let frequent = summary.prune(stats.items, self.cfg.k_majority);
        stats.epochs_published = self.engine.registry().epochs_published();
        stats.deltas_published = self
            .windows
            .as_ref()
            .map_or(0, |w| w.store().deltas_published());
        stats.per_shard_items.shrink_to_fit();
        QueryResult { summary, frequent, stats }
    }
}

/// Convenience: stream an [`ItemSource`] through a coordinator in
/// `chunk_len`-item chunks.
pub fn run_source(
    cfg: CoordinatorConfig,
    source: &dyn ItemSource,
    chunk_len: usize,
) -> QueryResult {
    let mut c = Coordinator::start(cfg);
    let n = source.len();
    let mut pos = 0u64;
    while pos < n {
        let take = ((n - pos) as usize).min(chunk_len);
        c.push(source.slice(pos, pos + take as u64));
        pos += take as u64;
    }
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::Exact;
    use crate::gen::GeneratedSource;
    use crate::metrics::AccuracyReport;

    #[test]
    fn coordinator_matches_batch_guarantees() {
        let src = GeneratedSource::zipf(120_000, 4_000, 1.1, 33);
        // Per-item path: seed-exact behavior (the batched path has its
        // own guarantee test below).
        let cfg = CoordinatorConfig {
            shards: 4,
            k: 256,
            k_majority: 256,
            batch_ingest: false,
            ..Default::default()
        };
        let out = run_source(cfg, &src, 4096);
        assert_eq!(out.stats.items, 120_000);

        let mut exact = Exact::new();
        exact.offer_all(&src.slice(0, 120_000));
        let acc = AccuracyReport::evaluate(&out.frequent, &exact, 256);
        assert_eq!(acc.recall, 1.0);
        assert_eq!(acc.precision, 1.0);
    }

    #[test]
    fn round_robin_balances_items() {
        let src = GeneratedSource::uniform(100_000, 1000, 1);
        let cfg = CoordinatorConfig { shards: 5, k: 64, k_majority: 64, ..Default::default() };
        let out = run_source(cfg, &src, 1000);
        let min = *out.stats.per_shard_items.iter().min().unwrap();
        let max = *out.stats.per_shard_items.iter().max().unwrap();
        assert!(max - min <= 1000, "imbalance: {:?}", out.stats.per_shard_items);
    }

    #[test]
    fn least_loaded_routing_works() {
        let src = GeneratedSource::zipf(50_000, 500, 1.8, 2);
        let cfg = CoordinatorConfig {
            shards: 3,
            k: 64,
            k_majority: 64,
            routing: Routing::LeastLoaded,
            ..Default::default()
        };
        let out = run_source(cfg, &src, 2048);
        assert_eq!(out.stats.items, 50_000);
        assert!(out.frequent.iter().any(|c| c.item == 1));
    }

    #[test]
    fn backpressure_fires_with_tiny_queues() {
        let src = GeneratedSource::uniform(200_000, 100, 3);
        let cfg = CoordinatorConfig {
            shards: 1,
            k: 32,
            k_majority: 32,
            queue_depth: 1,
            ..Default::default()
        };
        let out = run_source(cfg, &src, 256);
        assert!(
            out.stats.backpressure_events > 0,
            "expected stalls with a depth-1 queue and 782 chunks"
        );
        assert_eq!(out.stats.items, 200_000);
    }

    #[test]
    fn empty_chunks_ignored_and_empty_stream_ok() {
        let mut c = Coordinator::start(CoordinatorConfig::default());
        c.push(Vec::new());
        let out = c.finish();
        assert_eq!(out.stats.items, 0);
        assert!(out.frequent.is_empty());
    }

    #[test]
    fn incremental_push_api() {
        let mut c = Coordinator::start(CoordinatorConfig {
            shards: 2,
            k: 16,
            k_majority: 4,
            ..Default::default()
        });
        for _ in 0..100 {
            c.push(vec![7; 50]);
            c.push(vec![1, 2, 3, 4, 5]);
        }
        let out = c.finish();
        assert_eq!(out.stats.items, 100 * 55);
        assert_eq!(out.frequent.len(), 1);
        assert_eq!(out.frequent[0].item, 7);
    }

    #[test]
    fn spawn_returns_live_query_handle() {
        let (mut c, q) = Coordinator::spawn(CoordinatorConfig {
            shards: 2,
            k: 64,
            k_majority: 8,
            epoch_items: 100,
            ..Default::default()
        });
        for _ in 0..50 {
            c.push(vec![3; 40]);
        }
        // Epochs were published mid-ingest (cadence 100 items, 2000
        // items pushed): wait for at least one to land.
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while q.stats().items_published == 0 {
            assert!(std::time::Instant::now() < deadline, "no epoch published");
            std::thread::yield_now();
        }
        let snap = q.snapshot();
        assert!(snap.n() > 0);
        assert_eq!(snap.top_k(1)[0].item, 3);
        let out = c.finish();
        assert!(out.stats.epochs_published >= 2, "at least the drain epochs");
        // After finish the engine still answers, now with full coverage.
        let final_snap = q.snapshot();
        assert_eq!(final_snap.n(), 2000);
        assert_eq!(final_snap.point(3).estimate, 2000);
        assert!(final_snap.epochs().iter().all(|e| e.finished));
    }

    #[test]
    fn refresh_publishes_from_idle_shards() {
        let (mut c, q) = Coordinator::spawn(CoordinatorConfig {
            shards: 2,
            k: 16,
            k_majority: 4,
            epoch_items: 0, // no count-triggered publication
            ..Default::default()
        });
        c.push(vec![9; 30]);
        c.push(vec![9; 30]);
        q.refresh();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while q.stats().items_published < 60 {
            assert!(
                std::time::Instant::now() < deadline,
                "refresh did not reach idle shards: {:?}",
                q.stats()
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(q.point(9).estimate, 60);
        c.finish();
    }

    #[test]
    fn try_push_rejects_when_full_and_counts() {
        let (mut c, _q) = Coordinator::spawn(CoordinatorConfig {
            shards: 1,
            k: 16,
            k_majority: 4,
            queue_depth: 1,
            epoch_items: 0,
            ..Default::default()
        });
        let mut accepted = 0u64;
        let mut rejected = 0u64;
        let mut rejected_items = 0u64;
        for _ in 0..5_000 {
            match c.try_push(vec![1; 64]) {
                Ok(()) => accepted += 64,
                Err(e @ PushError::Full { .. }) => {
                    rejected += 1;
                    let chunk = e.into_chunk();
                    assert_eq!(chunk.len(), 64, "chunk comes back intact");
                    rejected_items += chunk.len() as u64;
                }
                Err(other) => panic!("unexpected error: {other}"),
            }
        }
        assert!(
            rejected > 0,
            "a depth-1 queue flooded with 5000 chunks must reject some"
        );
        assert_eq!(c.stats().rejected_chunks, rejected);
        let out = c.finish();
        assert_eq!(out.stats.items, accepted);
        assert_eq!(out.stats.items + rejected_items, 5_000 * 64);
        // Accepted mass is fully accounted by the shard summaries.
        assert_eq!(out.summary.n(), accepted);
    }

    #[test]
    fn batched_and_per_item_paths_account_identically() {
        // Same stream through both write paths: identical item/chunk
        // accounting, identical total mass, and both honor the
        // guarantee (recall 1 against exact truth).
        let src = GeneratedSource::zipf(80_000, 2_000, 1.3, 9);
        let mut exact = Exact::new();
        exact.offer_all(&src.slice(0, 80_000));
        for batch_ingest in [false, true] {
            let cfg = CoordinatorConfig {
                shards: 3,
                k: 128,
                k_majority: 128,
                batch_ingest,
                ..Default::default()
            };
            let out = run_source(cfg, &src, 4096);
            assert_eq!(out.stats.items, 80_000, "batch={batch_ingest}");
            assert_eq!(out.summary.n(), 80_000, "batch={batch_ingest}");
            let acc = AccuracyReport::evaluate(&out.frequent, &exact, 128);
            assert_eq!(acc.recall, 1.0, "batch={batch_ingest}");
        }
    }

    #[test]
    fn batched_ingest_single_heavy_item_is_exact() {
        // A chunk of one repeated item is the best case for the batch
        // path: one run, one weighted update, exact count.
        let (mut c, q) = Coordinator::spawn(CoordinatorConfig {
            shards: 2,
            k: 16,
            k_majority: 4,
            ..Default::default()
        });
        assert!(c.config().batch_ingest, "batched path is the default");
        for _ in 0..200 {
            c.push(vec![11; 64]);
        }
        let out = c.finish();
        assert_eq!(out.stats.items, 200 * 64);
        assert_eq!(q.point(11).estimate, 200 * 64);
        assert_eq!(q.point(11).guaranteed, 200 * 64);
    }

    #[test]
    fn delta_ring_default_off_and_balances_when_on() {
        // Off by default: no deltas, no window handle, write path
        // untouched.
        let (c, _q) = Coordinator::spawn(CoordinatorConfig::default());
        assert_eq!(c.config().delta_ring, 0);
        assert!(c.windows().is_none());
        let out = c.finish();
        assert_eq!(out.stats.deltas_published, 0);

        // On: every ingested item lands in exactly one delta, so the
        // window over the full ring covers the entire stream — including
        // the drain-time partial epoch.
        let (mut c, _q) = Coordinator::spawn(CoordinatorConfig {
            shards: 2,
            k: 32,
            k_majority: 8,
            epoch_items: 500,
            delta_ring: 64,
            window_epochs: 4,
            ..Default::default()
        });
        let w = c.windows().expect("delta ring on");
        // 43 chunks: both shards end on a partial epoch (130-item chunks
        // against a 500-item cadence), exercising the drain delta.
        for _ in 0..43 {
            c.push(vec![5; 130]);
        }
        let out = c.finish();
        assert_eq!(out.stats.items, 5_590);
        assert!(out.stats.deltas_published >= 2, "cadence + drain deltas");
        let snap = w.window(64);
        assert_eq!(snap.n(), 5_590, "full-ring window covers the whole stream");
        assert_eq!(snap.point(5).estimate, 5_590);
        assert!(snap.deltas().iter().any(|d| d.finished), "drain delta published");
        assert_eq!(
            out.stats.deltas_published,
            w.window_stats().deltas_published
        );
    }

    #[test]
    fn try_push_empty_is_ok() {
        let (mut c, _q) = Coordinator::spawn(CoordinatorConfig::default());
        assert!(c.try_push(Vec::new()).is_ok());
        let out = c.finish();
        assert_eq!(out.stats.items, 0);
        assert_eq!(out.stats.rejected_chunks, 0);
    }
}
