//! The streaming coordinator: sharded ingestion over lock-free SPSC
//! rings (backpressure), per-shard Space Saving, epoch snapshot
//! publication for the live read path, and a final combine-tree merge —
//! Parallel Space Saving as a long-running service rather than a
//! one-shot batch job.
//!
//! Topology:
//!
//! ```text
//!  push(chunk) ─▶ router ─▶ [SPSC ring]─▶ shard 0: summary core ──▶ epoch Arc ─┐
//!                        ─▶ [SPSC ring]─▶ shard 1: summary core ──▶ epoch Arc ─┼▶ QueryEngine
//!                        ─▶    ...     ─▶ shard s: summary core ──▶ epoch Arc ─┘  (live reads)
//!       ◀─────────────────[free ring]── consumed chunk buffers flow back
//!  finish() ──────────────── join ─▶ tree_reduce(combine) ─▶ prune
//! ```
//!
//! **Transport.** Each shard is fed through a bounded, cache-line-padded
//! lock-free SPSC ring ([`crate::parallel::spsc`]) — a couple of plain
//! stores per chunk handoff instead of `sync_channel`'s mutex+condvar
//! handshake. A full ring back-pressures the producer through a
//! spin-then-park [`Backoff`] (stalls counted in
//! [`IngestStats::backpressure_events`], retry rounds in
//! [`IngestStats::transport_retries`]); the non-blocking
//! [`Coordinator::try_push`] instead returns the chunk in a typed
//! [`PushError`] and counts the rejection. The old mpsc transport is
//! kept behind [`Transport::Mpsc`] purely as the benchmark baseline
//! (`pss bench --suite transport`, `bench_transport`).
//!
//! **Chunk recycling.** In ring mode each shard also owns a reverse
//! *free ring*: consumed chunk `Vec`s are cleared and handed back to
//! the producer side, where [`Coordinator::take_buffer`] (used by
//! [`run_source`] and the keyed scatter path) reuses them — steady-state
//! ingest allocates nothing. Reuses are counted in
//! [`IngestStats::buffers_recycled`].
//!
//! **Routing.** [`Routing::RoundRobin`] (default) and
//! [`Routing::LeastLoaded`] assign whole chunks to shards; every shard
//! then observes the full key space and merged bounds add across
//! shards. [`Routing::Keyed`] hash-partitions *items* to their home
//! shard ([`crate::util::shard_of`], the same mix64 family as
//! `FastMap`), making per-shard summaries key-disjoint: the drain and
//! the query engines then merge by concatenation
//! ([`crate::summary::merge_disjoint`]) under the tighter
//! max-per-shard bound `maxᵢ ⌊nᵢ/k⌋`.
//!
//! **Hot-key tier.** [`Routing::KeyedAdaptive`] removes keyed routing's
//! skew cliff (one viral key saturating its home shard). The producer
//! runs a small Space Saving sketch over a 1-in-[`HOT_SAMPLE_STRIDE`]
//! sample of the scattered items and, every [`HOT_EVAL_ITEMS`] items,
//! promotes keys whose share exceeds `1/(2·shards)` — candidates are
//! seeded from the sketch *and* from the top counter of each shard's
//! own published snapshot. Promoted keys are spread round-robin across
//! all shards ([`crate::util::spread_of`]); every scattered sub-chunk
//! carries the hot-set *generation* as its first element, so a worker
//! classifies items against the exact immutable set the producer used
//! (no producer/worker race across a rebalance). Split-key occurrences
//! are counted **exactly** in per-shard side tables — they never enter
//! any Space Saving structure — published with each epoch
//! ([`crate::query::EpochSnapshot::hot`], [`DeltaSummary::hot`]) and
//! recombined at read time ([`crate::summary::absorb_exact`]): a split
//! key's estimate is `home-shard estimate + Σ exact partials`, so the
//! max-per-shard bound survives with at most the home shard's ε of
//! over-estimation.
//!
//! With [`CoordinatorConfig::batch_ingest`] on (the default) each shard
//! first collapses an incoming chunk into `(item, weight)` runs with a
//! reusable scratch map and applies weighted Space Saving updates — one
//! summary touch per distinct item instead of per occurrence (see
//! [`crate::summary::batch`]).
//!
//! Every `epoch_items` items (and at drain), each shard freezes its
//! summary and swaps it into the shared [`EpochRegistry`], so
//! [`QueryEngine`] handles returned by [`Coordinator::spawn`] serve
//! `top_k` / `point` / `threshold` queries concurrently with ingestion.
//!
//! With [`CoordinatorConfig::delta_ring`] > 0 each publication also
//! cuts a per-epoch *delta summary* (the Space Saving state of just
//! that epoch's items, accumulated by a [`DeltaBuilder`] from the same
//! runs the batched path already aggregates) into a bounded
//! [`WindowStore`] ring, enabling sliding-window queries
//! (`top_k_window`, `k_majority_window`, …) through the
//! [`WindowedQueryEngine`] handle from [`Coordinator::windows`] — see
//! [`crate::window`].

use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::gen::ItemSource;
use crate::parallel::reduction::tree_reduce;
use crate::parallel::spsc::{self, Backoff, PopTimeoutError, TryPushError};
use crate::query::{EpochRegistry, QueryEngine};
use crate::summary::batch::{offer_runs, ChunkAggregator};
use crate::summary::{
    absorb_exact, merge_disjoint, Counter, FrequencySummary, SpaceSaving, Summary, SummaryKind,
};
use crate::util::{shard_of, spread_of};
use crate::window::{DeltaBuilder, WindowStore, WindowedQueryEngine};

use super::router::{Router, Routing};

/// How long an idle shard sleeps between checks for refresh requests.
const IDLE_POLL: Duration = Duration::from_millis(20);

/// Counter budget of the producer's hot-key detection sketch
/// ([`Routing::KeyedAdaptive`]): tiny on purpose — it only has to
/// surface keys with a Θ(1/shards) share, far coarser than the shard
/// summaries' k.
const HOT_SKETCH_K: usize = 64;

/// Items scattered between hot-set evaluations.
const HOT_EVAL_ITEMS: u64 = 65_536;

/// Maximum keys in the hot set (splitting is for the catastrophic few,
/// not the merely popular).
const HOT_SET_CAP: usize = 8;

/// Detection sampling stride: 1 in this many scattered items feeds the
/// sketch, keeping the per-item scatter overhead a compare + rare
/// offer.
const HOT_SAMPLE_STRIDE: u64 = 8;

/// Producer→shard chunk transport.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transport {
    /// Bounded lock-free SPSC ring with chunk-buffer recycling
    /// ([`crate::parallel::spsc`]). The default.
    Ring,
    /// `std::sync::mpsc::sync_channel` — one mutex+condvar handshake
    /// per chunk, no recycling. Kept as the measurable baseline the
    /// ring is judged against (`bench_transport`); not recommended
    /// for production sessions.
    Mpsc,
}

impl std::fmt::Display for Transport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Transport::Ring => "ring",
            Transport::Mpsc => "mpsc",
        })
    }
}

impl std::str::FromStr for Transport {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "ring" | "spsc" => Ok(Transport::Ring),
            "mpsc" | "channel" => Ok(Transport::Mpsc),
            other => Err(format!("unknown transport '{other}' (ring|mpsc)")),
        }
    }
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Shard workers (each owns one Space Saving instance).
    pub shards: usize,
    /// Counters per shard summary.
    pub k: usize,
    /// k-majority parameter for the final prune.
    pub k_majority: u64,
    /// Bounded queue depth, in chunks, per shard (ring transport
    /// rounds it up to the next power of two).
    pub queue_depth: usize,
    /// Chunk routing policy. [`Routing::Keyed`] hash-partitions items
    /// to shards, making shard summaries key-disjoint and the merged
    /// error bound max-per-shard instead of additive.
    /// [`Routing::KeyedAdaptive`] adds the hot-key tier: detected
    /// heavy keys are split round-robin across all shards and counted
    /// exactly in side tables, keeping the same bound under adversarial
    /// skew (see the module docs).
    pub routing: Routing,
    /// Producer→shard transport ([`Transport::Ring`] by default;
    /// [`Transport::Mpsc`] is the benchmark baseline).
    pub transport: Transport,
    /// Per-shard summary structure ([`SummaryKind::BucketList`] by
    /// default; [`SummaryKind::Compact`] is the cache-conscious SoA
    /// core, [`SummaryKind::Heap`] the `O(log k)` baseline). Every
    /// choice honors the same `f ≤ f̂ ≤ f + n/k` guarantee — only the
    /// per-update cost differs (`bench_summary_core`).
    pub structure: SummaryKind,
    /// Per-shard epoch snapshot cadence, in items: a shard republishes
    /// its summary after processing this many items since its last
    /// publication. 0 disables count-triggered publication (snapshots
    /// then only happen on [`QueryEngine::refresh`] and at drain).
    pub epoch_items: u64,
    /// Route chunks through the batched ingest fast path (default on):
    /// each shard pre-aggregates a chunk into `(item, weight)` runs
    /// with a reusable [`ChunkAggregator`] and applies one weighted
    /// Space Saving update per *distinct* item instead of one per
    /// occurrence. Identical error guarantees (`f ≤ f̂ ≤ f + n/k`,
    /// full recall above `n/k`) — individual estimates may differ
    /// within those bounds from per-item ingestion. Turn off to
    /// reproduce exact per-item update sequences.
    pub batch_ingest: bool,
    /// Sliding-window read path: ring capacity, in epoch *deltas*
    /// retained per shard. When > 0 every epoch publication also cuts a
    /// delta summary — the Space Saving state of just that epoch's
    /// items — into the shard's bounded [`WindowStore`] ring, and
    /// [`Coordinator::windows`] hands out a [`WindowedQueryEngine`]
    /// serving `top_k_window` / `point_in_window` / `k_majority_window`
    /// under the windowed bound `f ≤ f̂ ≤ f + W/k` (`W` = window mass).
    /// 0 (the default) disables delta publication entirely: zero
    /// write-path overhead, windowed queries unavailable.
    pub delta_ring: usize,
    /// Default windowed-query width, in epochs, for the engine handed
    /// back by [`Coordinator::spawn`] (only meaningful with
    /// `delta_ring > 0`; explicit widths can always be passed per
    /// query).
    pub window_epochs: usize,
    /// Epoch-versioned snapshot caching on the read path (default on):
    /// between publications concurrent readers share one merged view
    /// (`Arc` clone + relaxed version check) instead of each re-running
    /// the combine tree. Answers are bit-identical either way — the
    /// cache only dedups merges over identical inputs. Turn off to
    /// benchmark the uncached baseline
    /// ([`QueryEngine::without_cache`]).
    pub snapshot_cache: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            shards: 4,
            k: 2000,
            k_majority: 2000,
            queue_depth: 8,
            routing: Routing::RoundRobin,
            transport: Transport::Ring,
            structure: SummaryKind::BucketList,
            epoch_items: 65_536,
            batch_ingest: true,
            delta_ring: 0,
            window_epochs: 8,
            snapshot_cache: true,
        }
    }
}

/// Ingestion statistics.
#[derive(Debug, Clone, Default)]
pub struct IngestStats {
    /// Caller chunks fully accepted. A keyed chunk counts once even
    /// though it scatters into per-shard sub-chunks; a keyed
    /// `try_push` that is only *partially* accepted does not count —
    /// the re-offered remainder's fully-accepting push does (so a
    /// retried chunk still counts exactly once). Partial item mass is
    /// always reflected in [`IngestStats::items`].
    pub chunks: u64,
    /// Items accepted.
    pub items: u64,
    /// Producer stalls on a full shard queue (blocking `push`; counted
    /// once per stalled chunk).
    pub backpressure_events: u64,
    /// Failed ring-push attempts during blocking `push` (one per
    /// backoff round while stalled; 0 on the mpsc baseline, which
    /// blocks inside the channel instead of retrying).
    pub transport_retries: u64,
    /// Chunk buffers reused from the recycling path (free rings +
    /// spare pool) by [`Coordinator::take_buffer`] and the keyed
    /// scatter, instead of freshly allocated.
    pub buffers_recycled: u64,
    /// Chunks rejected by the non-blocking `try_push`.
    pub rejected_chunks: u64,
    /// Epoch snapshots published by the shards (filled at `finish`).
    pub epochs_published: u64,
    /// Epoch deltas published into the window rings (filled at
    /// `finish`; 0 when [`CoordinatorConfig::delta_ring`] is 0). Their
    /// masses partition the accepted items exactly: every ingested item
    /// lands in exactly one delta.
    pub deltas_published: u64,
    /// Items processed per shard.
    pub per_shard_items: Vec<u64>,
    /// Keyed-adaptive only: items routed through the hot-key split
    /// tier ([`crate::util::spread_of`]) instead of their home shard.
    pub split_items: u64,
    /// Keyed-adaptive only: hot-set generations published (detection
    /// promotions, demotions and [`Coordinator::force_hot_set`] calls).
    pub hot_rebalances: u64,
}

/// Typed rejection from [`Coordinator::try_push`]: the chunk comes back
/// so the caller can retry, reroute or drop it deliberately.
///
/// Under [`Routing::Keyed`] a chunk scatters into per-shard sub-chunks
/// and may be *partially* accepted: the error then carries only the
/// unrouted remainder (re-pushing it is sound — items re-hash to the
/// same shards), with `shard` naming the first shard that rejected.
#[derive(Debug)]
pub enum PushError {
    /// The routed shard's queue was full.
    Full {
        /// Shard whose queue rejected the chunk.
        shard: usize,
        /// The rejected chunk, returned to the caller.
        chunk: Vec<u64>,
    },
    /// The routed shard's worker has terminated.
    Disconnected {
        /// Shard whose worker is gone.
        shard: usize,
        /// The rejected chunk, returned to the caller.
        chunk: Vec<u64>,
    },
}

impl PushError {
    /// Recover the rejected chunk.
    pub fn into_chunk(self) -> Vec<u64> {
        match self {
            PushError::Full { chunk, .. } | PushError::Disconnected { chunk, .. } => chunk,
        }
    }
}

impl std::fmt::Display for PushError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PushError::Full { shard, chunk } => {
                write!(f, "shard {shard} queue full ({} items returned)", chunk.len())
            }
            PushError::Disconnected { shard, chunk } => {
                write!(f, "shard {shard} worker gone ({} items returned)", chunk.len())
            }
        }
    }
}

impl std::error::Error for PushError {}

/// Final result of a coordinator session.
#[derive(Debug, Clone)]
pub struct QueryResult {
    /// Merged global summary (combine tree, or disjoint concatenation
    /// under keyed routing).
    pub summary: Summary,
    /// k-majority candidates (`f̂ > n/k_majority`), descending.
    pub frequent: Vec<Counter>,
    /// Ingestion statistics.
    pub stats: IngestStats,
}

/// Why a try-send failed (transport-agnostic).
enum SendFailure {
    Full,
    Disconnected,
}

/// Producer-side chunk sender, one per shard.
enum ChunkTx {
    Ring(spsc::Producer<Vec<u64>>),
    Mpsc(SyncSender<Vec<u64>>),
}

impl ChunkTx {
    fn try_send(&mut self, chunk: Vec<u64>) -> Result<(), (Vec<u64>, SendFailure)> {
        match self {
            ChunkTx::Ring(tx) => match tx.try_push(chunk) {
                Ok(()) => Ok(()),
                Err(TryPushError::Full(c)) => Err((c, SendFailure::Full)),
                Err(TryPushError::Closed(c)) => Err((c, SendFailure::Disconnected)),
            },
            ChunkTx::Mpsc(tx) => match tx.try_send(chunk) {
                Ok(()) => Ok(()),
                Err(TrySendError::Full(c)) => Err((c, SendFailure::Full)),
                Err(TrySendError::Disconnected(c)) => Err((c, SendFailure::Disconnected)),
            },
        }
    }
}

/// Worker-side chunk receiver.
enum ChunkRx {
    Ring(spsc::Consumer<Vec<u64>>),
    Mpsc(Receiver<Vec<u64>>),
}

/// Unified receive outcome across transports.
enum Recv {
    Chunk(Vec<u64>),
    Timeout,
    /// Producer gone *and* queue drained: time to finish.
    Closed,
}

impl ChunkRx {
    fn recv_timeout(&mut self, timeout: Duration) -> Recv {
        match self {
            ChunkRx::Ring(rx) => match rx.pop_timeout(timeout) {
                Ok(c) => Recv::Chunk(c),
                Err(PopTimeoutError::Timeout) => Recv::Timeout,
                Err(PopTimeoutError::Closed) => Recv::Closed,
            },
            ChunkRx::Mpsc(rx) => match rx.recv_timeout(timeout) {
                Ok(c) => Recv::Chunk(c),
                Err(RecvTimeoutError::Timeout) => Recv::Timeout,
                Err(RecvTimeoutError::Disconnected) => Recv::Closed,
            },
        }
    }
}

/// The producer's handles to one shard: the chunk sender and (ring
/// transport only) the consumer end of the shard's buffer free ring.
struct ShardLink {
    tx: ChunkTx,
    free: Option<spsc::Consumer<Vec<u64>>>,
}

/// What one shard worker hands back at drain.
struct ShardOutcome {
    /// The shard's final cumulative summary.
    summary: Summary,
    /// Items the shard processed.
    items: u64,
    /// Total mass of the deltas the shard published (must equal
    /// `items` when the delta ring is on — every item lands in exactly
    /// one delta; split-key mass is included via the deltas' `hot`
    /// partials).
    delta_mass: u64,
    /// Keyed-adaptive only: the shard's cumulative exact split-key
    /// counts (its side table at drain).
    hot: Vec<(u64, u64)>,
}

/// Producer-side hot-key detection state ([`Routing::KeyedAdaptive`]).
struct AdaptiveState {
    /// Detection sketch over the sampled scatter substream since the
    /// last rebalance.
    sketch: SpaceSaving,
    /// Items the sketch has absorbed (the share denominator).
    sampled: u64,
    /// Scatter tick driving the 1-in-[`HOT_SAMPLE_STRIDE`] sample.
    tick: u64,
    /// Items scattered since the last hot-set evaluation.
    since_eval: u64,
    /// Current hot set, sorted ascending, ≤ [`HOT_SET_CAP`] keys.
    hot: Vec<u64>,
    /// Hot-set generation stamped onto every scattered sub-chunk
    /// (index into the registry's append-only generation table).
    generation: u64,
    /// Round-robin split cursor ([`spread_of`]).
    cursor: u64,
}

impl AdaptiveState {
    fn new() -> Self {
        Self {
            sketch: SpaceSaving::new(HOT_SKETCH_K),
            sampled: 0,
            tick: 0,
            since_eval: 0,
            hot: Vec::new(),
            generation: 0,
            cursor: 0,
        }
    }
}

/// Fold one epoch's split-key counts into a cumulative side table
/// (both tables are tiny — at most the union of the hot sets seen).
fn fold_hot(cum: &mut Vec<(u64, u64)>, epoch: &[(u64, u64)]) {
    for &(item, w) in epoch {
        match cum.iter_mut().find(|e| e.0 == item) {
            Some(e) => e.1 += w,
            None => cum.push((item, w)),
        }
    }
}

/// Total mass of a split-key side table.
fn hot_mass(table: &[(u64, u64)]) -> u64 {
    table.iter().map(|&(_, w)| w).sum()
}

/// A running coordinator session.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    links: Vec<ShardLink>,
    handles: Vec<JoinHandle<ShardOutcome>>,
    router: Router,
    stats: IngestStats,
    engine: QueryEngine,
    /// Sliding-window query handle; `Some` iff `delta_ring > 0`.
    windows: Option<WindowedQueryEngine>,
    /// Recycled chunk buffers awaiting reuse (keyed scatter returns,
    /// rejected sub-chunks, caller chunks after scatter).
    spare: Vec<Vec<u64>>,
    /// Next shard whose free ring [`Coordinator::take_buffer`] polls.
    reclaim_next: usize,
    /// Keyed-routing scatter buffers, one per shard (empty between
    /// pushes).
    scatter: Vec<Vec<u64>>,
    /// Hot-key detection state; `Some` iff
    /// [`Routing::KeyedAdaptive`].
    adaptive: Option<AdaptiveState>,
}

impl Coordinator {
    /// Spawn the shard workers and return the session plus a live
    /// [`QueryEngine`] handle attached to its epoch registry. The
    /// engine (and any clone of it) keeps answering queries during
    /// ingestion and remains valid after [`Coordinator::finish`] —
    /// final drain snapshots stay published.
    pub fn spawn(cfg: CoordinatorConfig) -> (Self, QueryEngine) {
        assert!(cfg.shards >= 1 && cfg.queue_depth >= 1);
        let router = Router::new(cfg.routing, cfg.shards);
        let registry = EpochRegistry::new(cfg.shards, cfg.k);
        // Windowed read path: a bounded delta ring per shard, served by
        // a WindowedQueryEngine the coordinator hands out (the landmark
        // QueryEngine stays independent of the window layer).
        let store = (cfg.delta_ring > 0)
            .then(|| WindowStore::new(cfg.shards, cfg.delta_ring, cfg.k));
        // Keyed routing ⇒ per-shard summaries are key-disjoint: tell
        // both read paths before any worker publishes, so every merge
        // uses the concatenation path and the max-per-shard bound.
        if cfg.routing.is_disjoint() {
            registry.set_disjoint(true);
            if let Some(s) = store.as_ref() {
                s.set_disjoint(true);
            }
        }
        let windows = store.as_ref().map(|s| {
            let w = WindowedQueryEngine::new(s.clone(), cfg.window_epochs, cfg.k_majority);
            if cfg.snapshot_cache {
                w
            } else {
                w.without_cache()
            }
        });
        let engine = QueryEngine::new(registry.clone(), cfg.k_majority);
        let engine = if cfg.snapshot_cache {
            engine
        } else {
            engine.without_cache()
        };
        let mut links = Vec::with_capacity(cfg.shards);
        let mut handles = Vec::with_capacity(cfg.shards);
        for shard in 0..cfg.shards {
            let (tx, mut rx) = match cfg.transport {
                Transport::Ring => {
                    let (p, c) = spsc::ring::<Vec<u64>>(cfg.queue_depth);
                    (ChunkTx::Ring(p), ChunkRx::Ring(c))
                }
                Transport::Mpsc => {
                    let (p, c) = sync_channel::<Vec<u64>>(cfg.queue_depth);
                    (ChunkTx::Mpsc(p), ChunkRx::Mpsc(c))
                }
            };
            // The reverse free ring: consumed chunk buffers flow back
            // to the producer. Sized past the chunk ring so a burst of
            // consumed buffers never forces a drop while the producer
            // is slow to reclaim.
            let (mut free_tx, free_rx) = match cfg.transport {
                Transport::Ring => {
                    let (p, c) = spsc::ring::<Vec<u64>>(cfg.queue_depth + 2);
                    (Some(p), Some(c))
                }
                Transport::Mpsc => (None, None),
            };
            let k = cfg.k;
            let epoch_items = cfg.epoch_items;
            let batch_ingest = cfg.batch_ingest;
            let structure = cfg.structure;
            let adaptive = cfg.routing.is_adaptive();
            let loads = router.loads.clone();
            let registry = registry.clone();
            let window = store.clone();
            handles.push(std::thread::spawn(move || {
                // The configured Space Saving core (bucket list by
                // default, `compact` for the cache-conscious SoA hot
                // loop); one predictable enum-dispatch branch per call.
                let mut ss = structure.build(k);
                // Scratch for the batched fast path, reused across chunks
                // so the steady state allocates nothing.
                let mut scratch = batch_ingest.then(ChunkAggregator::new);
                // Window side: accumulate this epoch's exact (item,
                // weight) runs; cut into a delta at each publication.
                let mut delta = window.as_ref().map(|_| DeltaBuilder::new());
                let mut delta_mass = 0u64;
                let mut items = 0u64;
                let mut since_publish = 0u64;
                let mut refresh_seen = 0u64;
                // Keyed-adaptive side tables: split-key occurrences are
                // counted exactly here, never offered to `ss` — the
                // summary stays key-disjoint and its n excludes split
                // mass. `hot_cum` is the cumulative table published
                // with every landmark snapshot; `hot_epoch` holds just
                // the current epoch's counts for the window delta.
                let mut hot_cum: Vec<(u64, u64)> = Vec::new();
                let mut hot_epoch: Vec<(u64, u64)> = Vec::new();
                // Scratch for the non-split remainder of a sub-chunk.
                let mut normal: Vec<u64> = Vec::new();
                loop {
                    match rx.recv_timeout(IDLE_POLL) {
                        Recv::Chunk(mut chunk) => {
                            if adaptive {
                                // Sub-chunks carry the hot-set
                                // generation as their first element;
                                // classify against that *immutable*
                                // set, so a rebalance mid-flight can
                                // never disagree with the placement
                                // the producer already made.
                                let (gen, rest) =
                                    chunk.split_first().expect("stamped sub-chunk");
                                let hot_set = registry.hot_set(*gen);
                                normal.clear();
                                for &item in rest {
                                    if hot_set.contains(&item) {
                                        match hot_epoch.iter_mut().find(|e| e.0 == item) {
                                            Some(e) => e.1 += 1,
                                            None => hot_epoch.push((item, 1)),
                                        }
                                    } else {
                                        normal.push(item);
                                    }
                                }
                            }
                            let data: &[u64] = if adaptive { &normal } else { &chunk };
                            match scratch.as_mut() {
                                Some(agg) => {
                                    // Aggregate once, apply twice: the
                                    // runs feed the cumulative summary
                                    // and (one map probe per distinct
                                    // item) the pending delta.
                                    let runs = agg.aggregate(data);
                                    offer_runs(&mut ss, runs);
                                    if let Some(db) = delta.as_mut() {
                                        db.absorb_runs(runs);
                                    }
                                }
                                None => {
                                    ss.offer_all(data);
                                    if let Some(db) = delta.as_mut() {
                                        db.absorb_items(data);
                                    }
                                }
                            }
                            // The generation stamp is transport framing,
                            // not stream mass: every accounting path
                            // (items, loads, epoch cadence) sees the
                            // body length.
                            let len = chunk.len() - usize::from(adaptive);
                            items += len as u64;
                            since_publish += len as u64;
                            Router::drained(&loads, shard, len);
                            // Hand the emptied buffer back to the
                            // producer (ring transport); a full or
                            // abandoned free ring just drops it.
                            if let Some(free) = free_tx.as_mut() {
                                chunk.clear();
                                let _ = free.try_push(chunk);
                            }
                            let watermark = registry.refresh_watermark();
                            let due = epoch_items > 0 && since_publish >= epoch_items;
                            if due || watermark > refresh_seen {
                                // Delta first, cumulative snapshot second:
                                // a reader that observes the new landmark
                                // epoch (e.g. staleness reaching 0) is then
                                // guaranteed the matching window delta is
                                // already in the ring. Epoch split-key
                                // partials fold into the cumulative table
                                // and ride the window delta (a hot-only
                                // epoch still publishes — its delta is an
                                // empty summary plus exact partials).
                                fold_hot(&mut hot_cum, &hot_epoch);
                                if let (Some(db), Some(ws)) = (delta.as_mut(), window.as_ref()) {
                                    if !db.is_empty() || !hot_epoch.is_empty() {
                                        delta_mass += db.mass() + hot_mass(&hot_epoch);
                                        ws.publish_with_hot(
                                            shard,
                                            db.cut(k),
                                            false,
                                            std::mem::take(&mut hot_epoch),
                                        );
                                    }
                                }
                                hot_epoch.clear();
                                registry.publish_with_hot(
                                    shard,
                                    ss.freeze(),
                                    false,
                                    hot_cum.clone(),
                                );
                                since_publish = 0;
                                refresh_seen = watermark;
                            }
                        }
                        Recv::Timeout => {
                            // Idle: honor on-demand refresh requests so
                            // readers are not stuck behind a quiet shard.
                            let watermark = registry.refresh_watermark();
                            if watermark > refresh_seen {
                                fold_hot(&mut hot_cum, &hot_epoch);
                                if let (Some(db), Some(ws)) = (delta.as_mut(), window.as_ref()) {
                                    if !db.is_empty() || !hot_epoch.is_empty() {
                                        delta_mass += db.mass() + hot_mass(&hot_epoch);
                                        ws.publish_with_hot(
                                            shard,
                                            db.cut(k),
                                            false,
                                            std::mem::take(&mut hot_epoch),
                                        );
                                    }
                                }
                                hot_epoch.clear();
                                registry.publish_with_hot(
                                    shard,
                                    ss.freeze(),
                                    false,
                                    hot_cum.clone(),
                                );
                                since_publish = 0;
                                refresh_seen = watermark;
                            }
                        }
                        Recv::Closed => break,
                    }
                }
                // Drain: the final epoch covers everything this shard saw.
                // The last partial epoch must reach the window ring too —
                // before the final landmark snapshot, as above — or items
                // since the final cadence cut would be visible to landmark
                // queries but silently missing from windowed ones.
                let summary = ss.freeze();
                fold_hot(&mut hot_cum, &hot_epoch);
                if let (Some(db), Some(ws)) = (delta.as_mut(), window.as_ref()) {
                    if db.is_empty() && hot_epoch.is_empty() {
                        ws.finish_shard(shard);
                    } else {
                        delta_mass += db.mass() + hot_mass(&hot_epoch);
                        ws.publish_with_hot(
                            shard,
                            db.cut(k),
                            true,
                            std::mem::take(&mut hot_epoch),
                        );
                    }
                }
                registry.publish_with_hot(shard, summary.clone(), true, hot_cum.clone());
                ShardOutcome { summary, items, delta_mass, hot: hot_cum }
            }));
            links.push(ShardLink { tx, free: free_rx });
        }
        let coordinator = Self {
            stats: IngestStats { per_shard_items: vec![0; cfg.shards], ..Default::default() },
            scatter: (0..cfg.shards).map(|_| Vec::new()).collect(),
            adaptive: cfg.routing.is_adaptive().then(AdaptiveState::new),
            cfg,
            links,
            handles,
            router,
            engine: engine.clone(),
            windows,
            spare: Vec::new(),
            reclaim_next: 0,
        };
        (coordinator, engine)
    }

    /// Spawn without keeping the query handle (batch-style sessions).
    pub fn start(cfg: CoordinatorConfig) -> Self {
        Self::spawn(cfg).0
    }

    /// Configuration in use.
    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    /// A live query handle over this session's epoch snapshots (same
    /// registry as the handle returned by [`Coordinator::spawn`]).
    pub fn queries(&self) -> QueryEngine {
        self.engine.clone()
    }

    /// The sliding-window query handle, when this session publishes
    /// epoch deltas ([`CoordinatorConfig::delta_ring`] > 0). Cheap to
    /// clone; stays valid (serving the final drain-time deltas) after
    /// [`Coordinator::finish`].
    pub fn windows(&self) -> Option<WindowedQueryEngine> {
        self.windows.clone()
    }

    /// Ingestion statistics so far (`epochs_published` is finalized by
    /// [`Coordinator::finish`]).
    pub fn stats(&self) -> &IngestStats {
        &self.stats
    }

    /// A cleared chunk buffer recycled from the shard workers' free
    /// rings (or the spare pool), falling back to a fresh allocation
    /// when nothing is waiting. Fill it and hand it to
    /// [`Coordinator::push`]/[`Coordinator::try_push`]: with the ring
    /// transport, steady-state ingest then allocates nothing
    /// ([`run_source`] does exactly this).
    pub fn take_buffer(&mut self) -> Vec<u64> {
        if let Some(buf) = self.spare.pop() {
            self.stats.buffers_recycled += 1;
            return buf;
        }
        let shards = self.links.len();
        for i in 0..shards {
            let s = (self.reclaim_next + i) % shards;
            if let Some(free) = self.links[s].free.as_mut() {
                if let Ok(buf) = free.try_pop() {
                    self.reclaim_next = (s + 1) % shards;
                    self.stats.buffers_recycled += 1;
                    debug_assert!(buf.is_empty(), "free-ring buffers come back cleared");
                    return buf;
                }
            }
        }
        Vec::new()
    }

    /// Park a no-longer-needed buffer in the spare pool (bounded; the
    /// overflow is simply dropped).
    fn recycle(&mut self, mut buf: Vec<u64>) {
        if buf.capacity() > 0 && self.spare.len() < 2 * self.links.len() + 4 {
            buf.clear();
            self.spare.push(buf);
        }
    }

    fn account_items(&mut self, shard: usize, len: usize) {
        self.stats.items += len as u64;
        self.stats.per_shard_items[shard] += len as u64;
        self.engine.registry().add_items_routed(len as u64);
    }

    /// Blocking transport send: mpsc blocks in the channel; the ring
    /// spins-then-parks, counting retry rounds.
    fn send_blocking(&mut self, shard: usize, chunk: Vec<u64>) {
        match &mut self.links[shard].tx {
            ChunkTx::Mpsc(tx) => match tx.try_send(chunk) {
                Ok(()) => {}
                Err(TrySendError::Full(msg)) => {
                    self.stats.backpressure_events += 1;
                    // Block until the shard drains — backpressure, not drop.
                    tx.send(msg).expect("shard died");
                }
                Err(TrySendError::Disconnected(_)) => panic!("shard died"),
            },
            ChunkTx::Ring(tx) => {
                let mut pending = chunk;
                let mut backoff = Backoff::new();
                let mut stalled = false;
                loop {
                    match tx.try_push(pending) {
                        Ok(()) => break,
                        Err(TryPushError::Full(m)) => {
                            if !stalled {
                                self.stats.backpressure_events += 1;
                                stalled = true;
                            }
                            self.stats.transport_retries += 1;
                            pending = m;
                            backoff.snooze();
                        }
                        Err(TryPushError::Closed(_)) => panic!("shard died"),
                    }
                }
            }
        }
    }

    /// Scatter a chunk into the per-shard buffers by home shard. In
    /// adaptive mode every buffer is first stamped with the current
    /// hot-set generation, hot items are spread round-robin instead of
    /// going home, and a 1-in-[`HOT_SAMPLE_STRIDE`] sample feeds the
    /// detection sketch.
    fn scatter_chunk(&mut self, chunk: &[u64]) {
        let shards = self.links.len();
        if let Some(ad) = self.adaptive.as_mut() {
            for buf in &mut self.scatter {
                debug_assert!(buf.is_empty(), "scatter buffers cleared between pushes");
                buf.push(ad.generation);
            }
            for &item in chunk {
                let dest = if ad.hot.contains(&item) {
                    let d = spread_of(ad.cursor, shards);
                    ad.cursor += 1;
                    self.stats.split_items += 1;
                    d
                } else {
                    shard_of(item, shards)
                };
                self.scatter[dest].push(item);
                ad.tick += 1;
                if ad.tick % HOT_SAMPLE_STRIDE == 0 {
                    ad.sketch.offer(item);
                    ad.sampled += 1;
                }
            }
            ad.since_eval += chunk.len() as u64;
        } else {
            for &item in chunk {
                self.scatter[shard_of(item, shards)].push(item);
            }
        }
    }

    /// Body length of shard `shard`'s pending scatter buffer (the
    /// generation stamp is framing, not payload).
    fn scatter_body_len(&self, shard: usize) -> usize {
        self.scatter[shard]
            .len()
            .saturating_sub(usize::from(self.adaptive.is_some()))
    }

    /// Ingest one chunk. Blocks when the target shard's queue is full
    /// (counted as a backpressure event). Under keyed routing the chunk
    /// is hash-scattered and each non-empty sub-chunk pushed to its
    /// home shard (keyed-adaptive additionally spreads detected hot
    /// keys across all shards).
    pub fn push(&mut self, chunk: Vec<u64>) {
        if chunk.is_empty() {
            return;
        }
        if self.cfg.routing.is_keyed() {
            self.push_keyed(chunk);
            return;
        }
        let len = chunk.len();
        let shard = self.router.route(len);
        self.send_blocking(shard, chunk);
        self.stats.chunks += 1;
        self.account_items(shard, len);
    }

    fn push_keyed(&mut self, chunk: Vec<u64>) {
        self.scatter_chunk(&chunk);
        self.recycle(chunk);
        self.stats.chunks += 1;
        for shard in 0..self.links.len() {
            let len = self.scatter_body_len(shard);
            if len == 0 {
                // Nothing routed here; drop a bare generation stamp so
                // the next scatter starts from a clean buffer.
                self.scatter[shard].clear();
                continue;
            }
            let replacement = self.take_buffer();
            let sub = std::mem::replace(&mut self.scatter[shard], replacement);
            self.router.enqueued(shard, len);
            self.send_blocking(shard, sub);
            self.account_items(shard, len);
        }
        // Evaluate only after every sub-chunk of this push is
        // dispatched: they carry the pre-evaluation generation, and the
        // classification baked into their placement matches it.
        self.maybe_evaluate_hot_set();
    }

    /// Non-blocking ingest: route the chunk and enqueue it if the shard
    /// has room, otherwise hand it straight back as a typed
    /// [`PushError`] (counted in [`IngestStats::rejected_chunks`]).
    /// Load-shedding callers can drop the chunk; latency-tolerant ones
    /// retry or fall back to the blocking [`Coordinator::push`]. Keyed
    /// chunks may be partially accepted — see [`PushError`].
    pub fn try_push(&mut self, chunk: Vec<u64>) -> Result<(), PushError> {
        if chunk.is_empty() {
            return Ok(());
        }
        if self.cfg.routing.is_keyed() {
            return self.try_push_keyed(chunk);
        }
        let len = chunk.len();
        let shard = self.router.route(len);
        match self.links[shard].tx.try_send(chunk) {
            Ok(()) => {
                self.stats.chunks += 1;
                self.account_items(shard, len);
                Ok(())
            }
            Err((chunk, failure)) => {
                // Undo the router's load accounting for the queued-items
                // gauge; the chunk never reached the shard.
                Router::drained(&self.router.loads, shard, len);
                self.stats.rejected_chunks += 1;
                Err(match failure {
                    SendFailure::Full => PushError::Full { shard, chunk },
                    SendFailure::Disconnected => PushError::Disconnected { shard, chunk },
                })
            }
        }
    }

    fn try_push_keyed(&mut self, chunk: Vec<u64>) -> Result<(), PushError> {
        let adaptive = self.adaptive.is_some();
        self.scatter_chunk(&chunk);
        self.recycle(chunk);
        let mut rejected: Option<(usize, SendFailure, Vec<u64>)> = None;
        for shard in 0..self.links.len() {
            let len = self.scatter_body_len(shard);
            if len == 0 {
                self.scatter[shard].clear();
                continue;
            }
            let replacement = self.take_buffer();
            let sub = std::mem::replace(&mut self.scatter[shard], replacement);
            self.router.enqueued(shard, len);
            match self.links[shard].tx.try_send(sub) {
                Ok(()) => {
                    self.account_items(shard, len);
                }
                Err((mut sub, failure)) => {
                    Router::drained(&self.router.loads, shard, len);
                    // The remainder goes back to the caller as a plain
                    // chunk: strip the generation stamp (a re-offered
                    // chunk is re-scattered and re-stamped; order is
                    // irrelevant, counts are multisets).
                    rejected = match rejected.take() {
                        None => {
                            if adaptive {
                                sub.swap_remove(0);
                            }
                            Some((shard, failure, sub))
                        }
                        Some((first_shard, first_failure, mut remainder)) => {
                            remainder.extend_from_slice(&sub[usize::from(adaptive)..]);
                            self.recycle(sub);
                            Some((first_shard, first_failure, remainder))
                        }
                    };
                }
            }
        }
        if adaptive {
            self.maybe_evaluate_hot_set();
        }
        // A caller chunk counts once, on the attempt that accepts its
        // last item — a partially-accepted chunk whose remainder the
        // caller re-offers is counted by that later, fully-accepting
        // push, never twice.
        match rejected {
            None => {
                self.stats.chunks += 1;
                Ok(())
            }
            Some((shard, failure, chunk)) => {
                self.stats.rejected_chunks += 1;
                Err(match failure {
                    SendFailure::Full => PushError::Full { shard, chunk },
                    SendFailure::Disconnected => PushError::Disconnected { shard, chunk },
                })
            }
        }
    }

    /// Run a hot-set evaluation if the cadence ([`HOT_EVAL_ITEMS`]) is
    /// due.
    fn maybe_evaluate_hot_set(&mut self) {
        if self
            .adaptive
            .as_ref()
            .is_some_and(|ad| ad.since_eval >= HOT_EVAL_ITEMS)
        {
            self.evaluate_hot_set();
        }
    }

    /// Decide the next hot set from the detection sketch plus the top
    /// published counter of every shard (the "seeded from the shards'
    /// own snapshots" half: a key that saturated a shard *before* the
    /// producer's sketch window saw it still becomes a candidate), and
    /// install it if it differs from the current one.
    ///
    /// A key is promoted when its estimated share exceeds
    /// `1/(2·shards)` — the point where one key materially unbalances
    /// a hash partition — and an already-hot key is kept down to half
    /// that (hysteresis, so borderline keys don't flap each window).
    fn evaluate_hot_set(&mut self) {
        let shards = self.links.len();
        let (mut candidates, current) = {
            let Some(ad) = self.adaptive.as_mut() else { return };
            ad.since_eval = 0;
            let mut c: Vec<(u64, f64)> = Vec::new();
            if ad.sampled > 0 {
                for ctr in ad.sketch.freeze().top_k(2 * HOT_SET_CAP) {
                    c.push((ctr.item, ctr.count as f64 / ad.sampled as f64));
                }
            }
            (c, ad.hot.clone())
        };
        let parts = self.engine.registry().latest();
        let published: u64 = parts.iter().map(|p| p.summary.n() + p.hot_mass()).sum();
        if published > 0 {
            for p in &parts {
                if let Some(top) = p.summary.top_k(1).first() {
                    candidates.push((top.item, top.count as f64 / published as f64));
                }
            }
        }
        let hot_share = 1.0 / (2.0 * shards as f64);
        candidates
            .sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
        let mut next: Vec<u64> = Vec::new();
        for (item, share) in candidates {
            if next.len() >= HOT_SET_CAP {
                break;
            }
            if next.contains(&item) {
                continue;
            }
            let threshold =
                if current.contains(&item) { hot_share / 2.0 } else { hot_share };
            if share > threshold {
                next.push(item);
            }
        }
        next.sort_unstable();
        if next != current {
            self.install_hot_set(next);
        }
    }

    /// Publish `keys` as the next hot-set generation and reset the
    /// detection window (the sketch restarts so drifted distributions
    /// are re-measured from scratch).
    fn install_hot_set(&mut self, keys: Vec<u64>) -> u64 {
        let generation = self.engine.registry().publish_hot_set(keys.clone());
        let ad = self.adaptive.as_mut().expect("adaptive routing");
        ad.hot = keys;
        ad.generation = generation;
        ad.cursor = 0;
        ad.sketch = SpaceSaving::new(HOT_SKETCH_K);
        ad.sampled = 0;
        ad.since_eval = 0;
        self.stats.hot_rebalances += 1;
        generation
    }

    /// Force the hot set to exactly `keys` (sorted, deduplicated),
    /// bypassing detection — the deterministic handle the adversarial
    /// tests drive rebalances with. Returns the published generation.
    /// Subsequent pushes split these keys round-robin; detection keeps
    /// running and may still replace the set at the next due
    /// evaluation.
    ///
    /// # Panics
    ///
    /// If the session's routing is not [`Routing::KeyedAdaptive`].
    pub fn force_hot_set(&mut self, keys: Vec<u64>) -> u64 {
        assert!(
            self.cfg.routing.is_adaptive(),
            "force_hot_set requires keyed-adaptive routing"
        );
        let mut keys = keys;
        keys.sort_unstable();
        keys.dedup();
        assert!(keys.len() <= HOT_SET_CAP, "hot set capped at {HOT_SET_CAP} keys");
        self.install_hot_set(keys)
    }

    /// Current queued load per shard (items), for monitoring.
    pub fn queued(&self) -> Vec<u64> {
        self.router
            .loads
            .iter()
            .map(|l| l.load(Ordering::Relaxed))
            .collect()
    }

    /// Drain, merge and prune. The epoch registry (and every
    /// [`QueryEngine`] handle) survives with each shard's final
    /// snapshot published.
    pub fn finish(mut self) -> QueryResult {
        // Dropping the producer halves closes every ring / channel:
        // the workers drain what is buffered, publish their final
        // snapshots, and exit — the transports' close protocol *is*
        // the finish message. Fields are taken out so the `Drop` impl
        // (the abandoned-session path) sees empty vectors and no-ops.
        drop(std::mem::take(&mut self.links));
        let handles = std::mem::take(&mut self.handles);
        let mut summaries = Vec::with_capacity(handles.len());
        let mut stats = std::mem::take(&mut self.stats);
        // Keyed-adaptive: sum the shards' exact split-key side tables
        // (each shard's partial counts a disjoint sub-stream of the
        // split key, so the sum is exact).
        let mut hot_totals: std::collections::BTreeMap<u64, u64> =
            std::collections::BTreeMap::new();
        for (shard, h) in handles.into_iter().enumerate() {
            let out = h.join().expect("shard panicked");
            debug_assert_eq!(out.items, stats.per_shard_items[shard]);
            if self.windows.is_some() {
                // Delta accounting balance: the published deltas of a
                // shard partition exactly the items it ingested (the
                // drain path publishes the last partial epoch).
                debug_assert_eq!(
                    out.delta_mass, out.items,
                    "shard {shard}: delta mass must cover every ingested item"
                );
            }
            for (item, w) in out.hot {
                *hot_totals.entry(item).or_default() += w;
            }
            summaries.push(out.summary);
        }
        // Per-shard min counts, captured before the merge consumes the
        // summaries: the bound on a split key's evicted pre-split
        // history when recombination has to insert it fresh.
        let shard_mins: Vec<u64> = summaries.iter().map(Summary::min_count).collect();
        let mut summary = if self.cfg.routing.is_disjoint() {
            // Keyed routing: shard summaries are key-disjoint —
            // concatenate instead of cross-charging mins.
            let refs: Vec<&Summary> = summaries.iter().collect();
            merge_disjoint(&refs)
        } else {
            tree_reduce(summaries)
        };
        if !hot_totals.is_empty() {
            // Recombine split keys: home estimate + Σ exact partials.
            // Afterwards summary.n() covers the split mass again, so
            // the prune threshold below sees the whole stream.
            let extras: Vec<(u64, u64)> = hot_totals.into_iter().collect();
            summary = absorb_exact(&summary, &extras, |item| {
                shard_mins[shard_of(item, shard_mins.len())]
            });
        }
        let frequent = summary.prune(stats.items, self.cfg.k_majority);
        stats.epochs_published = self.engine.registry().epochs_published();
        stats.deltas_published = self
            .windows
            .as_ref()
            .map_or(0, |w| w.store().deltas_published());
        stats.per_shard_items.shrink_to_fit();
        QueryResult { summary, frequent, stats }
    }
}

impl Drop for Coordinator {
    /// Drop safety: a session abandoned without [`Coordinator::finish`]
    /// (an error path unwinding, a server tearing down a failed bind)
    /// must not leak parked shard workers. Closing the transports
    /// (dropping the producer halves) wakes every worker out of its
    /// park, lets it drain what is buffered and publish its final
    /// snapshot, and the join guarantees no thread outlives the
    /// session. After a normal `finish()` both vectors are already
    /// empty and this is a no-op.
    fn drop(&mut self) {
        drop(std::mem::take(&mut self.links));
        for h in self.handles.drain(..) {
            // A worker that panicked already tore its state down; the
            // drop path only guarantees termination, not results.
            let _ = h.join();
        }
    }
}

/// Convenience: stream an [`ItemSource`] through a coordinator in
/// `chunk_len`-item chunks, reusing recycled chunk buffers
/// ([`Coordinator::take_buffer`]) so ring-transport sessions are
/// allocation-free in the steady state.
pub fn run_source(
    cfg: CoordinatorConfig,
    source: &dyn ItemSource,
    chunk_len: usize,
) -> QueryResult {
    let mut c = Coordinator::start(cfg);
    let n = source.len();
    let mut pos = 0u64;
    while pos < n {
        let take = ((n - pos) as usize).min(chunk_len);
        let mut buf = c.take_buffer();
        buf.resize(take, 0);
        source.fill(pos, &mut buf);
        c.push(buf);
        pos += take as u64;
    }
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::Exact;
    use crate::gen::GeneratedSource;
    use crate::metrics::AccuracyReport;

    #[test]
    fn coordinator_matches_batch_guarantees() {
        let src = GeneratedSource::zipf(120_000, 4_000, 1.1, 33);
        // Per-item path: seed-exact behavior (the batched path has its
        // own guarantee test below).
        let cfg = CoordinatorConfig {
            shards: 4,
            k: 256,
            k_majority: 256,
            batch_ingest: false,
            ..Default::default()
        };
        let out = run_source(cfg, &src, 4096);
        assert_eq!(out.stats.items, 120_000);

        let mut exact = Exact::new();
        exact.offer_all(&src.slice(0, 120_000));
        let acc = AccuracyReport::evaluate(&out.frequent, &exact, 256);
        assert_eq!(acc.recall, 1.0);
        assert_eq!(acc.precision, 1.0);
    }

    #[test]
    fn round_robin_balances_items() {
        let src = GeneratedSource::uniform(100_000, 1000, 1);
        let cfg = CoordinatorConfig { shards: 5, k: 64, k_majority: 64, ..Default::default() };
        let out = run_source(cfg, &src, 1000);
        let min = *out.stats.per_shard_items.iter().min().unwrap();
        let max = *out.stats.per_shard_items.iter().max().unwrap();
        assert!(max - min <= 1000, "imbalance: {:?}", out.stats.per_shard_items);
    }

    #[test]
    fn least_loaded_routing_works() {
        let src = GeneratedSource::zipf(50_000, 500, 1.8, 2);
        let cfg = CoordinatorConfig {
            shards: 3,
            k: 64,
            k_majority: 64,
            routing: Routing::LeastLoaded,
            ..Default::default()
        };
        let out = run_source(cfg, &src, 2048);
        assert_eq!(out.stats.items, 50_000);
        assert!(out.frequent.iter().any(|c| c.item == 1));
    }

    #[test]
    fn backpressure_fires_with_tiny_queues() {
        let src = GeneratedSource::uniform(200_000, 100, 3);
        let cfg = CoordinatorConfig {
            shards: 1,
            k: 32,
            k_majority: 32,
            queue_depth: 1,
            ..Default::default()
        };
        let out = run_source(cfg, &src, 256);
        assert!(
            out.stats.backpressure_events > 0,
            "expected stalls with a depth-1 queue and 782 chunks"
        );
        // Ring transport: every stall spends at least one retry round.
        assert!(out.stats.transport_retries >= out.stats.backpressure_events);
        assert_eq!(out.stats.items, 200_000);
    }

    #[test]
    fn empty_chunks_ignored_and_empty_stream_ok() {
        let mut c = Coordinator::start(CoordinatorConfig::default());
        c.push(Vec::new());
        let out = c.finish();
        assert_eq!(out.stats.items, 0);
        assert!(out.frequent.is_empty());
    }

    #[test]
    fn incremental_push_api() {
        let mut c = Coordinator::start(CoordinatorConfig {
            shards: 2,
            k: 16,
            k_majority: 4,
            ..Default::default()
        });
        for _ in 0..100 {
            c.push(vec![7; 50]);
            c.push(vec![1, 2, 3, 4, 5]);
        }
        let out = c.finish();
        assert_eq!(out.stats.items, 100 * 55);
        assert_eq!(out.frequent.len(), 1);
        assert_eq!(out.frequent[0].item, 7);
    }

    #[test]
    fn spawn_returns_live_query_handle() {
        let (mut c, q) = Coordinator::spawn(CoordinatorConfig {
            shards: 2,
            k: 64,
            k_majority: 8,
            epoch_items: 100,
            ..Default::default()
        });
        for _ in 0..50 {
            c.push(vec![3; 40]);
        }
        // Epochs were published mid-ingest (cadence 100 items, 2000
        // items pushed): wait for at least one to land.
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while q.stats().items_published == 0 {
            assert!(std::time::Instant::now() < deadline, "no epoch published");
            std::thread::yield_now();
        }
        let snap = q.snapshot();
        assert!(snap.n() > 0);
        assert_eq!(snap.top_k(1)[0].item, 3);
        let out = c.finish();
        assert!(out.stats.epochs_published >= 2, "at least the drain epochs");
        // After finish the engine still answers, now with full coverage.
        let final_snap = q.snapshot();
        assert_eq!(final_snap.n(), 2000);
        assert_eq!(final_snap.point(3).estimate, 2000);
        assert!(final_snap.epochs().iter().all(|e| e.finished));
    }

    #[test]
    fn refresh_publishes_from_idle_shards() {
        let (mut c, q) = Coordinator::spawn(CoordinatorConfig {
            shards: 2,
            k: 16,
            k_majority: 4,
            epoch_items: 0, // no count-triggered publication
            ..Default::default()
        });
        c.push(vec![9; 30]);
        c.push(vec![9; 30]);
        q.refresh();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while q.stats().items_published < 60 {
            assert!(
                std::time::Instant::now() < deadline,
                "refresh did not reach idle shards: {:?}",
                q.stats()
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(q.point(9).estimate, 60);
        c.finish();
    }

    #[test]
    fn try_push_rejects_when_full_and_counts() {
        let (mut c, _q) = Coordinator::spawn(CoordinatorConfig {
            shards: 1,
            k: 16,
            k_majority: 4,
            queue_depth: 1,
            epoch_items: 0,
            ..Default::default()
        });
        let mut accepted = 0u64;
        let mut rejected = 0u64;
        let mut rejected_items = 0u64;
        for _ in 0..5_000 {
            match c.try_push(vec![1; 64]) {
                Ok(()) => accepted += 64,
                Err(e @ PushError::Full { .. }) => {
                    rejected += 1;
                    let chunk = e.into_chunk();
                    assert_eq!(chunk.len(), 64, "chunk comes back intact");
                    rejected_items += chunk.len() as u64;
                }
                Err(other) => panic!("unexpected error: {other}"),
            }
        }
        assert!(
            rejected > 0,
            "a depth-1 queue flooded with 5000 chunks must reject some"
        );
        assert_eq!(c.stats().rejected_chunks, rejected);
        let out = c.finish();
        assert_eq!(out.stats.items, accepted);
        assert_eq!(out.stats.items + rejected_items, 5_000 * 64);
        // Accepted mass is fully accounted by the shard summaries.
        assert_eq!(out.summary.n(), accepted);
    }

    #[test]
    fn batched_and_per_item_paths_account_identically() {
        // Same stream through both write paths: identical item/chunk
        // accounting, identical total mass, and both honor the
        // guarantee (recall 1 against exact truth).
        let src = GeneratedSource::zipf(80_000, 2_000, 1.3, 9);
        let mut exact = Exact::new();
        exact.offer_all(&src.slice(0, 80_000));
        for batch_ingest in [false, true] {
            let cfg = CoordinatorConfig {
                shards: 3,
                k: 128,
                k_majority: 128,
                batch_ingest,
                ..Default::default()
            };
            let out = run_source(cfg, &src, 4096);
            assert_eq!(out.stats.items, 80_000, "batch={batch_ingest}");
            assert_eq!(out.summary.n(), 80_000, "batch={batch_ingest}");
            let acc = AccuracyReport::evaluate(&out.frequent, &exact, 128);
            assert_eq!(acc.recall, 1.0, "batch={batch_ingest}");
        }
    }

    #[test]
    fn batched_ingest_single_heavy_item_is_exact() {
        // A chunk of one repeated item is the best case for the batch
        // path: one run, one weighted update, exact count.
        let (mut c, q) = Coordinator::spawn(CoordinatorConfig {
            shards: 2,
            k: 16,
            k_majority: 4,
            ..Default::default()
        });
        assert!(c.config().batch_ingest, "batched path is the default");
        for _ in 0..200 {
            c.push(vec![11; 64]);
        }
        let out = c.finish();
        assert_eq!(out.stats.items, 200 * 64);
        assert_eq!(q.point(11).estimate, 200 * 64);
        assert_eq!(q.point(11).guaranteed, 200 * 64);
    }

    #[test]
    fn delta_ring_default_off_and_balances_when_on() {
        // Off by default: no deltas, no window handle, write path
        // untouched.
        let (c, _q) = Coordinator::spawn(CoordinatorConfig::default());
        assert_eq!(c.config().delta_ring, 0);
        assert!(c.windows().is_none());
        let out = c.finish();
        assert_eq!(out.stats.deltas_published, 0);

        // On: every ingested item lands in exactly one delta, so the
        // window over the full ring covers the entire stream — including
        // the drain-time partial epoch.
        let (mut c, _q) = Coordinator::spawn(CoordinatorConfig {
            shards: 2,
            k: 32,
            k_majority: 8,
            epoch_items: 500,
            delta_ring: 64,
            window_epochs: 4,
            ..Default::default()
        });
        let w = c.windows().expect("delta ring on");
        // 43 chunks: both shards end on a partial epoch (130-item chunks
        // against a 500-item cadence), exercising the drain delta.
        for _ in 0..43 {
            c.push(vec![5; 130]);
        }
        let out = c.finish();
        assert_eq!(out.stats.items, 5_590);
        assert!(out.stats.deltas_published >= 2, "cadence + drain deltas");
        let snap = w.window(64);
        assert_eq!(snap.n(), 5_590, "full-ring window covers the whole stream");
        assert_eq!(snap.point(5).estimate, 5_590);
        assert!(snap.deltas().iter().any(|d| d.finished), "drain delta published");
        assert_eq!(
            out.stats.deltas_published,
            w.window_stats().deltas_published
        );
    }

    #[test]
    fn summary_structures_are_selectable_and_meet_guarantees() {
        let src = GeneratedSource::zipf(90_000, 2_500, 1.3, 11);
        let mut exact = Exact::new();
        exact.offer_all(&src.slice(0, 90_000));
        for structure in [SummaryKind::Heap, SummaryKind::BucketList, SummaryKind::Compact] {
            for batch_ingest in [false, true] {
                let out = run_source(
                    CoordinatorConfig {
                        shards: 3,
                        k: 128,
                        k_majority: 128,
                        structure,
                        batch_ingest,
                        ..Default::default()
                    },
                    &src,
                    4096,
                );
                assert_eq!(out.stats.items, 90_000, "{structure} batch={batch_ingest}");
                assert_eq!(out.summary.n(), 90_000, "{structure} batch={batch_ingest}");
                let acc = AccuracyReport::evaluate(&out.frequent, &exact, 128);
                assert_eq!(acc.recall, 1.0, "{structure} batch={batch_ingest}");
                for c in out.summary.counters() {
                    let f = exact.count(c.item);
                    assert!(c.count >= f, "{structure}: under-estimate of {}", c.item);
                    assert!(c.count - c.err <= f, "{structure}: err bound of {}", c.item);
                }
            }
        }
    }

    #[test]
    fn try_push_empty_is_ok() {
        let (mut c, _q) = Coordinator::spawn(CoordinatorConfig::default());
        assert!(c.try_push(Vec::new()).is_ok());
        let out = c.finish();
        assert_eq!(out.stats.items, 0);
        assert_eq!(out.stats.rejected_chunks, 0);
    }

    #[test]
    fn mpsc_baseline_matches_ring_accounting() {
        let src = GeneratedSource::zipf(60_000, 1_500, 1.2, 21);
        let mut exact = Exact::new();
        exact.offer_all(&src.slice(0, 60_000));
        for transport in [Transport::Ring, Transport::Mpsc] {
            let out = run_source(
                CoordinatorConfig {
                    shards: 3,
                    k: 128,
                    k_majority: 128,
                    transport,
                    ..Default::default()
                },
                &src,
                2048,
            );
            assert_eq!(out.stats.items, 60_000, "{transport}");
            assert_eq!(out.summary.n(), 60_000, "{transport}");
            let acc = AccuracyReport::evaluate(&out.frequent, &exact, 128);
            assert_eq!(acc.recall, 1.0, "{transport}");
            if transport == Transport::Mpsc {
                // The baseline neither retries nor recycles.
                assert_eq!(out.stats.transport_retries, 0);
                assert_eq!(out.stats.buffers_recycled, 0);
            }
        }
    }

    #[test]
    fn ring_transport_recycles_buffers() {
        let (mut c, _q) = Coordinator::spawn(CoordinatorConfig {
            shards: 1,
            k: 16,
            k_majority: 4,
            epoch_items: 0,
            ..Default::default()
        });
        for _ in 0..8 {
            let mut buf = c.take_buffer();
            buf.resize(100, 9);
            c.push(buf);
        }
        // The worker clears consumed buffers into the free ring; poll
        // until one comes back (capacity > 0 marks a real recycle).
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            let buf = c.take_buffer();
            if buf.capacity() > 0 {
                assert!(buf.is_empty(), "recycled buffers come back cleared");
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "no buffer recycled: {:?}",
                c.stats()
            );
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(c.stats().buffers_recycled > 0);
        let out = c.finish();
        assert_eq!(out.stats.items, 800);
        assert_eq!(out.summary.n(), 800);
    }

    #[test]
    fn keyed_routing_is_key_disjoint_end_to_end() {
        let src = GeneratedSource::zipf(120_000, 3_000, 1.2, 17);
        let (mut c, q) = Coordinator::spawn(CoordinatorConfig {
            shards: 4,
            k: 256,
            k_majority: 256,
            routing: Routing::Keyed,
            epoch_items: 10_000,
            ..Default::default()
        });
        let n = src.len();
        let mut pos = 0u64;
        while pos < n {
            let take = ((n - pos) as usize).min(4096);
            let mut buf = c.take_buffer();
            buf.resize(take, 0);
            src.fill(pos, &mut buf);
            c.push(buf);
            pos += take as u64;
        }
        let out = c.finish();
        assert_eq!(out.stats.items, 120_000);
        assert_eq!(out.summary.n(), 120_000);
        // Per-shard items follow the hash partition, not round-robin:
        // every shard saw something on this universe.
        assert!(out.stats.per_shard_items.iter().all(|&i| i > 0));

        // Final drain snapshots are pairwise key-disjoint, and every
        // monitored item lives on its home shard.
        let parts = q.registry().latest();
        let mut seen = std::collections::HashSet::new();
        for p in &parts {
            for ctr in p.summary.counters() {
                assert!(seen.insert(ctr.item), "item {} on two shards", ctr.item);
                assert_eq!(shard_of(ctr.item, 4), p.shard, "item off home shard");
            }
        }

        // The merged view reports the tighter max-per-shard bound and
        // still honors the guarantee against exact truth.
        let snap = q.snapshot();
        assert!(snap.is_disjoint());
        let eps_max = parts.iter().map(|p| p.summary.epsilon()).max().unwrap();
        assert_eq!(snap.epsilon(), eps_max);
        assert!(eps_max <= 120_000 / 256, "never looser than the summed bound");
        let mut exact = Exact::new();
        exact.offer_all(&src.slice(0, 120_000));
        let acc = AccuracyReport::evaluate(&out.frequent, &exact, 256);
        assert_eq!(acc.recall, 1.0);
        for ctr in snap.summary().counters() {
            let f = exact.count(ctr.item);
            assert!(ctr.count >= f, "under-estimate");
            assert!(ctr.count - f <= eps_max, "max-per-shard bound broken");
        }
    }

    #[test]
    fn drop_without_finish_joins_workers_and_publishes_drain() {
        // Abandoning a session (server error paths) must close the
        // rings and join the shard workers — after `drop` returns, the
        // drain-time snapshots are deterministically visible because
        // the workers have already exited.
        let (mut c, q) = Coordinator::spawn(CoordinatorConfig {
            shards: 3,
            k: 32,
            k_majority: 8,
            epoch_items: 0,
            ..Default::default()
        });
        for _ in 0..20 {
            c.push(vec![5; 50]);
        }
        drop(c);
        // No polling: Drop joined the workers, so the final snapshots
        // are published and flagged finished.
        let snap = q.snapshot();
        assert_eq!(snap.n(), 1000);
        assert_eq!(snap.point(5).estimate, 1000);
        assert!(snap.epochs().iter().all(|e| e.finished), "drain snapshots published");

        // Same for a windowed session: the drain deltas land too.
        let (mut c, _q) = Coordinator::spawn(CoordinatorConfig {
            shards: 2,
            k: 16,
            k_majority: 4,
            epoch_items: 0,
            delta_ring: 8,
            ..Default::default()
        });
        let w = c.windows().expect("delta ring on");
        c.push(vec![3; 40]);
        drop(c);
        let win = w.window(8);
        assert_eq!(win.n(), 40);
        assert!(win.deltas().iter().any(|d| d.finished));
    }

    #[test]
    fn finish_after_restructure_still_noops_drop() {
        // finish() takes the links/handles out of self; the Drop that
        // follows must be a no-op (double-join or double-close would
        // hang or panic here).
        let mut c = Coordinator::start(CoordinatorConfig {
            shards: 2,
            k: 16,
            k_majority: 4,
            ..Default::default()
        });
        c.push(vec![1; 10]);
        let out = c.finish();
        assert_eq!(out.stats.items, 10);
    }

    #[test]
    fn keyed_try_push_accounts_partial_acceptance() {
        let (mut c, _q) = Coordinator::spawn(CoordinatorConfig {
            shards: 2,
            k: 32,
            k_majority: 8,
            queue_depth: 1,
            routing: Routing::Keyed,
            epoch_items: 0,
            ..Default::default()
        });
        let mut sent = 0u64;
        let mut returned = 0u64;
        for round in 0..3_000u64 {
            let chunk: Vec<u64> = (0..64).map(|j| round * 64 + j).collect();
            sent += 64;
            if let Err(e) = c.try_push(chunk) {
                let remainder = e.into_chunk();
                assert!(!remainder.is_empty());
                // Remainder items still hash to real shards.
                for &it in &remainder {
                    assert!(shard_of(it, 2) < 2);
                }
                returned += remainder.len() as u64;
            }
        }
        assert!(returned > 0, "depth-1 rings flooded must reject something");
        let out = c.finish();
        // Everything not returned was accepted and fully accounted.
        assert_eq!(out.stats.items, sent - returned);
        assert_eq!(out.summary.n(), sent - returned);
    }

    #[test]
    fn adaptive_cold_stream_matches_keyed() {
        // No key near the 1/(2·shards) share: the hot tier must stay
        // dormant and keyed-adaptive must behave exactly like keyed —
        // disjoint summaries, items on their home shards, full recall.
        let src = GeneratedSource::uniform(50_000, 5_000, 13);
        let (mut c, q) = Coordinator::spawn(CoordinatorConfig {
            shards: 4,
            k: 256,
            k_majority: 256,
            routing: Routing::KeyedAdaptive,
            ..Default::default()
        });
        let n = src.len();
        let mut pos = 0u64;
        while pos < n {
            let take = ((n - pos) as usize).min(4096);
            let mut buf = c.take_buffer();
            buf.resize(take, 0);
            src.fill(pos, &mut buf);
            c.push(buf);
            pos += take as u64;
        }
        let out = c.finish();
        assert_eq!(out.stats.items, 50_000);
        assert_eq!(out.stats.split_items, 0, "uniform stream has no hot keys");
        assert_eq!(out.stats.hot_rebalances, 0);
        assert_eq!(out.summary.n(), 50_000);
        let parts = q.registry().latest();
        let mut seen = std::collections::HashSet::new();
        for p in &parts {
            assert!(p.hot.is_empty(), "no split partials on a cold stream");
            for ctr in p.summary.counters() {
                assert!(seen.insert(ctr.item), "item {} on two shards", ctr.item);
                assert_eq!(shard_of(ctr.item, 4), p.shard, "item off home shard");
            }
        }
    }

    #[test]
    fn adaptive_force_hot_set_splits_and_recombines_exactly() {
        let (mut c, q) = Coordinator::spawn(CoordinatorConfig {
            shards: 4,
            k: 64,
            k_majority: 8,
            epoch_items: 0,
            routing: Routing::KeyedAdaptive,
            ..Default::default()
        });
        // Pre-split history: 100 occurrences of key 7 reach its home
        // shard's Space Saving structure, filler 0..20 goes home too.
        let mut pre: Vec<u64> = vec![7; 100];
        pre.extend(0..20u64);
        c.push(pre);
        let generation = c.force_hot_set(vec![7]);
        assert_eq!(generation, 1, "first rebalance publishes generation 1");
        // Post-split: 400 occurrences spread round-robin from cursor 0
        // — exactly 100 per shard — counted exactly in side tables.
        let mut post: Vec<u64> = vec![7; 400];
        post.extend(20..40u64);
        c.push(post);
        let out = c.finish();
        assert_eq!(out.stats.items, 540);
        assert_eq!(out.stats.split_items, 400);
        assert_eq!(out.stats.hot_rebalances, 1);
        // Per-shard placement is fully deterministic: home-routed items
        // by shard_of, plus 100 split items everywhere.
        let mut expect = [0u64; 4];
        expect[shard_of(7, 4)] += 100;
        for item in 0..40u64 {
            expect[shard_of(item, 4)] += 1;
        }
        for e in &mut expect {
            *e += 100;
        }
        assert_eq!(out.stats.per_shard_items, expect);
        // k = 64 exceeds the distinct-item count, so every estimate is
        // exact — the split key recombines to its true frequency.
        assert_eq!(out.summary.n(), 540, "split mass folded back into n");
        assert_eq!(out.summary.estimate(7), Some(500));
        assert_eq!(out.frequent[0].item, 7);
        assert_eq!(out.frequent[0].count, 500);
        // The live read path agrees: home estimate + exact partials.
        let p = q.point(7);
        assert_eq!(p.estimate, 500);
        assert_eq!(p.guaranteed, 500);
        let snap = q.snapshot();
        assert_eq!(snap.n(), 540);
        assert_eq!(snap.summary().estimate(7), Some(500));
    }

    #[test]
    fn adaptive_detects_and_splits_single_hot_key() {
        // Adversarial single-hot-key workload: key H is 90% of the
        // stream. Detection must fire without any force_hot_set, split
        // mass must flow, and the recombined answer must keep the
        // guarantee.
        const H: u64 = 999_999;
        const N: usize = 200_000;
        let mut rng = crate::util::SplitMix64::new(4242);
        let (mut c, _q) = Coordinator::spawn(CoordinatorConfig {
            shards: 4,
            k: 256,
            k_majority: 64,
            routing: Routing::KeyedAdaptive,
            ..Default::default()
        });
        let mut true_h = 0u64;
        let mut pushed = 0usize;
        while pushed < N {
            let take = 4096.min(N - pushed);
            let mut buf = c.take_buffer();
            for _ in 0..take {
                if rng.next_f64() < 0.9 {
                    buf.push(H);
                    true_h += 1;
                } else {
                    buf.push(rng.next_below(10_000));
                }
            }
            c.push(buf);
            pushed += take;
        }
        let out = c.finish();
        assert_eq!(out.stats.items, N as u64);
        assert!(out.stats.hot_rebalances >= 1, "detection never fired");
        assert!(out.stats.split_items > 0, "hot key never split");
        // The split tier must have unloaded H's home shard: nobody
        // carries the ~90% share a plain keyed partition would pin
        // on one shard.
        let max = *out.stats.per_shard_items.iter().max().unwrap();
        assert!(
            max < (N as u64) * 6 / 10,
            "home shard still overloaded: {:?}",
            out.stats.per_shard_items
        );
        // Guarantee intact through detection + split + recombination.
        let est = out.summary.estimate(H).expect("hot key monitored");
        assert!(est >= true_h, "under-estimate");
        let eps = (out.stats.items / 256) as u64; // loosest per-shard bound
        assert!(est - true_h <= eps, "over-estimate past ε");
        assert_eq!(out.frequent[0].item, H);
    }

    #[test]
    fn adaptive_window_covers_split_mass() {
        let (mut c, _q) = Coordinator::spawn(CoordinatorConfig {
            shards: 2,
            k: 32,
            k_majority: 8,
            epoch_items: 0,
            delta_ring: 8,
            routing: Routing::KeyedAdaptive,
            ..Default::default()
        });
        let w = c.windows().expect("delta ring on");
        c.push(vec![5; 50]);
        c.force_hot_set(vec![5]);
        c.push(vec![5; 200]); // split 100 / 100
        let out = c.finish();
        assert_eq!(out.stats.items, 250);
        assert_eq!(out.stats.split_items, 200);
        // The windowed read path folds the deltas' exact partials: the
        // full-ring window covers the whole stream, split mass included.
        let snap = w.window(8);
        assert_eq!(snap.n(), 250, "window covers split mass");
        assert_eq!(snap.point(5).estimate, 250);
        assert_eq!(out.summary.estimate(5), Some(250));
    }
}
