//! The streaming coordinator: sharded ingestion with bounded queues
//! (backpressure), per-shard Space Saving, and a final combine-tree
//! merge — Parallel Space Saving as a long-running service rather than
//! a one-shot batch job.
//!
//! Topology:
//!
//! ```text
//!  push(chunk) ─▶ router ─▶ [bounded queue]─▶ shard 0: SpaceSaving
//!                        ─▶ [bounded queue]─▶ shard 1: SpaceSaving
//!                        ─▶      ...      ─▶ shard s: SpaceSaving
//!  finish() ──────────────── join ─▶ tree_reduce(combine) ─▶ prune
//! ```
//!
//! Queues are `std::sync::mpsc::sync_channel`s of `queue_depth` chunks;
//! a full queue blocks the producer (backpressure), and every such stall
//! is counted in [`IngestStats::backpressure_events`].

use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::thread::JoinHandle;

use crate::gen::ItemSource;
use crate::parallel::reduction::tree_reduce;
use crate::summary::{Counter, FrequencySummary, StreamSummary, Summary};

use super::router::{Router, Routing};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Shard workers (each owns one Space Saving instance).
    pub shards: usize,
    /// Counters per shard summary.
    pub k: usize,
    /// k-majority parameter for the final prune.
    pub k_majority: u64,
    /// Bounded queue depth, in chunks, per shard.
    pub queue_depth: usize,
    /// Chunk routing policy.
    pub routing: Routing,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            shards: 4,
            k: 2000,
            k_majority: 2000,
            queue_depth: 8,
            routing: Routing::RoundRobin,
        }
    }
}

/// Ingestion statistics.
#[derive(Debug, Clone, Default)]
pub struct IngestStats {
    /// Chunks accepted.
    pub chunks: u64,
    /// Items accepted.
    pub items: u64,
    /// Producer stalls on a full shard queue.
    pub backpressure_events: u64,
    /// Items processed per shard.
    pub per_shard_items: Vec<u64>,
}

/// Final result of a coordinator session.
#[derive(Debug, Clone)]
pub struct QueryResult {
    /// Merged global summary.
    pub summary: Summary,
    /// k-majority candidates (`f̂ > n/k_majority`), descending.
    pub frequent: Vec<Counter>,
    /// Ingestion statistics.
    pub stats: IngestStats,
}

enum Msg {
    Chunk(Vec<u64>),
    Finish,
}

/// A running coordinator session.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    senders: Vec<SyncSender<Msg>>,
    handles: Vec<JoinHandle<(Summary, u64)>>,
    router: Router,
    stats: IngestStats,
}

impl Coordinator {
    /// Spawn the shard workers.
    pub fn start(cfg: CoordinatorConfig) -> Self {
        assert!(cfg.shards >= 1 && cfg.queue_depth >= 1);
        let router = Router::new(cfg.routing, cfg.shards);
        let mut senders = Vec::with_capacity(cfg.shards);
        let mut handles = Vec::with_capacity(cfg.shards);
        for shard in 0..cfg.shards {
            let (tx, rx) = sync_channel::<Msg>(cfg.queue_depth);
            let k = cfg.k;
            let loads = router.loads.clone();
            handles.push(std::thread::spawn(move || {
                // Bucket-list Space Saving: O(1) amortized and ~30% faster
                // on the eviction-heavy paths (see EXPERIMENTS.md §Perf).
                let mut ss = StreamSummary::new(k);
                let mut items = 0u64;
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Chunk(chunk) => {
                            ss.offer_all(&chunk);
                            items += chunk.len() as u64;
                            Router::drained(&loads, shard, chunk.len());
                        }
                        Msg::Finish => break,
                    }
                }
                (ss.freeze(), items)
            }));
            senders.push(tx);
        }
        Self {
            stats: IngestStats { per_shard_items: vec![0; cfg.shards], ..Default::default() },
            cfg,
            senders,
            handles,
            router,
        }
    }

    /// Configuration in use.
    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    /// Ingest one chunk. Blocks when the target shard's queue is full
    /// (counted as a backpressure event).
    pub fn push(&mut self, chunk: Vec<u64>) {
        if chunk.is_empty() {
            return;
        }
        let shard = self.router.route(chunk.len());
        self.stats.chunks += 1;
        self.stats.items += chunk.len() as u64;
        self.stats.per_shard_items[shard] += chunk.len() as u64;
        match self.senders[shard].try_send(Msg::Chunk(chunk)) {
            Ok(()) => {}
            Err(TrySendError::Full(msg)) => {
                self.stats.backpressure_events += 1;
                // Block until the shard drains — backpressure, not drop.
                self.senders[shard].send(msg).expect("shard died");
            }
            Err(TrySendError::Disconnected(_)) => panic!("shard died"),
        }
    }

    /// Current queued load per shard (items), for monitoring.
    pub fn queued(&self) -> Vec<u64> {
        self.router
            .loads
            .iter()
            .map(|l| l.load(Ordering::Relaxed))
            .collect()
    }

    /// Drain, merge and prune.
    pub fn finish(self) -> QueryResult {
        for tx in &self.senders {
            let _ = tx.send(Msg::Finish);
        }
        drop(self.senders);
        let mut summaries = Vec::with_capacity(self.handles.len());
        let mut stats = self.stats;
        for (shard, h) in self.handles.into_iter().enumerate() {
            let (summary, items) = h.join().expect("shard panicked");
            debug_assert_eq!(items, stats.per_shard_items[shard]);
            summaries.push(summary);
        }
        let summary = tree_reduce(summaries);
        let frequent = summary.prune(stats.items, self.cfg.k_majority);
        stats.per_shard_items.shrink_to_fit();
        QueryResult { summary, frequent, stats }
    }
}

/// Convenience: stream an [`ItemSource`] through a coordinator in
/// `chunk_len`-item chunks.
pub fn run_source(
    cfg: CoordinatorConfig,
    source: &dyn ItemSource,
    chunk_len: usize,
) -> QueryResult {
    let mut c = Coordinator::start(cfg);
    let n = source.len();
    let mut pos = 0u64;
    while pos < n {
        let take = ((n - pos) as usize).min(chunk_len);
        c.push(source.slice(pos, pos + take as u64));
        pos += take as u64;
    }
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::Exact;
    use crate::gen::GeneratedSource;
    use crate::metrics::AccuracyReport;

    #[test]
    fn coordinator_matches_batch_guarantees() {
        let src = GeneratedSource::zipf(120_000, 4_000, 1.1, 33);
        let cfg = CoordinatorConfig { shards: 4, k: 256, k_majority: 256, ..Default::default() };
        let out = run_source(cfg, &src, 4096);
        assert_eq!(out.stats.items, 120_000);

        let mut exact = Exact::new();
        exact.offer_all(&src.slice(0, 120_000));
        let acc = AccuracyReport::evaluate(&out.frequent, &exact, 256);
        assert_eq!(acc.recall, 1.0);
        assert_eq!(acc.precision, 1.0);
    }

    #[test]
    fn round_robin_balances_items() {
        let src = GeneratedSource::uniform(100_000, 1000, 1);
        let cfg = CoordinatorConfig { shards: 5, k: 64, k_majority: 64, ..Default::default() };
        let out = run_source(cfg, &src, 1000);
        let min = *out.stats.per_shard_items.iter().min().unwrap();
        let max = *out.stats.per_shard_items.iter().max().unwrap();
        assert!(max - min <= 1000, "imbalance: {:?}", out.stats.per_shard_items);
    }

    #[test]
    fn least_loaded_routing_works() {
        let src = GeneratedSource::zipf(50_000, 500, 1.8, 2);
        let cfg = CoordinatorConfig {
            shards: 3,
            k: 64,
            k_majority: 64,
            routing: Routing::LeastLoaded,
            ..Default::default()
        };
        let out = run_source(cfg, &src, 2048);
        assert_eq!(out.stats.items, 50_000);
        assert!(out.frequent.iter().any(|c| c.item == 1));
    }

    #[test]
    fn backpressure_fires_with_tiny_queues() {
        let src = GeneratedSource::uniform(200_000, 100, 3);
        let cfg = CoordinatorConfig {
            shards: 1,
            k: 32,
            k_majority: 32,
            queue_depth: 1,
            ..Default::default()
        };
        let out = run_source(cfg, &src, 256);
        assert!(
            out.stats.backpressure_events > 0,
            "expected stalls with a depth-1 queue and 782 chunks"
        );
        assert_eq!(out.stats.items, 200_000);
    }

    #[test]
    fn empty_chunks_ignored_and_empty_stream_ok() {
        let mut c = Coordinator::start(CoordinatorConfig::default());
        c.push(Vec::new());
        let out = c.finish();
        assert_eq!(out.stats.items, 0);
        assert!(out.frequent.is_empty());
    }

    #[test]
    fn incremental_push_api() {
        let mut c = Coordinator::start(CoordinatorConfig {
            shards: 2,
            k: 16,
            k_majority: 4,
            ..Default::default()
        });
        for _ in 0..100 {
            c.push(vec![7; 50]);
            c.push(vec![1, 2, 3, 4, 5]);
        }
        let out = c.finish();
        assert_eq!(out.stats.items, 100 * 55);
        assert_eq!(out.frequent.len(), 1);
        assert_eq!(out.frequent[0].item, 7);
    }
}
