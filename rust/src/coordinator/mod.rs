//! The L3 streaming coordinator — Parallel Space Saving as a service.
//!
//! The paper's Algorithm 1 is a one-shot batch job; production stream
//! mining runs continuously. This module wraps the same machinery
//! (block-partitioned sequential Space Saving + combine-tree reduction)
//! in a sharded, backpressured ingestion service:
//!
//! * [`router`] — chunk routing (round-robin / least-loaded).
//! * [`service`] — shard workers over bounded queues, `push`/`finish`
//!   API, ingestion statistics.
//!
//! The offline verification pass (PJRT `verify_counts` artifact, see
//! [`crate::runtime`]) plugs in after `finish()` to discard false
//! positives when the stream is replayable.

pub mod profiler;
pub mod router;
pub mod service;

pub use profiler::{ChunkProfile, SkewProfiler, StreamProfile};
pub use router::{Router, Routing};
pub use service::{run_source, Coordinator, CoordinatorConfig, IngestStats, QueryResult};
