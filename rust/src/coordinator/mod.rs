//! The L3 streaming coordinator — Parallel Space Saving as a service.
//!
//! The paper's Algorithm 1 is a one-shot batch job; production stream
//! mining runs continuously. This module wraps the same machinery
//! (block-partitioned sequential Space Saving + combine-tree reduction)
//! in a sharded, backpressured ingestion service:
//!
//! * [`router`] — chunk routing (round-robin / least-loaded / keyed
//!   hash-partition; keyed shards are key-disjoint and merge under the
//!   tighter max-per-shard bound).
//! * [`service`] — shard workers over bounded lock-free SPSC rings
//!   (with a reverse chunk-buffer free list; mpsc kept as the bench
//!   baseline), `push`/`try_push`/`finish` API, epoch snapshot
//!   publication, ingestion statistics.
//!
//! [`Coordinator::spawn`](service::Coordinator::spawn) additionally
//! returns a [`QueryEngine`](crate::query::QueryEngine) handle: shards
//! publish epoch snapshots (every
//! [`epoch_items`](service::CoordinatorConfig::epoch_items) items, on
//! demand, and at drain) that the engine merges to serve live `top_k` /
//! `point` / `threshold` queries without blocking ingestion. With
//! [`delta_ring`](service::CoordinatorConfig::delta_ring) > 0 each
//! publication also cuts a per-epoch delta into the sliding-window
//! rings (see [`crate::window`]), adding time-scoped `top_k_window` /
//! `k_majority_window` answers.
//!
//! The offline verification pass (PJRT `verify_counts` artifact, see
//! [`crate::runtime`]) plugs in after `finish()` to discard false
//! positives when the stream is replayable.

pub mod profiler;
pub mod router;
pub mod service;

pub use profiler::{ChunkProfile, SkewProfiler, StreamProfile};
pub use router::{shard_of, Router, Routing};
pub use service::{
    run_source, Coordinator, CoordinatorConfig, IngestStats, PushError, QueryResult, Transport,
};
