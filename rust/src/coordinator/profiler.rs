//! Stream skew profiling via the PJRT `skew_profile` artifact.
//!
//! The L1 `block_histogram` kernel (lowered into
//! `profile_16x65536x1024.hlo.txt`) buckets each 65 536-item chunk by a
//! Fibonacci hash. The coordinator uses the per-chunk bucket histograms
//! for two things:
//!
//! * a **skew estimate** (top-bucket share and normalized entropy) that
//!   tells the operator whether [`Routing::LeastLoaded`] is worth it and
//!   how large `k` should be relative to the head, and
//! * a CountMin-style **upper bound**: a bucket's total bounds the
//!   frequency of every item hashing into it, so chunks whose maximum
//!   bucket stays below the global threshold cannot contain a heavy
//!   candidate.
//!
//! [`Routing::LeastLoaded`]: super::router::Routing::LeastLoaded

use crate::runtime::{ArtifactKind, Runtime};
use crate::Result;

/// Profile of one stream chunk.
#[derive(Debug, Clone)]
pub struct ChunkProfile {
    /// Items in the chunk (excluding padding).
    pub items: u64,
    /// Largest bucket total — an upper bound on the most frequent item
    /// in the chunk.
    pub max_bucket: u64,
    /// Top-bucket share of the chunk (1/num_buckets ≈ uniform; →1 ≈
    /// single dominating item).
    pub top_share: f64,
    /// Normalized Shannon entropy of the bucket distribution (1 =
    /// uniform, 0 = degenerate).
    pub entropy: f64,
}

/// Aggregate profile over a whole stream.
#[derive(Debug, Clone)]
pub struct StreamProfile {
    /// Per-chunk profiles, in stream order.
    pub chunks: Vec<ChunkProfile>,
}

impl StreamProfile {
    /// Mean normalized entropy (the stream-level skew indicator).
    pub fn mean_entropy(&self) -> f64 {
        if self.chunks.is_empty() {
            return 1.0;
        }
        self.chunks.iter().map(|c| c.entropy).sum::<f64>() / self.chunks.len() as f64
    }

    /// Mean top-bucket share.
    pub fn mean_top_share(&self) -> f64 {
        if self.chunks.is_empty() {
            return 0.0;
        }
        self.chunks.iter().map(|c| c.top_share).sum::<f64>() / self.chunks.len() as f64
    }

    /// Chunks that *cannot* contain an item with frequency above
    /// `threshold` (their max bucket stays below it) — candidates for
    /// cheap skipping in the offline verification pass.
    pub fn skippable(&self, threshold: u64) -> usize {
        self.chunks.iter().filter(|c| c.max_bucket <= threshold).count()
    }
}

/// Profiler over the AOT `skew_profile` program.
pub struct SkewProfiler {
    rt: Runtime,
    entry: String,
    chunks_per_call: usize,
    chunk_len: usize,
    num_buckets: usize,
    stream_pad: i32,
}

impl SkewProfiler {
    /// Open against an artifact directory.
    pub fn new(dir: &std::path::Path) -> Result<Self> {
        let rt = Runtime::new(dir)?;
        let entry = rt
            .manifest()
            .entries
            .iter()
            .find(|e| e.kind == ArtifactKind::Profile)
            .ok_or_else(|| anyhow::anyhow!("no profile artifact (run `make artifacts`)"))?
            .clone();
        let stream_pad = rt.manifest().stream_pad;
        Ok(Self {
            rt,
            entry: entry.name.clone(),
            chunks_per_call: entry.chunks,
            chunk_len: entry.chunk_len,
            num_buckets: entry.num_buckets,
            stream_pad,
        })
    }

    /// Profile a stream of item ids.
    pub fn profile(&mut self, items: &[u64]) -> Result<StreamProfile> {
        let enc = crate::runtime::verifier::encode::items_to_i32(items)?;
        let call_len = self.chunks_per_call * self.chunk_len;
        let mut chunks = Vec::new();
        let mut pos = 0usize;
        while pos < enc.len() {
            let take = (enc.len() - pos).min(call_len);
            let mut buf = enc[pos..pos + take].to_vec();
            buf.resize(call_len, self.stream_pad);
            let hist = self.rt.run_profile(&self.entry, &buf)?;
            // Only rows covering real items (padding inflates one bucket
            // — the pad sentinel hashes somewhere — so per-row item
            // counts come from the un-padded prefix length).
            let mut remaining = take;
            for row in 0..self.chunks_per_call {
                if remaining == 0 {
                    break;
                }
                let row_items = remaining.min(self.chunk_len);
                let h = &hist[row * self.num_buckets..(row + 1) * self.num_buckets];
                chunks.push(profile_row(h, row_items as u64, self.chunk_len as u64));
                remaining -= row_items;
            }
            pos += take;
        }
        Ok(StreamProfile { chunks })
    }
}

/// Build one [`ChunkProfile`] from a bucket histogram row.
///
/// When the row is padded (`items < row_len`), the pad sentinel's own
/// bucket is corrected by the pad count before computing statistics.
fn profile_row(hist: &[f32], items: u64, row_len: u64) -> ChunkProfile {
    let pad = (row_len - items) as f64;
    let mut totals: Vec<f64> = hist.iter().map(|&x| x as f64).collect();
    if pad > 0.0 {
        // All pad items share one bucket (identical sentinel): subtract
        // from the largest bucket that can hold them.
        if let Some(mx) = totals
            .iter_mut()
            .filter(|v| **v >= pad)
            .max_by(|a, b| a.partial_cmp(b).unwrap())
        {
            *mx -= pad;
        }
    }
    let n: f64 = totals.iter().sum();
    let max_bucket = totals.iter().copied().fold(0.0, f64::max);
    let (top_share, entropy) = if n > 0.0 {
        let mut h = 0.0;
        for &v in &totals {
            if v > 0.0 {
                let p = v / n;
                h -= p * p.ln();
            }
        }
        (max_bucket / n, h / (totals.len() as f64).ln())
    } else {
        (0.0, 1.0)
    };
    ChunkProfile { items, max_bucket: max_bucket as u64, top_share, entropy }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_row_uniformish() {
        let hist = vec![4.0f32; 256];
        let p = profile_row(&hist, 1024, 1024);
        assert!(p.entropy > 0.99);
        assert!((p.top_share - 4.0 / 1024.0).abs() < 1e-9);
        assert_eq!(p.max_bucket, 4);
    }

    #[test]
    fn profile_row_degenerate() {
        let mut hist = vec![0.0f32; 256];
        hist[7] = 1024.0;
        let p = profile_row(&hist, 1024, 1024);
        assert_eq!(p.entropy, 0.0);
        assert_eq!(p.top_share, 1.0);
    }

    #[test]
    fn profile_row_pad_correction() {
        // 512 real items uniform + 512 pad items stacked on one bucket.
        let mut hist = vec![2.0f32; 256];
        hist[0] += 512.0;
        let p = profile_row(&hist, 512, 1024);
        assert_eq!(p.max_bucket, 2);
        assert!(p.entropy > 0.99);
    }

    #[test]
    fn stream_profile_aggregates() {
        let sp = StreamProfile {
            chunks: vec![
                ChunkProfile { items: 10, max_bucket: 100, top_share: 0.9, entropy: 0.2 },
                ChunkProfile { items: 10, max_bucket: 3, top_share: 0.1, entropy: 0.8 },
            ],
        };
        assert!((sp.mean_entropy() - 0.5).abs() < 1e-12);
        assert_eq!(sp.skippable(50), 1);
    }
}
