// Clippy (CI runs `clippy --all-targets -D warnings`): the streaming
// hot loops index with a computed prefetch lookahead (`items.get(i +
// AHEAD)` next to `items[i]`), which reads better as a range loop.
#![allow(clippy::needless_range_loop)]

//! # pss — Parallel Space Saving
//!
//! A full reproduction of *Parallel Space Saving on Multi and Many-Core
//! Processors* (Cafaro, Pulimeno, Epicoco, Aloisio — Concurrency and
//! Computation: Practice and Experience, 2016) as a three-layer
//! Rust + JAX/Pallas stack.
//!
//! The crate is organized bottom-up:
//!
//! * [`util`] — fast hashing, open-addressing map, deterministic RNG.
//! * [`summary`] — the Space Saving stream summaries (heap, bucket
//!   list, and the SoA block-min `CompactSummary`), runtime structure
//!   selection, and the paper's `combine` merge operator (Algorithm 2).
//! * [`baselines`] — Frequent (Misra–Gries), Lossy Counting, CountMin,
//!   CountSketch, and an exact oracle, for the related-work comparisons.
//! * [`gen`] — zipf / zipf-Mandelbrot workload generators and the binary
//!   dataset format.
//! * [`parallel`] — the shared-memory ("OpenMP") parallel algorithm:
//!   block decomposition + user-defined tree reduction (Algorithm 1).
//! * [`distsim`] — a deterministic discrete-event cluster simulator
//!   (virtual clocks, α–β network, machine models) substituting for the
//!   paper's Galileo cluster; `mpisim` runs the pure-MPI version on it.
//! * [`hybrid`] — the MPI × OpenMP hybrid composition.
//! * [`mic`] — the Intel Phi (MIC) offload model.
//! * [`metrics`] — ARE / precision / recall / fractional overhead and
//!   paper-style table/figure reporting.
//! * [`runtime`] — PJRT client executing the AOT artifacts (offline
//!   candidate verification; python is never on the streaming path).
//! * [`coordinator`] — the streaming orchestrator service: sharding,
//!   backpressure, chunk batching, end-to-end queries.
//! * [`query`] — the live read path: shards publish epoch snapshots
//!   behind atomically-swapped `Arc`s; the [`query::QueryEngine`]
//!   merges them with the combine tree and serves `top_k` / `point` /
//!   `threshold` / `stats` concurrently with ingestion.
//! * [`serve`] — the network-facing service layer: a length-prefixed
//!   binary wire protocol, a TCP/Unix-socket server where one ingest
//!   connection = one producer feeding the recycled chunk buffers, a
//!   query reader pool over the epoch snapshots, and the `pss loadgen`
//!   multi-client load generator.
//! * [`cluster`] — multi-process hierarchical aggregation (the hybrid
//!   decomposition running for real): a head process partitions the
//!   stream across P worker processes (each a full serve-layer
//!   server), pulls their summary snapshots over protocol-v2 worker
//!   frames, and merges them — `merge_disjoint` under keyed routing,
//!   a recursive-halving combine tree under block routing — into a
//!   cluster-scope [`cluster::ClusterView`].
//! * [`window`] — the sliding-window read path: shards additionally
//!   publish per-epoch *delta* summaries into bounded rings; the
//!   [`window::WindowedQueryEngine`] merges the last `w` deltas and
//!   serves time-scoped `top_k_window` / `point_in_window` /
//!   `k_majority_window` under the windowed bound `f ≤ f̂ ≤ f + W/k`.
//! * [`config`] — TOML experiment configuration and paper presets.
//! * [`bench_harness`] — one driver per paper table/figure.

pub mod baselines;
pub mod bench_harness;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod distsim;
pub mod gen;
pub mod hybrid;
pub mod metrics;
pub mod mic;
pub mod parallel;
pub mod query;
pub mod runtime;
pub mod serve;
pub mod summary;
pub mod util;
pub mod window;

pub use summary::{
    CompactSummary, Counter, FrequencySummary, SpaceSaving, StreamSummary, SummaryKind,
};

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
