//! Cross-process merge-latency prediction — the calibrated cost model
//! repurposed for the *real* cluster (`rust/src/cluster`).
//!
//! The simulator charges a recursive-halving reduction
//! `⌈log₂P⌉ · (α + bytes/β + combine)` and a flat gather
//! `(P−1) · (α + bytes/β + combine)`; the cluster bench
//! (`pss bench --suite cluster`) measures both strategies on real
//! snapshots and reports measured-vs-predicted side by side — the
//! paper's Figure 4 comparison, with the model as the yardstick
//! instead of a second cluster.

use super::machine::MachineModel;
use super::network::NetworkModel;

/// Predicted latency split for one merge strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MergePrediction {
    /// Time spent moving summaries (α–β model).
    pub transfer_s: f64,
    /// Time spent in `combine` calls on the critical path.
    pub combine_s: f64,
}

impl MergePrediction {
    /// Total predicted wall time.
    pub fn total_s(&self) -> f64 {
        self.transfer_s + self.combine_s
    }
}

/// Wire size of one k-counter summary snapshot (the serve-protocol
/// `SummarySnapshot` body: 41-byte header + 4-byte table length +
/// 24 bytes per counter; the hot table is typically tiny and charged
/// to the same figure via `extra_counters`).
pub fn snapshot_bytes(k: u64, extra_counters: u64) -> u64 {
    41 + 4 + 4 + (k + extra_counters) * 24
}

/// Flat gather: the head receives `P − 1` summaries and folds each in
/// sequentially — both the transfers (one head NIC) and the combines
/// (one head core) serialize, so the critical path is
/// `(P−1) · (transfer + combine)`.
pub fn predict_flat(
    p: usize,
    bytes_per_summary: u64,
    k: u64,
    machine: &MachineModel,
    net: &NetworkModel,
) -> MergePrediction {
    if p <= 1 {
        return MergePrediction { transfer_s: 0.0, combine_s: 0.0 };
    }
    let rounds = (p - 1) as f64;
    MergePrediction {
        transfer_s: rounds * net.transfer_seconds(bytes_per_summary),
        combine_s: rounds * machine.combine_seconds(k),
    }
}

/// Recursive-halving tree: pairs merge in parallel rounds, so the
/// critical path is `⌈log₂P⌉ · (transfer + combine)` — the advantage
/// the paper's Figure 4 shows over flat merging once `P` grows.
/// Block-routing combine keeps the summary at `k` counters every
/// round, so per-round cost is constant.
pub fn predict_tree(
    p: usize,
    bytes_per_summary: u64,
    k: u64,
    machine: &MachineModel,
    net: &NetworkModel,
) -> MergePrediction {
    if p <= 1 {
        return MergePrediction { transfer_s: 0.0, combine_s: 0.0 };
    }
    let rounds = (p as f64).log2().ceil();
    MergePrediction {
        transfer_s: rounds * net.transfer_seconds(bytes_per_summary),
        combine_s: rounds * machine.combine_seconds(k),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-traced: P = 8, k = 2000, shared-memory transport.
    /// bytes = 49 + 2000·24 = 48_049.
    /// transfer = 0.3 µs + 48_049/12e9 ≈ 4.304 µs.
    /// combine(Xeon, k=2000) = (2000·55 + 2000·log2(2000)·9)·1e-9
    /// ≈ 0.110 ms + 0.1974 ms ≈ 0.3074 ms.
    /// Flat: 7 rounds; tree: ⌈log₂8⌉ = 3 rounds — the ratio is 7/3.
    #[test]
    fn tree_beats_flat_by_log_over_linear() {
        let m = MachineModel::xeon_e5_2630_v3();
        let net = NetworkModel::shared_memory();
        let bytes = snapshot_bytes(2000, 0);
        assert_eq!(bytes, 48_049);

        let flat = predict_flat(8, bytes, 2000, &m, &net);
        let tree = predict_tree(8, bytes, 2000, &m, &net);
        assert!(flat.total_s() > 0.0);
        let ratio = flat.total_s() / tree.total_s();
        assert!((ratio - 7.0 / 3.0).abs() < 1e-9, "ratio {ratio}");

        // Per-round figures match the hand trace.
        assert!((tree.transfer_s / 3.0 - net.transfer_seconds(bytes)).abs() < 1e-15);
        assert!((tree.combine_s / 3.0 - m.combine_seconds(2000)).abs() < 1e-15);
    }

    #[test]
    fn degenerate_clusters_cost_nothing() {
        let m = MachineModel::xeon_e5_2630_v3();
        let net = NetworkModel::qdr_infiniband();
        for p in [0, 1] {
            assert_eq!(predict_flat(p, 1000, 100, &m, &net).total_s(), 0.0);
            assert_eq!(predict_tree(p, 1000, 100, &m, &net).total_s(), 0.0);
        }
        // P = 2: one round either way — the strategies only diverge
        // beyond two workers.
        let f = predict_flat(2, 1000, 100, &m, &net);
        let t = predict_tree(2, 1000, 100, &m, &net);
        assert_eq!(f, t);
    }
}
