//! Calibration tables for the virtual cost model.
//!
//! Every factor is anchored at the paper's own measurements (Tables
//! II–IV, single-core rows, Xeon E5-2630 v3, Intel C++ v17), normalized
//! to the reference operating point **k = 2000, ρ = 1.1, n = 8 B**, where
//! the measured per-item cost is 238.45 s / 8e9 ≈ 29.8 ns.
//!
//! The simulator multiplies the machine's `base_item_ns` by:
//!
//! * [`k_factor`] — counter-count dependence (more counters → bigger
//!   working set → more cache misses; non-monotone dip at 2000 exactly
//!   as measured),
//! * [`skew_factor`] — skew dependence (ρ = 1.8 streams hit the
//!   monitored-increment fast path more often: factor ≈ 0.8),
//! * [`n_factor`] — stream-size dependence (bigger streams touch more
//!   distinct items; the OpenMP binary showed a pronounced 29 B
//!   anomaly, the MPI binary did not — both tables are kept),
//! * [`contention`] — saturating per-node memory-bandwidth contention in
//!   the number of active hardware threads per node.

/// Piecewise-linear interpolation through `(x, y)` points (sorted by x),
/// flat extrapolation outside the range.
pub fn interp(points: &[(f64, f64)], x: f64) -> f64 {
    debug_assert!(points.len() >= 2);
    if x <= points[0].0 {
        return points[0].1;
    }
    for w in points.windows(2) {
        let ((x0, y0), (x1, y1)) = (w[0], w[1]);
        if x <= x1 {
            return y0 + (y1 - y0) * (x - x0) / (x1 - x0);
        }
    }
    points.last().unwrap().1
}

/// Per-item cost factor vs. the number of Space Saving counters `k`
/// (paper Table II "Varying k" single-core row over the k=2000 cell).
/// Interpolated in `log2 k`.
pub fn k_factor(k: u64) -> f64 {
    const PTS: &[(f64, f64)] = &[
        // (log2 k, factor): 279.63, 244.56, 238.45, 258.01, 277.79 / 238.45
        (8.9658, 1.1727), // k = 500
        (9.9658, 1.0256), // k = 1000
        (10.9658, 1.0000), // k = 2000
        (11.9658, 1.0820), // k = 4000
        (12.9658, 1.1650), // k = 8000
    ];
    interp(PTS, (k.max(1) as f64).log2())
}

/// Per-item cost factor vs. zipf skew ρ (paper Table II "Varying ρ":
/// 190.08 s at ρ=1.8 vs 238.45 s at ρ=1.1).
pub fn skew_factor(rho: f64) -> f64 {
    const PTS: &[(f64, f64)] = &[(1.1, 1.0), (1.8, 0.7972)];
    interp(PTS, rho)
}

/// Which binary's calibration to use for the n-dependence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NTable {
    /// OpenMP binary (Table II): shows the 29 B single-core anomaly.
    OpenMp,
    /// MPI / hybrid binaries (Tables III–IV): flat in n.
    Mpi,
}

/// Per-item cost factor vs. stream length `n` (billions), relative to
/// the 8 B reference.
pub fn n_factor(table: NTable, n: u64) -> f64 {
    let nb = n as f64 / 1e9;
    match table {
        // 120.60/ (238.45/2), 1.0, 481.33/(238.45*2), 1047.10/(238.45*29/8)
        NTable::OpenMp => interp(
            &[(4.0, 1.0117), (8.0, 1.0), (16.0, 1.0093), (29.0, 1.2114)],
            nb,
        ),
        // 122.24/(238.96/2), 1.0, 481.52/(238.96*2), 874.88/(238.96*29/8)
        NTable::Mpi => interp(
            &[(4.0, 1.0231), (8.0, 1.0), (16.0, 1.0075), (29.0, 1.0100)],
            nb,
        ),
    }
}

/// Saturating per-node memory-bandwidth contention: the slowdown of one
/// worker's scan when `active` hardware threads share the node.
///
/// `1 + γ₁(a−1)/(1 + γ₂(a−1))` — fitted to Table II (OpenMP, 8 B):
/// measured slowdowns 1.03/1.16/1.27/1.31 at 2/4/8/16 threads, and
/// consistent with Table III's ~1.25–1.30 at 16 MPI ranks per node.
pub fn contention(gamma1: f64, gamma2: f64, active: u32) -> f64 {
    let a = active.saturating_sub(1) as f64;
    1.0 + gamma1 * a / (1.0 + gamma2 * a)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interp_basics() {
        let pts = [(0.0, 0.0), (10.0, 100.0)];
        assert_eq!(interp(&pts, -5.0), 0.0);
        assert_eq!(interp(&pts, 5.0), 50.0);
        assert_eq!(interp(&pts, 50.0), 100.0);
    }

    #[test]
    fn k_factor_anchors() {
        assert!((k_factor(2000) - 1.0).abs() < 1e-4);
        assert!((k_factor(500) - 1.1727).abs() < 1e-3);
        assert!((k_factor(8000) - 1.1650).abs() < 1e-3);
        // Dip at 2000: cheaper than both 500 and 8000.
        assert!(k_factor(2000) < k_factor(500));
        assert!(k_factor(2000) < k_factor(8000));
    }

    #[test]
    fn skew_factor_monotone_down() {
        assert!((skew_factor(1.1) - 1.0).abs() < 1e-9);
        assert!(skew_factor(1.8) < 0.8);
        assert!(skew_factor(1.4) < 1.0 && skew_factor(1.4) > skew_factor(1.8));
    }

    #[test]
    fn n_factor_tables_disagree_at_29b() {
        let omp = n_factor(NTable::OpenMp, 29_000_000_000);
        let mpi = n_factor(NTable::Mpi, 29_000_000_000);
        assert!(omp > 1.2 && mpi < 1.05, "omp={omp} mpi={mpi}");
    }

    #[test]
    fn contention_saturates() {
        let c16 = contention(0.08, 0.20, 16);
        let c8 = contention(0.08, 0.20, 8);
        let c2 = contention(0.08, 0.20, 2);
        assert!(c2 < c8 && c8 < c16);
        assert!((c16 - 1.30).abs() < 0.05, "c16={c16}");
        // Doubling threads far out barely moves it.
        assert!(contention(0.08, 0.20, 64) - c16 < 0.08);
    }

    #[test]
    fn reference_point_is_identity() {
        let f = k_factor(2000) * skew_factor(1.1) * n_factor(NTable::Mpi, 8_000_000_000);
        assert!((f - 1.0).abs() < 1e-4);
    }
}
