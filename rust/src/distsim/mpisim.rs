//! The simulation engine: real algorithm execution + virtual time.
//!
//! A simulated run is bit-faithful to paper Algorithm 1: the (scaled)
//! stream is block-decomposed over `ranks × threads` workers, every
//! worker runs real sequential Space Saving, summaries are combined in
//! the exact recursive-halving tree an MPI user-defined reduction
//! executes (intra-rank shared-memory tree first for hybrid runs), and
//! the root prunes. Alongside, every phase is charged virtual seconds
//! from the calibrated machine/network models at **paper scale**
//! (`n_virtual` items), so a laptop reproduces 512-core Galileo curves.

use crate::gen::{GeneratedSource, ItemSource};
use crate::metrics::PhaseTimes;
use crate::parallel::partition::block_range;
use crate::summary::{Counter, FrequencySummary, StreamSummary, Summary};

use super::cost::NTable;
use super::network::NetworkModel;
use super::topology::{ClusterSpec, Flavor};

/// MPI launcher/runtime init cost: base + per-rank dispatch (PMI wire-up
/// is linear in ranks at Galileo's scale).
const MPI_INIT_BASE_S: f64 = 0.05;
const MPI_INIT_PER_RANK_S: f64 = 2.0e-3;

/// Bytes per stream item resident on a device (the paper stores 32-bit
/// ids; 3 B items ≈ 12 GB just fits the Phi's 16 GB — §4.3).
const DEVICE_BYTES_PER_ITEM: u64 = 4;

/// A workload to simulate: paper-scale `n_virtual` for the clock, scaled
/// `n_real` for the actual computation.
#[derive(Debug, Clone)]
pub struct SimWorkload {
    /// Stream length the virtual clock charges (paper scale).
    pub n_virtual: u64,
    /// Stream length actually processed (accuracy is real at this size).
    pub n_real: u64,
    /// Space Saving counters per summary.
    pub k: usize,
    /// k-majority parameter for the final prune (the paper uses the
    /// number of counters, i.e. `φ = 1/k`).
    pub k_majority: u64,
    /// Zipf skew ρ (0.0 = uniform stream).
    pub skew: f64,
    /// Item universe (distinct ranks) of the generator.
    pub universe: u64,
    /// Generation seed.
    pub seed: u64,
}

impl SimWorkload {
    /// A paper experiment point: `n_virtual` items at skew `rho` with
    /// `k` counters, executed for real at `scale_denominator`× reduction
    /// (default universe 2²²).
    pub fn paper(n_virtual: u64, k: usize, rho: f64, scale_denominator: u64, seed: u64) -> Self {
        Self {
            n_virtual,
            n_real: (n_virtual / scale_denominator).max(1),
            k,
            k_majority: k as u64,
            skew: rho,
            universe: 1 << 22,
            seed,
        }
    }

    /// The deterministic generated source for the real computation.
    pub fn source(&self) -> GeneratedSource {
        if self.skew > 0.0 {
            GeneratedSource::zipf(self.n_real, self.universe, self.skew, self.seed)
        } else {
            GeneratedSource::uniform(self.n_real, self.universe, self.seed)
        }
    }
}

/// Result of one simulated run.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    /// Virtual phase times at paper scale (seconds).
    pub times: PhaseTimes,
    /// The reduced global summary (real, over the scaled stream).
    pub summary: Summary,
    /// Pruned k-majority candidates (real).
    pub frequent: Vec<Counter>,
    /// Per-rank virtual scan-finish times (spawn + local scan + intra
    /// reduce), for load-balance inspection.
    pub rank_finish: Vec<f64>,
    /// Modeled per-rank device memory footprint, bytes.
    pub rank_mem_bytes: u64,
}

impl SimOutcome {
    /// Total virtual runtime.
    pub fn total_seconds(&self) -> f64 {
        self.times.total()
    }
}

/// Simulate one run of Parallel Space Saving on `cluster`.
///
/// Errors if a rank's block cannot fit its device memory (the paper's
/// 16 GB Phi bound) or the spec is degenerate.
pub fn simulate(
    w: &SimWorkload,
    cluster: &ClusterSpec,
    net: &NetworkModel,
) -> anyhow::Result<SimOutcome> {
    anyhow::ensure!(cluster.ranks >= 1 && cluster.threads_per_rank >= 1, "empty cluster");
    let ranks = cluster.ranks as u64;
    let threads = cluster.threads_per_rank as u64;
    let m = &cluster.machine;

    // ---- memory gate (per-rank resident block) --------------------------
    let rank_block_virtual = w.n_virtual.div_ceil(ranks);
    let rank_mem = rank_block_virtual * DEVICE_BYTES_PER_ITEM;
    anyhow::ensure!(
        rank_mem <= m.mem_bytes,
        "rank block of {} items ({} GiB) exceeds {} memory ({} GiB)",
        rank_block_virtual,
        rank_mem >> 30,
        m.name,
        m.mem_bytes >> 30
    );

    let ntable = match cluster.flavor {
        Flavor::OpenMp => NTable::OpenMp,
        _ => NTable::Mpi,
    };

    // ---- spawn phase -----------------------------------------------------
    let mut spawn = match cluster.flavor {
        Flavor::OpenMp => m.spawn_seconds(cluster.threads_per_rank),
        Flavor::Mpi => MPI_INIT_BASE_S + MPI_INIT_PER_RANK_S * ranks as f64,
        Flavor::Hybrid => {
            MPI_INIT_BASE_S
                + MPI_INIT_PER_RANK_S * ranks as f64
                + m.spawn_seconds(cluster.threads_per_rank)
        }
        Flavor::MicOffload => {
            MPI_INIT_BASE_S
                + MPI_INIT_PER_RANK_S * ranks as f64
                + m.spawn_seconds(cluster.threads_per_rank)
        }
    };
    if cluster.flavor == Flavor::MicOffload {
        // Host -> device dataset transfer overlaps across accelerators
        // (each has its own PCIe link): charge one rank block.
        spawn += NetworkModel::pcie_offload()
            .transfer_seconds(rank_block_virtual * DEVICE_BYTES_PER_ITEM);
    }

    // ---- local scans (real + virtual) ------------------------------------
    let src = w.source();
    let total_workers = ranks * threads;
    let mut rank_summaries: Vec<Summary> = Vec::with_capacity(ranks as usize);
    let mut rank_scan_virtual: Vec<f64> = Vec::with_capacity(ranks as usize);
    let mut rank_finish: Vec<f64> = Vec::with_capacity(ranks as usize);
    let intra_levels = (threads as f64).log2().ceil() as u32;

    for r in 0..ranks {
        let active = cluster.active_threads_on_node(r as u32);
        let mut worker_summaries: Vec<Summary> = Vec::with_capacity(threads as usize);
        let mut worker_virtual_max = 0.0f64;
        for t in 0..threads {
            let wid = r * threads + t;
            // Real block over the scaled stream.
            let (lo, hi) = block_range(w.n_real, total_workers, wid);
            let mut ss = StreamSummary::new(w.k);
            let mut buf = vec![0u64; 1 << 14];
            let mut pos = lo;
            while pos < hi {
                let take = ((hi - pos) as usize).min(buf.len());
                src.fill(pos, &mut buf[..take]);
                ss.offer_all(&buf[..take]);
                pos += take as u64;
            }
            worker_summaries.push(ss.freeze());
            // Virtual block at paper scale.
            let (vlo, vhi) = block_range(w.n_virtual, total_workers, wid);
            let tv = m.scan_seconds(vhi - vlo, w.k as u64, w.skew, w.n_virtual, ntable, active)
                // freeze sort of k counters
                + w.k as f64 * (w.k as f64).max(2.0).log2() * m.sort_ns_per_counter * 1e-9;
            worker_virtual_max = worker_virtual_max.max(tv);
        }
        // Intra-rank shared-memory reduction (hybrid/OpenMP).
        let rank_summary = crate::parallel::reduction::tree_reduce(worker_summaries);
        let intra = intra_levels as f64 * (m.combine_seconds(w.k as u64) + m.barrier_ns * 1e-9);
        rank_summaries.push(rank_summary);
        rank_scan_virtual.push(worker_virtual_max);
        rank_finish.push(spawn + worker_virtual_max + intra);
    }

    let scan = rank_scan_virtual.iter().copied().fold(0.0, f64::max);

    // ---- inter-rank reduction tree (recursive halving) -------------------
    let shared = NetworkModel::shared_memory();
    let mut live: Vec<(u32, f64, Summary)> = rank_finish
        .iter()
        .zip(rank_summaries)
        .enumerate()
        .map(|(r, (t, s))| (r as u32, *t, s))
        .collect();
    while live.len() > 1 {
        let mut next: Vec<(u32, f64, Summary)> = Vec::with_capacity(live.len() / 2 + 1);
        let mut it = live.into_iter();
        while let Some((ra, ta, sa)) = it.next() {
            match it.next() {
                Some((rb, tb, sb)) => {
                    let link = if cluster.node_of(ra) == cluster.node_of(rb) {
                        &shared
                    } else {
                        net
                    };
                    let arrive = tb + link.transfer_seconds(sb.wire_bytes());
                    let done = ta.max(arrive) + m.combine_seconds(w.k as u64);
                    next.push((ra, done, sa.combine(&sb)));
                }
                None => next.push((ra, ta, sa)),
            }
        }
        live = next;
    }
    let (_, t_root, summary) = live.pop().expect("non-empty reduction");
    let reduce = (t_root - spawn - scan).max(0.0);

    // ---- prune ------------------------------------------------------------
    // Virtual: linear pass over k counters on the root.
    let prune = w.k as f64 * 10.0e-9;
    // Real: threshold at the real stream length.
    let frequent = summary.prune(w.n_real, w.k_majority);

    Ok(SimOutcome {
        times: PhaseTimes { spawn, scan, reduce, prune },
        summary,
        frequent,
        rank_finish,
        rank_mem_bytes: rank_mem,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::Exact;
    use crate::distsim::machine::MachineModel;
    use crate::metrics::AccuracyReport;

    fn xeon() -> MachineModel {
        MachineModel::xeon_e5_2630_v3()
    }

    fn qdr() -> NetworkModel {
        NetworkModel::qdr_infiniband()
    }

    #[test]
    fn single_rank_matches_paper_29b_mpi() {
        // Table III: 29 B items, k=2000, ρ=1.1, 1 core -> 874.88 s.
        let w = SimWorkload::paper(29_000_000_000, 2000, 1.1, 100_000, 1);
        let c = ClusterSpec::mpi(xeon(), 1);
        let out = simulate(&w, &c, &qdr()).unwrap();
        let t = out.total_seconds();
        assert!((t - 874.88).abs() / 874.88 < 0.05, "t={t}");
    }

    #[test]
    fn openmp_29b_single_core_anomaly_reproduced() {
        // Table II: 29 B, 1 OpenMP core -> 1047.10 s (the OpenMP binary's
        // n-dependence).
        let w = SimWorkload::paper(29_000_000_000, 2000, 1.1, 100_000, 1);
        let c = ClusterSpec::openmp(xeon(), 1);
        let out = simulate(&w, &c, &qdr()).unwrap();
        let t = out.total_seconds();
        assert!((t - 1047.1).abs() / 1047.1 < 0.05, "t={t}");
    }

    #[test]
    fn mpi_512_core_band() {
        // Table III: 29 B, 512 ranks -> 3.35 s (speedup 261).
        let w = SimWorkload::paper(29_000_000_000, 2000, 1.1, 1_000_000, 1);
        let c = ClusterSpec::mpi(xeon(), 512);
        let out = simulate(&w, &c, &qdr()).unwrap();
        let t = out.total_seconds();
        assert!((2.3..4.5).contains(&t), "t={t}");
    }

    #[test]
    fn hybrid_beats_mpi_at_512_cores() {
        // Tables III vs IV at 512 cores: 3.35 s MPI vs 2.40 s hybrid.
        let w = SimWorkload::paper(29_000_000_000, 2000, 1.1, 1_000_000, 1);
        let mpi = simulate(&w, &ClusterSpec::mpi(xeon(), 512), &qdr()).unwrap();
        let hyb = simulate(&w, &ClusterSpec::hybrid(xeon(), 64, 8), &qdr()).unwrap();
        assert!(
            hyb.total_seconds() < mpi.total_seconds(),
            "hybrid {} !< mpi {}",
            hyb.total_seconds(),
            mpi.total_seconds()
        );
    }

    #[test]
    fn accuracy_is_real_and_perfect_recall() {
        let w = SimWorkload {
            n_virtual: 8_000_000_000,
            n_real: 200_000,
            k: 200,
            k_majority: 200,
            skew: 1.1,
            universe: 50_000,
            seed: 3,
        };
        let c = ClusterSpec::mpi(xeon(), 32);
        let out = simulate(&w, &c, &qdr()).unwrap();
        let mut exact = Exact::new();
        let src = w.source();
        exact.offer_all(&src.slice(0, w.n_real));
        let acc = AccuracyReport::evaluate(&out.frequent, &exact, w.k_majority);
        assert_eq!(acc.recall, 1.0);
        assert_eq!(acc.precision, 1.0);
        assert!(acc.are < 0.01, "ARE {}", acc.are);
    }

    #[test]
    fn phi_memory_gate() {
        // 8 B items on one Phi (32 GB virtual footprint) must be refused.
        let w = SimWorkload::paper(8_000_000_000, 2000, 1.1, 1_000_000, 1);
        let c = ClusterSpec::mic_offload(1, 120);
        assert!(simulate(&w, &c, &qdr()).is_err());
        // 3 B fits (12 GB < 16 GB) — the paper's §4.3 configuration.
        let w3 = SimWorkload::paper(3_000_000_000, 2000, 1.1, 1_000_000, 1);
        assert!(simulate(&w3, &c, &qdr()).is_ok());
    }

    #[test]
    fn simulated_equals_sequential_result() {
        // The simulated reduction must produce the same frequent set as a
        // plain sequential run over the same real stream.
        let w = SimWorkload {
            n_virtual: 1_000_000,
            n_real: 100_000,
            k: 100,
            k_majority: 100,
            skew: 1.4,
            universe: 10_000,
            seed: 9,
        };
        let src = w.source();
        let mut seq = StreamSummary::new(w.k);
        seq.offer_all(&src.slice(0, w.n_real));
        let seq_frequent = seq.freeze().prune(w.n_real, w.k_majority);

        for ranks in [2u32, 7, 16] {
            let out =
                simulate(&w, &ClusterSpec::mpi(xeon(), ranks), &qdr()).unwrap();
            let a: Vec<u64> = seq_frequent.iter().map(|c| c.item).collect();
            let b: Vec<u64> = out.frequent.iter().map(|c| c.item).collect();
            assert_eq!(a, b, "ranks={ranks}");
        }
    }

    #[test]
    fn reduce_time_grows_with_k() {
        let mk = |k: usize| {
            let w = SimWorkload::paper(8_000_000_000, k, 1.1, 10_000_000, 1);
            simulate(&w, &ClusterSpec::mpi(xeon(), 128), &qdr())
                .unwrap()
                .times
                .reduce
        };
        assert!(mk(8000) > mk(500), "reduction cost must grow with k");
    }
}
