//! Machine cost models, calibrated to the paper's testbed.

use super::cost::{self, NTable};

/// Cost parameters of one machine type.
#[derive(Debug, Clone)]
pub struct MachineModel {
    /// Human-readable name.
    pub name: &'static str,
    /// Physical cores per socket.
    pub cores_per_socket: u32,
    /// Sockets per node.
    pub sockets_per_node: u32,
    /// Hardware threads per core that still add throughput (the paper
    /// found 2 of the Phi's 4; Xeon ran with hyperthreading disabled).
    pub useful_smt: u32,
    /// Per-item Space Saving cost at the reference point
    /// (k=2000, ρ=1.1, n=8B), nanoseconds.
    pub base_item_ns: f64,
    /// Memory-contention fit (γ₁, γ₂) for [`cost::contention`].
    pub gamma: (f64, f64),
    /// Thread spawn cost, ns per thread (OpenMP region entry).
    pub spawn_ns_per_thread: f64,
    /// Barrier / join cost, ns per tree level.
    pub barrier_ns: f64,
    /// Combine merge cost, ns per counter.
    pub combine_ns_per_counter: f64,
    /// Sort cost, ns per counter per log₂(k) (freeze + post-merge sort).
    pub sort_ns_per_counter: f64,
    /// Device/node memory in bytes (bounds the workload a rank can hold).
    pub mem_bytes: u64,
    /// Penalty multiplier once threads exceed `useful_smt × cores`
    /// (oversubscription: the paper's 240-thread Phi runs were *slower*
    /// than 120).
    pub oversub_penalty: f64,
}

impl MachineModel {
    /// Intel Xeon E5-2630 v3 (octa-core, 2.4 GHz) — the Galileo node CPU.
    ///
    /// `base_item_ns` = 238.45 s / 8e9 items (Table II, 1 core, k=2000,
    /// ρ=1.1, n=8B). Contention fitted to Table II slowdowns
    /// (1.03/1.16/1.27/1.31 at 2/4/8/16 threads per node).
    pub fn xeon_e5_2630_v3() -> Self {
        Self {
            name: "Xeon E5-2630 v3",
            cores_per_socket: 8,
            sockets_per_node: 2,
            useful_smt: 1, // hyperthreading disabled on Galileo
            base_item_ns: 29.81,
            gamma: (0.08, 0.20),
            spawn_ns_per_thread: 30_000.0,
            barrier_ns: 5_000.0,
            combine_ns_per_counter: 55.0,
            sort_ns_per_counter: 9.0,
            mem_bytes: 128 << 30,
            oversub_penalty: 1.15,
        }
    }

    /// Intel Phi 7120P (61 in-order cores @ 1.238 GHz, 4-way SMT, 16 GB
    /// GDDR5).
    ///
    /// Per-thread cost derated ×36 from the Xeon: in-order pipeline at
    /// half the clock, and — the paper's own diagnosis (§4.4) — the
    /// hash-table update loop defeats both the 512-bit SIMD unit and the
    /// cache hierarchy (unordered, unpredictable accesses, no locality).
    /// The paper measured ~2–3× slower than a Xeon socket at the Phi's
    /// best configuration (120 threads = 2 hw threads/core); this factor
    /// reproduces that ratio.
    pub fn phi_7120p() -> Self {
        Self {
            name: "Phi 7120P",
            cores_per_socket: 61,
            sockets_per_node: 1,
            useful_smt: 2,
            base_item_ns: 29.81 * 36.0,
            // High-bandwidth GDDR5: contention milder per thread.
            gamma: (0.015, 0.10),
            spawn_ns_per_thread: 45_000.0,
            barrier_ns: 12_000.0,
            combine_ns_per_counter: 160.0,
            sort_ns_per_counter: 28.0,
            mem_bytes: 16 << 30,
            oversub_penalty: 1.18,
        }
    }

    /// Hardware threads per node that add throughput.
    pub fn max_useful_threads_per_node(&self) -> u32 {
        self.cores_per_socket * self.sockets_per_node * self.useful_smt
    }

    /// Virtual seconds for one worker to scan `items` stream elements
    /// with `k` counters at skew `rho`, while `active_on_node` hardware
    /// threads share its node.
    ///
    /// The stream-size cost factor is evaluated on the *per-worker
    /// block* (`items`), not the total stream: the paper's Table II
    /// shows the 29 B slowdown at 1 core (29 B block) but near-ideal —
    /// even superlinear — speedups once the per-core block shrinks
    /// (2 cores, 14.5 B/core: speedup 2.36), i.e. the anomaly is a
    /// working-set effect that vanishes with smaller blocks.
    pub fn scan_seconds(
        &self,
        items: u64,
        k: u64,
        rho: f64,
        _n_total: u64,
        ntable: NTable,
        active_on_node: u32,
    ) -> f64 {
        let per_item = self.base_item_ns
            * cost::k_factor(k)
            * cost::skew_factor(rho)
            * cost::n_factor(ntable, items);
        let useful = self.max_useful_threads_per_node();
        let contended = cost::contention(self.gamma.0, self.gamma.1, active_on_node.min(useful));
        // Oversubscription: workers beyond the useful hardware threads
        // time-slice — each worker's wallclock stretches by the ratio,
        // plus a switching penalty (paper Fig. 5: 240 Phi threads are
        // slower than 120).
        let oversub = if active_on_node > useful {
            active_on_node as f64 / useful as f64 * self.oversub_penalty
        } else {
            1.0
        };
        items as f64 * per_item * contended * oversub * 1e-9
    }

    /// Virtual seconds for one combine of two k-counter summaries
    /// (hash-index build + merge + re-sort).
    pub fn combine_seconds(&self, k: u64) -> f64 {
        let kf = k as f64;
        (kf * self.combine_ns_per_counter + kf * (kf.max(2.0)).log2() * self.sort_ns_per_counter)
            * 1e-9
    }

    /// Virtual seconds to enter/exit a parallel region of `threads`.
    pub fn spawn_seconds(&self, threads: u32) -> f64 {
        threads as f64 * self.spawn_ns_per_thread * 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xeon_single_core_matches_paper_anchor() {
        let m = MachineModel::xeon_e5_2630_v3();
        // Table II: 8B items, k=2000, ρ=1.1, 1 core -> 238.45 s.
        let t = m.scan_seconds(8_000_000_000, 2000, 1.1, 8_000_000_000, NTable::OpenMp, 1);
        assert!((t - 238.45).abs() / 238.45 < 0.01, "t={t}");
    }

    #[test]
    fn xeon_16_thread_slowdown_in_band() {
        let m = MachineModel::xeon_e5_2630_v3();
        let t1 = m.scan_seconds(1_000_000, 2000, 1.1, 8_000_000_000, NTable::OpenMp, 1);
        let t16 = m.scan_seconds(1_000_000, 2000, 1.1, 8_000_000_000, NTable::OpenMp, 16);
        let slow = t16 / t1;
        assert!((1.25..1.40).contains(&slow), "slowdown {slow}");
    }

    #[test]
    fn phi_socket_slower_than_xeon_socket() {
        // Paper §4.4: Phi (120 thr) is ~2–3× slower than a Xeon socket
        // (8 cores) on the same 3B-item workload.
        let xeon = MachineModel::xeon_e5_2630_v3();
        let phi = MachineModel::phi_7120p();
        let n = 3_000_000_000u64;
        let t_xeon = xeon.scan_seconds(n / 8, 2000, 1.1, n, NTable::Mpi, 8);
        let t_phi = phi.scan_seconds(n / 120, 2000, 1.1, n, NTable::Mpi, 120);
        let ratio = t_phi / t_xeon;
        assert!((1.8..3.5).contains(&ratio), "phi/xeon ratio {ratio}");
    }

    #[test]
    fn phi_240_threads_worse_than_120() {
        let phi = MachineModel::phi_7120p();
        let n = 3_000_000_000u64;
        let t120 = phi.scan_seconds(n / 120, 2000, 1.1, n, NTable::Mpi, 120);
        let t240 = phi.scan_seconds(n / 240, 2000, 1.1, n, NTable::Mpi, 240);
        assert!(t240 > t120, "t120={t120} t240={t240}");
    }

    #[test]
    fn combine_scales_with_k() {
        let m = MachineModel::xeon_e5_2630_v3();
        assert!(m.combine_seconds(8000) > 3.0 * m.combine_seconds(2000));
        assert!(m.combine_seconds(2000) < 0.01, "combine stays sub-10ms");
    }
}
