//! α–β network model: message time = α + bytes/β.
//!
//! Presets for the paper's interconnects: QDR Infiniband (Galileo's
//! 40 Gb/s fabric), intra-node shared memory, and the PCIe gen2 x16 link
//! to the Phi accelerator (used for offload transfer charges).

/// Point-to-point message cost model.
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    /// Latency per message, seconds.
    pub alpha: f64,
    /// Bandwidth, bytes per second.
    pub beta: f64,
}

impl NetworkModel {
    /// QDR Infiniband: ~1.3 µs MPI latency, 40 Gb/s signal → ~4 GB/s
    /// effective payload bandwidth.
    pub fn qdr_infiniband() -> Self {
        Self { alpha: 1.3e-6, beta: 4.0e9 }
    }

    /// Intra-node shared-memory transport (MPI ranks on one node).
    pub fn shared_memory() -> Self {
        Self { alpha: 0.3e-6, beta: 12.0e9 }
    }

    /// PCIe gen2 x16 to the Phi accelerator (~6.5 GB/s effective, plus
    /// offload-launch latency folded into α).
    pub fn pcie_offload() -> Self {
        Self { alpha: 100e-6, beta: 6.5e9 }
    }

    /// Time to move `bytes` point-to-point.
    pub fn transfer_seconds(&self, bytes: u64) -> f64 {
        self.alpha + bytes as f64 / self.beta
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_dominates_small_messages() {
        let n = NetworkModel::qdr_infiniband();
        let t = n.transfer_seconds(64);
        assert!((t - n.alpha) / n.alpha < 0.02);
    }

    #[test]
    fn bandwidth_dominates_large_messages() {
        let n = NetworkModel::qdr_infiniband();
        let t = n.transfer_seconds(1 << 30);
        assert!((t - (1u64 << 30) as f64 / n.beta).abs() / t < 0.01);
    }

    #[test]
    fn summary_message_is_microseconds() {
        // k=8000 counters * 24 B ≈ 192 KB → tens of µs on QDR: the
        // paper's observation that reduction cost grows with k.
        let n = NetworkModel::qdr_infiniband();
        let t2000 = n.transfer_seconds(2000 * 24 + 16);
        let t8000 = n.transfer_seconds(8000 * 24 + 16);
        assert!(t8000 > 3.0 * t2000);
        assert!(t8000 < 1e-3);
    }

    #[test]
    fn pcie_dataset_transfer_is_seconds() {
        // 3B u32 items = 12 GB → ~2 s, the Phi offload charge.
        let n = NetworkModel::pcie_offload();
        let t = n.transfer_seconds(12 * (1u64 << 30));
        assert!((1.5..2.5).contains(&t), "t={t}");
    }
}
