//! Cluster topology: how ranks and threads map onto nodes.

use super::machine::MachineModel;

/// Which parallel code path a simulated run models (paper §3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Flavor {
    /// Pure OpenMP: one process, `threads_per_rank` threads on one node.
    OpenMp,
    /// Pure MPI: one single-threaded rank per core.
    Mpi,
    /// Hybrid MPI/OpenMP: multi-threaded ranks (8 threads/rank in the
    /// paper's Xeon runs).
    Hybrid,
    /// Hybrid with the compute offloaded to a MIC accelerator; charges
    /// the PCIe dataset transfer and uses the Phi machine model.
    MicOffload,
}

/// A simulated cluster allocation.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    /// Machine model of the compute devices.
    pub machine: MachineModel,
    /// MPI ranks.
    pub ranks: u32,
    /// OpenMP threads within each rank.
    pub threads_per_rank: u32,
    /// Ranks co-located per node (1 rank/node for MicOffload: one
    /// accelerator per rank).
    pub ranks_per_node: u32,
    /// Code-path flavor (selects calibration table + overhead charges).
    pub flavor: Flavor,
}

impl ClusterSpec {
    /// Pure OpenMP on one node.
    pub fn openmp(machine: MachineModel, threads: u32) -> Self {
        Self { machine, ranks: 1, threads_per_rank: threads, ranks_per_node: 1, flavor: Flavor::OpenMp }
    }

    /// Pure MPI, `ranks` single-threaded processes packed
    /// `cores-per-node` to a node.
    pub fn mpi(machine: MachineModel, ranks: u32) -> Self {
        let per_node = machine.cores_per_socket * machine.sockets_per_node;
        Self {
            machine,
            ranks,
            threads_per_rank: 1,
            ranks_per_node: per_node.min(ranks.max(1)),
            flavor: Flavor::Mpi,
        }
    }

    /// Hybrid: one rank per socket, 8 threads each (the paper's layout).
    pub fn hybrid(machine: MachineModel, ranks: u32, threads_per_rank: u32) -> Self {
        let per_node = ((machine.cores_per_socket * machine.sockets_per_node)
            / threads_per_rank.max(1))
        .max(1);
        Self {
            machine,
            ranks,
            threads_per_rank,
            ranks_per_node: per_node.min(ranks.max(1)),
            flavor: Flavor::Hybrid,
        }
    }

    /// MIC offload: one rank per accelerator, `threads` OpenMP threads
    /// on the device.
    pub fn mic_offload(ranks: u32, threads: u32) -> Self {
        Self {
            machine: MachineModel::phi_7120p(),
            ranks,
            threads_per_rank: threads,
            ranks_per_node: 1,
            flavor: Flavor::MicOffload,
        }
    }

    /// Total worker threads across the allocation.
    pub fn total_workers(&self) -> u64 {
        self.ranks as u64 * self.threads_per_rank as u64
    }

    /// Node index hosting `rank` (dense packing, as `mpirun` does).
    pub fn node_of(&self, rank: u32) -> u32 {
        rank / self.ranks_per_node.max(1)
    }

    /// Active hardware threads on `rank`'s node during the scan phase.
    pub fn active_threads_on_node(&self, rank: u32) -> u32 {
        let node = self.node_of(rank);
        let first = node * self.ranks_per_node;
        let co_resident = self.ranks.min(first + self.ranks_per_node) - first;
        co_resident * self.threads_per_rank
    }

    /// Number of nodes the allocation spans.
    pub fn nodes(&self) -> u32 {
        self.ranks.div_ceil(self.ranks_per_node.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mpi_packs_16_per_xeon_node() {
        let c = ClusterSpec::mpi(MachineModel::xeon_e5_2630_v3(), 64);
        assert_eq!(c.ranks_per_node, 16);
        assert_eq!(c.nodes(), 4);
        assert_eq!(c.node_of(0), 0);
        assert_eq!(c.node_of(15), 0);
        assert_eq!(c.node_of(16), 1);
        assert_eq!(c.active_threads_on_node(3), 16);
    }

    #[test]
    fn hybrid_two_ranks_per_node() {
        let c = ClusterSpec::hybrid(MachineModel::xeon_e5_2630_v3(), 64, 8);
        assert_eq!(c.ranks_per_node, 2);
        assert_eq!(c.nodes(), 32);
        assert_eq!(c.active_threads_on_node(0), 16);
        assert_eq!(c.total_workers(), 512);
    }

    #[test]
    fn partial_last_node() {
        let c = ClusterSpec::mpi(MachineModel::xeon_e5_2630_v3(), 20);
        assert_eq!(c.nodes(), 2);
        // Last node hosts only 4 ranks -> 4 active threads.
        assert_eq!(c.active_threads_on_node(19), 4);
        assert_eq!(c.active_threads_on_node(0), 16);
    }

    #[test]
    fn openmp_single_node() {
        let c = ClusterSpec::openmp(MachineModel::xeon_e5_2630_v3(), 16);
        assert_eq!(c.nodes(), 1);
        assert_eq!(c.active_threads_on_node(0), 16);
    }

    #[test]
    fn mic_allocation() {
        let c = ClusterSpec::mic_offload(4, 120);
        assert_eq!(c.nodes(), 4);
        assert_eq!(c.total_workers(), 480);
        assert_eq!(c.active_threads_on_node(2), 120);
    }
}
