//! Deterministic cluster simulator — the substitute for the paper's
//! Galileo testbed (516 nodes of 2× octa-core Xeon E5-2630 v3 + Intel
//! Phi 7120P accelerators, QDR Infiniband).
//!
//! Design (DESIGN.md §2): the *algorithm executes for real* — every
//! simulated rank/thread runs actual sequential Space Saving over its
//! block of a real (scaled) stream, and the reduction performs actual
//! `combine` calls in the exact recursive-halving tree MPI would use —
//! while *time is charged virtually* from calibrated cost models:
//!
//! * [`machine`] — per-machine cost parameters (Xeon E5-2630 v3,
//!   Phi 7120P), calibrated against the paper's own single-core
//!   measurements (Tables II–IV).
//! * [`cost`] — the calibration tables: per-item cost factors in `k`,
//!   skew ρ, stream size `n`, and the saturating memory-contention model.
//! * [`network`] — α–β message model (QDR Infiniband, PCIe offload).
//! * [`topology`] — cluster shape: nodes × ranks × threads, placement.
//! * [`mpisim`] — the engine: decompose → real local scans → timed
//!   combine tree → pruned result + virtual [`PhaseTimes`].
//!
//! Accuracy metrics from a simulated run are *real* (computed on the
//! scaled stream against an exact oracle); runtimes are *virtual*
//! (paper-scale seconds from the cost model).
//!
//! [`PhaseTimes`]: crate::metrics::PhaseTimes

pub mod cost;
pub mod machine;
pub mod mpisim;
pub mod network;
pub mod predict;
pub mod topology;

pub use machine::MachineModel;
pub use mpisim::{simulate, SimOutcome, SimWorkload};
pub use network::NetworkModel;
pub use predict::{predict_flat, predict_tree, snapshot_bytes, MergePrediction};
pub use topology::{ClusterSpec, Flavor};
