//! The network-facing service layer: wire protocol, server, clients.
//!
//! Everything below the socket is the existing stack — [`proto`]
//! frames splice into the coordinator's recycled chunk buffers,
//! queries answer from the epoch snapshots — so the service preserves
//! both invariants the library guarantees in process: the
//! `f ≤ f̂ ≤ f + n/k` bound end to end, and the allocation-free ingest
//! steady state across the socket hop.
//!
//! * [`proto`] — length-prefixed little-endian frames: the 8-byte
//!   hello (magic/version/role), `IngestItems`/`IngestRuns` with
//!   per-frame acks, the query/answer pairs, typed errors, and the
//!   resumable [`proto::FrameReader`] that survives read timeouts
//!   mid-frame.
//! * [`server`] — [`server::Server`]: TCP + Unix-socket listener, one
//!   ingest connection = one producer, a fixed query reader pool, and
//!   a drain-then-join shutdown protocol.
//! * [`client`] — [`client::IngestClient`] (pipelined acks + latency
//!   attribution), [`client::QueryClient`] (engine-typed answers),
//!   [`client::SnapshotClient`] (cluster-head pulls of full summary
//!   state over the worker role), and [`client::run_loadgen`] behind
//!   `pss loadgen`.
//!
//! Protocol v2 adds the worker role for cluster mode: a head process
//! handshakes as [`Role::Worker`] and exchanges
//! [`Frame::SummaryRequest`] / [`Frame::SummarySnapshot`] to pull each
//! worker's *pre-absorb* merged summary plus its exact hot side table,
//! so the head can replay the merge and keep the per-worker ε bounds
//! honest (see `cluster/`).
//!
//! Protocol v4 adds the deadline layer: every blocking read and write
//! carries a deadline ([`ProtoError::Timeout`] /
//! [`ErrorCode::Timeout`]), and [`faultline`] provides the
//! deterministic fault-injection proxy ([`faultline::FaultLine`]) the
//! failure-path tests and `pss faultgen` drive against it.

pub mod client;
pub mod faultline;
pub mod proto;
pub mod server;

pub use client::{
    run_loadgen, IngestClient, LoadgenConfig, LoadgenReport, QueryClient, SnapshotClient,
    TopKAnswer,
};
pub use faultline::{Direction, FaultAction, FaultLine, FaultPlan, FaultRule};
pub use proto::{
    ErrorCode, Frame, FrameReader, ProtoError, Role, WireCounter, WireSnapshot, WireStats,
};
pub use server::{AnyStream, Endpoint, ServeConfig, ServeStats, Server};
