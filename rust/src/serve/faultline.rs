//! Deterministic fault injection: an in-process proxy that sits
//! between a client and a `pss` server and misbehaves on schedule.
//!
//! ```text
//!   client ──► FaultLine ──► server
//!          ◄──           ◄──
//! ```
//!
//! The proxy forwards the 8-byte hello verbatim, then parses each
//! direction's byte stream into frames with the resumable
//! [`FrameReader`] and applies a [`FaultPlan`] keyed on the
//! per-direction frame index: drop the frame, delay it, truncate its
//! wire image mid-byte (then kill the connection), reset the
//! connection outright, or forward a garbage frame (length header
//! intact, kind and body randomized from a seeded [`SplitMix64`]).
//!
//! Everything is deterministic given `(plan, seed)` and the input
//! stream: the same run produces the same observed bytes downstream,
//! which is what lets the failure-path tests assert exact outcomes
//! instead of hoping a flaky sleep races the right way. The pure
//! transform is exposed as [`FaultPlan::apply_stream`] so property
//! tests can drive it without sockets; the live proxy
//! ([`FaultLine::spawn`]) runs the identical code over real
//! connections and is what `pss faultgen` and the integration tests
//! use.

use std::io::{Read, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::proto::{FrameReader, Poll};
use super::server::{AnyListener, AnyStream, Endpoint};
use crate::metrics::{FaultCounters, FaultStats};
use crate::util::SplitMix64;

/// Which way a frame is travelling through the proxy.
///
/// Frame indices count per direction per connection, starting at 0.
/// Note the server's `HelloOk` is server→client frame 0 (the hello
/// itself is raw bytes, not a frame, and is never faulted).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Client → server (ingest frames, queries, summary requests).
    ClientToServer,
    /// Server → client (acks, results, snapshots).
    ServerToClient,
}

impl std::str::FromStr for Direction {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "c2s" => Ok(Direction::ClientToServer),
            "s2c" => Ok(Direction::ServerToClient),
            other => Err(format!("unrecognized direction '{other}' (want c2s or s2c)")),
        }
    }
}

impl std::fmt::Display for Direction {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Direction::ClientToServer => "c2s",
            Direction::ServerToClient => "s2c",
        })
    }
}

/// What to do to the selected frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Swallow the frame; the stream continues with the next one.
    Drop,
    /// Hold the frame back this long, then forward it intact.
    Delay(Duration),
    /// Forward only the first `n` bytes of the frame's wire image,
    /// then kill the connection — the downstream peer sees a
    /// mid-frame truncation.
    Truncate(usize),
    /// Kill the connection at this frame boundary without forwarding.
    Reset,
    /// Forward a frame with the original length but randomized kind
    /// and body bytes (seeded, so reproducible).
    Garbage,
}

/// One scheduled fault: on the `frame_index`-th frame (0-based, per
/// direction, per connection) travelling `direction`, do `action`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultRule {
    /// Which frame to hit (0-based within its direction).
    pub frame_index: u64,
    /// Which direction's stream to hit.
    pub direction: Direction,
    /// What to do to it.
    pub action: FaultAction,
}

/// A set of scheduled faults. Empty plans forward everything — a
/// transparent proxy, the control case.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// A plan from explicit rules.
    pub fn new(rules: Vec<FaultRule>) -> Self {
        Self { rules }
    }

    /// The common one-fault plan.
    pub fn single(direction: Direction, frame_index: u64, action: FaultAction) -> Self {
        Self::new(vec![FaultRule { frame_index, direction, action }])
    }

    /// The action scheduled for this frame, if any (first match wins).
    pub fn rule_for(&self, direction: Direction, frame_index: u64) -> Option<FaultAction> {
        self.rules
            .iter()
            .find(|r| r.direction == direction && r.frame_index == frame_index)
            .map(|r| r.action)
    }

    /// Run the pure per-frame transform over a complete byte stream of
    /// frames, as the proxy's first connection would: returns the
    /// bytes the downstream peer observes and whether the connection
    /// was killed mid-stream. Deterministic in `(self, direction,
    /// seed, input)` — the property the fault tests pin.
    pub fn apply_stream(&self, direction: Direction, seed: u64, input: &[u8]) -> (Vec<u8>, bool) {
        let counters = FaultCounters::new();
        let mut pump = FramePump::new(self.clone(), direction, seed, 0);
        let mut reader = FrameReader::new();
        let mut cursor = std::io::Cursor::new(input);
        let mut observed = Vec::new();
        let mut frame = Vec::new();
        loop {
            match reader.poll(&mut cursor) {
                Ok(Poll::Frame(kind, body)) => {
                    frame.clear();
                    let ctl = pump.transform(kind, body, &mut frame, &counters);
                    observed.extend_from_slice(&frame);
                    if ctl.kill {
                        return (observed, true);
                    }
                }
                Ok(Poll::Pending) => {}
                Ok(Poll::Eof) | Err(_) => return (observed, false),
            }
        }
    }
}

/// Outcome of transforming one frame.
struct PumpControl {
    /// Sleep this long before forwarding (the bytes are already in the
    /// output buffer; the live pump sleeps before writing them).
    delay: Option<Duration>,
    /// Kill the connection after writing whatever was produced.
    kill: bool,
}

/// The per-direction transform state: plan lookup, frame counter and
/// the seeded garbage source.
struct FramePump {
    plan: FaultPlan,
    direction: Direction,
    rng: SplitMix64,
    seen: u64,
}

impl FramePump {
    /// The garbage RNG is derived from `(seed, connection, direction)`
    /// so every pump in a run has an independent, reproducible stream.
    fn new(plan: FaultPlan, direction: Direction, seed: u64, conn: u64) -> Self {
        let lane = conn * 2 + matches!(direction, Direction::ServerToClient) as u64;
        Self { plan, direction, rng: SplitMix64::new(seed).split(lane), seen: 0 }
    }

    /// Transform one complete frame `(kind, body)`: append the bytes
    /// to forward to `out` (possibly none) and say what else to do.
    fn transform(
        &mut self,
        kind: u8,
        body: &[u8],
        out: &mut Vec<u8>,
        counters: &FaultCounters,
    ) -> PumpControl {
        let index = self.seen;
        self.seen += 1;
        let forward = |out: &mut Vec<u8>| {
            out.extend_from_slice(&((body.len() + 1) as u32).to_le_bytes());
            out.push(kind);
            out.extend_from_slice(body);
        };
        match self.plan.rule_for(self.direction, index) {
            None => {
                counters.record_forwarded();
                forward(out);
                PumpControl { delay: None, kill: false }
            }
            Some(FaultAction::Drop) => {
                counters.record_dropped();
                PumpControl { delay: None, kill: false }
            }
            Some(FaultAction::Delay(d)) => {
                counters.record_delayed();
                forward(out);
                PumpControl { delay: Some(d), kill: false }
            }
            Some(FaultAction::Truncate(n)) => {
                counters.record_truncated();
                forward(out);
                out.truncate(out.len().min(n));
                PumpControl { delay: None, kill: true }
            }
            Some(FaultAction::Reset) => {
                counters.record_reset();
                PumpControl { delay: None, kill: true }
            }
            Some(FaultAction::Garbage) => {
                counters.record_garbled();
                out.extend_from_slice(&((body.len() + 1) as u32).to_le_bytes());
                for _ in 0..=body.len() {
                    out.push(self.rng.next_u64() as u8);
                }
                PumpControl { delay: None, kill: false }
            }
        }
    }
}

/// A running fault-injection proxy. Spawn with [`FaultLine::spawn`],
/// point a client at [`FaultLine::endpoint`], stop and collect the
/// injected-fault accounting with [`FaultLine::finish`].
pub struct FaultLine {
    endpoint: Endpoint,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    shutdown: Arc<AtomicBool>,
    counters: Arc<FaultCounters>,
    unix_path: Option<PathBuf>,
}

impl FaultLine {
    /// Listen on `listen`, proxying each accepted connection to
    /// `upstream` through `plan`. Every connection gets its own
    /// per-direction frame counters and garbage RNG lanes derived from
    /// `seed` and the connection index (accept order), so multi-client
    /// runs stay reproducible.
    pub fn spawn(
        listen: &Endpoint,
        upstream: &Endpoint,
        plan: FaultPlan,
        seed: u64,
    ) -> crate::Result<FaultLine> {
        let (listener, endpoint, unix_path) = AnyListener::bind(listen)?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let counters = Arc::new(FaultCounters::new());
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let upstream = upstream.clone();
            let shutdown = shutdown.clone();
            let counters = counters.clone();
            let conns = conns.clone();
            let next_conn = AtomicU64::new(0);
            std::thread::Builder::new()
                .name("pss-faultline".into())
                .spawn(move || {
                    while !shutdown.load(Ordering::Acquire) {
                        match listener.accept() {
                            Ok(client) => {
                                let conn = next_conn.fetch_add(1, Ordering::Relaxed);
                                let upstream = upstream.clone();
                                let plan = plan.clone();
                                let shutdown = shutdown.clone();
                                let counters = counters.clone();
                                let handle = std::thread::Builder::new()
                                    .name("pss-faultline-conn".into())
                                    .spawn(move || {
                                        proxy_conn(
                                            client, &upstream, plan, seed, conn, &counters,
                                            &shutdown,
                                        );
                                    })
                                    .expect("spawn faultline connection");
                                conns.lock().expect("faultline conns lock").push(handle);
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(10));
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                            Err(_) => break,
                        }
                    }
                })
                .expect("spawn faultline accept loop")
        };
        Ok(FaultLine {
            endpoint,
            accept: Some(accept),
            conns,
            shutdown,
            counters,
            unix_path,
        })
    }

    /// Where clients should connect (TCP port resolved).
    pub fn endpoint(&self) -> &Endpoint {
        &self.endpoint
    }

    /// Live injected-fault accounting across every connection so far.
    pub fn stats(&self) -> FaultStats {
        self.counters.stats()
    }

    /// Stop accepting, join every proxy thread and report the final
    /// fault accounting.
    pub fn finish(mut self) -> FaultStats {
        self.shutdown.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles = {
            let mut guard = self.conns.lock().expect("faultline conns lock");
            std::mem::take(&mut *guard)
        };
        for h in handles {
            let _ = h.join();
        }
        if let Some(path) = self.unix_path.take() {
            let _ = std::fs::remove_file(path);
        }
        self.counters.stats()
    }
}

impl Drop for FaultLine {
    /// Dropping without [`finish`](Self::finish) still signals the
    /// threads to exit (they poll the flag every few milliseconds);
    /// only the accept loop is joined so drop never blocks on a
    /// misbehaving connection.
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// One proxied connection: forward the hello, then pump both
/// directions through the fault transform until either side closes, a
/// fault kills the connection, or the proxy shuts down.
fn proxy_conn(
    mut client: AnyStream,
    upstream: &Endpoint,
    plan: FaultPlan,
    seed: u64,
    conn: u64,
    counters: &Arc<FaultCounters>,
    shutdown: &Arc<AtomicBool>,
) {
    let mut server = match upstream.connect() {
        Ok(s) => s,
        Err(_) => {
            let _ = client.shutdown(std::net::Shutdown::Both);
            return;
        }
    };
    // The hello is raw bytes, not a frame: forward it verbatim. A peer
    // that stalls mid-hello gets cut off by the read timeout.
    let _ = client.set_read_timeout(Some(Duration::from_secs(5)));
    let _ = client.set_write_timeout(Some(Duration::from_secs(30)));
    let _ = server.set_write_timeout(Some(Duration::from_secs(30)));
    let mut hello = [0u8; 8];
    if client.read_exact(&mut hello).is_err()
        || server.write_all(&hello).and_then(|()| server.flush()).is_err()
    {
        let _ = client.shutdown(std::net::Shutdown::Both);
        let _ = server.shutdown(std::net::Shutdown::Both);
        return;
    }
    let (Ok(client_r), Ok(server_r)) = (client.try_clone(), server.try_clone()) else {
        let _ = client.shutdown(std::net::Shutdown::Both);
        let _ = server.shutdown(std::net::Shutdown::Both);
        return;
    };
    // server → client in a side thread, client → server inline.
    let s2c = {
        let pump = FramePump::new(plan.clone(), Direction::ServerToClient, seed, conn);
        let counters = counters.clone();
        let shutdown = shutdown.clone();
        std::thread::Builder::new()
            .name("pss-faultline-s2c".into())
            .spawn(move || pump_frames(server_r, client, pump, &counters, &shutdown))
            .expect("spawn faultline s2c pump")
    };
    let pump = FramePump::new(plan, Direction::ClientToServer, seed, conn);
    pump_frames(client_r, server, pump, counters, shutdown);
    let _ = s2c.join();
}

/// Read frames from `src`, transform, write to `dst`. On exit (EOF,
/// error, injected kill, or proxy shutdown), both sockets are shut
/// down so the paired pump exits too.
fn pump_frames(
    mut src: AnyStream,
    mut dst: AnyStream,
    mut pump: FramePump,
    counters: &FaultCounters,
    shutdown: &AtomicBool,
) {
    let _ = src.set_read_timeout(Some(Duration::from_millis(20)));
    let mut reader = FrameReader::new();
    let mut out = Vec::new();
    loop {
        if shutdown.load(Ordering::Acquire) {
            break;
        }
        match reader.poll(&mut src) {
            Ok(Poll::Frame(kind, body)) => {
                out.clear();
                let ctl = pump.transform(kind, body, &mut out, counters);
                if let Some(d) = ctl.delay {
                    std::thread::sleep(d);
                }
                if !out.is_empty()
                    && dst.write_all(&out).and_then(|()| dst.flush()).is_err()
                {
                    break;
                }
                if ctl.kill {
                    break;
                }
            }
            Ok(Poll::Pending) => {}
            Ok(Poll::Eof) | Err(_) => break,
        }
    }
    let _ = src.shutdown(std::net::Shutdown::Both);
    let _ = dst.shutdown(std::net::Shutdown::Both);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::proto::{kind, Frame, ProtoError};

    fn stream_of(frames: &[Frame]) -> Vec<u8> {
        let mut wire = Vec::new();
        for f in frames {
            f.encode_into(&mut wire);
        }
        wire
    }

    fn frames_of(bytes: &[u8]) -> Vec<Result<Frame, ProtoError>> {
        let mut reader = FrameReader::new();
        let mut cursor = std::io::Cursor::new(bytes);
        let mut got = Vec::new();
        loop {
            match reader.poll(&mut cursor) {
                Ok(Poll::Frame(k, body)) => got.push(Frame::decode(k, body)),
                Ok(Poll::Pending) => {}
                Ok(Poll::Eof) | Err(_) => return got,
            }
        }
    }

    fn three_acks() -> Vec<Frame> {
        (0..3).map(|i| Frame::IngestAck { seq: i, items: 10 + i }).collect()
    }

    #[test]
    fn empty_plan_is_transparent() {
        let wire = stream_of(&three_acks());
        let (observed, killed) =
            FaultPlan::default().apply_stream(Direction::ClientToServer, 7, &wire);
        assert_eq!(observed, wire, "no rules ⇒ byte-identical passthrough");
        assert!(!killed);
    }

    #[test]
    fn drop_swallows_exactly_the_indexed_frame() {
        let frames = three_acks();
        let wire = stream_of(&frames);
        let plan = FaultPlan::single(Direction::ClientToServer, 1, FaultAction::Drop);
        let (observed, killed) = plan.apply_stream(Direction::ClientToServer, 7, &wire);
        assert!(!killed);
        let got: Vec<Frame> = frames_of(&observed).into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(got, vec![frames[0].clone(), frames[2].clone()]);
        // The other direction is untouched by a c2s rule.
        let (observed, _) = plan.apply_stream(Direction::ServerToClient, 7, &wire);
        assert_eq!(observed, wire);
    }

    #[test]
    fn truncate_cuts_mid_frame_and_kills() {
        let wire = stream_of(&three_acks());
        let plan = FaultPlan::single(Direction::ClientToServer, 0, FaultAction::Truncate(7));
        let (observed, killed) = plan.apply_stream(Direction::ClientToServer, 7, &wire);
        assert!(killed);
        assert_eq!(observed.len(), 7);
        assert_eq!(&observed[..], &wire[..7], "a truncation is a prefix of the real image");
        // Downstream, that reads as a typed truncation.
        let mut reader = FrameReader::new();
        let mut cursor = std::io::Cursor::new(observed);
        loop {
            match reader.poll(&mut cursor) {
                Ok(Poll::Pending) => {}
                Err(e) => {
                    assert_eq!(e, ProtoError::Truncated);
                    break;
                }
                Ok(other) => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn reset_kills_without_forwarding() {
        let wire = stream_of(&three_acks());
        let plan = FaultPlan::single(Direction::ServerToClient, 0, FaultAction::Reset);
        let (observed, killed) = plan.apply_stream(Direction::ServerToClient, 7, &wire);
        assert!(killed);
        assert!(observed.is_empty());
    }

    #[test]
    fn garbage_keeps_framing_but_scrambles_content() {
        let frames = three_acks();
        let wire = stream_of(&frames);
        let plan = FaultPlan::single(Direction::ClientToServer, 1, FaultAction::Garbage);
        let (observed, killed) = plan.apply_stream(Direction::ClientToServer, 7, &wire);
        assert!(!killed);
        let got = frames_of(&observed);
        assert_eq!(got.len(), 3, "length header intact ⇒ framing survives");
        assert_eq!(*got[0].as_ref().unwrap(), frames[0]);
        assert_eq!(*got[2].as_ref().unwrap(), frames[2]);
        // The garbled frame decodes to garbage — with a seeded RNG the
        // kind byte is effectively never a valid ack again.
        assert_ne!(*got[1].as_ref().unwrap_or(&Frame::Stats), frames[1]);
        // Deterministic per seed; different seeds differ.
        let again = plan.apply_stream(Direction::ClientToServer, 7, &wire);
        assert_eq!(again.0, observed);
        let other = plan.apply_stream(Direction::ClientToServer, 8, &wire);
        assert_ne!(other.0, observed);
    }

    #[test]
    fn live_proxy_forwards_and_injects() {
        use crate::serve::proto::{encode_hello, read_frame, Role};
        // A hand-rolled upstream echo server: accepts one connection,
        // reads the hello, then acks every ingest frame.
        let upstream = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let upstream_ep = Endpoint::Tcp(upstream.local_addr().unwrap().to_string());
        let server = std::thread::spawn(move || {
            let (mut s, _) = upstream.accept().unwrap();
            let mut hello = [0u8; 8];
            s.read_exact(&mut hello).unwrap();
            let mut scratch = Vec::new();
            let mut wire = Vec::new();
            let mut acked = 0u64;
            while let Ok(Some((k, body))) = read_frame(&mut s, &mut scratch) {
                assert_eq!(k, kind::INGEST_ITEMS);
                let seq = u64::from_le_bytes(body[..8].try_into().unwrap());
                wire.clear();
                Frame::IngestAck { seq, items: ((body.len() - 8) / 8) as u64 }
                    .encode_into(&mut wire);
                if s.write_all(&wire).is_err() {
                    break;
                }
                acked += 1;
            }
            (hello, acked)
        });

        // Drop c2s frame 1: the server must see frames 0 and 2 only.
        let plan = FaultPlan::single(Direction::ClientToServer, 1, FaultAction::Drop);
        let proxy =
            FaultLine::spawn(&Endpoint::Tcp("127.0.0.1:0".into()), &upstream_ep, plan, 99)
                .unwrap();

        let mut c = proxy.endpoint().connect().unwrap();
        c.write_all(&encode_hello(Role::Ingest)).unwrap();
        let mut wire = Vec::new();
        for seq in 0..3u64 {
            wire.clear();
            Frame::IngestItems { seq, items: vec![seq; 4] }.encode_into(&mut wire);
            c.write_all(&wire).unwrap();
        }
        let mut scratch = Vec::new();
        let mut acks = Vec::new();
        for _ in 0..2 {
            let (k, body) = read_frame(&mut c, &mut scratch).unwrap().unwrap();
            acks.push(Frame::decode(k, body).unwrap());
        }
        assert_eq!(
            acks,
            vec![
                Frame::IngestAck { seq: 0, items: 4 },
                Frame::IngestAck { seq: 2, items: 4 }
            ],
            "the dropped frame never reached the server"
        );
        drop(c);

        let (hello, acked) = server.join().unwrap();
        assert_eq!(hello, encode_hello(Role::Ingest), "hello forwarded verbatim");
        assert_eq!(acked, 2);
        let stats = proxy.finish();
        assert_eq!(stats.dropped, 1);
        // 2 ingest frames forwarded c2s + 2 acks s2c.
        assert_eq!(stats.forwarded, 4);
    }
}
