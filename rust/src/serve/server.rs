//! The network-facing `pss` service: socket ingest + socket queries
//! over one [`Coordinator`] session.
//!
//! ```text
//!             ┌────────────────────── serve::Server ──────────────────────┐
//!  ingest ────┤ conn thread ──┐                                           │
//!  ingest ────┤ conn thread ──┼─▶ Mutex<Coordinator> ─▶ SPSC rings ─▶ shards
//!  (hello:    │   (decode     │      (take_buffer +        │              │
//!   ingest)   │    outside    │       try_push, short      ▼              │
//!             │    the lock)  │       critical section)  epoch Arcs       │
//!             │               │                            │              │
//!  query  ────┤ reader pool ──┴────────────────────────────┴─▶ answers    │
//!  (hello:    │   (QueryEngine / WindowedQueryEngine clones — never      │
//!   query)    │    touches the coordinator mutex: readers don't block    │
//!             │    writers, writers don't block readers)                 │
//!             └────────────────────────────────────────────────────────────┘
//! ```
//!
//! **Connection = producer.** Each ingest connection gets a dedicated
//! thread that owns its socket and decodes frames *outside* the
//! coordinator lock: it borrows a recycled chunk buffer
//! ([`Coordinator::take_buffer`], one short lock), expands the frame
//! into it, then routes it with [`Coordinator::try_push`] (second
//! short lock, released between backpressure retries so one slow shard
//! never convoys every other connection). One ingest frame becomes
//! exactly one coordinator chunk, and consumed buffers flow back
//! through the free rings — the zero-alloc ingest steady state
//! survives the socket hop ([`IngestStats::buffers_recycled`] keeps
//! counting on the socket path).
//!
//! **Queries never wait on ingest.** Query connections are served by a
//! small fixed reader pool holding [`QueryEngine`] /
//! [`WindowedQueryEngine`] clones. Those answer from the epoch
//! snapshots (atomically-swapped `Arc`s), so query fan-out is
//! embarrassingly parallel and completely decoupled from the ingest
//! mutex.
//!
//! **Worker role (cluster mode).** A connection greeting with
//! [`Role::Worker`] is a cluster head pulling this process's merged
//! summary: each [`Frame::SummaryRequest`] is answered with a
//! [`Frame::SummarySnapshot`] exporting the full
//! [`MergedSnapshot`](crate::query::MergedSnapshot) state (pre-absorb
//! summary, exact hot table with history bounds, worker-computed ε).
//! A `drain: true` request additionally takes the coordinator, drains
//! it ([`Coordinator::finish`]), stows the [`QueryResult`] for
//! [`Server::finish`] to return, replies with the *final* snapshot
//! (`finished: true`) and flips the shutdown flag — the wire-level
//! equivalent of the local drain, so a head can stop its workers and
//! still collect their exact final state in one round trip.
//!
//! **Shutdown protocol.** [`Server::request_shutdown`] (or a wire
//! [`Frame::Shutdown`] from a query connection) flips one flag; the
//! accept loop stops accepting, every connection thread finishes the
//! frame it is mid-way through (the resumable [`FrameReader`] makes
//! the poll loop timeout-safe), answers in-flight ingest with a final
//! ack, tells peers `ShuttingDown`, and exits; [`Server::finish`]
//! joins them all, then drains the coordinator
//! ([`Coordinator::finish`]) for the final merged summary. Connections
//! that die mid-frame, send garbage, or overflow the frame caps are
//! answered with a typed [`Frame::Error`] and closed *individually* —
//! one bad peer never poisons the listener, the pool, or another
//! connection.
//!
//! [`IngestStats::buffers_recycled`]: crate::coordinator::IngestStats::buffers_recycled

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::{Coordinator, CoordinatorConfig, PushError, QueryResult};
use crate::query::QueryEngine;
use crate::window::WindowedQueryEngine;

use super::proto::{
    read_hello, write_frame, decode_ingest_into, ErrorCode, Frame, FrameReader, Poll,
    ProtoError, Role, WireCounter, WireSnapshot, WireStats, VERSION,
};

/// Where the server listens (or a client connects).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Endpoint {
    /// TCP, `host:port` (port 0 binds an ephemeral port).
    Tcp(String),
    /// Unix domain socket at this path (unix targets only).
    Unix(PathBuf),
}

impl Endpoint {
    /// Connect a client stream to this endpoint.
    pub fn connect(&self) -> std::io::Result<AnyStream> {
        match self {
            Endpoint::Tcp(addr) => TcpStream::connect(addr.as_str()).map(AnyStream::Tcp),
            #[cfg(unix)]
            Endpoint::Unix(path) => UnixStream::connect(path).map(AnyStream::Unix),
            #[cfg(not(unix))]
            Endpoint::Unix(_) => Err(std::io::Error::new(
                std::io::ErrorKind::Unsupported,
                "unix sockets are not available on this target",
            )),
        }
    }
}

impl std::str::FromStr for Endpoint {
    type Err = String;

    /// `unix:/path`, `tcp:host:port`, a bare `/path` (unix) or a bare
    /// `host:port` (tcp).
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        if let Some(path) = s.strip_prefix("unix:") {
            return Ok(Endpoint::Unix(PathBuf::from(path)));
        }
        if let Some(addr) = s.strip_prefix("tcp:") {
            return Ok(Endpoint::Tcp(addr.to_string()));
        }
        if s.starts_with('/') || s.starts_with("./") {
            return Ok(Endpoint::Unix(PathBuf::from(s)));
        }
        if s.contains(':') {
            return Ok(Endpoint::Tcp(s.to_string()));
        }
        Err(format!(
            "unrecognized endpoint '{s}' (want unix:/path, tcp:host:port, /path or host:port)"
        ))
    }
}

impl std::fmt::Display for Endpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Endpoint::Tcp(a) => write!(f, "tcp:{a}"),
            Endpoint::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

/// A connected stream over either transport. Cloning duplicates the OS
/// handle (shared offset), which is how the ingest client splits its
/// writer and ack-reader halves.
#[derive(Debug)]
pub enum AnyStream {
    /// TCP connection.
    Tcp(TcpStream),
    /// Unix-socket connection (unix targets only).
    #[cfg(unix)]
    Unix(UnixStream),
}

impl AnyStream {
    /// Duplicate the OS handle.
    pub fn try_clone(&self) -> std::io::Result<AnyStream> {
        match self {
            AnyStream::Tcp(s) => s.try_clone().map(AnyStream::Tcp),
            #[cfg(unix)]
            AnyStream::Unix(s) => s.try_clone().map(AnyStream::Unix),
        }
    }

    /// Set the read timeout (None = blocking).
    pub fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            AnyStream::Tcp(s) => s.set_read_timeout(d),
            #[cfg(unix)]
            AnyStream::Unix(s) => s.set_read_timeout(d),
        }
    }

    /// Set the write timeout (None = blocking).
    pub fn set_write_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            AnyStream::Tcp(s) => s.set_write_timeout(d),
            #[cfg(unix)]
            AnyStream::Unix(s) => s.set_write_timeout(d),
        }
    }

    /// Half- or full-close the connection.
    pub fn shutdown(&self, how: std::net::Shutdown) -> std::io::Result<()> {
        match self {
            AnyStream::Tcp(s) => s.shutdown(how),
            #[cfg(unix)]
            AnyStream::Unix(s) => s.shutdown(how),
        }
    }
}

impl Read for AnyStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            AnyStream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            AnyStream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for AnyStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            AnyStream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            AnyStream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            AnyStream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            AnyStream::Unix(s) => s.flush(),
        }
    }
}

pub(crate) enum AnyListener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl AnyListener {
    /// Bind on `endpoint`: resolves ephemeral TCP ports and clears
    /// stale unix socket files. Returns the listener, the resolved
    /// endpoint and the unix path the owner must unlink on shutdown.
    pub(crate) fn bind(
        endpoint: &Endpoint,
    ) -> crate::Result<(AnyListener, Endpoint, Option<PathBuf>)> {
        match endpoint {
            Endpoint::Tcp(addr) => {
                let l = TcpListener::bind(addr.as_str())
                    .map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?;
                let actual = l.local_addr()?;
                Ok((AnyListener::Tcp(l), Endpoint::Tcp(actual.to_string()), None))
            }
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                // A stale socket file from a dead server blocks the
                // bind; remove it (connect-refused is the live check a
                // production server would do — this is a demo service).
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)
                    .map_err(|e| anyhow::anyhow!("bind {}: {e}", path.display()))?;
                Ok((
                    AnyListener::Unix(l),
                    Endpoint::Unix(path.clone()),
                    Some(path.clone()),
                ))
            }
            #[cfg(not(unix))]
            Endpoint::Unix(p) => {
                anyhow::bail!("unix endpoint {} unsupported on this target", p.display())
            }
        }
    }

    pub(crate) fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            AnyListener::Tcp(l) => l.set_nonblocking(nb),
            #[cfg(unix)]
            AnyListener::Unix(l) => l.set_nonblocking(nb),
        }
    }

    pub(crate) fn accept(&self) -> std::io::Result<AnyStream> {
        match self {
            AnyListener::Tcp(l) => l.accept().map(|(s, _)| AnyStream::Tcp(s)),
            #[cfg(unix)]
            AnyListener::Unix(l) => l.accept().map(|(s, _)| AnyStream::Unix(s)),
        }
    }
}

/// Server configuration: the coordinator session plus the service
/// shape around it.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// The coordinator session (shards, k, routing, transport,
    /// structure, batch ingest, epoch cadence, delta ring — everything
    /// is selectable over the wire path).
    pub coordinator: CoordinatorConfig,
    /// Query reader pool size.
    pub query_threads: usize,
    /// Maximum concurrent ingest connections; excess connections are
    /// answered `Overloaded` and closed.
    pub max_ingest: usize,
    /// Socket poll granularity: how long an idle connection thread
    /// blocks in a read before re-checking the shutdown flag.
    pub poll: Duration,
    /// How long a freshly accepted peer gets to complete the hello
    /// before the connection is dropped with a [`ErrorCode::Timeout`].
    pub hello_deadline: Duration,
    /// Per-write deadline on every connection: a peer that stops
    /// reading cannot pin a connection thread past this.
    pub write_deadline: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            coordinator: CoordinatorConfig::default(),
            query_threads: 2,
            max_ingest: 64,
            poll: Duration::from_millis(50),
            hello_deadline: Duration::from_secs(5),
            write_deadline: Duration::from_secs(30),
        }
    }
}

/// Shared state between the accept loop, connection threads, the
/// query pool and the handle.
struct Shared {
    coord: Mutex<Option<Coordinator>>,
    /// The drained session result when a wire `SummaryRequest{drain}`
    /// (worker role) finished the coordinator before [`Server::finish`]
    /// could — `finish` falls back to this.
    drained: Mutex<Option<QueryResult>>,
    engine: QueryEngine,
    windows: Option<WindowedQueryEngine>,
    k_majority: u64,
    shutdown: AtomicBool,
    poll: Duration,
    hello_deadline: Duration,
    write_deadline: Duration,
    max_ingest: usize,
    ingest_active: AtomicUsize,
    ingest_conns: AtomicU64,
    query_conns: AtomicU64,
    worker_conns: AtomicU64,
    frames_in: AtomicU64,
    proto_errors: AtomicU64,
    deadline_expirations: AtomicU64,
}

impl Shared {
    fn shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    /// Wire-visible counter snapshot (one brief coordinator lock).
    fn wire_stats(&self) -> WireStats {
        let (items, chunks, recycled, backpressure) = {
            let guard = self.coord.lock().expect("coordinator lock");
            match guard.as_ref() {
                Some(c) => {
                    let s = c.stats();
                    (s.items, s.chunks, s.buffers_recycled, s.backpressure_events)
                }
                None => (0, 0, 0, 0),
            }
        };
        // Landmark + windowed caches, aggregated: one pair of engines
        // is shared by the whole query pool, so these counters already
        // cover every reader thread.
        let cache = self.cache_stats();
        WireStats {
            items,
            chunks,
            buffers_recycled: recycled,
            backpressure_events: backpressure,
            epochs_published: self.engine.registry().epochs_published(),
            ingest_connections: self.ingest_conns.load(Ordering::Relaxed),
            query_connections: self.query_conns.load(Ordering::Relaxed),
            proto_errors: self.proto_errors.load(Ordering::Relaxed),
            cache_hits: cache.hits,
            cache_misses: cache.misses,
            merges_avoided: cache.merges_avoided,
            deadline_expirations: self.deadline_expirations.load(Ordering::Relaxed),
        }
    }

    /// Combined snapshot-cache accounting over the landmark engine and
    /// (when a delta ring runs) the windowed engine.
    fn cache_stats(&self) -> crate::metrics::CacheStats {
        let l = self.engine.cache_stats();
        let w = self
            .windows
            .as_ref()
            .map(|e| e.cache_stats())
            .unwrap_or_default();
        crate::metrics::CacheStats {
            hits: l.hits + w.hits,
            misses: l.misses + w.misses,
            merges_avoided: l.merges_avoided + w.merges_avoided,
        }
    }
}

/// Service-layer statistics reported by [`Server::finish`] alongside
/// the coordinator's [`QueryResult`].
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Ingest connections accepted over the server's lifetime.
    pub ingest_connections: u64,
    /// Query connections accepted over the server's lifetime.
    pub query_connections: u64,
    /// Worker (cluster-head) connections accepted over the server's
    /// lifetime.
    pub worker_connections: u64,
    /// Frames received (all roles).
    pub frames: u64,
    /// Connections terminated with a protocol error.
    pub proto_errors: u64,
    /// Connections closed because a read or write deadline expired
    /// (counted within `proto_errors` too).
    pub deadline_expirations: u64,
    /// Snapshot-cache accounting over the server's query engines
    /// (landmark + windowed, summed across the query pool).
    pub cache: crate::metrics::CacheStats,
}

/// A running `pss` server. Bind with [`Server::bind`], stop with
/// [`Server::request_shutdown`] (or a wire [`Frame::Shutdown`]), then
/// collect the drained session with [`Server::finish`].
pub struct Server {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    conn_threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
    pool: Vec<JoinHandle<()>>,
    endpoint: Endpoint,
    /// Unix-socket path to unlink on finish.
    unix_path: Option<PathBuf>,
}

impl Server {
    /// Bind the listener, spawn the coordinator session, the accept
    /// loop and the query pool. For TCP with port 0, the returned
    /// server's [`Server::endpoint`] carries the resolved port.
    pub fn bind(endpoint: &Endpoint, cfg: ServeConfig) -> crate::Result<Server> {
        anyhow::ensure!(cfg.query_threads >= 1, "query_threads must be >= 1");
        anyhow::ensure!(cfg.max_ingest >= 1, "max_ingest must be >= 1");
        let (listener, endpoint, unix_path) = AnyListener::bind(endpoint)?;
        listener.set_nonblocking(true)?;

        let k_majority = cfg.coordinator.k_majority;
        let (coord, engine) = Coordinator::spawn(cfg.coordinator.clone());
        let windows = coord.windows();
        let shared = Arc::new(Shared {
            coord: Mutex::new(Some(coord)),
            drained: Mutex::new(None),
            engine,
            windows,
            k_majority,
            shutdown: AtomicBool::new(false),
            poll: cfg.poll,
            hello_deadline: cfg.hello_deadline,
            write_deadline: cfg.write_deadline,
            max_ingest: cfg.max_ingest,
            ingest_active: AtomicUsize::new(0),
            ingest_conns: AtomicU64::new(0),
            query_conns: AtomicU64::new(0),
            worker_conns: AtomicU64::new(0),
            frames_in: AtomicU64::new(0),
            proto_errors: AtomicU64::new(0),
            deadline_expirations: AtomicU64::new(0),
        });

        // Query pool: fixed worker threads pulling accepted query
        // connections off a shared channel.
        let (query_tx, query_rx) = channel::<AnyStream>();
        let query_rx = Arc::new(Mutex::new(query_rx));
        let pool = (0..cfg.query_threads)
            .map(|i| {
                let shared = shared.clone();
                let rx = query_rx.clone();
                std::thread::Builder::new()
                    .name(format!("pss-query-{i}"))
                    .spawn(move || query_worker(&shared, &rx))
                    .expect("spawn query worker")
            })
            .collect();

        let conn_threads = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let shared = shared.clone();
            let conn_threads = conn_threads.clone();
            std::thread::Builder::new()
                .name("pss-accept".into())
                .spawn(move || accept_loop(&listener, &shared, &conn_threads, &query_tx))
                .expect("spawn accept loop")
        };

        Ok(Server {
            shared,
            accept: Some(accept),
            conn_threads,
            pool,
            endpoint,
            unix_path,
        })
    }

    /// The bound endpoint (TCP port resolved).
    pub fn endpoint(&self) -> &Endpoint {
        &self.endpoint
    }

    /// In-process live query handle over the same epoch snapshots the
    /// wire queries answer from.
    pub fn queries(&self) -> QueryEngine {
        self.shared.engine.clone()
    }

    /// In-process windowed query handle (`Some` iff the session runs a
    /// delta ring).
    pub fn windows(&self) -> Option<WindowedQueryEngine> {
        self.shared.windows.clone()
    }

    /// Begin the drain: stop accepting, let connections finish their
    /// in-flight frames and close.
    pub fn request_shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
    }

    /// Whether a shutdown (handle- or wire-initiated) is in progress.
    pub fn shutdown_requested(&self) -> bool {
        self.shared.shutting_down()
    }

    /// Block until shutdown is requested (wire `Shutdown` frame or
    /// another handle), or until `max` elapses — at which point the
    /// shutdown is initiated here.
    pub fn wait_shutdown(&self, max: Option<Duration>) {
        let deadline = max.map(|d| Instant::now() + d);
        while !self.shared.shutting_down() {
            if deadline.is_some_and(|d| Instant::now() >= d) {
                self.request_shutdown();
                break;
            }
            std::thread::sleep(Duration::from_millis(25));
        }
    }

    /// Drain and stop: joins the accept loop, every connection thread
    /// and the query pool, then finishes the coordinator session.
    /// Returns the final merged [`QueryResult`] plus service counters.
    pub fn finish(mut self) -> (QueryResult, ServeStats) {
        self.request_shutdown();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // The accept loop has exited, so no new connection threads can
        // appear; join what is there.
        let handles = {
            let mut guard = self.conn_threads.lock().expect("conn threads lock");
            std::mem::take(&mut *guard)
        };
        for h in handles {
            let _ = h.join();
        }
        for h in self.pool.drain(..) {
            let _ = h.join();
        }
        let coord = self.shared.coord.lock().expect("coordinator lock").take();
        let result = match coord {
            Some(c) => c.finish(),
            // A wire-level drain (worker role, `SummaryRequest{drain}`)
            // already finished the session; hand out its stored result.
            None => self
                .shared
                .drained
                .lock()
                .expect("drained result lock")
                .take()
                .expect("server finished twice"),
        };
        if let Some(path) = self.unix_path.take() {
            let _ = std::fs::remove_file(path);
        }
        let stats = ServeStats {
            ingest_connections: self.shared.ingest_conns.load(Ordering::Relaxed),
            query_connections: self.shared.query_conns.load(Ordering::Relaxed),
            worker_connections: self.shared.worker_conns.load(Ordering::Relaxed),
            frames: self.shared.frames_in.load(Ordering::Relaxed),
            proto_errors: self.shared.proto_errors.load(Ordering::Relaxed),
            deadline_expirations: self.shared.deadline_expirations.load(Ordering::Relaxed),
            cache: self.shared.cache_stats(),
        };
        (result, stats)
    }
}

/// Accept until shutdown. Each accepted stream gets a greeter thread
/// that validates the hello and becomes the ingest handler (ingest
/// role) or hands the stream to the query pool (query role) — so a
/// peer that connects and stalls mid-hello never blocks the accept
/// loop.
fn accept_loop(
    listener: &AnyListener,
    shared: &Arc<Shared>,
    conn_threads: &Arc<Mutex<Vec<JoinHandle<()>>>>,
    query_tx: &Sender<AnyStream>,
) {
    while !shared.shutting_down() {
        match listener.accept() {
            Ok(stream) => {
                let shared = shared.clone();
                let query_tx = query_tx.clone();
                let handle = std::thread::Builder::new()
                    .name("pss-conn".into())
                    .spawn(move || greet(stream, &shared, &query_tx))
                    .expect("spawn connection thread");
                let mut guard = conn_threads.lock().expect("conn threads lock");
                // Reap finished handlers so a long session with many
                // reconnects does not accumulate join handles.
                let (done, live): (Vec<_>, Vec<_>) =
                    guard.drain(..).partition(|h| h.is_finished());
                for h in done {
                    let _ = h.join();
                }
                *guard = live;
                guard.push(handle);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => break, // listener gone
        }
    }
}

fn send_error(stream: &mut AnyStream, wire: &mut Vec<u8>, code: ErrorCode, message: String) {
    let _ = write_frame(stream, &Frame::Error { code, message }, wire);
    let _ = stream.shutdown(std::net::Shutdown::Both);
}

/// Record a protocol failure (deadline expiries separately), answer the
/// peer with the typed error, and close the connection.
fn fail_conn(stream: &mut AnyStream, shared: &Shared, wire: &mut Vec<u8>, e: &ProtoError) {
    shared.proto_errors.fetch_add(1, Ordering::Relaxed);
    if matches!(e, ProtoError::Timeout) {
        shared.deadline_expirations.fetch_add(1, Ordering::Relaxed);
    }
    send_error(stream, wire, e.code(), e.to_string());
}

/// Validate the hello and dispatch the connection by role.
fn greet(mut stream: AnyStream, shared: &Arc<Shared>, query_tx: &Sender<AnyStream>) {
    let mut wire = Vec::new();
    // A peer gets `hello_deadline` to say hello (an expired deadline
    // surfaces as a typed `ProtoError::Timeout`); the write side is
    // bounded so a peer that never reads cannot pin this thread
    // forever.
    let _ = stream.set_read_timeout(Some(shared.hello_deadline));
    let _ = stream.set_write_timeout(Some(shared.write_deadline));
    let role = match read_hello(&mut stream) {
        Ok(role) => role,
        Err(e) => {
            fail_conn(&mut stream, shared, &mut wire, &e);
            return;
        }
    };
    if shared.shutting_down() {
        send_error(
            &mut stream,
            &mut wire,
            ErrorCode::ShuttingDown,
            "server is draining".into(),
        );
        return;
    }
    if write_frame(&mut stream, &Frame::HelloOk { version: VERSION }, &mut wire).is_err() {
        return;
    }
    // From here the connection polls so it can observe shutdown.
    let _ = stream.set_read_timeout(Some(shared.poll));
    match role {
        Role::Ingest => {
            if shared.ingest_active.fetch_add(1, Ordering::AcqRel) >= shared.max_ingest {
                shared.ingest_active.fetch_sub(1, Ordering::AcqRel);
                send_error(
                    &mut stream,
                    &mut wire,
                    ErrorCode::Overloaded,
                    format!("ingest connection limit {} reached", shared.max_ingest),
                );
                return;
            }
            shared.ingest_conns.fetch_add(1, Ordering::Relaxed);
            ingest_conn(&mut stream, shared, &mut wire);
            shared.ingest_active.fetch_sub(1, Ordering::AcqRel);
        }
        Role::Query => {
            shared.query_conns.fetch_add(1, Ordering::Relaxed);
            // Hand off to the pool; if the pool is gone (drain), tell
            // the peer and close.
            if query_tx.send(stream).is_err() {
                // Stream moved into the failed send; nothing to do.
            }
        }
        Role::Worker => {
            shared.worker_conns.fetch_add(1, Ordering::Relaxed);
            worker_conn(&mut stream, shared, &mut wire);
        }
    }
}

/// One ingest connection: frames → recycled chunk buffers → the
/// coordinator, acked per frame.
fn ingest_conn(stream: &mut AnyStream, shared: &Arc<Shared>, wire: &mut Vec<u8>) {
    let mut reader = FrameReader::new();
    loop {
        // Honor the drain at every frame boundary — a peer streaming
        // frames back-to-back keeps the socket readable, so the
        // Pending arm alone would never observe the flag and the
        // connection would ingest past the requested shutdown.
        // Mid-frame the peer keeps the right to complete (and get the
        // ack for) what it started.
        if shared.shutting_down() && !reader.mid_frame() {
            send_error(
                stream,
                wire,
                ErrorCode::ShuttingDown,
                "server is draining".into(),
            );
            return;
        }
        match reader.poll(stream) {
            Ok(Poll::Frame(kind, body)) => {
                shared.frames_in.fetch_add(1, Ordering::Relaxed);
                // Borrow a recycled chunk buffer (short lock), decode
                // outside the lock, push (second short lock).
                let mut chunk = {
                    let mut guard = shared.coord.lock().expect("coordinator lock");
                    match guard.as_mut() {
                        Some(c) => c.take_buffer(),
                        None => return,
                    }
                };
                match decode_ingest_into(kind, body, &mut chunk) {
                    Ok(Some((seq, mass))) => {
                        if !push_with_backpressure(shared, chunk) {
                            send_error(
                                stream,
                                wire,
                                ErrorCode::ShuttingDown,
                                "coordinator gone".into(),
                            );
                            return;
                        }
                        if write_frame(stream, &Frame::IngestAck { seq, items: mass }, wire)
                            .is_err()
                        {
                            return;
                        }
                    }
                    Ok(None) => {
                        shared.proto_errors.fetch_add(1, Ordering::Relaxed);
                        send_error(
                            stream,
                            wire,
                            ErrorCode::WrongRole,
                            format!("frame kind {kind:#04x} not valid on an ingest connection"),
                        );
                        return;
                    }
                    Err(e) => {
                        shared.proto_errors.fetch_add(1, Ordering::Relaxed);
                        send_error(stream, wire, e.code(), e.to_string());
                        return;
                    }
                }
            }
            // Idle: loop back to the boundary check above.
            Ok(Poll::Pending) => {}
            Ok(Poll::Eof) => return, // clean close
            Err(e) => {
                fail_conn(stream, shared, wire, &e);
                return;
            }
        }
    }
}

/// Route one chunk, releasing the coordinator lock between
/// backpressure retries so other connections (and buffer reclaim)
/// stay live while a shard is saturated. Returns false when the
/// coordinator is gone or a shard worker died.
fn push_with_backpressure(shared: &Arc<Shared>, chunk: Vec<u64>) -> bool {
    let mut pending = chunk;
    loop {
        let outcome = {
            let mut guard = shared.coord.lock().expect("coordinator lock");
            match guard.as_mut() {
                Some(c) => c.try_push(std::mem::take(&mut pending)),
                None => return false,
            }
        };
        match outcome {
            Ok(()) => return true,
            Err(PushError::Full { chunk, .. }) => {
                pending = chunk;
                std::thread::sleep(Duration::from_micros(200));
            }
            Err(PushError::Disconnected { .. }) => return false,
        }
    }
}

/// One query-pool worker: serve connections off the channel until the
/// channel closes (accept loop gone) and no connection is in hand.
fn query_worker(shared: &Arc<Shared>, rx: &Arc<Mutex<Receiver<AnyStream>>>) {
    loop {
        let next = {
            let guard = rx.lock().expect("query rx lock");
            guard.recv_timeout(shared.poll)
        };
        match next {
            Ok(mut stream) => query_conn(&mut stream, shared),
            Err(RecvTimeoutError::Timeout) => {
                if shared.shutting_down() {
                    return;
                }
            }
            Err(RecvTimeoutError::Disconnected) => return,
        }
    }
}

fn counters_to_wire(counters: &[crate::summary::Counter]) -> Vec<WireCounter> {
    counters
        .iter()
        .map(|c| WireCounter { item: c.item, count: c.count, err: c.err })
        .collect()
}

/// Serve one query connection to completion.
fn query_conn(stream: &mut AnyStream, shared: &Arc<Shared>) {
    let mut reader = FrameReader::new();
    let mut wire = Vec::new();
    loop {
        // Same boundary check as `ingest_conn`: a pipelined query
        // client keeps the socket readable, so only checking in the
        // Pending arm would let queries run past the drain forever.
        if shared.shutting_down() && !reader.mid_frame() {
            send_error(
                stream,
                &mut wire,
                ErrorCode::ShuttingDown,
                "server is draining".into(),
            );
            return;
        }
        match reader.poll(stream) {
            Ok(Poll::Frame(kind, body)) => {
                shared.frames_in.fetch_add(1, Ordering::Relaxed);
                let frame = match Frame::decode(kind, body) {
                    Ok(f) => f,
                    Err(e) => {
                        shared.proto_errors.fetch_add(1, Ordering::Relaxed);
                        send_error(stream, &mut wire, e.code(), e.to_string());
                        return;
                    }
                };
                let reply = match answer_query(shared, &frame) {
                    Some(r) => r,
                    None => {
                        shared.proto_errors.fetch_add(1, Ordering::Relaxed);
                        send_error(
                            stream,
                            &mut wire,
                            ErrorCode::WrongRole,
                            format!("frame kind {kind:#04x} not valid on a query connection"),
                        );
                        return;
                    }
                };
                let is_shutdown = matches!(reply, Frame::ShutdownAck);
                if write_frame(stream, &reply, &mut wire).is_err() {
                    return;
                }
                if is_shutdown {
                    // The drain begins; this connection is done.
                    let _ = stream.shutdown(std::net::Shutdown::Both);
                    return;
                }
            }
            // Idle: loop back to the boundary check above.
            Ok(Poll::Pending) => {}
            Ok(Poll::Eof) => return,
            Err(e) => {
                fail_conn(stream, shared, &mut wire, &e);
                return;
            }
        }
    }
}

/// Answer one query frame from the snapshot engines. `None` marks a
/// frame that is not a query (role error).
fn answer_query(shared: &Arc<Shared>, frame: &Frame) -> Option<Frame> {
    let windowed = |w: u32| -> Result<Arc<crate::window::WindowSnapshot>, Frame> {
        match shared.windows.as_ref() {
            Some(eng) => Ok(eng.window(w as usize)),
            None => Err(Frame::Error {
                code: ErrorCode::WindowUnavailable,
                message: "server runs no delta ring (start with --delta-ring N)".into(),
            }),
        }
    };
    Some(match *frame {
        Frame::TopK { m, window_epochs: 0 } => {
            let snap = shared.engine.snapshot();
            Frame::TopKResult {
                n: snap.n(),
                epsilon: snap.epsilon(),
                counters: counters_to_wire(&snap.top_k(m as usize)),
            }
        }
        Frame::TopK { m, window_epochs } => match windowed(window_epochs) {
            Ok(win) => Frame::TopKResult {
                n: win.n(),
                epsilon: win.epsilon(),
                counters: counters_to_wire(&win.top_k(m as usize)),
            },
            Err(e) => e,
        },
        Frame::Point { item, window_epochs: 0 } => {
            let p = shared.engine.snapshot().point(item);
            Frame::PointResult {
                estimate: p.estimate,
                guaranteed: p.guaranteed,
                monitored: p.monitored,
                n: p.n,
            }
        }
        Frame::Point { item, window_epochs } => match windowed(window_epochs) {
            Ok(win) => {
                let p = win.point(item);
                Frame::PointResult {
                    estimate: p.estimate,
                    guaranteed: p.guaranteed,
                    monitored: p.monitored,
                    n: p.n,
                }
            }
            Err(e) => e,
        },
        Frame::KMajority { k, window_epochs } => {
            let k = if k < 2 { shared.k_majority } else { k };
            let report = if window_epochs == 0 {
                shared.engine.snapshot().k_majority(k)
            } else {
                match windowed(window_epochs) {
                    Ok(win) => win.k_majority(k),
                    Err(e) => return Some(e),
                }
            };
            Frame::KMajorityResult {
                n: report.n,
                epsilon: report.epsilon,
                threshold: report.threshold,
                guaranteed: counters_to_wire(&report.guaranteed),
                possible: counters_to_wire(&report.possible),
            }
        }
        Frame::Stats => Frame::StatsResult(shared.wire_stats()),
        Frame::Shutdown => {
            shared.shutdown.store(true, Ordering::Release);
            Frame::ShutdownAck
        }
        _ => return None,
    })
}

/// Export the engine's current merged view as a wire snapshot: the
/// pre-absorb summary, the exact hot table with its history bounds,
/// and the worker-computed bound metadata. The head replays the absorb
/// itself ([`MergedSnapshot::hot_exports`](crate::query::MergedSnapshot::hot_exports)),
/// so the exported state reproduces this node's answers exactly.
fn export_snapshot(shared: &Arc<Shared>) -> WireSnapshot {
    let snap = shared.engine.snapshot();
    let ss = snap.ss_summary();
    WireSnapshot {
        epoch: snap.max_epoch(),
        n: ss.n(),
        k: ss.k() as u64,
        epsilon: snap.epsilon(),
        min_count: snap.unmonitored_bound(),
        disjoint: snap.is_disjoint(),
        finished: snap.all_finished(),
        counters: counters_to_wire(ss.counters()),
        hot: snap
            .hot_exports()
            .into_iter()
            .map(|(item, count, err)| WireCounter { item, count, err })
            .collect(),
    }
}

/// One cluster-head connection: answer [`Frame::SummaryRequest`]s with
/// full summary exports; a `drain` request finishes the coordinator
/// (stowing the [`QueryResult`] for [`Server::finish`]), replies with
/// the final snapshot and initiates the server shutdown.
fn worker_conn(stream: &mut AnyStream, shared: &Arc<Shared>, wire: &mut Vec<u8>) {
    let mut reader = FrameReader::new();
    loop {
        // Same frame-boundary drain check as the other roles.
        if shared.shutting_down() && !reader.mid_frame() {
            send_error(
                stream,
                wire,
                ErrorCode::ShuttingDown,
                "server is draining".into(),
            );
            return;
        }
        match reader.poll(stream) {
            Ok(Poll::Frame(kind, body)) => {
                shared.frames_in.fetch_add(1, Ordering::Relaxed);
                let frame = match Frame::decode(kind, body) {
                    Ok(f) => f,
                    Err(e) => {
                        shared.proto_errors.fetch_add(1, Ordering::Relaxed);
                        send_error(stream, wire, e.code(), e.to_string());
                        return;
                    }
                };
                match frame {
                    Frame::SummaryRequest { drain: false } => {
                        // Prompt the shards to republish (lands
                        // asynchronously; the head polls), then export
                        // the freshest published view.
                        shared.engine.refresh();
                        let snap = export_snapshot(shared);
                        if write_frame(stream, &Frame::SummarySnapshot(snap), wire).is_err()
                        {
                            return;
                        }
                    }
                    Frame::SummaryRequest { drain: true } => {
                        // Drain the session (idempotent: a second drain
                        // request re-exports the already-final state).
                        let coord =
                            shared.coord.lock().expect("coordinator lock").take();
                        if let Some(c) = coord {
                            let result = c.finish();
                            *shared.drained.lock().expect("drained result lock") =
                                Some(result);
                        }
                        let snap = export_snapshot(shared);
                        let _ = write_frame(stream, &Frame::SummarySnapshot(snap), wire);
                        // Flip the flag last so the reply above is
                        // never pre-empted by this conn's own boundary
                        // check.
                        shared.shutdown.store(true, Ordering::Release);
                        let _ = stream.shutdown(std::net::Shutdown::Both);
                        return;
                    }
                    _ => {
                        shared.proto_errors.fetch_add(1, Ordering::Relaxed);
                        send_error(
                            stream,
                            wire,
                            ErrorCode::WrongRole,
                            format!("frame kind {kind:#04x} not valid on a worker connection"),
                        );
                        return;
                    }
                }
            }
            // Idle: loop back to the boundary check above.
            Ok(Poll::Pending) => {}
            Ok(Poll::Eof) => return,
            Err(e) => {
                fail_conn(stream, shared, wire, &e);
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::proto::encode_hello;
    use crate::util::TempDir;

    fn tiny_cfg() -> ServeConfig {
        ServeConfig {
            coordinator: CoordinatorConfig {
                shards: 2,
                k: 64,
                k_majority: 8,
                epoch_items: 100,
                ..Default::default()
            },
            query_threads: 1,
            ..Default::default()
        }
    }

    fn read_one(stream: &mut AnyStream) -> Frame {
        let mut r = FrameReader::new();
        loop {
            match r.poll(stream).expect("frame") {
                Poll::Frame(k, body) => return Frame::decode(k, body).expect("decode"),
                Poll::Pending => continue,
                Poll::Eof => panic!("eof before frame"),
            }
        }
    }

    #[test]
    fn endpoint_parses_and_displays() {
        assert_eq!(
            "unix:/tmp/x.sock".parse::<Endpoint>().unwrap(),
            Endpoint::Unix(PathBuf::from("/tmp/x.sock"))
        );
        assert_eq!(
            "/tmp/x.sock".parse::<Endpoint>().unwrap(),
            Endpoint::Unix(PathBuf::from("/tmp/x.sock"))
        );
        assert_eq!(
            "tcp:127.0.0.1:9009".parse::<Endpoint>().unwrap(),
            Endpoint::Tcp("127.0.0.1:9009".into())
        );
        assert_eq!(
            "127.0.0.1:0".parse::<Endpoint>().unwrap(),
            Endpoint::Tcp("127.0.0.1:0".into())
        );
        assert!("florp".parse::<Endpoint>().is_err());
        assert_eq!(
            "unix:/a/b".parse::<Endpoint>().unwrap().to_string(),
            "unix:/a/b"
        );
    }

    #[test]
    fn tcp_hello_ingest_ack_and_query_roundtrip() {
        let server = Server::bind(&"127.0.0.1:0".parse().unwrap(), tiny_cfg()).unwrap();
        let endpoint = server.endpoint().clone();

        // Ingest connection: hello, one frame, one ack.
        let mut ing = endpoint.connect().unwrap();
        ing.write_all(&encode_hello(Role::Ingest)).unwrap();
        assert_eq!(read_one(&mut ing), Frame::HelloOk { version: VERSION });
        let mut wire = Vec::new();
        write_frame(
            &mut ing,
            &Frame::IngestItems { seq: 1, items: vec![42; 500] },
            &mut wire,
        )
        .unwrap();
        assert_eq!(read_one(&mut ing), Frame::IngestAck { seq: 1, items: 500 });
        // Runs shape too.
        write_frame(
            &mut ing,
            &Frame::IngestRuns { seq: 2, runs: vec![(42, 250), (7, 250)] },
            &mut wire,
        )
        .unwrap();
        assert_eq!(read_one(&mut ing), Frame::IngestAck { seq: 2, items: 500 });
        drop(ing);

        // Query connection: point lookup sees the ingested mass after
        // a refresh (cadence 100 already forced epochs).
        let mut q = endpoint.connect().unwrap();
        q.write_all(&encode_hello(Role::Query)).unwrap();
        assert_eq!(read_one(&mut q), Frame::HelloOk { version: VERSION });
        server.queries().refresh();
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            write_frame(&mut q, &Frame::Point { item: 42, window_epochs: 0 }, &mut wire)
                .unwrap();
            match read_one(&mut q) {
                Frame::PointResult { estimate, n, .. } if n >= 1000 => {
                    assert_eq!(estimate, 750);
                    break;
                }
                Frame::PointResult { .. } => {
                    assert!(Instant::now() < deadline, "epochs never covered ingest");
                    std::thread::sleep(Duration::from_millis(5));
                    server.queries().refresh();
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // Stats over the wire.
        write_frame(&mut q, &Frame::Stats, &mut wire).unwrap();
        match read_one(&mut q) {
            Frame::StatsResult(s) => {
                assert_eq!(s.items, 1000);
                assert_eq!(s.ingest_connections, 1);
                assert!(s.query_connections >= 1);
            }
            other => panic!("unexpected {other:?}"),
        }
        // Wire-initiated shutdown.
        write_frame(&mut q, &Frame::Shutdown, &mut wire).unwrap();
        assert_eq!(read_one(&mut q), Frame::ShutdownAck);
        let (result, stats) = server.finish();
        assert_eq!(result.stats.items, 1000);
        assert_eq!(stats.ingest_connections, 1);
        assert_eq!(stats.proto_errors, 0);
    }

    #[cfg(unix)]
    #[test]
    fn unix_socket_serves_and_cleans_up() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("pss.sock");
        let endpoint = Endpoint::Unix(path.clone());
        let server = Server::bind(&endpoint, tiny_cfg()).unwrap();
        assert!(path.exists());
        let mut ing = endpoint.connect().unwrap();
        ing.write_all(&encode_hello(Role::Ingest)).unwrap();
        assert_eq!(read_one(&mut ing), Frame::HelloOk { version: VERSION });
        let mut wire = Vec::new();
        write_frame(
            &mut ing,
            &Frame::IngestItems { seq: 9, items: vec![1, 2, 3] },
            &mut wire,
        )
        .unwrap();
        assert_eq!(read_one(&mut ing), Frame::IngestAck { seq: 9, items: 3 });
        drop(ing);
        server.request_shutdown();
        let (result, _) = server.finish();
        assert_eq!(result.stats.items, 3);
        assert!(!path.exists(), "socket file unlinked on finish");
    }

    #[test]
    fn bad_magic_gets_typed_error_and_close() {
        let server = Server::bind(&"127.0.0.1:0".parse().unwrap(), tiny_cfg()).unwrap();
        let mut s = server.endpoint().connect().unwrap();
        s.write_all(b"GARBAGE!").unwrap();
        match read_one(&mut s) {
            Frame::Error { code, .. } => assert_eq!(code, ErrorCode::BadMagic),
            other => panic!("unexpected {other:?}"),
        }
        // The connection is closed afterwards...
        let mut reader = FrameReader::new();
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            match reader.poll(&mut s) {
                Ok(Poll::Eof) | Err(_) => break,
                Ok(Poll::Frame(..)) => panic!("frame after error"),
                Ok(Poll::Pending) => assert!(Instant::now() < deadline, "no close"),
            }
        }
        // ...but the server keeps serving new connections.
        let mut ok = server.endpoint().connect().unwrap();
        ok.write_all(&encode_hello(Role::Query)).unwrap();
        assert_eq!(read_one(&mut ok), Frame::HelloOk { version: VERSION });
        let (_, stats) = server.finish();
        assert_eq!(stats.proto_errors, 1);
    }

    #[test]
    fn worker_conn_exports_snapshots_and_drains() {
        let server = Server::bind(&"127.0.0.1:0".parse().unwrap(), tiny_cfg()).unwrap();
        let endpoint = server.endpoint().clone();
        let mut wire = Vec::new();

        // Feed a deterministic stream: 600×42, 400×7.
        let mut ing = endpoint.connect().unwrap();
        ing.write_all(&encode_hello(Role::Ingest)).unwrap();
        assert_eq!(read_one(&mut ing), Frame::HelloOk { version: VERSION });
        write_frame(
            &mut ing,
            &Frame::IngestRuns { seq: 1, runs: vec![(42, 600), (7, 400)] },
            &mut wire,
        )
        .unwrap();
        assert_eq!(read_one(&mut ing), Frame::IngestAck { seq: 1, items: 1000 });
        drop(ing);

        // Worker connection: poll until the published epochs cover the
        // ingested mass, then drain.
        let mut w = endpoint.connect().unwrap();
        w.write_all(&encode_hello(Role::Worker)).unwrap();
        assert_eq!(read_one(&mut w), Frame::HelloOk { version: VERSION });
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            write_frame(&mut w, &Frame::SummaryRequest { drain: false }, &mut wire)
                .unwrap();
            match read_one(&mut w) {
                Frame::SummarySnapshot(s) if s.total_mass() >= 1000 => {
                    assert!(!s.finished);
                    assert!(s.epoch >= 1);
                    // k=64 per shard, 2 shards under-full: exact counts.
                    let c42 =
                        s.counters.iter().find(|c| c.item == 42).expect("42 monitored");
                    assert_eq!(c42.count, 600);
                    break;
                }
                Frame::SummarySnapshot(_) => {
                    assert!(Instant::now() < deadline, "epochs never covered ingest");
                    std::thread::sleep(Duration::from_millis(5));
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // Drain: the final snapshot is finished and exact.
        write_frame(&mut w, &Frame::SummaryRequest { drain: true }, &mut wire).unwrap();
        match read_one(&mut w) {
            Frame::SummarySnapshot(s) => {
                assert!(s.finished, "drain reply must be the final state");
                assert_eq!(s.total_mass(), 1000);
                assert_eq!(
                    s.counters.iter().find(|c| c.item == 7).map(|c| c.count),
                    Some(400)
                );
            }
            other => panic!("unexpected {other:?}"),
        }
        // The wire drain already finished the session; the handle's
        // finish() hands out the stowed result instead of panicking.
        assert!(server.shutdown_requested());
        let (result, stats) = server.finish();
        assert_eq!(result.stats.items, 1000);
        assert_eq!(result.summary.estimate(42), Some(600));
        assert_eq!(stats.worker_connections, 1);
        assert_eq!(stats.proto_errors, 0);
    }

    #[test]
    fn ingest_frame_on_worker_conn_is_role_error() {
        let server = Server::bind(&"127.0.0.1:0".parse().unwrap(), tiny_cfg()).unwrap();
        let mut w = server.endpoint().connect().unwrap();
        w.write_all(&encode_hello(Role::Worker)).unwrap();
        assert_eq!(read_one(&mut w), Frame::HelloOk { version: VERSION });
        let mut wire = Vec::new();
        write_frame(&mut w, &Frame::IngestItems { seq: 1, items: vec![1] }, &mut wire)
            .unwrap();
        match read_one(&mut w) {
            Frame::Error { code, .. } => assert_eq!(code, ErrorCode::WrongRole),
            other => panic!("unexpected {other:?}"),
        }
        server.finish();
    }

    #[test]
    fn query_frame_on_ingest_conn_is_role_error() {
        let server = Server::bind(&"127.0.0.1:0".parse().unwrap(), tiny_cfg()).unwrap();
        let mut s = server.endpoint().connect().unwrap();
        s.write_all(&encode_hello(Role::Ingest)).unwrap();
        assert_eq!(read_one(&mut s), Frame::HelloOk { version: VERSION });
        let mut wire = Vec::new();
        write_frame(&mut s, &Frame::TopK { m: 5, window_epochs: 0 }, &mut wire).unwrap();
        match read_one(&mut s) {
            Frame::Error { code, .. } => assert_eq!(code, ErrorCode::WrongRole),
            other => panic!("unexpected {other:?}"),
        }
        server.finish();
    }

    #[test]
    fn window_query_without_ring_is_typed_error() {
        let server = Server::bind(&"127.0.0.1:0".parse().unwrap(), tiny_cfg()).unwrap();
        let mut q = server.endpoint().connect().unwrap();
        q.write_all(&encode_hello(Role::Query)).unwrap();
        assert_eq!(read_one(&mut q), Frame::HelloOk { version: VERSION });
        let mut wire = Vec::new();
        write_frame(&mut q, &Frame::TopK { m: 5, window_epochs: 4 }, &mut wire).unwrap();
        match read_one(&mut q) {
            Frame::Error { code, .. } => assert_eq!(code, ErrorCode::WindowUnavailable),
            other => panic!("unexpected {other:?}"),
        }
        server.finish();
    }
}
