//! Client side of the wire protocol: typed ingest/query clients plus
//! the multi-threaded load generator behind `pss loadgen`.
//!
//! [`IngestClient`] pipelines ingest frames with a bounded in-flight
//! window — it keeps writing while acks trail behind, so one
//! connection can saturate the socket without unbounded buffering —
//! and attributes each ack round trip to a [`LatencyHistogram`]
//! sample. [`QueryClient`] speaks the query frames and hands back the
//! *same* answer types the in-process engines produce
//! ([`PointEstimate`], [`ThresholdReport`]), so a caller can swap
//! in-process and over-the-wire query paths without touching its
//! result handling.
//!
//! [`run_loadgen`] drives N concurrent ingest connections from the
//! `gen/` workload generators (one deterministic source per client,
//! seeds `seed..seed+N`) and folds the per-client histograms with
//! [`LatencyHistogram::merge`] into one end-to-end report.

use std::collections::VecDeque;
use std::io::Write as _;
use std::time::{Duration, Instant};

use crate::gen::{GeneratedSource, ItemSource};
use crate::metrics::{LatencyHistogram, LatencySummary};
use crate::query::{PointEstimate, ThresholdReport};
use crate::summary::{ChunkAggregator, Counter};

use super::proto::{
    encode_hello, encode_items_into, encode_runs_into, read_frame, write_frame, Frame, Role,
    WireSnapshot, WireStats, MAX_FRAME_MASS, MAX_ITEMS_PER_FRAME, MAX_RUNS_PER_FRAME, VERSION,
};
use super::server::{AnyStream, Endpoint};

/// Connect, send the hello, and require a `HelloOk`.
fn handshake(endpoint: &Endpoint, role: Role) -> crate::Result<AnyStream> {
    let mut stream = endpoint
        .connect()
        .map_err(|e| anyhow::anyhow!("connect {endpoint}: {e}"))?;
    // Client reads are blocking with a generous safety-net timeout so a
    // wedged server fails loudly instead of hanging the caller forever.
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    stream.set_write_timeout(Some(Duration::from_secs(30)))?;
    stream.write_all(&encode_hello(role))?;
    stream.flush()?;
    let mut scratch = Vec::new();
    match read_frame(&mut stream, &mut scratch)? {
        Some((kind, body)) => match Frame::decode(kind, body)? {
            Frame::HelloOk { version } => {
                anyhow::ensure!(
                    version == VERSION,
                    "server speaks protocol v{version}, client v{VERSION}"
                );
                Ok(stream)
            }
            Frame::Error { code, message } => {
                anyhow::bail!("server rejected hello ({code:?}): {message}")
            }
            other => anyhow::bail!("unexpected reply to hello: {other:?}"),
        },
        None => anyhow::bail!("server closed during handshake"),
    }
}

/// A pipelined ingest connection: one wire producer.
///
/// Frames carry a client sequence number; the server acks each one,
/// and the client bounds unacked frames at `max_inflight` — writes
/// overlap with acks (pipelining) but memory and latency attribution
/// stay bounded. Every ack round trip lands in the client's
/// [`LatencyHistogram`].
pub struct IngestClient {
    stream: AnyStream,
    wire: Vec<u8>,
    scratch: Vec<u8>,
    seq: u64,
    inflight: VecDeque<(u64, Instant)>,
    max_inflight: usize,
    latency: LatencyHistogram,
    acked_items: u64,
    frames: u64,
}

impl IngestClient {
    /// Connect and handshake as an ingest producer.
    pub fn connect(endpoint: &Endpoint) -> crate::Result<IngestClient> {
        Ok(IngestClient {
            stream: handshake(endpoint, Role::Ingest)?,
            wire: Vec::new(),
            scratch: Vec::new(),
            seq: 0,
            inflight: VecDeque::new(),
            max_inflight: 4,
            latency: LatencyHistogram::new(),
            acked_items: 0,
            frames: 0,
        })
    }

    /// Bound on unacked frames (default 4). 1 degenerates to
    /// request/response lock-step.
    pub fn with_inflight(mut self, max_inflight: usize) -> IngestClient {
        self.max_inflight = max_inflight.max(1);
        self
    }

    /// Send one flat item chunk as an `IngestItems` frame. Chunks are
    /// capped at [`MAX_ITEMS_PER_FRAME`] — the wire-length limit, which
    /// for flat frames binds before the mass cap — so anything this
    /// accepts the server accepts too.
    pub fn send_items(&mut self, items: &[u64]) -> crate::Result<()> {
        anyhow::ensure!(
            items.len() <= MAX_ITEMS_PER_FRAME,
            "chunk of {} items exceeds the per-frame item cap {MAX_ITEMS_PER_FRAME}",
            items.len()
        );
        self.wire.clear();
        self.seq += 1;
        encode_items_into(self.seq, items, &mut self.wire);
        self.dispatch()
    }

    /// Send pre-aggregated `(item, weight)` runs as an `IngestRuns`
    /// frame (the batched-ingest wire shape — compact under skew).
    /// Both server-side caps are enforced here: the expanded mass
    /// (Σ weights ≤ [`MAX_FRAME_MASS`]) and the wire image
    /// (runs ≤ [`MAX_RUNS_PER_FRAME`]).
    pub fn send_runs(&mut self, runs: &[(u64, u64)]) -> crate::Result<()> {
        anyhow::ensure!(
            runs.len() <= MAX_RUNS_PER_FRAME,
            "{} runs exceed the per-frame run cap {MAX_RUNS_PER_FRAME}",
            runs.len()
        );
        let mass: u64 = runs.iter().map(|&(_, w)| w).sum();
        anyhow::ensure!(
            mass <= MAX_FRAME_MASS,
            "runs of mass {mass} exceed the frame mass cap {MAX_FRAME_MASS}"
        );
        self.wire.clear();
        self.seq += 1;
        encode_runs_into(self.seq, runs, &mut self.wire);
        self.dispatch()
    }

    /// Write the staged frame, then absorb acks until the in-flight
    /// window has room again.
    fn dispatch(&mut self) -> crate::Result<()> {
        self.stream.write_all(&self.wire)?;
        self.stream.flush()?;
        self.inflight.push_back((self.seq, Instant::now()));
        self.frames += 1;
        while self.inflight.len() >= self.max_inflight {
            self.recv_ack()?;
        }
        Ok(())
    }

    /// Block for the next ack; acks arrive strictly in send order.
    fn recv_ack(&mut self) -> crate::Result<()> {
        let (want, sent_at) = self
            .inflight
            .pop_front()
            .ok_or_else(|| anyhow::anyhow!("recv_ack with nothing in flight"))?;
        match read_frame(&mut self.stream, &mut self.scratch)? {
            Some((kind, body)) => match Frame::decode(kind, body)? {
                Frame::IngestAck { seq, items } => {
                    anyhow::ensure!(
                        seq == want,
                        "ack out of order: got seq {seq}, expected {want}"
                    );
                    self.latency.record(sent_at.elapsed());
                    self.acked_items += items;
                    Ok(())
                }
                Frame::Error { code, message } => {
                    anyhow::bail!("server error ({code:?}): {message}")
                }
                other => anyhow::bail!("unexpected frame on ingest connection: {other:?}"),
            },
            None => anyhow::bail!("server closed with {} frames unacked", self.inflight.len() + 1),
        }
    }

    /// Wait for every outstanding ack.
    pub fn drain(&mut self) -> crate::Result<()> {
        while !self.inflight.is_empty() {
            self.recv_ack()?;
        }
        Ok(())
    }

    /// Item mass acked so far.
    pub fn acked_items(&self) -> u64 {
        self.acked_items
    }

    /// Per-frame ack round-trip latency so far.
    pub fn latency(&self) -> &LatencyHistogram {
        &self.latency
    }

    /// Drain outstanding acks and close, returning `(frames sent,
    /// items acked, latency histogram)`.
    pub fn finish(mut self) -> crate::Result<(u64, u64, LatencyHistogram)> {
        self.drain()?;
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
        Ok((self.frames, self.acked_items, self.latency))
    }
}

/// A top-k answer from the wire, in engine terms.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopKAnswer {
    /// Stream coverage of the answer.
    pub n: u64,
    /// Error bound every counter honors.
    pub epsilon: u64,
    /// The heavy hitters, descending by count.
    pub counters: Vec<Counter>,
}

fn from_wire(counters: Vec<super::proto::WireCounter>) -> Vec<Counter> {
    counters
        .into_iter()
        .map(|c| Counter { item: c.item, count: c.count, err: c.err })
        .collect()
}

/// A query connection speaking request/response frames. Answers come
/// back as the same types the in-process [`QueryEngine`] yields.
///
/// [`QueryEngine`]: crate::query::QueryEngine
pub struct QueryClient {
    stream: AnyStream,
    wire: Vec<u8>,
    scratch: Vec<u8>,
}

impl QueryClient {
    /// Connect and handshake as a query reader.
    pub fn connect(endpoint: &Endpoint) -> crate::Result<QueryClient> {
        Ok(QueryClient {
            stream: handshake(endpoint, Role::Query)?,
            wire: Vec::new(),
            scratch: Vec::new(),
        })
    }

    /// One request/response round trip; server `Error` frames become
    /// `Err` here.
    fn request(&mut self, frame: &Frame) -> crate::Result<Frame> {
        write_frame(&mut self.stream, frame, &mut self.wire)?;
        match read_frame(&mut self.stream, &mut self.scratch)? {
            Some((kind, body)) => match Frame::decode(kind, body)? {
                Frame::Error { code, message } => {
                    anyhow::bail!("server error ({code:?}): {message}")
                }
                reply => Ok(reply),
            },
            None => anyhow::bail!("server closed mid-query"),
        }
    }

    /// Top-`m` heavy hitters; `window_epochs` 0 = landmark, else the
    /// last `w` epochs.
    pub fn top_k(&mut self, m: u32, window_epochs: u32) -> crate::Result<TopKAnswer> {
        match self.request(&Frame::TopK { m, window_epochs })? {
            Frame::TopKResult { n, epsilon, counters } => {
                Ok(TopKAnswer { n, epsilon, counters: from_wire(counters) })
            }
            other => anyhow::bail!("unexpected top-k reply: {other:?}"),
        }
    }

    /// Point frequency estimate for one item.
    pub fn point(&mut self, item: u64, window_epochs: u32) -> crate::Result<PointEstimate> {
        match self.request(&Frame::Point { item, window_epochs })? {
            Frame::PointResult { estimate, guaranteed, monitored, n } => {
                Ok(PointEstimate { item, estimate, guaranteed, monitored, n })
            }
            other => anyhow::bail!("unexpected point reply: {other:?}"),
        }
    }

    /// k-majority report (`f̂ > n/k`); `k < 2` uses the server's
    /// configured default. The report's `threshold` is the one the
    /// server actually split against (echoed over the wire), so it is
    /// faithful even when the server substituted its default k.
    pub fn k_majority(&mut self, k: u64, window_epochs: u32) -> crate::Result<ThresholdReport> {
        match self.request(&Frame::KMajority { k, window_epochs })? {
            Frame::KMajorityResult { n, epsilon, threshold, guaranteed, possible } => {
                Ok(ThresholdReport {
                    threshold,
                    guaranteed: from_wire(guaranteed),
                    possible: from_wire(possible),
                    n,
                    epsilon,
                })
            }
            other => anyhow::bail!("unexpected k-majority reply: {other:?}"),
        }
    }

    /// Server counter snapshot.
    pub fn stats(&mut self) -> crate::Result<WireStats> {
        match self.request(&Frame::Stats)? {
            Frame::StatsResult(s) => Ok(s),
            other => anyhow::bail!("unexpected stats reply: {other:?}"),
        }
    }

    /// Ask the server to drain and stop (consumes the connection — the
    /// server closes it after acking).
    pub fn shutdown_server(mut self) -> crate::Result<()> {
        match self.request(&Frame::Shutdown)? {
            Frame::ShutdownAck => Ok(()),
            other => anyhow::bail!("unexpected shutdown reply: {other:?}"),
        }
    }
}

/// The cluster head's connection to one worker process: pulls full
/// summary snapshots over the [`Role::Worker`] exchange
/// ([`Frame::SummaryRequest`] → [`Frame::SummarySnapshot`]).
pub struct SnapshotClient {
    stream: AnyStream,
    wire: Vec<u8>,
    scratch: Vec<u8>,
}

impl SnapshotClient {
    /// Connect and handshake as a cluster head.
    pub fn connect(endpoint: &Endpoint) -> crate::Result<SnapshotClient> {
        Ok(SnapshotClient {
            stream: handshake(endpoint, Role::Worker)?,
            wire: Vec::new(),
            scratch: Vec::new(),
        })
    }

    /// One snapshot round trip. `drain: true` asks the worker to stop
    /// ingesting, drain its coordinator and reply with the *final*
    /// state (`finished: true`) before shutting down — after which this
    /// connection is spent.
    pub fn fetch(&mut self, drain: bool) -> crate::Result<WireSnapshot> {
        write_frame(&mut self.stream, &Frame::SummaryRequest { drain }, &mut self.wire)?;
        match read_frame(&mut self.stream, &mut self.scratch)? {
            Some((kind, body)) => match Frame::decode(kind, body)? {
                Frame::SummarySnapshot(s) => Ok(s),
                Frame::Error { code, message } => {
                    anyhow::bail!("worker error ({code:?}): {message}")
                }
                other => anyhow::bail!("unexpected snapshot reply: {other:?}"),
            },
            None => anyhow::bail!("worker closed mid-snapshot"),
        }
    }

    /// Drain the worker and return its final snapshot (consumes the
    /// connection — the worker shuts down after replying).
    pub fn drain(mut self) -> crate::Result<WireSnapshot> {
        let snap = self.fetch(true)?;
        anyhow::ensure!(
            snap.finished,
            "worker answered a drain request with a non-final snapshot"
        );
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
        Ok(snap)
    }
}

/// Shape of one `pss loadgen` run.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Concurrent ingest connections.
    pub clients: usize,
    /// Items each client streams.
    pub items_per_client: u64,
    /// Items per ingest frame.
    pub chunk_len: usize,
    /// Workload universe.
    pub universe: u64,
    /// Zipf skew (0 = uniform).
    pub skew: f64,
    /// Zipf-Mandelbrot shift.
    pub shift: f64,
    /// Base seed; client `i` uses `seed + i`.
    pub seed: u64,
    /// Pre-aggregate each chunk into `(item, weight)` runs and send
    /// `IngestRuns` frames (compact under skew) instead of flat items.
    pub runs: bool,
    /// Per-connection in-flight frame window.
    pub max_inflight: usize,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            clients: 4,
            items_per_client: 1_000_000,
            chunk_len: crate::parallel::batch_chunk_len_default(),
            universe: 1 << 20,
            skew: 1.1,
            shift: 0.0,
            seed: 42,
            runs: false,
            max_inflight: 4,
        }
    }
}

/// What a load-generation run measured.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    /// Connections that ran.
    pub clients: usize,
    /// Items streamed (sum over clients).
    pub items_sent: u64,
    /// Item mass the server acked.
    pub items_acked: u64,
    /// Ingest frames sent.
    pub frames: u64,
    /// Wall time of the whole run.
    pub elapsed: Duration,
    /// Per-frame ack round-trip latency, merged over all clients.
    pub frame_latency: LatencySummary,
}

impl LoadgenReport {
    /// End-to-end acked throughput in items/s.
    pub fn items_per_sec(&self) -> f64 {
        let s = self.elapsed.as_secs_f64();
        if s == 0.0 {
            0.0
        } else {
            self.items_acked as f64 / s
        }
    }
}

/// Drive `cfg.clients` concurrent ingest connections against
/// `endpoint`, each streaming a deterministic `gen/` workload, and
/// merge the per-client latency histograms into one report. Fails if
/// any client fails.
pub fn run_loadgen(endpoint: &Endpoint, cfg: &LoadgenConfig) -> crate::Result<LoadgenReport> {
    anyhow::ensure!(cfg.clients >= 1, "loadgen needs at least one client");
    anyhow::ensure!(cfg.chunk_len >= 1, "chunk_len must be positive");
    // Bound chunk_len by the *wire* caps, which bind before the mass
    // cap: a flat chunk is one item per 8 wire bytes, and a runs chunk
    // can degenerate to one run per item (uniform workload), so both
    // shapes must fit MAX_FRAME_LEN at chunk_len.
    anyhow::ensure!(
        cfg.chunk_len <= MAX_ITEMS_PER_FRAME,
        "chunk_len {} exceeds the per-frame item cap {MAX_ITEMS_PER_FRAME}",
        cfg.chunk_len
    );
    anyhow::ensure!(
        !cfg.runs || cfg.chunk_len <= MAX_RUNS_PER_FRAME,
        "chunk_len {} with --runs can exceed the per-frame run cap {MAX_RUNS_PER_FRAME}",
        cfg.chunk_len
    );
    let t0 = Instant::now();
    let outcomes: Vec<crate::Result<(u64, u64, u64, LatencyHistogram)>> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..cfg.clients)
                .map(|i| {
                    scope.spawn(move || -> crate::Result<(u64, u64, u64, LatencyHistogram)> {
                        let n = cfg.items_per_client;
                        let seed = cfg.seed + i as u64;
                        let src = if cfg.skew > 0.0 {
                            GeneratedSource::zipf_mandelbrot(
                                n,
                                cfg.universe,
                                cfg.skew,
                                cfg.shift,
                                seed,
                            )
                        } else {
                            GeneratedSource::uniform(n, cfg.universe, seed)
                        };
                        let mut client =
                            IngestClient::connect(endpoint)?.with_inflight(cfg.max_inflight);
                        let mut buf = vec![0u64; cfg.chunk_len];
                        let mut agg = ChunkAggregator::with_capacity(cfg.chunk_len);
                        let mut pos = 0u64;
                        let mut sent = 0u64;
                        while pos < n {
                            let take = ((n - pos) as usize).min(cfg.chunk_len);
                            src.fill(pos, &mut buf[..take]);
                            if cfg.runs {
                                client.send_runs(agg.aggregate(&buf[..take]))?;
                            } else {
                                client.send_items(&buf[..take])?;
                            }
                            pos += take as u64;
                            sent += take as u64;
                        }
                        let (frames, acked, hist) = client.finish()?;
                        Ok((sent, acked, frames, hist))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("loadgen client panicked"))
                .collect()
        });
    let elapsed = t0.elapsed();
    let merged = LatencyHistogram::new();
    let (mut items_sent, mut items_acked, mut frames) = (0u64, 0u64, 0u64);
    for outcome in outcomes {
        let (sent, acked, f, hist) = outcome?;
        items_sent += sent;
        items_acked += acked;
        frames += f;
        merged.merge(&hist);
    }
    Ok(LoadgenReport {
        clients: cfg.clients,
        items_sent,
        items_acked,
        frames,
        elapsed,
        frame_latency: merged.summary(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::serve::server::{ServeConfig, Server};

    fn tiny_server() -> Server {
        Server::bind(
            &"127.0.0.1:0".parse().unwrap(),
            ServeConfig {
                coordinator: CoordinatorConfig {
                    shards: 2,
                    k: 64,
                    k_majority: 8,
                    epoch_items: 200,
                    ..Default::default()
                },
                query_threads: 1,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn ingest_client_pipelines_and_attributes_latency() {
        let server = tiny_server();
        let mut c = IngestClient::connect(server.endpoint()).unwrap().with_inflight(3);
        for i in 0..10u64 {
            c.send_items(&[i % 3; 100]).unwrap();
        }
        let (frames, acked, hist) = c.finish().unwrap();
        assert_eq!(frames, 10);
        assert_eq!(acked, 1000);
        assert_eq!(hist.count(), 10, "one latency sample per frame");
        let (result, _) = server.finish();
        assert_eq!(result.stats.items, 1000);
    }

    #[test]
    fn query_client_speaks_engine_types() {
        let server = tiny_server();
        let mut ing = IngestClient::connect(server.endpoint()).unwrap();
        // 600 of item 5, 400 of item 9, as runs.
        ing.send_runs(&[(5, 600), (9, 400)]).unwrap();
        ing.finish().unwrap();
        server.queries().refresh();

        let mut q = QueryClient::connect(server.endpoint()).unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        let top = loop {
            let t = q.top_k(2, 0).unwrap();
            if t.n >= 1000 {
                break t;
            }
            assert!(Instant::now() < deadline, "epochs never covered ingest");
            std::thread::sleep(Duration::from_millis(5));
            server.queries().refresh();
        };
        assert_eq!(top.counters[0].item, 5);
        assert_eq!(top.counters[0].count, 600);
        let p = q.point(9, 0).unwrap();
        assert_eq!(p.estimate, 400);
        assert!(p.monitored);
        let rep = q.k_majority(8, 0).unwrap();
        assert!(rep.guaranteed.iter().any(|c| c.item == 5));
        assert_eq!(rep.threshold, rep.n / 8, "server echoes the real split threshold");
        // k < 2 delegates to the server's configured default (8 here);
        // the echoed threshold must reflect that default, not a guess.
        let rep0 = q.k_majority(0, 0).unwrap();
        assert_eq!(rep0.threshold, rep0.n / 8);
        assert_eq!(rep0.guaranteed, rep.guaranteed);
        let s = q.stats().unwrap();
        assert_eq!(s.items, 1000);
        q.shutdown_server().unwrap();
        assert!(server.shutdown_requested());
        let (result, _) = server.finish();
        assert_eq!(result.stats.items, 1000);
    }

    #[test]
    fn loadgen_drives_concurrent_clients() {
        let server = tiny_server();
        let report = run_loadgen(
            server.endpoint(),
            &LoadgenConfig {
                clients: 3,
                items_per_client: 2_000,
                chunk_len: 256,
                universe: 1 << 10,
                seed: 7,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.items_sent, 6_000);
        assert_eq!(report.items_acked, 6_000);
        assert_eq!(report.frames, 3 * 8);
        assert_eq!(report.frame_latency.count, report.frames);
        assert!(report.items_per_sec() > 0.0);
        let (result, stats) = server.finish();
        assert_eq!(result.stats.items, 6_000);
        assert_eq!(stats.ingest_connections, 3);
    }

    #[test]
    fn loadgen_runs_shape_matches_flat() {
        let server = tiny_server();
        let cfg = LoadgenConfig {
            clients: 2,
            items_per_client: 1_000,
            chunk_len: 250,
            universe: 1 << 8,
            seed: 11,
            runs: true,
            ..Default::default()
        };
        let report = run_loadgen(server.endpoint(), &cfg).unwrap();
        assert_eq!(report.items_acked, 2_000, "runs expand to full mass server-side");
        let (result, _) = server.finish();
        assert_eq!(result.stats.items, 2_000);
    }

    #[test]
    fn snapshot_client_fetches_and_drains() {
        let server = tiny_server();
        let mut ing = IngestClient::connect(server.endpoint()).unwrap();
        ing.send_runs(&[(42, 600), (7, 400)]).unwrap();
        ing.finish().unwrap();
        server.queries().refresh();

        let mut sc = SnapshotClient::connect(server.endpoint()).unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let snap = sc.fetch(false).unwrap();
            if snap.total_mass() >= 1000 {
                assert!(!snap.finished, "live poll must not report a final state");
                assert!(snap.k >= 1);
                let c42 = snap
                    .counters
                    .iter()
                    .chain(snap.hot.iter())
                    .find(|c| c.item == 42)
                    .expect("heavy item visible in snapshot");
                assert_eq!(c42.count, 600);
                break;
            }
            assert!(Instant::now() < deadline, "epochs never covered ingest");
            std::thread::sleep(Duration::from_millis(5));
            server.queries().refresh();
        }

        let fin = sc.drain().unwrap();
        assert!(fin.finished);
        assert_eq!(fin.total_mass(), 1000);
        assert!(server.shutdown_requested());
        let (result, stats) = server.finish();
        assert_eq!(result.stats.items, 1000);
        assert_eq!(stats.worker_connections, 1);
        assert_eq!(stats.proto_errors, 0);
    }

    #[test]
    fn oversized_chunk_is_rejected_client_side() {
        let server = tiny_server();
        let mut c = IngestClient::connect(server.endpoint()).unwrap();
        let e = c.send_runs(&[(1, MAX_FRAME_MASS + 1)]).unwrap_err();
        assert!(e.to_string().contains("mass"), "{e}");
        // The wire-length caps bind too: a flat chunk between
        // MAX_ITEMS_PER_FRAME and MAX_FRAME_MASS items would pass the
        // mass check yet exceed MAX_FRAME_LEN server-side, so the
        // client must reject it before writing a byte.
        let big = vec![0u64; MAX_ITEMS_PER_FRAME + 1];
        let e = c.send_items(&big).unwrap_err();
        assert!(e.to_string().contains("item cap"), "{e}");
        let runs = vec![(0u64, 1u64); MAX_RUNS_PER_FRAME + 1];
        let e = c.send_runs(&runs).unwrap_err();
        assert!(e.to_string().contains("run cap"), "{e}");
        server.finish();
    }
}
