//! Client side of the wire protocol: typed ingest/query clients plus
//! the multi-threaded load generator behind `pss loadgen`.
//!
//! [`IngestClient`] pipelines ingest frames with a bounded in-flight
//! window — it keeps writing while acks trail behind, so one
//! connection can saturate the socket without unbounded buffering —
//! and attributes each ack round trip to a [`LatencyHistogram`]
//! sample. [`QueryClient`] speaks the query frames and hands back the
//! *same* answer types the in-process engines produce
//! ([`PointEstimate`], [`ThresholdReport`]), so a caller can swap
//! in-process and over-the-wire query paths without touching its
//! result handling.
//!
//! [`run_loadgen`] drives N concurrent ingest connections from the
//! `gen/` workload generators (one deterministic source per client,
//! seeds `seed..seed+N`) and folds the per-client histograms with
//! [`LatencyHistogram::merge`] into one end-to-end report.

use std::collections::VecDeque;
use std::io::Write as _;
use std::time::{Duration, Instant};

use crate::gen::{GeneratedSource, ItemSource};
use crate::metrics::{LatencyHistogram, LatencySummary};
use crate::query::{PointEstimate, ThresholdReport};
use crate::summary::{ChunkAggregator, Counter};
use crate::util::Backoff;

use super::proto::{
    encode_hello, encode_items_into, encode_runs_into, write_frame, Frame, FrameReader, Poll,
    ProtoError, Role, WireSnapshot, WireStats, MAX_FRAME_MASS, MAX_ITEMS_PER_FRAME,
    MAX_RUNS_PER_FRAME, VERSION,
};
use super::server::{AnyStream, Endpoint};

/// Default overall deadline for every blocking read and write. Override
/// per client with `with_deadline` / `connect_with_deadline`.
pub const DEFAULT_DEADLINE: Duration = Duration::from_secs(30);

/// OS-level read timeout: how often a blocked read wakes so the
/// resumable [`FrameReader`] can check the overall deadline. Short
/// enough that small deadlines overshoot by at most one quantum.
const POLL_QUANTUM: Duration = Duration::from_millis(50);

/// Read one complete frame within `deadline` (resumable across OS read
/// timeouts). `Ok(None)` is a clean close at a frame boundary; an
/// expired deadline is [`ProtoError::Timeout`]. Takes the stream and
/// reader as separate borrows so callers can keep mutating their other
/// fields while the returned body is alive.
fn read_reply<'a>(
    stream: &mut AnyStream,
    reader: &'a mut FrameReader,
    deadline: Duration,
) -> Result<Option<(u8, &'a [u8])>, ProtoError> {
    match reader.poll_deadline(stream, deadline)? {
        Poll::Frame(kind, body) => Ok(Some((kind, body))),
        Poll::Eof => Ok(None),
        Poll::Pending => unreachable!("poll_deadline never yields Pending"),
    }
}

/// Call `connect` up to `attempts` times, sleeping per `backoff`
/// between failures. The last error is returned annotated with the
/// attempt count.
fn retry_connect<T>(
    attempts: u32,
    backoff: &mut Backoff,
    mut connect: impl FnMut() -> crate::Result<T>,
) -> crate::Result<T> {
    let attempts = attempts.max(1);
    let mut last = None;
    for attempt in 0..attempts {
        if attempt > 0 {
            backoff.sleep();
        }
        match connect() {
            Ok(v) => return Ok(v),
            Err(e) => last = Some(e),
        }
    }
    Err(last.expect("at least one attempt ran").context(format!("after {attempts} attempts")))
}

/// Connect, send the hello, and require a `HelloOk` within `deadline`.
fn handshake(endpoint: &Endpoint, role: Role, deadline: Duration) -> crate::Result<AnyStream> {
    let mut stream = endpoint
        .connect()
        .map_err(|e| anyhow::anyhow!("connect {endpoint}: {e}"))?;
    // Reads wake every POLL_QUANTUM so the resumable reader can enforce
    // the overall deadline; writes get the deadline as an OS timeout
    // (a write that blocks that long means a dead or wedged peer).
    stream.set_read_timeout(Some(POLL_QUANTUM))?;
    stream.set_write_timeout(Some(deadline.max(Duration::from_millis(1))))?;
    stream.write_all(&encode_hello(role))?;
    stream.flush()?;
    let mut reader = FrameReader::new();
    match read_reply(&mut stream, &mut reader, deadline) {
        Ok(Some((kind, body))) => match Frame::decode(kind, body)? {
            Frame::HelloOk { version } => {
                anyhow::ensure!(
                    version == VERSION,
                    "server speaks protocol v{version}, client v{VERSION}"
                );
                Ok(stream)
            }
            Frame::Error { code, message } => {
                anyhow::bail!("server rejected hello ({code:?}): {message}")
            }
            other => anyhow::bail!("unexpected reply to hello: {other:?}"),
        },
        Ok(None) => anyhow::bail!("server closed during handshake"),
        Err(ProtoError::Timeout) => {
            anyhow::bail!("deadline expired: no hello reply within {deadline:?}")
        }
        Err(e) => Err(e.into()),
    }
}

/// A pipelined ingest connection: one wire producer.
///
/// Frames carry a client sequence number; the server acks each one,
/// and the client bounds unacked frames at `max_inflight` — writes
/// overlap with acks (pipelining) but memory and latency attribution
/// stay bounded. Every ack round trip lands in the client's
/// [`LatencyHistogram`].
pub struct IngestClient {
    stream: AnyStream,
    wire: Vec<u8>,
    reader: FrameReader,
    seq: u64,
    inflight: VecDeque<(u64, Instant)>,
    max_inflight: usize,
    deadline: Duration,
    latency: LatencyHistogram,
    acked_items: u64,
    frames: u64,
}

impl IngestClient {
    /// Connect and handshake as an ingest producer (default deadline).
    pub fn connect(endpoint: &Endpoint) -> crate::Result<IngestClient> {
        Self::connect_with_deadline(endpoint, DEFAULT_DEADLINE)
    }

    /// Connect with an explicit per-operation deadline: the handshake,
    /// every ack read, and every frame write must finish within it.
    pub fn connect_with_deadline(
        endpoint: &Endpoint,
        deadline: Duration,
    ) -> crate::Result<IngestClient> {
        Ok(IngestClient {
            stream: handshake(endpoint, Role::Ingest, deadline)?,
            wire: Vec::new(),
            reader: FrameReader::new(),
            seq: 0,
            inflight: VecDeque::new(),
            max_inflight: 4,
            deadline,
            latency: LatencyHistogram::new(),
            acked_items: 0,
            frames: 0,
        })
    }

    /// Connect with retry: transient connect/handshake failures sleep
    /// per `backoff` and try again, up to `attempts` total.
    pub fn connect_retry(
        endpoint: &Endpoint,
        deadline: Duration,
        attempts: u32,
        backoff: &mut Backoff,
    ) -> crate::Result<IngestClient> {
        retry_connect(attempts, backoff, || Self::connect_with_deadline(endpoint, deadline))
    }

    /// Bound on unacked frames (default 4). 1 degenerates to
    /// request/response lock-step.
    pub fn with_inflight(mut self, max_inflight: usize) -> IngestClient {
        self.max_inflight = max_inflight.max(1);
        self
    }

    /// Send one flat item chunk as an `IngestItems` frame. Chunks are
    /// capped at [`MAX_ITEMS_PER_FRAME`] — the wire-length limit, which
    /// for flat frames binds before the mass cap — so anything this
    /// accepts the server accepts too.
    pub fn send_items(&mut self, items: &[u64]) -> crate::Result<()> {
        anyhow::ensure!(
            items.len() <= MAX_ITEMS_PER_FRAME,
            "chunk of {} items exceeds the per-frame item cap {MAX_ITEMS_PER_FRAME}",
            items.len()
        );
        self.wire.clear();
        self.seq += 1;
        encode_items_into(self.seq, items, &mut self.wire);
        self.dispatch()
    }

    /// Send pre-aggregated `(item, weight)` runs as an `IngestRuns`
    /// frame (the batched-ingest wire shape — compact under skew).
    /// Both server-side caps are enforced here: the expanded mass
    /// (Σ weights ≤ [`MAX_FRAME_MASS`]) and the wire image
    /// (runs ≤ [`MAX_RUNS_PER_FRAME`]).
    pub fn send_runs(&mut self, runs: &[(u64, u64)]) -> crate::Result<()> {
        anyhow::ensure!(
            runs.len() <= MAX_RUNS_PER_FRAME,
            "{} runs exceed the per-frame run cap {MAX_RUNS_PER_FRAME}",
            runs.len()
        );
        let mass: u64 = runs.iter().map(|&(_, w)| w).sum();
        anyhow::ensure!(
            mass <= MAX_FRAME_MASS,
            "runs of mass {mass} exceed the frame mass cap {MAX_FRAME_MASS}"
        );
        self.wire.clear();
        self.seq += 1;
        encode_runs_into(self.seq, runs, &mut self.wire);
        self.dispatch()
    }

    /// Write the staged frame, then absorb acks until the in-flight
    /// window has room again.
    fn dispatch(&mut self) -> crate::Result<()> {
        self.stream.write_all(&self.wire)?;
        self.stream.flush()?;
        self.inflight.push_back((self.seq, Instant::now()));
        self.frames += 1;
        while self.inflight.len() >= self.max_inflight {
            self.recv_ack()?;
        }
        Ok(())
    }

    /// Block for the next ack (bounded by the deadline); acks arrive
    /// strictly in send order. A silent server — alive at the TCP level
    /// but no longer acking — surfaces as a typed deadline error here
    /// instead of wedging the pipelining loop forever.
    fn recv_ack(&mut self) -> crate::Result<()> {
        let (want, sent_at) = self
            .inflight
            .pop_front()
            .ok_or_else(|| anyhow::anyhow!("recv_ack with nothing in flight"))?;
        let reply = match read_reply(&mut self.stream, &mut self.reader, self.deadline) {
            Ok(reply) => reply,
            Err(ProtoError::Timeout) => anyhow::bail!(
                "deadline expired: no ack for seq {want} within {:?} ({} more frames in flight)",
                self.deadline,
                self.inflight.len()
            ),
            Err(e) => return Err(e.into()),
        };
        match reply {
            Some((kind, body)) => match Frame::decode(kind, body)? {
                Frame::IngestAck { seq, items } => {
                    anyhow::ensure!(
                        seq == want,
                        "ack out of order: got seq {seq}, expected {want}"
                    );
                    self.latency.record(sent_at.elapsed());
                    self.acked_items += items;
                    Ok(())
                }
                Frame::Error { code, message } => {
                    anyhow::bail!("server error ({code:?}): {message}")
                }
                other => anyhow::bail!("unexpected frame on ingest connection: {other:?}"),
            },
            None => anyhow::bail!("server closed with {} frames unacked", self.inflight.len() + 1),
        }
    }

    /// Wait for every outstanding ack.
    pub fn drain(&mut self) -> crate::Result<()> {
        while !self.inflight.is_empty() {
            self.recv_ack()?;
        }
        Ok(())
    }

    /// Item mass acked so far.
    pub fn acked_items(&self) -> u64 {
        self.acked_items
    }

    /// Per-frame ack round-trip latency so far.
    pub fn latency(&self) -> &LatencyHistogram {
        &self.latency
    }

    /// Drain outstanding acks and close, returning `(frames sent,
    /// items acked, latency histogram)`.
    pub fn finish(mut self) -> crate::Result<(u64, u64, LatencyHistogram)> {
        self.drain()?;
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
        Ok((self.frames, self.acked_items, self.latency))
    }
}

/// A top-k answer from the wire, in engine terms.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopKAnswer {
    /// Stream coverage of the answer.
    pub n: u64,
    /// Error bound every counter honors.
    pub epsilon: u64,
    /// The heavy hitters, descending by count.
    pub counters: Vec<Counter>,
}

fn from_wire(counters: Vec<super::proto::WireCounter>) -> Vec<Counter> {
    counters
        .into_iter()
        .map(|c| Counter { item: c.item, count: c.count, err: c.err })
        .collect()
}

/// A query connection speaking request/response frames. Answers come
/// back as the same types the in-process [`QueryEngine`] yields.
///
/// [`QueryEngine`]: crate::query::QueryEngine
pub struct QueryClient {
    stream: AnyStream,
    wire: Vec<u8>,
    reader: FrameReader,
    deadline: Duration,
}

impl QueryClient {
    /// Connect and handshake as a query reader (default deadline).
    pub fn connect(endpoint: &Endpoint) -> crate::Result<QueryClient> {
        Self::connect_with_deadline(endpoint, DEFAULT_DEADLINE)
    }

    /// Connect with an explicit per-round-trip deadline.
    pub fn connect_with_deadline(
        endpoint: &Endpoint,
        deadline: Duration,
    ) -> crate::Result<QueryClient> {
        Ok(QueryClient {
            stream: handshake(endpoint, Role::Query, deadline)?,
            wire: Vec::new(),
            reader: FrameReader::new(),
            deadline,
        })
    }

    /// Connect with retry: transient connect/handshake failures sleep
    /// per `backoff` and try again, up to `attempts` total.
    pub fn connect_retry(
        endpoint: &Endpoint,
        deadline: Duration,
        attempts: u32,
        backoff: &mut Backoff,
    ) -> crate::Result<QueryClient> {
        retry_connect(attempts, backoff, || Self::connect_with_deadline(endpoint, deadline))
    }

    /// One request/response round trip (bounded by the deadline);
    /// server `Error` frames become `Err` here.
    fn request(&mut self, frame: &Frame) -> crate::Result<Frame> {
        write_frame(&mut self.stream, frame, &mut self.wire)?;
        let reply = match read_reply(&mut self.stream, &mut self.reader, self.deadline) {
            Ok(reply) => reply,
            Err(ProtoError::Timeout) => anyhow::bail!(
                "deadline expired: no reply to {frame:?} within {:?}",
                self.deadline
            ),
            Err(e) => return Err(e.into()),
        };
        match reply {
            Some((kind, body)) => match Frame::decode(kind, body)? {
                Frame::Error { code, message } => {
                    anyhow::bail!("server error ({code:?}): {message}")
                }
                reply => Ok(reply),
            },
            None => anyhow::bail!("server closed mid-query"),
        }
    }

    /// Top-`m` heavy hitters; `window_epochs` 0 = landmark, else the
    /// last `w` epochs.
    pub fn top_k(&mut self, m: u32, window_epochs: u32) -> crate::Result<TopKAnswer> {
        match self.request(&Frame::TopK { m, window_epochs })? {
            Frame::TopKResult { n, epsilon, counters } => {
                Ok(TopKAnswer { n, epsilon, counters: from_wire(counters) })
            }
            other => anyhow::bail!("unexpected top-k reply: {other:?}"),
        }
    }

    /// Point frequency estimate for one item.
    pub fn point(&mut self, item: u64, window_epochs: u32) -> crate::Result<PointEstimate> {
        match self.request(&Frame::Point { item, window_epochs })? {
            Frame::PointResult { estimate, guaranteed, monitored, n } => {
                Ok(PointEstimate { item, estimate, guaranteed, monitored, n })
            }
            other => anyhow::bail!("unexpected point reply: {other:?}"),
        }
    }

    /// k-majority report (`f̂ > n/k`); `k < 2` uses the server's
    /// configured default. The report's `threshold` is the one the
    /// server actually split against (echoed over the wire), so it is
    /// faithful even when the server substituted its default k.
    pub fn k_majority(&mut self, k: u64, window_epochs: u32) -> crate::Result<ThresholdReport> {
        match self.request(&Frame::KMajority { k, window_epochs })? {
            Frame::KMajorityResult { n, epsilon, threshold, guaranteed, possible } => {
                Ok(ThresholdReport {
                    threshold,
                    guaranteed: from_wire(guaranteed),
                    possible: from_wire(possible),
                    n,
                    epsilon,
                })
            }
            other => anyhow::bail!("unexpected k-majority reply: {other:?}"),
        }
    }

    /// Server counter snapshot.
    pub fn stats(&mut self) -> crate::Result<WireStats> {
        match self.request(&Frame::Stats)? {
            Frame::StatsResult(s) => Ok(s),
            other => anyhow::bail!("unexpected stats reply: {other:?}"),
        }
    }

    /// Ask the server to drain and stop (consumes the connection — the
    /// server closes it after acking).
    pub fn shutdown_server(mut self) -> crate::Result<()> {
        match self.request(&Frame::Shutdown)? {
            Frame::ShutdownAck => Ok(()),
            other => anyhow::bail!("unexpected shutdown reply: {other:?}"),
        }
    }
}

/// The cluster head's connection to one worker process: pulls full
/// summary snapshots over the [`Role::Worker`] exchange
/// ([`Frame::SummaryRequest`] → [`Frame::SummarySnapshot`]).
pub struct SnapshotClient {
    stream: AnyStream,
    wire: Vec<u8>,
    reader: FrameReader,
    deadline: Duration,
}

impl SnapshotClient {
    /// Connect and handshake as a cluster head (default deadline).
    pub fn connect(endpoint: &Endpoint) -> crate::Result<SnapshotClient> {
        Self::connect_with_deadline(endpoint, DEFAULT_DEADLINE)
    }

    /// Connect with an explicit per-round-trip deadline.
    pub fn connect_with_deadline(
        endpoint: &Endpoint,
        deadline: Duration,
    ) -> crate::Result<SnapshotClient> {
        Ok(SnapshotClient {
            stream: handshake(endpoint, Role::Worker, deadline)?,
            wire: Vec::new(),
            reader: FrameReader::new(),
            deadline,
        })
    }

    /// Connect with retry: transient connect/handshake failures sleep
    /// per `backoff` and try again, up to `attempts` total.
    pub fn connect_retry(
        endpoint: &Endpoint,
        deadline: Duration,
        attempts: u32,
        backoff: &mut Backoff,
    ) -> crate::Result<SnapshotClient> {
        retry_connect(attempts, backoff, || Self::connect_with_deadline(endpoint, deadline))
    }

    /// One snapshot round trip (bounded by the deadline). `drain: true`
    /// asks the worker to stop ingesting, drain its coordinator and
    /// reply with the *final* state (`finished: true`) before shutting
    /// down — after which this connection is spent.
    pub fn fetch(&mut self, drain: bool) -> crate::Result<WireSnapshot> {
        write_frame(&mut self.stream, &Frame::SummaryRequest { drain }, &mut self.wire)?;
        let reply = match read_reply(&mut self.stream, &mut self.reader, self.deadline) {
            Ok(reply) => reply,
            Err(ProtoError::Timeout) => anyhow::bail!(
                "deadline expired: no snapshot within {:?} (drain: {drain})",
                self.deadline
            ),
            Err(e) => return Err(e.into()),
        };
        match reply {
            Some((kind, body)) => match Frame::decode(kind, body)? {
                Frame::SummarySnapshot(s) => Ok(s),
                Frame::Error { code, message } => {
                    anyhow::bail!("worker error ({code:?}): {message}")
                }
                other => anyhow::bail!("unexpected snapshot reply: {other:?}"),
            },
            None => anyhow::bail!("worker closed mid-snapshot"),
        }
    }

    /// Drain the worker and return its final snapshot (consumes the
    /// connection — the worker shuts down after replying).
    pub fn drain(mut self) -> crate::Result<WireSnapshot> {
        let snap = self.fetch(true)?;
        anyhow::ensure!(
            snap.finished,
            "worker answered a drain request with a non-final snapshot"
        );
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
        Ok(snap)
    }
}

/// Shape of one `pss loadgen` run.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Concurrent ingest connections.
    pub clients: usize,
    /// Items each client streams.
    pub items_per_client: u64,
    /// Items per ingest frame.
    pub chunk_len: usize,
    /// Workload universe.
    pub universe: u64,
    /// Zipf skew (0 = uniform).
    pub skew: f64,
    /// Zipf-Mandelbrot shift.
    pub shift: f64,
    /// Base seed; client `i` uses `seed + i`.
    pub seed: u64,
    /// Pre-aggregate each chunk into `(item, weight)` runs and send
    /// `IngestRuns` frames (compact under skew) instead of flat items.
    pub runs: bool,
    /// Per-connection in-flight frame window.
    pub max_inflight: usize,
    /// Per-operation deadline for every client (handshake, ack reads,
    /// frame writes).
    pub deadline: Duration,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            clients: 4,
            items_per_client: 1_000_000,
            chunk_len: crate::parallel::batch_chunk_len_default(),
            universe: 1 << 20,
            skew: 1.1,
            shift: 0.0,
            seed: 42,
            runs: false,
            max_inflight: 4,
            deadline: DEFAULT_DEADLINE,
        }
    }
}

/// What a load-generation run measured.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    /// Connections that ran.
    pub clients: usize,
    /// Items streamed (sum over clients).
    pub items_sent: u64,
    /// Item mass the server acked.
    pub items_acked: u64,
    /// Ingest frames sent.
    pub frames: u64,
    /// Wall time of the whole run.
    pub elapsed: Duration,
    /// Per-frame ack round-trip latency, merged over all clients.
    pub frame_latency: LatencySummary,
}

impl LoadgenReport {
    /// End-to-end acked throughput in items/s.
    pub fn items_per_sec(&self) -> f64 {
        let s = self.elapsed.as_secs_f64();
        if s == 0.0 {
            0.0
        } else {
            self.items_acked as f64 / s
        }
    }
}

/// Drive `cfg.clients` concurrent ingest connections against
/// `endpoint`, each streaming a deterministic `gen/` workload, and
/// merge the per-client latency histograms into one report. Fails if
/// any client fails.
pub fn run_loadgen(endpoint: &Endpoint, cfg: &LoadgenConfig) -> crate::Result<LoadgenReport> {
    anyhow::ensure!(cfg.clients >= 1, "loadgen needs at least one client");
    anyhow::ensure!(cfg.chunk_len >= 1, "chunk_len must be positive");
    // Bound chunk_len by the *wire* caps, which bind before the mass
    // cap: a flat chunk is one item per 8 wire bytes, and a runs chunk
    // can degenerate to one run per item (uniform workload), so both
    // shapes must fit MAX_FRAME_LEN at chunk_len.
    anyhow::ensure!(
        cfg.chunk_len <= MAX_ITEMS_PER_FRAME,
        "chunk_len {} exceeds the per-frame item cap {MAX_ITEMS_PER_FRAME}",
        cfg.chunk_len
    );
    anyhow::ensure!(
        !cfg.runs || cfg.chunk_len <= MAX_RUNS_PER_FRAME,
        "chunk_len {} with --runs can exceed the per-frame run cap {MAX_RUNS_PER_FRAME}",
        cfg.chunk_len
    );
    let t0 = Instant::now();
    let outcomes: Vec<crate::Result<(u64, u64, u64, LatencyHistogram)>> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..cfg.clients)
                .map(|i| {
                    scope.spawn(move || -> crate::Result<(u64, u64, u64, LatencyHistogram)> {
                        let n = cfg.items_per_client;
                        let seed = cfg.seed + i as u64;
                        let src = if cfg.skew > 0.0 {
                            GeneratedSource::zipf_mandelbrot(
                                n,
                                cfg.universe,
                                cfg.skew,
                                cfg.shift,
                                seed,
                            )
                        } else {
                            GeneratedSource::uniform(n, cfg.universe, seed)
                        };
                        let mut client = IngestClient::connect_with_deadline(
                            endpoint,
                            cfg.deadline,
                        )?
                        .with_inflight(cfg.max_inflight);
                        let mut buf = vec![0u64; cfg.chunk_len];
                        let mut agg = ChunkAggregator::with_capacity(cfg.chunk_len);
                        let mut pos = 0u64;
                        let mut sent = 0u64;
                        while pos < n {
                            let take = ((n - pos) as usize).min(cfg.chunk_len);
                            src.fill(pos, &mut buf[..take]);
                            if cfg.runs {
                                client.send_runs(agg.aggregate(&buf[..take]))?;
                            } else {
                                client.send_items(&buf[..take])?;
                            }
                            pos += take as u64;
                            sent += take as u64;
                        }
                        let (frames, acked, hist) = client.finish()?;
                        Ok((sent, acked, frames, hist))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("loadgen client panicked"))
                .collect()
        });
    let elapsed = t0.elapsed();
    let merged = LatencyHistogram::new();
    let (mut items_sent, mut items_acked, mut frames) = (0u64, 0u64, 0u64);
    for outcome in outcomes {
        let (sent, acked, f, hist) = outcome?;
        items_sent += sent;
        items_acked += acked;
        frames += f;
        merged.merge(&hist);
    }
    Ok(LoadgenReport {
        clients: cfg.clients,
        items_sent,
        items_acked,
        frames,
        elapsed,
        frame_latency: merged.summary(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::serve::server::{ServeConfig, Server};

    fn tiny_server() -> Server {
        Server::bind(
            &"127.0.0.1:0".parse().unwrap(),
            ServeConfig {
                coordinator: CoordinatorConfig {
                    shards: 2,
                    k: 64,
                    k_majority: 8,
                    epoch_items: 200,
                    ..Default::default()
                },
                query_threads: 1,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn ingest_client_pipelines_and_attributes_latency() {
        let server = tiny_server();
        let mut c = IngestClient::connect(server.endpoint()).unwrap().with_inflight(3);
        for i in 0..10u64 {
            c.send_items(&[i % 3; 100]).unwrap();
        }
        let (frames, acked, hist) = c.finish().unwrap();
        assert_eq!(frames, 10);
        assert_eq!(acked, 1000);
        assert_eq!(hist.count(), 10, "one latency sample per frame");
        let (result, _) = server.finish();
        assert_eq!(result.stats.items, 1000);
    }

    #[test]
    fn query_client_speaks_engine_types() {
        let server = tiny_server();
        let mut ing = IngestClient::connect(server.endpoint()).unwrap();
        // 600 of item 5, 400 of item 9, as runs.
        ing.send_runs(&[(5, 600), (9, 400)]).unwrap();
        ing.finish().unwrap();
        server.queries().refresh();

        let mut q = QueryClient::connect(server.endpoint()).unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        let top = loop {
            let t = q.top_k(2, 0).unwrap();
            if t.n >= 1000 {
                break t;
            }
            assert!(Instant::now() < deadline, "epochs never covered ingest");
            std::thread::sleep(Duration::from_millis(5));
            server.queries().refresh();
        };
        assert_eq!(top.counters[0].item, 5);
        assert_eq!(top.counters[0].count, 600);
        let p = q.point(9, 0).unwrap();
        assert_eq!(p.estimate, 400);
        assert!(p.monitored);
        let rep = q.k_majority(8, 0).unwrap();
        assert!(rep.guaranteed.iter().any(|c| c.item == 5));
        assert_eq!(rep.threshold, rep.n / 8, "server echoes the real split threshold");
        // k < 2 delegates to the server's configured default (8 here);
        // the echoed threshold must reflect that default, not a guess.
        let rep0 = q.k_majority(0, 0).unwrap();
        assert_eq!(rep0.threshold, rep0.n / 8);
        assert_eq!(rep0.guaranteed, rep.guaranteed);
        let s = q.stats().unwrap();
        assert_eq!(s.items, 1000);
        q.shutdown_server().unwrap();
        assert!(server.shutdown_requested());
        let (result, _) = server.finish();
        assert_eq!(result.stats.items, 1000);
    }

    #[test]
    fn loadgen_drives_concurrent_clients() {
        let server = tiny_server();
        let report = run_loadgen(
            server.endpoint(),
            &LoadgenConfig {
                clients: 3,
                items_per_client: 2_000,
                chunk_len: 256,
                universe: 1 << 10,
                seed: 7,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.items_sent, 6_000);
        assert_eq!(report.items_acked, 6_000);
        assert_eq!(report.frames, 3 * 8);
        assert_eq!(report.frame_latency.count, report.frames);
        assert!(report.items_per_sec() > 0.0);
        let (result, stats) = server.finish();
        assert_eq!(result.stats.items, 6_000);
        assert_eq!(stats.ingest_connections, 3);
    }

    #[test]
    fn loadgen_runs_shape_matches_flat() {
        let server = tiny_server();
        let cfg = LoadgenConfig {
            clients: 2,
            items_per_client: 1_000,
            chunk_len: 250,
            universe: 1 << 8,
            seed: 11,
            runs: true,
            ..Default::default()
        };
        let report = run_loadgen(server.endpoint(), &cfg).unwrap();
        assert_eq!(report.items_acked, 2_000, "runs expand to full mass server-side");
        let (result, _) = server.finish();
        assert_eq!(result.stats.items, 2_000);
    }

    #[test]
    fn snapshot_client_fetches_and_drains() {
        let server = tiny_server();
        let mut ing = IngestClient::connect(server.endpoint()).unwrap();
        ing.send_runs(&[(42, 600), (7, 400)]).unwrap();
        ing.finish().unwrap();
        server.queries().refresh();

        let mut sc = SnapshotClient::connect(server.endpoint()).unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let snap = sc.fetch(false).unwrap();
            if snap.total_mass() >= 1000 {
                assert!(!snap.finished, "live poll must not report a final state");
                assert!(snap.k >= 1);
                let c42 = snap
                    .counters
                    .iter()
                    .chain(snap.hot.iter())
                    .find(|c| c.item == 42)
                    .expect("heavy item visible in snapshot");
                assert_eq!(c42.count, 600);
                break;
            }
            assert!(Instant::now() < deadline, "epochs never covered ingest");
            std::thread::sleep(Duration::from_millis(5));
            server.queries().refresh();
        }

        let fin = sc.drain().unwrap();
        assert!(fin.finished);
        assert_eq!(fin.total_mass(), 1000);
        assert!(server.shutdown_requested());
        let (result, stats) = server.finish();
        assert_eq!(result.stats.items, 1000);
        assert_eq!(stats.worker_connections, 1);
        assert_eq!(stats.proto_errors, 0);
    }

    /// A hand-rolled "server" that completes the hello and then
    /// misbehaves per `acks_before_silence`: ack that many ingest
    /// frames, then either go silent (keep reading, never ack) or die
    /// (close the socket).
    fn treacherous_server(
        acks_before_silence: u64,
        die_after: bool,
    ) -> (Endpoint, std::thread::JoinHandle<()>) {
        use super::super::proto::{read_frame, read_hello};
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let ep = Endpoint::Tcp(listener.local_addr().unwrap().to_string());
        let handle = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            assert_eq!(read_hello(&mut s).unwrap(), Role::Ingest);
            let mut wire = Vec::new();
            write_frame(&mut s, &Frame::HelloOk { version: VERSION }, &mut wire).unwrap();
            let mut scratch = Vec::new();
            let mut acked = 0u64;
            while let Ok(Some((_, body))) = read_frame(&mut s, &mut scratch) {
                if acked < acks_before_silence {
                    let seq = u64::from_le_bytes(body[..8].try_into().unwrap());
                    let items = ((body.len() - 8) / 8) as u64;
                    if write_frame(&mut s, &Frame::IngestAck { seq, items }, &mut wire).is_err()
                    {
                        return;
                    }
                    acked += 1;
                } else if die_after {
                    return; // drop the socket: the "crash"
                }
                // else: silent — keep draining frames, never ack.
            }
        });
        (ep, handle)
    }

    #[test]
    fn silent_server_mid_burst_hits_the_deadline() {
        // Regression: the pipelined client blocks on an ack read once
        // the in-flight window fills; with a server that stops acking
        // mid-burst that read used to hang forever. The deadline must
        // turn it into a typed error, promptly.
        let (ep, server) = treacherous_server(1, false);
        let mut c = IngestClient::connect_with_deadline(&ep, Duration::from_millis(300))
            .unwrap()
            .with_inflight(2);
        let t0 = Instant::now();
        let err = (0..64u64)
            .find_map(|i| c.send_items(&[i; 8]).err())
            .expect("a silent server must surface an error, not hang");
        assert!(
            err.to_string().contains("deadline expired"),
            "want a typed deadline error, got: {err}"
        );
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "the deadline must fire promptly, not after the old 30s safety net"
        );
        drop(c); // closes the socket; the server thread sees EOF
        server.join().unwrap();
    }

    #[test]
    fn server_death_mid_burst_is_a_typed_error() {
        let (ep, server) = treacherous_server(1, true);
        let mut c = IngestClient::connect_with_deadline(&ep, Duration::from_secs(5))
            .unwrap()
            .with_inflight(2);
        let err = match (0..64u64).find_map(|i| c.send_items(&[i; 8]).err()) {
            Some(e) => e,
            // All writes may land in socket buffers before the close is
            // observed; the drain must fail instead.
            None => c.finish().expect_err("finish against a dead server must fail"),
        };
        let msg = err.to_string().to_lowercase();
        assert!(
            msg.contains("unacked")
                || msg.contains("truncat")
                || msg.contains("pipe")
                || msg.contains("reset")
                || msg.contains("connection"),
            "want a typed closed/truncated error, got: {err}"
        );
        server.join().unwrap();
    }

    #[test]
    fn connect_retry_reaches_a_late_server() {
        use crate::util::Backoff;
        // Nothing is listening yet; a connect_retry with a few attempts
        // must succeed once the server appears between attempts.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener); // free the port: first attempts fail
        let ep = Endpoint::Tcp(addr.clone());
        let opener = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(60));
            tiny_server_at(&addr)
        });
        let mut backoff =
            Backoff::new(Duration::from_millis(20), Duration::from_millis(100), 7);
        let c = QueryClient::connect_retry(&ep, Duration::from_secs(5), 50, &mut backoff)
            .expect("retry must outlast the startup gap");
        assert!(backoff.attempt() > 0, "at least one failed attempt backed off");
        drop(c);
        opener.join().unwrap().finish();
    }

    fn tiny_server_at(addr: &str) -> Server {
        Server::bind(
            &Endpoint::Tcp(addr.to_string()),
            ServeConfig {
                coordinator: CoordinatorConfig {
                    shards: 2,
                    k: 64,
                    k_majority: 8,
                    epoch_items: 200,
                    ..Default::default()
                },
                query_threads: 1,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn oversized_chunk_is_rejected_client_side() {
        let server = tiny_server();
        let mut c = IngestClient::connect(server.endpoint()).unwrap();
        let e = c.send_runs(&[(1, MAX_FRAME_MASS + 1)]).unwrap_err();
        assert!(e.to_string().contains("mass"), "{e}");
        // The wire-length caps bind too: a flat chunk between
        // MAX_ITEMS_PER_FRAME and MAX_FRAME_MASS items would pass the
        // mass check yet exceed MAX_FRAME_LEN server-side, so the
        // client must reject it before writing a byte.
        let big = vec![0u64; MAX_ITEMS_PER_FRAME + 1];
        let e = c.send_items(&big).unwrap_err();
        assert!(e.to_string().contains("item cap"), "{e}");
        let runs = vec![(0u64, 1u64); MAX_RUNS_PER_FRAME + 1];
        let e = c.send_runs(&runs).unwrap_err();
        assert!(e.to_string().contains("run cap"), "{e}");
        server.finish();
    }
}
