//! The `pss` wire protocol: length-prefixed binary frames over a byte
//! stream (TCP or Unix socket).
//!
//! A connection opens with an 8-byte **hello**, then carries
//! self-describing **frames**:
//!
//! ```text
//!  hello (client → server, once):
//!  ┌─────────────┬────────────┬──────────┬───────────┐
//!  │ magic: u32  │ version:u16│ role: u8 │ flags: u8 │   "PSS1", 2, ingest|query|worker, 0
//!  └─────────────┴────────────┴──────────┴───────────┘
//!
//!  frame (either direction, repeated):
//!  ┌────────────┬───────────┬──────────────────────────┐
//!  │ len: u32   │ kind: u8  │ body: len − 1 bytes      │   len covers kind + body
//!  └────────────┴───────────┴──────────────────────────┘
//! ```
//!
//! All integers are **little-endian**. `len` is capped at
//! [`MAX_FRAME_LEN`] so a malformed or hostile peer cannot make the
//! server allocate unboundedly. Ingest payloads come in two shapes:
//!
//! * [`Frame::IngestItems`] — a flat `u64` item array. The body is a
//!   byte-image of the chunk buffer: decoding is a bounds check plus a
//!   `u64::from_le_bytes` sweep straight into a recycled `Vec<u64>`
//!   ([`decode_ingest_into`]), so the zero-alloc ingest steady state
//!   survives the socket hop.
//! * [`Frame::IngestRuns`] — `(item, weight)` pairs, the batched-ingest
//!   run representation. Under skew this is the compact encoding (a
//!   chunk of 16k items collapses to its distinct items); the server
//!   expands runs back into the chunk buffer, and the *declared mass*
//!   (Σ weights) is validated against [`MAX_FRAME_MASS`] before any
//!   expansion happens, so a tiny frame cannot claim a huge weight and
//!   blow up server memory.
//!
//! Every ingest frame carries a client-chosen `seq`; the server answers
//! each with [`Frame::IngestAck`]`{seq, items}`. Acks return in frame
//! order (the transport is a byte stream), which is what lets the
//! client pipeline frames and still attribute per-frame latency.
//!
//! Malformed input never panics and never kills the server: every
//! decode path returns a typed [`ProtoError`], which the server maps to
//! a [`Frame::Error`] (code + message) before closing *that*
//! connection only.
//!
//! Version 2 adds the **worker** role and the cluster snapshot
//! exchange: a cluster head connects with [`Role::Worker`] and pulls
//! [`Frame::SummarySnapshot`] replies to [`Frame::SummaryRequest`] —
//! the worker's full merged Space Saving state ([`WireSnapshot`]:
//! counters with per-counter error, the exact hot-key side table with
//! its history bounds, `n`, `k`, the worker-computed ε and the
//! unmonitored-item bound) so the head can replicate the worker's own
//! read-path merge exactly and combine workers without weakening the
//! `f ≤ f̂ ≤ f + ε` guarantee.

use std::io::{Read, Write};
use std::time::{Duration, Instant};

/// Connection magic: `b"PSS1"` read as a little-endian u32.
pub const MAGIC: u32 = u32::from_le_bytes(*b"PSS1");

/// Protocol version carried in the hello. Version 2 added the worker
/// role and the cluster snapshot frames; version 3 widened
/// [`Frame::StatsResult`] with the query-cache counters; version 4
/// added the deadline layer ([`ErrorCode::Timeout`] and the
/// `deadline_expirations` stats counter).
pub const VERSION: u16 = 4;

/// Hard cap on `len` (kind + body), bytes. 16 MiB ≈ a 2M-item flat
/// chunk — far past any sane chunk_len, small enough to bound a
/// hostile peer's damage.
pub const MAX_FRAME_LEN: u32 = 16 << 20;

/// Hard cap on the declared item mass (Σ weights) of one ingest frame:
/// the expanded chunk buffer never exceeds this many items.
pub const MAX_FRAME_MASS: u64 = 4 << 20;

/// Most items a flat [`Frame::IngestItems`] frame can carry without
/// its wire image (`kind + seq + 8·items`) exceeding [`MAX_FRAME_LEN`]
/// — the binding cap for flat frames (≈2M, tighter than
/// [`MAX_FRAME_MASS`]). Senders must honor it or the server rejects
/// the frame with [`ProtoError::FrameTooLarge`].
pub const MAX_ITEMS_PER_FRAME: usize = (MAX_FRAME_LEN as usize - 9) / 8;

/// Most `(item, weight)` runs a [`Frame::IngestRuns`] frame can carry
/// within [`MAX_FRAME_LEN`] (`kind + seq + 16·runs`, ≈1M). The mass
/// cap bounds the *expanded* chunk; this bounds the wire image.
pub const MAX_RUNS_PER_FRAME: usize = (MAX_FRAME_LEN as usize - 9) / 16;

/// Connection role declared in the hello.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// This connection streams ingest frames (connection = producer).
    Ingest,
    /// This connection issues queries (served by the reader pool).
    Query,
    /// This connection is a cluster head pulling summary snapshots
    /// from a worker process ([`Frame::SummaryRequest`] /
    /// [`Frame::SummarySnapshot`]).
    Worker,
}

impl Role {
    fn to_u8(self) -> u8 {
        match self {
            Role::Ingest => 0,
            Role::Query => 1,
            Role::Worker => 2,
        }
    }

    fn from_u8(b: u8) -> Result<Role, ProtoError> {
        match b {
            0 => Ok(Role::Ingest),
            1 => Ok(Role::Query),
            2 => Ok(Role::Worker),
            other => Err(ProtoError::BadRole(other)),
        }
    }
}

impl std::fmt::Display for Role {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Role::Ingest => "ingest",
            Role::Query => "query",
            Role::Worker => "worker",
        })
    }
}

/// Frame kind discriminants (the `kind` byte on the wire). Public so
/// tests and raw-frame tooling can hand-assemble wire images without
/// going through [`Frame`].
pub mod kind {
    /// [`super::Frame::IngestItems`].
    pub const INGEST_ITEMS: u8 = 0x01;
    /// [`super::Frame::IngestRuns`].
    pub const INGEST_RUNS: u8 = 0x02;
    /// [`super::Frame::IngestAck`].
    pub const INGEST_ACK: u8 = 0x03;
    /// [`super::Frame::TopK`].
    pub const TOP_K: u8 = 0x10;
    /// [`super::Frame::Point`].
    pub const POINT: u8 = 0x11;
    /// [`super::Frame::KMajority`].
    pub const K_MAJORITY: u8 = 0x12;
    /// [`super::Frame::Stats`].
    pub const STATS: u8 = 0x13;
    /// [`super::Frame::TopKResult`].
    pub const TOP_K_RESULT: u8 = 0x20;
    /// [`super::Frame::PointResult`].
    pub const POINT_RESULT: u8 = 0x21;
    /// [`super::Frame::KMajorityResult`].
    pub const K_MAJORITY_RESULT: u8 = 0x22;
    /// [`super::Frame::StatsResult`].
    pub const STATS_RESULT: u8 = 0x23;
    /// [`super::Frame::HelloOk`].
    pub const HELLO_OK: u8 = 0x30;
    /// [`super::Frame::Shutdown`].
    pub const SHUTDOWN: u8 = 0x3E;
    /// [`super::Frame::ShutdownAck`].
    pub const SHUTDOWN_ACK: u8 = 0x3F;
    /// [`super::Frame::Error`].
    pub const ERROR: u8 = 0x40;
    /// [`super::Frame::SummaryRequest`].
    pub const SUMMARY_REQUEST: u8 = 0x50;
    /// [`super::Frame::SummarySnapshot`].
    pub const SUMMARY_SNAPSHOT: u8 = 0x51;
}

/// Typed error codes carried by [`Frame::Error`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Hello magic mismatch — not a pss client.
    BadMagic,
    /// Hello version unsupported.
    BadVersion,
    /// Frame failed to decode (bad length, unknown kind, bad payload).
    Malformed,
    /// Frame length or declared mass over the protocol caps.
    TooLarge,
    /// Frame kind not valid for this connection's role.
    WrongRole,
    /// Server is draining; no further frames accepted.
    ShuttingDown,
    /// Server at its ingest-connection limit.
    Overloaded,
    /// Windowed query against a server with no delta ring.
    WindowUnavailable,
    /// A read or write deadline expired mid-exchange; the peer closed
    /// the connection rather than block forever.
    Timeout,
    /// Code not understood by this build (forward compatibility).
    Unknown(u16),
}

impl ErrorCode {
    /// Wire encoding.
    pub fn to_u16(self) -> u16 {
        match self {
            ErrorCode::BadMagic => 1,
            ErrorCode::BadVersion => 2,
            ErrorCode::Malformed => 3,
            ErrorCode::TooLarge => 4,
            ErrorCode::WrongRole => 5,
            ErrorCode::ShuttingDown => 6,
            ErrorCode::Overloaded => 7,
            ErrorCode::WindowUnavailable => 8,
            ErrorCode::Timeout => 9,
            ErrorCode::Unknown(c) => c,
        }
    }

    /// Wire decoding (never fails: unknown codes round-trip).
    pub fn from_u16(c: u16) -> ErrorCode {
        match c {
            1 => ErrorCode::BadMagic,
            2 => ErrorCode::BadVersion,
            3 => ErrorCode::Malformed,
            4 => ErrorCode::TooLarge,
            5 => ErrorCode::WrongRole,
            6 => ErrorCode::ShuttingDown,
            7 => ErrorCode::Overloaded,
            8 => ErrorCode::WindowUnavailable,
            9 => ErrorCode::Timeout,
            other => ErrorCode::Unknown(other),
        }
    }
}

/// One wire counter in a query result: `(item, count, err)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireCounter {
    /// Item id.
    pub item: u64,
    /// Estimated count `f̂`.
    pub count: u64,
    /// Over-estimation bound (`f ≥ f̂ − err`).
    pub err: u64,
}

/// Server-side counters surfaced over the wire ([`Frame::StatsResult`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireStats {
    /// Items accepted into the coordinator.
    pub items: u64,
    /// Caller chunks accepted.
    pub chunks: u64,
    /// Chunk buffers reused instead of allocated (socket-path recycling).
    pub buffers_recycled: u64,
    /// Producer stalls on full shard queues.
    pub backpressure_events: u64,
    /// Epoch snapshots published so far.
    pub epochs_published: u64,
    /// Ingest connections accepted since bind.
    pub ingest_connections: u64,
    /// Query connections accepted since bind.
    pub query_connections: u64,
    /// Frames rejected with a protocol error.
    pub proto_errors: u64,
    /// Snapshot-cache fast-path hits on the server's query engines
    /// (landmark + windowed), aggregated across the query pool.
    pub cache_hits: u64,
    /// Snapshot-cache misses: queries that ran a merge server-side.
    pub cache_misses: u64,
    /// Merges avoided (hits plus slow-path reuses of a view another
    /// reader built concurrently); `≥ cache_hits`.
    pub merges_avoided: u64,
    /// Connections the server closed because a read or write deadline
    /// expired (slow, stalled, or vanished peers).
    pub deadline_expirations: u64,
}

/// A worker's full merged Space Saving state, shipped to the cluster
/// head in a [`Frame::SummarySnapshot`].
///
/// `counters` is the worker's **pre-hot-absorb** merged summary (the
/// disjoint concatenation or combine tree over its shards), and `hot`
/// the exact split-key side table — each hot entry's `count` is the
/// key's exact observed weight and its `err` the home-shard history
/// bound. The head replays the worker's own `absorb_exact` step from
/// these two pieces, so a cluster query is *bit-identical in bound
/// structure* to asking the worker directly. `epsilon` is
/// worker-computed (max-per-shard under keyed routing, `n/k`
/// otherwise): the head must take the max (key-disjoint workers) or
/// sum (overlapping workers) of these rather than recompute `n/k` from
/// the merged state, whose widened `k` would understate the bound.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct WireSnapshot {
    /// Max per-shard epoch folded into this snapshot (0 = nothing
    /// published yet).
    pub epoch: u64,
    /// Space Saving mass covered by `counters` (excludes hot mass).
    pub n: u64,
    /// Counter budget of the merged summary.
    pub k: u64,
    /// Worker-computed error bound every counter honors.
    pub epsilon: u64,
    /// Upper bound on any item *not* in `counters` or `hot` (the
    /// merged summary's min count; 0 while under-full).
    pub min_count: u64,
    /// Whether this worker's shards were key-disjoint (keyed routing).
    pub disjoint: bool,
    /// Whether this is the worker's final, drained state.
    pub finished: bool,
    /// The merged summary's counters (`item`, `count` = f̂, `err`).
    pub counters: Vec<WireCounter>,
    /// Exact hot-key side table: `item`, `count` = exact split weight,
    /// `err` = home-shard history bound for `absorb_exact`.
    pub hot: Vec<WireCounter>,
}

impl WireSnapshot {
    /// Total item mass this snapshot accounts for (Space Saving mass
    /// plus the exact hot side-table mass).
    pub fn total_mass(&self) -> u64 {
        self.n + self.hot.iter().map(|c| c.count).sum::<u64>()
    }
}

/// A decoded protocol frame.
///
/// `Ingest*` frames flow client→server; `*Result`/`IngestAck`/`Error`
/// flow server→client; `Shutdown` is the admin drain request (query
/// role); `Summary*` frames are the worker-role snapshot exchange.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Flat item chunk.
    IngestItems {
        /// Client-chosen sequence number, echoed by the ack.
        seq: u64,
        /// The items.
        items: Vec<u64>,
    },
    /// Pre-aggregated `(item, weight)` runs (batched-ingest shape).
    IngestRuns {
        /// Client-chosen sequence number, echoed by the ack.
        seq: u64,
        /// The runs; Σ weight ≤ [`MAX_FRAME_MASS`].
        runs: Vec<(u64, u64)>,
    },
    /// Per-ingest-frame acknowledgement.
    IngestAck {
        /// Echo of the ingest frame's `seq`.
        seq: u64,
        /// Item mass accepted from that frame.
        items: u64,
    },
    /// Top-`m` query; `window_epochs` 0 = landmark, else the last `w`
    /// epochs from the delta rings.
    TopK {
        /// How many heavy hitters to return.
        m: u32,
        /// 0 = landmark; else windowed width in epochs.
        window_epochs: u32,
    },
    /// Point frequency query for one item.
    Point {
        /// Item to look up.
        item: u64,
        /// 0 = landmark; else windowed width in epochs.
        window_epochs: u32,
    },
    /// k-majority query (`f̂ > n/k`).
    KMajority {
        /// The k in k-majority.
        k: u64,
        /// 0 = landmark; else windowed width in epochs.
        window_epochs: u32,
    },
    /// Server-side counter snapshot request.
    Stats,
    /// Top-k answer.
    TopKResult {
        /// Stream coverage of the answer.
        n: u64,
        /// Error bound every counter honors.
        epsilon: u64,
        /// The heavy hitters, descending by count.
        counters: Vec<WireCounter>,
    },
    /// Point answer.
    PointResult {
        /// Upper-bound estimate `f̂`.
        estimate: u64,
        /// Guaranteed lower bound.
        guaranteed: u64,
        /// Whether the item held a counter.
        monitored: bool,
        /// Stream coverage of the answer.
        n: u64,
    },
    /// k-majority answer, split per the paper.
    KMajorityResult {
        /// Stream coverage of the answer.
        n: u64,
        /// Error bound of the report.
        epsilon: u64,
        /// The absolute threshold the split was computed against
        /// (`n/k` for the *effective* k — the server substitutes its
        /// configured default when the request carried `k < 2`, and
        /// echoes the real threshold here so the client never guesses).
        threshold: u64,
        /// Lower bound clears the threshold: true positives.
        guaranteed: Vec<WireCounter>,
        /// Estimate clears it, lower bound does not: candidates.
        possible: Vec<WireCounter>,
    },
    /// Server counters.
    StatsResult(WireStats),
    /// Hello accepted; carries the server's protocol version.
    HelloOk {
        /// Server protocol version.
        version: u16,
    },
    /// Admin: drain and stop the server (query role).
    Shutdown,
    /// Shutdown request acknowledged; the server is draining.
    ShutdownAck,
    /// Typed failure; the server closes the connection after sending.
    Error {
        /// Machine-readable code.
        code: ErrorCode,
        /// Human-readable detail.
        message: String,
    },
    /// Cluster head → worker: ship me your current merged summary.
    /// `drain: true` additionally asks the worker to stop ingesting,
    /// drain its coordinator, reply with the *final* snapshot
    /// (`finished: true`) and shut down.
    SummaryRequest {
        /// Whether the worker should drain and exit after replying.
        drain: bool,
    },
    /// Worker → cluster head: the full merged summary state.
    SummarySnapshot(WireSnapshot),
}

/// Why a hello or frame failed to decode.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProtoError {
    /// Stream ended mid-hello or mid-frame.
    Truncated,
    /// Hello magic mismatch.
    BadMagic(u32),
    /// Hello version unsupported.
    BadVersion(u16),
    /// Hello role byte invalid.
    BadRole(u8),
    /// Zero-length frame (no kind byte).
    EmptyFrame,
    /// Unknown frame kind byte.
    UnknownKind(u8),
    /// Body length inconsistent with the frame kind.
    BadLength {
        /// Offending kind byte.
        kind: u8,
        /// Body length received.
        len: usize,
    },
    /// Frame length over [`MAX_FRAME_LEN`].
    FrameTooLarge(u32),
    /// Declared ingest mass over [`MAX_FRAME_MASS`] (or u64 overflow).
    MassTooLarge(u64),
    /// Error-frame message is not UTF-8.
    BadUtf8,
    /// A blocking read or write exceeded its deadline. Distinct from
    /// [`ProtoError::Io`] so callers can branch on "peer is slow or
    /// dead" versus "stream is broken" — the former is retryable, the
    /// latter is not.
    Timeout,
    /// Underlying socket error.
    Io(std::io::ErrorKind),
}

impl ProtoError {
    /// The wire error code a server should answer this failure with.
    pub fn code(&self) -> ErrorCode {
        match self {
            ProtoError::BadMagic(_) => ErrorCode::BadMagic,
            ProtoError::BadVersion(_) => ErrorCode::BadVersion,
            ProtoError::FrameTooLarge(_) | ProtoError::MassTooLarge(_) => ErrorCode::TooLarge,
            ProtoError::Timeout => ErrorCode::Timeout,
            _ => ErrorCode::Malformed,
        }
    }
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Truncated => write!(f, "stream truncated mid-frame"),
            ProtoError::BadMagic(m) => write!(f, "bad magic {m:#010x} (want {MAGIC:#010x})"),
            ProtoError::BadVersion(v) => write!(f, "unsupported version {v} (want {VERSION})"),
            ProtoError::BadRole(r) => write!(f, "invalid role byte {r}"),
            ProtoError::EmptyFrame => write!(f, "zero-length frame"),
            ProtoError::UnknownKind(k) => write!(f, "unknown frame kind {k:#04x}"),
            ProtoError::BadLength { kind, len } => {
                write!(f, "bad body length {len} for frame kind {kind:#04x}")
            }
            ProtoError::FrameTooLarge(l) => {
                write!(f, "frame length {l} over cap {MAX_FRAME_LEN}")
            }
            ProtoError::MassTooLarge(m) => {
                write!(f, "ingest mass {m} over cap {MAX_FRAME_MASS}")
            }
            ProtoError::BadUtf8 => write!(f, "error message is not UTF-8"),
            ProtoError::Timeout => write!(f, "deadline expired mid-exchange"),
            ProtoError::Io(k) => write!(f, "io error: {k:?}"),
        }
    }
}

impl std::error::Error for ProtoError {}

impl From<std::io::Error> for ProtoError {
    fn from(e: std::io::Error) -> Self {
        match e.kind() {
            std::io::ErrorKind::UnexpectedEof => ProtoError::Truncated,
            // OS-level socket timeouts (SO_RCVTIMEO/SO_SNDTIMEO)
            // surface as either kind depending on platform. The
            // resumable [`FrameReader::poll`] intercepts these as
            // [`Poll::Pending`] before this conversion runs; everywhere
            // else — blocking client reads, `write_frame`, the hello
            // exchange — an expired OS timeout is a typed deadline
            // failure, never a generic io error.
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => ProtoError::Timeout,
            kind => ProtoError::Io(kind),
        }
    }
}

// ---------------------------------------------------------------------------
// Little-endian body readers (all bounds-checked, never panic).

fn take_u64(body: &[u8], off: usize) -> Option<u64> {
    body.get(off..off + 8)
        .map(|b| u64::from_le_bytes(b.try_into().unwrap()))
}

fn take_u32(body: &[u8], off: usize) -> Option<u32> {
    body.get(off..off + 4)
        .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
}

fn take_u16(body: &[u8], off: usize) -> Option<u16> {
    body.get(off..off + 2)
        .map(|b| u16::from_le_bytes(b.try_into().unwrap()))
}

fn counters_bytes(counters: &[WireCounter], out: &mut Vec<u8>) {
    out.extend_from_slice(&(counters.len() as u32).to_le_bytes());
    for c in counters {
        out.extend_from_slice(&c.item.to_le_bytes());
        out.extend_from_slice(&c.count.to_le_bytes());
        out.extend_from_slice(&c.err.to_le_bytes());
    }
}

fn read_counters(kind: u8, body: &[u8], off: &mut usize) -> Result<Vec<WireCounter>, ProtoError> {
    let bad = |len| ProtoError::BadLength { kind, len };
    let count = take_u32(body, *off).ok_or(bad(body.len()))? as usize;
    *off += 4;
    // A counter is 24 bytes; reject declared counts past the body so a
    // hostile length cannot drive a huge reserve.
    if count > (body.len() - *off) / 24 {
        return Err(bad(body.len()));
    }
    let mut v = Vec::with_capacity(count);
    for _ in 0..count {
        let item = take_u64(body, *off).ok_or(bad(body.len()))?;
        let count_ = take_u64(body, *off + 8).ok_or(bad(body.len()))?;
        let err = take_u64(body, *off + 16).ok_or(bad(body.len()))?;
        *off += 24;
        v.push(WireCounter { item, count: count_, err });
    }
    Ok(v)
}

impl Frame {
    /// The frame's wire kind byte.
    pub fn kind(&self) -> u8 {
        match self {
            Frame::IngestItems { .. } => kind::INGEST_ITEMS,
            Frame::IngestRuns { .. } => kind::INGEST_RUNS,
            Frame::IngestAck { .. } => kind::INGEST_ACK,
            Frame::TopK { .. } => kind::TOP_K,
            Frame::Point { .. } => kind::POINT,
            Frame::KMajority { .. } => kind::K_MAJORITY,
            Frame::Stats => kind::STATS,
            Frame::TopKResult { .. } => kind::TOP_K_RESULT,
            Frame::PointResult { .. } => kind::POINT_RESULT,
            Frame::KMajorityResult { .. } => kind::K_MAJORITY_RESULT,
            Frame::StatsResult(_) => kind::STATS_RESULT,
            Frame::HelloOk { .. } => kind::HELLO_OK,
            Frame::Shutdown => kind::SHUTDOWN,
            Frame::ShutdownAck => kind::SHUTDOWN_ACK,
            Frame::Error { .. } => kind::ERROR,
            Frame::SummaryRequest { .. } => kind::SUMMARY_REQUEST,
            Frame::SummarySnapshot(_) => kind::SUMMARY_SNAPSHOT,
        }
    }

    /// Append this frame's wire image (`len | kind | body`) to `out`.
    /// The buffer is reusable across frames; steady-state encoding
    /// allocates nothing once it has grown to the working frame size.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let start = out.len();
        out.extend_from_slice(&[0u8; 4]); // len placeholder
        out.push(self.kind());
        match self {
            Frame::IngestItems { seq, items } => {
                out.extend_from_slice(&seq.to_le_bytes());
                for it in items {
                    out.extend_from_slice(&it.to_le_bytes());
                }
            }
            Frame::IngestRuns { seq, runs } => {
                out.extend_from_slice(&seq.to_le_bytes());
                for (item, weight) in runs {
                    out.extend_from_slice(&item.to_le_bytes());
                    out.extend_from_slice(&weight.to_le_bytes());
                }
            }
            Frame::IngestAck { seq, items } => {
                out.extend_from_slice(&seq.to_le_bytes());
                out.extend_from_slice(&items.to_le_bytes());
            }
            Frame::TopK { m, window_epochs } => {
                out.extend_from_slice(&m.to_le_bytes());
                out.extend_from_slice(&window_epochs.to_le_bytes());
            }
            Frame::Point { item, window_epochs } => {
                out.extend_from_slice(&item.to_le_bytes());
                out.extend_from_slice(&window_epochs.to_le_bytes());
            }
            Frame::KMajority { k, window_epochs } => {
                out.extend_from_slice(&k.to_le_bytes());
                out.extend_from_slice(&window_epochs.to_le_bytes());
            }
            Frame::Stats | Frame::Shutdown | Frame::ShutdownAck => {}
            Frame::TopKResult { n, epsilon, counters } => {
                out.extend_from_slice(&n.to_le_bytes());
                out.extend_from_slice(&epsilon.to_le_bytes());
                counters_bytes(counters, out);
            }
            Frame::PointResult { estimate, guaranteed, monitored, n } => {
                out.extend_from_slice(&estimate.to_le_bytes());
                out.extend_from_slice(&guaranteed.to_le_bytes());
                out.push(u8::from(*monitored));
                out.extend_from_slice(&n.to_le_bytes());
            }
            Frame::KMajorityResult { n, epsilon, threshold, guaranteed, possible } => {
                out.extend_from_slice(&n.to_le_bytes());
                out.extend_from_slice(&epsilon.to_le_bytes());
                out.extend_from_slice(&threshold.to_le_bytes());
                counters_bytes(guaranteed, out);
                counters_bytes(possible, out);
            }
            Frame::StatsResult(s) => {
                for v in [
                    s.items,
                    s.chunks,
                    s.buffers_recycled,
                    s.backpressure_events,
                    s.epochs_published,
                    s.ingest_connections,
                    s.query_connections,
                    s.proto_errors,
                    s.cache_hits,
                    s.cache_misses,
                    s.merges_avoided,
                    s.deadline_expirations,
                ] {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            Frame::HelloOk { version } => {
                out.extend_from_slice(&version.to_le_bytes());
            }
            Frame::Error { code, message } => {
                out.extend_from_slice(&code.to_u16().to_le_bytes());
                out.extend_from_slice(message.as_bytes());
            }
            Frame::SummaryRequest { drain } => {
                out.push(u8::from(*drain));
            }
            Frame::SummarySnapshot(s) => {
                out.extend_from_slice(&s.epoch.to_le_bytes());
                out.extend_from_slice(&s.n.to_le_bytes());
                out.extend_from_slice(&s.k.to_le_bytes());
                out.extend_from_slice(&s.epsilon.to_le_bytes());
                out.extend_from_slice(&s.min_count.to_le_bytes());
                out.push(u8::from(s.disjoint) | (u8::from(s.finished) << 1));
                counters_bytes(&s.counters, out);
                counters_bytes(&s.hot, out);
            }
        }
        let len = (out.len() - start - 4) as u32;
        out[start..start + 4].copy_from_slice(&len.to_le_bytes());
    }

    /// Encode into a fresh buffer (tests and one-shot senders).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// Decode a frame from its kind byte and body. Every failure is a
    /// typed [`ProtoError`]; no input panics.
    pub fn decode(kind_byte: u8, body: &[u8]) -> Result<Frame, ProtoError> {
        let bad = || ProtoError::BadLength { kind: kind_byte, len: body.len() };
        match kind_byte {
            kind::INGEST_ITEMS => {
                if body.len() < 8 || (body.len() - 8) % 8 != 0 {
                    return Err(bad());
                }
                let seq = take_u64(body, 0).ok_or_else(bad)?;
                let items = body[8..]
                    .chunks_exact(8)
                    .map(|b| u64::from_le_bytes(b.try_into().unwrap()))
                    .collect();
                Ok(Frame::IngestItems { seq, items })
            }
            kind::INGEST_RUNS => {
                if body.len() < 8 || (body.len() - 8) % 16 != 0 {
                    return Err(bad());
                }
                let seq = take_u64(body, 0).ok_or_else(bad)?;
                let mut runs = Vec::with_capacity((body.len() - 8) / 16);
                let mut mass = 0u64;
                for pair in body[8..].chunks_exact(16) {
                    let item = u64::from_le_bytes(pair[..8].try_into().unwrap());
                    let weight = u64::from_le_bytes(pair[8..].try_into().unwrap());
                    mass = mass
                        .checked_add(weight)
                        .ok_or(ProtoError::MassTooLarge(u64::MAX))?;
                    runs.push((item, weight));
                }
                if mass > MAX_FRAME_MASS {
                    return Err(ProtoError::MassTooLarge(mass));
                }
                Ok(Frame::IngestRuns { seq, runs })
            }
            kind::INGEST_ACK => {
                if body.len() != 16 {
                    return Err(bad());
                }
                Ok(Frame::IngestAck {
                    seq: take_u64(body, 0).ok_or_else(bad)?,
                    items: take_u64(body, 8).ok_or_else(bad)?,
                })
            }
            kind::TOP_K => {
                if body.len() != 8 {
                    return Err(bad());
                }
                Ok(Frame::TopK {
                    m: take_u32(body, 0).ok_or_else(bad)?,
                    window_epochs: take_u32(body, 4).ok_or_else(bad)?,
                })
            }
            kind::POINT => {
                if body.len() != 12 {
                    return Err(bad());
                }
                Ok(Frame::Point {
                    item: take_u64(body, 0).ok_or_else(bad)?,
                    window_epochs: take_u32(body, 8).ok_or_else(bad)?,
                })
            }
            kind::K_MAJORITY => {
                if body.len() != 12 {
                    return Err(bad());
                }
                Ok(Frame::KMajority {
                    k: take_u64(body, 0).ok_or_else(bad)?,
                    window_epochs: take_u32(body, 8).ok_or_else(bad)?,
                })
            }
            kind::STATS => {
                if !body.is_empty() {
                    return Err(bad());
                }
                Ok(Frame::Stats)
            }
            kind::TOP_K_RESULT => {
                let n = take_u64(body, 0).ok_or_else(bad)?;
                let epsilon = take_u64(body, 8).ok_or_else(bad)?;
                let mut off = 16;
                let counters = read_counters(kind_byte, body, &mut off)?;
                if off != body.len() {
                    return Err(bad());
                }
                Ok(Frame::TopKResult { n, epsilon, counters })
            }
            kind::POINT_RESULT => {
                if body.len() != 25 {
                    return Err(bad());
                }
                Ok(Frame::PointResult {
                    estimate: take_u64(body, 0).ok_or_else(bad)?,
                    guaranteed: take_u64(body, 8).ok_or_else(bad)?,
                    monitored: body[16] != 0,
                    n: take_u64(body, 17).ok_or_else(bad)?,
                })
            }
            kind::K_MAJORITY_RESULT => {
                let n = take_u64(body, 0).ok_or_else(bad)?;
                let epsilon = take_u64(body, 8).ok_or_else(bad)?;
                let threshold = take_u64(body, 16).ok_or_else(bad)?;
                let mut off = 24;
                let guaranteed = read_counters(kind_byte, body, &mut off)?;
                let possible = read_counters(kind_byte, body, &mut off)?;
                if off != body.len() {
                    return Err(bad());
                }
                Ok(Frame::KMajorityResult { n, epsilon, threshold, guaranteed, possible })
            }
            kind::STATS_RESULT => {
                if body.len() != 96 {
                    return Err(bad());
                }
                let f = |i: usize| take_u64(body, i * 8).unwrap();
                Ok(Frame::StatsResult(WireStats {
                    items: f(0),
                    chunks: f(1),
                    buffers_recycled: f(2),
                    backpressure_events: f(3),
                    epochs_published: f(4),
                    ingest_connections: f(5),
                    query_connections: f(6),
                    proto_errors: f(7),
                    cache_hits: f(8),
                    cache_misses: f(9),
                    merges_avoided: f(10),
                    deadline_expirations: f(11),
                }))
            }
            kind::HELLO_OK => {
                if body.len() != 2 {
                    return Err(bad());
                }
                Ok(Frame::HelloOk { version: take_u16(body, 0).ok_or_else(bad)? })
            }
            kind::SHUTDOWN => {
                if !body.is_empty() {
                    return Err(bad());
                }
                Ok(Frame::Shutdown)
            }
            kind::SHUTDOWN_ACK => {
                if !body.is_empty() {
                    return Err(bad());
                }
                Ok(Frame::ShutdownAck)
            }
            kind::ERROR => {
                let code = ErrorCode::from_u16(take_u16(body, 0).ok_or_else(bad)?);
                let message = std::str::from_utf8(&body[2..])
                    .map_err(|_| ProtoError::BadUtf8)?
                    .to_string();
                Ok(Frame::Error { code, message })
            }
            kind::SUMMARY_REQUEST => {
                if body.len() != 1 || body[0] > 1 {
                    return Err(bad());
                }
                Ok(Frame::SummaryRequest { drain: body[0] != 0 })
            }
            kind::SUMMARY_SNAPSHOT => {
                // Fixed prefix: 5 u64 fields + 1 flag byte = 41 bytes.
                let epoch = take_u64(body, 0).ok_or_else(bad)?;
                let n = take_u64(body, 8).ok_or_else(bad)?;
                let k = take_u64(body, 16).ok_or_else(bad)?;
                let epsilon = take_u64(body, 24).ok_or_else(bad)?;
                let min_count = take_u64(body, 32).ok_or_else(bad)?;
                let flags = *body.get(40).ok_or_else(bad)?;
                if flags > 3 {
                    return Err(bad());
                }
                let mut off = 41;
                let counters = read_counters(kind_byte, body, &mut off)?;
                let hot = read_counters(kind_byte, body, &mut off)?;
                if off != body.len() {
                    return Err(bad());
                }
                Ok(Frame::SummarySnapshot(WireSnapshot {
                    epoch,
                    n,
                    k,
                    epsilon,
                    min_count,
                    disjoint: flags & 1 != 0,
                    finished: flags & 2 != 0,
                    counters,
                    hot,
                }))
            }
            other => Err(ProtoError::UnknownKind(other)),
        }
    }
}

// ---------------------------------------------------------------------------
// Hello handshake.

/// Encode the 8-byte client hello.
pub fn encode_hello(role: Role) -> [u8; 8] {
    let mut h = [0u8; 8];
    h[0..4].copy_from_slice(&MAGIC.to_le_bytes());
    h[4..6].copy_from_slice(&VERSION.to_le_bytes());
    h[6] = role.to_u8();
    h
}

/// Read and validate the client hello, returning the declared role.
pub fn read_hello(r: &mut impl Read) -> Result<Role, ProtoError> {
    let mut h = [0u8; 8];
    r.read_exact(&mut h)?;
    let magic = u32::from_le_bytes(h[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(ProtoError::BadMagic(magic));
    }
    let version = u16::from_le_bytes(h[4..6].try_into().unwrap());
    if version != VERSION {
        return Err(ProtoError::BadVersion(version));
    }
    Role::from_u8(h[6])
}

// ---------------------------------------------------------------------------
// Stream framing.

/// Read one raw frame (`kind`, body in `scratch`). Returns `Ok(None)`
/// on a clean EOF *at a frame boundary*; EOF mid-frame is
/// [`ProtoError::Truncated`]. `scratch` is reused across calls so the
/// read side allocates nothing in the steady state.
pub fn read_frame<'a>(
    r: &mut impl Read,
    scratch: &'a mut Vec<u8>,
) -> Result<Option<(u8, &'a [u8])>, ProtoError> {
    let mut len4 = [0u8; 4];
    // A clean close before any header byte is a graceful end-of-stream.
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len4[got..]) {
            Ok(0) => {
                return if got == 0 {
                    Ok(None)
                } else {
                    Err(ProtoError::Truncated)
                };
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_le_bytes(len4);
    if len == 0 {
        return Err(ProtoError::EmptyFrame);
    }
    if len > MAX_FRAME_LEN {
        return Err(ProtoError::FrameTooLarge(len));
    }
    let mut kind_byte = [0u8; 1];
    r.read_exact(&mut kind_byte)?;
    scratch.clear();
    scratch.resize(len as usize - 1, 0);
    r.read_exact(scratch)?;
    Ok(Some((kind_byte[0], scratch.as_slice())))
}

/// Outcome of one [`FrameReader::poll`] call.
#[derive(Debug)]
pub enum Poll<'a> {
    /// A complete frame: `(kind, body)`.
    Frame(u8, &'a [u8]),
    /// The read timed out (or would block) with no frame complete; no
    /// bytes were lost — call again.
    Pending,
    /// Clean end of stream at a frame boundary.
    Eof,
}

/// A resumable frame reader for sockets with a read timeout.
///
/// The server polls connections so idle threads can observe the
/// shutdown flag, which means a read can time out *mid-frame* (TCP
/// delivers bytes in arbitrary pieces). A plain `read_exact` loop
/// would lose the partial bytes it already consumed and desync the
/// stream; this reader keeps the partial header/body across
/// [`Poll::Pending`] returns, so timeouts are always safe to retry.
#[derive(Debug, Default)]
pub struct FrameReader {
    header: [u8; 4],
    header_got: usize,
    /// `kind + body` length once the header parsed; `None` while the
    /// header is still being read.
    need: Option<usize>,
    buf: Vec<u8>,
    body_got: usize,
}

impl FrameReader {
    /// New reader with empty state.
    pub fn new() -> Self {
        Self::default()
    }

    /// Whether a frame is partially read (an EOF now would truncate).
    pub fn mid_frame(&self) -> bool {
        self.header_got > 0 || self.need.is_some()
    }

    /// Try to complete one frame from `r`. Timeouts return
    /// [`Poll::Pending`] without losing progress; a clean close at a
    /// frame boundary returns [`Poll::Eof`]; a close mid-frame is
    /// [`ProtoError::Truncated`].
    pub fn poll(&mut self, r: &mut impl Read) -> Result<Poll<'_>, ProtoError> {
        match self.step(r)? {
            Step::Pending => Ok(Poll::Pending),
            Step::Eof => Ok(Poll::Eof),
            Step::Frame => Ok(Poll::Frame(self.buf[0], &self.buf[1..])),
        }
    }

    /// Like [`poll`](Self::poll), but keeps retrying `Pending` until a
    /// frame completes or `deadline` elapses, at which point it fails
    /// with [`ProtoError::Timeout`]. Progress is cumulative across OS
    /// read timeouts (the resumable state absorbs them), so this is the
    /// blocking-with-deadline read every client uses: set a short OS
    /// read timeout on the socket (the poll quantum) and an overall
    /// deadline here.
    pub fn poll_deadline(
        &mut self,
        r: &mut impl Read,
        deadline: Duration,
    ) -> Result<Poll<'_>, ProtoError> {
        let start = Instant::now();
        loop {
            match self.step(r)? {
                Step::Pending => {
                    if start.elapsed() >= deadline {
                        return Err(ProtoError::Timeout);
                    }
                }
                Step::Eof => return Ok(Poll::Eof),
                Step::Frame => return Ok(Poll::Frame(self.buf[0], &self.buf[1..])),
            }
        }
    }

    /// One read attempt; the borrow-free core both poll flavors wrap.
    /// On `Step::Frame` the reader state is already reset and the frame
    /// sits in `self.buf` (`kind` at 0, body after).
    fn step(&mut self, r: &mut impl Read) -> Result<Step, ProtoError> {
        // Phase 1: the 4-byte length header.
        while self.need.is_none() {
            if self.header_got == 4 {
                let len = u32::from_le_bytes(self.header);
                if len == 0 {
                    return Err(ProtoError::EmptyFrame);
                }
                if len > MAX_FRAME_LEN {
                    return Err(ProtoError::FrameTooLarge(len));
                }
                self.need = Some(len as usize);
                self.buf.clear();
                self.buf.resize(len as usize, 0);
                self.body_got = 0;
                break;
            }
            match r.read(&mut self.header[self.header_got..]) {
                Ok(0) => {
                    return if self.mid_frame() {
                        Err(ProtoError::Truncated)
                    } else {
                        Ok(Step::Eof)
                    };
                }
                Ok(n) => self.header_got += n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    return Ok(Step::Pending);
                }
                Err(e) => return Err(e.into()),
            }
        }
        // Phase 2: kind byte + body.
        let need = self.need.unwrap_or(0);
        while self.body_got < need {
            match r.read(&mut self.buf[self.body_got..]) {
                Ok(0) => return Err(ProtoError::Truncated),
                Ok(n) => self.body_got += n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    return Ok(Step::Pending);
                }
                Err(e) => return Err(e.into()),
            }
        }
        // Complete: reset state for the next call, then hand out the
        // borrow (the buffer itself is only cleared on the next
        // header parse).
        self.header_got = 0;
        self.need = None;
        Ok(Step::Frame)
    }
}

/// Owned mirror of [`Poll`] used by [`FrameReader::step`] so the retry
/// loop in [`FrameReader::poll_deadline`] does not fight the borrow on
/// the frame buffer.
enum Step {
    Frame,
    Pending,
    Eof,
}

/// Encode and write one frame through `buf` (reused; no steady-state
/// allocation), then flush.
pub fn write_frame(
    w: &mut impl Write,
    frame: &Frame,
    buf: &mut Vec<u8>,
) -> Result<(), ProtoError> {
    buf.clear();
    frame.encode_into(buf);
    w.write_all(buf)?;
    w.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Zero-copy-friendly ingest decoding.

/// Decode an ingest frame body straight into a (recycled) chunk
/// buffer, returning `(seq, mass)`. [`Frame::IngestItems`] appends the
/// item array verbatim; [`Frame::IngestRuns`] validates the declared
/// mass against [`MAX_FRAME_MASS`] *before* expanding the runs, so the
/// output length is bounded no matter what the peer claims. Non-ingest
/// kinds return `Ok(None)` so callers can fall back to
/// [`Frame::decode`].
pub fn decode_ingest_into(
    kind_byte: u8,
    body: &[u8],
    out: &mut Vec<u64>,
) -> Result<Option<(u64, u64)>, ProtoError> {
    let bad = || ProtoError::BadLength { kind: kind_byte, len: body.len() };
    match kind_byte {
        kind::INGEST_ITEMS => {
            if body.len() < 8 || (body.len() - 8) % 8 != 0 {
                return Err(bad());
            }
            let seq = take_u64(body, 0).ok_or_else(bad)?;
            let mass = ((body.len() - 8) / 8) as u64;
            if mass > MAX_FRAME_MASS {
                return Err(ProtoError::MassTooLarge(mass));
            }
            out.reserve(mass as usize);
            for b in body[8..].chunks_exact(8) {
                out.push(u64::from_le_bytes(b.try_into().unwrap()));
            }
            Ok(Some((seq, mass)))
        }
        kind::INGEST_RUNS => {
            if body.len() < 8 || (body.len() - 8) % 16 != 0 {
                return Err(bad());
            }
            let seq = take_u64(body, 0).ok_or_else(bad)?;
            // Validate the total mass before growing `out` at all.
            let mut mass = 0u64;
            for pair in body[8..].chunks_exact(16) {
                let weight = u64::from_le_bytes(pair[8..].try_into().unwrap());
                mass = mass
                    .checked_add(weight)
                    .ok_or(ProtoError::MassTooLarge(u64::MAX))?;
            }
            if mass > MAX_FRAME_MASS {
                return Err(ProtoError::MassTooLarge(mass));
            }
            out.reserve(mass as usize);
            for pair in body[8..].chunks_exact(16) {
                let item = u64::from_le_bytes(pair[..8].try_into().unwrap());
                let weight = u64::from_le_bytes(pair[8..].try_into().unwrap());
                for _ in 0..weight {
                    out.push(item);
                }
            }
            Ok(Some((seq, mass)))
        }
        _ => Ok(None),
    }
}

/// Encode a flat item chunk as an `IngestItems` frame appended to
/// `out` (the reusable wire buffer): the hot-path encoder the ingest
/// client uses, skipping the `Frame` allocation entirely.
pub fn encode_items_into(seq: u64, items: &[u64], out: &mut Vec<u8>) {
    let len = (1 + 8 + 8 * items.len()) as u32;
    out.extend_from_slice(&len.to_le_bytes());
    out.push(kind::INGEST_ITEMS);
    out.extend_from_slice(&seq.to_le_bytes());
    for it in items {
        out.extend_from_slice(&it.to_le_bytes());
    }
}

/// Encode `(item, weight)` runs as an `IngestRuns` frame appended to
/// `out`. The caller guarantees Σ weight ≤ [`MAX_FRAME_MASS`] (a chunk
/// aggregated from ≤ `MAX_FRAME_MASS` items always does).
pub fn encode_runs_into(seq: u64, runs: &[(u64, u64)], out: &mut Vec<u8>) {
    let len = (1 + 8 + 16 * runs.len()) as u32;
    out.extend_from_slice(&len.to_le_bytes());
    out.push(kind::INGEST_RUNS);
    out.extend_from_slice(&seq.to_le_bytes());
    for (item, weight) in runs {
        out.extend_from_slice(&item.to_le_bytes());
        out.extend_from_slice(&weight.to_le_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: &Frame) -> Frame {
        let bytes = f.encode();
        let mut r = std::io::Cursor::new(bytes);
        let mut scratch = Vec::new();
        let (k, body) = read_frame(&mut r, &mut scratch).unwrap().unwrap();
        Frame::decode(k, body).unwrap()
    }

    #[test]
    fn frames_roundtrip() {
        let frames = [
            Frame::IngestItems { seq: 7, items: vec![1, 2, 3, u64::MAX] },
            Frame::IngestRuns { seq: 8, runs: vec![(5, 1000), (9, 1)] },
            Frame::IngestAck { seq: 7, items: 4 },
            Frame::TopK { m: 10, window_epochs: 0 },
            Frame::Point { item: 42, window_epochs: 3 },
            Frame::KMajority { k: 100, window_epochs: 0 },
            Frame::Stats,
            Frame::TopKResult {
                n: 1000,
                epsilon: 10,
                counters: vec![WireCounter { item: 1, count: 500, err: 3 }],
            },
            Frame::PointResult { estimate: 9, guaranteed: 4, monitored: true, n: 100 },
            Frame::KMajorityResult {
                n: 1000,
                epsilon: 10,
                threshold: 125,
                guaranteed: vec![WireCounter { item: 1, count: 900, err: 0 }],
                possible: vec![WireCounter { item: 2, count: 11, err: 5 }],
            },
            Frame::StatsResult(WireStats {
                items: 1,
                chunks: 2,
                buffers_recycled: 3,
                backpressure_events: 4,
                epochs_published: 5,
                ingest_connections: 6,
                query_connections: 7,
                proto_errors: 8,
                cache_hits: 9,
                cache_misses: 10,
                merges_avoided: 11,
                deadline_expirations: 12,
            }),
            Frame::HelloOk { version: VERSION },
            Frame::Shutdown,
            Frame::ShutdownAck,
            Frame::Error { code: ErrorCode::Malformed, message: "nope".into() },
            Frame::SummaryRequest { drain: false },
            Frame::SummaryRequest { drain: true },
            Frame::SummarySnapshot(WireSnapshot {
                epoch: 12,
                n: 90_000,
                k: 512,
                epsilon: 175,
                min_count: 40,
                disjoint: true,
                finished: false,
                counters: vec![
                    WireCounter { item: 3, count: 700, err: 20 },
                    WireCounter { item: 9, count: 41, err: 41 },
                ],
                hot: vec![WireCounter { item: 1, count: 5000, err: 17 }],
            }),
            // Empty worker state (nothing published yet) encodes too.
            Frame::SummarySnapshot(WireSnapshot { k: 16, ..WireSnapshot::default() }),
        ];
        for f in &frames {
            assert_eq!(&roundtrip(f), f, "{f:?}");
        }
    }

    #[test]
    fn hello_roundtrips_and_rejects() {
        for role in [Role::Ingest, Role::Query, Role::Worker] {
            let h = encode_hello(role);
            let mut r = std::io::Cursor::new(h.to_vec());
            assert_eq!(read_hello(&mut r).unwrap(), role);
        }
        // Bad magic.
        let mut h = encode_hello(Role::Ingest);
        h[0] ^= 0xFF;
        assert!(matches!(
            read_hello(&mut std::io::Cursor::new(h.to_vec())),
            Err(ProtoError::BadMagic(_))
        ));
        // Bad version.
        let mut h = encode_hello(Role::Ingest);
        h[4] = 99;
        assert!(matches!(
            read_hello(&mut std::io::Cursor::new(h.to_vec())),
            Err(ProtoError::BadVersion(99))
        ));
        // Bad role.
        let mut h = encode_hello(Role::Ingest);
        h[6] = 7;
        assert!(matches!(
            read_hello(&mut std::io::Cursor::new(h.to_vec())),
            Err(ProtoError::BadRole(7))
        ));
        // Truncated hello.
        assert!(matches!(
            read_hello(&mut std::io::Cursor::new(vec![1, 2, 3])),
            Err(ProtoError::Truncated)
        ));
    }

    #[test]
    fn clean_eof_vs_truncation() {
        let mut scratch = Vec::new();
        // Empty stream: clean end.
        let mut r = std::io::Cursor::new(Vec::<u8>::new());
        assert!(read_frame(&mut r, &mut scratch).unwrap().is_none());
        // One whole frame then EOF: frame, then clean end.
        let bytes = Frame::Stats.encode();
        let mut r = std::io::Cursor::new(bytes.clone());
        assert!(read_frame(&mut r, &mut scratch).unwrap().is_some());
        assert!(read_frame(&mut r, &mut scratch).unwrap().is_none());
        // Cut mid-header and mid-body: truncation, not a panic.
        for cut in 1..bytes.len() {
            let mut r = std::io::Cursor::new(bytes[..cut].to_vec());
            assert_eq!(
                read_frame(&mut r, &mut scratch).unwrap_err(),
                ProtoError::Truncated,
                "cut at {cut}"
            );
        }
    }

    #[test]
    fn oversized_and_empty_frames_rejected() {
        let mut scratch = Vec::new();
        let mut bytes = vec![];
        bytes.extend_from_slice(&(MAX_FRAME_LEN + 1).to_le_bytes());
        bytes.push(kind::STATS);
        let mut r = std::io::Cursor::new(bytes);
        assert!(matches!(
            read_frame(&mut r, &mut scratch),
            Err(ProtoError::FrameTooLarge(_))
        ));
        let mut r = std::io::Cursor::new(0u32.to_le_bytes().to_vec());
        assert_eq!(read_frame(&mut r, &mut scratch).unwrap_err(), ProtoError::EmptyFrame);
    }

    #[test]
    fn runs_mass_cap_enforced_before_expansion() {
        // A 32-byte frame claiming u64::MAX mass must be rejected
        // without growing the output buffer.
        let f = Frame::IngestRuns { seq: 1, runs: vec![(3, MAX_FRAME_MASS + 1)] };
        let bytes = f.encode();
        let mut out = Vec::new();
        let err = decode_ingest_into(bytes[4], &bytes[5..], &mut out).unwrap_err();
        assert!(matches!(err, ProtoError::MassTooLarge(_)));
        assert!(out.is_empty(), "no expansion before validation");
        // Overflowing sums are caught too.
        let f = Frame::IngestRuns { seq: 1, runs: vec![(3, u64::MAX), (4, 2)] };
        let bytes = f.encode();
        assert!(matches!(
            decode_ingest_into(bytes[4], &bytes[5..], &mut out),
            Err(ProtoError::MassTooLarge(_))
        ));
        // Frame::decode applies the same cap.
        assert!(matches!(
            Frame::decode(bytes[4], &bytes[5..]),
            Err(ProtoError::MassTooLarge(_))
        ));
    }

    #[test]
    fn ingest_decode_into_expands_runs() {
        let mut out = vec![99]; // pre-existing content is preserved
        let f = Frame::IngestRuns { seq: 5, runs: vec![(7, 3), (8, 1)] };
        let bytes = f.encode();
        let (seq, mass) = decode_ingest_into(bytes[4], &bytes[5..], &mut out)
            .unwrap()
            .unwrap();
        assert_eq!((seq, mass), (5, 4));
        assert_eq!(out, vec![99, 7, 7, 7, 8]);

        let mut out = Vec::new();
        let mut wire = Vec::new();
        encode_items_into(9, &[4, 5, 6], &mut wire);
        let mut r = std::io::Cursor::new(wire);
        let mut scratch = Vec::new();
        let (k, body) = read_frame(&mut r, &mut scratch).unwrap().unwrap();
        let (seq, mass) = decode_ingest_into(k, body, &mut out).unwrap().unwrap();
        assert_eq!((seq, mass), (9, 3));
        assert_eq!(out, vec![4, 5, 6]);

        // Non-ingest frames pass through untouched.
        let bytes = Frame::Stats.encode();
        assert!(decode_ingest_into(bytes[4], &bytes[5..], &mut out)
            .unwrap()
            .is_none());
        assert_eq!(out, vec![4, 5, 6]);
    }

    #[test]
    fn per_frame_caps_match_encoded_lengths() {
        // A frame at exactly the item cap fits; one more item busts
        // MAX_FRAME_LEN. (Checked on the length formula, not a real
        // 16 MiB buffer.)
        assert!(9 + 8 * MAX_ITEMS_PER_FRAME as u64 <= MAX_FRAME_LEN as u64);
        assert!(9 + 8 * (MAX_ITEMS_PER_FRAME as u64 + 1) > MAX_FRAME_LEN as u64);
        assert!(9 + 16 * MAX_RUNS_PER_FRAME as u64 <= MAX_FRAME_LEN as u64);
        assert!(9 + 16 * (MAX_RUNS_PER_FRAME as u64 + 1) > MAX_FRAME_LEN as u64);
        // The formulas mirror the hot-path encoders: frame len =
        // kind(1) + seq(8) + payload.
        let mut wire = Vec::new();
        encode_items_into(1, &[7; 13], &mut wire);
        assert_eq!(u32::from_le_bytes(wire[..4].try_into().unwrap()), 9 + 8 * 13);
        wire.clear();
        encode_runs_into(1, &[(7, 2); 13], &mut wire);
        assert_eq!(u32::from_le_bytes(wire[..4].try_into().unwrap()), 9 + 16 * 13);
    }

    #[test]
    fn hot_path_encoders_match_frame_encoding() {
        let mut wire = Vec::new();
        encode_items_into(3, &[10, 20], &mut wire);
        assert_eq!(wire, Frame::IngestItems { seq: 3, items: vec![10, 20] }.encode());
        wire.clear();
        encode_runs_into(4, &[(10, 2)], &mut wire);
        assert_eq!(wire, Frame::IngestRuns { seq: 4, runs: vec![(10, 2)] }.encode());
    }

    #[test]
    fn malformed_bodies_are_typed_errors() {
        // Wrong body sizes for fixed-size frames.
        for (k, len) in [
            (kind::INGEST_ACK, 15),
            (kind::TOP_K, 7),
            (kind::POINT, 11),
            (kind::K_MAJORITY, 0),
            (kind::STATS, 1),
            (kind::POINT_RESULT, 24),
            (kind::STATS_RESULT, 64),
            (kind::STATS_RESULT, 88),
            (kind::STATS_RESULT, 95),
            (kind::HELLO_OK, 3),
            (kind::SHUTDOWN, 2),
            (kind::SUMMARY_REQUEST, 0),
            (kind::SUMMARY_REQUEST, 2),
            (kind::SUMMARY_SNAPSHOT, 40),
        ] {
            let body = vec![0u8; len];
            assert!(
                matches!(Frame::decode(k, &body), Err(ProtoError::BadLength { .. })),
                "kind {k:#04x} len {len}"
            );
        }
        // Unknown kind.
        assert!(matches!(
            Frame::decode(0x77, &[]),
            Err(ProtoError::UnknownKind(0x77))
        ));
        // Counter list length lying past the body.
        let mut body = vec![0u8; 16];
        body.extend_from_slice(&1000u32.to_le_bytes());
        assert!(matches!(
            Frame::decode(kind::TOP_K_RESULT, &body),
            Err(ProtoError::BadLength { .. })
        ));
        // Non-UTF8 error message.
        let mut body = 3u16.to_le_bytes().to_vec();
        body.extend_from_slice(&[0xFF, 0xFE]);
        assert_eq!(Frame::decode(kind::ERROR, &body).unwrap_err(), ProtoError::BadUtf8);
    }

    #[test]
    fn malformed_snapshot_bodies_are_typed_errors() {
        let snap = Frame::SummarySnapshot(WireSnapshot {
            epoch: 1,
            n: 100,
            k: 8,
            epsilon: 12,
            min_count: 3,
            disjoint: false,
            finished: true,
            counters: vec![WireCounter { item: 5, count: 60, err: 2 }],
            hot: vec![],
        });
        let wire = snap.encode();
        let body = &wire[5..];
        // The well-formed body decodes back.
        assert_eq!(Frame::decode(kind::SUMMARY_SNAPSHOT, body).unwrap(), snap);
        // Every strict prefix of the body is a typed error, not a panic.
        for cut in 0..body.len() {
            assert!(
                matches!(
                    Frame::decode(kind::SUMMARY_SNAPSHOT, &body[..cut]),
                    Err(ProtoError::BadLength { kind: k, .. }) if k == kind::SUMMARY_SNAPSHOT
                ),
                "cut at {cut}"
            );
        }
        // Trailing garbage after the hot list is rejected.
        let mut long = body.to_vec();
        long.push(0);
        assert!(matches!(
            Frame::decode(kind::SUMMARY_SNAPSHOT, &long),
            Err(ProtoError::BadLength { .. })
        ));
        // A counter count lying past the body cannot drive a huge
        // allocation: rejected before any reserve.
        let mut lying = body.to_vec();
        lying[41..45].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            Frame::decode(kind::SUMMARY_SNAPSHOT, &lying),
            Err(ProtoError::BadLength { .. })
        ));
        // Undefined flag bits are rejected (reserved for evolution).
        let mut flagged = body.to_vec();
        flagged[40] = 4;
        assert!(matches!(
            Frame::decode(kind::SUMMARY_SNAPSHOT, &flagged),
            Err(ProtoError::BadLength { .. })
        ));
        // A drain byte other than 0/1 is rejected.
        assert!(matches!(
            Frame::decode(kind::SUMMARY_REQUEST, &[2]),
            Err(ProtoError::BadLength { .. })
        ));
    }

    /// A reader that yields one byte, then `WouldBlock`, alternating —
    /// the worst-case fragmentation a timed-out socket can produce.
    struct Dribble {
        data: Vec<u8>,
        pos: usize,
        starve: bool,
    }

    impl std::io::Read for Dribble {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            self.starve = !self.starve;
            if self.starve {
                return Err(std::io::ErrorKind::WouldBlock.into());
            }
            if self.pos >= self.data.len() {
                return Ok(0);
            }
            out[0] = self.data[self.pos];
            self.pos += 1;
            Ok(1)
        }
    }

    #[test]
    fn frame_reader_survives_timeouts_mid_frame() {
        let mut wire = Frame::IngestAck { seq: 3, items: 64 }.encode();
        wire.extend(Frame::Stats.encode());
        let mut r = Dribble { data: wire, pos: 0, starve: false };
        let mut fr = FrameReader::new();
        let mut got = Vec::new();
        loop {
            match fr.poll(&mut r).unwrap() {
                Poll::Frame(k, body) => got.push(Frame::decode(k, body).unwrap()),
                Poll::Pending => continue,
                Poll::Eof => break,
            }
        }
        assert_eq!(
            got,
            vec![Frame::IngestAck { seq: 3, items: 64 }, Frame::Stats]
        );
    }

    #[test]
    fn frame_reader_survives_dribbled_snapshot() {
        // The snapshot exchange must survive worst-case fragmentation
        // too: a request and a multi-counter snapshot, one byte per
        // read with a timeout between every byte.
        let snap = Frame::SummarySnapshot(WireSnapshot {
            epoch: 4,
            n: 50_000,
            k: 128,
            epsilon: 390,
            min_count: 390,
            disjoint: true,
            finished: true,
            counters: (0..128)
                .map(|i| WireCounter { item: i, count: 1000 - i, err: i % 7 })
                .collect(),
            hot: vec![WireCounter { item: 999, count: 77, err: 3 }],
        });
        let mut wire = Frame::SummaryRequest { drain: true }.encode();
        wire.extend(snap.encode());
        let mut r = Dribble { data: wire, pos: 0, starve: false };
        let mut fr = FrameReader::new();
        let mut got = Vec::new();
        loop {
            match fr.poll(&mut r).unwrap() {
                Poll::Frame(k, body) => got.push(Frame::decode(k, body).unwrap()),
                Poll::Pending => continue,
                Poll::Eof => break,
            }
        }
        assert_eq!(got, vec![Frame::SummaryRequest { drain: true }, snap]);
    }

    #[test]
    fn frame_reader_flags_truncation_and_boundaries() {
        // EOF mid-frame is truncation, not a clean end.
        let wire = Frame::Stats.encode();
        for cut in 1..wire.len() {
            let mut r = std::io::Cursor::new(wire[..cut].to_vec());
            let mut fr = FrameReader::new();
            loop {
                match fr.poll(&mut r) {
                    Ok(Poll::Pending) => continue,
                    Ok(other) => panic!("cut {cut}: unexpected {other:?}"),
                    Err(e) => {
                        assert_eq!(e, ProtoError::Truncated, "cut {cut}");
                        break;
                    }
                }
            }
        }
        // mid_frame reporting.
        let mut fr = FrameReader::new();
        assert!(!fr.mid_frame());
        let mut r = std::io::Cursor::new(wire[..2].to_vec());
        while !matches!(fr.poll(&mut r), Err(ProtoError::Truncated)) {}
        // Oversized frames rejected at the header.
        let mut fr = FrameReader::new();
        let mut bytes = (MAX_FRAME_LEN + 1).to_le_bytes().to_vec();
        bytes.push(kind::STATS);
        let mut r = std::io::Cursor::new(bytes);
        assert!(matches!(
            fr.poll(&mut r),
            Err(ProtoError::FrameTooLarge(_))
        ));
    }

    /// A reader that yields a byte prefix, then `WouldBlock` forever —
    /// a peer that sent part of a frame and went silent.
    struct PrefixThenStall {
        data: Vec<u8>,
        pos: usize,
    }

    impl std::io::Read for PrefixThenStall {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.pos >= self.data.len() {
                return Err(std::io::ErrorKind::WouldBlock.into());
            }
            out[0] = self.data[self.pos];
            self.pos += 1;
            Ok(1)
        }
    }

    #[test]
    fn poll_deadline_completes_or_times_out() {
        // A dribbled stream completes under the deadline: WouldBlock
        // gaps cost retries, not the frame.
        let wire = Frame::IngestAck { seq: 1, items: 2 }.encode();
        let mut r = Dribble { data: wire, pos: 0, starve: false };
        let mut fr = FrameReader::new();
        match fr.poll_deadline(&mut r, Duration::from_secs(5)).unwrap() {
            Poll::Frame(k, body) => {
                assert_eq!(
                    Frame::decode(k, body).unwrap(),
                    Frame::IngestAck { seq: 1, items: 2 }
                );
            }
            other => panic!("unexpected {other:?}"),
        }
        // A peer that goes silent before the first byte is a typed
        // Timeout, not a hang or an io error.
        let mut silent = PrefixThenStall { data: vec![], pos: 0 };
        let mut fr = FrameReader::new();
        assert_eq!(
            fr.poll_deadline(&mut silent, Duration::ZERO).unwrap_err(),
            ProtoError::Timeout
        );
        assert!(!fr.mid_frame());
        // A peer that stalls mid-frame times out too, and the partial
        // bytes stay buffered (a later retry could still finish).
        let mut partial = PrefixThenStall { data: Frame::Stats.encode()[..2].to_vec(), pos: 0 };
        let mut fr = FrameReader::new();
        assert_eq!(
            fr.poll_deadline(&mut partial, Duration::from_millis(1)).unwrap_err(),
            ProtoError::Timeout
        );
        assert!(fr.mid_frame(), "partial header survives the timeout");
    }

    #[test]
    fn io_timeouts_map_to_typed_timeout() {
        for kind in [std::io::ErrorKind::WouldBlock, std::io::ErrorKind::TimedOut] {
            assert_eq!(ProtoError::from(std::io::Error::from(kind)), ProtoError::Timeout);
        }
        assert_eq!(ProtoError::Timeout.code(), ErrorCode::Timeout);
    }

    #[test]
    fn error_codes_roundtrip() {
        for code in [
            ErrorCode::BadMagic,
            ErrorCode::BadVersion,
            ErrorCode::Malformed,
            ErrorCode::TooLarge,
            ErrorCode::WrongRole,
            ErrorCode::ShuttingDown,
            ErrorCode::Overloaded,
            ErrorCode::WindowUnavailable,
            ErrorCode::Timeout,
            ErrorCode::Unknown(999),
        ] {
            assert_eq!(ErrorCode::from_u16(code.to_u16()), code);
        }
    }
}
