//! The sliding-window layer — time-scoped frequent items over the
//! streaming shards.
//!
//! The landmark read path ([`crate::query`]) answers "top-k since
//! startup". Production stream mining usually wants "top-k over the
//! last W items / last few minutes" — the query-window gap QPOPSS
//! (Jarlow et al., arXiv:2409.01749) identifies for query-heavy Space
//! Saving deployments. Because the paper's `combine` (Algorithm 2)
//! makes summaries mergeable, windows fall out of *deltas*: publish
//! the Space Saving state of each epoch separately, keep a bounded
//! ring of recent deltas, and merge exactly the in-window ones on
//! demand.
//!
//! ```text
//!  shard worker (per epoch_items, refresh(), drain):
//!    chunk ─▶ ChunkAggregator runs ─▶ cumulative StreamSummary ─▶ EpochRegistry (landmark)
//!                      └──────────▶ DeltaBuilder ──cut()──▶ WindowStore ring  (window)
//!                                                           [Δ₁ Δ₂ … Δᵣ] oldest retired
//!  windowed query:
//!    last w deltas × shards ──borrow──▶ tree_reduce_refs(combine) ─▶ WindowSnapshot
//!                                        top_k / point / k_majority / stats
//! ```
//!
//! * [`delta`] — [`DeltaBuilder`]: epoch-lifetime `(item, weight)`
//!   accumulation (reusing the batched-ingest run aggregation) and the
//!   `cut()` that freezes an epoch into a delta [`Summary`].
//! * [`store`] — [`DeltaSummary`] and the [`WindowStore`]: bounded
//!   per-shard delta rings with inline retirement, writers never
//!   blocked by readers.
//! * [`engine`] — [`WindowedQueryEngine`] / [`WindowSnapshot`]:
//!   `top_k_window`, `point_in_window`, `k_majority_window`,
//!   `window_by_age`, `window_stats`.
//!
//! Guarantee: a window covering deltas of total mass `W` (with counter
//! budget `k`) satisfies `f ≤ f̂ ≤ f + W/k` for every item's true count
//! `f` within the covered window, and monitors every item with
//! `f > W/k` — the Space Saving bound, re-scoped from the whole stream
//! to the window (`prop_windowed_bounds` drives this across shard
//! counts and window widths). The coordinator wires the layer up when
//! [`CoordinatorConfig::delta_ring`] > 0; every delta publication is
//! accounted so window mass balances ingest
//! ([`IngestStats::deltas_published`]).
//!
//! [`Summary`]: crate::summary::Summary
//! [`CoordinatorConfig::delta_ring`]: crate::coordinator::CoordinatorConfig::delta_ring
//! [`IngestStats::deltas_published`]: crate::coordinator::IngestStats::deltas_published

pub mod delta;
pub mod engine;
pub mod store;

pub use delta::DeltaBuilder;
pub use engine::{DeltaInfo, WindowSnapshot, WindowStats, WindowedQueryEngine};
pub use store::{DeltaSummary, WindowStore};
