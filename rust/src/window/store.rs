//! `WindowStore` — bounded per-shard rings of published epoch deltas,
//! the shared state between the shard workers (delta publishers) and
//! every [`WindowedQueryEngine`](super::WindowedQueryEngine) handle.
//!
//! Each shard owns one delta ring: a `VecDeque` of the last
//! `capacity` `Arc<DeltaSummary>`s. Publication pushes the new delta
//! and retires the oldest in the same briefly-held write lock — both
//! are pointer moves, never data copies, so expiry happens inline on
//! the write path without a sweeper thread and without ever blocking
//! on a reader's merge (readers only hold the read lock long enough to
//! clone `Arc`s; the summaries themselves are immutable). This is the
//! same isolation discipline as [`crate::query::EpochSlot`], extended
//! from "latest snapshot" to "last R deltas".

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use crate::summary::Summary;

/// One published, immutable per-shard epoch delta: the Space Saving
/// state of just that epoch's items (`summary.n()` = the epoch's mass).
#[derive(Debug, Clone)]
pub struct DeltaSummary {
    /// Shard that published this delta.
    pub shard: usize,
    /// Per-shard delta sequence number (the first published delta is 1).
    pub seq: u64,
    /// The frozen delta summary (counters ascending, `n` = epoch mass).
    pub summary: Summary,
    /// When the delta was published (the basis of time-based windows).
    pub published_at: Instant,
    /// Whether this is the shard's final (drain-time) partial delta.
    pub finished: bool,
    /// Keyed-adaptive only: **exact** split-key counts this shard
    /// absorbed during this epoch (hot keys routed round-robin across
    /// shards bypass the Space Saving structures; `summary.n()`
    /// excludes this mass). Per-epoch, not cumulative — the windowed
    /// read path sums the in-window deltas' partials. Empty in every
    /// other routing mode.
    pub hot: Vec<(u64, u64)>,
}

impl DeltaSummary {
    /// Total exact split-key mass of this epoch (0 outside the
    /// keyed-adaptive hot tier).
    pub fn hot_mass(&self) -> u64 {
        self.hot.iter().map(|&(_, w)| w).sum()
    }
}

/// One shard's bounded delta ring.
#[derive(Debug)]
struct DeltaRing {
    /// Oldest → newest. The lock is held only for push/pop/`Arc` clones.
    deltas: RwLock<VecDeque<Arc<DeltaSummary>>>,
    /// Last published sequence number (0 = nothing published yet).
    seq: AtomicU64,
    /// Set at drain, whether or not a final delta was published.
    finished: AtomicBool,
}

/// Shared delta-ring state: `shards` rings of `capacity` deltas each.
#[derive(Debug)]
pub struct WindowStore {
    rings: Vec<DeltaRing>,
    capacity: usize,
    /// Counter budget every published delta was cut with.
    k: usize,
    deltas_published: AtomicU64,
    deltas_retired: AtomicU64,
    queries_served: AtomicU64,
    /// Whether deltas of *different shards* are key-disjoint (keyed
    /// routing). Deltas of the same shard always overlap (same
    /// substream over time), so the windowed disjoint merge combines
    /// within a shard first, then concatenates across shards.
    disjoint: AtomicBool,
}

impl WindowStore {
    /// Store for `shards` rings holding `capacity` deltas each, all cut
    /// with counter budget `k`.
    pub fn new(shards: usize, capacity: usize, k: usize) -> Arc<Self> {
        assert!(shards >= 1 && capacity >= 1 && k >= 1);
        Arc::new(Self {
            rings: (0..shards)
                .map(|_| DeltaRing {
                    deltas: RwLock::new(VecDeque::with_capacity(capacity + 1)),
                    seq: AtomicU64::new(0),
                    finished: AtomicBool::new(false),
                })
                .collect(),
            capacity,
            k,
            deltas_published: AtomicU64::new(0),
            deltas_retired: AtomicU64::new(0),
            queries_served: AtomicU64::new(0),
            disjoint: AtomicBool::new(false),
        })
    }

    /// Declare the shards' substreams key-disjoint (keyed routing; the
    /// coordinator calls this before any delta is published). Windowed
    /// engines then combine within each shard and concatenate across
    /// shards, reporting the max-per-shard bound.
    pub fn set_disjoint(&self, disjoint: bool) {
        self.disjoint.store(disjoint, Ordering::Release);
    }

    /// Whether shard substreams are key-disjoint (keyed routing).
    pub fn disjoint(&self) -> bool {
        self.disjoint.load(Ordering::Acquire)
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.rings.len()
    }

    /// Ring capacity (deltas retained per shard).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Counter budget of the published deltas.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Publisher side: append shard `shard`'s next epoch delta, retiring
    /// the oldest one if the ring is full. Returns the delta's per-shard
    /// sequence number. `finished` marks the drain-time final delta.
    pub fn publish(&self, shard: usize, summary: Summary, finished: bool) -> u64 {
        self.publish_with_hot(shard, summary, finished, Vec::new())
    }

    /// [`WindowStore::publish`] carrying this epoch's exact split-key
    /// partials (keyed-adaptive hot tier; see [`DeltaSummary::hot`]).
    pub fn publish_with_hot(
        &self,
        shard: usize,
        summary: Summary,
        finished: bool,
        hot: Vec<(u64, u64)>,
    ) -> u64 {
        let ring = &self.rings[shard];
        // Single publisher per shard: load+store needs no RMW.
        let seq = ring.seq.load(Ordering::Relaxed) + 1;
        let delta = Arc::new(DeltaSummary {
            shard,
            seq,
            summary,
            published_at: Instant::now(),
            finished,
            hot,
        });
        {
            let mut q = ring.deltas.write().expect("delta ring poisoned");
            q.push_back(delta);
            if q.len() > self.capacity {
                q.pop_front();
                self.deltas_retired.fetch_add(1, Ordering::Relaxed);
            }
        }
        ring.seq.store(seq, Ordering::Release);
        if finished {
            ring.finished.store(true, Ordering::Release);
        }
        self.deltas_published.fetch_add(1, Ordering::Relaxed);
        seq
    }

    /// Publisher side: mark a shard drained when its final partial
    /// epoch was empty (no delta to publish).
    pub fn finish_shard(&self, shard: usize) {
        self.rings[shard].finished.store(true, Ordering::Release);
    }

    /// Whether shard `shard` has published its drain-time state.
    pub fn shard_finished(&self, shard: usize) -> bool {
        self.rings[shard].finished.load(Ordering::Acquire)
    }

    /// Last sequence number shard `shard` published (0 = none yet).
    pub fn last_seq(&self, shard: usize) -> u64 {
        self.rings[shard].seq.load(Ordering::Acquire)
    }

    /// Deltas currently held for shard `shard` (≤ `capacity`).
    pub fn available(&self, shard: usize) -> usize {
        self.rings[shard].deltas.read().expect("delta ring poisoned").len()
    }

    /// Reader side: the newest `take` deltas of one shard, oldest →
    /// newest (fewer if the shard has not published that many).
    pub fn latest(&self, shard: usize, take: usize) -> Vec<Arc<DeltaSummary>> {
        let q = self.rings[shard].deltas.read().expect("delta ring poisoned");
        let skip = q.len().saturating_sub(take);
        q.iter().skip(skip).cloned().collect()
    }

    /// Reader side: the count-based window — the newest `epochs` deltas
    /// of **every** shard, concatenated (each shard's run oldest →
    /// newest).
    pub fn window(&self, epochs: usize) -> Vec<Arc<DeltaSummary>> {
        let mut parts = Vec::with_capacity(self.rings.len() * epochs.min(self.capacity));
        for shard in 0..self.rings.len() {
            parts.extend(self.latest(shard, epochs));
        }
        parts
    }

    /// Reader side: the coarse time-based window — every retained delta
    /// published within the last `max_age` (granularity = one epoch; a
    /// delta is in or out by its publication instant).
    pub fn window_by_age(&self, max_age: Duration) -> Vec<Arc<DeltaSummary>> {
        let now = Instant::now();
        let mut parts = Vec::new();
        for ring in &self.rings {
            let q = ring.deltas.read().expect("delta ring poisoned");
            parts.extend(
                q.iter()
                    .filter(|d| now.saturating_duration_since(d.published_at) <= max_age)
                    .cloned(),
            );
        }
        parts
    }

    /// Total deltas published across all shards.
    pub fn deltas_published(&self) -> u64 {
        self.deltas_published.load(Ordering::Relaxed)
    }

    /// Total deltas retired (pushed out of a full ring).
    pub fn deltas_retired(&self) -> u64 {
        self.deltas_retired.load(Ordering::Relaxed)
    }

    /// Count one served windowed query.
    pub fn count_query(&self) {
        self.queries_served.fetch_add(1, Ordering::Relaxed);
    }

    /// Windowed queries served so far.
    pub fn queries_served(&self) -> u64 {
        self.queries_served.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::summary::{FrequencySummary, SpaceSaving};

    fn summary_of(items: &[u64], k: usize) -> Summary {
        let mut ss = SpaceSaving::new(k);
        ss.offer_all(items);
        ss.freeze()
    }

    #[test]
    fn publish_sequences_and_ring_bound() {
        let store = WindowStore::new(2, 3, 8);
        for round in 1..=5u64 {
            let seq = store.publish(0, summary_of(&[round], 8), false);
            assert_eq!(seq, round);
        }
        assert_eq!(store.last_seq(0), 5);
        assert_eq!(store.last_seq(1), 0);
        assert_eq!(store.available(0), 3, "ring keeps only the newest 3");
        assert_eq!(store.deltas_published(), 5);
        assert_eq!(store.deltas_retired(), 2);
        // Oldest → newest, and only the surviving sequences.
        let seqs: Vec<u64> = store.latest(0, 10).iter().map(|d| d.seq).collect();
        assert_eq!(seqs, vec![3, 4, 5]);
        let newest: Vec<u64> = store.latest(0, 2).iter().map(|d| d.seq).collect();
        assert_eq!(newest, vec![4, 5]);
    }

    #[test]
    fn window_spans_all_shards() {
        let store = WindowStore::new(3, 4, 8);
        store.publish(0, summary_of(&[1, 1], 8), false);
        store.publish(2, summary_of(&[2], 8), false);
        store.publish(2, summary_of(&[3], 8), false);
        let parts = store.window(2);
        let mut got: Vec<(usize, u64)> = parts.iter().map(|d| (d.shard, d.seq)).collect();
        got.sort_unstable();
        assert_eq!(got, vec![(0, 1), (2, 1), (2, 2)]);
    }

    #[test]
    fn readers_pin_deltas_past_retirement() {
        let store = WindowStore::new(1, 1, 4);
        store.publish(0, summary_of(&[7, 7], 4), false);
        let pinned = store.latest(0, 1);
        // The ring retires seq 1, but the reader's Arc keeps it alive.
        store.publish(0, summary_of(&[9], 4), false);
        assert_eq!(pinned[0].seq, 1);
        assert_eq!(pinned[0].summary.estimate(7), Some(2));
        assert_eq!(store.latest(0, 1)[0].seq, 2);
    }

    #[test]
    fn finished_marks_drain() {
        let store = WindowStore::new(2, 2, 4);
        assert!(!store.shard_finished(0));
        store.publish(0, summary_of(&[1], 4), true);
        assert!(store.shard_finished(0));
        assert!(store.latest(0, 1)[0].finished);
        // Empty final epoch: no delta, still marked drained.
        store.finish_shard(1);
        assert!(store.shard_finished(1));
        assert_eq!(store.available(1), 0);
    }

    #[test]
    fn publish_with_hot_carries_epoch_partials() {
        let store = WindowStore::new(2, 4, 8);
        store.publish_with_hot(0, summary_of(&[1], 8), false, vec![(99, 7), (5, 3)]);
        store.publish(0, summary_of(&[2], 8), false);
        let parts = store.latest(0, 2);
        assert_eq!(parts[0].hot, vec![(99, 7), (5, 3)]);
        assert_eq!(parts[0].hot_mass(), 10);
        assert!(parts[1].hot.is_empty(), "plain publish carries no partials");
        assert_eq!(parts[1].hot_mass(), 0);
    }

    #[test]
    fn age_window_filters_old_deltas() {
        let store = WindowStore::new(1, 8, 4);
        store.publish(0, summary_of(&[1], 4), false);
        std::thread::sleep(Duration::from_millis(200));
        store.publish(0, summary_of(&[2], 4), false);
        // Generous cut between the two publication instants.
        let recent = store.window_by_age(Duration::from_millis(100));
        assert_eq!(recent.len(), 1);
        assert_eq!(recent[0].seq, 2);
        let all = store.window_by_age(Duration::from_secs(3600));
        assert_eq!(all.len(), 2);
    }

    #[test]
    fn concurrent_publish_and_read() {
        let store = WindowStore::new(1, 4, 16);
        std::thread::scope(|s| {
            let st = &store;
            s.spawn(move || {
                for round in 1..=300u64 {
                    st.publish(0, summary_of(&vec![round; round as usize], 16), false);
                }
            });
            s.spawn(move || {
                let mut last_newest = 0u64;
                for _ in 0..500 {
                    let parts = st.latest(0, 4);
                    // Sequences are contiguous oldest → newest and never
                    // go backwards across reads.
                    for w in parts.windows(2) {
                        assert_eq!(w[1].seq, w[0].seq + 1, "gap in ring");
                    }
                    if let Some(newest) = parts.last() {
                        assert!(newest.seq >= last_newest);
                        last_newest = newest.seq;
                        // Each delta is internally consistent.
                        assert_eq!(newest.summary.n(), newest.seq);
                    }
                }
            });
        });
        assert_eq!(store.last_seq(0), 300);
        assert_eq!(store.deltas_published(), 300);
        assert_eq!(store.deltas_retired(), 296);
    }
}
