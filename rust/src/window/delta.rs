//! `DeltaBuilder` — the write-side accumulator that turns one epoch's
//! items into a *delta summary*: the Space Saving state of just that
//! epoch.
//!
//! The builder is the epoch-lifetime sibling of
//! [`ChunkAggregator`](crate::summary::ChunkAggregator): the same
//! open-addressing scratch (`FastMap` item → run index plus an
//! `(item, weight)` run list), but accumulated *across* chunks instead
//! of cleared per chunk. On the batched ingest path the shard worker
//! already collapses each chunk into runs for the cumulative summary,
//! so feeding the window side costs one cheap map probe per *distinct*
//! item in the chunk ([`DeltaBuilder::absorb_runs`]) — not one summary
//! update per occurrence. The per-item path uses
//! [`DeltaBuilder::absorb_items`], one probe per occurrence.
//!
//! At each epoch boundary [`DeltaBuilder::cut`] freezes the epoch into
//! a [`Summary`] with counter budget `k` and resets the builder:
//!
//! * up to `k` distinct items — the delta is **exact** (`err = 0` on
//!   every counter): an aggregation, not a sketch;
//! * more than `k` — the `k` heaviest runs are kept exactly and the
//!   tail is pruned. Because every dropped run's count is at most the
//!   `k`-th heaviest (which is at most `n_delta/k`), this is a valid
//!   ε-deficient Space Saving state of the epoch: `f ≤ f̂ ≤
//!   f + n_delta/k`, full recall above `n_delta/k`, and its
//!   `min_count` bounds every unmonitored item — exactly what
//!   Algorithm 2's `combine` assumes of its inputs. (Cheaper than
//!   replaying the runs through a live summary, and the kept counters
//!   stay exact.)
//!
//! Either way the delta is a mergeable summary, so a window of deltas
//! combined by the paper's Algorithm 2 tree carries the windowed bound
//! `f ≤ f̂ ≤ f + W/k` (`W` = total window mass) — see
//! [`crate::window::WindowSnapshot`].

use crate::summary::{Counter, Summary};
use crate::util::FastMap;

/// Epoch-lifetime `(item, weight)` accumulator feeding the delta ring.
///
/// Scratch is recycled across epochs: [`DeltaBuilder::cut`] clears the
/// run list and index but keeps the allocation, shrinking back (with
/// 8× hysteresis, never below the construction floor) after an
/// unusually wide epoch so one burst does not tax every later reset.
#[derive(Debug)]
pub struct DeltaBuilder {
    /// item -> index into `runs` (cleared per epoch).
    index: FastMap,
    /// `(item, weight)` runs in first-occurrence order; weights are the
    /// item's **exact** count within the current epoch.
    runs: Vec<(u64, u64)>,
    /// Distinct-entry budget `index` is sized for.
    capacity: usize,
    /// Configured floor: the scratch never shrinks below this.
    min_capacity: usize,
    /// Total items absorbed since the last cut.
    mass: u64,
}

impl Default for DeltaBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl DeltaBuilder {
    /// Builder sized for epochs of moderate width; grows on demand.
    pub fn new() -> Self {
        Self::with_capacity(1024)
    }

    /// Builder sized for epochs of up to `distinct` distinct items
    /// without a rebuild (also the floor it never shrinks below).
    pub fn with_capacity(distinct: usize) -> Self {
        let capacity = distinct.max(16);
        Self {
            index: FastMap::with_capacity(capacity),
            runs: Vec::with_capacity(capacity),
            capacity,
            min_capacity: capacity,
            mass: 0,
        }
    }

    /// Items absorbed since the last cut (the pending delta's `n`).
    pub fn mass(&self) -> u64 {
        self.mass
    }

    /// Distinct items absorbed since the last cut.
    pub fn distinct(&self) -> usize {
        self.runs.len()
    }

    /// True if nothing was absorbed since the last cut.
    pub fn is_empty(&self) -> bool {
        self.mass == 0
    }

    /// Distinct-item budget the scratch map is currently sized for.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Double the index when the run list hits its budget (rebuild +
    /// reinsert; amortized O(1) per distinct item).
    fn grow_if_full(&mut self) {
        if self.runs.len() < self.capacity {
            return;
        }
        self.capacity *= 2;
        self.index = FastMap::with_capacity(self.capacity);
        for (i, &(item, _)) in self.runs.iter().enumerate() {
            self.index.insert(item, i as u32);
        }
    }

    /// Absorb `weight` occurrences of `item` into the pending epoch.
    #[inline]
    pub fn add(&mut self, item: u64, weight: u64) {
        if weight == 0 {
            return;
        }
        self.mass += weight;
        match self.index.get(item) {
            Some(r) => self.runs[r as usize].1 += weight,
            None => {
                self.grow_if_full();
                self.index.insert(item, self.runs.len() as u32);
                self.runs.push((item, weight));
            }
        }
    }

    /// Absorb pre-aggregated `(item, weight)` runs — the output of
    /// [`ChunkAggregator::aggregate`](crate::summary::ChunkAggregator::aggregate)
    /// the batched ingest path already computed for the cumulative
    /// summary, reused here at one probe per distinct item.
    pub fn absorb_runs(&mut self, runs: &[(u64, u64)]) {
        for &(item, weight) in runs {
            self.add(item, weight);
        }
    }

    /// Absorb raw items (the per-item ingest path), with the same
    /// prefetch pipelining as the summary hot loops.
    pub fn absorb_items(&mut self, items: &[u64]) {
        const AHEAD: usize = 8;
        for (i, &item) in items.iter().enumerate() {
            if let Some(&next) = items.get(i + AHEAD) {
                self.index.prefetch(next);
            }
            self.add(item, 1);
        }
    }

    /// Freeze the pending epoch into a delta [`Summary`] with counter
    /// budget `k` and reset the builder for the next epoch.
    ///
    /// With at most `k` distinct items the delta is exact (`err = 0`
    /// everywhere). Beyond that, the `k` heaviest runs are kept exactly
    /// and the tail pruned: every dropped run weighs at most the
    /// summary's `min_count ≤ n_delta/k`, so the result is a valid
    /// ε-deficient Space Saving state of the epoch (`f ≤ f̂ ≤
    /// f + n_delta/k`, full recall above `n_delta/k`) with `n` set to
    /// the full epoch mass `n_delta`.
    pub fn cut(&mut self, k: usize) -> Summary {
        assert!(k >= 1, "k must be at least 1");
        let distinct = self.runs.len();
        if self.runs.len() > k {
            // Keep the k heaviest runs. An item with in-epoch count
            // above the k-th weight is necessarily among them, so
            // recall survives the prune.
            self.runs.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
            self.runs.truncate(k);
        }
        let counters: Vec<Counter> = self
            .runs
            .iter()
            .map(|&(item, count)| Counter { item, count, err: 0 })
            .collect();
        let summary = Summary::new(k, self.mass, counters);
        // Reset: the map clear is O(1) (generation-stamped), so only the
        // memory-footprint shrink (8× hysteresis after an unusually wide
        // epoch, mirroring ChunkAggregator's policy) ever touches the
        // allocation.
        let fit = distinct.max(self.min_capacity).next_power_of_two();
        self.runs.clear();
        self.mass = 0;
        if self.capacity > fit.saturating_mul(8) {
            self.capacity = fit;
            self.index = FastMap::with_capacity(self.capacity);
            self.runs.shrink_to(self.capacity);
        } else {
            self.index.clear();
        }
        summary
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;
    use std::collections::HashMap;

    #[test]
    fn exact_delta_under_budget() {
        let mut db = DeltaBuilder::new();
        db.absorb_items(&[5, 1, 5, 2, 1, 5]);
        assert_eq!(db.mass(), 6);
        assert_eq!(db.distinct(), 3);
        let delta = db.cut(8);
        assert_eq!(delta.n(), 6);
        assert_eq!(delta.estimate(5), Some(3));
        assert_eq!(delta.estimate(1), Some(2));
        assert_eq!(delta.estimate(2), Some(1));
        assert!(delta.counters().iter().all(|c| c.err == 0), "exact delta");
        // The builder is reset for the next epoch.
        assert!(db.is_empty());
        let next = db.cut(8);
        assert!(next.is_empty());
        assert_eq!(next.n(), 0);
    }

    #[test]
    fn runs_and_items_paths_agree() {
        let chunk = [7u64, 7, 9, 7, 3, 9];
        let mut agg = crate::summary::ChunkAggregator::new();
        let mut by_runs = DeltaBuilder::new();
        by_runs.absorb_runs(agg.aggregate(&chunk));
        by_runs.absorb_runs(agg.aggregate(&chunk[..3]));
        let mut by_items = DeltaBuilder::new();
        by_items.absorb_items(&chunk);
        by_items.absorb_items(&chunk[..3]);
        assert_eq!(by_runs.mass(), by_items.mass());
        let (a, b) = (by_runs.cut(16), by_items.cut(16));
        assert_eq!(a.counters(), b.counters());
        assert_eq!(a.n(), 9);
    }

    #[test]
    fn overfull_delta_keeps_space_saving_guarantees() {
        let mut rng = SplitMix64::new(31);
        for trial in 0..30 {
            let n = 500 + rng.next_below(4_000) as usize;
            let k = 1 + rng.next_below(48) as usize;
            let universe = 2 + rng.next_below(600);
            let items: Vec<u64> = (0..n).map(|_| rng.next_below(universe)).collect();
            let mut truth: HashMap<u64, u64> = HashMap::new();
            for &it in &items {
                *truth.entry(it).or_default() += 1;
            }
            let mut db = DeltaBuilder::with_capacity(64);
            for block in items.chunks(97) {
                db.absorb_items(block);
            }
            let delta = db.cut(k);
            assert_eq!(delta.n(), n as u64, "trial {trial}: mass");
            assert!(delta.counters().len() <= k, "trial {trial}: budget");
            let eps = delta.epsilon();
            for c in delta.counters() {
                let f = truth.get(&c.item).copied().unwrap_or(0);
                assert!(c.count >= f, "trial {trial}: under-estimate");
                assert!(c.count - f <= eps, "trial {trial}: ε bound");
                assert!(c.count - c.err <= f, "trial {trial}: err bound");
            }
            let thresh = n as u64 / k as u64;
            let monitored: std::collections::HashSet<u64> =
                delta.counters().iter().map(|c| c.item).collect();
            for (item, f) in &truth {
                if *f > thresh {
                    assert!(monitored.contains(item), "trial {trial}: lost {item}");
                }
            }
        }
    }

    #[test]
    fn grows_past_capacity_then_shrinks_back() {
        let mut db = DeltaBuilder::with_capacity(16);
        let wide: Vec<u64> = (0..10_000).collect();
        db.absorb_items(&wide);
        assert_eq!(db.distinct(), 10_000);
        assert!(db.capacity() >= 10_000);
        let delta = db.cut(128);
        assert_eq!(delta.n(), 10_000);
        // A narrow follow-up epoch shrinks the scratch back toward the floor.
        db.absorb_items(&[1, 1, 2]);
        let _ = db.cut(128);
        assert!(db.capacity() < 10_000);
        assert!(db.capacity() >= 16);
        // Still correct after the resize dance.
        db.absorb_items(&wide);
        assert_eq!(db.cut(128).n(), 10_000);
    }

    #[test]
    fn zero_weight_is_a_noop() {
        let mut db = DeltaBuilder::new();
        db.add(9, 0);
        assert!(db.is_empty());
        db.add(9, 3);
        assert_eq!(db.mass(), 3);
    }
}
