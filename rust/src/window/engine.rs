//! `WindowedQueryEngine` / `WindowSnapshot` — the windowed query API
//! over the delta rings.
//!
//! A windowed query materializes a [`WindowSnapshot`]: it clones the
//! in-window `Arc<DeltaSummary>`s out of the [`WindowStore`] (refcount
//! bumps, never data) and runs the paper's combine tree
//! ([`tree_reduce_refs`]) over the *borrowed* delta summaries — exactly
//! the machinery the landmark read path uses, pointed at the last `w`
//! epochs instead of the cumulative snapshots.
//!
//! ## The windowed error bound
//!
//! Every delta is a valid Space Saving summary of its epoch (see
//! [`DeltaBuilder`](super::DeltaBuilder)), and Algorithm 2's `combine`
//! preserves the bound additively, so a merged window whose deltas
//! total `W` items (the *window mass*, [`WindowSnapshot::n`]) carries
//! for every item, with `f` its true count **within the covered
//! window**:
//!
//! * no under-estimation: `f ≤ f̂`,
//! * bounded over-estimation: `f̂ ≤ f + ⌊W/k⌋`,
//! * windowed k-majority recall: every item with `f > W/k` holds a
//!   counter in the merged summary.
//!
//! "Covered window" is exact, not approximate: the snapshot reports the
//! precise delta set it merged ([`WindowSnapshot::deltas`]), so the
//! answer is always *about* a well-defined slice of the stream — the
//! property-tested contract (`prop_windowed_bounds`).
//!
//! Under **keyed routing** the shards' substreams are key-disjoint, so
//! the window merge combines each shard's in-window deltas with the
//! regular combine tree (same-shard deltas overlap over time) and then
//! *concatenates* across shards ([`merge_disjoint`]): the windowed
//! bound tightens from `⌊W/k⌋` to the max-per-shard `maxᵢ ⌊Wᵢ/k⌋`
//! (`Wᵢ` = shard `i`'s in-window mass), and unmonitored point queries
//! bound by the item's home-shard window instead of the global one.
//!
//! Under **keyed-adaptive** routing, deltas additionally carry exact
//! split-key partials ([`DeltaSummary::hot`]): the snapshot sums the
//! in-window partials per key and folds them into the merged summary
//! as exact mass ([`crate::summary::absorb_exact`]), so a split key's
//! windowed estimate is `home-shard window estimate + Σ in-window
//! partials`. Exact counts add no over-estimation, so `ε` stays the
//! max-per-shard bound of the Space Saving parts alone.

use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::metrics::{CacheCounters, CacheStats, LatencyHistogram, LatencySummary};
use crate::parallel::tree_reduce_refs;
use crate::query::engine::{point_estimate, threshold_split};
use crate::query::{PointEstimate, ThresholdReport};
use crate::summary::{absorb_exact, merge_disjoint, Counter, Summary};
use crate::util::{shard_of, FastMap};

use super::store::{DeltaSummary, WindowStore};

/// A point-in-time, internally-consistent view over one window of
/// epoch deltas across all shards.
///
/// Holding one pins the underlying deltas (via `Arc`), so repeated
/// queries against it are answered from identical data even as the
/// rings keep turning over.
#[derive(Debug, Clone)]
pub struct WindowSnapshot {
    /// The merge of every in-window delta (combine tree; per-shard
    /// combine + cross-shard concatenation in disjoint mode).
    merged: Summary,
    /// The deltas this view was built from.
    parts: Vec<Arc<DeltaSummary>>,
    /// Disjoint mode only: each covered shard's merged window summary,
    /// for home-shard point bounds. Empty otherwise.
    shard_merged: Vec<(usize, Summary)>,
    /// Key-disjoint shards (keyed routing)?
    disjoint: bool,
    /// Shard count of the owning store (home-shard hashing).
    shards: usize,
    /// The reported bound: `⌊W/k⌋`, or `maxᵢ ⌊Wᵢ/k⌋` in disjoint mode.
    epsilon: u64,
    /// In-window exact split-key totals (keyed-adaptive), summed over
    /// the merged deltas' partials; sorted by key, already folded into
    /// `merged`. Empty outside the hot tier.
    hot_totals: Vec<(u64, u64)>,
    /// When the view was materialized.
    taken_at: Instant,
}

/// One delta's contribution to a [`WindowSnapshot`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeltaInfo {
    /// Shard index.
    pub shard: usize,
    /// Per-shard delta sequence number.
    pub seq: u64,
    /// Items covered by that delta (its epoch mass).
    pub n: u64,
    /// Drain-time final partial delta?
    pub finished: bool,
}

impl WindowSnapshot {
    fn build(parts: Vec<Arc<DeltaSummary>>, k: usize, disjoint: bool, shards: usize) -> Self {
        let mut shard_merged: Vec<(usize, Summary)> = Vec::new();
        let (merged, epsilon) = if parts.is_empty() {
            (Summary::empty(k), 0)
        } else if disjoint {
            // Same-shard deltas overlap over time: combine each
            // shard's run first, then concatenate the key-disjoint
            // per-shard results.
            for shard in 0..shards {
                let leaves: Vec<&Summary> = parts
                    .iter()
                    .filter(|p| p.shard == shard)
                    .map(|p| &p.summary)
                    .collect();
                if !leaves.is_empty() {
                    shard_merged.push((shard, tree_reduce_refs(&leaves)));
                }
            }
            let per_shard: Vec<&Summary> =
                shard_merged.iter().map(|(_, s)| s).collect();
            let merged = merge_disjoint(&per_shard);
            let epsilon = per_shard.iter().map(|s| s.epsilon()).max().unwrap_or(0);
            (merged, epsilon)
        } else {
            let leaves: Vec<&Summary> = parts.iter().map(|p| &p.summary).collect();
            let merged = tree_reduce_refs(&leaves);
            let epsilon = merged.epsilon();
            (merged, epsilon)
        };
        // Keyed-adaptive: sum the in-window deltas' exact split-key
        // partials and fold them into the merged summary. ε stands as
        // computed above — exact mass adds no over-estimation. Skipped
        // outright when no delta carries partials (every non-adaptive
        // mode); FastMap-indexed accumulation otherwise.
        let hot_totals: Vec<(u64, u64)> = if parts.iter().all(|p| p.hot.is_empty()) {
            Vec::new()
        } else {
            let cap: usize = parts.iter().map(|p| p.hot.len()).sum();
            let mut idx = FastMap::with_capacity(cap);
            let mut acc: Vec<(u64, u64)> = Vec::with_capacity(cap);
            for p in &parts {
                for &(item, w) in &p.hot {
                    match idx.get(item) {
                        Some(i) => acc[i as usize].1 += w,
                        None => {
                            idx.insert(item, acc.len() as u32);
                            acc.push((item, w));
                        }
                    }
                }
            }
            // Sorted by key, matching the landmark fold's contract.
            acc.sort_unstable_by_key(|e| e.0);
            acc
        };
        let merged = if hot_totals.is_empty() {
            merged
        } else {
            // A split key absent from the merged summary may still have
            // in-window pre-split history that its home shard's window
            // evicted; that history is bounded by the home window's min
            // count, which seeds the inserted counter's count and err.
            absorb_exact(&merged, &hot_totals, |item| {
                let home = shard_of(item, shards);
                shard_merged
                    .iter()
                    .find(|(s, _)| *s == home)
                    .map_or(0, |(_, s)| s.min_count())
            })
        };
        Self {
            merged,
            parts,
            shard_merged,
            disjoint,
            shards,
            epsilon,
            hot_totals,
            taken_at: Instant::now(),
        }
    }

    /// The merged window summary itself.
    pub fn summary(&self) -> &Summary {
        &self.merged
    }

    /// Window mass `W`: total items covered by the merged deltas.
    pub fn n(&self) -> u64 {
        self.merged.n()
    }

    /// The over-estimation bound of this window: `ε = ⌊W/k⌋`, or the
    /// tighter max-per-shard `maxᵢ ⌊Wᵢ/k⌋` under keyed routing.
    pub fn epsilon(&self) -> u64 {
        self.epsilon
    }

    /// Whether this window merged key-disjoint shards (keyed routing)
    /// — and therefore reports the max-per-shard bound.
    pub fn is_disjoint(&self) -> bool {
        self.disjoint
    }

    /// True when the window covers no published delta.
    pub fn is_empty(&self) -> bool {
        self.parts.is_empty()
    }

    /// The exact delta set this view merged (per shard: contiguous
    /// sequence numbers, oldest → newest).
    pub fn deltas(&self) -> Vec<DeltaInfo> {
        self.parts
            .iter()
            .map(|p| DeltaInfo {
                shard: p.shard,
                seq: p.seq,
                n: p.summary.n() + p.hot_mass(),
                finished: p.finished,
            })
            .collect()
    }

    /// Age of the *oldest* merged delta — how far back the window
    /// reaches in wall-clock terms.
    pub fn span(&self) -> Duration {
        self.parts
            .iter()
            .map(|p| self.taken_at.saturating_duration_since(p.published_at))
            .max()
            .unwrap_or_default()
    }

    /// Age of the *newest* merged delta — how far the window trails the
    /// write path.
    pub fn staleness(&self) -> Duration {
        self.parts
            .iter()
            .map(|p| self.taken_at.saturating_duration_since(p.published_at))
            .min()
            .unwrap_or_default()
    }

    /// Top-`m` items of the window by estimated frequency, descending.
    pub fn top_k(&self, m: usize) -> Vec<Counter> {
        self.merged.top_k(m)
    }

    /// The prefix of [`WindowSnapshot::top_k`] whose order is certain.
    pub fn top_k_guaranteed(&self, m: usize) -> Vec<Counter> {
        self.merged.top_k_guaranteed(m)
    }

    /// Frequency estimate for one item within the window, with bounds
    /// (`n` in the result is the window mass `W`).
    ///
    /// Under keyed routing, unmonitored items are bounded by their
    /// *home shard's* merged window (its min count) — a shard whose
    /// window covers none of the item's substream bounds it at 0.
    pub fn point(&self, item: u64) -> PointEstimate {
        if self.disjoint {
            let home = shard_of(item, self.shards);
            let mut p = match self.shard_merged.iter().find(|(s, _)| *s == home) {
                Some((_, summary)) => point_estimate(summary, item),
                // No home-shard delta in the window: the covered
                // window contains none of this item's occurrences.
                None => PointEstimate {
                    item,
                    estimate: 0,
                    guaranteed: 0,
                    monitored: false,
                    n: 0,
                },
            };
            // Split-key recombination: the window's exact partials add
            // to both the estimate and the lower bound.
            let extra = self
                .hot_totals
                .iter()
                .find(|e| e.0 == item)
                .map_or(0, |e| e.1);
            if extra > 0 {
                p.estimate += extra;
                p.guaranteed += extra;
                p.monitored = true;
            }
            p.n = self.n(); // the answer is about the whole window mass
            p
        } else {
            point_estimate(&self.merged, item)
        }
    }

    /// Items above a relative threshold `phi` ∈ `[0, 1)` of the window
    /// mass (`f̂ > phi·W`), split into guaranteed and possible.
    pub fn threshold(&self, phi: f64) -> ThresholdReport {
        assert!((0.0..1.0).contains(&phi), "phi must be in [0, 1)");
        threshold_split(
            &self.merged,
            (phi * self.n() as f64).floor() as u64,
            self.epsilon,
        )
    }

    /// The windowed k-majority query: all items with `f̂ > W/k_majority`
    /// in the covered window.
    pub fn k_majority(&self, k_majority: u64) -> ThresholdReport {
        assert!(k_majority >= 2, "k_majority must be >= 2");
        threshold_split(&self.merged, self.n() / k_majority, self.epsilon)
    }
}

/// Point-in-time window-layer statistics.
#[derive(Debug, Clone)]
pub struct WindowStats {
    /// Shard count.
    pub shards: usize,
    /// Ring capacity (deltas retained per shard).
    pub ring_capacity: usize,
    /// Default window width, in epochs.
    pub window_epochs: usize,
    /// Deltas published across all shards since spawn.
    pub deltas_published: u64,
    /// Deltas retired (pushed out of a full ring).
    pub deltas_retired: u64,
    /// Deltas currently retained, per shard.
    pub per_shard_available: Vec<usize>,
    /// Newest published sequence number, per shard (0 = none yet).
    pub per_shard_seq: Vec<u64>,
    /// Windowed queries served across all engine handles.
    pub queries_served: u64,
    /// Latency digest over this engine's windowed queries.
    pub query_latency: LatencySummary,
    /// Window-snapshot cache accounting (hits / misses / merges
    /// avoided), aggregated across every clone of this engine. All
    /// zero when the cache is disabled
    /// ([`WindowedQueryEngine::without_cache`]).
    pub cache: CacheStats,
}

/// The windowed sibling of the landmark engine's snapshot cache: one
/// cached `Arc<WindowSnapshot>` keyed by `(window width, per-shard
/// delta-ring seq vector)`.
///
/// The seq vector plays the role the registry version plays on the
/// landmark path: ring contents change only when a shard publishes a
/// delta, and every publication bumps that shard's seq
/// ([`WindowStore::last_seq`]) — so an unchanged `(width, seqs)` key
/// proves the same delta set would be collected again. The rebuild is
/// validated seqlock-style (seqs read before and after the ring
/// collection must agree) and serialized by a mutex so one publication
/// costs one window merge, not one per concurrent reader.
#[derive(Debug)]
struct WindowCache {
    /// `(width, per-shard seqs, view)`; written only under `rebuild`.
    #[allow(clippy::type_complexity)]
    slot: RwLock<Option<(usize, Vec<u64>, Arc<WindowSnapshot>)>>,
    /// Serializes rebuilds (never held on the hit path).
    rebuild: Mutex<()>,
    /// Shared hit/miss accounting.
    counters: CacheCounters,
}

impl WindowCache {
    fn new() -> Self {
        Self {
            slot: RwLock::new(None),
            rebuild: Mutex::new(()),
            counters: CacheCounters::new(),
        }
    }

    /// The cached view, if it was built for exactly this key.
    fn lookup(&self, width: usize, seqs: &[u64]) -> Option<Arc<WindowSnapshot>> {
        let slot = self.slot.read().expect("window cache poisoned");
        slot.as_ref().and_then(|(w, s, view)| {
            (*w == width && s == seqs).then(|| view.clone())
        })
    }

    fn install(&self, width: usize, seqs: Vec<u64>, view: &Arc<WindowSnapshot>) {
        *self.slot.write().expect("window cache poisoned") =
            Some((width, seqs, view.clone()));
    }
}

/// Cheap-to-clone handle serving sliding-window queries over the delta
/// rings.
#[derive(Debug, Clone)]
pub struct WindowedQueryEngine {
    store: Arc<WindowStore>,
    latency: Arc<LatencyHistogram>,
    /// Shared window-snapshot cache ([`WindowCache`]); `None` =
    /// uncached, every windowed query re-merges its delta set.
    cache: Option<Arc<WindowCache>>,
    /// Default window width (epochs) for the no-argument sugar.
    window_epochs: usize,
    /// k-majority parameter for [`WindowedQueryEngine::frequent_window`].
    k_majority: u64,
}

impl WindowedQueryEngine {
    /// Attach an engine to a store. `window_epochs` is the default
    /// window width; `k_majority` parameterizes
    /// [`WindowedQueryEngine::frequent_window`]. The window cache is on
    /// by default.
    pub fn new(store: Arc<WindowStore>, window_epochs: usize, k_majority: u64) -> Self {
        Self {
            store,
            latency: Arc::new(LatencyHistogram::new()),
            cache: Some(Arc::new(WindowCache::new())),
            window_epochs: window_epochs.max(1),
            k_majority,
        }
    }

    /// Disable the window cache on this handle (and clones made from
    /// it afterwards): every windowed query re-merges. The bench
    /// baseline, mirroring [`QueryEngine::without_cache`]
    /// (`crate::query::QueryEngine::without_cache`).
    pub fn without_cache(mut self) -> Self {
        self.cache = None;
        self
    }

    /// Window-cache accounting (all zero when the cache is off).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.as_ref().map_or_else(CacheStats::default, |c| c.counters.stats())
    }

    /// The shared delta store (for publishers / the coordinator).
    pub fn store(&self) -> &Arc<WindowStore> {
        &self.store
    }

    /// The default window width, in epochs.
    pub fn default_window(&self) -> usize {
        self.window_epochs
    }

    /// Materialize a consistent merged view over the last `epochs`
    /// published deltas of every shard (fewer where a shard has not
    /// published — or no longer retains — that many). This is the only
    /// place window merge work happens; the query sugar below goes
    /// through it.
    ///
    /// Between delta publications a given width's merged window is
    /// immutable, so concurrent callers share one `Arc<WindowSnapshot>`
    /// (see [`WindowCache`]); any shard's next publication invalidates
    /// it within one seq-vector check.
    pub fn window(&self, epochs: usize) -> Arc<WindowSnapshot> {
        let width = epochs.max(1);
        let t0 = Instant::now();
        let snap = self.window_inner(width);
        self.latency.record(t0.elapsed());
        self.store.count_query();
        snap
    }

    fn window_inner(&self, width: usize) -> Arc<WindowSnapshot> {
        let Some(cache) = &self.cache else {
            return Arc::new(self.build_window(width).0);
        };
        // Fast path: seq-vector compare + Arc clone.
        if let Some(view) = cache.lookup(width, &self.seq_vector()) {
            cache.counters.record_hit();
            cache.counters.record_merge_avoided();
            return view;
        }
        // Slow path: exactly one reader re-merges per ring change.
        let _rebuild = cache.rebuild.lock().expect("window cache poisoned");
        if let Some(view) = cache.lookup(width, &self.seq_vector()) {
            cache.counters.record_merge_avoided();
            return view;
        }
        let (snap, key) = self.build_window(width);
        let snap = Arc::new(snap);
        cache.counters.record_miss();
        if let Some(seqs) = key {
            cache.install(width, seqs, &snap);
        }
        snap
    }

    /// Build a window view, seqlock-validating that no delta landed
    /// while the ring was being collected. Returns the view plus the
    /// seq-vector key it may be cached under (`None` when a publisher
    /// raced the collection — the view is still a valid answer, each
    /// delta being individually consistent, but no single key ever
    /// described it).
    fn build_window(&self, width: usize) -> (WindowSnapshot, Option<Vec<u64>>) {
        let mut parts = Vec::new();
        let mut key = None;
        for _attempt in 0..2 {
            let s1 = self.seq_vector();
            parts = self.store.window(width);
            if self.seq_vector() == s1 {
                key = Some(s1);
                break;
            }
        }
        let snap = WindowSnapshot::build(
            parts,
            self.store.k(),
            self.store.disjoint(),
            self.store.shards(),
        );
        (snap, key)
    }

    /// Per-shard newest delta seqs — the cache key material.
    fn seq_vector(&self) -> Vec<u64> {
        (0..self.store.shards()).map(|s| self.store.last_seq(s)).collect()
    }

    /// Coarse time-based window: merge every retained delta published
    /// within the last `max_age` (granularity = one epoch). Never
    /// cached — the delta set is wall-clock-dependent, so no seq key
    /// describes it.
    pub fn window_by_age(&self, max_age: Duration) -> Arc<WindowSnapshot> {
        let t0 = Instant::now();
        let snap = Arc::new(WindowSnapshot::build(
            self.store.window_by_age(max_age),
            self.store.k(),
            self.store.disjoint(),
            self.store.shards(),
        ));
        self.latency.record(t0.elapsed());
        self.store.count_query();
        snap
    }

    /// The default-width window (`window_epochs` epochs).
    pub fn latest(&self) -> Arc<WindowSnapshot> {
        self.window(self.window_epochs)
    }

    /// Top-`m` items over the last `epochs` epochs, descending.
    ///
    /// Convenience for `self.window(epochs).top_k(m)`; take an explicit
    /// [`WindowedQueryEngine::window`] when several queries must see the
    /// same delta set.
    pub fn top_k_window(&self, epochs: usize, m: usize) -> Vec<Counter> {
        self.window(epochs).top_k(m)
    }

    /// Frequency estimate for one item over the last `epochs` epochs.
    pub fn point_in_window(&self, epochs: usize, item: u64) -> PointEstimate {
        self.window(epochs).point(item)
    }

    /// k-majority over the last `epochs` epochs: items with
    /// `f̂ > W/k_majority`, split guaranteed vs possible.
    pub fn k_majority_window(&self, epochs: usize, k_majority: u64) -> ThresholdReport {
        self.window(epochs).k_majority(k_majority)
    }

    /// The windowed k-majority at the engine's configured defaults.
    pub fn frequent_window(&self) -> ThresholdReport {
        self.k_majority_window(self.window_epochs, self.k_majority)
    }

    /// Ring occupancy, publication counters and query latency.
    pub fn window_stats(&self) -> WindowStats {
        let shards = self.store.shards();
        WindowStats {
            shards,
            ring_capacity: self.store.capacity(),
            window_epochs: self.window_epochs,
            deltas_published: self.store.deltas_published(),
            deltas_retired: self.store.deltas_retired(),
            per_shard_available: (0..shards).map(|s| self.store.available(s)).collect(),
            per_shard_seq: (0..shards).map(|s| self.store.last_seq(s)).collect(),
            queries_served: self.store.queries_served(),
            query_latency: self.latency.summary(),
            cache: self.cache_stats(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::summary::{FrequencySummary, SpaceSaving};
    use std::collections::HashMap;

    fn summary_of(items: &[u64], k: usize) -> Summary {
        let mut ss = SpaceSaving::new(k);
        ss.offer_all(items);
        ss.freeze()
    }

    #[test]
    fn empty_window_answers_empty() {
        let engine = WindowedQueryEngine::new(WindowStore::new(2, 4, 16), 4, 16);
        let snap = engine.window(4);
        assert!(snap.is_empty());
        assert_eq!(snap.n(), 0);
        assert!(snap.top_k(5).is_empty());
        let p = snap.point(42);
        assert_eq!((p.estimate, p.guaranteed, p.monitored), (0, 0, false));
        let rep = engine.frequent_window();
        assert!(rep.guaranteed.is_empty() && rep.possible.is_empty());
        assert_eq!(engine.window_stats().queries_served, 2);
    }

    #[test]
    fn window_merges_only_requested_epochs() {
        let store = WindowStore::new(1, 8, 16);
        let engine = WindowedQueryEngine::new(store.clone(), 2, 16);
        store.publish(0, summary_of(&[1, 1, 1], 16), false); // seq 1
        store.publish(0, summary_of(&[2, 2], 16), false); // seq 2
        store.publish(0, summary_of(&[3], 16), false); // seq 3

        // Window of 2 = seqs {2, 3}: item 1 is outside.
        let snap = engine.window(2);
        assert_eq!(snap.n(), 3);
        assert_eq!(
            snap.deltas(),
            vec![
                DeltaInfo { shard: 0, seq: 2, n: 2, finished: false },
                DeltaInfo { shard: 0, seq: 3, n: 1, finished: false },
            ]
        );
        assert_eq!(snap.point(2).estimate, 2);
        assert!(!snap.point(1).monitored, "expired epoch must not leak in");
        // The full window still sees everything retained.
        assert_eq!(engine.window(8).n(), 6);
        // A pinned snapshot survives ring turnover.
        for round in 0..10 {
            store.publish(0, summary_of(&[round], 16), false);
        }
        assert_eq!(snap.n(), 3, "pinned view unchanged");
    }

    #[test]
    fn windowed_bounds_hold_across_shards() {
        let k = 32;
        let store = WindowStore::new(3, 4, k);
        let engine = WindowedQueryEngine::new(store.clone(), 4, k as u64);
        let mut rng = crate::util::SplitMix64::new(13);
        let mut in_window: Vec<u64> = Vec::new();
        for shard in 0..3usize {
            for _epoch in 0..2 {
                let items: Vec<u64> = (0..3_000)
                    .map(|_| {
                        if rng.next_f64() < 0.5 {
                            rng.next_below(5)
                        } else {
                            rng.next_below(1_500)
                        }
                    })
                    .collect();
                in_window.extend_from_slice(&items);
                store.publish(shard, summary_of(&items, k), false);
            }
        }
        let snap = engine.window(2);
        assert_eq!(snap.n(), in_window.len() as u64);
        let mut truth: HashMap<u64, u64> = HashMap::new();
        for &i in &in_window {
            *truth.entry(i).or_default() += 1;
        }
        let eps = snap.epsilon();
        for c in snap.summary().counters() {
            let f = truth.get(&c.item).copied().unwrap_or(0);
            assert!(c.count >= f, "window under-estimate");
            assert!(c.count - f <= eps, "window ε bound broken");
        }
        let monitored: std::collections::HashSet<u64> =
            snap.summary().counters().iter().map(|c| c.item).collect();
        for (item, f) in &truth {
            if *f > eps {
                assert!(monitored.contains(item), "lost windowed heavy hitter {item}");
            }
        }
        // Guaranteed windowed k-majority items are true positives.
        let rep = snap.k_majority(k as u64);
        for c in &rep.guaranteed {
            let f = truth.get(&c.item).copied().unwrap_or(0);
            assert!(f > rep.threshold, "guaranteed false positive {}", c.item);
        }
    }

    #[test]
    fn disjoint_window_combines_within_shard_then_concatenates() {
        use crate::util::shard_of;
        let k = 8;
        let store = WindowStore::new(2, 4, k);
        store.set_disjoint(true);
        let engine = WindowedQueryEngine::new(store.clone(), 2, k as u64);
        // Two epochs per shard, keyed split, imbalanced masses.
        let mut per_shard: Vec<Vec<u64>> = vec![Vec::new(); 2];
        for item in 0..300u64 {
            let copies = if item < 4 { 40 } else { 1 };
            per_shard[shard_of(item, 2)].extend(std::iter::repeat(item).take(copies));
        }
        let mut shard_window_mass = [0u64; 2];
        for (s, items) in per_shard.iter().enumerate() {
            let mid = items.len() / 2;
            store.publish(s, summary_of(&items[..mid], k), false);
            store.publish(s, summary_of(&items[mid..], k), false);
            shard_window_mass[s] = items.len() as u64;
        }
        let snap = engine.window(2);
        assert!(snap.is_disjoint());
        let total: u64 = shard_window_mass.iter().sum();
        assert_eq!(snap.n(), total);
        // Max-per-shard windowed bound, tighter than the summed one.
        let eps_max = shard_window_mass.iter().map(|&w| w / k as u64).max().unwrap();
        assert_eq!(snap.epsilon(), eps_max);
        assert!(snap.epsilon() <= total / k as u64);
        // Same-shard epochs combined: heavy items keep exact counts
        // (each epoch summary is exact for them, and combine sums).
        for item in 0..4u64 {
            let p = snap.point(item);
            assert_eq!(p.n, total);
            assert!(p.estimate >= 40, "heavy item {item} lost mass");
        }
        // The report epsilon carries the tightened bound too.
        assert_eq!(snap.k_majority(k as u64).epsilon, eps_max);
        // A window with no home-shard coverage bounds an item at 0:
        // publish only shard 0, fresh store.
        let store2 = WindowStore::new(2, 4, k);
        store2.set_disjoint(true);
        let engine2 = WindowedQueryEngine::new(store2.clone(), 2, k as u64);
        store2.publish(0, summary_of(&per_shard[0], k), false);
        let snap2 = engine2.window(2);
        let other = (0u64..300)
            .find(|&i| shard_of(i, 2) == 1)
            .expect("some item homes on shard 1");
        let p = snap2.point(other);
        assert_eq!((p.estimate, p.guaranteed, p.monitored), (0, 0, false));
    }

    #[test]
    fn adaptive_window_folds_exact_split_partials() {
        use crate::util::shard_of;
        let k = 8;
        let store = WindowStore::new(2, 4, k);
        store.set_disjoint(true);
        let engine = WindowedQueryEngine::new(store.clone(), 2, k as u64);
        let hot = 77u64;
        let home = shard_of(hot, 2);
        // Epoch 1: the hot key's pre-split history lives in its home
        // shard's delta; epoch 2: split partials on both shards, the
        // non-home shard contributing a hot-only (empty-summary) delta.
        store.publish(home, summary_of(&vec![hot; 30], k), false);
        store.publish(1 - home, summary_of(&[500, 501], k), false);
        store.publish_with_hot(home, summary_of(&[1000], k), false, vec![(hot, 25)]);
        store.publish_with_hot(1 - home, Summary::empty(k), false, vec![(hot, 35)]);
        let snap = engine.window(2);
        assert!(snap.is_disjoint());
        // Window mass includes the 60 split occurrences.
        assert_eq!(snap.n(), 30 + 2 + 1 + 60);
        // Point: home window estimate (30) + in-window partials (60),
        // with the exact mass hardening the lower bound too.
        let p = snap.point(hot);
        assert_eq!(p.estimate, 90);
        assert_eq!(p.guaranteed, 90);
        assert!(p.monitored);
        assert_eq!(p.n, snap.n());
        // The merged summary agrees, and the split key tops the window.
        assert_eq!(snap.summary().estimate(hot), Some(90));
        assert_eq!(snap.top_k(1)[0].item, hot);
        // ε still comes from the Space Saving parts alone (all
        // under-full here → 33/8 = 4 at worst per shard).
        assert!(snap.epsilon() <= 33 / k as u64);
        // DeltaInfo reports epoch mass including the hot share.
        let infos = snap.deltas();
        let hot_only = infos
            .iter()
            .find(|d| d.shard == 1 - home && d.seq == 2)
            .expect("hot-only delta in window");
        assert_eq!(hot_only.n, 35);
    }

    #[test]
    fn stats_reflect_rings() {
        let store = WindowStore::new(2, 2, 8);
        let engine = WindowedQueryEngine::new(store.clone(), 3, 8);
        assert_eq!(engine.default_window(), 3);
        for _ in 0..3 {
            store.publish(0, summary_of(&[1], 8), false);
        }
        let s = engine.window_stats();
        assert_eq!(s.shards, 2);
        assert_eq!(s.ring_capacity, 2);
        assert_eq!(s.deltas_published, 3);
        assert_eq!(s.deltas_retired, 1);
        assert_eq!(s.per_shard_available, vec![2, 0]);
        assert_eq!(s.per_shard_seq, vec![3, 0]);
        let _ = engine.top_k_window(2, 1);
        assert_eq!(engine.window_stats().query_latency.count, 1);
    }

    #[test]
    fn window_cache_reuses_views_between_publications() {
        let store = WindowStore::new(2, 4, 16);
        let engine = WindowedQueryEngine::new(store.clone(), 2, 16);
        store.publish(0, summary_of(&[1, 1, 2], 16), false);
        store.publish(1, summary_of(&[3], 16), false);

        // Same (width, seqs) key → one merge, shared Arc.
        let a = engine.window(2);
        let b = engine.window(2);
        assert!(Arc::ptr_eq(&a, &b), "cached view must be shared");
        let s = engine.cache_stats();
        assert_eq!((s.hits, s.misses, s.merges_avoided), (1, 1, 1));

        // A different width is a different key.
        let wide = engine.window(4);
        assert!(!Arc::ptr_eq(&b, &wide));
        assert_eq!(engine.cache_stats().misses, 2);

        // Any shard's publication invalidates within one check.
        store.publish(0, summary_of(&[9, 9], 16), false);
        let c = engine.window(4);
        assert!(!Arc::ptr_eq(&wide, &c), "stale view served after publish");
        assert_eq!(c.n(), 6);

        // Clones share the cache; stats surface it; every call counted.
        let clone = engine.clone();
        let d = clone.window(4);
        assert!(Arc::ptr_eq(&c, &d));
        let ws = engine.window_stats();
        assert_eq!(ws.cache.hits, 2);
        assert_eq!(ws.queries_served, 5);
        assert_eq!(ws.query_latency.count, 5);
    }

    #[test]
    fn uncached_window_engine_rebuilds_every_query() {
        let store = WindowStore::new(1, 4, 16);
        let engine = WindowedQueryEngine::new(store.clone(), 2, 16).without_cache();
        store.publish(0, summary_of(&[5, 5, 6], 16), false);
        let a = engine.window(2);
        let b = engine.window(2);
        assert!(!Arc::ptr_eq(&a, &b), "uncached engine must rebuild");
        assert_eq!(a.summary().counters(), b.summary().counters());
        assert_eq!(engine.cache_stats(), CacheStats::default());
        assert_eq!(engine.window_stats().queries_served, 2);
    }
}
