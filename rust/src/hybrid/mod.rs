//! The hybrid MPI × OpenMP composition (paper §3, last paragraphs, and
//! the §4.2 comparison): the input is partitioned among MPI ranks, each
//! rank's sub-array is partitioned again among its OpenMP threads, the
//! per-thread summaries are merged by the intra-node user-defined
//! reduction, and the per-rank summaries by the MPI reduction.
//!
//! The execution semantics live in [`distsim`] (`Flavor::Hybrid` runs
//! the two-level decomposition and the two-level combine tree); this
//! module owns the *experiment logic*: paper-shaped configurations and
//! the MPI-vs-hybrid comparison of Figure 4 / Tables III–IV.
//!
//! [`distsim`]: crate::distsim

use crate::distsim::{simulate, ClusterSpec, MachineModel, NetworkModel, SimOutcome, SimWorkload};
use crate::metrics::fractional_overhead;

/// The paper's hybrid layout: 8 threads per MPI process, one process per
/// socket, hyperthreading off.
pub const THREADS_PER_RANK: u32 = 8;

/// One (cores → outcome) comparison point between the pure-MPI and the
/// hybrid code paths.
#[derive(Debug, Clone)]
pub struct ComparisonPoint {
    /// Total cores (= MPI ranks for pure MPI; ranks × 8 for hybrid).
    pub cores: u32,
    /// Pure-MPI outcome.
    pub mpi: SimOutcome,
    /// Hybrid outcome (None when cores < [`THREADS_PER_RANK`]).
    pub hybrid: Option<SimOutcome>,
}

impl ComparisonPoint {
    /// Speedups relative to the given single-core baselines.
    pub fn speedups(&self, mpi_t1: f64, hybrid_t1: f64) -> (f64, Option<f64>) {
        (
            mpi_t1 / self.mpi.total_seconds(),
            self.hybrid.as_ref().map(|h| hybrid_t1 / h.total_seconds()),
        )
    }

    /// Fractional overheads (paper Fig. 4 right-hand panels).
    pub fn overheads(&self) -> (f64, Option<f64>) {
        (
            fractional_overhead(&self.mpi.times),
            self.hybrid.as_ref().map(|h| fractional_overhead(&h.times)),
        )
    }
}

/// Run the pure-MPI configuration on `cores` Xeon cores.
pub fn run_mpi(w: &SimWorkload, cores: u32) -> anyhow::Result<SimOutcome> {
    simulate(
        w,
        &ClusterSpec::mpi(MachineModel::xeon_e5_2630_v3(), cores),
        &NetworkModel::qdr_infiniband(),
    )
}

/// Run the hybrid configuration on `cores` Xeon cores (8 threads/rank).
pub fn run_hybrid(w: &SimWorkload, cores: u32) -> anyhow::Result<SimOutcome> {
    anyhow::ensure!(
        cores % THREADS_PER_RANK == 0 || cores == 1,
        "hybrid needs a multiple of {THREADS_PER_RANK} cores (got {cores})"
    );
    let (ranks, threads) = if cores == 1 {
        (1, 1) // the single-core baseline row of Table IV
    } else {
        (cores / THREADS_PER_RANK, THREADS_PER_RANK)
    };
    simulate(
        w,
        &ClusterSpec::hybrid(MachineModel::xeon_e5_2630_v3(), ranks, threads),
        &NetworkModel::qdr_infiniband(),
    )
}

/// The §4.2 sweep: pure MPI vs hybrid across `cores_list`.
pub fn compare(w: &SimWorkload, cores_list: &[u32]) -> anyhow::Result<Vec<ComparisonPoint>> {
    cores_list
        .iter()
        .map(|&cores| {
            Ok(ComparisonPoint {
                cores,
                mpi: run_mpi(w, cores)?,
                hybrid: (cores == 1 || cores % THREADS_PER_RANK == 0)
                    .then(|| run_hybrid(w, cores))
                    .transpose()?,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn workload() -> SimWorkload {
        SimWorkload::paper(29_000_000_000, 2000, 1.1, 1_000_000, 1)
    }

    #[test]
    fn paper_cores_sweep_shapes() {
        let w = workload();
        let pts = compare(&w, &[1, 32, 64, 128, 256, 512]).unwrap();
        let t1_mpi = pts[0].mpi.total_seconds();
        let t1_hyb = pts[0].hybrid.as_ref().unwrap().total_seconds();

        // Monotone decreasing runtimes.
        for w2 in pts.windows(2) {
            assert!(w2[1].mpi.total_seconds() < w2[0].mpi.total_seconds());
        }

        // Table III band: MPI efficiency at 512 cores ~50% (paper 51%).
        let last = pts.last().unwrap();
        let (s_mpi, s_hyb) = last.speedups(t1_mpi, t1_hyb);
        let eff_mpi = s_mpi / 512.0;
        let eff_hyb = s_hyb.unwrap() / 512.0;
        assert!((0.40..0.62).contains(&eff_mpi), "mpi eff {eff_mpi}");
        // Table IV: hybrid efficiency > 62%.
        assert!(eff_hyb > 0.60, "hybrid eff {eff_hyb}");
        assert!(eff_hyb > eff_mpi, "hybrid must beat MPI at 512 cores");
    }

    #[test]
    fn hybrid_reduces_overhead_at_scale() {
        let w = workload();
        let pts = compare(&w, &[256, 512]).unwrap();
        for p in &pts {
            let (o_mpi, o_hyb) = p.overheads();
            assert!(
                o_hyb.unwrap() < o_mpi,
                "cores={}: hybrid overhead {} !< mpi {}",
                p.cores,
                o_hyb.unwrap(),
                o_mpi
            );
        }
    }

    #[test]
    fn comparable_at_low_core_counts() {
        // Paper: "the performance of both versions are comparable" below
        // ~128 cores.
        let w = workload();
        let pts = compare(&w, &[32, 64]).unwrap();
        for p in &pts {
            let h = p.hybrid.as_ref().unwrap().total_seconds();
            let m = p.mpi.total_seconds();
            assert!((h - m).abs() / m < 0.15, "cores={}: {h} vs {m}", p.cores);
        }
    }

    #[test]
    fn rejects_non_multiple_cores() {
        assert!(run_hybrid(&workload(), 12).is_err());
    }
}
