//! The Intel Phi (MIC) offload experiments — paper §4.3 (single-
//! accelerator thread sweep, Figure 5) and §4.4 (Xeon-vs-Phi socket
//! scaling, Figure 6).
//!
//! The offload execution model follows the paper: the Space Saving scan
//! and the user-defined reduction run on the accelerator, I/O stays on
//! the host, and the dataset crosses PCIe once per run (charged by
//! `Flavor::MicOffload` in [`distsim`]).
//!
//! [`distsim`]: crate::distsim

use crate::distsim::{simulate, ClusterSpec, MachineModel, NetworkModel, SimOutcome, SimWorkload};

/// §4.3 sweep: one accelerator, varying OpenMP thread counts.
/// Paper values: 15, 30, 60, 120, 240 — best at 120 (2 hw threads/core).
pub fn phi_thread_sweep(
    w: &SimWorkload,
    threads_list: &[u32],
) -> anyhow::Result<Vec<(u32, SimOutcome)>> {
    threads_list
        .iter()
        .map(|&t| {
            let out = simulate(
                w,
                &ClusterSpec::mic_offload(1, t),
                &NetworkModel::qdr_infiniband(),
            )?;
            Ok((t, out))
        })
        .collect()
}

/// One §4.4 comparison point: `sockets` compute devices, where a Xeon
/// socket is 8 cores (one hybrid rank) and a MIC socket is one Phi
/// accelerator at 120 threads.
#[derive(Debug, Clone)]
pub struct SocketPoint {
    /// Number of sockets/accelerators.
    pub sockets: u32,
    /// Hybrid MPI/OpenMP on Xeon sockets.
    pub xeon: SimOutcome,
    /// MPI + offload on Phi accelerators.
    pub mic: SimOutcome,
}

/// §4.4 sweep: Xeon sockets vs Phi accelerators at equal socket counts.
pub fn xeon_vs_mic(w: &SimWorkload, sockets_list: &[u32]) -> anyhow::Result<Vec<SocketPoint>> {
    let net = NetworkModel::qdr_infiniband();
    sockets_list
        .iter()
        .map(|&s| {
            let xeon = simulate(
                w,
                &ClusterSpec::hybrid(MachineModel::xeon_e5_2630_v3(), s, 8),
                &net,
            )?;
            let mic = simulate(w, &ClusterSpec::mic_offload(s, 120), &net)?;
            Ok(SocketPoint { sockets: s, xeon, mic })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn workload() -> SimWorkload {
        // §4.3/§4.4 configuration: 3 B items (fits the Phi's 16 GB),
        // k=2000, ρ=1.1.
        SimWorkload::paper(3_000_000_000, 2000, 1.1, 1_000_000, 1)
    }

    #[test]
    fn best_phi_config_is_120_threads() {
        // Paper Figure 5: 120 threads (2 hw threads/core) beats 15, 30,
        // 60 and 240.
        let w = workload();
        let sweep = phi_thread_sweep(&w, &[15, 30, 60, 120, 240]).unwrap();
        let times: Vec<(u32, f64)> =
            sweep.iter().map(|(t, o)| (*t, o.total_seconds())).collect();
        let best = times
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        assert_eq!(best.0, 120, "times: {times:?}");
        // Monotone improvement up to 120.
        for w2 in times[..4].windows(2) {
            assert!(w2[1].1 < w2[0].1, "times: {times:?}");
        }
    }

    #[test]
    fn phi_never_beats_xeon_socket_for_socket() {
        // Paper Figure 6 / §5: "the Intel Phi accelerator did not provide
        // any advantage with regard to the Intel Xeon processor".
        let w = workload();
        let pts = xeon_vs_mic(&w, &[1, 4, 8, 16, 32, 64]).unwrap();
        for p in &pts {
            assert!(
                p.mic.total_seconds() > p.xeon.total_seconds(),
                "sockets={}: mic {} !> xeon {}",
                p.sockets,
                p.mic.total_seconds(),
                p.xeon.total_seconds()
            );
        }
        // And the gap is the paper's ~2–3×(+offload) at one socket.
        let r = pts[0].mic.total_seconds() / pts[0].xeon.total_seconds();
        assert!((1.8..4.0).contains(&r), "ratio {r}");
    }

    #[test]
    fn phi_scales_across_accelerators() {
        let w = workload();
        let pts = xeon_vs_mic(&w, &[1, 4, 8]).unwrap();
        assert!(pts[1].mic.total_seconds() < pts[0].mic.total_seconds() / 2.5);
        assert!(pts[2].mic.total_seconds() < pts[1].mic.total_seconds());
    }

    #[test]
    fn varying_k_keeps_ordering() {
        for k in [500usize, 8000] {
            let w = SimWorkload::paper(3_000_000_000, k, 1.1, 1_000_000, 1);
            let pts = xeon_vs_mic(&w, &[8]).unwrap();
            assert!(pts[0].mic.total_seconds() > pts[0].xeon.total_seconds(), "k={k}");
        }
    }
}
