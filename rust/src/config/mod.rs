//! Experiment configuration: JSON-backed run configs and the paper
//! experiment registry (Table I).

use std::path::Path;

use crate::coordinator::{CoordinatorConfig, Routing, Transport};
use crate::summary::SummaryKind;
use crate::util::Json;
use crate::Result;

/// Configuration of one `pss run` (synthetic stream + execution shape).
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Stream length.
    pub n: u64,
    /// Rank universe of the generator.
    pub universe: u64,
    /// Zipf skew (0 = uniform).
    pub skew: f64,
    /// Zipf-Mandelbrot shift.
    pub shift: f64,
    /// Generation seed.
    pub seed: u64,
    /// Space Saving counters.
    pub k: usize,
    /// k-majority parameter (defaults to `k`).
    pub k_majority: u64,
    /// Worker threads / shards.
    pub threads: usize,
    /// Coordinator chunk length.
    pub chunk_len: usize,
    /// Bounded queue depth (chunks) per shard.
    pub queue_depth: usize,
    /// Chunk routing policy: `rr` (round-robin, default), `ll`
    /// (least-loaded), `keyed` (mix64 hash-partition items to their
    /// home shard — key-disjoint shard summaries, max-per-shard error
    /// bound), or `keyed-adaptive` (keyed plus the hot-key tier:
    /// detected heavy keys split round-robin across all shards and
    /// recombined exactly at query time).
    pub routing: Routing,
    /// Producer→shard transport: `ring` (lock-free SPSC, default) or
    /// `mpsc` (the sync_channel benchmark baseline).
    pub transport: Transport,
    /// Per-shard summary structure: `heap` (`O(log k)` min-heap),
    /// `bucket` (Metwally bucket list, default), or `compact`
    /// (SoA block-min core — fastest hot loop). Identical guarantees
    /// in every case.
    pub structure: SummaryKind,
    /// Route chunks through the batched ingest fast path (per-chunk
    /// pre-aggregation + weighted updates). Same error guarantees as
    /// per-item ingestion; off reproduces exact per-item sequences.
    pub batch_ingest: bool,
    /// Epoch publication cadence in items per shard (live read path).
    /// 0 disables epoch snapshots — right for batch `pss run`, useless
    /// for `pss query`/`pss serve`, which need live readers.
    pub epoch_items: u64,
    /// Sliding-window read path: delta-ring capacity, in epoch deltas
    /// retained per shard. 0 (default) disables delta publication and
    /// windowed queries.
    pub delta_ring: usize,
    /// Default windowed-query width, in epochs (`pss query --window`).
    pub window_epochs: usize,
    /// Epoch-versioned snapshot caching on the read path (default on;
    /// `--no-snapshot-cache` benchmarks the uncached baseline).
    pub snapshot_cache: bool,
    /// Per-operation wire deadline, in milliseconds: serve-layer
    /// clients bound every blocking read/write by it, servers use it
    /// as the per-write deadline, cluster heads as the snapshot/ack
    /// deadline. No blocking socket call outlives it.
    pub deadline_ms: u64,
    /// Run the PJRT offline verification afterwards.
    pub verify: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            n: 10_000_000,
            universe: 1 << 22,
            skew: 1.1,
            shift: 0.0,
            seed: 42,
            k: 2000,
            k_majority: 2000,
            threads: 4,
            // Sized so the batched-ingest scratch map stays L2-resident
            // (see parallel::batch_chunk_len).
            chunk_len: crate::parallel::batch_chunk_len_default(),
            queue_depth: 8,
            routing: Routing::RoundRobin,
            transport: Transport::Ring,
            structure: SummaryKind::BucketList,
            batch_ingest: true,
            epoch_items: 65_536,
            delta_ring: 0,
            window_epochs: 8,
            snapshot_cache: true,
            deadline_ms: 30_000,
            verify: false,
        }
    }
}

impl RunConfig {
    /// Load from a JSON file; absent fields keep defaults.
    pub fn from_json_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("bad config: {e}"))?;
        let mut c = Self::default();
        let get_u = |k: &str| j.get(k).and_then(|v| v.as_u64());
        let get_f = |k: &str| j.get(k).and_then(|v| v.as_f64());
        if let Some(v) = get_u("n") { c.n = v; }
        if let Some(v) = get_u("universe") { c.universe = v; }
        if let Some(v) = get_f("skew") { c.skew = v; }
        if let Some(v) = get_f("shift") { c.shift = v; }
        if let Some(v) = get_u("seed") { c.seed = v; }
        if let Some(v) = get_u("k") { c.k = v as usize; }
        if let Some(v) = get_u("k_majority") { c.k_majority = v; } else { c.k_majority = c.k as u64; }
        if let Some(v) = get_u("threads") { c.threads = v as usize; }
        if let Some(v) = get_u("chunk_len") { c.chunk_len = v as usize; }
        if let Some(v) = get_u("queue_depth") { c.queue_depth = v as usize; }
        if let Some(v) = j.get("routing").and_then(|v| v.as_str()) {
            c.routing = v.parse().map_err(anyhow::Error::msg)?;
        }
        if let Some(v) = j.get("transport").and_then(|v| v.as_str()) {
            c.transport = v.parse().map_err(anyhow::Error::msg)?;
        }
        if let Some(v) = j.get("structure").and_then(|v| v.as_str()) {
            c.structure = v.parse().map_err(anyhow::Error::msg)?;
        }
        if let Some(v) = j.get("batch_ingest").and_then(|v| v.as_bool()) { c.batch_ingest = v; }
        if let Some(v) = get_u("epoch_items") { c.epoch_items = v; }
        if let Some(v) = get_u("delta_ring") { c.delta_ring = v as usize; }
        if let Some(v) = get_u("window_epochs") { c.window_epochs = v as usize; }
        if let Some(v) = j.get("snapshot_cache").and_then(|v| v.as_bool()) { c.snapshot_cache = v; }
        if let Some(v) = get_u("deadline_ms") { c.deadline_ms = v; }
        if let Some(v) = j.get("verify").and_then(|v| v.as_bool()) { c.verify = v; }
        c.validate()?;
        Ok(c)
    }

    /// Sanity limits.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.n >= 1, "n must be positive");
        anyhow::ensure!(self.universe >= 1, "universe must be positive");
        anyhow::ensure!(self.skew >= 0.0, "skew must be non-negative");
        anyhow::ensure!(self.k >= 1, "k must be positive");
        anyhow::ensure!(self.k_majority >= 2, "k_majority must be >= 2");
        anyhow::ensure!(self.threads >= 1, "threads must be positive");
        anyhow::ensure!(self.chunk_len >= 1, "chunk_len must be positive");
        anyhow::ensure!(self.window_epochs >= 1, "window_epochs must be positive");
        anyhow::ensure!(self.deadline_ms >= 1, "deadline_ms must be positive");
        Ok(())
    }

    /// Serialize to JSON (for `--dump-config`).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"n\": {}, \"universe\": {}, \"skew\": {}, \"shift\": {}, \"seed\": {},\n \
              \"k\": {}, \"k_majority\": {}, \"threads\": {}, \"chunk_len\": {},\n \
              \"queue_depth\": {}, \"routing\": \"{}\", \"transport\": \"{}\",\n \
              \"structure\": \"{}\", \"batch_ingest\": {}, \"epoch_items\": {},\n \
              \"delta_ring\": {}, \"window_epochs\": {}, \"snapshot_cache\": {},\n \
              \"deadline_ms\": {}, \"verify\": {}}}",
            self.n, self.universe, self.skew, self.shift, self.seed, self.k,
            self.k_majority, self.threads, self.chunk_len, self.queue_depth,
            self.routing, self.transport, self.structure, self.batch_ingest,
            self.epoch_items, self.delta_ring, self.window_epochs,
            self.snapshot_cache, self.deadline_ms, self.verify
        )
    }

    /// The coordinator session this config describes. One mapping used
    /// by `pss query`, `pss serve`, and the serve integration tests, so
    /// a config file means the same session everywhere.
    pub fn coordinator(&self) -> CoordinatorConfig {
        CoordinatorConfig {
            shards: self.threads,
            k: self.k,
            k_majority: self.k_majority,
            queue_depth: self.queue_depth,
            routing: self.routing,
            transport: self.transport,
            structure: self.structure,
            epoch_items: self.epoch_items,
            batch_ingest: self.batch_ingest,
            delta_ring: self.delta_ring,
            window_epochs: self.window_epochs,
            snapshot_cache: self.snapshot_cache,
        }
    }
}

/// One paper experiment (Table I + figure/table ids).
#[derive(Debug, Clone, Copy)]
pub struct ExperimentInfo {
    /// CLI id (`pss repro --exp <id>`).
    pub id: &'static str,
    /// What it regenerates.
    pub what: &'static str,
}

/// The full registry (DESIGN.md §5).
pub const EXPERIMENTS: &[ExperimentInfo] = &[
    ExperimentInfo { id: "fig1a", what: "ARE vs cores, varying k (OpenMP, n=8B, rho=1.1)" },
    ExperimentInfo { id: "fig1b", what: "ARE vs cores, varying n (OpenMP, k=2000, rho=1.1)" },
    ExperimentInfo { id: "fig1c", what: "ARE vs cores, varying rho (OpenMP, n=8B, k=2000)" },
    ExperimentInfo { id: "fig2a", what: "runtime vs cores, varying k (OpenMP)" },
    ExperimentInfo { id: "fig2b", what: "runtime vs cores, varying n (OpenMP)" },
    ExperimentInfo { id: "fig2c", what: "runtime vs cores, varying rho (OpenMP)" },
    ExperimentInfo { id: "tab2", what: "Table II: OpenMP runtime+speedup grid (1-16 cores)" },
    ExperimentInfo { id: "fig3a", what: "fractional overhead vs threads, varying k (OpenMP)" },
    ExperimentInfo { id: "fig3b", what: "fractional overhead vs threads, varying n (OpenMP)" },
    ExperimentInfo { id: "tab3", what: "Table III: pure MPI grid (1-512 cores)" },
    ExperimentInfo { id: "tab4", what: "Table IV: hybrid MPI/OpenMP grid (1-512 cores)" },
    ExperimentInfo { id: "fig4", what: "Fig 4: MPI vs hybrid speedup + overhead (n=8B, 29B)" },
    ExperimentInfo { id: "fig5", what: "Fig 5: Phi thread sweep 15-240 (n=3B)" },
    ExperimentInfo { id: "fig6", what: "Fig 6: Xeon vs MIC sockets 1-64 (n=3B)" },
    ExperimentInfo { id: "all", what: "every table and figure above" },
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    #[test]
    fn default_roundtrips_through_json() {
        let d = TempDir::new().unwrap();
        let p = d.path().join("cfg.json");
        let c = RunConfig { n: 123, k: 7, k_majority: 7, ..Default::default() };
        std::fs::write(&p, c.to_json()).unwrap();
        let c2 = RunConfig::from_json_file(&p).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn partial_config_keeps_defaults() {
        let d = TempDir::new().unwrap();
        let p = d.path().join("cfg.json");
        std::fs::write(&p, r#"{"n": 5000, "skew": 1.8}"#).unwrap();
        let c = RunConfig::from_json_file(&p).unwrap();
        assert_eq!(c.n, 5000);
        assert_eq!(c.skew, 1.8);
        assert_eq!(c.k, RunConfig::default().k);
    }

    #[test]
    fn batch_ingest_defaults_on_and_parses() {
        assert!(RunConfig::default().batch_ingest);
        let d = TempDir::new().unwrap();
        let p = d.path().join("cfg.json");
        std::fs::write(&p, r#"{"batch_ingest": false}"#).unwrap();
        let c = RunConfig::from_json_file(&p).unwrap();
        assert!(!c.batch_ingest);
        // And it survives the serialize/parse roundtrip.
        std::fs::write(&p, c.to_json()).unwrap();
        assert!(!RunConfig::from_json_file(&p).unwrap().batch_ingest);
    }

    #[test]
    fn epoch_items_roundtrips_and_maps_to_coordinator() {
        let c = RunConfig::default();
        assert_eq!(c.epoch_items, 65_536, "live read path on by default");
        let d = TempDir::new().unwrap();
        let p = d.path().join("cfg.json");
        std::fs::write(&p, r#"{"epoch_items": 1024, "threads": 3, "delta_ring": 8}"#).unwrap();
        let c = RunConfig::from_json_file(&p).unwrap();
        assert_eq!(c.epoch_items, 1024);
        std::fs::write(&p, c.to_json()).unwrap();
        assert_eq!(RunConfig::from_json_file(&p).unwrap(), c);
        // One mapping for every session spawner.
        let cc = c.coordinator();
        assert_eq!(cc.epoch_items, 1024);
        assert_eq!(cc.shards, 3);
        assert_eq!(cc.delta_ring, 8);
        assert_eq!(cc.k, c.k);
        assert_eq!(cc.routing, c.routing);
        assert_eq!(cc.structure, c.structure);
    }

    #[test]
    fn window_fields_default_and_roundtrip() {
        let c = RunConfig::default();
        assert_eq!(c.delta_ring, 0, "windows are opt-in");
        assert_eq!(c.window_epochs, 8);
        let d = TempDir::new().unwrap();
        let p = d.path().join("cfg.json");
        std::fs::write(&p, r#"{"delta_ring": 16, "window_epochs": 4}"#).unwrap();
        let c = RunConfig::from_json_file(&p).unwrap();
        assert_eq!(c.delta_ring, 16);
        assert_eq!(c.window_epochs, 4);
        std::fs::write(&p, c.to_json()).unwrap();
        let c2 = RunConfig::from_json_file(&p).unwrap();
        assert_eq!(c, c2);
        // window_epochs must be positive.
        std::fs::write(&p, r#"{"window_epochs": 0}"#).unwrap();
        assert!(RunConfig::from_json_file(&p).is_err());
    }

    #[test]
    fn deadline_ms_defaults_roundtrips_and_validates() {
        let c = RunConfig::default();
        assert_eq!(c.deadline_ms, 30_000, "deadlines are on by default");
        let d = TempDir::new().unwrap();
        let p = d.path().join("cfg.json");
        std::fs::write(&p, r#"{"deadline_ms": 1500}"#).unwrap();
        let c = RunConfig::from_json_file(&p).unwrap();
        assert_eq!(c.deadline_ms, 1500);
        std::fs::write(&p, c.to_json()).unwrap();
        assert_eq!(RunConfig::from_json_file(&p).unwrap(), c);
        // A zero deadline would mean every wire operation times out
        // immediately — reject it at load time.
        std::fs::write(&p, r#"{"deadline_ms": 0}"#).unwrap();
        assert!(RunConfig::from_json_file(&p).is_err());
    }

    #[test]
    fn routing_and_transport_default_and_roundtrip() {
        let c = RunConfig::default();
        assert_eq!(c.routing, Routing::RoundRobin);
        assert_eq!(c.transport, Transport::Ring);
        let d = TempDir::new().unwrap();
        let p = d.path().join("cfg.json");
        std::fs::write(&p, r#"{"routing": "keyed", "transport": "mpsc"}"#).unwrap();
        let c = RunConfig::from_json_file(&p).unwrap();
        assert_eq!(c.routing, Routing::Keyed);
        assert_eq!(c.transport, Transport::Mpsc);
        std::fs::write(&p, c.to_json()).unwrap();
        let c2 = RunConfig::from_json_file(&p).unwrap();
        assert_eq!(c, c2);
        // The adaptive tier parses and round-trips through its Display
        // form, and the mapping hands it to the coordinator unchanged.
        std::fs::write(&p, r#"{"routing": "keyed-adaptive"}"#).unwrap();
        let c = RunConfig::from_json_file(&p).unwrap();
        assert_eq!(c.routing, Routing::KeyedAdaptive);
        assert_eq!(c.coordinator().routing, Routing::KeyedAdaptive);
        std::fs::write(&p, c.to_json()).unwrap();
        assert_eq!(RunConfig::from_json_file(&p).unwrap().routing, Routing::KeyedAdaptive);
        // Unknown values are rejected, not silently defaulted.
        std::fs::write(&p, r#"{"routing": "teleport"}"#).unwrap();
        assert!(RunConfig::from_json_file(&p).is_err());
        std::fs::write(&p, r#"{"transport": "carrier-pigeon"}"#).unwrap();
        assert!(RunConfig::from_json_file(&p).is_err());
    }

    #[test]
    fn structure_defaults_and_roundtrips() {
        let c = RunConfig::default();
        assert_eq!(c.structure, SummaryKind::BucketList);
        let d = TempDir::new().unwrap();
        let p = d.path().join("cfg.json");
        for (text, want) in [
            (r#"{"structure": "heap"}"#, SummaryKind::Heap),
            (r#"{"structure": "bucket"}"#, SummaryKind::BucketList),
            (r#"{"structure": "compact"}"#, SummaryKind::Compact),
        ] {
            std::fs::write(&p, text).unwrap();
            let c = RunConfig::from_json_file(&p).unwrap();
            assert_eq!(c.structure, want);
            std::fs::write(&p, c.to_json()).unwrap();
            assert_eq!(RunConfig::from_json_file(&p).unwrap(), c);
        }
        // Unknown structures are rejected, not silently defaulted.
        std::fs::write(&p, r#"{"structure": "btree"}"#).unwrap();
        assert!(RunConfig::from_json_file(&p).is_err());
    }

    #[test]
    fn invalid_rejected() {
        let d = TempDir::new().unwrap();
        let p = d.path().join("cfg.json");
        std::fs::write(&p, r#"{"k_majority": 1}"#).unwrap();
        assert!(RunConfig::from_json_file(&p).is_err());
    }

    #[test]
    fn registry_has_all_paper_artifacts() {
        let ids: Vec<&str> = EXPERIMENTS.iter().map(|e| e.id).collect();
        for want in ["fig1a", "fig2b", "tab2", "tab3", "tab4", "fig4", "fig5", "fig6"] {
            assert!(ids.contains(&want), "missing {want}");
        }
    }
}
