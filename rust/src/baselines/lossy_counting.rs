//! `LossyCounting` — Manku & Motwani (VLDB 2002), the other major
//! counter-based algorithm the paper's §2 cites.
//!
//! The stream is processed in buckets of width `w = ⌈1/ε⌉`. Each entry
//! carries its count plus `delta`, the maximum count it could have missed
//! before insertion (current bucket id - 1). At bucket boundaries every
//! entry with `count + delta <= bucket` is deleted. Guarantees
//! `f - εn <= f̂ <= f` with `O((1/ε) log εn)` space.

use crate::summary::counter::Counter;
use crate::summary::traits::FrequencySummary;
use std::collections::HashMap;

#[derive(Debug, Clone, Copy)]
struct Entry {
    count: u64,
    delta: u64,
}

/// Lossy Counting with error parameter `ε = 1/k` (so it is comparable to
/// a Space Saving instance with `k` counters).
#[derive(Debug, Clone)]
pub struct LossyCounting {
    entries: HashMap<u64, Entry>,
    /// Bucket width `w = ⌈1/ε⌉ = k`.
    width: u64,
    /// Current bucket id (1-based).
    bucket: u64,
    n: u64,
    k: usize,
}

impl LossyCounting {
    /// Create with error ε = 1/k.
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        Self {
            entries: HashMap::new(),
            width: k as u64,
            bucket: 1,
            n: 0,
            k,
        }
    }

    fn compress(&mut self) {
        let b = self.bucket;
        self.entries.retain(|_, e| e.count + e.delta > b);
    }
}

impl FrequencySummary for LossyCounting {
    fn capacity(&self) -> usize {
        // Space is adaptive; report the nominal 1/ε for comparability.
        self.k
    }

    fn offer(&mut self, item: u64) {
        self.n += 1;
        let b = self.bucket;
        self.entries
            .entry(item)
            .and_modify(|e| e.count += 1)
            .or_insert(Entry { count: 1, delta: b - 1 });
        if self.n % self.width == 0 {
            self.compress();
            self.bucket += 1;
        }
    }

    fn processed(&self) -> u64 {
        self.n
    }

    fn counters(&self) -> Vec<Counter> {
        self.entries
            .iter()
            .map(|(item, e)| Counter { item: *item, count: e.count + e.delta, err: e.delta })
            .collect()
    }

    fn estimate(&self, item: u64) -> Option<u64> {
        self.entries.get(&item).map(|e| e.count + e.delta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;
    use std::collections::HashMap;

    #[test]
    fn error_bound_holds() {
        let mut rng = SplitMix64::new(41);
        let items: Vec<u64> = (0..50_000)
            .map(|_| if rng.next_f64() < 0.5 { rng.next_below(10) } else { rng.next_below(10_000) })
            .collect();
        let mut truth: HashMap<u64, u64> = HashMap::new();
        for &i in &items {
            *truth.entry(i).or_default() += 1;
        }
        let k = 100;
        let mut lc = LossyCounting::new(k);
        lc.offer_all(&items);
        let eps_n = items.len() as u64 / k as u64;
        for c in lc.counters() {
            let f = truth.get(&c.item).copied().unwrap_or(0);
            assert!(c.count >= f, "reported estimate must upper-bound f");
            assert!(c.count <= f + eps_n, "over-estimate beyond εn");
        }
        // Recall: every item with f > n/k survives.
        for (item, f) in &truth {
            if *f > eps_n {
                assert!(lc.estimate(*item).is_some(), "lost frequent item {item}");
            }
        }
    }

    #[test]
    fn space_stays_bounded() {
        let mut rng = SplitMix64::new(42);
        let mut lc = LossyCounting::new(50);
        for _ in 0..200_000 {
            lc.offer(rng.next_below(1_000_000));
        }
        // Theory: O((1/ε) log εn) = 50 * log(200000/50) ≈ 50 * 12.
        assert!(lc.entries.len() <= 50 * 14, "space blow-up: {}", lc.entries.len());
    }

    #[test]
    fn exact_within_first_bucket() {
        let mut lc = LossyCounting::new(100);
        lc.offer_all(&[1, 1, 2, 3, 3, 3]);
        assert_eq!(lc.estimate(3), Some(3));
        assert_eq!(lc.estimate(1), Some(2));
    }
}
