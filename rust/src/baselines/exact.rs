//! `Exact` — exact frequency counting: the ground-truth oracle behind
//! every accuracy metric (ARE, precision, recall) and the off-line
//! verification comparison for the PJRT artifact path.

use crate::summary::counter::Counter;
use crate::summary::traits::FrequencySummary;
use std::collections::HashMap;

/// Exact counts over the full stream (memory `O(distinct items)`).
#[derive(Debug, Clone, Default)]
pub struct Exact {
    counts: HashMap<u64, u64>,
    n: u64,
}

impl Exact {
    /// New empty oracle.
    pub fn new() -> Self {
        Self::default()
    }

    /// Exact frequency (0 when unseen).
    pub fn count(&self, item: u64) -> u64 {
        self.counts.get(&item).copied().unwrap_or(0)
    }

    /// All true k-majority elements: `f > n/k`, descending by frequency.
    pub fn k_majority(&self, k: u64) -> Vec<Counter> {
        let thresh = self.n / k;
        let mut v: Vec<Counter> = self
            .counts
            .iter()
            .filter(|(_, &f)| f > thresh)
            .map(|(&item, &f)| Counter { item, count: f, err: 0 })
            .collect();
        v.sort_unstable_by(|a, b| b.count.cmp(&a.count).then(a.item.cmp(&b.item)));
        v
    }

    /// The `top` most frequent items, descending.
    pub fn top_k(&self, top: usize) -> Vec<Counter> {
        let mut v: Vec<Counter> = self
            .counts
            .iter()
            .map(|(&item, &f)| Counter { item, count: f, err: 0 })
            .collect();
        v.sort_unstable_by(|a, b| b.count.cmp(&a.count).then(a.item.cmp(&b.item)));
        v.truncate(top);
        v
    }

    /// Number of distinct items seen.
    pub fn distinct(&self) -> usize {
        self.counts.len()
    }
}

impl FrequencySummary for Exact {
    fn capacity(&self) -> usize {
        usize::MAX
    }

    fn offer(&mut self, item: u64) {
        self.n += 1;
        *self.counts.entry(item).or_default() += 1;
    }

    fn processed(&self) -> u64 {
        self.n
    }

    fn counters(&self) -> Vec<Counter> {
        self.counts
            .iter()
            .map(|(&item, &count)| Counter { item, count, err: 0 })
            .collect()
    }

    fn estimate(&self, item: u64) -> Option<u64> {
        self.counts.get(&item).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_counts() {
        let mut e = Exact::new();
        e.offer_all(&[1, 2, 1, 3, 1, 2]);
        assert_eq!(e.count(1), 3);
        assert_eq!(e.count(2), 2);
        assert_eq!(e.count(9), 0);
        assert_eq!(e.distinct(), 3);
        assert_eq!(e.processed(), 6);
    }

    #[test]
    fn k_majority_thresholding() {
        let mut e = Exact::new();
        // n = 10; k = 3 -> threshold 3, need f > 3.
        e.offer_all(&[1, 1, 1, 1, 2, 2, 2, 3, 3, 4]);
        let hh = e.k_majority(3);
        assert_eq!(hh.len(), 1);
        assert_eq!(hh[0].item, 1);
    }

    #[test]
    fn top_k_order() {
        let mut e = Exact::new();
        e.offer_all(&[5, 5, 5, 7, 7, 9]);
        let t = e.top_k(2);
        assert_eq!(t[0].item, 5);
        assert_eq!(t[1].item, 7);
    }
}
