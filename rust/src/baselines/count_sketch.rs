//! `CountSketch` — Charikar, Chen, Farach-Colton (ICALP 2002): the
//! signed sketch the paper's §2 cites alongside CountMin.
//!
//! Each row hashes the item to a column *and* to a sign in {−1, +1};
//! updates add the sign, the estimate is the **median** of the signed row
//! reads. Unbiased (errors cancel), two-sided error `O(‖f‖₂/√w)`.

use crate::summary::counter::Counter;
use crate::summary::traits::FrequencySummary;
use crate::util::hash::row_hash;
use std::collections::HashMap;

/// CountSketch with candidate tracking (same reporting scheme as
/// [`CountMin`](super::count_min::CountMin) so comparisons are fair).
#[derive(Debug, Clone)]
pub struct CountSketch {
    rows: usize,
    width: usize,
    table: Vec<i64>,
    candidates: HashMap<u64, i64>,
    heap_cap: usize,
    n: u64,
}

impl CountSketch {
    /// `width` columns (power of two), `rows` independent rows (odd, for
    /// a well-defined median), reporting the top `heap_cap` items.
    pub fn new(width: usize, rows: usize, heap_cap: usize) -> Self {
        assert!(width.is_power_of_two());
        assert!(rows % 2 == 1, "rows must be odd for the median");
        Self {
            rows,
            width,
            table: vec![0; width * rows],
            candidates: HashMap::with_capacity(heap_cap * 2),
            heap_cap,
            n: 0,
        }
    }

    #[inline]
    fn cell_and_sign(&self, item: u64, row: usize) -> (usize, i64) {
        let h = row_hash(item, row as u64);
        let col = (h as usize) & (self.width - 1);
        // Take the sign from a high bit not used for the column.
        let sign = if (h >> 60) & 1 == 1 { 1 } else { -1 };
        (row * self.width + col, sign)
    }

    /// Median-of-rows estimate (may be negative for noise items).
    pub fn query(&self, item: u64) -> i64 {
        let mut reads: Vec<i64> = (0..self.rows)
            .map(|r| {
                let (cell, sign) = self.cell_and_sign(item, r);
                self.table[cell] * sign
            })
            .collect();
        reads.sort_unstable();
        reads[self.rows / 2]
    }

    fn shrink_candidates(&mut self) {
        if self.candidates.len() <= self.heap_cap {
            return;
        }
        let mut v: Vec<(u64, i64)> = self.candidates.iter().map(|(k, v)| (*k, *v)).collect();
        v.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(self.heap_cap);
        self.candidates = v.into_iter().collect();
    }
}

impl FrequencySummary for CountSketch {
    fn capacity(&self) -> usize {
        self.heap_cap
    }

    fn offer(&mut self, item: u64) {
        self.n += 1;
        for r in 0..self.rows {
            let (cell, sign) = self.cell_and_sign(item, r);
            self.table[cell] += sign;
        }
        let est = self.query(item);
        self.candidates.insert(item, est);
        if self.candidates.len() > self.heap_cap * 2 {
            self.shrink_candidates();
        }
    }

    fn processed(&self) -> u64 {
        self.n
    }

    fn counters(&self) -> Vec<Counter> {
        let mut snapshot = self.clone();
        snapshot.shrink_candidates();
        snapshot
            .candidates
            .iter()
            .filter(|(_, est)| **est > 0)
            .map(|(&item, &est)| Counter { item, count: est as u64, err: 0 })
            .collect()
    }

    fn estimate(&self, item: u64) -> Option<u64> {
        let q = self.query(item);
        (q > 0).then_some(q as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;
    use std::collections::HashMap;

    #[test]
    fn heavy_items_estimated_closely() {
        let mut rng = SplitMix64::new(61);
        let mut items = Vec::new();
        for hh in 0..4u64 {
            items.extend(std::iter::repeat(hh).take(10_000));
        }
        items.extend((0..20_000).map(|_| 100 + rng.next_below(100_000)));
        for i in (1..items.len()).rev() {
            let j = rng.next_below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
        let mut cs = CountSketch::new(4096, 5, 16);
        cs.offer_all(&items);
        for hh in 0..4u64 {
            let est = cs.query(hh);
            let err = (est - 10_000).abs();
            assert!(err < 1_000, "heavy item {hh} est {est}");
        }
    }

    #[test]
    fn estimate_unbiased_on_average() {
        let mut rng = SplitMix64::new(62);
        let items: Vec<u64> = (0..50_000).map(|_| rng.next_below(1_000)).collect();
        let mut truth: HashMap<u64, u64> = HashMap::new();
        for &i in &items {
            *truth.entry(i).or_default() += 1;
        }
        let mut cs = CountSketch::new(2048, 5, 64);
        cs.offer_all(&items);
        let mean_err: f64 = truth
            .iter()
            .map(|(&i, &f)| cs.query(i) as f64 - f as f64)
            .sum::<f64>()
            / truth.len() as f64;
        assert!(mean_err.abs() < 10.0, "bias {mean_err}");
    }

    #[test]
    fn rows_must_be_odd() {
        let r = std::panic::catch_unwind(|| CountSketch::new(64, 4, 8));
        assert!(r.is_err());
    }
}
