//! `Frequent` — the Misra–Gries algorithm (1982), as re-discovered by
//! Demaine, López-Ortiz, Munro (ESA 2002) and Karp, Shenker,
//! Papadimitriou (2003): the paper's §2 ancestor of Space Saving and the
//! subject of the authors' earlier parallel-merge work [23].
//!
//! Update rule with `k-1` counters: monitored items increment; an
//! unmonitored item takes a spare counter if one exists; otherwise *all*
//! counters decrement by one (zeroed counters become spare). Guarantees
//! `f - n/k <= f̂ <= f` — an UNDER-estimate, unlike Space Saving.
//!
//! The decrement-all is implemented physically but costs amortized `O(1)`
//! per item: total decrement mass is bounded by total increment mass.

use crate::summary::counter::Counter;
use crate::summary::traits::FrequencySummary;
use crate::util::FastMap;

/// Misra–Gries summary with `k - 1` counters (solves k-majority).
#[derive(Debug, Clone)]
pub struct Frequent {
    items: Vec<u64>,
    counts: Vec<u64>,
    /// Spare (zero-count) slot ids.
    free: Vec<u32>,
    map: FastMap,
    k: usize,
    n: u64,
}

impl Frequent {
    /// `k` is the k-majority parameter; the structure keeps `k-1` counters.
    pub fn new(k: usize) -> Self {
        assert!(k >= 2, "k-majority needs k >= 2");
        let cap = k - 1;
        Self {
            items: vec![0; cap],
            counts: vec![0; cap],
            free: (0..cap as u32).rev().collect(),
            map: FastMap::with_capacity(cap),
            k,
            n: 0,
        }
    }
}

impl FrequencySummary for Frequent {
    fn capacity(&self) -> usize {
        self.k - 1
    }

    fn offer(&mut self, item: u64) {
        self.n += 1;
        if let Some(slot) = self.map.get(item) {
            self.counts[slot as usize] += 1;
        } else if let Some(slot) = self.free.pop() {
            self.items[slot as usize] = item;
            self.counts[slot as usize] = 1;
            self.map.insert(item, slot);
        } else {
            // Decrement everything; newly-zeroed counters become spare.
            for slot in 0..self.counts.len() {
                debug_assert!(self.counts[slot] > 0);
                self.counts[slot] -= 1;
                if self.counts[slot] == 0 {
                    self.map.remove(self.items[slot]);
                    self.free.push(slot as u32);
                }
            }
        }
    }

    fn processed(&self) -> u64 {
        self.n
    }

    fn counters(&self) -> Vec<Counter> {
        self.items
            .iter()
            .zip(&self.counts)
            .filter(|(_, c)| **c > 0)
            .map(|(i, c)| Counter { item: *i, count: *c, err: 0 })
            .collect()
    }

    fn estimate(&self, item: u64) -> Option<u64> {
        self.map.get(item).map(|s| self.counts[s as usize])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;
    use std::collections::HashMap;

    #[test]
    fn never_overestimates() {
        let mut rng = SplitMix64::new(31);
        let items: Vec<u64> = (0..20_000).map(|_| rng.next_below(100)).collect();
        let mut truth: HashMap<u64, u64> = HashMap::new();
        for &i in &items {
            *truth.entry(i).or_default() += 1;
        }
        let mut f = Frequent::new(16);
        f.offer_all(&items);
        for c in f.counters() {
            let t = truth[&c.item];
            assert!(c.count <= t, "over-estimate");
            assert!(c.count + items.len() as u64 / 16 >= t, "error bound broken");
        }
    }

    #[test]
    fn recall_one_for_k_majority() {
        // 42 appears > n/4 times -> must survive with k=4.
        let mut items = vec![42u64; 3_000];
        let mut rng = SplitMix64::new(32);
        items.extend((0..7_000).map(|_| 100 + rng.next_below(5_000)));
        for i in (1..items.len()).rev() {
            let j = rng.next_below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
        let mut f = Frequent::new(4);
        f.offer_all(&items);
        assert!(f.estimate(42).is_some(), "k-majority element lost");
    }

    #[test]
    fn majority_classic() {
        let mut f = Frequent::new(2); // single counter: Boyer–Moore
        f.offer_all(&[1, 2, 1, 3, 1, 1]);
        assert_eq!(f.counters()[0].item, 1);
    }

    #[test]
    fn decrement_frees_slots() {
        let mut f = Frequent::new(3); // 2 counters
        f.offer_all(&[1, 2, 3]); // third item triggers decrement-all
        // counters for 1 and 2 both drop to 0 -> both spare.
        assert_eq!(f.counters().len(), 0);
        f.offer(9);
        assert_eq!(f.estimate(9), Some(1));
    }
}
