//! `CountMin` — the Cormode–Muthukrishnan sketch (J. Algorithms 2005),
//! the paper's §2 representative of the *sketch-based* class.
//!
//! `d` rows × `w` columns of counters; each row hashes the item to one
//! column; the estimate is the row-wise minimum. Over-estimates by at
//! most `εn = (e/w)·n` with probability `1 - e^-d`. A candidate min-heap
//! of the current top items turns the sketch into a frequent-items
//! reporter comparable to Space Saving.

use crate::summary::counter::Counter;
use crate::summary::traits::FrequencySummary;
use crate::util::hash::row_hash;
use std::collections::{BinaryHeap, HashMap};
use std::cmp::Reverse;

/// CountMin sketch plus a top-candidate tracker of size `heap_cap`.
#[derive(Debug, Clone)]
pub struct CountMin {
    rows: usize,
    width: usize,
    table: Vec<u64>,
    /// Current top candidates: item -> estimate.
    candidates: HashMap<u64, u64>,
    heap_cap: usize,
    n: u64,
}

impl CountMin {
    /// `width` columns (≈ e/ε), `rows` hash functions (≈ ln 1/δ),
    /// tracking the `heap_cap` largest items for reporting.
    pub fn new(width: usize, rows: usize, heap_cap: usize) -> Self {
        assert!(width.is_power_of_two(), "width must be a power of two");
        assert!(rows >= 1 && heap_cap >= 1);
        Self {
            rows,
            width,
            table: vec![0; width * rows],
            candidates: HashMap::with_capacity(heap_cap * 2),
            heap_cap,
            n: 0,
        }
    }

    /// Sketch estimate (row-wise min) regardless of candidate tracking.
    pub fn query(&self, item: u64) -> u64 {
        let mut est = u64::MAX;
        for r in 0..self.rows {
            let col = (row_hash(item, r as u64) as usize) & (self.width - 1);
            est = est.min(self.table[r * self.width + col]);
        }
        est
    }

    fn shrink_candidates(&mut self) {
        if self.candidates.len() <= self.heap_cap {
            return;
        }
        // Keep the heap_cap largest estimates.
        let mut heap: BinaryHeap<Reverse<(u64, u64)>> = BinaryHeap::new();
        for (&item, &est) in &self.candidates {
            heap.push(Reverse((est, item)));
            if heap.len() > self.heap_cap {
                heap.pop();
            }
        }
        self.candidates = heap.into_iter().map(|Reverse((e, i))| (i, e)).collect();
    }
}

impl FrequencySummary for CountMin {
    fn capacity(&self) -> usize {
        self.heap_cap
    }

    fn offer(&mut self, item: u64) {
        self.n += 1;
        let mut est = u64::MAX;
        for r in 0..self.rows {
            let col = (row_hash(item, r as u64) as usize) & (self.width - 1);
            let cell = &mut self.table[r * self.width + col];
            *cell += 1;
            est = est.min(*cell);
        }
        self.candidates.insert(item, est);
        if self.candidates.len() > self.heap_cap * 2 {
            self.shrink_candidates();
        }
    }

    fn processed(&self) -> u64 {
        self.n
    }

    fn counters(&self) -> Vec<Counter> {
        let mut snapshot = self.clone();
        snapshot.shrink_candidates();
        snapshot
            .candidates
            .iter()
            .map(|(&item, &est)| Counter { item, count: est, err: est.saturating_sub(1) })
            .collect()
    }

    fn estimate(&self, item: u64) -> Option<u64> {
        Some(self.query(item))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;
    use std::collections::HashMap;

    #[test]
    fn never_underestimates() {
        let mut rng = SplitMix64::new(51);
        let items: Vec<u64> = (0..30_000).map(|_| rng.next_below(2_000)).collect();
        let mut truth: HashMap<u64, u64> = HashMap::new();
        for &i in &items {
            *truth.entry(i).or_default() += 1;
        }
        let mut cm = CountMin::new(1024, 4, 64);
        cm.offer_all(&items);
        for (&item, &f) in &truth {
            assert!(cm.query(item) >= f, "CountMin under-estimated");
        }
    }

    #[test]
    fn error_within_bound_whp() {
        let mut rng = SplitMix64::new(52);
        let n = 100_000u64;
        let items: Vec<u64> = (0..n).map(|_| rng.next_below(5_000)).collect();
        let mut truth: HashMap<u64, u64> = HashMap::new();
        for &i in &items {
            *truth.entry(i).or_default() += 1;
        }
        let width = 2048usize;
        let mut cm = CountMin::new(width, 5, 64);
        cm.offer_all(&items);
        // ε = e/width; allow 3x slack for the tail.
        let bound = (3.0 * std::f64::consts::E / width as f64 * n as f64) as u64;
        let mut violations = 0;
        for (&item, &f) in &truth {
            if cm.query(item) > f + bound {
                violations += 1;
            }
        }
        assert!(violations * 100 < truth.len(), "too many large errors");
    }

    #[test]
    fn heavy_hitters_reported() {
        let mut rng = SplitMix64::new(53);
        let mut items = Vec::new();
        for hh in 0..5u64 {
            items.extend(std::iter::repeat(hh).take(5_000));
        }
        items.extend((0..25_000).map(|_| 100 + rng.next_below(50_000)));
        for i in (1..items.len()).rev() {
            let j = rng.next_below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
        let mut cm = CountMin::new(4096, 4, 16);
        cm.offer_all(&items);
        let reported: std::collections::HashSet<u64> =
            cm.counters().iter().map(|c| c.item).collect();
        for hh in 0..5u64 {
            assert!(reported.contains(&hh), "missed heavy hitter {hh}");
        }
    }
}
