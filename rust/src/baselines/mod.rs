//! Comparator algorithms from the paper's related-work section (§2) plus
//! the exact oracle used for ground truth.
//!
//! * [`Frequent`] — Misra–Gries / Demaine et al. decrement-based counters.
//! * [`LossyCounting`] — Manku–Motwani bucketed deletion.
//! * [`CountMin`] — Cormode–Muthukrishnan sketch (+ candidate heap).
//! * [`CountSketch`] — Charikar–Chen–Farach-Colton signed sketch.
//! * [`Exact`] — exact hash-map counts: the metrics oracle.

pub mod count_min;
pub mod count_sketch;
pub mod exact;
pub mod frequent;
pub mod lossy_counting;

pub use count_min::CountMin;
pub use count_sketch::CountSketch;
pub use exact::Exact;
pub use frequent::Frequent;
pub use lossy_counting::LossyCounting;
