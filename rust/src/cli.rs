//! Tiny CLI argument parser (the vendored crate set has no `clap`):
//! `pss <subcommand> [--flag value]... [--switch]...`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// The subcommand (first positional).
    pub command: String,
    /// `--key value` pairs.
    pub flags: BTreeMap<String, String>,
    /// Bare `--switch`es.
    pub switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut args = Args { command, ..Default::default() };
        while let Some(a) = it.next() {
            let Some(name) = a.strip_prefix("--") else {
                return Err(format!("unexpected positional argument '{a}'"));
            };
            if name.is_empty() {
                return Err("bare '--' not supported".into());
            }
            // `--key=value` or `--key value` or switch.
            if let Some((k, v)) = name.split_once('=') {
                args.flags.insert(k.to_string(), v.to_string());
            } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                args.flags.insert(name.to_string(), it.next().unwrap());
            } else {
                args.switches.push(name.to_string());
            }
        }
        Ok(args)
    }

    /// String flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// Typed flag with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("bad value for --{key}: '{v}'")),
        }
    }

    /// Required typed flag.
    pub fn require<T: std::str::FromStr>(&self, key: &str) -> Result<T, String> {
        self.flags
            .get(key)
            .ok_or_else(|| format!("missing required --{key}"))?
            .parse()
            .map_err(|_| format!("bad value for --{key}"))
    }

    /// Switch presence.
    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn subcommand_flags_switches() {
        let a = parse("repro --exp tab3 --scale 1000 --list");
        assert_eq!(a.command, "repro");
        assert_eq!(a.get("exp"), Some("tab3"));
        assert_eq!(a.get_or::<u64>("scale", 1).unwrap(), 1000);
        assert!(a.has("list"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("run --k=500 --skew=1.8");
        assert_eq!(a.get_or::<usize>("k", 0).unwrap(), 500);
        assert_eq!(a.get_or::<f64>("skew", 0.0).unwrap(), 1.8);
    }

    #[test]
    fn typed_errors() {
        let a = parse("run --k abc");
        assert!(a.get_or::<usize>("k", 1).is_err());
        assert!(a.require::<u64>("missing").is_err());
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(["run".into(), "stray".into()]).is_err());
    }
}
