//! Stream summaries: the sequential Space Saving algorithm (three
//! implementations) and the paper's `combine` merge operator.
//!
//! * [`SpaceSaving`] — hash map + slot-indexed binary min-heap,
//!   `O(log k)` per item. The simplest structure; ablation baseline.
//! * [`StreamSummary`] — Metwally's bucket-list structure, `O(1)`
//!   amortized per item, pointer-heavy.
//! * [`CompactSummary`] — Structure-of-Arrays counters with block-min
//!   eviction: `O(1)` amortized *and* cache-resident, the fastest
//!   per-shard hot loop (`bench_summary_core`, `pss bench --suite
//!   summary`).
//! * [`SummaryKind`] / [`AnySummary`] — runtime structure selection
//!   (CLI `--structure heap|bucket|compact`) with enum dispatch.
//! * [`Summary`] — the frozen, frequency-sorted summary value that ranks
//!   and threads exchange; [`Summary::combine`] is paper Algorithm 2,
//!   [`merge_disjoint`] the cheaper concatenation merge for
//!   key-disjoint (keyed-routed) substreams.
//! * [`batch`] — the batched ingest fast path: [`ChunkAggregator`]
//!   collapses a chunk into `(item, weight)` runs and [`offer_batched`]
//!   applies them as weighted updates, one summary touch per distinct
//!   item.
//!
//! All live implementations share the [`FrequencySummary`] trait so the
//! parallel layers are generic over the structure used per worker.

pub mod batch;
pub mod combine;
pub mod compact;
pub mod counter;
pub mod kind;
pub mod space_saving;
pub mod stream_summary;
pub mod traits;

pub use batch::{offer_batched, offer_runs, ChunkAggregator};
pub use combine::{absorb_exact, merge_disjoint, Summary};
pub use compact::CompactSummary;
pub use counter::Counter;
pub use kind::{AnySummary, SummaryKind};
pub use space_saving::SpaceSaving;
pub use stream_summary::StreamSummary;
pub use traits::FrequencySummary;
