//! `CompactSummary` — cache-conscious Space Saving: Structure-of-Arrays
//! counter storage with **block-min** eviction, `O(1)` amortized per
//! update.
//!
//! # Layout
//!
//! Counters live in three parallel flat arrays indexed by slot id —
//! `keys`, `counts`, `errors` — with the [`FastMap`] mapping item ids
//! straight to slots. The hot loop therefore touches exactly two
//! cachelines per monitored-item hit (the map probe and the slot's
//! `counts` word); nothing else moves. Compare the alternatives:
//!
//! * [`SpaceSaving`](super::SpaceSaving) interleaves every touch with an
//!   `O(log k)` heap sift across three bookkeeping vectors;
//! * [`StreamSummary`](super::StreamSummary) walks a doubly-linked
//!   bucket list — five link words per detach/attach even on the fast
//!   path.
//!
//! # Block-min eviction
//!
//! Space Saving only ever needs the *minimum* counter, and only at
//! eviction time. Slots are grouped into fixed blocks of `BLOCK` = 64
//! (one cacheline of `u64` counts is 8 slots; 64 keeps the per-block
//! metadata array 64× smaller than `k` while a block scan still spans
//! just 8 lines, streamed linearly). Each block caches
//! `(min_count, argmin)`:
//!
//! * **increment** — bump `counts[slot]`; if the slot was its block's
//!   cached argmin, mark the block *dirty* (the cache becomes a lower
//!   bound — the true block min can only have grown). No scan, no sift:
//!   `O(1)` always.
//! * **eviction** — linearly scan the `k/64`-entry block-min array for
//!   the smallest cached value (branch-light: one compare per block).
//!   If that block is dirty, repair it (rescan its ≤64 counts, restore
//!   the exact cache) and rescan; because dirty caches are lower
//!   bounds, the first *clean* minimum found is the true global
//!   minimum. Evict its argmin, then repair just that one block.
//!
//! Amortization: a block goes dirty only when its cached argmin is
//! incremented, and each repair retires one such event, so repairs are
//! bounded by update count — each costing one ≤64-slot scan over a
//! contiguous `counts` range the eviction was about to touch anyway.
//! Together with the `k/64` block-min sweep this keeps
//! [`offer`](FrequencySummary::offer) /
//! [`offer_weighted`](FrequencySummary::offer_weighted) `O(1)`
//! amortized with no sift loops and no linked-list traffic, which is
//! what lets the per-shard update loop run at memory bandwidth (QPOPSS,
//! arXiv:2409.01749; merge-side analysis in arXiv:1401.0702).

use super::counter::Counter;
use super::traits::FrequencySummary;
use crate::util::FastMap;

/// Slots per block: 8 cachelines of `u64` counts, and a block-min array
/// 64× smaller than `k`.
const BLOCK: usize = 64;

/// Space Saving over Structure-of-Arrays storage with block-min
/// eviction. See the [module docs](self) for the layout and the
/// amortization argument.
#[derive(Debug, Clone)]
pub struct CompactSummary {
    /// Monitored item per slot.
    keys: Vec<u64>,
    /// Estimated frequency per slot (`f̂`).
    counts: Vec<u64>,
    /// Over-estimation bound per slot (`err`).
    errors: Vec<u64>,
    /// item id -> slot id.
    map: FastMap,
    /// Cached minimum count per block. Exact while the block is clean;
    /// a lower bound on the true block minimum while dirty.
    block_min: Vec<u64>,
    /// Slot holding the cached minimum, per block (meaningful only
    /// while the block is clean).
    block_argmin: Vec<u32>,
    /// Whether the block's cache went stale since its last repair.
    dirty: Vec<bool>,
    /// Counter budget.
    k: usize,
    /// Items processed.
    n: u64,
}

impl CompactSummary {
    /// Create a summary with `k` counters (`k >= 1`).
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "k must be at least 1");
        let blocks = k.div_ceil(BLOCK);
        Self {
            keys: Vec::with_capacity(k),
            counts: Vec::with_capacity(k),
            errors: Vec::with_capacity(k),
            map: FastMap::with_capacity(k),
            block_min: Vec::with_capacity(blocks),
            block_argmin: Vec::with_capacity(blocks),
            dirty: Vec::with_capacity(blocks),
            k,
            n: 0,
        }
    }

    /// Count of the current minimum counter (0 while under-full).
    /// Repairs nothing: dirty blocks are rescanned on the fly.
    pub fn min_count(&self) -> u64 {
        if self.keys.len() < self.k {
            return 0;
        }
        let mut min = u64::MAX;
        for b in 0..self.block_min.len() {
            let v = if self.dirty[b] { self.scan_block(b).0 } else { self.block_min[b] };
            min = min.min(v);
        }
        min
    }

    /// True minimum `(count, slot)` of block `b` by scanning its counts.
    #[inline]
    fn scan_block(&self, b: usize) -> (u64, usize) {
        let start = b * BLOCK;
        let end = (start + BLOCK).min(self.counts.len());
        let mut min = self.counts[start];
        let mut argmin = start;
        for s in start + 1..end {
            // SAFETY: `s < end <= counts.len()`.
            let c = unsafe { *self.counts.get_unchecked(s) };
            if c < min {
                min = c;
                argmin = s;
            }
        }
        (min, argmin)
    }

    /// Restore block `b`'s exact `(min, argmin)` cache.
    #[inline]
    fn repair_block(&mut self, b: usize) {
        let (min, argmin) = self.scan_block(b);
        self.block_min[b] = min;
        self.block_argmin[b] = argmin as u32;
        self.dirty[b] = false;
    }

    /// Locate the global minimum slot, repairing stale blocks on the
    /// way. Returns `(block, slot)`; requires a full summary.
    ///
    /// Dirty caches are lower bounds, so whenever the smallest cached
    /// value belongs to a dirty block the true global minimum might
    /// hide behind it — repair (which can only raise the cache) and
    /// rescan. The first time the smallest cache is clean, it is the
    /// true minimum. Each repair retires a dirtying increment, so the
    /// loop is `O(1)` amortized against the update stream.
    #[inline]
    fn locate_min(&mut self) -> (usize, usize) {
        debug_assert_eq!(self.keys.len(), self.k);
        loop {
            // Branch-light linear sweep of the k/64-entry min array.
            let mut best = 0usize;
            let mut best_v = self.block_min[0];
            for b in 1..self.block_min.len() {
                // SAFETY: `b < block_min.len()`.
                let v = unsafe { *self.block_min.get_unchecked(b) };
                if v < best_v {
                    best_v = v;
                    best = b;
                }
            }
            if !self.dirty[best] {
                return (best, self.block_argmin[best] as usize);
            }
            self.repair_block(best);
        }
    }

    /// Bump a monitored slot by `weight`, dirtying its block's cache
    /// only when the cached argmin was the slot touched.
    #[inline]
    fn bump(&mut self, slot: usize, weight: u64) {
        // SAFETY: `slot` comes from the map, which only stores ids of
        // live slots in `[0, keys.len())`.
        unsafe {
            *self.counts.get_unchecked_mut(slot) += weight;
        }
        let b = slot / BLOCK;
        if self.block_argmin[b] as usize == slot {
            self.dirty[b] = true;
        }
    }

    /// Adopt `item` into a spare slot with an exact count (`err = 0`).
    #[inline]
    fn adopt(&mut self, item: u64, weight: u64) {
        let slot = self.keys.len();
        self.keys.push(item);
        self.counts.push(weight);
        self.errors.push(0);
        self.map.insert(item, slot as u32);
        let b = slot / BLOCK;
        if b == self.block_min.len() {
            // First slot of a fresh block seeds its cache exactly.
            self.block_min.push(weight);
            self.block_argmin.push(slot as u32);
            self.dirty.push(false);
        } else if weight < self.block_min[b] {
            // Clean: the cache stays exact. Dirty: it stays a valid
            // lower bound (min(cache, weight) ≤ min(true_min, weight)).
            self.block_min[b] = weight;
            self.block_argmin[b] = slot as u32;
        }
    }

    /// Evict the global minimum counter in favor of `item` (weighted
    /// Space Saving rule), then repair the one block touched.
    #[inline]
    fn evict_into(&mut self, item: u64, weight: u64) {
        let (b, slot) = self.locate_min();
        let evicted = self.keys[slot];
        self.map.remove(evicted);
        self.map.insert(item, slot as u32);
        self.keys[slot] = item;
        self.errors[slot] = self.counts[slot];
        self.counts[slot] += weight;
        self.repair_block(b);
    }

    /// Prefetch the slot's `counts` cacheline (stage two of the
    /// [`offer_all`](FrequencySummary::offer_all) software pipeline;
    /// stage one is the map-probe prefetch).
    #[inline]
    fn prefetch_slot(&self, slot: usize) {
        #[cfg(target_arch = "x86_64")]
        unsafe {
            use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
            _mm_prefetch(self.counts.as_ptr().add(slot) as *const i8, _MM_HINT_T0);
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            let _ = slot;
        }
    }

    /// Walk the whole structure and panic on any broken invariant: the
    /// parallel arrays in sync, the item map exact, mass conserved, and
    /// the block-min cache sound — clean blocks cache exactly their
    /// true `(min, argmin)`; dirty blocks cache a lower bound; and the
    /// derived [`CompactSummary::min_count`] equals the true global
    /// minimum. `O(k)`.
    ///
    /// Test/debug aid (the cross-structure property suite calls it
    /// after every mutation burst); not on any hot path.
    pub fn check_consistency(&self) {
        let len = self.keys.len();
        assert!(len <= self.k, "more slots than budget");
        assert_eq!(self.counts.len(), len, "counts out of step");
        assert_eq!(self.errors.len(), len, "errors out of step");
        assert_eq!(self.map.len(), len, "item map size mismatch");
        assert_eq!(self.block_min.len(), len.div_ceil(BLOCK), "block count");
        assert_eq!(self.block_min.len(), self.block_argmin.len());
        assert_eq!(self.block_min.len(), self.dirty.len());
        let mut mass = 0u64;
        for s in 0..len {
            assert_eq!(self.map.get(self.keys[s]), Some(s as u32), "map out of sync");
            assert!(self.errors[s] <= self.counts[s], "err exceeds count");
            mass += self.counts[s];
        }
        assert_eq!(mass, self.n, "mass not conserved");
        let mut true_min = u64::MAX;
        for b in 0..self.block_min.len() {
            let (min, _) = self.scan_block(b);
            true_min = true_min.min(min);
            if self.dirty[b] {
                assert!(
                    self.block_min[b] <= min,
                    "dirty block {b}: cache {} above true min {min}",
                    self.block_min[b]
                );
            } else {
                assert_eq!(self.block_min[b], min, "clean block {b}: stale min");
                let am = self.block_argmin[b] as usize;
                assert!(am / BLOCK == b && am < len, "block {b}: argmin out of range");
                assert_eq!(self.counts[am], min, "block {b}: argmin not minimal");
            }
        }
        if len == self.k {
            assert_eq!(self.min_count(), true_min, "min_count != true min");
        } else {
            assert_eq!(self.min_count(), 0, "under-full min_count");
        }
    }
}

impl FrequencySummary for CompactSummary {
    fn capacity(&self) -> usize {
        self.k
    }

    /// Process one stream item — the Space Saving update rule over the
    /// SoA layout.
    ///
    /// # Example
    ///
    /// ```
    /// use pss::summary::{CompactSummary, FrequencySummary};
    ///
    /// let mut s = CompactSummary::new(2);
    /// for &item in &[1u64, 1, 2, 3] {
    ///     s.offer(item);
    /// }
    /// assert_eq!(s.processed(), 4);
    /// assert_eq!(s.estimate(1), Some(2));
    /// // 3 evicted the minimum counter (2, count 1): f̂ = 2, err = 1 —
    /// // so f ≤ f̂ ≤ f + n/k holds for every monitored item.
    /// assert_eq!(s.estimate(2), None);
    /// assert_eq!(s.estimate(3), Some(2));
    /// ```
    #[inline]
    fn offer(&mut self, item: u64) {
        self.offer_weighted(item, 1);
    }

    #[inline]
    fn offer_weighted(&mut self, item: u64, weight: u64) {
        if weight == 0 {
            return;
        }
        self.n += weight;
        if let Some(slot) = self.map.get(item) {
            // Monitored: one counter bump, one block-cache check.
            self.bump(slot as usize, weight);
        } else if self.keys.len() < self.k {
            // Spare counter available: adopt with f̂ = weight exactly.
            self.adopt(item, weight);
        } else {
            // One eviction amortized over the run: the new item inherits
            // min+weight with err = min.
            self.evict_into(item, weight);
        }
    }

    fn offer_all(&mut self, items: &[u64]) {
        // Two-stage software pipeline. Far stage: hash the item 8 ahead
        // and pull its map probe line into L1 (as the other structures
        // do). Near stage: by 4 items ahead that line is resident, so a
        // cheap probe resolves the slot and prefetches its `counts`
        // word — the second cacheline the update will touch. The probe
        // result is *not* reused (an eviction in between could remap
        // the item); only the prefetch side effect is kept.
        const MAP_AHEAD: usize = 8;
        const SLOT_AHEAD: usize = 4;
        for i in 0..items.len() {
            if let Some(&far) = items.get(i + MAP_AHEAD) {
                self.map.prefetch(far);
            }
            if let Some(&near) = items.get(i + SLOT_AHEAD) {
                if let Some(slot) = self.map.get(near) {
                    self.prefetch_slot(slot as usize);
                }
            }
            self.offer(items[i]);
        }
    }

    fn processed(&self) -> u64 {
        self.n
    }

    fn counters(&self) -> Vec<Counter> {
        (0..self.keys.len())
            .map(|s| Counter { item: self.keys[s], count: self.counts[s], err: self.errors[s] })
            .collect()
    }

    fn estimate(&self, item: u64) -> Option<u64> {
        self.map.get(item).map(|s| self.counts[s as usize])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::summary::space_saving::SpaceSaving;
    use crate::summary::traits::testutil::check_invariants;
    use crate::util::SplitMix64;

    #[test]
    fn classic_example() {
        let (a, b, c) = (1u64, 2, 3);
        let mut ss = CompactSummary::new(2);
        ss.offer_all(&[a, a, b, c]);
        assert_eq!(ss.estimate(a), Some(2));
        assert_eq!(ss.estimate(b), None);
        assert_eq!(ss.estimate(c), Some(2));
        let cc = ss.counters().into_iter().find(|x| x.item == c).unwrap();
        assert_eq!(cc.err, 1);
        ss.check_consistency();
    }

    #[test]
    fn exact_when_distinct_items_fit() {
        let mut ss = CompactSummary::new(100);
        let items: Vec<u64> = (0..50).flat_map(|i| vec![i; (i + 1) as usize]).collect();
        ss.offer_all(&items);
        for i in 0..50u64 {
            assert_eq!(ss.estimate(i), Some(i + 1));
        }
        assert!(ss.counters().iter().all(|c| c.err == 0));
        ss.check_consistency();
    }

    #[test]
    fn invariants_uniform() {
        let mut rng = SplitMix64::new(1);
        let items: Vec<u64> = (0..20_000).map(|_| rng.next_below(500)).collect();
        check_invariants(&mut CompactSummary::new(64), &items);
    }

    #[test]
    fn invariants_heavy_skew() {
        let mut rng = SplitMix64::new(2);
        let items: Vec<u64> = (0..30_000)
            .map(|_| {
                if rng.next_f64() < 0.8 {
                    rng.next_below(5)
                } else {
                    100 + rng.next_below(100_000)
                }
            })
            .collect();
        check_invariants(&mut CompactSummary::new(128), &items);
    }

    #[test]
    fn invariants_adversarial_rotation() {
        // Round-robin over exactly k+1 items: every offer beyond warmup
        // is an eviction — the worst case for the block-min cache.
        let k = 33;
        let items: Vec<u64> = (0..50_000u64).map(|i| i % (k as u64 + 1)).collect();
        check_invariants(&mut CompactSummary::new(k), &items);
    }

    #[test]
    fn invariants_above_one_block() {
        // k spanning several blocks, stream overflowing the budget, so
        // evictions exercise the cross-block min sweep.
        let mut rng = SplitMix64::new(3);
        let items: Vec<u64> = (0..60_000).map(|_| rng.next_below(2_000)).collect();
        check_invariants(&mut CompactSummary::new(300), &items);
    }

    #[test]
    fn k_equals_one() {
        let mut ss = CompactSummary::new(1);
        ss.offer_all(&[7, 7, 7, 8, 7]);
        let c = ss.counters()[0];
        assert_eq!(c.item, 7);
        assert_eq!(c.count, 5);
        assert!(c.count - c.err <= 4);
        ss.check_consistency();
    }

    #[test]
    fn block_cache_consistent_under_random_churn() {
        // Dirty/repair bookkeeping checked after every single update,
        // across block-boundary sizes of k.
        for k in [1usize, 2, 63, 64, 65, 130] {
            let mut ss = CompactSummary::new(k);
            let mut rng = SplitMix64::new(k as u64);
            for _ in 0..5_000 {
                let item = rng.next_below(3 * k as u64 + 2);
                let w = if rng.next_f64() < 0.5 { 1 } else { 1 + rng.next_below(9) };
                ss.offer_weighted(item, w);
                ss.check_consistency();
            }
        }
    }

    #[test]
    fn min_count_tracks_true_minimum() {
        let mut ss = CompactSummary::new(3);
        assert_eq!(ss.min_count(), 0);
        ss.offer_all(&[1, 1, 2, 2, 2, 3]);
        assert_eq!(ss.min_count(), 1);
        ss.offer_all(&[3, 3]);
        assert_eq!(ss.min_count(), 2);
        ss.check_consistency();
    }

    #[test]
    fn weighted_updates_match_replayed_offers_when_monitored() {
        let mut a = CompactSummary::new(8);
        let mut b = CompactSummary::new(8);
        for (item, w) in [(1u64, 5u64), (2, 3), (1, 4), (3, 1)] {
            a.offer_weighted(item, w);
            for _ in 0..w {
                b.offer(item);
            }
        }
        assert_eq!(a.processed(), b.processed());
        for item in [1u64, 2, 3] {
            assert_eq!(a.estimate(item), b.estimate(item), "item {item}");
        }
        a.offer_weighted(9, 0); // no-op
        assert_eq!(a.processed(), 13);
        assert_eq!(a.estimate(9), None);
        a.check_consistency();
    }

    #[test]
    fn weighted_eviction_inherits_min_and_conserves_mass() {
        let mut ss = CompactSummary::new(2);
        ss.offer_weighted(1, 4);
        ss.offer_weighted(2, 3);
        ss.offer_weighted(3, 5); // evicts 2 (min 3)
        assert_eq!(ss.estimate(2), None);
        let c = ss.counters().into_iter().find(|c| c.item == 3).unwrap();
        assert_eq!(c.count, 8); // min 3 + weight 5
        assert_eq!(c.err, 3); // inherited min
        let total: u64 = ss.counters().iter().map(|c| c.count).sum();
        assert_eq!(total, ss.processed());
        ss.check_consistency();
    }

    #[test]
    fn agrees_with_heap_variant_on_count_multisets() {
        // Same update rule as the heap variant: eviction may pick a
        // different minimal victim, but the multiset of counter values
        // evolves identically.
        let mut rng = SplitMix64::new(8);
        let items: Vec<u64> = (0..50_000).map(|_| rng.next_below(200)).collect();
        let mut a = SpaceSaving::new(32);
        let mut b = CompactSummary::new(32);
        a.offer_all(&items);
        b.offer_all(&items);
        let mut ca: Vec<u64> = a.counters().iter().map(|c| c.count).collect();
        let mut cb: Vec<u64> = b.counters().iter().map(|c| c.count).collect();
        ca.sort_unstable();
        cb.sort_unstable();
        assert_eq!(ca, cb);
        b.check_consistency();
    }

    #[test]
    fn freeze_orders_ascending() {
        let mut ss = CompactSummary::new(16);
        let mut rng = SplitMix64::new(9);
        for _ in 0..10_000 {
            ss.offer(rng.next_below(40));
        }
        let s = ss.freeze();
        assert_eq!(s.n(), 10_000);
        assert!(s.counters().windows(2).all(|w| w[0].count <= w[1].count));
    }
}
