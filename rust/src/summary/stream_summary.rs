//! `StreamSummary` — Metwally's bucket-list Space Saving structure:
//! `O(1)` amortized per item.
//!
//! Buckets hold the set of counters sharing one exact count value and are
//! kept in a doubly-linked list sorted by count; incrementing a counter
//! detaches it from its bucket and attaches it to the successor bucket
//! (creating/destroying buckets at the seam). Everything is arena-backed
//! (`Vec` + `u32` links, `NIL = u32::MAX`) — no per-item allocation, no
//! pointer chasing across heap objects.
//!
//! This is the structure the original Space Saving paper describes; the
//! heap variant ([`SpaceSaving`]) trades a `log k` factor for simpler
//! memory traffic. `bench_space_saving` measures both.
//!
//! [`SpaceSaving`]: super::space_saving::SpaceSaving

use super::counter::Counter;
use super::traits::FrequencySummary;
use crate::util::FastMap;

const NIL: u32 = u32::MAX;

#[derive(Debug, Clone, Copy)]
struct CNode {
    item: u64,
    count: u64,
    err: u64,
    /// prev/next counter within the same bucket.
    prev: u32,
    next: u32,
    /// Owning bucket index.
    bucket: u32,
}

#[derive(Debug, Clone, Copy)]
struct BNode {
    count: u64,
    /// First counter in this bucket.
    head: u32,
    /// prev/next bucket in ascending-count order.
    prev: u32,
    next: u32,
}

/// Space Saving over Metwally's Stream-Summary structure.
#[derive(Debug, Clone)]
pub struct StreamSummary {
    counters: Vec<CNode>,
    buckets: Vec<BNode>,
    /// Recycled bucket indices.
    free_buckets: Vec<u32>,
    /// Bucket with the minimum count (list head); NIL while empty.
    min_bucket: u32,
    map: FastMap,
    k: usize,
    n: u64,
}

impl StreamSummary {
    /// Create a summary with `k` counters (`k >= 1`).
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "k must be at least 1");
        Self {
            counters: Vec::with_capacity(k),
            // Worst case: every counter in its own bucket, plus one
            // transient during increment.
            buckets: Vec::with_capacity(k + 1),
            free_buckets: Vec::new(),
            min_bucket: NIL,
            map: FastMap::with_capacity(k),
            k,
            n: 0,
        }
    }

    /// Count of the current minimum counter (0 while under-full).
    pub fn min_count(&self) -> u64 {
        if self.counters.len() < self.k || self.min_bucket == NIL {
            0
        } else {
            self.buckets[self.min_bucket as usize].count
        }
    }

    fn alloc_bucket(&mut self, count: u64, head: u32, prev: u32, next: u32) -> u32 {
        let node = BNode { count, head, prev, next };
        if let Some(i) = self.free_buckets.pop() {
            self.buckets[i as usize] = node;
            i
        } else {
            self.buckets.push(node);
            (self.buckets.len() - 1) as u32
        }
    }

    /// Detach counter `c` from its bucket's list (bucket bookkeeping —
    /// emptiness — handled by the caller).
    fn detach(&mut self, c: u32) {
        let (prev, next, bucket) = {
            let n = &self.counters[c as usize];
            (n.prev, n.next, n.bucket)
        };
        if prev != NIL {
            self.counters[prev as usize].next = next;
        } else {
            self.buckets[bucket as usize].head = next;
        }
        if next != NIL {
            self.counters[next as usize].prev = prev;
        }
    }

    /// Attach counter `c` at the front of bucket `b`.
    fn attach(&mut self, c: u32, b: u32) {
        let old_head = self.buckets[b as usize].head;
        {
            let n = &mut self.counters[c as usize];
            n.prev = NIL;
            n.next = old_head;
            n.bucket = b;
        }
        if old_head != NIL {
            self.counters[old_head as usize].prev = c;
        }
        self.buckets[b as usize].head = c;
    }

    /// Unlink an emptied bucket `b` from the bucket list and recycle it.
    fn release_bucket(&mut self, b: u32) {
        debug_assert_eq!(self.buckets[b as usize].head, NIL);
        let (prev, next) = {
            let n = &self.buckets[b as usize];
            (n.prev, n.next)
        };
        if prev != NIL {
            self.buckets[prev as usize].next = next;
        } else {
            self.min_bucket = next;
        }
        if next != NIL {
            self.buckets[next as usize].prev = prev;
        }
        self.free_buckets.push(b);
    }

    /// Move counter `c` from its bucket to the bucket for `count + w`
    /// (`w >= 1`). For `w == 1` (the per-item [`offer`] path) the walk
    /// degenerates to looking at the immediate successor bucket only;
    /// weighted runs from the batched ingest path may hop several
    /// buckets, still amortized by the run length they replace.
    ///
    /// [`offer`]: FrequencySummary::offer
    fn increment_by(&mut self, c: u32, w: u64) {
        let b = self.counters[c as usize].bucket;
        let new_count = self.buckets[b as usize].count + w;

        // Fast path: `c` is its bucket's only member and no successor
        // bucket is passed or matched — bump the bucket in place instead
        // of detach/attach/alloc/release. This is the steady state for a
        // dominant hot item (its singleton bucket rides far above the
        // rest), cutting the per-hit cost to two stores.
        {
            let node = &self.counters[c as usize];
            if node.prev == NIL && node.next == NIL {
                let next = self.buckets[b as usize].next;
                if next == NIL || self.buckets[next as usize].count > new_count {
                    self.buckets[b as usize].count = new_count;
                    self.counters[c as usize].count = new_count;
                    return;
                }
            }
        }

        self.detach(c);
        // Walk to the insertion point: the last bucket below `new_count`
        // (for w == 1 this loop body never runs).
        let mut prev = b;
        let mut next = self.buckets[b as usize].next;
        while next != NIL && self.buckets[next as usize].count < new_count {
            prev = next;
            next = self.buckets[next as usize].next;
        }

        let target = if next != NIL && self.buckets[next as usize].count == new_count {
            next
        } else {
            // Insert a fresh bucket between prev and next.
            let nb = self.alloc_bucket(new_count, NIL, prev, next);
            self.buckets[prev as usize].next = nb;
            if next != NIL {
                self.buckets[next as usize].prev = nb;
            }
            nb
        };
        self.attach(c, target);
        self.counters[c as usize].count = new_count;

        if self.buckets[b as usize].head == NIL {
            self.release_bucket(b);
        }
    }

    /// Insert a brand-new item with `count` (requires spare capacity).
    /// Per-item ingestion always inserts at `count == 1` (the list
    /// head); weighted runs may land anywhere, found by walking from the
    /// minimum bucket.
    fn insert_fresh(&mut self, item: u64, count: u64) {
        debug_assert!(self.counters.len() < self.k && count >= 1);
        let c = self.counters.len() as u32;
        self.counters.push(CNode {
            item,
            count,
            err: 0,
            prev: NIL,
            next: NIL,
            bucket: NIL,
        });
        // Walk to the insertion point (zero steps for count == 1).
        let mut prev = NIL;
        let mut cur = self.min_bucket;
        while cur != NIL && self.buckets[cur as usize].count < count {
            prev = cur;
            cur = self.buckets[cur as usize].next;
        }
        let target = if cur != NIL && self.buckets[cur as usize].count == count {
            cur
        } else {
            let nb = self.alloc_bucket(count, NIL, prev, cur);
            if prev != NIL {
                self.buckets[prev as usize].next = nb;
            } else {
                self.min_bucket = nb;
            }
            if cur != NIL {
                self.buckets[cur as usize].prev = nb;
            }
            nb
        };
        self.attach(c, target);
        self.map.insert(item, c);
    }

    /// Walk the whole structure and panic on any broken invariant:
    /// bucket counts strictly ascending, no empty bucket in the list,
    /// doubly-linked prev/next consistency on both lists, counter
    /// back-pointers and counts matching their bucket, every counter
    /// reachable, and the item map in sync. `O(k)`.
    ///
    /// Test/debug aid — the weighted-update property suite
    /// (`prop_weighted_bucket_list_invariants`) calls this after every
    /// update; it is not on any hot path.
    pub fn check_consistency(&self) {
        let mut b = self.min_bucket;
        let mut last = None::<u64>;
        let mut prev_b = NIL;
        let mut seen = 0usize;
        while b != NIL {
            let bn = &self.buckets[b as usize];
            assert!(bn.count >= 1, "zero-count bucket");
            if let Some(last) = last {
                assert!(bn.count > last, "buckets not strictly ascending");
            }
            assert_eq!(bn.prev, prev_b, "bucket prev link broken");
            assert_ne!(bn.head, NIL, "empty bucket in list");
            let mut c = bn.head;
            let mut prev_c = NIL;
            while c != NIL {
                let cn = &self.counters[c as usize];
                assert_eq!(cn.bucket, b, "counter bucket back-pointer wrong");
                assert_eq!(cn.count, bn.count, "counter count != bucket count");
                assert_eq!(cn.prev, prev_c, "counter prev link broken");
                assert_eq!(self.map.get(cn.item), Some(c), "item map out of sync");
                prev_c = c;
                seen += 1;
                c = cn.next;
            }
            last = Some(bn.count);
            prev_b = b;
            b = bn.next;
        }
        assert_eq!(seen, self.counters.len(), "counter outside the bucket list");
        assert_eq!(self.map.len(), self.counters.len(), "map size mismatch");
    }
}

impl FrequencySummary for StreamSummary {
    fn capacity(&self) -> usize {
        self.k
    }

    #[inline]
    fn offer(&mut self, item: u64) {
        self.offer_weighted(item, 1);
    }

    #[inline]
    fn offer_weighted(&mut self, item: u64, weight: u64) {
        if weight == 0 {
            return;
        }
        self.n += weight;
        if let Some(c) = self.map.get(item) {
            self.increment_by(c, weight);
        } else if self.counters.len() < self.k {
            self.insert_fresh(item, weight);
        } else {
            // Evict the head counter of the minimum bucket; the whole
            // run rides on this one eviction (err = old min).
            let c = self.buckets[self.min_bucket as usize].head;
            let node = &mut self.counters[c as usize];
            let evicted = node.item;
            node.err = node.count;
            node.item = item;
            self.map.remove(evicted);
            self.map.insert(item, c);
            self.increment_by(c, weight);
        }
    }

    fn offer_all(&mut self, items: &[u64]) {
        // Software pipelining: prefetch the hash slot a few items ahead —
        // the map probe is the dominant cache miss on high-entropy
        // streams (cf. the paper's own locality diagnosis, §4.4).
        const AHEAD: usize = 8;
        for i in 0..items.len() {
            if let Some(&next) = items.get(i + AHEAD) {
                self.map.prefetch(next);
            }
            self.offer(items[i]);
        }
    }

    fn processed(&self) -> u64 {
        self.n
    }

    fn counters(&self) -> Vec<Counter> {
        self.counters
            .iter()
            .map(|c| Counter { item: c.item, count: c.count, err: c.err })
            .collect()
    }

    fn estimate(&self, item: u64) -> Option<u64> {
        self.map.get(item).map(|c| self.counters[c as usize].count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::summary::space_saving::SpaceSaving;
    use crate::summary::traits::testutil::check_invariants;
    use crate::util::SplitMix64;

    #[test]
    fn bucket_list_stays_sorted_and_consistent() {
        let mut ss = StreamSummary::new(8);
        let mut rng = SplitMix64::new(5);
        for _ in 0..10_000 {
            ss.offer(rng.next_below(40));
            ss.check_consistency();
        }
    }

    #[test]
    fn invariants_uniform() {
        let mut rng = SplitMix64::new(6);
        let items: Vec<u64> = (0..20_000).map(|_| rng.next_below(500)).collect();
        check_invariants(&mut StreamSummary::new(64), &items);
    }

    #[test]
    fn invariants_skewed() {
        let mut rng = SplitMix64::new(7);
        let items: Vec<u64> = (0..30_000)
            .map(|_| {
                if rng.next_f64() < 0.7 {
                    rng.next_below(10)
                } else {
                    1000 + rng.next_below(1_000_000)
                }
            })
            .collect();
        check_invariants(&mut StreamSummary::new(256), &items);
    }

    #[test]
    fn agrees_with_heap_variant_exactly() {
        // Both implement the same update rule, so estimates must be
        // identical on identical input (eviction picks *a* min counter;
        // with distinct victims the multiset of counts still matches, so
        // compare count multisets plus monitored heavy items).
        let mut rng = SplitMix64::new(8);
        let items: Vec<u64> = (0..50_000).map(|_| rng.next_below(200)).collect();
        let mut a = SpaceSaving::new(32);
        let mut b = StreamSummary::new(32);
        a.offer_all(&items);
        b.offer_all(&items);
        let mut ca: Vec<u64> = a.counters().iter().map(|c| c.count).collect();
        let mut cb: Vec<u64> = b.counters().iter().map(|c| c.count).collect();
        ca.sort_unstable();
        cb.sort_unstable();
        assert_eq!(ca, cb);
    }

    #[test]
    fn k_equals_one() {
        let mut ss = StreamSummary::new(1);
        ss.offer_all(&[9, 9, 3, 9]);
        let c = ss.counters()[0];
        assert_eq!(c.item, 9);
        assert_eq!(c.count, 4);
    }

    #[test]
    fn weighted_updates_keep_bucket_list_sorted() {
        // Weighted runs hop buckets (unlike +1 increments); hammer the
        // structure with random runs and check the full invariant.
        let mut ss = StreamSummary::new(16);
        let mut rng = SplitMix64::new(9);
        let mut mass = 0u64;
        for _ in 0..5_000 {
            let item = rng.next_below(60);
            let w = 1 + rng.next_below(12);
            ss.offer_weighted(item, w);
            mass += w;
            ss.check_consistency();
        }
        assert_eq!(ss.processed(), mass);
        let total: u64 = ss.counters().iter().map(|c| c.count).sum();
        assert_eq!(total, mass, "weighted updates must conserve mass");
    }

    #[test]
    fn weighted_matches_replayed_offers_when_monitored() {
        let mut a = StreamSummary::new(8);
        let mut b = StreamSummary::new(8);
        for (item, w) in [(1u64, 7u64), (2, 2), (1, 3), (3, 9), (2, 1)] {
            a.offer_weighted(item, w);
            for _ in 0..w {
                b.offer(item);
            }
        }
        assert_eq!(a.processed(), b.processed());
        for item in [1u64, 2, 3] {
            assert_eq!(a.estimate(item), b.estimate(item), "item {item}");
        }
        a.offer_weighted(5, 0); // no-op
        assert_eq!(a.processed(), 22);
        assert_eq!(a.estimate(5), None);
    }

    #[test]
    fn weighted_eviction_inherits_min() {
        let mut ss = StreamSummary::new(2);
        ss.offer_weighted(1, 6);
        ss.offer_weighted(2, 4);
        ss.offer_weighted(3, 10); // evicts 2 (min 4)
        assert_eq!(ss.estimate(2), None);
        assert_eq!(ss.estimate(3), Some(14)); // 4 + 10
        let c3 = ss.counters().into_iter().find(|c| c.item == 3).unwrap();
        assert_eq!(c3.err, 4);
        ss.check_consistency();
    }

    #[test]
    fn exact_when_under_capacity() {
        let mut ss = StreamSummary::new(64);
        for i in 0..32u64 {
            for _ in 0..=i {
                ss.offer(i);
            }
        }
        for i in 0..32u64 {
            assert_eq!(ss.estimate(i), Some(i + 1));
        }
    }

    #[test]
    fn min_count_evolution() {
        let mut ss = StreamSummary::new(2);
        assert_eq!(ss.min_count(), 0);
        ss.offer(1);
        assert_eq!(ss.min_count(), 0); // under-full
        ss.offer(2);
        assert_eq!(ss.min_count(), 1);
        ss.offer(1);
        assert_eq!(ss.min_count(), 1);
        ss.offer(3); // evicts 2 -> count 2
        assert_eq!(ss.min_count(), 2);
    }
}
