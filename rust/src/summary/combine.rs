//! `Summary` — the frozen exchange format — and paper **Algorithm 2**
//! (`combine`), the user-defined reduction operator that merges two
//! stream summaries while preserving the Space Saving guarantees.
//!
//! The merge rule (Cafaro, Pulimeno, Tempesta — Information Sciences
//! 2016, recalled in the paper §3): with `m₁`, `m₂` the minimum counts of
//! the two inputs (0 if an input has spare counters),
//!
//! * item in both:      `f̂_C = f̂₁ + f̂₂`,   `ε_C = ε₁ + ε₂`
//! * item in S₁ only:   `f̂_C = f̂₁ + m₂`,  `ε_C = ε₁ + m₂`
//! * item in S₂ only:   `f̂_C = f̂₂ + m₁`,  `ε_C = ε₂ + m₁`
//!
//! then keep the `k` counters with the greatest frequencies. Correctness
//! and error bounds of the reduction are proved in [25] of the paper.

use super::counter::{sort_ascending, Counter};
use crate::util::FastMap;

/// A frozen stream summary: counters sorted **ascending** by frequency
/// (the order Algorithm 1 line 6 requires, making each input's minimum
/// its first counter), plus the stream-length and budget metadata the
/// reduction and the final prune need.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Summary {
    /// Counter budget `k` (shared by all summaries in one reduction).
    k: usize,
    /// Total items represented (sum over merged blocks).
    n: u64,
    /// Occupied counters, ascending by count.
    counters: Vec<Counter>,
}

impl Summary {
    /// Build from parts; sorts if needed. `counters.len() <= k`.
    pub fn new(k: usize, n: u64, mut counters: Vec<Counter>) -> Self {
        assert!(counters.len() <= k, "more counters than budget");
        if !counters.windows(2).all(|w| w[0].count <= w[1].count) {
            sort_ascending(&mut counters);
        }
        Self { k, n, counters }
    }

    /// An empty summary (identity element of [`Summary::combine`]).
    pub fn empty(k: usize) -> Self {
        Self { k, n: 0, counters: Vec::new() }
    }

    /// Counter budget.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Stream length this summary covers.
    pub fn n(&self) -> u64 {
        self.n
    }

    /// Counters, ascending by count.
    pub fn counters(&self) -> &[Counter] {
        &self.counters
    }

    /// Minimum frequency (`m` in Algorithm 2): the first counter's count,
    /// or 0 if the summary still has spare capacity — an under-full
    /// summary has seen every one of its items exactly.
    pub fn min_count(&self) -> u64 {
        if self.counters.len() < self.k {
            0
        } else {
            self.counters.first().map_or(0, |c| c.count)
        }
    }

    /// Estimated frequency of `item`, if present.
    pub fn estimate(&self, item: u64) -> Option<u64> {
        self.counters.iter().find(|c| c.item == item).map(|c| c.count)
    }

    /// The Space Saving error bound ε = ⌊n/k⌋: no estimate in this
    /// summary (or any combine-merge of summaries whose `n` sum to this
    /// `n`) over-estimates its true frequency by more than this.
    ///
    /// # Example
    ///
    /// ```
    /// use pss::summary::{FrequencySummary, SpaceSaving};
    ///
    /// // 100 items through k = 10 counters: ε = ⌊100/10⌋ = 10, so for
    /// // every monitored item  f ≤ f̂ ≤ f + 10.
    /// let mut ss = SpaceSaving::new(10);
    /// let items: Vec<u64> = (0..100).map(|i| i % 25).collect();
    /// ss.offer_all(&items);
    /// let summary = ss.freeze();
    /// assert_eq!(summary.epsilon(), 10);
    /// assert!(summary.counters().iter().all(|c| c.err <= summary.epsilon()));
    /// ```
    pub fn epsilon(&self) -> u64 {
        self.n / self.k as u64
    }

    /// Whether any counter is occupied.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty()
    }

    /// Serialized size in bytes when shipped between ranks (one record is
    /// item + count + err). Used by the network model.
    pub fn wire_bytes(&self) -> u64 {
        (self.counters.len() * 24 + 16) as u64
    }

    /// Paper **Algorithm 2**: merge two summaries into one that preserves
    /// the Space Saving bounds for the union of the underlying streams.
    pub fn combine(&self, other: &Summary) -> Summary {
        assert_eq!(self.k, other.k, "combine requires equal k");
        let k = self.k;
        let m1 = self.min_count();
        let m2 = other.min_count();

        // Index S2 by item (the paper's `S2.find`).
        let mut idx2 = FastMap::with_capacity(other.counters.len());
        for (i, c) in other.counters.iter().enumerate() {
            idx2.insert(c.item, i as u32);
        }
        let mut consumed2 = vec![false; other.counters.len()];

        // Three merge classes. `only1` and `only2` inherit their input's
        // (count, item) ascending order (a constant is added to every
        // count), so only `both` needs sorting — that drops the combine
        // from an O((2k) log 2k) full sort to O(|both| log |both|) plus
        // a linear 3-way merge (EXPERIMENTS.md §Perf change 5).
        let mut both: Vec<Counter> = Vec::new();
        let mut only1: Vec<Counter> = Vec::with_capacity(self.counters.len());

        // Scan S1 (Algorithm 2 lines 5–15).
        for c1 in &self.counters {
            if let Some(i2) = idx2.get(c1.item) {
                let c2 = other.counters[i2 as usize];
                consumed2[i2 as usize] = true; // the paper's S2.remove
                both.push(Counter {
                    item: c1.item,
                    count: c1.count + c2.count,
                    err: c1.err + c2.err,
                });
            } else {
                only1.push(Counter {
                    item: c1.item,
                    count: c1.count + m2,
                    err: c1.err + m2,
                });
            }
        }
        // Scan what remains of S2 (lines 16–20).
        let mut only2: Vec<Counter> = Vec::with_capacity(other.counters.len() - both.len());
        for (c2, used) in other.counters.iter().zip(&consumed2) {
            if !*used {
                only2.push(Counter {
                    item: c2.item,
                    count: c2.count + m1,
                    err: c2.err + m1,
                });
            }
        }
        sort_ascending(&mut both);

        // 3-way merge ascending by (count, item) — identical order to
        // the full sort — keeping the k greatest (line 21, PRUNE(k)).
        let total = both.len() + only1.len() + only2.len();
        let mut merged: Vec<Counter> = Vec::with_capacity(total.min(k));
        let skip = total.saturating_sub(k);
        let key = |c: &Counter| (c.count, c.item);
        let (mut i, mut j, mut l) = (0, 0, 0);
        for rank in 0..total {
            let pick_b = i < both.len()
                && (j >= only1.len() || key(&both[i]) <= key(&only1[j]))
                && (l >= only2.len() || key(&both[i]) <= key(&only2[l]));
            let pick_1 = !pick_b
                && j < only1.len()
                && (l >= only2.len() || key(&only1[j]) <= key(&only2[l]));
            let c = if pick_b {
                i += 1;
                both[i - 1]
            } else if pick_1 {
                j += 1;
                only1[j - 1]
            } else {
                l += 1;
                only2[l - 1]
            };
            if rank >= skip {
                merged.push(c);
            }
        }
        Summary { k, n: self.n + other.n, counters: merged }
    }

    /// Merge with a **key-disjoint** summary: concatenate the counter
    /// sets without Algorithm 2's `m₁`/`m₂` cross-charges.
    ///
    /// Valid only when the two summaries observed substreams with no
    /// item in common — the coordinator's keyed routing
    /// (`Routing::Keyed`, [`crate::util::shard_of`]) guarantees this by
    /// hashing every occurrence of an item to one home shard. An item
    /// absent from the *other* substream truly has frequency 0 there,
    /// so its estimate needs no `m` inflation; each counter keeps its
    /// home summary's exact `(count, err)`, and the merged per-counter
    /// bound is the **home shard's** `εᵢ = ⌊nᵢ/k⌋`, not the additive
    /// `⌊(n₁+n₂)/k⌋` of [`Summary::combine`].
    ///
    /// The result's budget is `k₁ + k₂` and no counter is pruned, so
    /// recall is preserved shard-locally: every item with
    /// `f > n_home/k_home` stays monitored. Two derived quantities are
    /// intentionally *not* meaningful on a disjoint-merged summary and
    /// must be taken from the per-shard parts instead (the query and
    /// window engines do):
    ///
    /// * [`Summary::epsilon`] (`n/(k₁+k₂)`) can understate the true
    ///   bound `maxᵢ ⌊nᵢ/k⌋` when shard masses are imbalanced;
    /// * [`Summary::min_count`] (the unmonitored-item upper bound) must
    ///   be the *home shard's* min count, not the concatenation's.
    pub fn combine_disjoint(&self, other: &Summary) -> Summary {
        merge_disjoint(&[self, other])
    }

    /// Final output filter (Algorithm 1 line 9, `PRUNED`): keep items
    /// whose estimate clears the k-majority threshold `⌊n/k⌋ + 1`, i.e.
    /// `f̂ > n/k`, reported descending by frequency.
    pub fn prune(&self, n: u64, k_majority: u64) -> Vec<Counter> {
        let thresh = n / k_majority;
        let mut out: Vec<Counter> = self
            .counters
            .iter()
            .copied()
            .filter(|c| c.count > thresh)
            .collect();
        out.reverse(); // ascending -> descending
        out
    }

    /// Top-`m` query (Metwally et al.'s *integrated* frequent + top-k
    /// computation, paper ref [21]): the `m` counters with the greatest
    /// estimates, descending.
    pub fn top_k(&self, m: usize) -> Vec<Counter> {
        let take = m.min(self.counters.len());
        let mut out: Vec<Counter> =
            self.counters[self.counters.len() - take..].to_vec();
        out.reverse();
        out
    }

    /// Guaranteed top-`m`: the longest prefix of [`Summary::top_k`]
    /// whose *order is certain* — element `i` is guaranteed to outrank
    /// element `i+1` when its guaranteed count (`f̂ᵢ − εᵢ`) is at least
    /// the next element's estimate `f̂ᵢ₊₁` (estimates never
    /// under-estimate, so `f̂ᵢ₊₁ ≥ fᵢ₊₁`). Metwally's "guaranteed
    /// top-k" criterion.
    pub fn top_k_guaranteed(&self, m: usize) -> Vec<Counter> {
        let cand = self.top_k(m.saturating_add(1));
        let mut out = Vec::with_capacity(m.min(cand.len()));
        for i in 0..m.min(cand.len()) {
            let next_est = cand.get(i + 1).map_or(0, |c| c.count);
            if cand[i].guaranteed() >= next_est {
                out.push(cand[i]);
            } else {
                break;
            }
        }
        out
    }

    /// Guaranteed-frequent subset: items whose *lower bound* clears the
    /// threshold (no false positive possible, used when the offline
    /// verification pass is unavailable).
    pub fn prune_guaranteed(&self, n: u64, k_majority: u64) -> Vec<Counter> {
        let thresh = n / k_majority;
        let mut out: Vec<Counter> = self
            .counters
            .iter()
            .copied()
            .filter(|c| c.guaranteed() > thresh)
            .collect();
        out.reverse();
        out
    }
}

/// N-way [`Summary::combine_disjoint`]: merge summaries of pairwise
/// key-disjoint substreams (one per keyed-routing shard) by
/// concatenation — `n = Σnᵢ`, budget `Σkᵢ`, every counter kept with its
/// home `(count, err)` intact. See [`Summary::combine_disjoint`] for
/// the bound semantics (and the derived quantities the caller must take
/// per-shard instead). Debug builds assert the disjointness
/// precondition.
pub fn merge_disjoint(parts: &[&Summary]) -> Summary {
    assert!(!parts.is_empty(), "nothing to merge");
    #[cfg(debug_assertions)]
    {
        let mut seen = std::collections::HashSet::new();
        for p in parts {
            for c in p.counters() {
                assert!(seen.insert(c.item), "item {} in two disjoint parts", c.item);
            }
        }
    }
    let k = parts.iter().map(|p| p.k()).sum();
    let n = parts.iter().map(|p| p.n()).sum();
    let mut counters =
        Vec::with_capacity(parts.iter().map(|p| p.counters().len()).sum());
    for p in parts {
        counters.extend_from_slice(p.counters());
    }
    Summary::new(k, n, counters)
}

/// Fold **exact** extra mass into a summary: for each `(item, weight)`
/// in `extras` (weights must be > 0 to matter; zero entries are
/// skipped), add `weight` to the item's counter — keeping its `err`
/// untouched, since the added mass is an exact count — or insert a
/// fresh counter if the item is unmonitored. `n` grows by the folded
/// mass.
///
/// `history_bound(item)` is consulted only on inserts: it must upper-
/// bound the item's true count in the summary's *underlying* streams
/// (history the structure may have evicted). The inserted counter is
/// `count = weight + b, err = b` with `b = history_bound(item)`, which
/// preserves both Space Saving invariants — `count ≥ f` (the evicted
/// history is at most `b`) and `count − err ≤ f` (the exact mass is a
/// true lower bound). Callers that know an item has no untracked
/// history pass `|_| 0`; the engines pass the item's **home shard**
/// `min_count()` (the Space Saving upper bound for an unmonitored
/// item). For already-monitored items the bound is ignored — their
/// history is tracked by the counter itself.
///
/// This is the read-side recombination step of the keyed-adaptive
/// hot-key tier: split-key occurrences are counted exactly in
/// per-shard side tables (never entering any Space Saving structure),
/// and after the disjoint concatenation the engines fold those
/// partials back in here. The resulting estimate for a split key is
/// `home-shard estimate + Σ exact partials`, so its over-estimation is
/// still bounded by the home shard's ε alone — the max-per-shard bound
/// `maxᵢ ⌊nᵢ/k⌋` survives the split (`nᵢ` = the Space Saving mass of
/// shard `i`, which *excludes* split mass; `min_count ≤ εᵢ` covers the
/// inserted case).
///
/// The budget is widened to fit inserted counters when needed (the
/// disjoint-merge budget `Σkᵢ` already exceeds the counter population,
/// but a summary saturated at `k` counters plus a never-monitored
/// split key would otherwise violate `len ≤ k`).
pub fn absorb_exact(
    summary: &Summary,
    extras: &[(u64, u64)],
    history_bound: impl Fn(u64) -> u64,
) -> Summary {
    let mut counters = summary.counters().to_vec();
    let mut n = summary.n();
    for &(item, weight) in extras {
        if weight == 0 {
            continue;
        }
        n += weight;
        match counters.iter_mut().find(|c| c.item == item) {
            Some(c) => c.count += weight,
            None => {
                let b = history_bound(item);
                counters.push(Counter { item, count: weight + b, err: b });
            }
        }
    }
    let k = summary.k().max(counters.len());
    Summary::new(k, n, counters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::summary::space_saving::SpaceSaving;
    use crate::summary::traits::FrequencySummary;
    use crate::util::SplitMix64;
    use std::collections::HashMap;

    fn summarize(items: &[u64], k: usize) -> Summary {
        let mut ss = SpaceSaving::new(k);
        ss.offer_all(items);
        ss.freeze()
    }

    fn truth(items: &[u64]) -> HashMap<u64, u64> {
        let mut t = HashMap::new();
        for &i in items {
            *t.entry(i).or_default() += 1;
        }
        t
    }

    #[test]
    fn combine_disjoint_underfull_is_exact() {
        let s1 = summarize(&[1, 1, 2], 8);
        let s2 = summarize(&[3, 3, 3, 4], 8);
        let c = s1.combine(&s2);
        assert_eq!(c.n(), 7);
        // Both inputs under-full => m1 = m2 = 0 => exact union.
        assert_eq!(c.estimate(1), Some(2));
        assert_eq!(c.estimate(3), Some(3));
        assert_eq!(c.estimate(4), Some(1));
    }

    #[test]
    fn combine_overlapping_sums() {
        let s1 = summarize(&[1, 1, 2, 2, 2], 8);
        let s2 = summarize(&[1, 2, 2], 8);
        let c = s1.combine(&s2);
        assert_eq!(c.estimate(1), Some(3));
        assert_eq!(c.estimate(2), Some(5));
    }

    #[test]
    fn combine_identity() {
        let s = summarize(&[5, 5, 6, 7, 7, 7], 4);
        let e = Summary::empty(4);
        assert_eq!(s.combine(&e).counters(), s.counters());
        assert_eq!(e.combine(&s).counters(), s.counters());
    }

    #[test]
    fn combine_commutative_in_estimates() {
        let mut rng = SplitMix64::new(21);
        let a: Vec<u64> = (0..5_000).map(|_| rng.next_below(300)).collect();
        let b: Vec<u64> = (0..5_000).map(|_| rng.next_below(300)).collect();
        let (sa, sb) = (summarize(&a, 64), summarize(&b, 64));
        let ab = sa.combine(&sb);
        let ba = sb.combine(&sa);
        let mut ca: Vec<_> = ab.counters().to_vec();
        let mut cb: Vec<_> = ba.counters().to_vec();
        ca.sort_unstable_by_key(|c| c.item);
        cb.sort_unstable_by_key(|c| c.item);
        assert_eq!(ca, cb);
    }

    #[test]
    fn combined_bounds_hold() {
        // The central theorem: after combining, for every monitored item
        // count - err <= f_true <= count, and every item with
        // f > (n1+n2)/k is monitored.
        let mut rng = SplitMix64::new(22);
        for trial in 0..20 {
            let k = 32;
            let a: Vec<u64> = (0..8_000)
                .map(|_| {
                    if rng.next_f64() < 0.6 {
                        rng.next_below(8)
                    } else {
                        rng.next_below(4_000)
                    }
                })
                .collect();
            let b: Vec<u64> = (0..8_000)
                .map(|_| {
                    if rng.next_f64() < 0.6 {
                        rng.next_below(8)
                    } else {
                        5_000 + rng.next_below(4_000)
                    }
                })
                .collect();
            let c = summarize(&a, k).combine(&summarize(&b, k));

            let mut all = a.clone();
            all.extend_from_slice(&b);
            let t = truth(&all);
            for ctr in c.counters() {
                let f = t.get(&ctr.item).copied().unwrap_or(0);
                assert!(ctr.count >= f, "trial {trial}: under-estimate");
                assert!(
                    ctr.count - ctr.err <= f,
                    "trial {trial}: error bound broken: item {} f̂={} ε={} f={}",
                    ctr.item,
                    ctr.count,
                    ctr.err,
                    f
                );
            }
            let monitored: std::collections::HashSet<u64> =
                c.counters().iter().map(|x| x.item).collect();
            let thresh = (all.len() as u64) / (k as u64);
            for (item, f) in &t {
                if *f > thresh {
                    assert!(monitored.contains(item), "trial {trial}: lost {item}");
                }
            }
        }
    }

    #[test]
    fn prune_filters_threshold() {
        let s = Summary::new(
            4,
            100,
            vec![
                Counter { item: 1, count: 5, err: 0 },
                Counter { item: 2, count: 26, err: 0 },
                Counter { item: 3, count: 60, err: 1 },
            ],
        );
        // k-majority with k=4: threshold 100/4 = 25, need f̂ > 25.
        let out = s.prune(100, 4);
        assert_eq!(out.iter().map(|c| c.item).collect::<Vec<_>>(), vec![3, 2]);
        // Guaranteed: item 2 guaranteed 26 > 25 yes; item 3: 59 > 25 yes.
        let g = s.prune_guaranteed(100, 4);
        assert_eq!(g.len(), 2);
    }

    #[test]
    fn combine_truncates_to_k_greatest() {
        let s1 = summarize(&[1, 1, 1, 2, 2, 3], 3);
        let s2 = summarize(&[4, 4, 4, 4, 5, 6], 3);
        let c = s1.combine(&s2);
        assert!(c.counters().len() <= 3);
        // Highest-frequency survivors must include 4 (f̂>=4) and 1 (f̂>=3).
        assert!(c.estimate(4).is_some());
        assert!(c.estimate(1).is_some());
    }

    #[test]
    fn top_k_returns_greatest_descending() {
        let s = summarize(&[1, 1, 1, 2, 2, 3, 3, 3, 3], 8);
        let t = s.top_k(2);
        assert_eq!(t.iter().map(|c| c.item).collect::<Vec<_>>(), vec![3, 1]);
        assert!(s.top_k(100).len() == 3, "clamps to occupied counters");
    }

    #[test]
    fn top_k_guaranteed_stops_at_uncertain_order() {
        // Exact summary (err 0): full order is guaranteed.
        let s = summarize(&[1, 1, 1, 2, 2, 3], 8);
        assert_eq!(s.top_k_guaranteed(3).len(), 3);

        // Uncertain: item with large err cannot be guaranteed above the
        // next estimate.
        let s = Summary::new(
            4,
            20,
            vec![
                Counter { item: 10, count: 10, err: 0 },
                Counter { item: 20, count: 7, err: 6 }, // guaranteed 1
                Counter { item: 30, count: 3, err: 0 },
            ],
        );
        let g = s.top_k_guaranteed(3);
        // 10 (guaranteed 10 >= 7) is certain; 20 (guaranteed 1 < 3) is not.
        assert_eq!(g.iter().map(|c| c.item).collect::<Vec<_>>(), vec![10]);
    }

    #[test]
    fn top_k_guaranteed_under_merge() {
        let mut rng = SplitMix64::new(77);
        let a: Vec<u64> = (0..6_000).map(|_| rng.next_below(40)).collect();
        let b: Vec<u64> = (0..6_000).map(|_| rng.next_below(40)).collect();
        let merged = summarize(&a, 16).combine(&summarize(&b, 16));
        let t = truth(&{
            let mut all = a.clone();
            all.extend_from_slice(&b);
            all
        });
        // The guaranteed ranking must agree with the true ranking.
        let g = merged.top_k_guaranteed(5);
        let mut true_rank: Vec<(u64, u64)> =
            t.iter().map(|(i, f)| (*f, *i)).collect();
        true_rank.sort_unstable_by(|x, y| y.cmp(x));
        for (i, c) in g.iter().enumerate() {
            assert_eq!(c.item, true_rank[i].1, "guaranteed rank {i} wrong");
        }
    }

    #[test]
    fn wire_bytes_scales_with_len() {
        let s = summarize(&[1, 2, 3, 4], 8);
        assert_eq!(s.wire_bytes(), 4 * 24 + 16);
    }

    #[test]
    fn disjoint_merge_keeps_exact_per_shard_estimates() {
        // Keyed-style split: evens to shard A, odds to shard B. Both
        // overflow their budget, so Algorithm 2 would inflate the
        // other side's estimates by m; the disjoint merge must not.
        let mut rng = SplitMix64::new(5);
        let items: Vec<u64> = (0..20_000)
            .map(|_| {
                if rng.next_f64() < 0.5 {
                    rng.next_below(6)
                } else {
                    rng.next_below(3_000)
                }
            })
            .collect();
        let (mut a, mut b) = (SpaceSaving::new(32), SpaceSaving::new(32));
        for &it in &items {
            if it % 2 == 0 {
                a.offer(it);
            } else {
                b.offer(it);
            }
        }
        let (fa, fb) = (a.freeze(), b.freeze());
        let merged = fa.combine_disjoint(&fb);
        assert_eq!(merged.n(), items.len() as u64);
        assert_eq!(merged.k(), 64);
        assert_eq!(
            merged.counters().len(),
            fa.counters().len() + fb.counters().len()
        );
        // Every merged counter is bit-identical to its home counter.
        for c in merged.counters() {
            let home = if c.item % 2 == 0 { &fa } else { &fb };
            let orig = home
                .counters()
                .iter()
                .find(|h| h.item == c.item)
                .copied()
                .expect("counter kept");
            assert_eq!(*c, orig);
        }
        // The per-shard bound holds against truth — strictly tighter
        // than the additive combine bound when both shards are full.
        let t = truth(&items);
        for c in merged.counters() {
            let home_eps = if c.item % 2 == 0 { fa.epsilon() } else { fb.epsilon() };
            let f = t.get(&c.item).copied().unwrap_or(0);
            assert!(c.count >= f && c.count - f <= home_eps);
        }
    }

    #[test]
    fn merge_disjoint_many_parts_orders_and_sums() {
        let parts: Vec<Summary> = (0..5u64)
            .map(|s| summarize(&vec![s; (s + 1) as usize], 4))
            .collect();
        let refs: Vec<&Summary> = parts.iter().collect();
        let m = merge_disjoint(&refs);
        assert_eq!(m.n(), 1 + 2 + 3 + 4 + 5);
        assert_eq!(m.k(), 20);
        // Ascending by count after the concat sort.
        assert!(m.counters().windows(2).all(|w| w[0].count <= w[1].count));
        for s in 0..5u64 {
            assert_eq!(m.estimate(s), Some(s + 1));
        }
    }

    #[test]
    fn absorb_exact_adds_mass_without_err() {
        // Monitored item: count grows, err untouched. Unmonitored:
        // fresh exact counter. n grows by the folded mass; re-sorted.
        let s = Summary::new(
            4,
            100,
            vec![
                Counter { item: 1, count: 10, err: 2 },
                Counter { item: 2, count: 40, err: 0 },
            ],
        );
        let out = absorb_exact(&s, &[(1, 50), (9, 5), (3, 0)], |_| 0);
        assert_eq!(out.n(), 155);
        assert_eq!(out.estimate(1), Some(60));
        assert_eq!(out.estimate(9), Some(5));
        assert_eq!(out.estimate(3), None, "zero-weight entries are skipped");
        let c1 = out.counters().iter().find(|c| c.item == 1).unwrap();
        assert_eq!(c1.err, 2, "exact mass never inflates err");
        let c9 = out.counters().iter().find(|c| c.item == 9).unwrap();
        assert_eq!(c9.err, 0);
        assert!(out.counters().windows(2).all(|w| w[0].count <= w[1].count));
        // Budget widens only when the insert would overflow it.
        let full = Summary::new(
            2,
            10,
            vec![
                Counter { item: 1, count: 4, err: 0 },
                Counter { item: 2, count: 6, err: 0 },
            ],
        );
        let widened = absorb_exact(&full, &[(7, 3)], |_| 0);
        assert_eq!(widened.k(), 3);
        assert_eq!(widened.estimate(7), Some(3));
    }

    #[test]
    fn absorb_exact_history_bound_covers_evicted_keys() {
        // A split key whose pre-split history was evicted from its home
        // structure: inserting with only the exact mass would
        // under-estimate. The history bound (home min_count) restores
        // `f ≤ count` while `count − err` stays the exact lower bound.
        let s = Summary::new(
            2,
            20,
            vec![
                Counter { item: 1, count: 8, err: 3 },
                Counter { item: 2, count: 12, err: 0 },
            ],
        );
        // Key 9 had ≤ min_count(=8) evicted occurrences plus 5 exact.
        let out = absorb_exact(&s, &[(9, 5)], |_| s.min_count());
        let c9 = out.counters().iter().find(|c| c.item == 9).unwrap();
        assert_eq!(c9.count, 13, "exact mass + history bound");
        assert_eq!(c9.err, 8, "the bound is uncertain, the mass is not");
        assert_eq!(c9.guaranteed(), 5);
        assert_eq!(out.n(), 25, "n grows by the exact mass only");
        // Monitored items never consult the bound.
        let out = absorb_exact(&s, &[(1, 5)], |_| panic!("bound consulted"));
        assert_eq!(out.estimate(1), Some(13));
    }

    #[test]
    fn absorb_exact_after_disjoint_merge_bounds_hold() {
        // The hot-key recombination in miniature: shard A holds the
        // split key's pre-split history in its SS summary; both shards
        // hold exact split partials on the side. After merge + absorb,
        // the key's estimate must be (home estimate + Σ partials) and
        // its over-estimate still ≤ the home shard's ε.
        let mut a = SpaceSaving::new(4);
        // Overflow shard A so ε_A > 0: 2 appears 5×, filler 4..12 once.
        let stream_a: Vec<u64> = [vec![2u64; 5], (4..12).collect()].concat();
        a.offer_all(&stream_a);
        let mut b = SpaceSaving::new(4);
        b.offer_all(&[3, 3, 13]);
        let (fa, fb) = (a.freeze(), b.freeze());
        let merged = fa.combine_disjoint(&fb);
        // Split partials for key 2: 10 on "shard A", 12 on "shard B".
        let out = absorb_exact(&merged, &[(2, 10), (2, 12)], |_| fa.min_count());
        assert_eq!(out.n(), merged.n() + 22);
        let est2 = out.estimate(2).unwrap();
        let home2 = fa.estimate(2).unwrap();
        assert_eq!(est2, home2 + 22, "sum of exacts plus the home estimate");
        // True f(2) = 5 (SS stream) + 22 (split) = 27; over-estimate
        // bounded by the home shard's ε alone.
        assert!(est2 >= 27 && est2 - 27 <= fa.epsilon());
    }

    #[test]
    #[should_panic(expected = "in two disjoint parts")]
    #[cfg(debug_assertions)]
    fn merge_disjoint_rejects_overlap_in_debug() {
        let a = summarize(&[1, 1], 4);
        let b = summarize(&[1, 2], 4);
        let _ = merge_disjoint(&[&a, &b]);
    }
}
