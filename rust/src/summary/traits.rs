//! The `FrequencySummary` trait: what the parallel layers require of a
//! per-worker sequential summary structure.

use super::combine::Summary;
use super::counter::{sort_ascending, Counter};

/// A live, updatable frequency summary over a stream prefix.
pub trait FrequencySummary {
    /// Number of counters (the `k` in k-majority).
    fn capacity(&self) -> usize;

    /// Process one stream item (the paper's Space Saving update rule).
    fn offer(&mut self, item: u64);

    /// Total items processed so far.
    fn processed(&self) -> u64;

    /// Snapshot of all occupied counters, in no particular order.
    fn counters(&self) -> Vec<Counter>;

    /// Estimated frequency of `item`, if monitored.
    fn estimate(&self, item: u64) -> Option<u64>;

    /// Process `weight` occurrences of `item` in a single update — the
    /// weighted Space Saving rule the batched ingest path relies on
    /// ([`batch`](super::batch)):
    ///
    /// * monitored item — its counter gains `weight`;
    /// * spare capacity — adopt with `f̂ = weight`, `err = 0`;
    /// * otherwise — one min-eviction charges the whole run: the new
    ///   item inherits `f̂ = min + weight`, `err = min`.
    ///
    /// Each case increases the summary's total mass by exactly `weight`,
    /// `err` stays a bound on the pre-adoption history, and `f̂ − err`
    /// counts only real occurrences — so `f ≤ f̂ ≤ f + n/k` holds after
    /// any interleaving of weighted and unit updates. `weight == 0` is a
    /// no-op. The default replays [`FrequencySummary::offer`];
    /// implementations override it with an `O(1)`-per-run version.
    fn offer_weighted(&mut self, item: u64, weight: u64) {
        for _ in 0..weight {
            self.offer(item);
        }
    }

    /// Process a slice of items.
    fn offer_all(&mut self, items: &[u64]) {
        for &it in items {
            self.offer(it);
        }
    }

    /// Freeze into the exchange format: counters sorted ascending by
    /// frequency (paper Algorithm 1 line 6 — "sort local by counters'
    /// frequency in ascending order").
    fn freeze(&self) -> Summary {
        let mut counters = self.counters();
        sort_ascending(&mut counters);
        Summary::new(self.capacity(), self.processed(), counters)
    }
}

/// Invariant checks shared by the test suites of both implementations.
#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use std::collections::HashMap;

    /// Run `items` through `s` and assert every Space Saving invariant:
    /// 1. sum of counts == items processed,
    /// 2. counts never under-estimate, and over-estimate by at most `err`,
    /// 3. every item with f > n/k is reported (recall = 1),
    /// 4. at most k counters are used.
    pub fn check_invariants<S: FrequencySummary>(s: &mut S, items: &[u64]) {
        s.offer_all(items);
        let n = items.len() as u64;
        assert_eq!(s.processed(), n);

        let counters = s.counters();
        assert!(counters.len() <= s.capacity());
        assert_eq!(counters.iter().map(|c| c.count).sum::<u64>(), n);

        let mut truth: HashMap<u64, u64> = HashMap::new();
        for &it in items {
            *truth.entry(it).or_default() += 1;
        }
        for c in &counters {
            let f = truth.get(&c.item).copied().unwrap_or(0);
            assert!(c.count >= f, "under-estimate: item {} f̂={} f={}", c.item, c.count, f);
            assert!(
                c.count - c.err <= f,
                "err bound violated: item {} f̂={} err={} f={}",
                c.item,
                c.count,
                c.err,
                f
            );
        }

        let k = s.capacity() as u64;
        let thresh = n / k;
        let monitored: std::collections::HashSet<u64> =
            counters.iter().map(|c| c.item).collect();
        for (item, f) in &truth {
            if *f > thresh {
                assert!(monitored.contains(item), "missed frequent item {item} (f={f})");
            }
        }
    }
}
