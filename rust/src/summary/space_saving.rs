//! `SpaceSaving` — the sequential Space Saving algorithm (Metwally,
//! Agrawal, El Abbadi 2005/2006) with a slot-indexed binary min-heap.
//!
//! Layout: counters live in stable `slots`; the heap orders *slot ids* by
//! count, and `pos[slot]` tracks each slot's heap index. Heap swaps touch
//! only two small vectors — the item→slot hash map is updated solely on
//! eviction, which keeps the common paths (monitored-item increment, min
//! eviction) tight. Per-item cost is `O(log k)`; see [`StreamSummary`]
//! for the `O(1)` bucket-list alternative and `bench_space_saving` for
//! the measured comparison.
//!
//! [`StreamSummary`]: super::stream_summary::StreamSummary

use super::counter::Counter;
use super::traits::FrequencySummary;
use crate::util::FastMap;

/// Sequential Space Saving with `k` counters.
#[derive(Debug, Clone)]
pub struct SpaceSaving {
    /// Stable counter storage, indexed by slot id.
    slots: Vec<Counter>,
    /// Min-heap over slot ids, ordered by `slots[id].count`.
    heap: Vec<u32>,
    /// `pos[slot] == index of slot in heap`.
    pos: Vec<u32>,
    /// item id -> slot id.
    map: FastMap,
    /// Counter budget.
    k: usize,
    /// Items processed.
    n: u64,
}

impl SpaceSaving {
    /// Create a summary with `k` counters (`k >= 1`).
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "k must be at least 1");
        Self {
            slots: Vec::with_capacity(k),
            heap: Vec::with_capacity(k),
            pos: Vec::with_capacity(k),
            map: FastMap::with_capacity(k),
            k,
            n: 0,
        }
    }

    /// Count of the current minimum counter (0 while under-full).
    #[inline]
    pub fn min_count(&self) -> u64 {
        if self.slots.len() < self.k {
            0
        } else {
            self.slots[self.heap[0] as usize].count
        }
    }

    #[inline]
    fn count_of(&self, slot: u32) -> u64 {
        // SAFETY: slot ids are created densely in [0, slots.len()).
        unsafe { self.slots.get_unchecked(slot as usize).count }
    }

    /// Restore heap order downward from heap index `i` after the count at
    /// that position increased.
    #[inline]
    fn sift_down(&mut self, mut i: usize) {
        let len = self.heap.len();
        loop {
            let l = 2 * i + 1;
            if l >= len {
                return;
            }
            let r = l + 1;
            let mut smallest = l;
            if r < len && self.count_of(self.heap[r]) < self.count_of(self.heap[l]) {
                smallest = r;
            }
            if self.count_of(self.heap[smallest]) >= self.count_of(self.heap[i]) {
                return;
            }
            self.heap.swap(i, smallest);
            self.pos[self.heap[i] as usize] = i as u32;
            self.pos[self.heap[smallest] as usize] = smallest as u32;
            i = smallest;
        }
    }

    /// Restore heap order upward from heap index `i` (used on insertion;
    /// counts only ever increase afterwards, so up-sifting is insert-only).
    #[inline]
    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.count_of(self.heap[parent]) <= self.count_of(self.heap[i]) {
                return;
            }
            self.heap.swap(i, parent);
            self.pos[self.heap[i] as usize] = i as u32;
            self.pos[self.heap[parent] as usize] = parent as u32;
            i = parent;
        }
    }
}

impl FrequencySummary for SpaceSaving {
    fn capacity(&self) -> usize {
        self.k
    }

    #[inline]
    fn offer(&mut self, item: u64) {
        self.offer_weighted(item, 1);
    }

    #[inline]
    fn offer_weighted(&mut self, item: u64, weight: u64) {
        if weight == 0 {
            return;
        }
        self.n += weight;
        if let Some(slot) = self.map.get(item) {
            // Monitored: add the whole run and re-heapify downward.
            self.slots[slot as usize].count += weight;
            self.sift_down(self.pos[slot as usize] as usize);
        } else if self.slots.len() < self.k {
            // Spare counter available: adopt with f̂ = weight exactly.
            let slot = self.slots.len() as u32;
            self.slots.push(Counter { item, count: weight, err: 0 });
            self.heap.push(slot);
            self.pos.push((self.heap.len() - 1) as u32);
            self.map.insert(item, slot);
            self.sift_up(self.heap.len() - 1);
        } else {
            // One eviction amortized over the run: the new item inherits
            // min+weight with err = min.
            let slot = self.heap[0];
            let c = &mut self.slots[slot as usize];
            let evicted = c.item;
            c.err = c.count;
            c.count += weight;
            c.item = item;
            self.map.remove(evicted);
            self.map.insert(item, slot);
            self.sift_down(0);
        }
    }

    fn offer_all(&mut self, items: &[u64]) {
        // Software pipelining: prefetch the hash slot a few items ahead
        // (see StreamSummary::offer_all).
        const AHEAD: usize = 8;
        for i in 0..items.len() {
            if let Some(&next) = items.get(i + AHEAD) {
                self.map.prefetch(next);
            }
            self.offer(items[i]);
        }
    }

    fn processed(&self) -> u64 {
        self.n
    }

    fn counters(&self) -> Vec<Counter> {
        self.slots.clone()
    }

    fn estimate(&self, item: u64) -> Option<u64> {
        self.map.get(item).map(|s| self.slots[s as usize].count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::summary::traits::testutil::check_invariants;
    use crate::util::SplitMix64;

    #[test]
    fn classic_example() {
        // Stream from the Space Saving paper style: k=2 over {a,b,c}.
        let (a, b, c) = (1u64, 2, 3);
        let mut ss = SpaceSaving::new(2);
        ss.offer_all(&[a, a, b, c]);
        // c evicted b? No: after [a,a,b]: a=2, b=1. Offer c: evicts min
        // (b, count 1) -> c has count 2, err 1.
        assert_eq!(ss.estimate(a), Some(2));
        assert_eq!(ss.estimate(b), None);
        assert_eq!(ss.estimate(c), Some(2));
        let cc = ss.counters().into_iter().find(|x| x.item == c).unwrap();
        assert_eq!(cc.err, 1);
    }

    #[test]
    fn exact_when_distinct_items_fit() {
        let mut ss = SpaceSaving::new(100);
        let items: Vec<u64> = (0..50).flat_map(|i| vec![i; (i + 1) as usize]).collect();
        ss.offer_all(&items);
        for i in 0..50u64 {
            assert_eq!(ss.estimate(i), Some(i + 1));
        }
        assert!(ss.counters().iter().all(|c| c.err == 0));
    }

    #[test]
    fn invariants_uniform() {
        let mut rng = SplitMix64::new(1);
        let items: Vec<u64> = (0..20_000).map(|_| rng.next_below(500)).collect();
        check_invariants(&mut SpaceSaving::new(64), &items);
    }

    #[test]
    fn invariants_heavy_skew() {
        let mut rng = SplitMix64::new(2);
        // 80% of mass on 5 items, the rest uniform over a large universe.
        let items: Vec<u64> = (0..30_000)
            .map(|_| {
                if rng.next_f64() < 0.8 {
                    rng.next_below(5)
                } else {
                    100 + rng.next_below(100_000)
                }
            })
            .collect();
        check_invariants(&mut SpaceSaving::new(128), &items);
    }

    #[test]
    fn invariants_adversarial_rotation() {
        // Round-robin over exactly k+1 items: worst case for eviction churn.
        let k = 33;
        let items: Vec<u64> = (0..50_000u64).map(|i| i % (k as u64 + 1)).collect();
        check_invariants(&mut SpaceSaving::new(k), &items);
    }

    #[test]
    fn k_equals_one() {
        let mut ss = SpaceSaving::new(1);
        ss.offer_all(&[7, 7, 7, 8, 7]);
        // Single counter: ends monitoring 7 with count 5 (err from churn).
        let c = ss.counters()[0];
        assert_eq!(c.item, 7);
        assert_eq!(c.count, 5);
        assert!(c.count - c.err <= 4);
    }

    #[test]
    fn min_count_tracks_heap_root() {
        let mut ss = SpaceSaving::new(3);
        assert_eq!(ss.min_count(), 0);
        ss.offer_all(&[1, 1, 2, 2, 2, 3]);
        assert_eq!(ss.min_count(), 1);
        ss.offer_all(&[3, 3]);
        assert_eq!(ss.min_count(), 2);
    }

    #[test]
    fn weighted_updates_match_replayed_offers_when_monitored() {
        // While an item stays monitored (or capacity is spare), a
        // weighted update is exactly `weight` replayed offers.
        let mut a = SpaceSaving::new(8);
        let mut b = SpaceSaving::new(8);
        for (item, w) in [(1u64, 5u64), (2, 3), (1, 4), (3, 1)] {
            a.offer_weighted(item, w);
            for _ in 0..w {
                b.offer(item);
            }
        }
        assert_eq!(a.processed(), b.processed());
        for item in [1u64, 2, 3] {
            assert_eq!(a.estimate(item), b.estimate(item), "item {item}");
        }
        // Zero weight is a no-op.
        a.offer_weighted(9, 0);
        assert_eq!(a.processed(), 13);
        assert_eq!(a.estimate(9), None);
    }

    #[test]
    fn weighted_eviction_inherits_min_and_conserves_mass() {
        let mut ss = SpaceSaving::new(2);
        ss.offer_weighted(1, 4);
        ss.offer_weighted(2, 3);
        // Full: a run of 5 × item 3 evicts the min (2, count 3).
        ss.offer_weighted(3, 5);
        assert_eq!(ss.estimate(2), None);
        let c = ss.counters().into_iter().find(|c| c.item == 3).unwrap();
        assert_eq!(c.count, 8); // min 3 + weight 5
        assert_eq!(c.err, 3); // inherited min
        let total: u64 = ss.counters().iter().map(|c| c.count).sum();
        assert_eq!(total, ss.processed());
    }

    #[test]
    fn majority_k2() {
        // k=2 solves the classic majority problem.
        let mut rng = SplitMix64::new(3);
        let mut items = vec![42u64; 6_000];
        items.extend((0..4_000).map(|_| 100 + rng.next_below(1000)));
        // Shuffle.
        for i in (1..items.len()).rev() {
            let j = rng.next_below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
        let mut ss = SpaceSaving::new(2);
        ss.offer_all(&items);
        let est = ss.estimate(42).expect("majority item must be monitored");
        assert!(est >= 6_000);
    }
}
