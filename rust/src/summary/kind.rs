//! Runtime selection of the per-worker summary structure.
//!
//! Every live implementation shares [`FrequencySummary`], but the
//! coordinator's shard workers, the shared-memory driver and the CLI
//! all need to pick one *at runtime* (`--structure heap|bucket|compact`,
//! the `structure` JSON field). [`SummaryKind`] names the choice and
//! [`SummaryKind::build`] instantiates it as an [`AnySummary`] — a
//! three-variant enum dispatching each trait call with one predictable
//! branch, so the selection costs nothing measurable against the
//! per-chunk work it guards (no boxing, no vtable on the hot loop).

use super::compact::CompactSummary;
use super::counter::Counter;
use super::space_saving::SpaceSaving;
use super::stream_summary::StreamSummary;
use super::traits::FrequencySummary;

/// Which sequential summary structure a worker uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SummaryKind {
    /// [`SpaceSaving`]: hash map + slot-indexed min-heap, `O(log k)`
    /// per update. The simplest structure; the ablation baseline.
    Heap,
    /// [`StreamSummary`]: Metwally's bucket list, `O(1)` amortized.
    BucketList,
    /// [`CompactSummary`]: Structure-of-Arrays counters with block-min
    /// eviction, `O(1)` amortized and cache-resident — the fastest
    /// per-shard hot loop.
    Compact,
}

impl SummaryKind {
    /// Instantiate the structure with `k` counters.
    pub fn build(self, k: usize) -> AnySummary {
        match self {
            SummaryKind::Heap => AnySummary::Heap(SpaceSaving::new(k)),
            SummaryKind::BucketList => AnySummary::Bucket(StreamSummary::new(k)),
            SummaryKind::Compact => AnySummary::Compact(CompactSummary::new(k)),
        }
    }
}

impl std::fmt::Display for SummaryKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SummaryKind::Heap => "heap",
            SummaryKind::BucketList => "bucket",
            SummaryKind::Compact => "compact",
        })
    }
}

impl std::str::FromStr for SummaryKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "heap" => Ok(SummaryKind::Heap),
            "bucket" | "bucketlist" | "bucket-list" => Ok(SummaryKind::BucketList),
            "compact" | "soa" => Ok(SummaryKind::Compact),
            other => Err(format!("unknown structure '{other}' (heap|bucket|compact)")),
        }
    }
}

/// A runtime-selected live summary (see [`SummaryKind::build`]).
#[derive(Debug, Clone)]
pub enum AnySummary {
    /// Heap-based [`SpaceSaving`].
    Heap(SpaceSaving),
    /// Bucket-list [`StreamSummary`].
    Bucket(StreamSummary),
    /// SoA block-min [`CompactSummary`].
    Compact(CompactSummary),
}

macro_rules! dispatch {
    ($self:expr, $s:ident => $body:expr) => {
        match $self {
            AnySummary::Heap($s) => $body,
            AnySummary::Bucket($s) => $body,
            AnySummary::Compact($s) => $body,
        }
    };
}

impl AnySummary {
    /// Count of the current minimum counter (0 while under-full).
    pub fn min_count(&self) -> u64 {
        dispatch!(self, s => s.min_count())
    }
}

impl FrequencySummary for AnySummary {
    fn capacity(&self) -> usize {
        dispatch!(self, s => s.capacity())
    }

    #[inline]
    fn offer(&mut self, item: u64) {
        dispatch!(self, s => s.offer(item))
    }

    #[inline]
    fn offer_weighted(&mut self, item: u64, weight: u64) {
        dispatch!(self, s => s.offer_weighted(item, weight))
    }

    fn offer_all(&mut self, items: &[u64]) {
        // Delegate so each structure keeps its own prefetch pipeline.
        dispatch!(self, s => s.offer_all(items))
    }

    fn processed(&self) -> u64 {
        dispatch!(self, s => s.processed())
    }

    fn counters(&self) -> Vec<Counter> {
        dispatch!(self, s => s.counters())
    }

    fn estimate(&self, item: u64) -> Option<u64> {
        dispatch!(self, s => s.estimate(item))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn parse_and_display_roundtrip() {
        for kind in [SummaryKind::Heap, SummaryKind::BucketList, SummaryKind::Compact] {
            let s = kind.to_string();
            assert_eq!(s.parse::<SummaryKind>().unwrap(), kind);
        }
        assert_eq!("bucketlist".parse::<SummaryKind>().unwrap(), SummaryKind::BucketList);
        assert_eq!("soa".parse::<SummaryKind>().unwrap(), SummaryKind::Compact);
        assert!("btree".parse::<SummaryKind>().is_err());
    }

    #[test]
    fn built_structures_agree_on_identical_streams() {
        let mut rng = SplitMix64::new(4);
        let items: Vec<u64> = (0..30_000).map(|_| rng.next_below(150)).collect();
        let mut built: Vec<AnySummary> =
            [SummaryKind::Heap, SummaryKind::BucketList, SummaryKind::Compact]
                .into_iter()
                .map(|kind| kind.build(24))
                .collect();
        for s in &mut built {
            assert_eq!(s.capacity(), 24);
            s.offer_all(&items);
            assert_eq!(s.processed(), items.len() as u64);
        }
        // Same update rule everywhere: identical count multisets and
        // identical true minimum.
        let mut counts: Vec<Vec<u64>> = built
            .iter()
            .map(|s| s.counters().iter().map(|c| c.count).collect())
            .collect();
        for c in &mut counts {
            c.sort_unstable();
        }
        assert_eq!(counts[0], counts[1]);
        assert_eq!(counts[0], counts[2]);
        assert_eq!(built[0].min_count(), built[2].min_count());
        assert_eq!(built[1].min_count(), built[2].min_count());
    }
}
