//! The counter record shared by every summary implementation.

/// One monitored item: the paper's `S[i].e` / `S[i].f̂` pair plus the
/// over-estimation bound `err` (the minimum counter value at the moment
/// the item took over this counter; Space Saving guarantees
/// `count - err <= f_true <= count`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Counter {
    /// Item id. Generators encode items into `[0, 2^63)`.
    pub item: u64,
    /// Estimated frequency `f̂` (never under-estimates).
    pub count: u64,
    /// Over-estimation bound `ε`: `f_true >= count - err`.
    pub err: u64,
}

impl Counter {
    /// New counter with a fresh item observed `count` times exactly.
    pub fn exact(item: u64, count: u64) -> Self {
        Self { item, count, err: 0 }
    }

    /// Guaranteed (lower-bound) frequency.
    #[inline]
    pub fn guaranteed(&self) -> u64 {
        self.count - self.err
    }
}

/// Sort ascending by estimated frequency (ties broken by item id so the
/// order — and therefore the pruned survivor set — is deterministic).
pub fn sort_ascending(counters: &mut [Counter]) {
    counters.sort_unstable_by(|a, b| a.count.cmp(&b.count).then(a.item.cmp(&b.item)));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guaranteed_subtracts_err() {
        let c = Counter { item: 1, count: 10, err: 3 };
        assert_eq!(c.guaranteed(), 7);
    }

    #[test]
    fn sort_is_deterministic_on_ties() {
        let mut v = vec![
            Counter { item: 5, count: 2, err: 0 },
            Counter { item: 3, count: 2, err: 0 },
            Counter { item: 9, count: 1, err: 0 },
        ];
        sort_ascending(&mut v);
        assert_eq!(
            v.iter().map(|c| c.item).collect::<Vec<_>>(),
            vec![9, 3, 5]
        );
    }
}
