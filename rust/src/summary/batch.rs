//! Batched ingest fast path: collapse each incoming chunk into
//! `(item, weight)` runs with a small open-addressing scratch map, then
//! apply weighted Space Saving updates — **one summary touch per
//! distinct item** in the chunk instead of one per occurrence.
//!
//! Motivation (QPOPSS, arXiv:2409.01749): on skewed streams most of a
//! chunk is duplicates of a few hot items, and the per-item update loop
//! pays the summary's hash probe plus heap/bucket maintenance for every
//! one of them. Counting duplicates locally first turns a run of `w`
//! occurrences into a single [`FrequencySummary::offer_weighted`] call:
//!
//! * monitored item — one counter bump of `+w` (one probe, one
//!   heap/bucket fix-up) instead of `w`;
//! * unmonitored item — one min-eviction amortized across the whole run
//!   instead of an eviction followed by `w − 1` increments.
//!
//! The scratch probe is a single multiply-shift hash into an
//! L2-resident table ([`FastMap`]), far cheaper than a summary update,
//! so the pass pays for itself at even modest duplication. Chunk sizes
//! should keep the scratch map cache-resident — see
//! [`batch_chunk_len`](crate::parallel::partition::batch_chunk_len).
//!
//! Error bounds are preserved: each weighted update grows the summary
//! mass by exactly `w`, adoption inherits `err = min` exactly as the
//! per-item rule does, and `f̂ − err` counts only real occurrences.
//! Batched and per-item ingestion of the same stream therefore yield
//! summaries honoring the same `f ≤ f̂ ≤ f + n/k` guarantee (the
//! `prop_batched_ingest_guarantees_match_per_item` property test drives
//! both paths over identical random streams); the individual estimates
//! may differ within those bounds, since a run moves its whole weight
//! through one eviction decision.

use super::traits::FrequencySummary;
use crate::util::FastMap;

/// Reusable per-chunk pre-aggregation scratch: an open-addressing
/// `item -> run index` map plus the `(item, weight)` run list, both
/// recycled across chunks so the steady state allocates nothing.
///
/// Sizing: [`FastMap`] keeps a ≤50% load factor, so the scratch is
/// provisioned for the worst case of an all-distinct chunk. A chunk
/// larger than the current capacity triggers a one-time rebuild at the
/// next power of two; once chunks get small again the scratch shrinks
/// back (never below the configured floor) so the map's memory
/// footprint tracks the chunks actually flowing, not the largest one
/// ever seen. The reset itself is `O(1)` regardless of capacity —
/// `FastMap::clear` is generation-stamped.
#[derive(Debug)]
pub struct ChunkAggregator {
    /// item -> index into `runs` (cleared per chunk).
    index: FastMap,
    /// `(item, weight)` runs in first-occurrence order.
    runs: Vec<(u64, u64)>,
    /// Distinct-entry budget `index` is sized for.
    capacity: usize,
    /// Configured floor: the scratch never shrinks below this.
    min_capacity: usize,
}

impl Default for ChunkAggregator {
    fn default() -> Self {
        Self::new()
    }
}

impl ChunkAggregator {
    /// Scratch sized for moderate chunks; grows on demand.
    pub fn new() -> Self {
        Self::with_capacity(1024)
    }

    /// Scratch sized for chunks of up to `chunk_len` items without a
    /// rebuild (also the floor it never shrinks below).
    pub fn with_capacity(chunk_len: usize) -> Self {
        let capacity = chunk_len.max(16);
        Self {
            index: FastMap::with_capacity(capacity),
            runs: Vec::with_capacity(capacity),
            capacity,
            min_capacity: capacity,
        }
    }

    /// Distinct-item budget the scratch map is currently sized for.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Collapse `chunk` into `(item, weight)` runs, preserving
    /// first-occurrence order. The returned slice is valid until the
    /// next call; weights always sum to `chunk.len()`.
    pub fn aggregate(&mut self, chunk: &[u64]) -> &[(u64, u64)] {
        self.runs.clear();
        // The map reset itself is O(1) (FastMap's generation-stamped
        // clear), so the per-chunk cost no longer scales with map
        // capacity. The 8×-hysteresis shrink (never below the configured
        // floor) survives purely for memory footprint and probe
        // locality: one huge chunk must not leave every later chunk
        // probing a grossly over-provisioned, cache-cold slot array.
        let fit = chunk.len().max(self.min_capacity).next_power_of_two();
        if chunk.len() > self.capacity {
            // Worst case is all-distinct; rebuild once at the next power
            // of two rather than rehashing incrementally mid-chunk.
            self.capacity = fit;
            self.index = FastMap::with_capacity(self.capacity);
        } else if self.capacity > fit.saturating_mul(8) {
            self.capacity = fit;
            self.index = FastMap::with_capacity(self.capacity);
            self.runs.shrink_to(self.capacity);
        } else {
            self.index.clear();
        }
        // Software pipelining as in `offer_all`: hash a few items ahead
        // so the probe line is in L1 by the time `get` needs it.
        const AHEAD: usize = 8;
        for i in 0..chunk.len() {
            if let Some(&next) = chunk.get(i + AHEAD) {
                self.index.prefetch(next);
            }
            let item = chunk[i];
            match self.index.get(item) {
                Some(r) => self.runs[r as usize].1 += 1,
                None => {
                    self.index.insert(item, self.runs.len() as u32);
                    self.runs.push((item, 1));
                }
            }
        }
        &self.runs
    }
}

/// Apply pre-aggregated `(item, weight)` runs to a summary, one
/// weighted update per run. Split out of [`offer_batched`] so callers
/// that need the runs for more than one consumer — the shard workers
/// feed the same runs to the cumulative summary *and* the window
/// [`DeltaBuilder`](crate::window::DeltaBuilder) — aggregate once and
/// apply everywhere.
pub fn offer_runs<S: FrequencySummary>(summary: &mut S, runs: &[(u64, u64)]) {
    for &(item, weight) in runs {
        summary.offer_weighted(item, weight);
    }
}

/// Ingest one chunk through the batched fast path: pre-aggregate into
/// runs with `scratch`, then apply one weighted update per distinct
/// item. Equivalent in guarantees (not in exact estimates) to
/// `summary.offer_all(chunk)`; `summary.processed()` advances by
/// exactly `chunk.len()`.
pub fn offer_batched<S: FrequencySummary>(
    summary: &mut S,
    scratch: &mut ChunkAggregator,
    chunk: &[u64],
) {
    offer_runs(summary, scratch.aggregate(chunk));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::summary::{SpaceSaving, StreamSummary};
    use crate::util::SplitMix64;
    use std::collections::HashMap;

    #[test]
    fn runs_match_exact_counts_in_first_occurrence_order() {
        let chunk = [5u64, 1, 5, 2, 1, 5, 9];
        let mut agg = ChunkAggregator::new();
        let runs = agg.aggregate(&chunk);
        assert_eq!(runs, &[(5, 3), (1, 2), (2, 1), (9, 1)]);
    }

    #[test]
    fn weights_sum_to_chunk_len_on_random_chunks() {
        let mut rng = SplitMix64::new(41);
        let mut agg = ChunkAggregator::with_capacity(64);
        for trial in 0..200 {
            let len = rng.next_below(3_000) as usize;
            let universe = 1 + rng.next_below(500);
            let chunk: Vec<u64> = (0..len).map(|_| rng.next_below(universe)).collect();
            let mut oracle: HashMap<u64, u64> = HashMap::new();
            for &it in &chunk {
                *oracle.entry(it).or_default() += 1;
            }
            let runs = agg.aggregate(&chunk);
            assert_eq!(runs.len(), oracle.len(), "trial {trial}: distinct count");
            let total: u64 = runs.iter().map(|&(_, w)| w).sum();
            assert_eq!(total, len as u64, "trial {trial}: mass");
            for &(item, w) in runs {
                assert_eq!(oracle.get(&item), Some(&w), "trial {trial}: item {item}");
            }
        }
    }

    #[test]
    fn scratch_grows_then_shrinks_back_to_floor() {
        let mut agg = ChunkAggregator::with_capacity(16);
        assert!(agg.capacity() >= 16);
        // All-distinct chunk far beyond the initial budget forces growth.
        let big: Vec<u64> = (0..10_000).collect();
        assert_eq!(agg.aggregate(&big).len(), 10_000);
        assert!(agg.capacity() >= 10_000);
        // A small follow-up chunk shrinks the scratch back toward the
        // floor — one oversized chunk must not tax every later reset.
        assert_eq!(agg.aggregate(&[3, 3, 3]), &[(3, 3)]);
        assert!(agg.capacity() < 10_000);
        assert!(agg.capacity() >= 16);
        assert_eq!(agg.aggregate(&[]), &[] as &[(u64, u64)]);
        // A scratch provisioned for big chunks honors its floor: small
        // chunks never shrink it below the configured capacity.
        let mut wide = ChunkAggregator::with_capacity(8_192);
        wide.aggregate(&big);
        wide.aggregate(&[1, 2, 1]);
        assert!(wide.capacity() >= 8_192);
        assert_eq!(wide.aggregate(&big).len(), 10_000, "still correct after resizes");
    }

    #[test]
    fn batched_is_exact_while_under_capacity() {
        // With spare counters throughout, batched and per-item are both
        // exact, so their estimates agree exactly.
        let mut rng = SplitMix64::new(42);
        let items: Vec<u64> = (0..5_000).map(|_| rng.next_below(50)).collect();
        let mut per_item = SpaceSaving::new(64);
        per_item.offer_all(&items);
        let mut batched = SpaceSaving::new(64);
        let mut agg = ChunkAggregator::new();
        for chunk in items.chunks(333) {
            offer_batched(&mut batched, &mut agg, chunk);
        }
        assert_eq!(batched.processed(), per_item.processed());
        for item in 0..50u64 {
            assert_eq!(batched.estimate(item), per_item.estimate(item), "item {item}");
        }
    }

    #[test]
    fn batched_preserves_invariants_under_eviction_churn() {
        // Overflowing both structures: check the full Space Saving
        // guarantee for the batched path against exact truth.
        let mut rng = SplitMix64::new(43);
        let items: Vec<u64> = (0..40_000)
            .map(|_| {
                if rng.next_f64() < 0.7 {
                    rng.next_below(10)
                } else {
                    100 + rng.next_below(30_000)
                }
            })
            .collect();
        let mut truth: HashMap<u64, u64> = HashMap::new();
        for &it in &items {
            *truth.entry(it).or_default() += 1;
        }
        let k = 64usize;
        let n = items.len() as u64;

        let mut heap = SpaceSaving::new(k);
        let mut bucket = StreamSummary::new(k);
        let mut agg = ChunkAggregator::with_capacity(1000);
        for chunk in items.chunks(1000) {
            offer_batched(&mut heap, &mut agg, chunk);
            offer_batched(&mut bucket, &mut agg, chunk);
        }
        for (label, counters, processed) in [
            ("heap", heap.counters(), heap.processed()),
            ("bucket", bucket.counters(), bucket.processed()),
        ] {
            assert_eq!(processed, n, "{label}: n");
            let total: u64 = counters.iter().map(|c| c.count).sum();
            assert_eq!(total, n, "{label}: mass");
            for c in &counters {
                let f = truth.get(&c.item).copied().unwrap_or(0);
                assert!(c.count >= f, "{label}: under-estimate of {}", c.item);
                assert!(c.count - c.err <= f, "{label}: err bound of {}", c.item);
            }
            let thresh = n / k as u64;
            let monitored: std::collections::HashSet<u64> =
                counters.iter().map(|c| c.item).collect();
            for (item, f) in &truth {
                if *f > thresh {
                    assert!(monitored.contains(item), "{label}: lost {item} (f={f})");
                }
            }
        }
    }
}
