//! Evaluation metrics (paper §4 definitions) and report formatting.
//!
//! * [`accuracy`] — Average Relative Error, precision, recall.
//! * [`timing`] — phase breakdowns and the paper's *fractional overhead*
//!   (Figure 3): overhead time / computational time.
//! * [`latency`] — wait-free log₂-bucket latency histogram for the live
//!   query path (per-query latency, snapshot staleness).
//! * [`cache`] — hit/miss/merges-avoided counters for the
//!   epoch-versioned snapshot caches on the read path.
//! * [`fault`] — injected-fault accounting for the deterministic
//!   fault-injection proxy in the serve layer.
//! * [`report`] — paper-style ASCII tables and figure series (+ CSV).

pub mod accuracy;
pub mod cache;
pub mod fault;
pub mod latency;
pub mod report;
pub mod timing;

pub use accuracy::{average_relative_error, precision, recall, AccuracyReport};
pub use cache::{CacheCounters, CacheStats};
pub use fault::{FaultCounters, FaultStats};
pub use latency::{LatencyHistogram, LatencySummary};
pub use report::{Series, Table};
pub use timing::{fractional_overhead, PhaseTimes};
