//! Evaluation metrics (paper §4 definitions) and report formatting.
//!
//! * [`accuracy`] — Average Relative Error, precision, recall.
//! * [`timing`] — phase breakdowns and the paper's *fractional overhead*
//!   (Figure 3): overhead time / computational time.
//! * [`report`] — paper-style ASCII tables and figure series (+ CSV).

pub mod accuracy;
pub mod report;
pub mod timing;

pub use accuracy::{average_relative_error, precision, recall, AccuracyReport};
pub use report::{Series, Table};
pub use timing::{fractional_overhead, PhaseTimes};
