//! Phase timing and the paper's *fractional overhead* metric (Figure 3):
//! the ratio of overhead time (thread spawning, synchronization, the
//! reduction operator) over the computational time.
//!
//! Times are plain `f64` seconds so the same types carry both measured
//! wallclock (this host) and simulated cluster time (`distsim`).

/// Per-phase time breakdown of one parallel run, in seconds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseTimes {
    /// Worker spawn / teardown (OpenMP parallel-region entry, MPI init).
    pub spawn: f64,
    /// Local sequential Space Saving scan (the computational part).
    pub scan: f64,
    /// Sort + parallel reduction with the combine operator.
    pub reduce: f64,
    /// Final prune on the root.
    pub prune: f64,
}

impl PhaseTimes {
    /// Total wall time of the run.
    pub fn total(&self) -> f64 {
        self.spawn + self.scan + self.reduce + self.prune
    }

    /// Overhead component (everything that is not the local scan).
    pub fn overhead(&self) -> f64 {
        self.spawn + self.reduce + self.prune
    }

    /// Element-wise accumulation (for averaging repeated runs).
    pub fn add(&mut self, other: &PhaseTimes) {
        self.spawn += other.spawn;
        self.scan += other.scan;
        self.reduce += other.reduce;
        self.prune += other.prune;
    }

    /// Scale every phase (for averaging repeated runs).
    pub fn scale(&self, by: f64) -> PhaseTimes {
        PhaseTimes {
            spawn: self.spawn * by,
            scan: self.scan * by,
            reduce: self.reduce * by,
            prune: self.prune * by,
        }
    }
}

/// Fractional overhead = overhead time / computational time (paper Fig. 3).
pub fn fractional_overhead(t: &PhaseTimes) -> f64 {
    if t.scan == 0.0 {
        return 0.0;
    }
    t.overhead() / t.scan
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_and_overhead() {
        let t = PhaseTimes { spawn: 1.0, scan: 10.0, reduce: 2.0, prune: 0.5 };
        assert!((t.total() - 13.5).abs() < 1e-12);
        assert!((t.overhead() - 3.5).abs() < 1e-12);
        assert!((fractional_overhead(&t) - 0.35).abs() < 1e-12);
    }

    #[test]
    fn zero_scan_guard() {
        let t = PhaseTimes::default();
        assert_eq!(fractional_overhead(&t), 0.0);
    }

    #[test]
    fn add_and_scale() {
        let mut a = PhaseTimes { spawn: 1.0, scan: 2.0, reduce: 3.0, prune: 4.0 };
        a.add(&a.clone());
        let half = a.scale(0.5);
        assert_eq!(half, PhaseTimes { spawn: 1.0, scan: 2.0, reduce: 3.0, prune: 4.0 });
    }
}
