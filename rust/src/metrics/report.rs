//! Paper-style output: ASCII tables (Tables II–IV) and figure series
//! (Figures 1–6), with CSV export for external plotting.

use std::fmt::Write as _;

/// A rectangular table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with column headers.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no rows were added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::from("|");
            for (w, c) in widths.iter().zip(cells) {
                let _ = write!(s, " {c:>w$} |", w = w);
            }
            let _ = writeln!(out, "{s}");
        };
        line(&mut out, &self.headers);
        let _ = writeln!(
            out,
            "|{}|",
            widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// Render as CSV (header + rows).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join(","));
        }
        out
    }
}

/// A figure: one x column plus named y series (log-log plots in the
/// paper become aligned numeric columns here + CSV for replotting).
#[derive(Debug, Clone)]
pub struct Series {
    title: String,
    x_label: String,
    names: Vec<String>,
    xs: Vec<f64>,
    ys: Vec<Vec<Option<f64>>>,
}

impl Series {
    /// New figure with an x-axis label and one name per y series.
    pub fn new(title: impl Into<String>, x_label: impl Into<String>, names: &[&str]) -> Self {
        Self {
            title: title.into(),
            x_label: x_label.into(),
            names: names.iter().map(|s| s.to_string()).collect(),
            xs: Vec::new(),
            ys: Vec::new(),
        }
    }

    /// Append one x point with one value per series (None = missing).
    pub fn point(&mut self, x: f64, values: Vec<Option<f64>>) {
        assert_eq!(values.len(), self.names.len(), "series arity mismatch");
        self.xs.push(x);
        self.ys.push(values);
    }

    /// Render as an aligned numeric block.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            self.title.clone(),
            &std::iter::once(self.x_label.as_str())
                .chain(self.names.iter().map(|s| s.as_str()))
                .collect::<Vec<_>>(),
        );
        for (x, row) in self.xs.iter().zip(&self.ys) {
            let mut cells = vec![format_num(*x)];
            cells.extend(row.iter().map(|v| v.map_or("-".into(), format_num)));
            t.row(cells);
        }
        t.render()
    }

    /// CSV export.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{},{}", self.x_label, self.names.join(","));
        for (x, row) in self.xs.iter().zip(&self.ys) {
            let cells: Vec<String> = row
                .iter()
                .map(|v| v.map_or(String::new(), |v| format!("{v}")))
                .collect();
            let _ = writeln!(out, "{x},{}", cells.join(","));
        }
        out
    }
}

/// Compact numeric formatting: integers plain, small values scientific.
fn format_num(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1e6 || v.abs() < 1e-3 {
        format!("{v:.3e}")
    } else if (v - v.round()).abs() < 1e-9 {
        format!("{}", v.round() as i64)
    } else {
        format!("{v:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["cores", "time", "speedup"]);
        t.row(vec!["1".into(), "120.60".into(), "1".into()]);
        t.row(vec!["16".into(), "9.74".into(), "12.37".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("cores"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    fn series_handles_missing() {
        let mut s = Series::new("fig", "cores", &["mpi", "hybrid"]);
        s.point(1.0, vec![Some(874.88), None]);
        s.point(512.0, vec![Some(3.35), Some(2.40)]);
        let r = s.render();
        assert!(r.contains('-'));
        let csv = s.to_csv();
        assert!(csv.starts_with("cores,mpi,hybrid"));
    }

    #[test]
    fn format_num_branches() {
        assert_eq!(format_num(0.0), "0");
        assert_eq!(format_num(16.0), "16");
        assert_eq!(format_num(12.37), "12.37");
        assert!(format_num(1e-8).contains('e'));
    }
}
