//! Wait-free counters for the fault-injection proxy
//! ([`FaultLine`](crate::serve::FaultLine)).
//!
//! Same discipline as [`CacheCounters`](super::CacheCounters): relaxed
//! `fetch_add`s shared behind an `Arc` by every proxy connection, read
//! as a plain-value snapshot when the harness reports.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

/// Shared accounting for one fault-injection proxy.
#[derive(Debug, Default)]
pub struct FaultCounters {
    forwarded: AtomicU64,
    dropped: AtomicU64,
    delayed: AtomicU64,
    garbled: AtomicU64,
    truncated: AtomicU64,
    reset: AtomicU64,
}

impl FaultCounters {
    /// Fresh counters, all zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// One frame forwarded unmodified.
    pub fn record_forwarded(&self) {
        self.forwarded.fetch_add(1, Ordering::Relaxed);
    }

    /// One frame swallowed (never reached the other side).
    pub fn record_dropped(&self) {
        self.dropped.fetch_add(1, Ordering::Relaxed);
    }

    /// One frame held back before forwarding.
    pub fn record_delayed(&self) {
        self.delayed.fetch_add(1, Ordering::Relaxed);
    }

    /// One frame forwarded with its kind and body randomized.
    pub fn record_garbled(&self) {
        self.garbled.fetch_add(1, Ordering::Relaxed);
    }

    /// One frame cut short mid-image, connection killed after.
    pub fn record_truncated(&self) {
        self.truncated.fetch_add(1, Ordering::Relaxed);
    }

    /// One connection reset outright at a frame boundary.
    pub fn record_reset(&self) {
        self.reset.fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time copy (each field individually exact; relaxed
    /// relative to each other).
    pub fn stats(&self) -> FaultStats {
        FaultStats {
            forwarded: self.forwarded.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
            delayed: self.delayed.load(Ordering::Relaxed),
            garbled: self.garbled.load(Ordering::Relaxed),
            truncated: self.truncated.load(Ordering::Relaxed),
            reset: self.reset.load(Ordering::Relaxed),
        }
    }
}

/// Plain-value snapshot of [`FaultCounters`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Frames forwarded unmodified.
    pub forwarded: u64,
    /// Frames swallowed.
    pub dropped: u64,
    /// Frames delayed before forwarding.
    pub delayed: u64,
    /// Frames forwarded with randomized content.
    pub garbled: u64,
    /// Frames truncated mid-image (kills the connection).
    pub truncated: u64,
    /// Connections reset at a frame boundary.
    pub reset: u64,
}

impl FaultStats {
    /// Total faults injected (everything except clean forwards).
    pub fn injected(&self) -> u64 {
        self.dropped + self.delayed + self.garbled + self.truncated + self.reset
    }
}

impl fmt::Display for FaultStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} forwarded, {} dropped, {} delayed, {} garbled, {} truncated, {} reset",
            self.forwarded, self.dropped, self.delayed, self.garbled, self.truncated, self.reset
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_snapshot() {
        let c = FaultCounters::new();
        assert_eq!(c.stats(), FaultStats::default());
        c.record_forwarded();
        c.record_forwarded();
        c.record_dropped();
        c.record_delayed();
        c.record_garbled();
        c.record_truncated();
        c.record_reset();
        let s = c.stats();
        assert_eq!(s.forwarded, 2);
        assert_eq!(s.injected(), 5);
        assert_eq!(
            s.to_string(),
            "2 forwarded, 1 dropped, 1 delayed, 1 garbled, 1 truncated, 1 reset"
        );
    }
}
