//! Lock-free latency accounting for the live read path.
//!
//! Writers on the ingest path must never block behind readers, and
//! readers must not serialize on each other — so the query layer records
//! latencies into a fixed array of power-of-two nanosecond buckets
//! updated with relaxed atomics. Quantiles come back as the upper edge
//! of the covering bucket (≤ 2× resolution), which is plenty for the
//! staleness / latency dashboards this feeds.
//!
//! Two lag signals matter on the live read path, and they are reported
//! separately:
//!
//! * **query latency** — wall time to materialize a merged snapshot and
//!   answer; every [`QueryEngine::snapshot`] records one sample into
//!   the engine's [`LatencyHistogram`], digested as
//!   [`QueryEngineStats::query_latency`] ([`LatencySummary`]).
//! * **staleness** — how far the answers trail ingestion:
//!   `staleness_items` (items routed minus items covered by published
//!   epochs) and [`MergedSnapshot::staleness`] (age of the oldest
//!   constituent shard snapshot). Staleness is epoch-protocol lag and
//!   shrinks with `epoch_items` / `refresh()`, not with faster queries.
//!
//! Recording is wait-free (a handful of relaxed atomic adds), so the
//! histogram can sit on any hot path; `mean`/`max` are exact while
//! quantiles are bucket-resolution, e.g.:
//!
//! ```
//! use pss::metrics::LatencyHistogram;
//! use std::time::Duration;
//!
//! let h = LatencyHistogram::new();
//! h.record(Duration::from_micros(3));
//! h.record(Duration::from_micros(90));
//! let s = h.summary();
//! assert_eq!(s.count, 2);
//! assert_eq!(s.max_ns, 90_000);
//! assert!(s.p99_ns >= 90_000, "quantiles report a covering upper edge");
//! ```
//!
//! [`QueryEngine::snapshot`]: crate::query::QueryEngine::snapshot
//! [`MergedSnapshot::staleness`]: crate::query::MergedSnapshot::staleness
//! [`QueryEngineStats::query_latency`]: crate::query::QueryEngineStats

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of log₂ buckets: bucket `i` holds durations of `i`-bit
/// nanosecond values — bucket 0 is exactly 0 ns, bucket `i ≥ 1` covers
/// `[2^(i-1), 2^i)` ns, and the last bucket is open-ended. 48 buckets
/// reach ~39 hours.
const BUCKETS: usize = 48;

/// A concurrent histogram of durations with power-of-two nanosecond
/// buckets. All methods take `&self`; recording is wait-free.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    /// New, empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket_of(ns: u64) -> usize {
        ((64 - ns.leading_zeros()) as usize).min(BUCKETS - 1)
    }

    /// Record one duration.
    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        self.buckets[Self::bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in nanoseconds (0 when empty).
    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Maximum recorded latency in nanoseconds.
    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed)
    }

    /// Upper-edge estimate (ns) of the `q`-quantile, `q` in `[0, 1]`.
    /// Returns 0 when empty.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                // Upper edge of bucket i: 2^i (bucket 0 = [0,2)).
                return 1u64 << i.min(63);
            }
        }
        self.max_ns()
    }

    /// Fold another histogram's samples into this one. Both sides stay
    /// usable; counts add bucket-wise, so quantiles of the merged
    /// histogram are exactly what one shared histogram would report.
    /// The load generator gives each client its own (uncontended)
    /// histogram and merges them for the final report.
    pub fn merge(&self, other: &LatencyHistogram) {
        for (mine, theirs) in self.buckets.iter().zip(&other.buckets) {
            let v = theirs.load(Ordering::Relaxed);
            if v > 0 {
                mine.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_ns
            .fetch_add(other.sum_ns.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_ns
            .fetch_max(other.max_ns.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Compact snapshot for reports.
    pub fn summary(&self) -> LatencySummary {
        LatencySummary {
            count: self.count(),
            mean_ns: self.mean_ns(),
            p50_ns: self.quantile_ns(0.50),
            p99_ns: self.quantile_ns(0.99),
            max_ns: self.max_ns(),
        }
    }
}

/// A point-in-time latency digest.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencySummary {
    /// Samples recorded.
    pub count: u64,
    /// Mean, nanoseconds.
    pub mean_ns: f64,
    /// ~median upper bound, nanoseconds.
    pub p50_ns: u64,
    /// ~99th percentile upper bound, nanoseconds.
    pub p99_ns: u64,
    /// Maximum, nanoseconds.
    pub max_ns: u64,
}

impl std::fmt::Display for LatencySummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        fn fmt_ns(ns: f64) -> String {
            if ns < 1e3 {
                format!("{ns:.0}ns")
            } else if ns < 1e6 {
                format!("{:.1}µs", ns / 1e3)
            } else if ns < 1e9 {
                format!("{:.2}ms", ns / 1e6)
            } else {
                format!("{:.2}s", ns / 1e9)
            }
        }
        write!(
            f,
            "n={} mean={} p50≤{} p99≤{} max={}",
            self.count,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns as f64),
            fmt_ns(self.p99_ns as f64),
            fmt_ns(self.max_ns as f64),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_ns(), 0.0);
        assert_eq!(h.quantile_ns(0.5), 0);
    }

    #[test]
    fn buckets_cover_powers_of_two() {
        assert_eq!(LatencyHistogram::bucket_of(0), 0);
        assert_eq!(LatencyHistogram::bucket_of(1), 1);
        assert_eq!(LatencyHistogram::bucket_of(2), 2);
        assert_eq!(LatencyHistogram::bucket_of(3), 2);
        assert_eq!(LatencyHistogram::bucket_of(1024), 11);
        assert_eq!(LatencyHistogram::bucket_of(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn mean_max_and_quantiles() {
        let h = LatencyHistogram::new();
        for ns in [100u64, 100, 100, 100_000] {
            h.record(Duration::from_nanos(ns));
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean_ns() - 25_075.0).abs() < 1e-9);
        assert_eq!(h.max_ns(), 100_000);
        // p50 falls in the bucket containing 100 ([64,128) → edge 128).
        assert_eq!(h.quantile_ns(0.5), 128);
        // p100 falls in the bucket containing 100_000.
        assert!(h.quantile_ns(1.0) >= 100_000);
    }

    #[test]
    fn concurrent_recording_is_consistent() {
        let h = std::sync::Arc::new(LatencyHistogram::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let h = h.clone();
                s.spawn(move || {
                    for i in 0..1_000u64 {
                        h.record(Duration::from_nanos(i));
                    }
                });
            }
        });
        assert_eq!(h.count(), 4_000);
        assert_eq!(h.max_ns(), 999);
    }

    #[test]
    fn merge_matches_shared_recording() {
        let a = LatencyHistogram::new();
        let b = LatencyHistogram::new();
        let shared = LatencyHistogram::new();
        for ns in [100u64, 3_000, 70_000] {
            a.record(Duration::from_nanos(ns));
            shared.record(Duration::from_nanos(ns));
        }
        for ns in [5u64, 900_000] {
            b.record(Duration::from_nanos(ns));
            shared.record(Duration::from_nanos(ns));
        }
        a.merge(&b);
        assert_eq!(a.summary(), shared.summary());
        // `b` is untouched.
        assert_eq!(b.count(), 2);
    }

    #[test]
    fn summary_formats() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(3));
        let s = h.summary();
        assert_eq!(s.count, 1);
        assert!(s.to_string().contains("n=1"), "{s}");
    }
}
