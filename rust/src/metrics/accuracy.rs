//! Accuracy metrics, exactly as the paper §4 defines them.
//!
//! * relative error `Δf = |f − f̂| / f`; **ARE** averages `Δf` over all
//!   measured (reported) frequencies,
//! * **precision** = true k-majority items reported / items reported
//!   (quantifies false positives),
//! * **recall** = true k-majority items reported / true k-majority items.

use crate::baselines::Exact;
use crate::summary::Counter;

/// Average Relative Error of the reported counters against exact counts.
///
/// Items reported but absent from the stream contribute `Δf = 1` (worst
/// case `|f − f̂|/f̂` convention would be undefined at `f = 0`; the paper's
/// streams never produce this case since Space Saving only reports seen
/// items — the guard is for sketch baselines).
pub fn average_relative_error(reported: &[Counter], exact: &Exact) -> f64 {
    if reported.is_empty() {
        return 0.0;
    }
    let total: f64 = reported
        .iter()
        .map(|c| {
            let f = exact.count(c.item);
            if f == 0 {
                1.0
            } else {
                (f as f64 - c.count as f64).abs() / f as f64
            }
        })
        .sum();
    total / reported.len() as f64
}

/// Precision of `reported` against the true k-majority set.
pub fn precision(reported: &[Counter], exact: &Exact, k: u64) -> f64 {
    if reported.is_empty() {
        return 1.0;
    }
    let truth: std::collections::HashSet<u64> =
        exact.k_majority(k).iter().map(|c| c.item).collect();
    let hits = reported.iter().filter(|c| truth.contains(&c.item)).count();
    hits as f64 / reported.len() as f64
}

/// Recall of `reported` against the true k-majority set.
pub fn recall(reported: &[Counter], exact: &Exact, k: u64) -> f64 {
    let truth: std::collections::HashSet<u64> =
        exact.k_majority(k).iter().map(|c| c.item).collect();
    if truth.is_empty() {
        return 1.0;
    }
    let hits = reported.iter().filter(|c| truth.contains(&c.item)).count();
    hits as f64 / truth.len() as f64
}

/// Bundle of all three metrics for one run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AccuracyReport {
    /// Average relative error over reported items.
    pub are: f64,
    /// Fraction of reported items that are truly frequent.
    pub precision: f64,
    /// Fraction of truly frequent items that were reported.
    pub recall: f64,
}

impl AccuracyReport {
    /// Evaluate `reported` against `exact` for k-majority parameter `k`.
    pub fn evaluate(reported: &[Counter], exact: &Exact, k: u64) -> Self {
        Self {
            are: average_relative_error(reported, exact),
            precision: precision(reported, exact, k),
            recall: recall(reported, exact, k),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::summary::FrequencySummary;

    fn oracle(items: &[u64]) -> Exact {
        let mut e = Exact::new();
        e.offer_all(items);
        e
    }

    #[test]
    fn are_zero_when_exact() {
        let e = oracle(&[1, 1, 1, 2, 2]);
        let reported = vec![Counter { item: 1, count: 3, err: 0 }];
        assert_eq!(average_relative_error(&reported, &e), 0.0);
    }

    #[test]
    fn are_measures_overestimate() {
        let e = oracle(&[1, 1, 1, 2]);
        // f̂ = 4, f = 3 -> Δf = 1/3.
        let reported = vec![Counter { item: 1, count: 4, err: 1 }];
        assert!((average_relative_error(&reported, &e) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn precision_counts_false_positives() {
        // n=8, k=2 -> threshold 4: only item 1 (f=5) is frequent.
        let e = oracle(&[1, 1, 1, 1, 1, 2, 2, 3]);
        let reported = vec![
            Counter { item: 1, count: 5, err: 0 },
            Counter { item: 2, count: 3, err: 1 },
        ];
        assert_eq!(precision(&reported, &e, 2), 0.5);
        assert_eq!(recall(&reported, &e, 2), 1.0);
    }

    #[test]
    fn recall_detects_misses() {
        let e = oracle(&[1, 1, 1, 1, 2, 2, 2, 2]);
        // k=2 -> threshold 4: neither clears (f=4 each, need >4) -> empty
        // truth -> recall 1 by convention.
        assert_eq!(recall(&[], &e, 2), 1.0);
        // k=3 -> threshold 2: both are frequent; reporting one -> 0.5.
        let reported = vec![Counter { item: 1, count: 4, err: 0 }];
        assert_eq!(recall(&reported, &e, 3), 0.5);
    }

    #[test]
    fn unseen_reported_item_counts_as_full_error() {
        let e = oracle(&[1, 1]);
        let reported = vec![Counter { item: 99, count: 5, err: 0 }];
        assert_eq!(average_relative_error(&reported, &e), 1.0);
    }
}
