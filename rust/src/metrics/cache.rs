//! Wait-free counters for the epoch-versioned read-path caches.
//!
//! The query engines ([`QueryEngine`](crate::query::QueryEngine), the
//! windowed engine, the cluster head) cache their last merged view and
//! revalidate it with a single relaxed version load per query. These
//! counters make the cache observable: they are shared (behind an
//! `Arc`) by every clone of an engine, so the serve layer's query pool
//! reports one aggregate across all reader threads.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

/// Shared hit/miss accounting for one snapshot cache.
///
/// All updates are relaxed `fetch_add`s — the counters are monitoring
/// data, never part of the cache's coherence argument.
#[derive(Debug, Default)]
pub struct CacheCounters {
    hits: AtomicU64,
    misses: AtomicU64,
    merges_avoided: AtomicU64,
}

impl CacheCounters {
    /// Fresh counters, all zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// One fast-path hit: the cached view's version matched and the
    /// reader served an `Arc` clone without taking any lock.
    pub fn record_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    /// One miss: this reader ran the merge itself (first query, or the
    /// version moved and this reader won the rebuild).
    pub fn record_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    /// One merge avoided: the query was answered from a view some
    /// *other* reader built — either a fast-path hit or a slow-path
    /// reuse of a concurrently rebuilt view. `merges_avoided ≥ hits`;
    /// the difference counts readers that arrived during a rebuild and
    /// reused its result instead of merging again (the thundering herd
    /// the cache exists to prevent).
    pub fn record_merge_avoided(&self) {
        self.merges_avoided.fetch_add(1, Ordering::Relaxed);
    }

    /// Consistent-enough point-in-time copy of the counters (each
    /// field individually exact; relaxed relative to each other).
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            merges_avoided: self.merges_avoided.load(Ordering::Relaxed),
        }
    }
}

/// Plain-value snapshot of [`CacheCounters`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Fast-path hits: version matched, served an `Arc` clone.
    pub hits: u64,
    /// Misses: the reader rebuilt the merged view itself.
    pub misses: u64,
    /// Queries served without running a merge (hits plus slow-path
    /// reuses of a view another reader was concurrently building).
    pub merges_avoided: u64,
}

impl CacheStats {
    /// Fraction of queries served from cache, in `[0, 1]`; 0 when no
    /// query has been served.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

impl fmt::Display for CacheStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} hits / {} misses ({:.1}% hit rate), {} merges avoided",
            self.hits,
            self.misses,
            self.hit_rate() * 100.0,
            self.merges_avoided
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_snapshot() {
        let c = CacheCounters::new();
        assert_eq!(c.stats(), CacheStats::default());
        c.record_hit();
        c.record_hit();
        c.record_merge_avoided();
        c.record_merge_avoided();
        c.record_merge_avoided();
        c.record_miss();
        let s = c.stats();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
        assert_eq!(s.merges_avoided, 3);
        assert!((s.hit_rate() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn hit_rate_is_zero_when_idle() {
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn counters_are_shared_across_threads() {
        let c = std::sync::Arc::new(CacheCounters::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.record_hit();
                        c.record_merge_avoided();
                    }
                });
            }
        });
        let s = c.stats();
        assert_eq!(s.hits, 4000);
        assert_eq!(s.merges_avoided, 4000);
    }
}
