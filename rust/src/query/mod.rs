//! The live query layer — epoch-snapshotted concurrent reads over the
//! streaming Space Saving shards.
//!
//! The paper's Algorithm 1 (and the batch [`coordinator`] API built on
//! it) only answers queries at `finish()`. Production stream mining
//! needs the opposite: consistent frequent-item answers *while* writers
//! keep ingesting. Following the QPOPSS co-design (Jarlow et al.) and
//! leaning on the mergeability of the paper's `combine` operator
//! (Algorithm 2), the read path is:
//!
//! ```text
//!  shard 0: StreamSummary ──freeze──▶ [Arc<EpochSnapshot>] ─┐ borrow
//!  shard 1: StreamSummary ──freeze──▶ [Arc<EpochSnapshot>] ─┼─▶ tree_reduce_refs ─▶ MergedSnapshot
//!  shard s: StreamSummary ──freeze──▶ [Arc<EpochSnapshot>] ─┘      (combine tree)    top_k / point /
//!                                         ▲ atomic swap                              threshold / stats
//!  writers keep ingesting ───────────────┘ (every epoch_items, or on refresh())
//! ```
//!
//! * [`epoch`] — [`EpochSnapshot`], the atomically-swapped per-shard
//!   [`EpochSlot`]s and the shared [`EpochRegistry`].
//! * [`engine`] — [`QueryEngine`] / [`MergedSnapshot`]: `top_k(m)`,
//!   `point(item)`, `threshold(phi)` / `k_majority(k)` with the
//!   guaranteed-vs-possible split, and `stats()` (staleness + latency).
//!
//! These are *landmark* answers (everything since startup). The sibling
//! [`crate::window`] layer rides the same epoch cadence to serve
//! *sliding-window* answers from per-epoch delta summaries; sessions
//! with [`CoordinatorConfig::delta_ring`] > 0 hand out that engine via
//! [`Coordinator::windows`].
//!
//! [`CoordinatorConfig::delta_ring`]: crate::coordinator::CoordinatorConfig::delta_ring
//! [`Coordinator::windows`]: crate::coordinator::Coordinator::windows
//!
//! The epoch-snapshot protocol, writer side then reader side:
//!
//! 1. every shard owns a private live summary no reader ever touches;
//! 2. after `epoch_items` ingested items — or when it observes a
//!    [`QueryEngine::refresh`] watermark newer than its last
//!    publication, or at drain — the shard freezes the summary
//!    (`freeze()`: sort + copy of ≤ k counters) and swaps the resulting
//!    immutable `Arc<EpochSnapshot>` into its [`EpochSlot`];
//! 3. a query clones the latest `Arc` of every slot (refcount bumps,
//!    no data copies) and combine-merges the borrowed summaries into a
//!    [`MergedSnapshot`] — a pinned, internally-consistent view that
//!    stays valid however far ingestion advances.
//!
//! Guarantees: a merged view over published prefixes totalling
//! `n_epoch` items satisfies `f ≤ f̂ ≤ f + ε` with `ε = n_epoch/k`, and
//! reports every item with `f > n_epoch/k` — the Space Saving bound,
//! preserved by `combine` (paper §3, proof in their ref [25]).
//! Readers never block writers: publication is an `Arc` swap, queries
//! run on frozen summaries the writer no longer touches. Answers trail
//! ingestion by at most the unpublished tails (`staleness_items` in
//! [`QueryEngineStats`]); query cost itself is tracked by the wait-free
//! histograms in [`crate::metrics::latency`].
//!
//! [`coordinator`]: crate::coordinator

pub mod engine;
pub mod epoch;

pub use engine::{
    EpochInfo, MergedSnapshot, PointEstimate, QueryEngine, QueryEngineStats, ThresholdReport,
};
pub use epoch::{EpochRegistry, EpochSlot, EpochSnapshot};
