//! Epoch snapshots: the immutable per-shard summaries the read path
//! consumes.
//!
//! Each shard worker periodically freezes its live Space Saving
//! structure into a [`Summary`] and *publishes* it as an
//! [`EpochSnapshot`] by swapping the `Arc` held in its [`EpochSlot`].
//! Readers clone the `Arc` (a refcount bump under a briefly-held lock —
//! never the data) and work on a frozen, internally-consistent summary
//! while the writer keeps ingesting. This is the QPOPSS-style
//! co-design: queries never block updates, updates never mutate
//! anything a reader can observe.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use crate::summary::Summary;

/// One published, immutable per-shard summary.
#[derive(Debug, Clone)]
pub struct EpochSnapshot {
    /// Shard that published this snapshot.
    pub shard: usize,
    /// Per-shard publication sequence number (0 = the empty snapshot
    /// installed at spawn; the first real publication is 1).
    pub epoch: u64,
    /// The frozen summary (counters ascending, `n` = items covered).
    pub summary: Summary,
    /// Exact cumulative counts of *split* (hot-tier) keys observed by
    /// this shard under `Routing::KeyedAdaptive`, `(item, count)`
    /// pairs. Split occurrences never enter the Space Saving structure
    /// (so `summary` stays key-disjoint and its `n` excludes them);
    /// the read side adds these partials back after the disjoint
    /// merge. Empty in every other routing mode.
    pub hot: Vec<(u64, u64)>,
    /// When the snapshot was published.
    pub published_at: Instant,
    /// Whether this is the shard's final (drain-time) snapshot.
    pub finished: bool,
}

impl EpochSnapshot {
    /// The initial empty snapshot every slot starts with.
    fn initial(shard: usize, k: usize) -> Self {
        Self {
            shard,
            epoch: 0,
            summary: Summary::empty(k),
            hot: Vec::new(),
            published_at: Instant::now(),
            finished: false,
        }
    }

    /// Total split-key mass carried by this snapshot's exact partials.
    pub fn hot_mass(&self) -> u64 {
        self.hot.iter().map(|&(_, w)| w).sum()
    }
}

/// The atomically-swapped per-shard snapshot cell. Writers replace the
/// `Arc` wholesale; readers clone it. The `RwLock` is held only for the
/// pointer swap / refcount bump, never across a merge or a scan.
#[derive(Debug)]
pub struct EpochSlot {
    current: RwLock<Arc<EpochSnapshot>>,
}

impl EpochSlot {
    fn new(shard: usize, k: usize) -> Self {
        Self { current: RwLock::new(Arc::new(EpochSnapshot::initial(shard, k))) }
    }

    /// The latest published snapshot (cheap: refcount bump).
    pub fn load(&self) -> Arc<EpochSnapshot> {
        self.current.read().expect("epoch slot poisoned").clone()
    }

    fn store(&self, snap: Arc<EpochSnapshot>) {
        *self.current.write().expect("epoch slot poisoned") = snap;
    }
}

/// Shared state between the shard workers (publishers), the coordinator
/// (ingest accounting) and every [`QueryEngine`](super::QueryEngine)
/// handle (readers).
#[derive(Debug)]
pub struct EpochRegistry {
    slots: Vec<EpochSlot>,
    /// Monotonic refresh-request clock; shards publish when they observe
    /// a value newer than their last publication's request watermark.
    refresh_requests: AtomicU64,
    /// Total snapshots published across all shards.
    epochs_published: AtomicU64,
    /// Items accepted by the coordinator (routed to any shard) — the
    /// reader-visible ingest watermark used for staleness accounting.
    items_routed: AtomicU64,
    /// Queries served through engines attached to this registry.
    queries_served: AtomicU64,
    /// Monotonic *read-path version*: bumped on every snapshot
    /// publication and every hot-set install, i.e. on every event that
    /// can change what a merged view would contain. Between bumps the
    /// merged state is immutable, so a cached [`MergedSnapshot`]
    /// (`super::MergedSnapshot`) tagged with this counter's value stays
    /// valid for exactly as long as the value does — a single relaxed
    /// load is the entire validity check on the cache hit path. The
    /// bump happens strictly *after* the slot swap, so a reader that
    /// observes version `v` both before and after collecting
    /// [`latest`](Self::latest) is guaranteed its parts form one
    /// coherent view for `v` (a concurrent publish would have moved
    /// the version between the two reads).
    version: AtomicU64,
    /// Whether the per-shard snapshots are key-disjoint (keyed
    /// routing): the engine then merges by concatenation and reports
    /// the max-per-shard error bound. Set once before ingestion starts.
    disjoint: AtomicBool,
    /// Hot-set generations under `Routing::KeyedAdaptive`, indexed by
    /// generation number; generation 0 is the empty set every session
    /// starts in. The producer appends a new generation on every
    /// rebalance; shard workers resolve the generation stamped into
    /// each scattered sub-chunk against this table, so every
    /// occurrence is classified against exactly the hot set its
    /// producer scattered it under — no producer/worker race.
    hot_sets: RwLock<Vec<Arc<Vec<u64>>>>,
}

impl EpochRegistry {
    /// Registry for `shards` slots, each starting at the empty epoch 0
    /// with counter budget `k`.
    pub fn new(shards: usize, k: usize) -> Arc<Self> {
        assert!(shards >= 1);
        Arc::new(Self {
            slots: (0..shards).map(|s| EpochSlot::new(s, k)).collect(),
            refresh_requests: AtomicU64::new(0),
            epochs_published: AtomicU64::new(0),
            items_routed: AtomicU64::new(0),
            queries_served: AtomicU64::new(0),
            version: AtomicU64::new(0),
            disjoint: AtomicBool::new(false),
            hot_sets: RwLock::new(vec![Arc::new(Vec::new())]),
        })
    }

    /// Declare the per-shard snapshots key-disjoint (the coordinator
    /// calls this when spawned with keyed routing, before any worker
    /// publishes). Engines then use the disjoint merge and the
    /// max-per-shard error bound.
    pub fn set_disjoint(&self, disjoint: bool) {
        self.disjoint.store(disjoint, Ordering::Release);
    }

    /// Whether snapshots are key-disjoint (keyed routing).
    pub fn disjoint(&self) -> bool {
        self.disjoint.load(Ordering::Acquire)
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.slots.len()
    }

    /// The slot of one shard.
    pub fn slot(&self, shard: usize) -> &EpochSlot {
        &self.slots[shard]
    }

    /// Collect the latest snapshot of every shard. The per-shard arcs
    /// are each individually consistent; the set is the engine's epoch
    /// view.
    pub fn latest(&self) -> Vec<Arc<EpochSnapshot>> {
        self.slots.iter().map(EpochSlot::load).collect()
    }

    /// Publisher side: install shard `shard`'s next snapshot.
    /// `finished` marks the drain-time final publication.
    pub fn publish(&self, shard: usize, summary: Summary, finished: bool) -> u64 {
        self.publish_with_hot(shard, summary, finished, Vec::new())
    }

    /// [`EpochRegistry::publish`] carrying the shard's cumulative
    /// exact split-key partials (`Routing::KeyedAdaptive`; pass an
    /// empty vec otherwise).
    pub fn publish_with_hot(
        &self,
        shard: usize,
        summary: Summary,
        finished: bool,
        hot: Vec<(u64, u64)>,
    ) -> u64 {
        let slot = &self.slots[shard];
        let epoch = slot.load().epoch + 1;
        slot.store(Arc::new(EpochSnapshot {
            shard,
            epoch,
            summary,
            hot,
            published_at: Instant::now(),
            finished,
        }));
        self.epochs_published.fetch_add(1, Ordering::Relaxed);
        // Version bump strictly after the slot swap (see the field
        // doc): Release pairs with nothing in particular — the slot's
        // RwLock already orders snapshot data — but keeps the bump
        // from sinking below the store under any future refactor.
        self.version.fetch_add(1, Ordering::Release);
        epoch
    }

    /// Producer side: install a new hot-set generation (sorted key
    /// list) and return its generation number. Generation 0 — the
    /// empty set — always exists.
    pub fn publish_hot_set(&self, keys: Vec<u64>) -> u64 {
        let generation = {
            let mut sets = self.hot_sets.write().expect("hot set table poisoned");
            sets.push(Arc::new(keys));
            (sets.len() - 1) as u64
        };
        // A hot-set install changes what future publications will
        // carry; bump the read-path version so caches revalidate.
        self.version.fetch_add(1, Ordering::Release);
        generation
    }

    /// The hot set of a given generation (a stale stamp resolves to
    /// exactly the set it was scattered under — generations are only
    /// ever appended).
    pub fn hot_set(&self, generation: u64) -> Arc<Vec<u64>> {
        let sets = self.hot_sets.read().expect("hot set table poisoned");
        sets[generation as usize].clone()
    }

    /// The newest hot-set generation number (0 = empty initial set).
    pub fn hot_generation(&self) -> u64 {
        (self.hot_sets.read().expect("hot set table poisoned").len() - 1) as u64
    }

    /// Reader side: ask every shard to publish a fresh snapshot at its
    /// next opportunity (chunk boundary or idle poll). Returns the new
    /// request watermark.
    pub fn request_refresh(&self) -> u64 {
        self.refresh_requests.fetch_add(1, Ordering::Release) + 1
    }

    /// Publisher side: the current refresh watermark (compared against
    /// the value observed at the shard's last publication).
    pub fn refresh_watermark(&self) -> u64 {
        self.refresh_requests.load(Ordering::Acquire)
    }

    /// Ingest side: account items accepted into shard queues.
    pub fn add_items_routed(&self, items: u64) {
        self.items_routed.fetch_add(items, Ordering::Relaxed);
    }

    /// Items accepted by the coordinator so far.
    pub fn items_routed(&self) -> u64 {
        self.items_routed.load(Ordering::Relaxed)
    }

    /// Total snapshots published across all shards.
    pub fn epochs_published(&self) -> u64 {
        self.epochs_published.load(Ordering::Relaxed)
    }

    /// The current read-path version (see the `version` field): a
    /// cached merged view tagged with this value is valid until the
    /// value changes. Relaxed — validity comes from equality of two
    /// reads around the snapshot collection, not from ordering.
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Relaxed)
    }

    /// Count one served query.
    pub fn count_query(&self) {
        self.queries_served.fetch_add(1, Ordering::Relaxed);
    }

    /// Queries served so far.
    pub fn queries_served(&self) -> u64 {
        self.queries_served.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::summary::{FrequencySummary, SpaceSaving};

    fn summary_of(items: &[u64], k: usize) -> Summary {
        let mut ss = SpaceSaving::new(k);
        ss.offer_all(items);
        ss.freeze()
    }

    #[test]
    fn slots_start_empty_at_epoch_zero() {
        let reg = EpochRegistry::new(3, 8);
        for (i, snap) in reg.latest().iter().enumerate() {
            assert_eq!(snap.shard, i);
            assert_eq!(snap.epoch, 0);
            assert_eq!(snap.summary.n(), 0);
            assert!(!snap.finished);
        }
    }

    #[test]
    fn publish_bumps_epoch_and_swaps_snapshot() {
        let reg = EpochRegistry::new(2, 8);
        let old = reg.slot(1).load();
        let e1 = reg.publish(1, summary_of(&[7, 7, 9], 8), false);
        let e2 = reg.publish(1, summary_of(&[7, 7, 9, 9], 8), false);
        assert_eq!((e1, e2), (1, 2));
        // The reader's old arc still sees the old epoch (snapshot
        // isolation); a fresh load sees the new one.
        assert_eq!(old.epoch, 0);
        let now = reg.slot(1).load();
        assert_eq!(now.epoch, 2);
        assert_eq!(now.summary.estimate(9), Some(2));
        assert_eq!(reg.epochs_published(), 2);
        // Shard 0 untouched.
        assert_eq!(reg.slot(0).load().epoch, 0);
    }

    #[test]
    fn hot_set_generations_append_and_resolve() {
        let reg = EpochRegistry::new(2, 8);
        // Generation 0 is the empty set.
        assert_eq!(reg.hot_generation(), 0);
        assert!(reg.hot_set(0).is_empty());
        let g1 = reg.publish_hot_set(vec![42]);
        let g2 = reg.publish_hot_set(vec![42, 99]);
        assert_eq!((g1, g2), (1, 2));
        assert_eq!(reg.hot_generation(), 2);
        // Old generations stay resolvable — a worker holding a stale
        // stamp classifies against exactly the set it was scattered
        // under.
        assert_eq!(*reg.hot_set(1), vec![42]);
        assert_eq!(*reg.hot_set(2), vec![42, 99]);
        // Partials ride publications; plain publish carries none.
        reg.publish_with_hot(0, summary_of(&[1, 1], 8), false, vec![(42, 7)]);
        reg.publish(1, summary_of(&[3], 8), false);
        let parts = reg.latest();
        assert_eq!(parts[0].hot, vec![(42, 7)]);
        assert_eq!(parts[0].hot_mass(), 7);
        assert!(parts[1].hot.is_empty());
    }

    #[test]
    fn version_bumps_on_publish_and_hot_set_install() {
        let reg = EpochRegistry::new(2, 8);
        assert_eq!(reg.version(), 0);
        reg.publish(0, summary_of(&[1, 2], 8), false);
        assert_eq!(reg.version(), 1);
        reg.publish_with_hot(1, summary_of(&[3], 8), false, vec![(42, 5)]);
        assert_eq!(reg.version(), 2);
        // A hot-set install invalidates cached views too, even though
        // no slot moved.
        reg.publish_hot_set(vec![42]);
        assert_eq!(reg.version(), 3);
        // Refresh requests do NOT bump the version: they change
        // nothing a merged view contains until a shard publishes.
        reg.request_refresh();
        assert_eq!(reg.version(), 3);
    }

    #[test]
    fn refresh_watermark_is_monotonic() {
        let reg = EpochRegistry::new(1, 4);
        assert_eq!(reg.refresh_watermark(), 0);
        assert_eq!(reg.request_refresh(), 1);
        assert_eq!(reg.request_refresh(), 2);
        assert_eq!(reg.refresh_watermark(), 2);
    }

    #[test]
    fn concurrent_publish_and_load() {
        let reg = EpochRegistry::new(1, 16);
        std::thread::scope(|s| {
            let r = &reg;
            s.spawn(move || {
                for round in 1..=200u64 {
                    let items: Vec<u64> = (0..round).collect();
                    r.publish(0, summary_of(&items, 16), false);
                }
            });
            s.spawn(move || {
                let mut last_epoch = 0;
                for _ in 0..500 {
                    let snap = r.slot(0).load();
                    // Epochs never go backwards and n matches the
                    // published stream prefix exactly.
                    assert!(snap.epoch >= last_epoch);
                    assert_eq!(snap.summary.n(), snap.epoch);
                    last_epoch = snap.epoch;
                }
            });
        });
        let done = reg.slot(0).load();
        assert_eq!(done.epoch, 200);
        // Mass conservation holds on the final snapshot.
        assert_eq!(
            done.summary.counters().iter().map(|c| c.count).sum::<u64>(),
            200
        );
    }
}
