//! The live query engine: merged epoch views and the query API.
//!
//! A [`QueryEngine`] is a cheap-to-clone handle over the shared
//! [`EpochRegistry`]. Every query materializes a [`MergedSnapshot`]: it
//! collects the latest per-shard `Arc<EpochSnapshot>`s and runs the
//! paper's combine tree ([`tree_reduce_refs`]) over the *borrowed*
//! summaries — no copy of the per-shard counter sets, no coordination
//! with the writers. The merged summary carries the full Space Saving
//! guarantee for the union of the published prefixes:
//!
//! * no under-estimation: `f̂ ≥ f`,
//! * bounded over-estimation: `f̂ − f ≤ ε` with `ε = n_epoch / k`,
//! * k-majority recall: every item with `f > n_epoch / k` is monitored,
//!
//! where `n_epoch` is the merged snapshot's stream coverage (the sum of
//! the per-shard published `n`s) — the epoch the answer is *about*.
//!
//! Under **keyed routing** (`Routing::Keyed`) the per-shard snapshots
//! are key-disjoint, so the engine switches to the concatenation merge
//! ([`merge_disjoint`]) and the bound tightens from the additive
//! `⌊n_epoch/k⌋` to the **max-per-shard** `ε = maxᵢ ⌊nᵢ/k⌋ ≤
//! ⌊n_epoch/k⌋`: every estimate is its home shard's estimate, inflated
//! by nothing. Point queries for unmonitored items likewise bound by
//! the *home shard's* minimum count ([`crate::util::shard_of`]) rather
//! than the global one.
//!
//! Under **keyed-adaptive routing** the per-shard snapshots also carry
//! exact split-key partials ([`EpochSnapshot::hot`]): hot keys the
//! coordinator spread across all shards, counted outside the Space
//! Saving structures. The snapshot sums the partials per key and folds
//! them into the merged summary as exact mass
//! ([`crate::summary::absorb_exact`]); a split key's estimate is its
//! home-shard estimate plus the exact sum, so `ε` keeps the
//! max-per-shard bound of the Space Saving parts alone.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};
use std::time::{Duration, Instant};

use crate::metrics::{CacheCounters, CacheStats, LatencyHistogram, LatencySummary};
use crate::parallel::tree_reduce_refs;
use crate::summary::{absorb_exact, merge_disjoint, Counter, Summary};
use crate::util::{shard_of, FastMap};

use super::epoch::{EpochRegistry, EpochSnapshot};

/// A point-in-time, internally-consistent view over all shards.
///
/// Holding one pins the underlying per-shard snapshots (via `Arc`), so
/// repeated queries against it are answered from identical data even
/// while ingestion continues.
#[derive(Debug, Clone)]
pub struct MergedSnapshot {
    /// The merge of every shard's published summary (combine tree, or
    /// concatenation when the shards are key-disjoint), with any exact
    /// split-key partials already absorbed.
    merged: Summary,
    /// The pre-absorb merge — the pure Space Saving state before the
    /// exact hot partials were folded in. `None` when there were no
    /// partials (then `merged` *is* the pre-absorb state). Kept for
    /// the cluster snapshot export ([`MergedSnapshot::ss_summary`]):
    /// the head replays the absorb itself, so it needs the state from
    /// *before* it.
    ss_merged: Option<Summary>,
    /// The per-shard snapshots this view was built from.
    parts: Vec<Arc<EpochSnapshot>>,
    /// Key-disjoint shards (keyed routing)?
    disjoint: bool,
    /// The reported over-estimation bound: `⌊n/k⌋` of the merge, or
    /// the tighter `maxᵢ ⌊nᵢ/k⌋` in disjoint mode.
    epsilon: u64,
    /// Exact split-key totals (keyed-adaptive), summed over the parts'
    /// cumulative partials; sorted by key, already folded into
    /// `merged`. Empty outside the hot tier.
    hot_totals: Vec<(u64, u64)>,
    /// The registry's read-path version this view was built at
    /// ([`EpochRegistry::version`]); the snapshot cache's validity tag.
    version: u64,
    /// Lazily computed descending counter order, shared by all query
    /// sugar on this view (`top_k`/`top_k_guaranteed`/`threshold`):
    /// with the snapshot cache in front, repeated top-k queries pay
    /// this once per *publication*, not once per call.
    order: OnceLock<Vec<Counter>>,
    /// When the view was materialized.
    taken_at: Instant,
}

/// One shard's contribution to a [`MergedSnapshot`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EpochInfo {
    /// Shard index.
    pub shard: usize,
    /// Publication sequence number.
    pub epoch: u64,
    /// Items covered by that publication.
    pub n: u64,
    /// Final drain-time snapshot?
    pub finished: bool,
}

/// A frequency answer for a single item.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PointEstimate {
    /// Queried item.
    pub item: u64,
    /// Upper-bound estimate `f̂` (`f ≤ f̂` always). For unmonitored
    /// items this is the merged summary's minimum count — the tightest
    /// generic upper bound Space Saving offers.
    pub estimate: u64,
    /// Guaranteed lower bound (`f ≥ estimate − err`; 0 if unmonitored).
    pub guaranteed: u64,
    /// Whether the item held a counter in the merged summary.
    pub monitored: bool,
    /// Stream coverage of the answer (the epoch's `n`).
    pub n: u64,
}

/// Result of a threshold / k-majority query, split per the paper into
/// certainly-frequent and possibly-frequent items.
#[derive(Debug, Clone)]
pub struct ThresholdReport {
    /// The absolute frequency threshold applied (`f̂ > threshold`).
    pub threshold: u64,
    /// Items whose *lower bound* clears the threshold — true positives,
    /// no verification pass needed.
    pub guaranteed: Vec<Counter>,
    /// Items whose estimate clears the threshold but whose lower bound
    /// does not — candidates a replayable stream could verify offline.
    pub possible: Vec<Counter>,
    /// Stream coverage of the answer.
    pub n: u64,
    /// The bound every estimate in this report honors: ε = n/k, or the
    /// tighter max-per-shard bound under keyed routing.
    pub epsilon: u64,
}

impl MergedSnapshot {
    fn build(parts: Vec<Arc<EpochSnapshot>>, disjoint: bool, version: u64) -> Self {
        let leaves: Vec<&Summary> = parts.iter().map(|p| &p.summary).collect();
        let (merged, epsilon) = if disjoint {
            // Key-disjoint shards: concatenate, and report the
            // max-per-shard bound (see the module docs).
            let merged = merge_disjoint(&leaves);
            let epsilon = leaves.iter().map(|s| s.epsilon()).max().unwrap_or(0);
            (merged, epsilon)
        } else {
            let merged = tree_reduce_refs(&leaves);
            let epsilon = merged.epsilon();
            (merged, epsilon)
        };
        // Keyed-adaptive: fold the shards' exact split-key partials
        // into the merged view. ε stands as computed above — exact
        // mass adds no over-estimation. The fold is skipped outright in
        // every other routing mode (no part carries partials), and
        // runs on a FastMap-indexed accumulator rather than a BTreeMap
        // when it does — one probe per partial, one sort at the end.
        let hot_totals: Vec<(u64, u64)> = if parts.iter().all(|p| p.hot.is_empty()) {
            Vec::new()
        } else {
            let cap: usize = parts.iter().map(|p| p.hot.len()).sum();
            let mut idx = FastMap::with_capacity(cap);
            let mut acc: Vec<(u64, u64)> = Vec::with_capacity(cap);
            for p in &parts {
                for &(item, w) in &p.hot {
                    match idx.get(item) {
                        Some(i) => acc[i as usize].1 += w,
                        None => {
                            idx.insert(item, acc.len() as u32);
                            acc.push((item, w));
                        }
                    }
                }
            }
            // hot_totals is sorted by key (the absorb and the cluster
            // export both rely on it).
            acc.sort_unstable_by_key(|e| e.0);
            acc
        };
        let (merged, ss_merged) = if hot_totals.is_empty() {
            (merged, None)
        } else {
            // Inserted (home-evicted) split keys carry their home
            // shard's min_count as the bound on pre-split history.
            let absorbed = absorb_exact(&merged, &hot_totals, |item| {
                home_history_bound(&parts, item)
            });
            (absorbed, Some(merged))
        };
        Self {
            merged,
            ss_merged,
            parts,
            disjoint,
            epsilon,
            hot_totals,
            version,
            order: OnceLock::new(),
            taken_at: Instant::now(),
        }
    }

    /// The registry read-path version this view was built at: the
    /// snapshot cache serves this exact view for as long as
    /// [`EpochRegistry::version`] still reads this value.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Counters in descending estimate order, computed once per
    /// snapshot and shared by every query-sugar call on it.
    fn ordered(&self) -> &[Counter] {
        self.order.get_or_init(|| {
            // `counters()` is ascending; the descending order is its
            // reversal (ties keep the merge's relative order, exactly
            // as `Summary::top_k` reported them before the hoist).
            let mut desc: Vec<Counter> = self.merged.counters().to_vec();
            desc.reverse();
            desc
        })
    }

    /// The merged summary itself.
    pub fn summary(&self) -> &Summary {
        &self.merged
    }

    /// Stream coverage: total items represented by this view (sum of
    /// the per-shard published `n`s).
    pub fn n(&self) -> u64 {
        self.merged.n()
    }

    /// The over-estimation bound of this view: `ε = ⌊n/k⌋`, or the
    /// tighter max-per-shard `maxᵢ ⌊nᵢ/k⌋` under keyed routing.
    pub fn epsilon(&self) -> u64 {
        self.epsilon
    }

    /// Whether this view merged key-disjoint shards (keyed routing) —
    /// and therefore reports the max-per-shard bound.
    pub fn is_disjoint(&self) -> bool {
        self.disjoint
    }

    /// Per-shard epochs this view is made of.
    pub fn epochs(&self) -> Vec<EpochInfo> {
        self.parts
            .iter()
            .map(|p| EpochInfo {
                shard: p.shard,
                epoch: p.epoch,
                n: p.summary.n() + p.hot_mass(),
                finished: p.finished,
            })
            .collect()
    }

    /// Age of the *oldest* constituent shard snapshot.
    pub fn staleness(&self) -> Duration {
        self.parts
            .iter()
            .map(|p| self.taken_at.saturating_duration_since(p.published_at))
            .max()
            .unwrap_or_default()
    }

    /// Top-`m` items by estimated frequency, descending. A prefix copy
    /// of the hoisted per-snapshot order — no per-call re-derivation.
    pub fn top_k(&self, m: usize) -> Vec<Counter> {
        let desc = self.ordered();
        desc[..m.min(desc.len())].to_vec()
    }

    /// The prefix of [`MergedSnapshot::top_k`] whose order is certain
    /// (Metwally's guaranteed-top-k criterion: element `i`'s lower
    /// bound must reach element `i+1`'s estimate).
    pub fn top_k_guaranteed(&self, m: usize) -> Vec<Counter> {
        let desc = self.ordered();
        let take = m.min(desc.len());
        let mut out = Vec::with_capacity(take);
        for i in 0..take {
            let next_est = desc.get(i + 1).map_or(0, |c| c.count);
            if desc[i].guaranteed() >= next_est {
                out.push(desc[i]);
            } else {
                break;
            }
        }
        out
    }

    /// Frequency estimate for one item, with its certainty bounds.
    ///
    /// Under keyed routing the answer comes from the item's *home
    /// shard*: identical for monitored items (the disjoint merge keeps
    /// home counters intact), and a tighter, correct upper bound for
    /// unmonitored ones (the home shard's minimum count — the
    /// concatenation's global minimum would be wrong there).
    pub fn point(&self, item: u64) -> PointEstimate {
        if self.disjoint {
            let home = shard_of(item, self.parts.len());
            let part = self
                .parts
                .iter()
                .find(|p| p.shard == home)
                .map(|p| &p.summary)
                .expect("one snapshot per shard");
            let mut p = point_estimate(part, item);
            // Split keys (keyed-adaptive): the home counter covers the
            // pre-split prefix; the scattered occurrences live in the
            // exact partials. Their sum is exact mass, so it lifts the
            // lower bound too.
            let extra = self
                .hot_totals
                .iter()
                .find(|e| e.0 == item)
                .map_or(0, |e| e.1);
            if extra > 0 {
                p.estimate += extra;
                p.guaranteed += extra;
                p.monitored = true;
            }
            p.n = self.n(); // the answer is about the merged coverage
            p
        } else {
            point_estimate(&self.merged, item)
        }
    }

    /// Items above a relative threshold `phi` ∈ `[0, 1)`: `f̂ > phi·n`,
    /// split into guaranteed and possible (`phi = 0` reports every
    /// monitored item with a non-zero estimate).
    pub fn threshold(&self, phi: f64) -> ThresholdReport {
        assert!((0.0..1.0).contains(&phi), "phi must be in [0, 1)");
        self.threshold_abs((phi * self.n() as f64).floor() as u64)
    }

    /// The paper's k-majority query: all items with `f̂ > n/k_majority`.
    pub fn k_majority(&self, k_majority: u64) -> ThresholdReport {
        assert!(k_majority >= 2, "k_majority must be >= 2");
        self.threshold_abs(self.n() / k_majority)
    }

    fn threshold_abs(&self, threshold: u64) -> ThresholdReport {
        // Same split as [`threshold_split`], walking the hoisted
        // descending order instead of reversing `counters()` per call.
        let mut guaranteed = Vec::new();
        let mut possible = Vec::new();
        for c in self.ordered() {
            if c.count <= threshold {
                break;
            }
            if c.guaranteed() > threshold {
                guaranteed.push(*c);
            } else {
                possible.push(*c);
            }
        }
        ThresholdReport {
            threshold,
            guaranteed,
            possible,
            n: self.merged.n(),
            epsilon: self.epsilon,
        }
    }

    // -----------------------------------------------------------------
    // Cluster snapshot export: the pieces a worker process ships to the
    // cluster head so it can replay this node's merge *exactly*
    // (`rust/src/cluster`).

    /// The pre-absorb Space Saving merge — the node's merged summary
    /// *before* any exact split-key partials were folded in (identical
    /// to [`MergedSnapshot::summary`] when there were none). The
    /// cluster head ships this plus [`MergedSnapshot::hot_exports`]
    /// and replays the absorb itself after the cross-worker merge, so
    /// exact mass is folded exactly once, at the top.
    pub fn ss_summary(&self) -> &Summary {
        self.ss_merged.as_ref().unwrap_or(&self.merged)
    }

    /// Exact split-key totals with their home-shard history bounds:
    /// `(item, exact weight, bound on the pre-split prefix)` per hot
    /// key. Feeding these to [`crate::summary::absorb_exact`] over
    /// [`MergedSnapshot::ss_summary`] reproduces
    /// [`MergedSnapshot::summary`] bit for bit.
    pub fn hot_exports(&self) -> Vec<(u64, u64, u64)> {
        self.hot_totals
            .iter()
            .map(|&(item, w)| (item, w, home_history_bound(&self.parts, item)))
            .collect()
    }

    /// Upper bound on the true count of any item monitored *nowhere*
    /// in this view (neither a summary counter nor a hot key): the
    /// home-shard min-count maximized over shards in disjoint mode,
    /// the merged summary's min count otherwise. 0 while under-full.
    pub fn unmonitored_bound(&self) -> u64 {
        if self.disjoint {
            self.parts
                .iter()
                .map(|p| p.summary.min_count())
                .max()
                .unwrap_or(0)
        } else {
            self.ss_summary().min_count()
        }
    }

    /// Whether every constituent shard snapshot is a drain-time final.
    pub fn all_finished(&self) -> bool {
        self.parts.iter().all(|p| p.finished)
    }

    /// The newest per-shard publication sequence number in this view.
    pub fn max_epoch(&self) -> u64 {
        self.parts.iter().map(|p| p.epoch).max().unwrap_or(0)
    }
}

/// The home shard's minimum count for `item` — the bound on any
/// history a split key accumulated in its home Space Saving structure
/// before detection evicted it (shared by the absorb in
/// [`MergedSnapshot::build`] and the cluster export).
fn home_history_bound(parts: &[Arc<EpochSnapshot>], item: u64) -> u64 {
    let home = shard_of(item, parts.len());
    parts
        .iter()
        .find(|p| p.shard == home)
        .map_or(0, |p| p.summary.min_count())
}

/// Point query over any merged summary — shared by the landmark
/// ([`MergedSnapshot`]) and windowed
/// ([`WindowSnapshot`](crate::window::WindowSnapshot)) read paths.
pub(crate) fn point_estimate(summary: &Summary, item: u64) -> PointEstimate {
    let n = summary.n();
    match summary.counters().iter().find(|c| c.item == item) {
        Some(c) => PointEstimate {
            item,
            estimate: c.count,
            guaranteed: c.guaranteed(),
            monitored: true,
            n,
        },
        None => PointEstimate {
            item,
            estimate: summary.min_count(),
            guaranteed: 0,
            monitored: false,
            n,
        },
    }
}

/// Threshold query with the guaranteed-vs-possible split, over any
/// merged summary — shared by the landmark and windowed read paths.
/// `epsilon` is the bound the caller's view honors (`⌊n/k⌋`, or the
/// max-per-shard bound for disjoint merges).
pub(crate) fn threshold_split(
    summary: &Summary,
    threshold: u64,
    epsilon: u64,
) -> ThresholdReport {
    let mut guaranteed = Vec::new();
    let mut possible = Vec::new();
    // Counters are ascending; walk from the top so both outputs
    // come out descending by estimate.
    for c in summary.counters().iter().rev() {
        if c.count <= threshold {
            break;
        }
        if c.guaranteed() > threshold {
            guaranteed.push(*c);
        } else {
            possible.push(*c);
        }
    }
    ThresholdReport {
        threshold,
        guaranteed,
        possible,
        n: summary.n(),
        epsilon,
    }
}

/// Point-in-time engine statistics (staleness + query accounting).
#[derive(Debug, Clone)]
pub struct QueryEngineStats {
    /// Per-shard epochs of the latest published snapshots.
    pub epochs: Vec<EpochInfo>,
    /// Items accepted by the coordinator (ingest watermark).
    pub items_routed: u64,
    /// Items covered by the latest published snapshots (query watermark).
    pub items_published: u64,
    /// `items_routed − items_published`: how far the read path lags the
    /// write path, in items.
    pub staleness_items: u64,
    /// Snapshots published across all shards since spawn.
    pub epochs_published: u64,
    /// Queries served across all engine handles.
    pub queries_served: u64,
    /// Latency digest over every query served by this engine's registry.
    pub query_latency: LatencySummary,
    /// Snapshot-cache accounting (hits / misses / merges avoided),
    /// aggregated across every clone of this engine. All zero when the
    /// cache is disabled ([`QueryEngine::without_cache`]).
    pub cache: CacheStats,
}

/// The engine's epoch-versioned snapshot cache: one `Arc<MergedSnapshot>`
/// shared by every reader between registry version bumps.
///
/// Coherence protocol (the version counter is
/// [`EpochRegistry::version`], bumped after every publication and
/// hot-set install):
///
/// * **Hit path** — one relaxed version load; if it equals the cached
///   view's tag, the view is current and an `Arc` clone answers the
///   query. The `RwLock` read below is held only for the refcount
///   bump, same discipline as [`EpochSlot`](super::epoch::EpochSlot).
/// * **Rebuild path** — exactly one reader merges at a time (the
///   `rebuild` mutex); readers that lose the race wait and reuse the
///   winner's view instead of merging again, so a version bump costs
///   one merge total, never a thundering herd.
/// * **Seqlock collection** — the rebuilder reads the version, collects
///   [`EpochRegistry::latest`], and re-reads the version; only if the
///   two reads agree is the view installed under that tag. A publish
///   landing mid-collection would otherwise cache a mixed set of parts
///   under a version that never described them. The retry is bounded:
///   under a hard publisher race the reader serves its (individually
///   consistent, merely uncacheable) view without installing it.
///
/// Staleness semantics are unchanged by all of this: the cache only
/// dedups merges that would have produced identical views anyway.
#[derive(Debug)]
struct SnapshotCache {
    /// Version tag of the cached view; `u64::MAX` = nothing cached yet
    /// (the registry version itself starts at 0 and only grows).
    version: AtomicU64,
    /// The cached view; written only by a rebuild-lock holder.
    view: RwLock<Option<Arc<MergedSnapshot>>>,
    /// Serializes rebuilds (never held on the hit path).
    rebuild: Mutex<()>,
    /// Shared hit/miss accounting.
    counters: CacheCounters,
}

impl SnapshotCache {
    fn new() -> Self {
        Self {
            version: AtomicU64::new(u64::MAX),
            view: RwLock::new(None),
            rebuild: Mutex::new(()),
            counters: CacheCounters::new(),
        }
    }

    /// The cached view, if its tag matches registry version `v`.
    fn lookup(&self, v: u64) -> Option<Arc<MergedSnapshot>> {
        if self.version.load(Ordering::Acquire) != v {
            return None;
        }
        let view = self.view.read().expect("snapshot cache poisoned").clone()?;
        // The tag and the slot are written separately; the view's own
        // version is the authoritative check.
        (view.version() == v).then_some(view)
    }

    /// Install `view` as the cached answer for its version.
    fn install(&self, view: &Arc<MergedSnapshot>) {
        *self.view.write().expect("snapshot cache poisoned") = Some(view.clone());
        self.version.store(view.version(), Ordering::Release);
    }
}

/// Cheap-to-clone handle serving live queries over the shard epochs.
///
/// Landmark answers only (everything since startup); the sliding-window
/// sibling handle is handed out by
/// [`Coordinator::windows`](crate::coordinator::Coordinator::windows)
/// for sessions with a delta ring.
#[derive(Debug, Clone)]
pub struct QueryEngine {
    registry: Arc<EpochRegistry>,
    latency: Arc<LatencyHistogram>,
    /// The shared epoch-versioned snapshot cache ([`SnapshotCache`]);
    /// `None` = uncached, every query rebuilds the merge (the bench
    /// baseline). Shared across clones, so the serve layer's whole
    /// query pool reuses one merged view per registry version.
    cache: Option<Arc<SnapshotCache>>,
    k_majority: u64,
}

impl QueryEngine {
    /// Attach an engine to a registry. `k_majority` parameterizes
    /// [`QueryEngine::frequent`]. The snapshot cache is on by default.
    pub fn new(registry: Arc<EpochRegistry>, k_majority: u64) -> Self {
        Self {
            registry,
            latency: Arc::new(LatencyHistogram::new()),
            cache: Some(Arc::new(SnapshotCache::new())),
            k_majority,
        }
    }

    /// Disable the snapshot cache on this handle (and every clone made
    /// from it afterwards): every query rebuilds the merge from the
    /// latest shard epochs. Identical answers, none of the reuse — the
    /// measurable baseline for `pss bench --suite query`.
    pub fn without_cache(mut self) -> Self {
        self.cache = None;
        self
    }

    /// The shared registry (for publishers / the coordinator).
    pub fn registry(&self) -> &Arc<EpochRegistry> {
        &self.registry
    }

    /// Materialize a consistent merged view of the latest shard epochs.
    /// This is the only place merge work happens; all query sugar below
    /// goes through it.
    ///
    /// Between registry version bumps ([`EpochRegistry::version`]) the
    /// merged state is immutable, so concurrent callers share one
    /// `Arc<MergedSnapshot>` (see [`SnapshotCache`]); a publication
    /// invalidates the cached view within one version check.
    pub fn snapshot(&self) -> Arc<MergedSnapshot> {
        let t0 = Instant::now();
        let snap = self.snapshot_inner();
        self.latency.record(t0.elapsed());
        self.registry.count_query();
        snap
    }

    fn snapshot_inner(&self) -> Arc<MergedSnapshot> {
        let Some(cache) = &self.cache else {
            return Arc::new(self.build_fresh().0);
        };
        // Fast path: one relaxed version load + Arc clone.
        let v = self.registry.version();
        if let Some(view) = cache.lookup(v) {
            cache.counters.record_hit();
            cache.counters.record_merge_avoided();
            return view;
        }
        // Slow path: exactly one reader rebuilds.
        let _rebuild = cache.rebuild.lock().expect("snapshot cache poisoned");
        // Double-check: the winner of the race we just lost may have
        // installed the view we need while we waited.
        if let Some(view) = cache.lookup(self.registry.version()) {
            cache.counters.record_merge_avoided();
            return view;
        }
        let (snap, coherent) = self.build_fresh();
        let snap = Arc::new(snap);
        cache.counters.record_miss();
        if coherent {
            cache.install(&snap);
        }
        snap
    }

    /// Build a merged view, seqlock-validating that no publication
    /// landed while the per-shard parts were being collected. Returns
    /// `(view, coherent)`: an incoherent view (publisher racing hard)
    /// is still a valid answer — each part is individually consistent
    /// — but must not be installed in the cache, because its version
    /// tag never described exactly this set of parts.
    fn build_fresh(&self) -> (MergedSnapshot, bool) {
        for _ in 0..2 {
            let v1 = self.registry.version();
            let parts = self.registry.latest();
            if self.registry.version() == v1 {
                return (
                    MergedSnapshot::build(parts, self.registry.disjoint(), v1),
                    true,
                );
            }
        }
        let v = self.registry.version();
        let parts = self.registry.latest();
        (MergedSnapshot::build(parts, self.registry.disjoint(), v), false)
    }

    /// Snapshot-cache accounting (all zero when the cache is off).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.as_ref().map_or_else(CacheStats::default, |c| c.counters.stats())
    }

    /// Top-`m` most frequent items right now, descending.
    ///
    /// Convenience for `self.snapshot().top_k(m)`; take an explicit
    /// [`QueryEngine::snapshot`] instead when several queries must see
    /// the same epoch.
    ///
    /// # Example
    ///
    /// Publish one shard epoch by hand and query it (the coordinator
    /// normally does the publishing — see [`crate::coordinator::Coordinator::spawn`]):
    ///
    /// ```
    /// use pss::query::{EpochRegistry, QueryEngine};
    /// use pss::summary::{FrequencySummary, SpaceSaving};
    ///
    /// let registry = EpochRegistry::new(1, 8);
    /// let engine = QueryEngine::new(registry.clone(), 8);
    ///
    /// let mut shard = SpaceSaving::new(8);
    /// shard.offer_all(&[7, 7, 7, 2, 2, 5]);
    /// registry.publish(0, shard.freeze(), false);
    ///
    /// let top = engine.top_k(2);
    /// assert_eq!(top[0].item, 7);
    /// assert_eq!(top[0].count, 3);
    /// assert_eq!(top[1].item, 2);
    /// ```
    pub fn top_k(&self, m: usize) -> Vec<Counter> {
        self.snapshot().top_k(m)
    }

    /// Frequency estimate and bounds for one item right now.
    pub fn point(&self, item: u64) -> PointEstimate {
        self.snapshot().point(item)
    }

    /// Relative-threshold query (`f̂ > phi·n`) right now.
    pub fn threshold(&self, phi: f64) -> ThresholdReport {
        self.snapshot().threshold(phi)
    }

    /// The k-majority query at the engine's configured `k_majority`.
    pub fn frequent(&self) -> ThresholdReport {
        self.snapshot().k_majority(self.k_majority)
    }

    /// Ask all shards to publish fresh snapshots at their next
    /// opportunity (next chunk or idle poll). Non-blocking; the refresh
    /// lands asynchronously.
    pub fn refresh(&self) -> u64 {
        self.registry.request_refresh()
    }

    /// Staleness and throughput accounting for dashboards.
    pub fn stats(&self) -> QueryEngineStats {
        let parts = self.registry.latest();
        let items_published: u64 =
            parts.iter().map(|p| p.summary.n() + p.hot_mass()).sum();
        let items_routed = self.registry.items_routed();
        QueryEngineStats {
            epochs: parts
                .iter()
                .map(|p| EpochInfo {
                    shard: p.shard,
                    epoch: p.epoch,
                    n: p.summary.n() + p.hot_mass(),
                    finished: p.finished,
                })
                .collect(),
            items_routed,
            items_published,
            staleness_items: items_routed.saturating_sub(items_published),
            epochs_published: self.registry.epochs_published(),
            queries_served: self.registry.queries_served(),
            query_latency: self.latency.summary(),
            cache: self.cache_stats(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::summary::{FrequencySummary, SpaceSaving};
    use std::collections::HashMap;

    fn summary_of(items: &[u64], k: usize) -> Summary {
        let mut ss = SpaceSaving::new(k);
        ss.offer_all(items);
        ss.freeze()
    }

    fn engine(shards: usize, k: usize) -> QueryEngine {
        QueryEngine::new(EpochRegistry::new(shards, k), k as u64)
    }

    #[test]
    fn empty_engine_answers_empty() {
        let e = engine(4, 16);
        assert!(e.top_k(5).is_empty());
        let p = e.point(42);
        assert_eq!((p.estimate, p.guaranteed, p.monitored, p.n), (0, 0, false, 0));
        let t = e.frequent();
        assert!(t.guaranteed.is_empty() && t.possible.is_empty());
        assert_eq!(e.stats().queries_served, 3);
    }

    #[test]
    fn merged_view_unions_shards() {
        let e = engine(2, 16);
        e.registry().publish(0, summary_of(&[1, 1, 1, 2], 16), false);
        e.registry().publish(1, summary_of(&[1, 3, 3], 16), false);

        let snap = e.snapshot();
        assert_eq!(snap.n(), 7);
        // Under-full inputs merge exactly.
        assert_eq!(snap.point(1).estimate, 4);
        assert_eq!(snap.point(3).estimate, 2);
        assert_eq!(snap.point(3).guaranteed, 2);
        let top = snap.top_k(2);
        assert_eq!(top[0].item, 1);
        assert_eq!(
            snap.epochs(),
            vec![
                EpochInfo { shard: 0, epoch: 1, n: 4, finished: false },
                EpochInfo { shard: 1, epoch: 1, n: 3, finished: false },
            ]
        );
    }

    #[test]
    fn snapshot_is_pinned_while_ingest_advances() {
        let e = engine(1, 16);
        e.registry().publish(0, summary_of(&[5, 5], 16), false);
        let view = e.snapshot();
        // A newer epoch lands...
        e.registry().publish(0, summary_of(&[5, 5, 5, 5], 16), false);
        // ...the pinned view still answers from its epoch.
        assert_eq!(view.point(5).estimate, 2);
        assert_eq!(view.n(), 2);
        // A fresh snapshot sees the new epoch.
        assert_eq!(e.snapshot().point(5).estimate, 4);
    }

    #[test]
    fn point_reports_min_count_bound_for_unmonitored() {
        // Overflow a k=2 summary so min_count > 0.
        let e = engine(1, 2);
        e.registry()
            .publish(0, summary_of(&[1, 1, 1, 2, 2, 3], 2), false);
        let p = e.point(999);
        assert!(!p.monitored);
        assert!(p.estimate > 0, "absent items bound by min_count");
        assert_eq!(p.guaranteed, 0);
    }

    #[test]
    fn threshold_splits_guaranteed_and_possible() {
        let e = engine(1, 4);
        let counters = vec![
            Counter { item: 10, count: 50, err: 0 },
            Counter { item: 20, count: 30, err: 25 },
            Counter { item: 30, count: 10, err: 0 },
        ];
        e.registry()
            .publish(0, Summary::new(4, 100, counters), false);
        let t = e.threshold(0.2); // threshold = 20
        assert_eq!(t.threshold, 20);
        assert_eq!(t.guaranteed.iter().map(|c| c.item).collect::<Vec<_>>(), vec![10]);
        assert_eq!(t.possible.iter().map(|c| c.item).collect::<Vec<_>>(), vec![20]);
        // k-majority form agrees (100/5 = 20).
        let km = e.snapshot().k_majority(5);
        assert_eq!(km.threshold, 20);
        assert_eq!(km.guaranteed.len(), 1);
        assert_eq!(km.possible.len(), 1);
    }

    #[test]
    fn merged_bounds_hold_against_truth() {
        // 3 shards, skewed streams, k small enough to force evictions.
        let k = 32;
        let e = engine(3, k);
        let mut all: Vec<u64> = Vec::new();
        let mut rng = crate::util::SplitMix64::new(9);
        for shard in 0..3 {
            let items: Vec<u64> = (0..6_000)
                .map(|_| {
                    if rng.next_f64() < 0.5 {
                        rng.next_below(6)
                    } else {
                        rng.next_below(2_000)
                    }
                })
                .collect();
            all.extend_from_slice(&items);
            e.registry().publish(shard, summary_of(&items, k), false);
        }
        let mut truth: HashMap<u64, u64> = HashMap::new();
        for &i in &all {
            *truth.entry(i).or_default() += 1;
        }
        let snap = e.snapshot();
        assert_eq!(snap.n(), all.len() as u64);
        let eps = snap.epsilon();
        assert_eq!(eps, all.len() as u64 / k as u64);
        for c in snap.summary().counters() {
            let f = truth.get(&c.item).copied().unwrap_or(0);
            assert!(c.count >= f, "under-estimate");
            assert!(c.count - f <= eps, "epsilon bound broken");
            assert!(c.count - c.err <= f, "per-counter err bound broken");
        }
        // k-majority recall on the union.
        let monitored: std::collections::HashSet<u64> =
            snap.summary().counters().iter().map(|c| c.item).collect();
        for (item, f) in &truth {
            if *f > eps {
                assert!(monitored.contains(item), "lost frequent item {item}");
            }
        }
    }

    #[test]
    fn disjoint_mode_uses_home_shard_bounds() {
        use crate::util::shard_of;
        // Keyed-style split: every item fed only to its home shard,
        // shard masses deliberately imbalanced so the max-per-shard
        // bound differs from the additive one.
        let k = 8;
        let registry = EpochRegistry::new(2, k);
        registry.set_disjoint(true);
        let e = QueryEngine::new(registry, k as u64);
        let mut per_shard: Vec<Vec<u64>> = vec![Vec::new(); 2];
        for item in 0..400u64 {
            let copies = if item < 5 { 50 } else { 1 };
            let home = shard_of(item, 2);
            per_shard[home].extend(std::iter::repeat(item).take(copies));
        }
        let frozen: Vec<Summary> =
            per_shard.iter().map(|v| summary_of(v, k)).collect();
        for (s, f) in frozen.iter().enumerate() {
            e.registry().publish(s, f.clone(), false);
        }
        let snap = e.snapshot();
        assert!(snap.is_disjoint());
        let total: u64 = frozen.iter().map(|f| f.n()).sum();
        assert_eq!(snap.n(), total);
        let eps_max = frozen.iter().map(|f| f.epsilon()).max().unwrap();
        assert_eq!(snap.epsilon(), eps_max, "max-per-shard bound");
        assert!(snap.epsilon() <= total / k as u64, "tighter than summed");
        // Monitored point estimates are the home counters, untouched.
        for c in snap.summary().counters() {
            let home = &frozen[shard_of(c.item, 2)];
            assert_eq!(home.estimate(c.item), Some(c.count));
            let p = snap.point(c.item);
            assert_eq!(p.estimate, c.count);
            assert_eq!(p.n, total);
        }
        // Unmonitored items bound by their home shard's min count.
        let absent = (0u64..400)
            .find(|&i| shard_of(i, 2) == 0 && frozen[0].estimate(i).is_none())
            .unwrap();
        let p = snap.point(absent);
        assert!(!p.monitored);
        assert_eq!(p.estimate, frozen[0].min_count());
        // The k-majority report carries the tightened epsilon.
        assert_eq!(snap.k_majority(k as u64).epsilon, eps_max);
    }

    #[test]
    fn adaptive_split_partials_fold_exactly() {
        use crate::util::shard_of;
        // Keyed-adaptive read path: one split key homed at shard 0 with
        // 30 pre-split occurrences in its home Space Saving structure,
        // plus exact scattered partials on both shards (25 + 35). The
        // merged view must report home + Σ partials with no extra ε.
        let k = 8;
        let registry = EpochRegistry::new(2, k);
        registry.set_disjoint(true);
        let e = QueryEngine::new(registry, k as u64);
        let hot = (0u64..).find(|&i| shard_of(i, 2) == 0).unwrap();
        let filler0: Vec<u64> = (0u64..100)
            .filter(|&i| i != hot && shard_of(i, 2) == 0)
            .take(3)
            .collect();
        let filler1: Vec<u64> =
            (0u64..100).filter(|&i| shard_of(i, 2) == 1).take(3).collect();
        let mut s0: Vec<u64> = vec![hot; 30];
        s0.extend_from_slice(&filler0);
        let f0 = summary_of(&s0, k);
        let f1 = summary_of(&filler1, k);
        let eps = f0.epsilon().max(f1.epsilon());
        e.registry().publish_with_hot(0, f0, false, vec![(hot, 25)]);
        e.registry().publish_with_hot(1, f1, false, vec![(hot, 35)]);

        let snap = e.snapshot();
        assert!(snap.is_disjoint());
        let total = 30 + 3 + 3 + 60u64;
        assert_eq!(snap.n(), total, "coverage includes the split mass");
        // Exact partials add no over-estimation: ε is that of the
        // Space Saving parts alone.
        assert_eq!(snap.epsilon(), eps);
        // Point estimate = home counter + exact sum; exact mass lifts
        // the lower bound too.
        let p = snap.point(hot);
        assert!(p.monitored);
        assert_eq!(p.estimate, 90);
        assert_eq!(p.guaranteed, 90);
        assert_eq!(p.n, total);
        // The merged summary itself folded the mass (top-k agrees).
        assert_eq!(snap.summary().estimate(hot), Some(90));
        assert_eq!(snap.top_k(1)[0].item, hot);
        // Coverage accounting includes the split mass everywhere.
        assert_eq!(snap.epochs()[0].n, 33 + 25);
        assert_eq!(snap.epochs()[1].n, 3 + 35);
        assert_eq!(e.stats().items_published, total);
    }

    #[test]
    fn export_hook_reproduces_merge_from_preabsorb_state() {
        use crate::util::shard_of;
        // Same setup as the adaptive fold test: one split key with
        // exact partials on both shards. The export pieces must let a
        // third party (the cluster head) rebuild the merged summary
        // bit for bit: absorb_exact(ss_summary, hot_exports) == summary.
        let k = 8;
        let registry = EpochRegistry::new(2, k);
        registry.set_disjoint(true);
        let e = QueryEngine::new(registry, k as u64);
        let hot = (0u64..).find(|&i| shard_of(i, 2) == 0).unwrap();
        let mut s0: Vec<u64> = vec![hot; 30];
        s0.extend((0u64..100).filter(|&i| i != hot && shard_of(i, 2) == 0).take(3));
        let s1: Vec<u64> =
            (0u64..100).filter(|&i| shard_of(i, 2) == 1).take(3).collect();
        e.registry().publish_with_hot(0, summary_of(&s0, k), false, vec![(hot, 25)]);
        e.registry().publish_with_hot(1, summary_of(&s1, k), true, vec![(hot, 35)]);

        let snap = e.snapshot();
        // Pre-absorb state excludes the exact partial mass...
        assert_eq!(snap.ss_summary().n(), 36);
        assert_eq!(snap.summary().n(), 96);
        // ...and replaying the absorb from the exports reproduces the
        // final merged summary exactly.
        let exports = snap.hot_exports();
        assert_eq!(exports.len(), 1);
        assert_eq!((exports[0].0, exports[0].1), (hot, 60));
        let pairs: Vec<(u64, u64)> = exports.iter().map(|e| (e.0, e.1)).collect();
        let replayed = absorb_exact(snap.ss_summary(), &pairs, |item| {
            exports.iter().find(|e| e.0 == item).map_or(0, |e| e.2)
        });
        assert_eq!(replayed.counters(), snap.summary().counters());
        assert_eq!(replayed.n(), snap.summary().n());
        // Metadata accessors.
        assert!(!snap.all_finished(), "shard 0 not drained");
        assert_eq!(snap.max_epoch(), 1);
        // Under-full shards: nothing evicted anywhere, bound is 0.
        assert_eq!(snap.unmonitored_bound(), 0);

        // A view with no hot partials exports its summary verbatim.
        let e2 = engine(1, 2);
        e2.registry().publish(0, summary_of(&[1, 1, 1, 2, 2, 3], 2), true);
        let snap2 = e2.snapshot();
        assert_eq!(snap2.ss_summary().counters(), snap2.summary().counters());
        assert!(snap2.hot_exports().is_empty());
        assert!(snap2.all_finished());
        // Overfull single shard: the unmonitored bound is min_count.
        assert_eq!(snap2.unmonitored_bound(), snap2.summary().min_count());
        assert!(snap2.unmonitored_bound() > 0);
    }

    #[test]
    fn stats_track_staleness_and_latency() {
        let e = engine(2, 8);
        e.registry().add_items_routed(100);
        e.registry().publish(0, summary_of(&[1; 40], 8), false);
        let s = e.stats();
        assert_eq!(s.items_routed, 100);
        assert_eq!(s.items_published, 40);
        assert_eq!(s.staleness_items, 60);
        assert_eq!(s.epochs_published, 1);
        let _ = e.top_k(1);
        assert_eq!(e.stats().query_latency.count, 1);
    }

    #[test]
    fn snapshot_cache_reuses_views_between_publications() {
        let e = engine(2, 16);
        e.registry().publish(0, summary_of(&[1, 1, 2], 16), false);
        let a = e.snapshot();
        let b = e.snapshot();
        assert!(Arc::ptr_eq(&a, &b), "same version must share one view");
        let s = e.cache_stats();
        assert_eq!((s.hits, s.misses, s.merges_avoided), (1, 1, 1));
        // A publication invalidates within one version check.
        e.registry().publish(1, summary_of(&[3], 16), false);
        let c = e.snapshot();
        assert!(!Arc::ptr_eq(&b, &c), "stale view must not be served");
        assert_eq!(c.point(3).estimate, 1);
        assert_eq!(c.version(), e.registry().version());
        assert_eq!(e.cache_stats().misses, 2);
        // Clones share the cache and its accounting — the serve pool
        // relies on this.
        let d = e.clone().snapshot();
        assert!(Arc::ptr_eq(&c, &d));
        assert_eq!(e.cache_stats().hits, 2);
        // Cache stats surface through the engine stats, and every
        // query was still counted on both paths.
        let stats = e.stats();
        assert_eq!(stats.cache.hits, 2);
        assert_eq!(stats.queries_served, 4);
        assert_eq!(stats.query_latency.count, 4);
    }

    #[test]
    fn hot_set_install_invalidates_cached_view() {
        let e = engine(1, 8);
        e.registry().publish(0, summary_of(&[1], 8), false);
        let a = e.snapshot();
        e.registry().publish_hot_set(vec![42]);
        let b = e.snapshot();
        assert!(!Arc::ptr_eq(&a, &b), "hot-set install must invalidate");
        assert_eq!(e.cache_stats().misses, 2);
    }

    #[test]
    fn uncached_engine_rebuilds_every_query() {
        let e = engine(1, 8).without_cache();
        e.registry().publish(0, summary_of(&[5, 5], 8), false);
        let a = e.snapshot();
        let b = e.snapshot();
        assert!(!Arc::ptr_eq(&a, &b), "uncached queries build fresh views");
        assert_eq!(a.summary().counters(), b.summary().counters());
        assert_eq!(a.version(), b.version());
        assert_eq!(e.cache_stats(), crate::metrics::CacheStats::default());
        // Query accounting is path-independent.
        assert_eq!(e.stats().queries_served, 2);
        assert_eq!(e.stats().cache.merges_avoided, 0);
    }

    #[test]
    fn cached_sugar_answers_match_uncached() {
        // Same registry behind a cached and an uncached engine: every
        // sugar query must agree exactly.
        let registry = EpochRegistry::new(2, 16);
        let cached = QueryEngine::new(registry.clone(), 8);
        let uncached = QueryEngine::new(registry.clone(), 8).without_cache();
        registry.publish(0, summary_of(&[1, 1, 1, 2, 2, 7], 16), false);
        registry.publish(1, summary_of(&[1, 7, 7, 9], 16), false);
        for _ in 0..3 {
            assert_eq!(cached.top_k(4), uncached.top_k(4));
            assert_eq!(cached.point(7), uncached.point(7));
            assert_eq!(cached.point(999), uncached.point(999));
            let (a, b) = (cached.frequent(), uncached.frequent());
            assert_eq!(a.threshold, b.threshold);
            assert_eq!(a.guaranteed, b.guaranteed);
            assert_eq!(a.possible, b.possible);
            let (a, b) = (cached.threshold(0.2), uncached.threshold(0.2));
            assert_eq!(a.guaranteed, b.guaranteed);
            assert_eq!(a.possible, b.possible);
        }
        assert!(cached.cache_stats().hits > 0);
    }

    #[test]
    fn snapshot_sugar_shares_the_hoisted_order() {
        let e = engine(1, 8);
        e.registry()
            .publish(0, summary_of(&[1, 1, 1, 1, 2, 2, 2, 3, 3, 4], 8), false);
        let snap = e.snapshot();
        // All sugar forms agree with the underlying Summary methods.
        assert_eq!(snap.top_k(3), snap.summary().top_k(3));
        assert_eq!(snap.top_k(99), snap.summary().top_k(99));
        assert_eq!(snap.top_k_guaranteed(3), snap.summary().top_k_guaranteed(3));
        assert_eq!(
            snap.top_k_guaranteed(99),
            snap.summary().top_k_guaranteed(99)
        );
        let t = snap.threshold(0.15);
        let reference = threshold_split(snap.summary(), t.threshold, snap.epsilon());
        assert_eq!(t.guaranteed, reference.guaranteed);
        assert_eq!(t.possible, reference.possible);
    }
}
