//! The worker side of cluster mode — a thin lifecycle wrapper around
//! `serve::Server`.
//!
//! A worker *is* a full serve-layer server (it accepts ingest and
//! query connections like any other), plus the v2 worker role: the
//! head's `SummaryRequest { drain: true }` takes the coordinator,
//! drains it, replies with the final snapshot and flips the server's
//! shutdown flag — so "run until the head drains me" is just bind,
//! wait, finish.

use crate::coordinator::QueryResult;
use crate::serve::{Endpoint, ServeConfig, ServeStats, Server};

/// Bind a worker on `endpoint` and run it until a cluster head drains
/// it (or `Server::request_shutdown` fires from another thread).
/// `announce` is called once with the bound endpoint — the CLI prints
/// it, tests capture it.
pub fn run_worker(
    endpoint: &Endpoint,
    cfg: ServeConfig,
    mut announce: impl FnMut(&Endpoint),
) -> crate::Result<(QueryResult, ServeStats)> {
    let server = Server::bind(endpoint, cfg)?;
    announce(server.endpoint());
    server.wait_shutdown(None);
    Ok(server.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::serve::SnapshotClient;

    /// The full worker lifecycle in-process: run_worker blocks until a
    /// head-style drain arrives, then returns the drained result.
    #[test]
    fn run_worker_lives_until_drained() {
        let dir = crate::util::TempDir::new().unwrap();
        let sock = dir.path().join("w.sock");
        let endpoint = Endpoint::Unix(sock);
        let cfg = ServeConfig {
            coordinator: CoordinatorConfig {
                shards: 2,
                k: 32,
                k_majority: 8,
                epoch_items: 100,
                ..Default::default()
            },
            query_threads: 1,
            ..Default::default()
        };

        let ep = endpoint.clone();
        let worker = std::thread::spawn(move || run_worker(&ep, cfg, |_| {}));

        // The worker binds asynchronously; retry until it accepts.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        let mut ing = loop {
            match crate::serve::IngestClient::connect(&endpoint) {
                Ok(c) => break c,
                Err(e) => {
                    assert!(std::time::Instant::now() < deadline, "worker never bound: {e}");
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
            }
        };
        ing.send_runs(&[(3, 70), (9, 30)]).unwrap();
        ing.finish().unwrap();

        let fin = SnapshotClient::connect(&endpoint).unwrap().drain().unwrap();
        assert!(fin.finished);
        assert_eq!(fin.total_mass(), 100);

        let (result, stats) = worker.join().unwrap().unwrap();
        assert_eq!(result.stats.items, 100);
        assert_eq!(stats.worker_connections, 1);
        assert_eq!(result.summary.counters().iter().find(|c| c.item == 3).unwrap().count, 70);
        // A cleanly drained worker unlinks its own listener socket —
        // the same invariant head-side supervision enforces for
        // workers that die (no stale socket files either way).
        if let Endpoint::Unix(path) = &endpoint {
            assert!(!path.exists(), "drained worker left its socket file behind");
        }
    }
}
