//! Wire snapshots → validated worker summaries → the merged cluster
//! view.
//!
//! A worker ships its **pre-absorb** merged summary plus its exact hot
//! side table ([`crate::serve::WireSnapshot`]); the head validates the
//! frame into a [`WorkerSummary`], merges all workers with the summary
//! algebra from `summary/` and replays the exact-mass absorb *once, at
//! the top* — so a hot key's estimate is `home estimate + Σ exact
//! partials` and the worker-computed ε bounds survive the cross-process
//! hop.
//!
//! ## The ε bound across processes
//!
//! Which merge (and which bound) is sound depends on how the head
//! routed the stream ([`ClusterRouting`]):
//!
//! * **Keyed** — the head partitions by `shard_of(item, P)`, so worker
//!   substreams are pairwise key-disjoint. The merge is concatenation
//!   ([`merge_disjoint`]) and every counter keeps its home worker's
//!   error, so the view-wide bound is `ε = maxᵢ ⌊nᵢ/kᵢ⌋` — each
//!   worker's own bound, not the sum.
//! * **Block** — whole chunks round-robin across workers and any key
//!   may appear anywhere. The merge is the paper's Algorithm 2
//!   [`Summary::combine`] over a recursive-halving tree, whose error
//!   adds one `min_count ≤ εᵢ` per combine, so the sound view-wide
//!   bound is `ε = Σᵢ ⌊nᵢ/k⌋`.
//!
//! Both use the *worker-computed* ε shipped in the snapshot (itself the
//! max-per-shard bound when the worker routes keyed internally) rather
//! than recomputing `n/k` at the head: the post-absorb `n` is inflated
//! by exact hot mass and the absorb may widen `k`, so a head-side
//! `n/k` would *understate* the true bound.

use crate::query::engine::{point_estimate, threshold_split, PointEstimate, ThresholdReport};
use crate::serve::WireSnapshot;
use crate::summary::{absorb_exact, merge_disjoint, Counter, Summary};
use std::collections::HashMap;

/// How the head partitions ingest across worker processes. Mirrors the
/// in-process `Routing` split: `Keyed` is the hybrid decomposition the
/// paper's MPI level uses (hash-partitioned ranks), `Block` is the
/// throughput-first round-robin that needs the additive combine bound.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ClusterRouting {
    /// Hash-partition by item: worker `shard_of(item, P)` owns the key.
    #[default]
    Keyed,
    /// Round-robin whole chunks: any worker may see any key.
    Block,
}

impl std::fmt::Display for ClusterRouting {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterRouting::Keyed => write!(f, "keyed"),
            ClusterRouting::Block => write!(f, "block"),
        }
    }
}

impl std::str::FromStr for ClusterRouting {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "keyed" => Ok(ClusterRouting::Keyed),
            "block" => Ok(ClusterRouting::Block),
            other => Err(format!("unknown cluster routing '{other}' (keyed|block)")),
        }
    }
}

/// A snapshot that decoded cleanly off the wire but does not describe a
/// valid Space Saving state. Kept separate from
/// [`crate::serve::ProtoError`]: the frame was well-formed, the
/// *semantics* were not — a malicious or buggy worker must not be able
/// to panic the head (e.g. `Summary::new` asserts `len ≤ k`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SnapshotError {
    /// `k = 0` — no Space Saving summary has zero budget.
    ZeroBudget,
    /// More counters than the budget admits (`len > k`).
    Overfull { len: usize, k: u64 },
    /// A counter claiming `err > count` (its guaranteed lower bound
    /// would underflow).
    NegativeGuarantee { item: u64 },
    /// Σ counter counts exceeds the claimed stream mass `n` is allowed
    /// to support — specifically a single counter with `count > n`.
    CountExceedsMass { item: u64 },
}

impl std::fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SnapshotError::ZeroBudget => write!(f, "snapshot has k = 0"),
            SnapshotError::Overfull { len, k } => {
                write!(f, "snapshot has {len} counters but budget k = {k}")
            }
            SnapshotError::NegativeGuarantee { item } => {
                write!(f, "counter for item {item} has err > count")
            }
            SnapshotError::CountExceedsMass { item } => {
                write!(f, "counter for item {item} exceeds the snapshot's stream mass")
            }
        }
    }
}

impl std::error::Error for SnapshotError {}

/// One worker's validated contribution to a cluster merge: the
/// pre-absorb summary, the exact hot partials (as [`Counter`]s whose
/// `err` carries the home-shard history bound), and the derived
/// quantities the head must take from the worker instead of
/// recomputing.
#[derive(Debug, Clone)]
pub struct WorkerSummary {
    /// Newest epoch covered by any shard of this worker.
    pub epoch: u64,
    /// The worker's merged summary *before* hot-mass absorption.
    pub summary: Summary,
    /// Exact hot partials: `item`, `count` = exact weight, `err` = the
    /// home-shard history bound to use if the item must be inserted.
    pub hot: Vec<Counter>,
    /// The worker-computed over-estimation bound for its view.
    pub epsilon: u64,
    /// The worker's upper bound for items it does not monitor.
    pub min_count: u64,
    /// Whether the worker's internal shards were key-disjoint.
    pub disjoint: bool,
    /// Whether this is the worker's *final* (drained) state.
    pub finished: bool,
    /// Whether the worker was alive to contribute this state. Dead
    /// workers are represented by [`WorkerSummary::lost`] placeholders
    /// so the merged view can report the full slot count.
    pub live: bool,
}

impl WorkerSummary {
    /// Total stream mass this worker accounts for (Space Saving mass
    /// plus exact hot mass).
    pub fn total_mass(&self) -> u64 {
        self.summary.n() + self.hot.iter().map(|c| c.count).sum::<u64>()
    }

    /// The placeholder for a worker that died: contributes nothing and
    /// is skipped by the merge (including the block equal-budget
    /// check), but keeps the slot visible so the merged view can
    /// report `workers_live` / `workers_total` and flag itself
    /// [`degraded`](ClusterView::degraded).
    pub fn lost() -> WorkerSummary {
        WorkerSummary {
            epoch: 0,
            summary: Summary::new(1, 0, Vec::new()),
            hot: Vec::new(),
            epsilon: 0,
            min_count: 0,
            disjoint: false,
            finished: false,
            live: false,
        }
    }
}

impl TryFrom<WireSnapshot> for WorkerSummary {
    type Error = SnapshotError;

    fn try_from(w: WireSnapshot) -> Result<Self, SnapshotError> {
        if w.k == 0 {
            return Err(SnapshotError::ZeroBudget);
        }
        if w.counters.len() as u64 > w.k {
            return Err(SnapshotError::Overfull { len: w.counters.len(), k: w.k });
        }
        let mut counters = Vec::with_capacity(w.counters.len());
        for c in &w.counters {
            if c.err > c.count {
                return Err(SnapshotError::NegativeGuarantee { item: c.item });
            }
            if c.count > w.n {
                return Err(SnapshotError::CountExceedsMass { item: c.item });
            }
            counters.push(Counter { item: c.item, count: c.count, err: c.err });
        }
        let mut hot = Vec::with_capacity(w.hot.len());
        for c in &w.hot {
            if c.err > c.count {
                return Err(SnapshotError::NegativeGuarantee { item: c.item });
            }
            hot.push(Counter { item: c.item, count: c.count, err: c.err });
        }
        Ok(WorkerSummary {
            epoch: w.epoch,
            summary: Summary::new(w.k as usize, w.n, counters),
            hot,
            epsilon: w.epsilon,
            min_count: w.min_count,
            disjoint: w.disjoint,
            finished: w.finished,
            live: true,
        })
    }
}

/// A cluster-level merge failure (distinct from per-snapshot
/// validation: the inputs were individually valid but cannot be merged
/// under the requested routing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterError {
    /// No worker snapshots to merge.
    NoWorkers,
    /// Block-routing combine requires every worker to run the same
    /// budget `k` (the paper's Algorithm 2 precondition).
    MismatchedBudget { expected: usize, got: usize, worker: usize },
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::NoWorkers => write!(f, "no worker snapshots to merge"),
            ClusterError::MismatchedBudget { expected, got, worker } => write!(
                f,
                "block combine needs equal budgets: worker {worker} has k = {got}, expected {expected}"
            ),
        }
    }
}

impl std::error::Error for ClusterError {}

/// Fold `parts` left to right with [`Summary::combine`] — the head
/// merges every leaf itself, `P − 1` sequential combines. The flat
/// strategy the paper's Figure 4 compares against.
pub fn flat_combine(parts: &[&Summary]) -> Summary {
    assert!(!parts.is_empty(), "nothing to combine");
    let mut acc = parts[0].clone();
    for p in &parts[1..] {
        acc = acc.combine(p);
    }
    acc
}

/// Recursive-halving combine: split the leaf set in half, merge each
/// half, combine the two results — `⌈log₂ P⌉` rounds of pairwise
/// [`Summary::combine`], the tree strategy of the paper's hybrid
/// decomposition. Same result mass as [`flat_combine`] (combine is
/// associative in `n`), but the critical path is logarithmic when the
/// pairwise merges run on different ranks.
pub fn tree_combine(parts: &[&Summary]) -> Summary {
    assert!(!parts.is_empty(), "nothing to combine");
    if parts.len() == 1 {
        return parts[0].clone();
    }
    let mid = parts.len() / 2;
    tree_combine(&parts[..mid]).combine(&tree_combine(&parts[mid..]))
}

/// The head's merged, queryable view of the whole cluster — the same
/// read API shape as the in-process `MergedSnapshot` (top-k, point,
/// k-majority) with cluster-scope bounds.
#[derive(Debug, Clone)]
pub struct ClusterView {
    merged: Summary,
    routing: ClusterRouting,
    epsilon: u64,
    unmonitored: u64,
    workers_total: usize,
    workers_live: usize,
    finished: bool,
    max_epoch: u64,
}

impl ClusterView {
    /// Merge validated worker summaries under `routing`. Slots marked
    /// dead ([`WorkerSummary::lost`]) are skipped by the merge, the ε
    /// accounting, and the block equal-budget check — the view covers
    /// the survivors only and says so ([`ClusterView::degraded`],
    /// [`ClusterView::workers_live`]). Zero survivors is
    /// [`ClusterError::NoWorkers`].
    ///
    /// Keyed: concatenate ([`merge_disjoint`] — debug builds assert the
    /// caller really did key-partition), `ε = maxᵢ εᵢ` over live
    /// workers. Block: recursive-halving [`tree_combine`] (equal `k`
    /// required), `ε = Σᵢ εᵢ` over live workers — survivor-only sums
    /// are sound because the merged state contains survivor substreams
    /// only; the dead workers' mass is *absent*, not approximated.
    /// Either way the exact hot partials are summed per item across
    /// live workers and absorbed once at the top, with the summed
    /// history bounds.
    pub fn build(
        workers: &[WorkerSummary],
        routing: ClusterRouting,
    ) -> Result<ClusterView, ClusterError> {
        let live: Vec<(usize, &WorkerSummary)> =
            workers.iter().enumerate().filter(|(_, w)| w.live).collect();
        if live.is_empty() {
            return Err(ClusterError::NoWorkers);
        }
        let leaves: Vec<&Summary> = live.iter().map(|(_, w)| &w.summary).collect();
        let (ss, epsilon, unmonitored) = match routing {
            ClusterRouting::Keyed => (
                merge_disjoint(&leaves),
                live.iter().map(|(_, w)| w.epsilon).max().unwrap_or(0),
                live.iter().map(|(_, w)| w.min_count).max().unwrap_or(0),
            ),
            ClusterRouting::Block => {
                let expected = leaves[0].k();
                for ((i, _), l) in live.iter().zip(&leaves) {
                    if l.k() != expected {
                        return Err(ClusterError::MismatchedBudget {
                            expected,
                            got: l.k(),
                            worker: *i,
                        });
                    }
                }
                (
                    tree_combine(&leaves),
                    live.iter().map(|(_, w)| w.epsilon).sum(),
                    live.iter().map(|(_, w)| w.min_count).sum(),
                )
            }
        };

        // Exact hot partials: sum weights per item across workers
        // (keyed routing puts an item on one worker only; block may
        // split it). History bounds add — each worker's bound covers
        // the history *it* may have evicted.
        let mut extras: Vec<(u64, u64)> = Vec::new();
        let mut bounds: HashMap<u64, u64> = HashMap::new();
        for (_, w) in &live {
            for c in &w.hot {
                match extras.iter_mut().find(|(item, _)| *item == c.item) {
                    Some((_, weight)) => *weight += c.count,
                    None => extras.push((c.item, c.count)),
                }
                *bounds.entry(c.item).or_insert(0) += c.err;
            }
        }
        let merged = if extras.is_empty() {
            ss
        } else {
            absorb_exact(&ss, &extras, |item| bounds.get(&item).copied().unwrap_or(0))
        };

        Ok(ClusterView {
            merged,
            routing,
            epsilon,
            unmonitored,
            workers_total: workers.len(),
            workers_live: live.len(),
            finished: live.iter().all(|(_, w)| w.finished),
            max_epoch: live.iter().map(|(_, w)| w.epoch).max().unwrap_or(0),
        })
    }

    /// The merged cluster summary (post-absorb).
    pub fn summary(&self) -> &Summary {
        &self.merged
    }

    /// Total stream mass across the cluster.
    pub fn n(&self) -> u64 {
        self.merged.n()
    }

    /// The bound every estimate honors: `maxᵢ εᵢ` (keyed) or `Σᵢ εᵢ`
    /// (block) — see the module docs for why the head must not
    /// recompute `n/k`.
    pub fn epsilon(&self) -> u64 {
        self.epsilon
    }

    /// How the merged substreams were routed.
    pub fn routing(&self) -> ClusterRouting {
        self.routing
    }

    /// Number of live workers merged into this view.
    pub fn workers(&self) -> usize {
        self.workers_live
    }

    /// Worker slots the cluster was built with, live and dead.
    pub fn workers_total(&self) -> usize {
        self.workers_total
    }

    /// Workers that actually contributed (alias of
    /// [`ClusterView::workers`], named for degraded-mode reporting).
    pub fn workers_live(&self) -> usize {
        self.workers_live
    }

    /// Whether any worker slot was dead when this view was merged: the
    /// view covers the surviving substreams only.
    pub fn degraded(&self) -> bool {
        self.workers_live < self.workers_total
    }

    /// Whether every *live* worker contributed its *final* (drained)
    /// state.
    pub fn all_finished(&self) -> bool {
        self.finished
    }

    /// Newest epoch covered by any worker.
    pub fn max_epoch(&self) -> u64 {
        self.max_epoch
    }

    /// Top-`m` by estimate, descending.
    pub fn top_k(&self, m: usize) -> Vec<Counter> {
        self.merged.top_k(m)
    }

    /// The certainly-ordered prefix of [`ClusterView::top_k`].
    pub fn top_k_guaranteed(&self, m: usize) -> Vec<Counter> {
        self.merged.top_k_guaranteed(m)
    }

    /// Point estimate for one item. For unmonitored items the upper
    /// bound is the cluster-scope unmonitored bound (max worker
    /// `min_count` under keyed routing — the item's home worker bound
    /// dominates; their sum under block — it could hide on any worker).
    pub fn point(&self, item: u64) -> PointEstimate {
        let mut p = point_estimate(&self.merged, item);
        if !p.monitored {
            p.estimate = self.unmonitored;
        }
        p
    }

    /// The paper's k-majority query at cluster scope: items with
    /// `f̂ > N/k` over the *cluster-wide* mass `N`, split into
    /// guaranteed and possible.
    pub fn k_majority(&self, k_majority: u64) -> ThresholdReport {
        assert!(k_majority >= 2, "k_majority must be >= 2");
        threshold_split(&self.merged, self.n() / k_majority, self.epsilon)
    }

    /// Relative threshold `phi` ∈ `[0, 1)`: `f̂ > phi·N`.
    pub fn threshold(&self, phi: f64) -> ThresholdReport {
        assert!((0.0..1.0).contains(&phi), "phi must be in [0, 1)");
        threshold_split(
            &self.merged,
            (phi * self.n() as f64).floor() as u64,
            self.epsilon,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::WireCounter;

    fn wire(
        n: u64,
        k: u64,
        counters: &[(u64, u64, u64)],
        hot: &[(u64, u64, u64)],
    ) -> WireSnapshot {
        WireSnapshot {
            epoch: 1,
            n,
            k,
            epsilon: if k == 0 { 0 } else { n / k },
            min_count: if counters.len() as u64 == k {
                counters.iter().map(|c| c.1).min().unwrap_or(0)
            } else {
                0
            },
            disjoint: false,
            finished: false,
            counters: counters
                .iter()
                .map(|&(item, count, err)| WireCounter { item, count, err })
                .collect(),
            hot: hot
                .iter()
                .map(|&(item, count, err)| WireCounter { item, count, err })
                .collect(),
        }
    }

    #[test]
    fn invalid_snapshots_are_typed_errors_not_panics() {
        let e = WorkerSummary::try_from(wire(10, 0, &[], &[])).unwrap_err();
        assert_eq!(e, SnapshotError::ZeroBudget);

        // 3 counters into a k=2 budget would trip Summary::new's
        // assert — must surface as Overfull instead.
        let e = WorkerSummary::try_from(wire(
            30,
            2,
            &[(1, 10, 0), (2, 10, 0), (3, 10, 0)],
            &[],
        ))
        .unwrap_err();
        assert_eq!(e, SnapshotError::Overfull { len: 3, k: 2 });

        let e = WorkerSummary::try_from(wire(10, 4, &[(1, 3, 5)], &[])).unwrap_err();
        assert_eq!(e, SnapshotError::NegativeGuarantee { item: 1 });

        let e = WorkerSummary::try_from(wire(10, 4, &[(1, 11, 0)], &[])).unwrap_err();
        assert_eq!(e, SnapshotError::CountExceedsMass { item: 1 });

        let e = WorkerSummary::try_from(wire(10, 4, &[(1, 5, 0)], &[(2, 3, 7)])).unwrap_err();
        assert_eq!(e, SnapshotError::NegativeGuarantee { item: 2 });
    }

    /// Hand-traced keyed-merge oracle.
    ///
    /// Worker 0 (keys ≡ 0 mod 2): n=100, k=10, ε=10, counters
    /// {2: (60, 4), 4: (30, 0)}, hot {8: weight 25, bound 4}.
    /// Worker 1 (keys ≡ 1 mod 2): n=40, k=10, ε=4, counters
    /// {3: (25, 2), 5: (10, 0)}.
    ///
    /// Keyed merge: concatenation → n = 140, every counter keeps its
    /// home (count, err); absorb folds hot key 8 in as
    /// count = 25 + 4 = 29, err = 4. Cluster ε = max(10, 4) = 10,
    /// N = 140 + 25 = 165.
    #[test]
    fn keyed_merge_matches_hand_trace() {
        let w0 = WorkerSummary::try_from(wire(
            100,
            10,
            &[(4, 30, 0), (2, 60, 4)],
            &[(8, 25, 4)],
        ))
        .unwrap();
        let w1 = WorkerSummary::try_from(wire(40, 10, &[(5, 10, 0), (3, 25, 2)], &[])).unwrap();
        assert_eq!(w0.total_mass(), 125);

        let view = ClusterView::build(&[w0, w1], ClusterRouting::Keyed).unwrap();
        assert_eq!(view.n(), 165);
        assert_eq!(view.epsilon(), 10);
        assert_eq!(view.workers(), 2);
        assert!(!view.all_finished());

        let top = view.top_k(5);
        assert_eq!(top[0], Counter { item: 2, count: 60, err: 4 });
        assert_eq!(top[1], Counter { item: 4, count: 30, err: 0 });
        assert_eq!(top[2], Counter { item: 8, count: 29, err: 4 });
        assert_eq!(top[3], Counter { item: 3, count: 25, err: 2 });

        let p = view.point(8);
        assert!(p.monitored);
        assert_eq!(p.estimate, 29);
        assert_eq!(p.guaranteed, 25);
        // Unmonitored: both workers under-full → bound 0.
        let p = view.point(99);
        assert!(!p.monitored);
        assert_eq!(p.estimate, 0);

        // k-majority at k=5: threshold = 165/5 = 33. Guaranteed needs
        // lower bound > 33: item 2 (60−4=56) qualifies; item 4
        // (estimate 30) is below threshold entirely.
        let rep = view.k_majority(5);
        assert_eq!(rep.threshold, 33);
        assert_eq!(rep.guaranteed.len(), 1);
        assert_eq!(rep.guaranteed[0].item, 2);
        assert!(rep.possible.is_empty());
    }

    /// Hand-traced block-merge oracle.
    ///
    /// Both workers k=2, saturated. Worker 0: n=20, counters
    /// {1: (12, 0), 2: (8, 0)} → min_count 8. Worker 1: n=15, counters
    /// {1: (9, 0), 3: (6, 0)} → min_count 6.
    ///
    /// Algorithm 2 combine: item 1 in both → 12 + 9 = 21, err 0;
    /// item 2 only in S1 → 8 + m2 = 8 + 6 = 14, err 6 + 0 = 6;
    /// item 3 only in S2 → 6 + m1 = 6 + 8 = 14, err 8 + 0 = 8.
    /// k=2 keeps the top two by count: 21 and one of the 14s — combine
    /// breaks the tie deterministically (item id). n = 35.
    /// Cluster ε = 20/2 + 15/2 = 10 + 7 = 17; unmonitored bound
    /// = 8 + 6 = 14.
    #[test]
    fn block_merge_matches_hand_trace() {
        let w0 = WorkerSummary::try_from(wire(20, 2, &[(2, 8, 0), (1, 12, 0)], &[])).unwrap();
        let w1 = WorkerSummary::try_from(wire(15, 2, &[(3, 6, 0), (1, 9, 0)], &[])).unwrap();
        let view = ClusterView::build(&[w0, w1], ClusterRouting::Block).unwrap();

        assert_eq!(view.n(), 35);
        assert_eq!(view.epsilon(), 17);
        let top = view.top_k(2);
        assert_eq!(top[0], Counter { item: 1, count: 21, err: 0 });
        assert_eq!(top[1].count, 14);

        let p = view.point(99);
        assert!(!p.monitored);
        assert_eq!(p.estimate, 14, "block unmonitored bound is the sum of worker bounds");
    }

    /// Degraded merges: lost slots are skipped but stay accounted.
    /// Same workers as the keyed hand trace plus a dead third slot —
    /// every estimate and the survivor-only ε must match the 2-worker
    /// trace, with the view flagged degraded.
    #[test]
    fn degraded_merge_covers_survivors_and_says_so() {
        let w0 = WorkerSummary::try_from(wire(
            100,
            10,
            &[(4, 30, 0), (2, 60, 4)],
            &[(8, 25, 4)],
        ))
        .unwrap();
        let w1 = WorkerSummary::try_from(wire(40, 10, &[(5, 10, 0), (3, 25, 2)], &[])).unwrap();

        let full = ClusterView::build(&[w0.clone(), w1.clone()], ClusterRouting::Keyed).unwrap();
        assert!(!full.degraded());
        assert_eq!(full.workers_total(), 2);

        let view = ClusterView::build(
            &[w0.clone(), w1.clone(), WorkerSummary::lost()],
            ClusterRouting::Keyed,
        )
        .unwrap();
        assert!(view.degraded());
        assert_eq!(view.workers_total(), 3);
        assert_eq!(view.workers_live(), 2);
        assert_eq!(view.workers(), 2);
        assert_eq!(view.n(), full.n(), "dead slots contribute no mass");
        assert_eq!(view.epsilon(), full.epsilon(), "ε is survivor-only (max over live)");
        assert_eq!(view.top_k(5), full.top_k(5));
        assert_eq!(view.point(8).estimate, 29);

        // Block routing: the dead slot must also be exempt from the
        // equal-budget check (its placeholder k=1 would trip it), and
        // ε sums over survivors only.
        let b0 = WorkerSummary::try_from(wire(20, 2, &[(2, 8, 0), (1, 12, 0)], &[])).unwrap();
        let b1 = WorkerSummary::try_from(wire(15, 2, &[(3, 6, 0), (1, 9, 0)], &[])).unwrap();
        let view =
            ClusterView::build(&[b0, WorkerSummary::lost(), b1], ClusterRouting::Block).unwrap();
        assert!(view.degraded());
        assert_eq!(view.n(), 35);
        assert_eq!(view.epsilon(), 17, "Σ over live εᵢ only");

        // Zero survivors cannot produce a view.
        assert_eq!(
            ClusterView::build(&[WorkerSummary::lost()], ClusterRouting::Keyed).unwrap_err(),
            ClusterError::NoWorkers
        );
    }

    #[test]
    fn block_merge_rejects_mismatched_budgets() {
        let w0 = WorkerSummary::try_from(wire(20, 2, &[(1, 12, 0), (2, 8, 0)], &[])).unwrap();
        let w1 = WorkerSummary::try_from(wire(15, 4, &[(1, 9, 0)], &[])).unwrap();
        let e = ClusterView::build(&[w0, w1], ClusterRouting::Block).unwrap_err();
        assert_eq!(e, ClusterError::MismatchedBudget { expected: 2, got: 4, worker: 1 });
        assert_eq!(
            ClusterView::build(&[], ClusterRouting::Keyed).unwrap_err(),
            ClusterError::NoWorkers
        );
    }

    /// Flat and tree combine agree on mass and on every estimate (the
    /// per-counter `err` may differ — association order changes which
    /// `min_count` each absorbed counter pays — but both stay within
    /// the additive bound).
    #[test]
    fn flat_and_tree_combine_agree_on_mass() {
        let mk = |n: u64, a: (u64, u64), b: (u64, u64)| {
            Summary::new(
                2,
                n,
                vec![Counter::exact(a.0, a.1), Counter::exact(b.0, b.1)],
            )
        };
        let parts = [
            mk(20, (1, 12), (2, 8)),
            mk(15, (1, 9), (3, 6)),
            mk(10, (2, 7), (4, 3)),
            mk(12, (1, 8), (5, 4)),
        ];
        let refs: Vec<&Summary> = parts.iter().collect();
        let flat = flat_combine(&refs);
        let tree = tree_combine(&refs);
        assert_eq!(flat.n(), 57);
        assert_eq!(tree.n(), 57);
        assert_eq!(flat.k(), 2);
        assert_eq!(tree.k(), 2);
        // Item 1 is monitored everywhere it appears: both strategies
        // must estimate at least its true mass 29.
        let est = |s: &Summary| s.counters().iter().find(|c| c.item == 1).map(|c| c.count);
        assert!(est(&flat).unwrap() >= 29);
        assert!(est(&tree).unwrap() >= 29);
    }
}
