//! Multi-process hierarchical aggregation — the paper's hybrid
//! MPI/OpenMP decomposition, running for real.
//!
//! The in-process stack already implements the "OpenMP node": a
//! `Coordinator` fans a stream across shared-memory shards and the
//! query engine merges their epoch summaries. This module adds the
//! outer level: a **head** process drives `P` **worker** processes,
//! each a full serve-layer server, and aggregates their summaries over
//! the wire.
//!
//! ```text
//!                 ┌──────────── head ────────────┐
//!                 │ partition → P ingest streams │
//!                 │ poll/drain ← P snapshots     │
//!                 │ merge_disjoint / tree combine│
//!                 │ + absorb exact hot partials  │
//!                 └──┬────────────┬───────────┬──┘
//!          IngestRuns│ Summary    │           │
//!                    ▼ Snapshot   ▼           ▼
//!               worker 0      worker 1 …  worker P−1
//!             (Coordinator  (Coordinator (Coordinator
//!              × shards)     × shards)    × shards)
//! ```
//!
//! * [`snapshot`] — [`WorkerSummary`] (validated wire state),
//!   [`ClusterView`] (the merged, queryable cluster answer) and the
//!   [`flat_combine`]/[`tree_combine`] merge strategies with the
//!   routing-dependent ε bound (`maxᵢ εᵢ` keyed, `Σᵢ εᵢ` block).
//! * [`head`] — [`ClusterHead`]: spawn or connect workers, partition
//!   ingest, poll live views, drain to a final [`ClusterDrain`].
//! * [`worker`] — [`run_worker`]: bind a server, serve until the head
//!   drains it.

pub mod head;
pub mod snapshot;
pub mod worker;

pub use head::{ClusterDrain, ClusterHead, Supervision, WorkerExit, MAX_SNAP_FAILURES};
pub use snapshot::{
    flat_combine, tree_combine, ClusterError, ClusterRouting, ClusterView, SnapshotError,
    WorkerSummary,
};
pub use worker::run_worker;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::serve::{Endpoint, ServeConfig};

    fn worker_thread(
        sock: std::path::PathBuf,
    ) -> std::thread::JoinHandle<crate::Result<(crate::coordinator::QueryResult, crate::serve::ServeStats)>>
    {
        std::thread::spawn(move || {
            run_worker(
                &Endpoint::Unix(sock),
                ServeConfig {
                    coordinator: CoordinatorConfig {
                        shards: 2,
                        k: 64,
                        k_majority: 8,
                        epoch_items: 100,
                        ..Default::default()
                    },
                    query_threads: 1,
                    ..Default::default()
                },
                |_| {},
            )
        })
    }

    fn wait_ready(eps: &[Endpoint]) {
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        for ep in eps {
            loop {
                match ep.connect() {
                    Ok(_) => break,
                    Err(e) => {
                        assert!(std::time::Instant::now() < deadline, "worker never bound: {e}");
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                }
            }
        }
    }

    /// Head ↔ two in-process workers over unix sockets, keyed routing:
    /// keys partition by `shard_of(item, 2)`, the drained view
    /// conserves mass, and both worker servers return cleanly.
    #[test]
    fn head_drives_two_workers_end_to_end() {
        let dir = crate::util::TempDir::new().unwrap();
        let socks = [dir.path().join("w0.sock"), dir.path().join("w1.sock")];
        let h0 = worker_thread(socks[0].clone());
        let h1 = worker_thread(socks[1].clone());
        let eps = [Endpoint::Unix(socks[0].clone()), Endpoint::Unix(socks[1].clone())];
        wait_ready(&eps);

        let mut head = ClusterHead::connect(&eps, ClusterRouting::Keyed).unwrap();
        assert_eq!(head.processes(), 2);
        // 2000 items over a small universe; weights make the heavy
        // hitters unambiguous.
        let runs: Vec<(u64, u64)> = (0..20u64).map(|i| (i, 100 - i)).collect();
        let total: u64 = runs.iter().map(|r| r.1).sum();
        head.send_runs(&runs).unwrap();

        let drained = head.drain().unwrap();
        assert_eq!(drained.view.n(), total, "no mass lost across processes");
        assert_eq!(drained.mass_lost, 0);
        assert!(drained.view.all_finished());
        assert!(!drained.view.degraded());
        assert_eq!(drained.workers.len(), 2);
        for w in &drained.workers {
            assert!(w.live);
            assert!(w.snapshot.as_ref().expect("live workers carry a snapshot").finished);
            assert!(w.status.is_none(), "connected (not spawned) workers have no status");
        }
        // Under-full everywhere → every estimate is exact.
        let top = drained.view.top_k(3);
        assert_eq!(top[0].item, 0);
        assert_eq!(top[0].count, 100);
        assert_eq!(top[0].err, 0);
        let p = drained.view.point(5);
        assert_eq!(p.estimate, 95);

        let (r0, _) = h0.join().unwrap().unwrap();
        let (r1, _) = h1.join().unwrap().unwrap();
        assert_eq!(r0.stats.items + r1.stats.items, total);
        // Keyed partition really was disjoint: each item landed on its
        // shard_of home only.
        for (items, worker) in [(&r0, 0usize), (&r1, 1usize)] {
            for c in items.summary.counters() {
                assert_eq!(crate::util::shard_of(c.item, 2), worker);
            }
        }
    }
}
