//! The cluster head: owns one connection pair per worker process
//! (ingest + snapshot), partitions the stream, and merges worker
//! snapshots into a [`ClusterView`].
//!
//! Workers are plain `serve::Server` processes — the head either
//! spawns them locally over unix sockets ([`ClusterHead::spawn_local`],
//! the `pss cluster --processes P` path) or connects to already-running
//! ones ([`ClusterHead::connect`], `--workers host:port,...`). Either
//! way the wire is the same: `IngestItems`/`IngestRuns` down, v2
//! `SummaryRequest` → `SummarySnapshot` back, and a final
//! `drain: true` exchange that stops each worker and collects its
//! drained state.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use super::snapshot::{ClusterRouting, ClusterView, WorkerSummary};
use crate::metrics::{CacheCounters, CacheStats};
use crate::serve::{Endpoint, IngestClient, SnapshotClient, WireSnapshot};
use crate::util::shard_of;

/// One worker process as the head sees it: its endpoint, the two live
/// connections, and — when the head spawned it — the child process
/// handle.
struct WorkerLink {
    endpoint: Endpoint,
    ingest: Option<IngestClient>,
    snap: Option<SnapshotClient>,
    child: Option<Child>,
}

impl Drop for WorkerLink {
    fn drop(&mut self) {
        // A worker that was drained cleanly has already exited; this
        // is the abnormal path (head error / panic) — don't leave
        // orphan processes behind.
        if let Some(mut child) = self.child.take() {
            if child.try_wait().ok().flatten().is_none() {
                let _ = child.kill();
                let _ = child.wait();
            }
        }
    }
}

/// The final state of one worker after a head-initiated drain.
#[derive(Debug)]
pub struct WorkerExit {
    /// The worker's endpoint (for reporting).
    pub endpoint: Endpoint,
    /// Its final (`finished: true`) snapshot.
    pub snapshot: WireSnapshot,
    /// Exit status, for workers the head spawned (`None` for workers
    /// it only connected to — they own their own lifecycle).
    pub status: Option<std::process::ExitStatus>,
}

/// The result of draining a cluster: the merged final view plus each
/// worker's exit record.
#[derive(Debug)]
pub struct ClusterDrain {
    /// Merged view over every worker's final snapshot.
    pub view: ClusterView,
    /// Per-worker final snapshots and exit statuses.
    pub workers: Vec<WorkerExit>,
}

/// Head-side handle over `P` worker processes.
pub struct ClusterHead {
    workers: Vec<WorkerLink>,
    routing: ClusterRouting,
    /// Round-robin cursor (block routing).
    next: usize,
    /// Per-worker staging buffers (keyed routing).
    staged: Vec<Vec<(u64, u64)>>,
    /// Last merged poll view, keyed by each worker's
    /// `(epoch, n, finished)` triple. A worker whose coordinator
    /// published nothing new answers the same snapshot again, so an
    /// unchanged key vector proves re-validating and re-merging would
    /// reproduce the cached view — the fetch still happens (it's the
    /// staleness probe), only the merge is skipped.
    poll_cache: Option<(Vec<(u64, u64, bool)>, ClusterView)>,
    /// Poll-cache accounting (`merges_avoided == hits` here: `poll`
    /// takes `&mut self`, so there is no concurrent-rebuild reuse).
    poll_counters: CacheCounters,
}

impl ClusterHead {
    /// Connect to already-running workers.
    pub fn connect(endpoints: &[Endpoint], routing: ClusterRouting) -> crate::Result<ClusterHead> {
        anyhow::ensure!(!endpoints.is_empty(), "a cluster needs at least one worker");
        let mut workers = Vec::with_capacity(endpoints.len());
        for ep in endpoints {
            workers.push(WorkerLink {
                endpoint: ep.clone(),
                ingest: Some(IngestClient::connect(ep)?),
                snap: Some(SnapshotClient::connect(ep)?),
                child: None,
            });
        }
        let staged = vec![Vec::new(); workers.len()];
        Ok(ClusterHead {
            workers,
            routing,
            next: 0,
            staged,
            poll_cache: None,
            poll_counters: CacheCounters::new(),
        })
    }

    /// Spawn `processes` local workers (`program cluster --worker
    /// --listen unix:<dir>/pss-worker-<i>.sock <worker_args...>`) and
    /// connect to them. `program` is the `pss` binary to exec —
    /// callers pass `std::env::current_exe()` (the CLI) or
    /// `env!("CARGO_BIN_EXE_pss")` (tests); taking it as a parameter
    /// keeps this spawnable from test binaries, whose own
    /// `current_exe` is not `pss`.
    pub fn spawn_local(
        program: &Path,
        dir: &Path,
        processes: usize,
        routing: ClusterRouting,
        worker_args: &[String],
    ) -> crate::Result<ClusterHead> {
        anyhow::ensure!(processes >= 1, "a cluster needs at least one worker");
        let mut links: Vec<(PathBuf, Child)> = Vec::with_capacity(processes);
        for i in 0..processes {
            let sock = dir.join(format!("pss-worker-{i}.sock"));
            let _ = std::fs::remove_file(&sock);
            let child = Command::new(program)
                .arg("cluster")
                .arg("--worker")
                .arg("--listen")
                .arg(format!("unix:{}", sock.display()))
                .args(worker_args)
                .stdin(Stdio::null())
                .spawn()
                .map_err(|e| anyhow::Error::msg(format!("spawning worker {i}: {e}")))?;
            links.push((sock, child));
        }

        let deadline = Instant::now() + Duration::from_secs(10);
        let mut workers = Vec::with_capacity(processes);
        for (i, (sock, mut child)) in links.into_iter().enumerate() {
            // The worker binds before it prints anything, so readiness
            // is simply "the socket accepts" — retry until the
            // deadline, failing fast if the child already died.
            let endpoint = Endpoint::Unix(sock);
            let ingest = loop {
                match IngestClient::connect(&endpoint) {
                    Ok(c) => break c,
                    Err(e) => {
                        if let Some(status) = child.try_wait().ok().flatten() {
                            anyhow::bail!("worker {i} exited before accepting: {status}");
                        }
                        anyhow::ensure!(
                            Instant::now() < deadline,
                            "worker {i} never came up: {e}"
                        );
                        std::thread::sleep(Duration::from_millis(10));
                    }
                }
            };
            let snap = SnapshotClient::connect(&endpoint)?;
            workers.push(WorkerLink {
                endpoint,
                ingest: Some(ingest),
                snap: Some(snap),
                child: Some(child),
            });
        }
        let staged = vec![Vec::new(); workers.len()];
        Ok(ClusterHead {
            workers,
            routing,
            next: 0,
            staged,
            poll_cache: None,
            poll_counters: CacheCounters::new(),
        })
    }

    /// Number of workers.
    pub fn processes(&self) -> usize {
        self.workers.len()
    }

    /// How ingest is partitioned.
    pub fn routing(&self) -> ClusterRouting {
        self.routing
    }

    /// Worker endpoints, in worker order.
    pub fn endpoints(&self) -> Vec<Endpoint> {
        self.workers.iter().map(|w| w.endpoint.clone()).collect()
    }

    /// Route one chunk of weighted runs to the cluster. Keyed routing
    /// partitions each run to its item's home worker
    /// (`shard_of(item, P)` — the same hash the in-process keyed
    /// router uses); block routing ships the whole chunk to the next
    /// worker round-robin.
    pub fn send_runs(&mut self, runs: &[(u64, u64)]) -> crate::Result<()> {
        match self.routing {
            ClusterRouting::Block => {
                let w = self.next;
                self.next = (self.next + 1) % self.workers.len();
                self.ingest_mut(w)?.send_runs(runs)
            }
            ClusterRouting::Keyed => {
                let p = self.workers.len();
                for buf in &mut self.staged {
                    buf.clear();
                }
                for &(item, weight) in runs {
                    self.staged[shard_of(item, p)].push((item, weight));
                }
                // take/put-back so the staged buffers and the clients
                // can be borrowed simultaneously.
                let staged = std::mem::take(&mut self.staged);
                let mut res = Ok(());
                for (w, buf) in staged.iter().enumerate() {
                    if buf.is_empty() {
                        continue;
                    }
                    res = self.ingest_mut(w).and_then(|c| c.send_runs(buf));
                    if res.is_err() {
                        break;
                    }
                }
                self.staged = staged;
                res
            }
        }
    }

    /// Route one chunk of unit-weight items ([`ClusterHead::send_runs`]
    /// with weight 1 semantics, without materializing runs on the
    /// block path).
    pub fn send_items(&mut self, items: &[u64]) -> crate::Result<()> {
        match self.routing {
            ClusterRouting::Block => {
                let w = self.next;
                self.next = (self.next + 1) % self.workers.len();
                self.ingest_mut(w)?.send_items(items)
            }
            ClusterRouting::Keyed => {
                let runs: Vec<(u64, u64)> = items.iter().map(|&i| (i, 1)).collect();
                self.send_runs(&runs)
            }
        }
    }

    /// Pull a live snapshot from every worker and merge. Workers
    /// refresh their epoch view on each request, so repeated polls
    /// converge on the ingested mass once epochs publish.
    ///
    /// Polls always fetch (that is the staleness probe), but when every
    /// worker answers the same `(epoch, n, finished)` triple as the
    /// previous poll, the head skips validation + merge and clones the
    /// cached [`ClusterView`] instead ([`ClusterHead::poll_cache_stats`]).
    pub fn poll(&mut self) -> crate::Result<ClusterView> {
        let routing = self.routing;
        let mut snaps = Vec::with_capacity(self.workers.len());
        for (i, w) in self.workers.iter_mut().enumerate() {
            let snap = w
                .snap
                .as_mut()
                .ok_or_else(|| anyhow::Error::msg(format!("worker {i} already drained")))?
                .fetch(false)?;
            snaps.push(snap);
        }
        let key: Vec<(u64, u64, bool)> =
            snaps.iter().map(|s| (s.epoch, s.n, s.finished)).collect();
        if let Some((cached_key, view)) = &self.poll_cache {
            if *cached_key == key {
                self.poll_counters.record_hit();
                self.poll_counters.record_merge_avoided();
                return Ok(view.clone());
            }
        }
        let mut parts = Vec::with_capacity(snaps.len());
        for snap in snaps {
            parts.push(WorkerSummary::try_from(snap).map_err(anyhow::Error::msg)?);
        }
        let view = ClusterView::build(&parts, routing).map_err(anyhow::Error::msg)?;
        self.poll_counters.record_miss();
        self.poll_cache = Some((key, view.clone()));
        Ok(view)
    }

    /// Poll-cache accounting: hits are polls whose worker snapshots
    /// were identical to the previous poll's (merge skipped).
    pub fn poll_cache_stats(&self) -> CacheStats {
        self.poll_counters.stats()
    }

    /// Drain the cluster: flush and close every ingest connection,
    /// issue `SummaryRequest { drain: true }` to every worker, merge
    /// the final snapshots, and reap spawned children — asserting
    /// nothing ingested was lost (each worker's final snapshot is its
    /// drained coordinator state).
    pub fn drain(mut self) -> crate::Result<ClusterDrain> {
        let routing = self.routing;
        let mut exits = Vec::with_capacity(self.workers.len());
        let mut parts = Vec::with_capacity(self.workers.len());
        for (i, w) in self.workers.iter_mut().enumerate() {
            if let Some(ingest) = w.ingest.take() {
                ingest.finish()?;
            }
            let snap = w
                .snap
                .take()
                .ok_or_else(|| anyhow::Error::msg(format!("worker {i} already drained")))?
                .drain()?;
            let status = match w.child.take() {
                Some(mut child) => Some(child.wait()?),
                None => None,
            };
            parts.push(WorkerSummary::try_from(snap.clone()).map_err(anyhow::Error::msg)?);
            exits.push(WorkerExit { endpoint: w.endpoint.clone(), snapshot: snap, status });
        }
        let view = ClusterView::build(&parts, routing).map_err(anyhow::Error::msg)?;
        Ok(ClusterDrain { view, workers: exits })
    }

    fn ingest_mut(&mut self, w: usize) -> crate::Result<&mut IngestClient> {
        self.workers[w]
            .ingest
            .as_mut()
            .ok_or_else(|| anyhow::Error::msg(format!("worker {w} ingest already closed")))
    }
}
