//! The cluster head: owns one connection pair per worker process
//! (ingest + snapshot), partitions the stream, and merges worker
//! snapshots into a [`ClusterView`].
//!
//! Workers are plain `serve::Server` processes — the head either
//! spawns them locally over unix sockets ([`ClusterHead::spawn_local`],
//! the `pss cluster --processes P` path) or connects to already-running
//! ones ([`ClusterHead::connect`], `--workers host:port,...`). Either
//! way the wire is the same: `IngestItems`/`IngestRuns` down, v2
//! `SummaryRequest` → `SummarySnapshot` back, and a final
//! `drain: true` exchange that stops each worker and collects its
//! drained state.
//!
//! ## Supervision and degraded mode
//!
//! Every wire operation carries a deadline (the serve-layer clients),
//! so a dead or wedged worker surfaces as a typed error instead of
//! hanging the head. When that happens — an ingest send fails, the
//! spawned child exits, or [`MAX_SNAP_FAILURES`] consecutive snapshot
//! fetches fail — the head *retires* the worker: child killed and
//! reaped, stale unix socket unlinked, and every item ever sent to it
//! accounted in [`ClusterHead::mass_lost`]. Under
//! [`Supervision::Quarantine`] (default) the slot stays dead and
//! [`ClusterHead::poll`]/[`ClusterHead::drain`] proceed over the
//! survivors, yielding a [`ClusterView`] flagged
//! [`degraded`](ClusterView::degraded) with survivor-only ε; under
//! [`Supervision::Restart`] a spawned slot gets a fresh worker (the
//! dead one's mass is still lost — a fresh Space Saving summary cannot
//! recover evicted history).
//!
//! Keyed routing never re-routes a dead worker's key range: its items
//! are dropped (and accounted lost) because shipping them to a
//! survivor would break the key-disjointness [`merge_disjoint`]'s
//! ε = maxᵢ εᵢ bound rests on. Block routing simply skips dead slots
//! in the round-robin. Either way the conservation invariant the tests
//! pin is `view.n() + mass_lost == items sent`.
//!
//! [`merge_disjoint`]: crate::summary::merge_disjoint

use std::path::{Path, PathBuf};
use std::process::{Child, Command, ExitStatus, Stdio};
use std::time::{Duration, Instant};

use super::snapshot::{ClusterRouting, ClusterView, WorkerSummary};
use crate::metrics::{CacheCounters, CacheStats};
use crate::serve::{Endpoint, IngestClient, SnapshotClient, WireSnapshot};
use crate::util::{shard_of, Backoff};

/// Consecutive snapshot-fetch failures before a worker whose process
/// the head cannot observe (a `connect`ed remote) is declared dead.
/// Spawned children are declared dead as soon as `try_wait` reaps them.
pub const MAX_SNAP_FAILURES: u32 = 3;

/// What the head does with a worker it has declared dead.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Supervision {
    /// Leave the slot dead: polls and the drain proceed over the
    /// surviving subset and the merged view is flagged degraded.
    #[default]
    Quarantine,
    /// Spawn a fresh worker on the dead slot (spawned workers only —
    /// connected remotes are quarantined regardless). The dead
    /// worker's mass is still lost; the replacement takes over the
    /// slot's share of the stream from here on.
    Restart,
}

/// One worker process as the head sees it: its endpoint, the two live
/// connections, and — when the head spawned it — the child process
/// handle, plus the supervision state.
struct WorkerLink {
    endpoint: Endpoint,
    ingest: Option<IngestClient>,
    snap: Option<SnapshotClient>,
    child: Option<Child>,
    /// False once supervision declared this worker dead.
    alive: bool,
    /// Consecutive snapshot-fetch failures (reset on success).
    snap_failures: u32,
    /// Item mass written to this worker so far. If the worker dies,
    /// the whole figure moves to [`ClusterHead::mass_lost`]: its
    /// snapshot is discarded, so everything it was sent leaves the
    /// merged total.
    sent_mass: u64,
    /// Exit status captured when supervision reaped the child.
    status: Option<ExitStatus>,
}

impl WorkerLink {
    fn new(endpoint: Endpoint, ingest: IngestClient, snap: SnapshotClient, child: Option<Child>) -> Self {
        WorkerLink {
            endpoint,
            ingest: Some(ingest),
            snap: Some(snap),
            child,
            alive: true,
            snap_failures: 0,
            sent_mass: 0,
            status: None,
        }
    }

    /// Kill and reap the child if it is still running, returning its
    /// exit status when there was one to collect.
    fn reap(&mut self) -> Option<ExitStatus> {
        let mut child = self.child.take()?;
        if child.try_wait().ok().flatten().is_none() {
            let _ = child.kill();
        }
        child.wait().ok()
    }

    /// Remove the worker's unix socket file. Only meaningful for
    /// spawned workers (the head owns their sockets); a dead worker
    /// cannot unlink its own listener, and a stale file would wedge
    /// the next bind or a restart.
    fn unlink_socket(&self) {
        if let Endpoint::Unix(path) = &self.endpoint {
            let _ = std::fs::remove_file(path);
        }
    }
}

impl Drop for WorkerLink {
    fn drop(&mut self) {
        // A worker that was drained cleanly has already exited; this
        // is the abnormal path (head error / panic) — don't leave
        // orphan processes or their stale socket files behind.
        if self.child.is_some() {
            self.reap();
            self.unlink_socket();
        }
    }
}

/// The final state of one worker after a head-initiated drain.
#[derive(Debug)]
pub struct WorkerExit {
    /// The worker's endpoint (for reporting).
    pub endpoint: Endpoint,
    /// Its final (`finished: true`) snapshot — `None` for a worker
    /// that died before the drain could collect one.
    pub snapshot: Option<WireSnapshot>,
    /// Exit status, for workers the head spawned (`None` for workers
    /// it only connected to — they own their own lifecycle).
    pub status: Option<ExitStatus>,
    /// Whether the worker survived to contribute its final state.
    pub live: bool,
}

/// The result of draining a cluster: the merged final view plus each
/// worker's exit record.
#[derive(Debug)]
pub struct ClusterDrain {
    /// Merged view over every surviving worker's final snapshot
    /// (degraded if any worker died).
    pub view: ClusterView,
    /// Per-worker final snapshots and exit statuses.
    pub workers: Vec<WorkerExit>,
    /// Item mass sent to workers that died (discarded with their
    /// snapshots): `view.n() + mass_lost` = items the head sent.
    pub mass_lost: u64,
}

/// How to respawn a dead slot (recorded by
/// [`ClusterHead::spawn_local`]).
struct RespawnSpec {
    program: PathBuf,
    dir: PathBuf,
    worker_args: Vec<String>,
}

/// Head-side handle over `P` worker processes.
pub struct ClusterHead {
    workers: Vec<WorkerLink>,
    routing: ClusterRouting,
    supervision: Supervision,
    deadline: Duration,
    /// Item mass accounted to dead workers (their snapshots are
    /// discarded, so this mass leaves the merged total).
    mass_lost: u64,
    respawn: Option<RespawnSpec>,
    /// Round-robin cursor (block routing).
    next: usize,
    /// Per-worker staging buffers (keyed routing).
    staged: Vec<Vec<(u64, u64)>>,
    /// Last merged poll view, keyed by each worker's
    /// `(epoch, n, finished, alive)` tuple. A worker whose coordinator
    /// published nothing new answers the same snapshot again, so an
    /// unchanged key vector proves re-validating and re-merging would
    /// reproduce the cached view — the fetch still happens (it's the
    /// staleness probe), only the merge is skipped.
    poll_cache: Option<(Vec<(u64, u64, bool, bool)>, ClusterView)>,
    /// Poll-cache accounting (`merges_avoided == hits` here: `poll`
    /// takes `&mut self`, so there is no concurrent-rebuild reuse).
    poll_counters: CacheCounters,
}

impl ClusterHead {
    /// Connect to already-running workers.
    pub fn connect(endpoints: &[Endpoint], routing: ClusterRouting) -> crate::Result<ClusterHead> {
        anyhow::ensure!(!endpoints.is_empty(), "a cluster needs at least one worker");
        let deadline = crate::serve::client::DEFAULT_DEADLINE;
        let mut workers = Vec::with_capacity(endpoints.len());
        for ep in endpoints {
            workers.push(WorkerLink::new(
                ep.clone(),
                IngestClient::connect_with_deadline(ep, deadline)?,
                SnapshotClient::connect_with_deadline(ep, deadline)?,
                None,
            ));
        }
        Ok(Self::assemble(workers, routing, deadline, None))
    }

    /// Spawn `processes` local workers (`program cluster --worker
    /// --listen unix:<dir>/pss-worker-<i>.sock <worker_args...>`) and
    /// connect to them. `program` is the `pss` binary to exec —
    /// callers pass `std::env::current_exe()` (the CLI) or
    /// `env!("CARGO_BIN_EXE_pss")` (tests); taking it as a parameter
    /// keeps this spawnable from test binaries, whose own
    /// `current_exe` is not `pss`.
    pub fn spawn_local(
        program: &Path,
        dir: &Path,
        processes: usize,
        routing: ClusterRouting,
        worker_args: &[String],
    ) -> crate::Result<ClusterHead> {
        anyhow::ensure!(processes >= 1, "a cluster needs at least one worker");
        let deadline = crate::serve::client::DEFAULT_DEADLINE;
        let mut links: Vec<(PathBuf, Child)> = Vec::with_capacity(processes);
        for i in 0..processes {
            let sock = dir.join(format!("pss-worker-{i}.sock"));
            links.push((sock.clone(), spawn_worker(program, &sock, worker_args, i)?));
        }

        let mut workers = Vec::with_capacity(processes);
        for (i, (sock, mut child)) in links.into_iter().enumerate() {
            let endpoint = Endpoint::Unix(sock);
            let (ingest, snap) = match await_worker(&endpoint, &mut child, deadline, i) {
                Ok(pair) => pair,
                Err(e) => {
                    // Don't leak the siblings that did come up (their
                    // links aren't constructed yet, so Drop can't).
                    let _ = child.kill();
                    let _ = child.wait();
                    return Err(e);
                }
            };
            workers.push(WorkerLink::new(endpoint, ingest, snap, Some(child)));
        }
        let respawn = RespawnSpec {
            program: program.to_path_buf(),
            dir: dir.to_path_buf(),
            worker_args: worker_args.to_vec(),
        };
        Ok(Self::assemble(workers, routing, deadline, Some(respawn)))
    }

    fn assemble(
        workers: Vec<WorkerLink>,
        routing: ClusterRouting,
        deadline: Duration,
        respawn: Option<RespawnSpec>,
    ) -> ClusterHead {
        let staged = vec![Vec::new(); workers.len()];
        ClusterHead {
            workers,
            routing,
            supervision: Supervision::default(),
            deadline,
            mass_lost: 0,
            respawn,
            next: 0,
            staged,
            poll_cache: None,
            poll_counters: CacheCounters::new(),
        }
    }

    /// What to do with workers that die (default
    /// [`Supervision::Quarantine`]).
    pub fn with_supervision(mut self, supervision: Supervision) -> ClusterHead {
        self.supervision = supervision;
        self
    }

    /// Per-operation wire deadline for connections the head opens from
    /// here on (reconnects and restarts; the initial connections use
    /// the serve-layer default).
    pub fn with_deadline(mut self, deadline: Duration) -> ClusterHead {
        self.deadline = deadline;
        self
    }

    /// Number of worker slots (live and dead).
    pub fn processes(&self) -> usize {
        self.workers.len()
    }

    /// Worker slots still alive.
    pub fn live_workers(&self) -> usize {
        self.workers.iter().filter(|w| w.alive).count()
    }

    /// Item mass sent to workers that have since died (discarded with
    /// their snapshots), plus keyed-routing items dropped because
    /// their home worker is dead.
    pub fn mass_lost(&self) -> u64 {
        self.mass_lost
    }

    /// How ingest is partitioned.
    pub fn routing(&self) -> ClusterRouting {
        self.routing
    }

    /// Worker endpoints, in worker order.
    pub fn endpoints(&self) -> Vec<Endpoint> {
        self.workers.iter().map(|w| w.endpoint.clone()).collect()
    }

    /// OS pid of spawned worker `i` (`None` for connected remotes or
    /// dead slots). The fault-injection harness kills workers by pid
    /// to exercise supervision exactly as an external failure would.
    pub fn worker_pid(&self, i: usize) -> Option<u32> {
        self.workers.get(i).and_then(|w| w.child.as_ref()).map(|c| c.id())
    }

    /// Declare worker `i` dead: close its connections, kill and reap
    /// the child, unlink its socket, move its mass to `mass_lost` —
    /// then, under [`Supervision::Restart`] on a spawned slot, try to
    /// bring up a replacement.
    fn retire(&mut self, i: usize, why: &anyhow::Error) {
        let w = &mut self.workers[i];
        if !w.alive {
            return;
        }
        w.alive = false;
        w.ingest = None;
        w.snap = None;
        self.mass_lost += w.sent_mass;
        w.sent_mass = 0;
        let spawned = w.child.is_some();
        w.status = w.reap();
        if spawned {
            w.unlink_socket();
        }
        eprintln!(
            "cluster head: worker {i} ({}) retired after: {why}",
            self.workers[i].endpoint
        );
        self.poll_cache = None;
        if self.supervision == Supervision::Restart && spawned {
            if let Err(e) = self.restart(i) {
                eprintln!("cluster head: restarting worker {i} failed ({e}); quarantined");
            }
        }
    }

    /// Spawn a fresh worker on slot `i` and reconnect. The replacement
    /// starts empty: the dead worker's mass stays lost.
    fn restart(&mut self, i: usize) -> crate::Result<()> {
        let spec = self
            .respawn
            .as_ref()
            .ok_or_else(|| anyhow::Error::msg("no respawn spec (connected cluster)"))?;
        let sock = spec.dir.join(format!("pss-worker-{i}.sock"));
        let mut child = spawn_worker(&spec.program, &sock, &spec.worker_args, i)?;
        let endpoint = Endpoint::Unix(sock);
        let (ingest, snap) = match await_worker(&endpoint, &mut child, self.deadline, i) {
            Ok(pair) => pair,
            Err(e) => {
                let _ = child.kill();
                let _ = child.wait();
                return Err(e);
            }
        };
        let status = self.workers[i].status.take();
        self.workers[i] = WorkerLink::new(endpoint, ingest, snap, Some(child));
        // Keep the original exit status for the final report even
        // though the slot is live again.
        self.workers[i].status = status;
        Ok(())
    }

    /// Route one chunk of weighted runs to the cluster. Keyed routing
    /// partitions each run to its item's home worker
    /// (`shard_of(item, P)` — the same hash the in-process keyed
    /// router uses); block routing ships the whole chunk to the next
    /// live worker round-robin.
    ///
    /// A send that kills a worker does not fail the stream: the worker
    /// is retired, its mass accounted lost, and the call succeeds as
    /// long as at least one worker survives. Keyed routing drops (and
    /// accounts) runs homed on dead workers rather than re-routing
    /// them — re-routing would break the key-disjointness the keyed
    /// merge bound rests on.
    pub fn send_runs(&mut self, runs: &[(u64, u64)]) -> crate::Result<()> {
        match self.routing {
            ClusterRouting::Block => {
                let mass: u64 = runs.iter().map(|&(_, w)| w).sum();
                let w = self.next_live()?;
                self.next = (w + 1) % self.workers.len();
                self.workers[w].sent_mass += mass;
                if let Err(e) = self.send_to(w, |c| c.send_runs(runs)) {
                    self.retire(w, &e);
                    self.ensure_some_live()?;
                }
                Ok(())
            }
            ClusterRouting::Keyed => {
                let p = self.workers.len();
                for buf in &mut self.staged {
                    buf.clear();
                }
                for &(item, weight) in runs {
                    self.staged[shard_of(item, p)].push((item, weight));
                }
                // take/put-back so the staged buffers and the clients
                // can be borrowed simultaneously.
                let staged = std::mem::take(&mut self.staged);
                for (w, buf) in staged.iter().enumerate() {
                    if buf.is_empty() {
                        continue;
                    }
                    let mass: u64 = buf.iter().map(|&(_, wt)| wt).sum();
                    if !self.workers[w].alive {
                        // Dead home worker: the key range is lost.
                        self.mass_lost += mass;
                        continue;
                    }
                    self.workers[w].sent_mass += mass;
                    if let Err(e) = self.send_to(w, |c| c.send_runs(buf)) {
                        self.retire(w, &e);
                    }
                }
                self.staged = staged;
                self.ensure_some_live()
            }
        }
    }

    /// Route one chunk of unit-weight items ([`ClusterHead::send_runs`]
    /// with weight 1 semantics, without materializing runs on the
    /// block path).
    pub fn send_items(&mut self, items: &[u64]) -> crate::Result<()> {
        match self.routing {
            ClusterRouting::Block => {
                let w = self.next_live()?;
                self.next = (w + 1) % self.workers.len();
                self.workers[w].sent_mass += items.len() as u64;
                if let Err(e) = self.send_to(w, |c| c.send_items(items)) {
                    self.retire(w, &e);
                    self.ensure_some_live()?;
                }
                Ok(())
            }
            ClusterRouting::Keyed => {
                let runs: Vec<(u64, u64)> = items.iter().map(|&i| (i, 1)).collect();
                self.send_runs(&runs)
            }
        }
    }

    /// The next live slot at or after the round-robin cursor.
    fn next_live(&mut self) -> crate::Result<usize> {
        let p = self.workers.len();
        for step in 0..p {
            let w = (self.next + step) % p;
            if self.workers[w].alive {
                return Ok(w);
            }
        }
        anyhow::bail!("every worker is dead ({} lost items)", self.mass_lost)
    }

    fn ensure_some_live(&self) -> crate::Result<()> {
        anyhow::ensure!(
            self.workers.iter().any(|w| w.alive),
            "every worker is dead ({} lost items)",
            self.mass_lost
        );
        Ok(())
    }

    fn send_to(
        &mut self,
        w: usize,
        f: impl FnOnce(&mut IngestClient) -> crate::Result<()>,
    ) -> crate::Result<()> {
        let client = self.workers[w]
            .ingest
            .as_mut()
            .ok_or_else(|| anyhow::Error::msg(format!("worker {w} ingest already closed")))?;
        f(client)
    }

    /// Pull a live snapshot from every surviving worker and merge.
    /// Workers refresh their epoch view on each request, so repeated
    /// polls converge on the ingested mass once epochs publish. Dead
    /// workers contribute a lost placeholder, so the view reports
    /// `workers_live`/`workers_total` and flags itself degraded.
    ///
    /// A failed fetch closes that snapshot connection and reconnects
    /// on the next poll; [`MAX_SNAP_FAILURES`] consecutive failures
    /// (or a reaped child) retire the worker.
    ///
    /// Polls always fetch (that is the staleness probe), but when every
    /// worker answers the same `(epoch, n, finished, alive)` tuple as
    /// the previous poll, the head skips validation + merge and clones
    /// the cached [`ClusterView`] instead
    /// ([`ClusterHead::poll_cache_stats`]).
    pub fn poll(&mut self) -> crate::Result<ClusterView> {
        let routing = self.routing;
        let mut snaps: Vec<Option<WireSnapshot>> = Vec::with_capacity(self.workers.len());
        for i in 0..self.workers.len() {
            if !self.workers[i].alive {
                snaps.push(None);
                continue;
            }
            // A spawned child that exited is dead no matter how its
            // last fetch went.
            if let Some(child) = self.workers[i].child.as_mut() {
                if let Ok(Some(status)) = child.try_wait() {
                    self.retire(i, &anyhow::Error::msg(format!("process exited: {status}")));
                    snaps.push(None);
                    continue;
                }
            }
            match self.fetch_snapshot(i) {
                Ok(snap) => {
                    self.workers[i].snap_failures = 0;
                    snaps.push(Some(snap));
                }
                Err(e) => {
                    // The stream may be desynced mid-frame: drop the
                    // connection and reconnect on the next poll.
                    self.workers[i].snap = None;
                    self.workers[i].snap_failures += 1;
                    if self.workers[i].snap_failures >= MAX_SNAP_FAILURES {
                        self.retire(i, &e);
                    }
                    snaps.push(None);
                }
            }
        }
        let key: Vec<(u64, u64, bool, bool)> = snaps
            .iter()
            .zip(&self.workers)
            .map(|(s, w)| match s {
                Some(s) => (s.epoch, s.n, s.finished, w.alive),
                None => (0, 0, false, w.alive),
            })
            .collect();
        if let Some((cached_key, view)) = &self.poll_cache {
            if *cached_key == key {
                self.poll_counters.record_hit();
                self.poll_counters.record_merge_avoided();
                return Ok(view.clone());
            }
        }
        let mut parts = Vec::with_capacity(snaps.len());
        for snap in snaps {
            parts.push(match snap {
                Some(snap) => WorkerSummary::try_from(snap).map_err(anyhow::Error::msg)?,
                None => WorkerSummary::lost(),
            });
        }
        let view = ClusterView::build(&parts, routing).map_err(anyhow::Error::msg)?;
        self.poll_counters.record_miss();
        self.poll_cache = Some((key, view.clone()));
        Ok(view)
    }

    /// One snapshot fetch from worker `i`, reconnecting first if the
    /// previous poll dropped the connection.
    fn fetch_snapshot(&mut self, i: usize) -> crate::Result<WireSnapshot> {
        if self.workers[i].snap.is_none() {
            let snap =
                SnapshotClient::connect_with_deadline(&self.workers[i].endpoint, self.deadline)?;
            self.workers[i].snap = Some(snap);
        }
        self.workers[i]
            .snap
            .as_mut()
            .expect("just reconnected")
            .fetch(false)
    }

    /// Poll-cache accounting: hits are polls whose worker snapshots
    /// were identical to the previous poll's (merge skipped).
    pub fn poll_cache_stats(&self) -> CacheStats {
        self.poll_counters.stats()
    }

    /// Drain the cluster: flush and close every surviving ingest
    /// connection, issue `SummaryRequest { drain: true }` to every
    /// surviving worker, merge the final snapshots, and reap spawned
    /// children. Workers that died (before or during the drain) are
    /// recorded with `live: false` and their mass in `mass_lost`; the
    /// merged view covers the survivors and is flagged degraded.
    /// Conservation: `view.n() + mass_lost` = items sent.
    pub fn drain(mut self) -> crate::Result<ClusterDrain> {
        let routing = self.routing;
        let mut parts = Vec::with_capacity(self.workers.len());
        for i in 0..self.workers.len() {
            if !self.workers[i].alive {
                parts.push(None);
                continue;
            }
            let drained: crate::Result<WireSnapshot> = (|| {
                if let Some(ingest) = self.workers[i].ingest.take() {
                    ingest.finish()?;
                }
                self.workers[i]
                    .snap
                    .take()
                    .ok_or_else(|| anyhow::Error::msg(format!("worker {i} already drained")))?
                    .drain()
            })();
            match drained {
                Ok(snap) => {
                    let status = match self.workers[i].child.take() {
                        Some(mut child) => Some(child.wait()?),
                        None => None,
                    };
                    self.workers[i].status = status;
                    parts.push(Some(snap));
                }
                Err(e) => {
                    self.retire(i, &e);
                    // Restart supervision may have revived the slot,
                    // but a fresh worker has nothing to contribute to
                    // this final merge.
                    parts.push(None);
                }
            }
        }
        let mut exits = Vec::with_capacity(self.workers.len());
        let mut summaries = Vec::with_capacity(self.workers.len());
        for (w, snap) in self.workers.iter_mut().zip(&parts) {
            summaries.push(match snap {
                Some(snap) => {
                    WorkerSummary::try_from(snap.clone()).map_err(anyhow::Error::msg)?
                }
                None => WorkerSummary::lost(),
            });
            exits.push(WorkerExit {
                endpoint: w.endpoint.clone(),
                snapshot: snap.clone(),
                status: w.status.take(),
                live: snap.is_some(),
            });
        }
        let view = ClusterView::build(&summaries, routing).map_err(anyhow::Error::msg)?;
        Ok(ClusterDrain { view, workers: exits, mass_lost: self.mass_lost })
    }
}

/// Exec one worker process listening on `sock`.
fn spawn_worker(
    program: &Path,
    sock: &Path,
    worker_args: &[String],
    i: usize,
) -> crate::Result<Child> {
    let _ = std::fs::remove_file(sock);
    Command::new(program)
        .arg("cluster")
        .arg("--worker")
        .arg("--listen")
        .arg(format!("unix:{}", sock.display()))
        .args(worker_args)
        .stdin(Stdio::null())
        .spawn()
        .map_err(|e| anyhow::Error::msg(format!("spawning worker {i}: {e}")))
}

/// Wait for a just-spawned worker to accept, with capped-exponential
/// backoff between probes, failing fast if the child already died.
/// The worker binds before it prints anything, so readiness is simply
/// "the socket accepts".
fn await_worker(
    endpoint: &Endpoint,
    child: &mut Child,
    deadline: Duration,
    i: usize,
) -> crate::Result<(IngestClient, SnapshotClient)> {
    let give_up = Instant::now() + Duration::from_secs(10);
    let mut backoff = Backoff::new(Duration::from_millis(5), Duration::from_millis(200), i as u64);
    let ingest = loop {
        match IngestClient::connect_with_deadline(endpoint, deadline) {
            Ok(c) => break c,
            Err(e) => {
                if let Some(status) = child.try_wait().ok().flatten() {
                    anyhow::bail!("worker {i} exited before accepting: {status}");
                }
                anyhow::ensure!(Instant::now() < give_up, "worker {i} never came up: {e}");
                backoff.sleep();
            }
        }
    };
    let snap = SnapshotClient::connect_with_deadline(endpoint, deadline)?;
    Ok((ingest, snap))
}
