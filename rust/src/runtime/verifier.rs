//! Offline candidate verification on the PJRT artifacts.
//!
//! Paper §1: in the off-line setting "a parallel scan of the input can be
//! used to determine the actual frequent items" and discard false
//! positives. That scan is exactly what the AOT-compiled
//! `verify_counts` program does (DESIGN.md §Hardware-Adaptation): the
//! coordinator hands it the stream in fixed-shape super-chunks and the
//! ≤K reported candidates, and gets back exact frequencies — used for
//! false-positive pruning and for ARE/precision reports without an
//! `O(distinct)` hash map.

use crate::summary::Counter;
use crate::Result;

use super::client::Runtime;

/// Maximum item id the i32 artifact interface can carry.
pub const MAX_ITEM: u64 = (i32::MAX as u64) - 1;

/// Exact-count verification report for a reported candidate set.
#[derive(Debug, Clone)]
pub struct VerifiedReport {
    /// `(item, estimated f̂, exact f)` for each reported counter.
    pub rows: Vec<(u64, u64, u64)>,
    /// Confirmed frequent items (exact `f > n/k`), descending by `f`.
    pub confirmed: Vec<Counter>,
    /// Average relative error of the estimates against exact counts.
    pub are: f64,
    /// Precision: confirmed / reported.
    pub precision: f64,
}

/// Pad-and-encode helpers (pure; unit-tested without PJRT).
pub mod encode {
    /// Encode item ids to i32, validating the id range.
    pub fn items_to_i32(items: &[u64]) -> anyhow::Result<Vec<i32>> {
        items
            .iter()
            .map(|&x| {
                anyhow::ensure!(x <= super::MAX_ITEM, "item id {x} exceeds i32 artifact range");
                Ok(x as i32)
            })
            .collect()
    }

    /// Pad `v` to `len` with `pad`.
    pub fn pad_to(mut v: Vec<i32>, len: usize, pad: i32) -> Vec<i32> {
        debug_assert!(v.len() <= len);
        v.resize(len, pad);
        v
    }
}

/// The verifier: owns a [`Runtime`] and drives the fixed-shape programs.
pub struct Verifier {
    rt: Runtime,
}

impl Verifier {
    /// Open against an artifact directory.
    pub fn new(dir: &std::path::Path) -> Result<Self> {
        Ok(Self { rt: Runtime::new(dir)? })
    }

    /// Open against `$PSS_ARTIFACTS` / `./artifacts`.
    pub fn from_default_dir() -> Result<Self> {
        Ok(Self { rt: Runtime::from_default_dir()? })
    }

    /// Borrow the underlying runtime.
    pub fn runtime(&mut self) -> &mut Runtime {
        &mut self.rt
    }

    /// Exact frequency of every candidate in `items`, via the AOT
    /// verify programs (super-chunks of 16×65536, remainder via the
    /// 1×65536 program, final partial chunk padded with the stream
    /// sentinel). Candidates beyond one program's capacity are processed
    /// in batches.
    pub fn count(&mut self, items: &[u64], candidates: &[u64]) -> Result<Vec<u64>> {
        if candidates.is_empty() {
            return Ok(Vec::new());
        }
        let m = self.rt.manifest();
        let stream_pad = m.stream_pad;
        let cand_pad = m.candidate_pad;
        let big = m
            .best_verify(1, 16)
            .ok_or_else(|| anyhow::anyhow!("no 16-chunk verify artifact"))?
            .clone();
        let small = m
            .best_verify(1, 1)
            .ok_or_else(|| anyhow::anyhow!("no 1-chunk verify artifact"))?
            .clone();
        // Candidate batch capacity: the largest 16-chunk program.
        let cap = m
            .entries
            .iter()
            .filter(|e| e.kind == super::artifacts::ArtifactKind::Verify)
            .map(|e| e.k)
            .max()
            .unwrap_or(big.k);

        let enc_items = encode::items_to_i32(items)?;
        let mut totals = vec![0u64; candidates.len()];

        for (batch_idx, cand_batch) in candidates.chunks(cap).enumerate() {
            let base = batch_idx * cap;
            // Pick the smallest program that fits this batch, per shape.
            let m = self.rt.manifest();
            let big = m.best_verify(cand_batch.len(), 16).unwrap_or(&big).clone();
            let small = m.best_verify(cand_batch.len(), 1).unwrap_or(&small).clone();
            let cand_big = encode::pad_to(encode::items_to_i32(cand_batch)?, big.k, cand_pad);
            let cand_small =
                encode::pad_to(encode::items_to_i32(cand_batch)?, small.k, cand_pad);

            let super_len = big.chunks * big.chunk_len;
            let mut pos = 0usize;
            // Full super-chunks through the 16-chunk program.
            while pos + super_len <= enc_items.len() {
                let counts =
                    self.rt
                        .run_verify(&big.name, &enc_items[pos..pos + super_len], &cand_big)?;
                for (t, c) in totals[base..base + cand_batch.len()]
                    .iter_mut()
                    .zip(&counts)
                {
                    *t += *c as u64;
                }
                pos += super_len;
            }
            // Remainder through the 1-chunk program, padding the tail.
            while pos < enc_items.len() {
                let take = (enc_items.len() - pos).min(small.chunk_len);
                let chunk = encode::pad_to(
                    enc_items[pos..pos + take].to_vec(),
                    small.chunk_len,
                    stream_pad,
                );
                let counts = self.rt.run_verify(&small.name, &chunk, &cand_small)?;
                for (t, c) in totals[base..base + cand_batch.len()]
                    .iter_mut()
                    .zip(&counts)
                {
                    *t += *c as u64;
                }
                pos += take;
            }
        }
        Ok(totals)
    }

    /// Verify a reported summary against the stream: exact counts,
    /// false-positive pruning at threshold `n/k_majority`, ARE.
    pub fn verify_report(
        &mut self,
        items: &[u64],
        reported: &[Counter],
        k_majority: u64,
    ) -> Result<VerifiedReport> {
        let cands: Vec<u64> = reported.iter().map(|c| c.item).collect();
        let exact = self.count(items, &cands)?;
        let n = items.len() as u64;
        let thresh = n / k_majority;

        let rows: Vec<(u64, u64, u64)> = reported
            .iter()
            .zip(&exact)
            .map(|(c, &f)| (c.item, c.count, f))
            .collect();
        let mut confirmed: Vec<Counter> = rows
            .iter()
            .filter(|(_, _, f)| *f > thresh)
            .map(|&(item, _, f)| Counter { item, count: f, err: 0 })
            .collect();
        confirmed.sort_unstable_by(|a, b| b.count.cmp(&a.count).then(a.item.cmp(&b.item)));

        let are = if rows.is_empty() {
            0.0
        } else {
            rows.iter()
                .map(|&(_, est, f)| {
                    if f == 0 {
                        1.0
                    } else {
                        (est as f64 - f as f64).abs() / f as f64
                    }
                })
                .sum::<f64>()
                / rows.len() as f64
        };
        let precision = if rows.is_empty() {
            1.0
        } else {
            confirmed.len() as f64 / rows.len() as f64
        };
        Ok(VerifiedReport { rows, confirmed, are, precision })
    }
}

#[cfg(test)]
mod tests {
    use super::encode::*;

    #[test]
    fn encode_validates_range() {
        assert!(items_to_i32(&[0, 1, super::MAX_ITEM]).is_ok());
        assert!(items_to_i32(&[super::MAX_ITEM + 1]).is_err());
    }

    #[test]
    fn pad_fills_with_sentinel() {
        let v = pad_to(vec![1, 2, 3], 6, -2);
        assert_eq!(v, vec![1, 2, 3, -2, -2, -2]);
    }

    #[test]
    fn pad_noop_at_exact_len() {
        let v = pad_to(vec![1, 2], 2, -1);
        assert_eq!(v, vec![1, 2]);
    }
}
