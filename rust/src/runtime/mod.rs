//! PJRT runtime — the rust side of the AOT bridge.
//!
//! `make artifacts` runs python **once** (jax/Pallas → HLO text, see
//! `python/compile/aot.py`); this module loads those artifacts with the
//! `xla` crate (`PjRtClient::cpu` → `HloModuleProto::from_text_file` →
//! `compile` → `execute`) and serves them to the coordinator. Python is
//! never on the request path.
//!
//! * [`artifacts`] — manifest discovery and program selection.
//! * [`client`] — compile-once/execute-many PJRT wrapper.
//! * [`verifier`] — offline candidate verification (exact counts, false
//!   positive pruning, ARE) on the `verify_counts` program.

pub mod artifacts;
pub mod client;
pub mod verifier;
pub mod xla_shim;

pub use artifacts::{ArtifactEntry, ArtifactKind, Manifest};
pub use client::Runtime;
pub use verifier::{VerifiedReport, Verifier};
