//! PJRT client wrapper: load HLO-text artifacts, compile once, execute
//! many times from the rust side. Python never runs here — this is the
//! request path.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO **text** is the
//! interchange format (the text parser reassigns jax's 64-bit
//! instruction ids, which xla_extension 0.5.1's proto path rejects), and
//! programs are lowered with `return_tuple=True`, so results unwrap with
//! `to_tuple1`.

use std::collections::HashMap;
use std::path::Path;

use crate::Result;

use super::artifacts::{ArtifactEntry, Manifest};
// Offline builds use the API-compatible stub; swap for the real `xla`
// crate (and delete this line) when the PJRT native runtime is vendored.
use super::xla_shim as xla;

/// A compiled-program cache over one PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open the artifact directory and create the CPU client.
    pub fn new(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, manifest, cache: HashMap::new() })
    }

    /// Open from the default artifact directory.
    pub fn from_default_dir() -> Result<Self> {
        Self::new(&Manifest::default_dir())
    }

    /// The loaded manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (for diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&mut self, entry: &ArtifactEntry) -> Result<()> {
        if self.cache.contains_key(&entry.name) {
            return Ok(());
        }
        let path = self.manifest.path_of(entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.cache.insert(entry.name.clone(), exe);
        Ok(())
    }

    /// Execute a verify program: `chunks` is row-major `(C, B)` i32,
    /// `cands` is `(K,)` i32; returns the `(K,)` f32 counts.
    pub fn run_verify(
        &mut self,
        entry_name: &str,
        chunks: &[i32],
        cands: &[i32],
    ) -> Result<Vec<f32>> {
        let entry = self
            .manifest
            .entry(entry_name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact {entry_name}"))?
            .clone();
        anyhow::ensure!(
            chunks.len() == entry.chunks * entry.chunk_len,
            "chunks len {} != {}x{}",
            chunks.len(),
            entry.chunks,
            entry.chunk_len
        );
        anyhow::ensure!(cands.len() == entry.k, "cands len {} != {}", cands.len(), entry.k);
        self.compile(&entry)?;
        let exe = self.cache.get(&entry.name).expect("just compiled");

        let x = xla::Literal::vec1(chunks)
            .reshape(&[entry.chunks as i64, entry.chunk_len as i64])?;
        let y = xla::Literal::vec1(cands);
        let result = exe.execute::<xla::Literal>(&[x, y])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute a profile program: `(C, B)` i32 chunks → `(C, NB)` f32
    /// histograms (row-major).
    pub fn run_profile(&mut self, entry_name: &str, chunks: &[i32]) -> Result<Vec<f32>> {
        let entry = self
            .manifest
            .entry(entry_name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact {entry_name}"))?
            .clone();
        anyhow::ensure!(
            chunks.len() == entry.chunks * entry.chunk_len,
            "chunks len {} != {}x{}",
            chunks.len(),
            entry.chunks,
            entry.chunk_len
        );
        self.compile(&entry)?;
        let exe = self.cache.get(&entry.name).expect("just compiled");

        let x = xla::Literal::vec1(chunks)
            .reshape(&[entry.chunks as i64, entry.chunk_len as i64])?;
        let result = exe.execute::<xla::Literal>(&[x])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}
