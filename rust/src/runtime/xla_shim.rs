//! Offline stub of the `xla` (xla-rs / PJRT) API surface used by
//! [`client`](super::client).
//!
//! The real PJRT native runtime (`xla_extension` shared library + the
//! `xla` crate) is not vendorable in an offline build, so this shim
//! mirrors the exact types and signatures the client uses and fails at
//! the earliest entry point — [`PjRtClient::cpu`] — with an actionable
//! error. Everything downstream of a client is therefore unreachable,
//! but still typechecks, keeping `client.rs` byte-for-byte compatible
//! with the real crate: restoring real PJRT execution is a matter of
//! adding the `xla` dependency and deleting the `use ... xla_shim as
//! xla` line.

use crate::Result;

fn unavailable() -> anyhow::Error {
    anyhow::anyhow!(
        "PJRT native runtime unavailable: this build uses the offline xla \
         shim (vendor the `xla` crate and the xla_extension library to \
         enable artifact execution)"
    )
}

/// Stub of `xla::PjRtClient`.
pub struct PjRtClient(());

impl PjRtClient {
    /// Always fails in the shim — there is no PJRT plugin to load.
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    /// Platform name (unreachable behind a failed [`PjRtClient::cpu`]).
    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    /// Compile a computation (unreachable in the shim).
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse HLO text (unreachable in the shim).
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable())
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a module proto.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with literal inputs (unreachable in the shim).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Device-to-host transfer (unreachable in the shim).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Stub of `xla::Literal`.
pub struct Literal(());

impl Literal {
    /// Build a rank-1 literal.
    pub fn vec1<T>(_values: &[T]) -> Self {
        Literal(())
    }

    /// Reshape (unreachable in the shim).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    /// Unwrap a 1-tuple result (unreachable in the shim).
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable())
    }

    /// Copy out as a typed vector (unreachable in the shim).
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_with_actionable_error() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("PJRT native runtime unavailable"), "{err}");
    }
}
