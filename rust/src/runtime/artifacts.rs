//! Artifact discovery: `artifacts/manifest.json` parsing.
//!
//! The manifest is written by `python/compile/aot.py` and describes each
//! lowered HLO-text program (shapes, padding sentinels) so the loader can
//! validate inputs before handing them to PJRT.

use std::path::{Path, PathBuf};

use crate::util::Json;
use crate::Result;

/// What a lowered program computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// `verify_counts`: (C,B) chunks × (K,) candidates → (K,) counts.
    Verify,
    /// `skew_profile`: (C,B) chunks → (C, NB) per-chunk histograms.
    Profile,
}

/// One manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// Program name (e.g. `verify_16x65536x2048`).
    pub name: String,
    /// Program kind.
    pub kind: ArtifactKind,
    /// Chunks per call (C).
    pub chunks: usize,
    /// Items per chunk (B).
    pub chunk_len: usize,
    /// Candidate slots (verify) — 0 for profile programs.
    pub k: usize,
    /// Histogram buckets (profile) — 0 for verify programs.
    pub num_buckets: usize,
    /// HLO text file name within the artifact dir.
    pub file: String,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Stream padding sentinel (never matches a candidate).
    pub stream_pad: i32,
    /// Candidate padding sentinel.
    pub candidate_pad: i32,
    /// All programs.
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("bad manifest: {e}"))?;
        anyhow::ensure!(
            j.get("format").and_then(|f| f.as_str()) == Some("hlo-text"),
            "unsupported artifact format"
        );
        let stream_pad = j
            .get("stream_pad")
            .and_then(|v| v.as_i64())
            .ok_or_else(|| anyhow::anyhow!("manifest missing stream_pad"))? as i32;
        let candidate_pad = j
            .get("candidate_pad")
            .and_then(|v| v.as_i64())
            .ok_or_else(|| anyhow::anyhow!("manifest missing candidate_pad"))? as i32;
        let mut entries = Vec::new();
        for e in j
            .get("entries")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing entries"))?
        {
            let s = |key: &str| e.get(key).and_then(|v| v.as_str()).map(str::to_string);
            let u = |key: &str| e.get(key).and_then(|v| v.as_u64()).unwrap_or(0) as usize;
            let kind = match s("kind").as_deref() {
                Some("verify") => ArtifactKind::Verify,
                Some("profile") => ArtifactKind::Profile,
                other => anyhow::bail!("unknown artifact kind {other:?}"),
            };
            entries.push(ArtifactEntry {
                name: s("name").ok_or_else(|| anyhow::anyhow!("entry missing name"))?,
                kind,
                chunks: u("chunks"),
                chunk_len: u("chunk_len"),
                k: u("k"),
                num_buckets: u("num_buckets"),
                file: s("file").ok_or_else(|| anyhow::anyhow!("entry missing file"))?,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), stream_pad, candidate_pad, entries })
    }

    /// The default artifact directory: `$PSS_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("PSS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Find an entry by name.
    pub fn entry(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// The verify program with the smallest candidate capacity ≥ `k`,
    /// preferring the requested super-chunk count.
    pub fn best_verify(&self, k: usize, chunks: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.kind == ArtifactKind::Verify && e.k >= k && e.chunks == chunks)
            .min_by_key(|e| e.k)
    }

    /// Absolute path of an entry's HLO text.
    pub fn path_of(&self, e: &ArtifactEntry) -> PathBuf {
        self.dir.join(&e.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::TempDir;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    const SAMPLE: &str = r#"{
      "format": "hlo-text", "stream_pad": -2, "candidate_pad": -1,
      "entries": [
        {"name": "verify_16x65536x2048", "kind": "verify", "chunks": 16,
         "chunk_len": 65536, "k": 2048, "file": "v16.hlo.txt"},
        {"name": "verify_16x65536x8192", "kind": "verify", "chunks": 16,
         "chunk_len": 65536, "k": 8192, "file": "v16b.hlo.txt"},
        {"name": "verify_1x65536x2048", "kind": "verify", "chunks": 1,
         "chunk_len": 65536, "k": 2048, "file": "v1.hlo.txt"},
        {"name": "profile_16x65536x1024", "kind": "profile", "chunks": 16,
         "chunk_len": 65536, "num_buckets": 1024, "file": "p.hlo.txt"}
      ]}"#;

    #[test]
    fn loads_and_selects() {
        let d = TempDir::new().unwrap();
        write_manifest(d.path(), SAMPLE);
        let m = Manifest::load(d.path()).unwrap();
        assert_eq!(m.stream_pad, -2);
        assert_eq!(m.entries.len(), 4);
        // Smallest capacity >= k.
        assert_eq!(m.best_verify(100, 16).unwrap().k, 2048);
        assert_eq!(m.best_verify(3000, 16).unwrap().k, 8192);
        assert!(m.best_verify(10_000, 16).is_none());
        assert_eq!(m.best_verify(100, 1).unwrap().name, "verify_1x65536x2048");
    }

    #[test]
    fn missing_dir_is_actionable() {
        let err = Manifest::load(Path::new("/nonexistent-dir-xyz")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn rejects_bad_format() {
        let d = TempDir::new().unwrap();
        write_manifest(d.path(), r#"{"format": "protobuf", "entries": []}"#);
        assert!(Manifest::load(d.path()).is_err());
    }
}
